//! Quickstart: train a tiny CoLA model for a handful of steps, evaluate
//! perplexity, checkpoint, and probe activation ranks — the whole public API
//! in ~40 lines.
//!
//!     make artifacts && cargo run --release --example quickstart

use cola::config::TrainConfig;
use cola::coordinator::Trainer;

fn main() -> anyhow::Result<()> {
    // 1. point at an AOT artifact (built by `make artifacts`)
    let cfg = TrainConfig {
        artifact: "tiny_cola".into(),
        steps: 40,
        eval_every: 20,
        eval_batches: 4,
        log_every: 10,
        out_dir: "runs/quickstart".into(),
        ..TrainConfig::default()
    };

    // 2. the trainer owns the PJRT state; python never runs here
    let mut trainer = Trainer::new(cfg)?;
    println!(
        "model: {} ({} params, variant={}, r={})",
        trainer.manifest().name,
        trainer.manifest().n_total_params,
        trainer.manifest().variant,
        trainer.manifest().rank,
    );

    // 3. train
    let report = trainer.run()?;
    println!(
        "trained {} steps: loss {:.3}, val ppl {:.2}, {:.0} tokens/s",
        report.steps, report.final_loss, report.val_ppl, report.tokens_per_sec
    );

    // 4. checkpoint + restore roundtrip
    let ckpt = std::path::Path::new("runs/quickstart/tiny_cola.npz");
    trainer.save_checkpoint(ckpt)?;
    trainer.load_checkpoint(ckpt)?;
    let ppl = trainer.evaluate(4)?;
    println!("after checkpoint roundtrip: val ppl {ppl:.2}");

    // 5. the paper's Fig. 2 analytics: effective rank of live activations
    for (tap, r, d) in trainer.rank_probe(0.95)? {
        println!("  effective rank r(0.95) @ {tap}: {r}/{d}");
    }
    Ok(())
}
