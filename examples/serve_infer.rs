//! Serving example: bring up a `ServicePool` (continuous batching + KV-cache
//! decode over the AOT artifacts) on a trained checkpoint, stream one
//! request token-by-token, then push a concurrent workload through the
//! bounded admission queue — the Table 11 measurement path as a library
//! consumer sees it.
//!
//!     cargo run --release --example serve_infer [artifact] [n_requests]

use cola::config::ServeConfig;
use cola::data::{corpus::CorpusCfg, CorpusGen};
use cola::metrics::{fmt_ms, percentile};
use cola::serve::{InferenceService, ServicePool, StreamEvent, SubmitOptions};
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let artifact = args.first().cloned().unwrap_or_else(|| "p350m_cola".into());
    let n_requests: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(32);

    let cfg = ServeConfig {
        artifact: artifact.clone(),
        max_new_tokens: 16,
        queue_depth: 16,
        ..ServeConfig::default()
    };
    let pool = ServicePool::start(cfg)?;

    let man = cola::runtime::ArtifactDir::open_named(&artifact)?.manifest;
    let bpe = cola::coordinator::trainer::shared_bpe(man.preset.vocab)?;
    let mut gen = CorpusGen::new(CorpusCfg { seed: 123, ..CorpusCfg::default() });

    // Streaming: tokens arrive as they decode (this first request also
    // compiles prefill+decode, so its time-to-first-token includes compile).
    let mut stream = pool
        .submit(bpe.encode(&gen.text(50)), SubmitOptions::default())
        .map_err(|e| anyhow::anyhow!("submit: {e}"))?;
    print!("streaming:");
    let completion = loop {
        match stream.recv() {
            Some(StreamEvent::Token(t)) => {
                // flush so the token-by-token arrival is actually visible
                print!(" {t}");
                std::io::Write::flush(&mut std::io::stdout())?;
            }
            Some(StreamEvent::Done(c)) => break c,
            None => anyhow::bail!("stream dropped"),
        }
    };
    println!(
        "\nwarmup: {} tokens ({:?}), text: {:?}",
        completion.tokens.len(),
        completion.finish_reason,
        bpe.decode(&completion.tokens)
    );

    // Concurrent workload: submit everything up front; the bounded queue
    // pushes back with QueueFull, which submit_wait rides out.
    let t0 = Instant::now();
    let mut streams = Vec::new();
    for _ in 0..n_requests {
        streams.push(pool.submit_wait(bpe.encode(&gen.text(50)), SubmitOptions::default())?);
    }
    let (mut total_tokens, mut lat, mut ttft) = (0usize, Vec::new(), Vec::new());
    for s in streams {
        let c = s.wait()?;
        total_tokens += c.tokens.len();
        lat.push(c.timing.total.as_secs_f64() * 1000.0);
        if let Some(t) = c.timing.first_token {
            ttft.push(t.as_secs_f64() * 1000.0);
        }
    }
    let secs = t0.elapsed().as_secs_f64();
    let stats = pool.stats();
    println!(
        "\n{n_requests} requests: {total_tokens} tokens in {secs:.2}s = {:.0} tok/s \
         (decode {:.0} tok/s)",
        total_tokens as f64 / secs.max(1e-9),
        stats.decode_tokens_per_sec
    );
    println!(
        "latency p50 {} | p90 {} | p99 {} | ttft p50 {} | engine RSS {:.2} GB",
        fmt_ms(percentile(&lat, 50.0)),
        fmt_ms(percentile(&lat, 90.0)),
        fmt_ms(percentile(&lat, 99.0)),
        fmt_ms(percentile(&ttft, 50.0)),
        cola::metrics::peak_rss_bytes() as f64 / 1e9
    );
    println!(
        "stats: submitted={} completed={} rejected={} active={}",
        stats.submitted, stats.completed, stats.rejected, stats.active
    );
    pool.shutdown();
    Ok(())
}
