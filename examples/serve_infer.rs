//! Serving example: bring up the inference engine (dynamic batcher +
//! KV-cache decode over the AOT artifacts) on a trained checkpoint and push
//! a concurrent workload through it, reporting latency percentiles and
//! throughput — the Table 11 measurement path as a library consumer sees it.
//!
//!     cargo run --release --example serve_infer [artifact] [n_requests]

use cola::config::ServeConfig;
use cola::data::{corpus::CorpusCfg, CorpusGen};
use cola::serve::Engine;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let artifact = args.first().cloned().unwrap_or_else(|| "p350m_cola".into());
    let n_requests: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(32);

    let cfg = ServeConfig {
        artifact: artifact.clone(),
        max_new_tokens: 16,
        max_wait_ms: 4,
    };
    let (engine, join) = Engine::spawn(cfg)?;

    let man = cola::runtime::ArtifactDir::open_named(&artifact)?.manifest;
    let bpe = cola::coordinator::trainer::shared_bpe(man.preset.vocab)?;
    let mut gen = CorpusGen::new(CorpusCfg { seed: 123, ..CorpusCfg::default() });

    // warmup: compiles prefill+decode once
    let w = engine.generate(bpe.encode(&gen.text(50)), 4)?;
    println!("warmup: {} tokens, decoded text: {:?}", w.tokens.len(), bpe.decode(&w.tokens));

    // concurrent workload from 4 client threads
    let t0 = Instant::now();
    let mut clients = Vec::new();
    for c in 0..4 {
        let engine = engine.clone();
        let bpe = bpe.clone();
        clients.push(std::thread::spawn(move || {
            let mut gen =
                CorpusGen::new(CorpusCfg { seed: 200 + c as u64, ..CorpusCfg::default() });
            let mut lat = Vec::new();
            let mut tokens = 0usize;
            for _ in 0..n_requests / 4 {
                let prompt = bpe.encode(&gen.text(50));
                let resp = engine.generate(prompt, 16).expect("generate");
                tokens += resp.tokens.len();
                lat.push(resp.latency.as_secs_f64() * 1000.0);
            }
            (lat, tokens)
        }));
    }
    let mut all_lat = Vec::new();
    let mut total_tokens = 0;
    for c in clients {
        let (lat, tokens) = c.join().unwrap();
        all_lat.extend(lat);
        total_tokens += tokens;
    }
    let secs = t0.elapsed().as_secs_f64();
    all_lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| all_lat[((all_lat.len() as f64 * p) as usize).min(all_lat.len() - 1)];
    println!(
        "\n{} requests from 4 clients: {total_tokens} tokens in {secs:.2}s = {:.0} tok/s",
        all_lat.len(),
        total_tokens as f64 / secs
    );
    println!(
        "latency p50 {:.0}ms | p90 {:.0}ms | p99 {:.0}ms | engine RSS {:.2} GB",
        pct(0.5),
        pct(0.9),
        pct(0.99),
        cola::metrics::peak_rss_bytes() as f64 / 1e9
    );
    drop(engine);
    let _ = join.join();
    Ok(())
}
