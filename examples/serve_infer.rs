//! Serving example: bring up a `ModelRouter` (named continuous-batching
//! pools with KV-cache decode over the AOT artifacts) on one or more
//! trained checkpoints, stream one request token-by-token, then push a
//! concurrent workload round-robin across the models through their bounded
//! admission queues — the Table 11 measurement path as a library consumer
//! sees it.
//!
//!     cargo run --release --example serve_infer [artifact[,artifact...]] [n_requests]

use cola::config::RouterConfig;
use cola::data::{corpus::CorpusCfg, CorpusGen};
use cola::metrics::{fmt_labels, fmt_ms, percentile};
use cola::serve::{ModelRouter, StreamEvent, SubmitOptions};
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut artifacts: Vec<String> = args
        .first()
        .map(|s| s.split(',').filter(|p| !p.is_empty()).map(String::from).collect())
        .unwrap_or_default();
    if artifacts.is_empty() {
        artifacts.push("p350m_cola".into());
    }
    let n_requests: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(32);

    // one pool per artifact, model name = artifact name
    let defaults = cola::config::ServeConfig {
        max_new_tokens: 16,
        queue_depth: 16,
        ..Default::default()
    };
    let models = artifacts
        .iter()
        .map(|a| {
            let cfg = cola::config::ServeConfig { artifact: a.clone(), ..defaults.clone() };
            (a.clone(), cfg)
        })
        .collect();
    let rcfg = RouterConfig { defaults, models };
    let router = ModelRouter::start(&rcfg)?;

    let mut encoders = Vec::new();
    for a in &artifacts {
        let man = cola::runtime::ArtifactDir::open_named(a)?.manifest;
        encoders.push(cola::coordinator::trainer::shared_bpe(man.preset.vocab)?);
    }
    let mut gen = CorpusGen::new(CorpusCfg { seed: 123, ..CorpusCfg::default() });

    // Streaming from the first model: tokens arrive as they decode (this
    // first request also compiles prefill+decode, so its time-to-first-token
    // includes compile).
    let mut stream = router
        .submit(&artifacts[0], encoders[0].encode(&gen.text(50)), SubmitOptions::default())
        .map_err(|e| anyhow::anyhow!("submit: {e}"))?;
    print!("streaming{}:", fmt_labels(&[("model", artifacts[0].as_str())]));
    let completion = loop {
        match stream.recv() {
            Some(StreamEvent::Token(t)) => {
                // flush so the token-by-token arrival is actually visible
                print!(" {t}");
                std::io::Write::flush(&mut std::io::stdout())?;
            }
            Some(StreamEvent::Done(c)) => break c,
            None => anyhow::bail!("stream dropped"),
        }
    };
    println!(
        "\nwarmup: {} tokens ({:?}), text: {:?}",
        completion.tokens.len(),
        completion.finish_reason,
        encoders[0].decode(&completion.tokens)
    );
    // warm the remaining models so the timed workload measures decode
    for (a, bpe) in artifacts.iter().zip(&encoders).skip(1) {
        let opts = SubmitOptions { max_new_tokens: Some(2), ..Default::default() };
        router.generate(a, bpe.encode(&gen.text(40)), opts)?;
    }

    // Concurrent workload round-robin across models: submit everything up
    // front. Each model's bounded queue pushes back with QueueFull, which
    // submit_wait rides out by sleeping — note this single submit thread
    // blocks on the full model, so a saturated queue briefly gates the
    // round-robin (a per-model submitter would avoid that; kept simple here).
    let t0 = Instant::now();
    let mut streams = Vec::new();
    for r in 0..n_requests {
        let which = r % artifacts.len();
        streams.push(router.submit_wait(
            &artifacts[which],
            encoders[which].encode(&gen.text(50)),
            SubmitOptions::default(),
        )?);
    }
    let (mut total_tokens, mut lat, mut ttft) = (0usize, Vec::new(), Vec::new());
    for s in streams {
        let c = s.wait()?;
        total_tokens += c.tokens.len();
        lat.push(c.timing.total.as_secs_f64() * 1000.0);
        if let Some(t) = c.timing.first_token {
            ttft.push(t.as_secs_f64() * 1000.0);
        }
    }
    let secs = t0.elapsed().as_secs_f64();
    let agg = router.aggregate_stats();
    println!(
        "\n{n_requests} requests across {} model(s): {total_tokens} tokens in {secs:.2}s = \
         {:.0} tok/s (decode {:.0} tok/s)",
        artifacts.len(),
        total_tokens as f64 / secs.max(1e-9),
        agg.decode_tokens_per_sec
    );
    println!(
        "latency p50 {} | p90 {} | p99 {} | ttft p50 {} | engine RSS {:.2} GB",
        fmt_ms(percentile(&lat, 50.0)),
        fmt_ms(percentile(&lat, 90.0)),
        fmt_ms(percentile(&lat, 99.0)),
        fmt_ms(percentile(&ttft, 50.0)),
        cola::metrics::peak_rss_bytes() as f64 / 1e9
    );
    for (name, s) in router.stats_by_model() {
        println!(
            "stats{}: submitted={} completed={} rejected={} active={}",
            fmt_labels(&[("model", name)]),
            s.submitted,
            s.completed,
            s.rejected,
            s.active
        );
    }
    router.shutdown();
    Ok(())
}
