//! End-to-end pre-training driver (the DESIGN.md validation workload):
//! trains the e2e-scale transformer (≈14M params full-rank / ≈7M CoLA — the
//! largest this single-CPU image pushes through hundreds of steps; see
//! DESIGN.md §6 for the scale substitution) for several hundred steps on the
//! streamed synthetic corpus, logging the loss curve, validation perplexity,
//! throughput and memory — for BOTH full-rank and CoLA so the headline
//! claim (on-par quality at ~half compute, higher throughput) is exercised
//! end to end through all three layers.
//!
//!     cargo run --release --example pretrain_e2e [steps] [variant...]
//!
//! Results land in EXPERIMENTS.md §E2E.

use cola::config::TrainConfig;
use cola::coordinator::Trainer;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(300);
    let variants: Vec<String> = if args.len() > 1 {
        args[1..].to_vec()
    } else {
        vec!["e2e_full".into(), "e2e_cola".into()]
    };

    let mut results = Vec::new();
    for artifact in &variants {
        println!("=== {artifact}: {steps} steps ===");
        let cfg = TrainConfig {
            artifact: artifact.clone(),
            steps,
            eval_every: (steps / 6).max(1),
            eval_batches: 4,
            log_every: (steps / 20).max(1),
            out_dir: "runs/e2e".into(),
            rank_probe_every: if artifact.contains("full") { steps / 2 } else { 0 },
            ..TrainConfig::default()
        };
        let mut tr = Trainer::new(cfg)?;
        let rep = tr.run()?;

        println!("\nloss curve ({artifact}):");
        for (s, l) in &rep.loss_curve {
            let bar = "#".repeat(((l - 3.0).max(0.0) * 12.0) as usize);
            println!("  step {s:>4}: {l:.3} {bar}");
        }
        println!("val ppl curve: {:?}", rep.val_curve);
        println!(
            "summary: loss {:.3} | val ppl {:.2} | {:.0} tok/s | peak RSS {:.2} GB\n",
            rep.final_loss,
            rep.val_ppl,
            rep.tokens_per_sec,
            rep.peak_rss_bytes as f64 / 1e9
        );

        // final checkpoint for the serving example
        let ckpt = std::path::PathBuf::from(format!("runs/e2e/{artifact}_final.npz"));
        tr.save_checkpoint(&ckpt)?;
        println!("checkpoint: {}", ckpt.display());
        results.push((artifact.clone(), rep));
    }

    if results.len() >= 2 {
        let (full, cola) = (&results[0].1, &results[1].1);
        println!("=== headline comparison (paper: on-par PPL, 1.86x train throughput) ===");
        println!(
            "full-rank: ppl {:.2} @ {:.0} tok/s | CoLA: ppl {:.2} @ {:.0} tok/s ({:.2}x)",
            full.val_ppl,
            full.tokens_per_sec,
            cola.val_ppl,
            cola.tokens_per_sec,
            cola.tokens_per_sec / full.tokens_per_sec
        );
        anyhow::ensure!(
            full.val_ppl < 0.8 * (full.loss_curve.first().map(|x| x.1).unwrap_or(9.0)).exp(),
            "training made no progress"
        );
    }
    Ok(())
}
