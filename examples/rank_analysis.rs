//! Activation-rank analysis (the paper's motivating §3.1 / Fig 2 / App A):
//! train a model briefly, then dump per-block singular-value spectra,
//! effective ranks at several α, and the cumulative-energy curves.
//!
//!     cargo run --release --example rank_analysis [artifact] [steps]

use cola::config::TrainConfig;
use cola::coordinator::{RankProbe, Trainer};
use cola::data::BatchIter;
use cola::linalg::spectrum_energy;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let artifact = args.first().cloned().unwrap_or_else(|| "p60m_full".into());
    let steps: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(150);

    let cfg = TrainConfig {
        artifact: artifact.clone(),
        steps,
        log_every: 50,
        ..TrainConfig::default()
    };
    let mut tr = Trainer::new(cfg)?;
    let rep = tr.run()?;
    println!("trained {artifact} to loss {:.3}\n", rep.final_loss);

    let man = tr.manifest().clone();
    let probe = RankProbe::new(&tr.art)?;
    let params = tr.params_literals()?;
    let client = cola::runtime::client()?;
    let bufs: Vec<xla::PjRtBuffer> = params
        .iter()
        .map(|l| client.buffer_from_host_literal(None, l))
        .collect::<Result<_, _>>()?;

    let bpe = cola::coordinator::trainer::shared_bpe(man.preset.vocab)?;
    let mut it = BatchIter::new(bpe, 31337, man.preset.vocab);
    let toks = it.next_eval(2, man.preset.seq_len + 1);
    let spectra = probe.spectra(&bufs, &toks, 0.95)?;

    println!("effective rank vs alpha (paper Eq. 1):");
    println!(
        "{:>10} {:>6} {:>8} {:>8} {:>8} {:>8}",
        "tap", "dim", "r(0.80)", "r(0.90)", "r(0.95)", "r(0.99)"
    );
    for s in &spectra {
        let r = |a: f64| cola::linalg::effective_rank(&s.singular_values, a);
        println!(
            "{:>10} {:>6} {:>8} {:>8} {:>8} {:>8}",
            s.name,
            s.full_dim,
            r(0.80),
            r(0.90),
            r(0.95),
            r(0.99)
        );
    }

    println!("\ncumulative spectral energy (Fig 2a), per tap:");
    for s in &spectra {
        let e = spectrum_energy(&s.singular_values);
        let marks: Vec<String> = [0.1, 0.25, 0.5, 0.75]
            .iter()
            .map(|&f| {
                let k = ((s.singular_values.len() as f64 * f) as usize).max(1) - 1;
                format!("top{:.0}%={:.0}%", f * 100.0, e[k] * 100.0)
            })
            .collect();
        println!("  {:>10}: {}", s.name, marks.join("  "));
    }
    println!("\n(untrained-vs-trained comparison: rerun with steps=0)");
    Ok(())
}
