//! σ-placement ablation driver (Table 10): trains the four CoLA variants at
//! the tiny scale (fast) and prints the PPL ordering. The full p60m version
//! lives in `cargo bench --bench table10_ablation`.
//!
//!     cargo run --release --example ablation_sigma [steps]

use cola::config::TrainConfig;
use cola::coordinator::Trainer;

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(60);

    let variants = [
        ("tiny_cola_both", "both sigma (AE sigma + original)"),
        ("tiny_cola", "low-rank sigma only (Eq. 3, default)"),
        ("tiny_cola_reduced", "low-rank sigma only where original had one"),
        ("tiny_cola_fullrank_only", "plain BA factorization + original sigma"),
    ];

    println!("sigma-placement ablation, tiny scale, {steps} steps:");
    let mut rows = Vec::new();
    for (artifact, desc) in variants {
        let cfg = TrainConfig {
            artifact: artifact.into(),
            steps,
            eval_batches: 4,
            log_every: 0,
            ..TrainConfig::default()
        };
        let mut tr = Trainer::new(cfg)?;
        let rep = tr.run()?;
        println!("  {:<28} ppl {:>8.2}  ({desc})", artifact, rep.val_ppl);
        rows.push((artifact, rep.val_ppl));
    }

    rows.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    println!("\nranking (best -> worst): ");
    for (a, p) in &rows {
        println!("  {a}: {p:.2}");
    }
    println!("\npaper's Table 10 @60M: both 34.04 | lowrank 34.35 | reduced 35.41 | fullrank-only 36.26");
    Ok(())
}
