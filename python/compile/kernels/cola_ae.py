"""Layer-1 Pallas kernels: the fused CoLA auto-encoder  y = B · σ(A · x).

This is the paper's compute hot-spot — after the CoLA rewrite, *every* linear
layer in the transformer is this auto-encoder, so one fused kernel covers the
entire GEMM budget of the model.

TPU mapping (DESIGN.md §Hardware-Adaptation): the paper's CUDA view (cuBLAS
GEMM pair + PyTorch checkpointing) becomes a Pallas kernel that tiles tokens
into MXU-friendly blocks, keeps A and B resident in VMEM, computes the
r-dimensional bottleneck u = x_blk·A into a VMEM scratch tile, applies σ
in-register, and immediately consumes it for the up-projection — the
full-width intermediate never exists, and the only tensor worth saving for
the backward pass is the r-dimensional pre-activation. That *is* the CoLA-M
insight, expressed at kernel level.

Autodiff: `pl.pallas_call` has no reverse-mode rule, so `cola_ae` carries a
`jax.custom_vjp` whose residuals are exactly (x, A, B, u) with u ∈ R^{N×r} —
the paper's "save only the low-rank activations". The backward pass fuses
ds = (g·Bᵀ)·σ'(u) in a second Pallas kernel (token-parallel), while the two
weight-gradient reductions dA = xᵀ·ds and dB = σ(u)ᵀ·g stay in XLA (they are
plain GEMM reductions the MXU/compiler already handles optimally).

On this CPU-only image kernels run `interpret=True` (Mosaic custom-calls
cannot execute on the CPU PJRT plugin); numerics are identical and the kernel
lowers into the same HLO module the rust runtime loads.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import cola_ae_ref, sigma

#: Token-block size. 128 matches the MXU systolic tile; shapes smaller than
#: one block fall back to a single-program grid.
DEFAULT_BLOCK_N = 128


def _pad_tokens(x2, blk):
    n = x2.shape[0]
    pad = (-n) % blk
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    return x2, n, n + pad


# ---------------------------------------------------------------------------
# Forward kernel
# ---------------------------------------------------------------------------

def _ae_fwd_kernel(x_ref, a_ref, b_ref, o_ref, u_ref, *, act: str):
    """One grid step: u = x_blk·A (VMEM), o = σ(u)·B."""
    x = x_ref[...]
    u = jnp.dot(x, a_ref[...], preferred_element_type=jnp.float32)
    u_ref[...] = u
    o_ref[...] = jnp.dot(sigma(act)(u), b_ref[...],
                         preferred_element_type=jnp.float32)


def _ae_forward(x2, a, b, act: str, block_n: int, interpret: bool):
    """Flattened forward returning (y, u) — u is the saved low-rank tensor."""
    d_in, r = a.shape
    _, d_out = b.shape
    blk = min(block_n, x2.shape[0])
    x2p, n, n_pad = _pad_tokens(x2, blk)

    y, u = pl.pallas_call(
        functools.partial(_ae_fwd_kernel, act=act),
        grid=(n_pad // blk,),
        in_specs=[
            pl.BlockSpec((blk, d_in), lambda i: (i, 0)),
            pl.BlockSpec((d_in, r), lambda i: (0, 0)),
            pl.BlockSpec((r, d_out), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((blk, d_out), lambda i: (i, 0)),
            pl.BlockSpec((blk, r), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad, d_out), x2.dtype),
            jax.ShapeDtypeStruct((n_pad, r), x2.dtype),
        ],
        interpret=interpret,
    )(x2p, a, b)
    return y[:n], u[:n]


# ---------------------------------------------------------------------------
# Backward kernel: token-parallel part of the VJP
# ---------------------------------------------------------------------------

def _ae_bwd_kernel(g_ref, u_ref, a_ref, b_ref, dx_ref, ds_ref, *, act: str):
    """ds = (g·Bᵀ) ⊙ σ'(u);  dx = ds·Aᵀ  — both per token block."""
    g = g_ref[...]
    u = u_ref[...]
    dz = jnp.dot(g, b_ref[...].T, preferred_element_type=jnp.float32)
    # elementwise σ' via jvp of the scalar nonlinearity (exact, traced once)
    _, ds = jax.jvp(sigma(act), (u,), (dz,))
    ds_ref[...] = ds
    dx_ref[...] = jnp.dot(ds, a_ref[...].T, preferred_element_type=jnp.float32)


def _ae_backward(g2, u2, x2, a, b, act: str, block_n: int, interpret: bool):
    d_in, r = a.shape
    _, d_out = b.shape
    blk = min(block_n, g2.shape[0])
    g2p, n, n_pad = _pad_tokens(g2, blk)
    u2p, _, _ = _pad_tokens(u2, blk)

    dx, ds = pl.pallas_call(
        functools.partial(_ae_bwd_kernel, act=act),
        grid=(n_pad // blk,),
        in_specs=[
            pl.BlockSpec((blk, d_out), lambda i: (i, 0)),
            pl.BlockSpec((blk, r), lambda i: (i, 0)),
            pl.BlockSpec((d_in, r), lambda i: (0, 0)),
            pl.BlockSpec((r, d_out), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((blk, d_in), lambda i: (i, 0)),
            pl.BlockSpec((blk, r), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad, d_in), g2.dtype),
            jax.ShapeDtypeStruct((n_pad, r), g2.dtype),
        ],
        interpret=interpret,
    )(g2p, u2p, a, b)
    dx, ds = dx[:n], ds[:n]
    # weight-gradient GEMM reductions: best left to XLA (MXU-native).
    da = x2.T @ ds
    db = sigma(act)(u2).T @ g2
    return dx, da, db


# ---------------------------------------------------------------------------
# custom_vjp wrapper
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _make_ae(act: str, block_n: int, interpret: bool):
    @jax.custom_vjp
    def ae(x2, a, b):
        y, _ = _ae_forward(x2, a, b, act, block_n, interpret)
        return y

    def fwd(x2, a, b):
        y, u = _ae_forward(x2, a, b, act, block_n, interpret)
        # residuals: inputs + the r-dim pre-activation (low-rank only)
        return y, (x2, a, b, u)

    def bwd(res, g):
        x2, a, b, u = res
        dx, da, db = _ae_backward(g, u, x2, a, b, act, block_n, interpret)
        return dx, da, db

    ae.defvjp(fwd, bwd)
    return ae


def cola_ae(x: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray,
            act: str = "silu", block_n: int = DEFAULT_BLOCK_N,
            interpret: bool = True) -> jnp.ndarray:
    """Fused auto-encoder over arbitrary leading dims.

    x: [..., d_in] → [..., d_out];  a: [d_in, r];  b: [r, d_out].
    Differentiable (custom VJP, low-rank residuals — see module docstring).
    """
    d_in, r = a.shape
    r2, d_out = b.shape
    assert r == r2, f"rank mismatch: A gives {r}, B takes {r2}"
    assert x.shape[-1] == d_in, (x.shape, a.shape)

    lead = x.shape[:-1]
    n = 1
    for s in lead:
        n *= s
    x2 = x.reshape(n, d_in)
    y = _make_ae(act, block_n, interpret)(x2, a, b)
    return y.reshape(*lead, d_out)


def cola_ae_dispatch(x, a, b, act: str = "silu", use_kernel: bool = True,
                     block_n: int = DEFAULT_BLOCK_N):
    """Kernel/oracle dispatch used by the L2 model.

    ``use_kernel=False`` selects the pure-jnp oracle path (identical numerics,
    verified by pytest); sweep configs may use it to keep interpret-mode HLO
    small when many grid steps would be unrolled.
    """
    if use_kernel:
        return cola_ae(x, a, b, act=act, block_n=block_n)
    return cola_ae_ref(x, a, b, act)


def vmem_plan(d_in: int, r: int, d_out: int, block_n: int = DEFAULT_BLOCK_N,
              bytes_per_el: int = 2) -> dict:
    """Estimate the kernel's VMEM footprint per grid step (real-TPU planning;
    mirrored by ``rust/src/costmodel`` for DESIGN.md §7)."""
    a_tile = d_in * r * bytes_per_el
    b_tile = r * d_out * bytes_per_el
    x_tile = block_n * d_in * bytes_per_el
    u_tile = block_n * r * bytes_per_el
    o_tile = block_n * d_out * bytes_per_el
    total = a_tile + b_tile + x_tile + u_tile + o_tile
    return {
        "a_tile": a_tile, "b_tile": b_tile, "x_tile": x_tile,
        "u_tile": u_tile, "o_tile": o_tile, "total": total,
        "fits_16mib": total <= 16 * 1024 * 1024,
    }
