"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each function here is the mathematical definition; `cola_ae.py` must match it
under f32 (pytest + hypothesis enforce allclose with tight tolerances).
"""

import jax
import jax.numpy as jnp

_SIGMAS = {
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
    "relu": jax.nn.relu,
    "identity": lambda x: x,
}


def sigma(name: str):
    """Look up a nonlinearity by name (shared with the kernel side)."""
    return _SIGMAS[name]


def cola_ae_ref(x: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray,
                act: str = "silu") -> jnp.ndarray:
    """CoLA auto-encoder, Eq. (3) of the paper:  h' = B · σ(A · x).

    x: [..., d_in]; a: [d_in, r]; b: [r, d_out]  (row-major, x @ A @ B).
    The r-dimensional intermediate is the low-rank activation CoLA-M
    checkpoints.
    """
    z = sigma(act)(x @ a)
    return z @ b


def cola_ae_bottleneck_ref(x: jnp.ndarray, a: jnp.ndarray,
                           act: str = "silu") -> jnp.ndarray:
    """Just the encoder half σ(A·x) — the saved activation in CoLA-M."""
    return sigma(act)(x @ a)


def cola_swiglu_mlp_ref(x, a_gate, b_gate, a_up, b_up, a_down, b_down,
                        act: str = "silu"):
    """CoLA LLaMA MLP: gate/up/down projections each replaced by an AE;
    the element-wise product stays in the original d_ff dimension (Fig. 4)."""
    g = cola_ae_ref(x, a_gate, b_gate, act)
    u = cola_ae_ref(x, a_up, b_up, act)
    h = g * u
    return cola_ae_ref(h, a_down, b_down, act)


def full_linear_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Full-rank baseline linear: x @ W  (W: [d_in, d_out])."""
    return x @ w
