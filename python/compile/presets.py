"""Model/training presets — the single source of truth for artifact geometry.

Two families:

* paper-scale presets (``llama60m`` .. ``llama7b``) — used only by the
  analytic cost model (mirrored in ``rust/src/costmodel/presets.rs``); we never
  lower artifacts for them on this single-CPU image.
* proxy presets (``tiny`` .. ``p1b``, ``e2e``, ``bert``) — the models we
  actually AOT-lower and train end-to-end.  They keep the paper's geometry
  ratios (d_ff = 8/3·d rounded to a multiple of 16, r = d/4 by default,
  head_dim = d / n_heads) so every FLOPs/memory *ratio* from the paper's
  analysis carries over.
"""

from dataclasses import dataclass, field, asdict


def _ffw(d: int) -> int:
    """LLaMA-style d_ff = 8/3 * d, rounded up to a multiple of 16."""
    raw = (8 * d) // 3
    return ((raw + 15) // 16) * 16


@dataclass
class Preset:
    name: str
    d: int                      # model width
    n_layers: int
    n_heads: int
    vocab: int
    seq_len: int
    d_ff: int = 0               # 0 -> 8/3 * d
    rank: int = 0               # 0 -> d // 4 (the paper's default r = d/4)
    batch: int = 8              # sequences per train step
    n_micro: int = 1            # in-graph microbatches (grad accumulation)
    # training hyper-parameters (paper App. D: lr 3e-3 class, wd 0.01 class)
    lr: float = 3e-3
    warmup_frac: float = 0.1
    total_steps: int = 400
    weight_decay: float = 0.01
    grad_clip: float = 0.5
    seed: int = 0
    is_encoder: bool = False    # BERT-proxy (MLM objective, no causal mask)

    def __post_init__(self):
        if self.d_ff == 0:
            self.d_ff = _ffw(self.d)
        if self.rank == 0:
            self.rank = max(8, self.d // 4)
        assert self.d % self.n_heads == 0, "head_dim must divide d"

    @property
    def head_dim(self) -> int:
        return self.d // self.n_heads

    def to_dict(self) -> dict:
        d = asdict(self)
        d["d_ff"] = self.d_ff
        d["rank"] = self.rank
        d["head_dim"] = self.head_dim
        return d


# ---------------------------------------------------------------------------
# Proxy presets actually lowered + trained on this image (1 CPU core).
# ---------------------------------------------------------------------------
PRESETS: dict[str, Preset] = {}


def _reg(p: Preset) -> Preset:
    PRESETS[p.name] = p
    return p


# Smoke/test scale: sub-second artifacts, used by pytest + quickstart.
_reg(Preset("tiny", d=64, n_layers=2, n_heads=4, vocab=512, seq_len=64,
            batch=4, total_steps=60, lr=6e-3))

# Proxy ladder mirroring the paper's 60M/130M/350M/1B (Tables 5 & 7).
_reg(Preset("p60m", d=128, n_layers=4, n_heads=4, vocab=1024, seq_len=128,
            batch=8, total_steps=400, lr=6e-3))
_reg(Preset("p130m", d=192, n_layers=6, n_heads=6, vocab=2048, seq_len=128,
            batch=8, total_steps=400, lr=3e-3))
_reg(Preset("p350m", d=256, n_layers=8, n_heads=8, vocab=2048, seq_len=128,
            batch=8, total_steps=400, lr=3e-3))
_reg(Preset("p1b", d=384, n_layers=10, n_heads=8, vocab=4096, seq_len=128,
            batch=8, total_steps=300, lr=2e-3))

# End-to-end driver scale (examples/pretrain_e2e.rs): the largest model this
# single core can push through a few hundred steps.
_reg(Preset("e2e", d=384, n_layers=6, n_heads=8, vocab=4096, seq_len=256,
            batch=4, total_steps=300, lr=2e-3))

# BERT-Large proxy (Table 8): encoder + MLM.
_reg(Preset("bert", d=192, n_layers=6, n_heads=6, vocab=2048, seq_len=128,
            batch=8, total_steps=400, lr=3e-3, is_encoder=True))


# ---------------------------------------------------------------------------
# Control presets (Table 7): full-rank scaled down to ~CoLA's FLOPs by
# shrinking width/depth, exactly as the paper's "Control" row.
# ---------------------------------------------------------------------------
_reg(Preset("p60m_control", d=96, n_layers=3, n_heads=4, vocab=1024,
            seq_len=128, batch=8, total_steps=400, lr=6e-3))
_reg(Preset("p130m_control", d=144, n_layers=4, n_heads=6, vocab=2048,
            seq_len=128, batch=8, total_steps=400, lr=3e-3))
_reg(Preset("p350m_control", d=192, n_layers=5, n_heads=8, vocab=2048,
            seq_len=128, batch=8, total_steps=400, lr=3e-3))


# Variant knobs --------------------------------------------------------------

#: Table 10 sigma-placement modes.
SIGMA_MODES = ("lowrank_only", "both", "reduced", "fullrank_only")

#: All supported architecture/training variants.
VARIANTS = (
    "full",        # full-rank LLaMA baseline
    "gcp",         # full-rank + vanilla block-level gradient checkpointing
    "cola",        # CoLA auto-encoders everywhere, sigma per sigma_mode
    "cola_m",      # CoLA + save-only-low-rank remat (CoLA-M)
    "lora",        # frozen W0 + trainable BA (ReLoRA's pure low-rank stage)
    "galore",      # full-rank arch, low-rank-projected Adam states
    "sltrain",     # low-rank BA + fixed-support sparse residual
)


def paper_rank_for(d: int, compute_frac: float) -> int:
    """Invert the paper's compute model to pick r for a target compute ratio.

    C_CoLA/C_full ≈ (48dr + 18r(d+dff)) / (24d² + 18d·dff) for the GEMM terms
    (attention SDP cancels).  Default r=d/4 gives ≈0.4–0.5×; Table 7's 0.7×
    rows bump r accordingly.
    """
    dff = _ffw(d)
    denom = 24 * d * d + 18 * d * dff
    r = compute_frac * denom / (48 * d + 18 * (d + dff))
    return max(8, int(r / 8) * 8)
