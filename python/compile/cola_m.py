"""CoLA-M (§4) and vanilla-GCP checkpointing as jax.checkpoint policies.

The paper's CoLA-M saves *only the low-rank activations* (the red circles in
Fig. 4 — each AE's r-dimensional σ(A·x)) plus block boundaries, and recomputes
the up-projections B·z and the attention SDP during the backward pass
(Table 4: memory 2nd+7nr, recompute ≈ C_CoLA/2 of the forward).

Mapping to JAX:
* every bottleneck tensor is tagged ``checkpoint_name(z, "lowrank")`` in
  ``model.linear_tagged``;
* each decoder block is wrapped in ``jax.checkpoint`` with
  ``save_only_these_names("lowrank")`` — so the saved set is exactly {block
  inputs} ∪ {low-rank activations}, and everything in the original width d
  (B·z outputs, attention scores, softmax, residual sums) is recomputed.
* vanilla GCP is the same wrapper with ``nothing_saveable`` (saves only block
  boundaries, recomputes the whole block — Eq. 15/16).

Note on the Pallas kernel: inside ``pl.pallas_call`` the bottleneck never
leaves VMEM, so there is nothing to checkpoint at the JAX level; CoLA-M
therefore uses the mathematically identical tagged jnp path (pytest verifies
equality), and the kernel remains the lowering used for the plain ``cola``
variant's forward/backward.
"""

import jax

from . import model as M


def _core(cfg, params, lname, x, pos, causal):
    return M.block(cfg, params, lname, x, pos, causal, M.linear_tagged)[0]


def _core_plain(cfg, params, lname, x, pos, causal):
    return M.block(cfg, params, lname, x, pos, causal, M.linear)[0]


def block_fn_for(cfg: M.ModelCfg):
    """Return the block function matching cfg.variant's memory strategy."""
    causal = not cfg.preset.is_encoder

    if cfg.variant == "cola_m":
        policy = jax.checkpoint_policies.save_only_these_names("lowrank")

        def bf(cfg_, params, lname, x, pos):
            fn = lambda pr, xx: _core(cfg_, pr, lname, xx, pos, causal)
            return jax.checkpoint(fn, policy=policy)(params, x)
        return bf

    if cfg.variant == "gcp":
        def bf(cfg_, params, lname, x, pos):
            fn = lambda pr, xx: _core_plain(cfg_, pr, lname, xx, pos, causal)
            return jax.checkpoint(
                fn, policy=jax.checkpoint_policies.nothing_saveable)(params, x)
        return bf

    # no remat: plain block with the variant's linear (kernel-backed for cola)
    def bf(cfg_, params, lname, x, pos):
        return M.block(cfg_, params, lname, x, pos, causal, M.linear)[0]
    return bf
