"""In-graph optimizers. The whole update (schedule, clipping, AdamW/GaLore)
lowers into train_step.hlo.txt so the rust hot path never computes math.

State layout contract with the rust runtime (see aot.py):
  train_step(state..., step, tokens) -> (state'..., loss, grad_norm)
with `state` an opaque ordered list; rust swaps outputs into inputs.
"""

import jax
import jax.numpy as jnp

from .model import ModelCfg, is_frozen


def cosine_lr(cfg: ModelCfg, step):
    """Warmup + cosine annealing (Loshchilov & Hutter), as the paper App. D."""
    p = cfg.preset
    warm = max(1.0, p.warmup_frac * p.total_steps)
    total = float(p.total_steps)
    lr_warm = p.lr * (step + 1.0) / warm
    prog = jnp.clip((step - warm) / jnp.maximum(total - warm, 1.0), 0.0, 1.0)
    lr_cos = 0.1 * p.lr + 0.9 * p.lr * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < warm, lr_warm, lr_cos)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(g * g) for g in jax.tree_util.tree_leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-6))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), gn


# ---------------------------------------------------------------------------
# AdamW (used by every variant except galore)
# ---------------------------------------------------------------------------

def adamw_init(cfg: ModelCfg, params: dict) -> dict:
    """m/v zeros for every trainable param."""
    st = {}
    for k, v in params.items():
        if is_frozen(cfg, k):
            continue
        st[f"m::{k}"] = jnp.zeros_like(v)
        st[f"v::{k}"] = jnp.zeros_like(v)
    return st


def adamw_update(cfg: ModelCfg, params, opt, grads, step,
                 b1=0.9, b2=0.999, eps=1e-8):
    lr = cosine_lr(cfg, step)
    t = step + 1.0
    new_p, new_o = {}, {}
    for k, p in params.items():
        if is_frozen(cfg, k):
            new_p[k] = p
            continue
        g = grads[k]
        m = b1 * opt[f"m::{k}"] + (1 - b1) * g
        v = b2 * opt[f"v::{k}"] + (1 - b2) * g * g
        mh = m / (1 - b1 ** t)
        vh = v / (1 - b2 ** t)
        upd = mh / (jnp.sqrt(vh) + eps)
        # decoupled weight decay on matrices only (not norms/embeddings-bias)
        if p.ndim >= 2:
            upd = upd + cfg.preset.weight_decay * p
        new_p[k] = p - lr * upd
        new_o[f"m::{k}"] = m
        new_o[f"v::{k}"] = v
    return new_p, new_o


# ---------------------------------------------------------------------------
# GaLore-style projected AdamW (Eq. 12)
# ---------------------------------------------------------------------------

def _galore_target(k: str, p) -> bool:
    """GaLore projects 2-D transformer weights; embeddings/head/norms use
    plain AdamW (as in the reference implementation)."""
    return p.ndim == 2 and (".attn." in k or ".mlp." in k)


def _orthonormalize(g):
    """Newton–Schulz orthogonalization (pure GEMMs — AOT-portable).

    jnp.linalg.qr lowers to a typed-FFI LAPACK custom-call that the runtime's
    xla_extension 0.5.1 cannot compile, so refresh_proj.hlo.txt must avoid it.
    Column-normalize, then iterate  P ← P·(3I − PᵀP)/2, which converges to an
    orthonormal basis of the same column space.
    """
    g = g / (jnp.linalg.norm(g, axis=0, keepdims=True) + 1e-6)
    g = g / jnp.sqrt(jnp.asarray(g.shape[1], g.dtype))  # spectral pre-scale
    eye = jnp.eye(g.shape[1], dtype=g.dtype)
    for _ in range(12):
        g = g @ (1.5 * eye - 0.5 * (g.T @ g))
    return g


def galore_init(cfg: ModelCfg, params: dict, seed: int = 0) -> dict:
    """Optimizer state: low-rank m/v plus the projection P per target.

    P is initialized as a random orthonormal basis and refreshed periodically
    by the separate `refresh_proj` artifact (the paper recomputes P via SVD of
    the gradient every ~200 steps; we use a random orthogonal refresh, the
    APOLLO variant — see DESIGN.md §6, same cost/memory class).
    """
    st = {}
    key = jax.random.PRNGKey(seed + 17)
    r = cfg.r
    for k, p in params.items():
        if is_frozen(cfg, k):
            continue
        if _galore_target(k, p):
            d_in, d_out = p.shape
            rr = min(r, d_in)
            key, kk = jax.random.split(key)
            q = _orthonormalize(jax.random.normal(kk, (d_in, rr)))
            st[f"P::{k}"] = q                       # [d_in, rr]
            st[f"m::{k}"] = jnp.zeros((rr, d_out))
            st[f"v::{k}"] = jnp.zeros((rr, d_out))
        else:
            st[f"m::{k}"] = jnp.zeros_like(p)
            st[f"v::{k}"] = jnp.zeros_like(p)
    return st


def galore_update(cfg: ModelCfg, params, opt, grads, step,
                  b1=0.9, b2=0.999, eps=1e-8, scale=0.25):
    lr = cosine_lr(cfg, step)
    t = step + 1.0
    new_p, new_o = {}, {}
    for k, p in params.items():
        if is_frozen(cfg, k):
            new_p[k] = p
            continue
        g = grads[k]
        if _galore_target(k, p):
            P = opt[f"P::{k}"]
            rg = P.T @ g                             # R_t = P^T G_t
            m = b1 * opt[f"m::{k}"] + (1 - b1) * rg
            v = b2 * opt[f"v::{k}"] + (1 - b2) * rg * rg
            mh = m / (1 - b1 ** t)
            vh = v / (1 - b2 ** t)
            upd = P @ (mh / (jnp.sqrt(vh) + eps)) / scale  # back-projection
            upd = upd + cfg.preset.weight_decay * p
            new_o[f"P::{k}"] = P
        else:
            m = b1 * opt[f"m::{k}"] + (1 - b1) * g
            v = b2 * opt[f"v::{k}"] + (1 - b2) * g * g
            mh = m / (1 - b1 ** t)
            vh = v / (1 - b2 ** t)
            upd = mh / (jnp.sqrt(vh) + eps)
            if p.ndim >= 2:
                upd = upd + cfg.preset.weight_decay * p
        new_p[k] = p - lr * upd
        new_o[f"m::{k}"] = m
        new_o[f"v::{k}"] = v
    return new_p, new_o


def galore_refresh(cfg: ModelCfg, opt: dict, seed) -> dict:
    """Re-draw the projection bases (in-graph, seeded by a scalar input) and
    reset the projected moments — lowered to refresh_proj.hlo.txt so the rust
    coordinator can refresh without python."""
    new = dict(opt)
    key = jax.random.PRNGKey(0)
    key = jax.random.fold_in(key, seed)
    for k in sorted(opt.keys()):
        if not k.startswith("P::"):
            continue
        key, kk = jax.random.split(key)
        d_in, rr = opt[k].shape
        new[k] = _orthonormalize(jax.random.normal(kk, (d_in, rr)))
        base = k[3:]
        new[f"m::{base}"] = jnp.zeros_like(opt[f"m::{base}"])
        new[f"v::{base}"] = jnp.zeros_like(opt[f"v::{base}"])
    return new


def opt_init(cfg: ModelCfg, params: dict) -> dict:
    if cfg.variant == "galore":
        return galore_init(cfg, params, cfg.preset.seed)
    return adamw_init(cfg, params)


def opt_update(cfg: ModelCfg, params, opt, grads, step):
    if cfg.variant == "galore":
        return galore_update(cfg, params, opt, grads, step)
    return adamw_update(cfg, params, opt, grads, step)
