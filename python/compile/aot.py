"""AOT pipeline: lower every step function to HLO *text* + emit state0.npz
and manifest.json per (preset, variant) artifact directory.

HLO text — NOT ``lowered.compiler_ir("hlo")`` protos or ``.serialize()`` —
is the interchange format: jax ≥ 0.5 emits HloModuleProtos with 64-bit
instruction ids that the runtime's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Artifact contract with the rust runtime (rust/src/runtime/artifact.rs):

  train_step.hlo.txt : (state..., step f32, tokens i32[M,B,T+1][, mask])
                       -> (state'..., loss f32, grad_norm f32)
  eval_step.hlo.txt  : (params..., tokens i32[B,T+1]) -> (sum_nll, count)
  activations.hlo.txt: (params..., tokens i32[B,T+1]) -> (tap_0..tap_L)
  prefill.hlo.txt    : (params..., prompt i32[B,Tp]) -> (next, kc, vc)
  decode_step.hlo.txt: (params..., kc, vc, tok i32[B], pos i32[B])
                       -> (next, kc', vc')   # per-row positions
  prefill_row.hlo.txt: (params..., kc, vc, window i32[Tp], row i32,
                       len i32, keep i32) -> (next i32, kc', vc')
                       # single-row prefill spliced into a live batch
  refresh_proj.hlo.txt (galore): (state..., seed i32) -> (state'...)
  cls_train.hlo.txt / cls_eval.hlo.txt (encoder presets): GLUE-proxy head.

`state` is opaque to rust: an ordered list of arrays (params sorted by name,
then optimizer entries sorted by name). manifest.json records names, shapes,
dtypes, and all geometry/hyper-parameters.
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from . import optim
from .cola_m import block_fn_for
from .presets import PRESETS, Preset, paper_rank_for


# ---------------------------------------------------------------------------
# Lowering helpers
# ---------------------------------------------------------------------------

def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def lower_to_file(fn, arg_specs, path: str) -> int:
    """jit-lower fn at arg_specs, write HLO text, return #bytes.

    keep_unused=True: the rust runtime passes the full state list to every
    step function; without it XLA prunes unused params (e.g. head.W in the
    activation-tap module) and the call arity no longer matches the manifest.
    """
    lowered = jax.jit(fn, keep_unused=True).lower(*arg_specs)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    return len(text)


def spec_of(x) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(x.shape, x.dtype)


# ---------------------------------------------------------------------------
# State flattening
# ---------------------------------------------------------------------------

class StateLayout:
    """Fixed ordering of params + optimizer entries for the flat interface."""

    def __init__(self, cfg: M.ModelCfg, params: dict, opt: dict):
        self.cfg = cfg
        self.param_names = sorted(params.keys())
        self.opt_names = sorted(opt.keys())
        self.n_params = len(self.param_names)
        self.n_state = self.n_params + len(self.opt_names)
        self._params = params
        self._opt = opt

    def flatten(self, params: dict, opt: dict) -> list:
        return ([params[k] for k in self.param_names] +
                [opt[k] for k in self.opt_names])

    def unflatten(self, flat):
        params = dict(zip(self.param_names, flat[:self.n_params]))
        opt = dict(zip(self.opt_names, flat[self.n_params:self.n_state]))
        return params, opt

    def state0(self) -> list:
        return self.flatten(self._params, self._opt)


# ---------------------------------------------------------------------------
# Step-function builders (flat-arg signatures)
# ---------------------------------------------------------------------------

def build_train_step(cfg: M.ModelCfg, layout: StateLayout):
    block_fn = block_fn_for(cfg)
    is_mlm = cfg.preset.is_encoder

    def loss_of(trainable, frozen, tok, mask=None):
        params = {**trainable, **frozen}
        if is_mlm:
            return M.mlm_loss(cfg, params, tok, mask, block_fn=block_fn)
        return M.lm_loss(cfg, params, tok, block_fn=block_fn)

    def train_step(*args):
        flat = list(args[:layout.n_state])
        step = args[layout.n_state]
        tokens = args[layout.n_state + 1]            # [M, B, T(+1)]
        mask = args[layout.n_state + 2] if is_mlm else None
        params, opt = layout.unflatten(flat)
        trainable = {k: v for k, v in params.items()
                     if not M.is_frozen(cfg, k)}
        frozen = {k: v for k, v in params.items() if M.is_frozen(cfg, k)}

        n_micro = tokens.shape[0]
        grad_fn = jax.value_and_grad(loss_of)

        def body(carry, xs):
            l_acc, g_acc = carry
            if is_mlm:
                tok, mk = xs
                l, g = grad_fn(trainable, frozen, tok, mk)
            else:
                l, g = grad_fn(trainable, frozen, xs)
            return (l_acc + l,
                    jax.tree_util.tree_map(jnp.add, g_acc, g)), None

        zeros = jax.tree_util.tree_map(jnp.zeros_like, trainable)
        xs = (tokens, mask) if is_mlm else tokens
        (l_sum, g_sum), _ = jax.lax.scan(body, (0.0, zeros), xs)
        loss = l_sum / n_micro
        grads = jax.tree_util.tree_map(lambda g: g / n_micro, g_sum)
        grads, gnorm = optim.clip_by_global_norm(grads, cfg.preset.grad_clip)

        new_tr, new_opt = optim.opt_update(cfg, trainable, opt, grads, step)
        new_params = {**new_tr, **frozen}
        out = layout.flatten(new_params, new_opt)
        return tuple(out) + (loss, gnorm)

    return train_step


def build_eval_step(cfg: M.ModelCfg, layout: StateLayout):
    def eval_step(*args):
        params = dict(zip(layout.param_names, args[:layout.n_params]))
        tokens = args[layout.n_params]
        return M.lm_loss_sum(cfg, params, tokens)
    return eval_step


def build_activations(cfg: M.ModelCfg, layout: StateLayout):
    def acts(*args):
        params = dict(zip(layout.param_names, args[:layout.n_params]))
        tokens = args[layout.n_params]
        taps = []
        M.forward_hidden(cfg, params, tokens[:, :-1], taps=taps)
        return tuple(t for (_, t) in taps)
    return acts


def build_prefill(cfg: M.ModelCfg, layout: StateLayout, max_len: int):
    def pf(*args):
        params = dict(zip(layout.param_names, args[:layout.n_params]))
        prompt = args[layout.n_params]
        return M.prefill(cfg, params, prompt, max_len)
    return pf


def build_decode(cfg: M.ModelCfg, layout: StateLayout):
    def dec(*args):
        params = dict(zip(layout.param_names, args[:layout.n_params]))
        kc, vc, tok, pos = args[layout.n_params:layout.n_params + 4]
        return M.decode_step(cfg, params, kc, vc, tok, pos)
    return dec


def build_prefill_row(cfg: M.ModelCfg, layout: StateLayout):
    def pfr(*args):
        params = dict(zip(layout.param_names, args[:layout.n_params]))
        kc, vc, window, row, length, keep = \
            args[layout.n_params:layout.n_params + 6]
        return M.prefill_row(cfg, params, kc, vc, window, row, length, keep)
    return pfr


def build_refresh(cfg: M.ModelCfg, layout: StateLayout):
    def refresh(*args):
        flat = list(args[:layout.n_state])
        seed = args[layout.n_state]
        params, opt = layout.unflatten(flat)
        new_opt = optim.galore_refresh(cfg, opt, seed)
        return tuple(layout.flatten(params, new_opt))
    return refresh


def build_cls(cfg: M.ModelCfg, layout: StateLayout, n_classes: int, lr: float):
    """GLUE-proxy fine-tune/eval steps. Classifier weights + their Adam
    moments ride at the end of the state list."""

    def cls_loss(trainable, frozen, cls_w, tokens, labels):
        params = {**trainable, **frozen}
        lg = M.cls_logits(cfg, params, tokens, cls_w)
        lse = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, labels[:, None], axis=-1)[:, 0]
        return jnp.mean(lse - gold)

    def cls_train(*args):
        flat = list(args[:layout.n_state])
        cls_w, cm, cv = args[layout.n_state:layout.n_state + 3]
        step = args[layout.n_state + 3]
        tokens = args[layout.n_state + 4]
        labels = args[layout.n_state + 5]
        params, opt = layout.unflatten(flat)
        trainable = {k: v for k, v in params.items()
                     if not M.is_frozen(cfg, k)}
        frozen = {k: v for k, v in params.items() if M.is_frozen(cfg, k)}

        (loss, (g_tr, g_cls)) = jax.value_and_grad(
            cls_loss, argnums=(0, 2))(trainable, frozen, cls_w, tokens, labels)
        (g_tr, g_cls), gnorm = optim.clip_by_global_norm(
            (g_tr, g_cls), cfg.preset.grad_clip)

        new_tr, new_opt = optim.opt_update(cfg, trainable, opt, g_tr, step)
        # plain Adam on the classifier head at fine-tune lr
        b1, b2, eps = 0.9, 0.999, 1e-8
        t = step + 1.0
        cm2 = b1 * cm + (1 - b1) * g_cls
        cv2 = b2 * cv + (1 - b2) * g_cls * g_cls
        cls_w2 = cls_w - lr * (cm2 / (1 - b1 ** t)) / (
            jnp.sqrt(cv2 / (1 - b2 ** t)) + eps)
        out = layout.flatten({**new_tr, **frozen}, new_opt)
        return tuple(out) + (cls_w2, cm2, cv2, loss)

    def cls_eval(*args):
        params = dict(zip(layout.param_names, args[:layout.n_params]))
        cls_w = args[layout.n_params]
        tokens = args[layout.n_params + 1]
        labels = args[layout.n_params + 2]
        lg = M.cls_logits(cfg, params, tokens, cls_w)
        pred = jnp.argmax(lg, -1).astype(jnp.int32)
        return (jnp.sum((pred == labels).astype(jnp.float32)),
                jnp.asarray(labels.shape[0], jnp.float32))

    return cls_train, cls_eval


# ---------------------------------------------------------------------------
# Artifact emission
# ---------------------------------------------------------------------------

def make_cfg(preset: str, variant: str, sigma_mode: str = "lowrank_only",
             rank: int = 0, compute_frac: float = 0.0,
             use_kernel: bool = True, block_n: int = 0) -> M.ModelCfg:
    p = PRESETS[preset]
    if compute_frac > 0:
        rank = paper_rank_for(p.d, compute_frac)
    if block_n == 0:
        # COLA_AE_BLOCK=whole collapses the interpret-mode grid to one
        # program (CPU perf; see EXPERIMENTS.md §Perf). Any integer works too.
        env = os.environ.get("COLA_AE_BLOCK", "128")
        block_n = p.batch // p.n_micro * p.seq_len if env == "whole" else int(env)
    return M.ModelCfg(preset=p, variant=variant, sigma_mode=sigma_mode,
                      use_kernel=use_kernel, rank=rank, block_n=block_n)


def artifact_name(cfg: M.ModelCfg, tag: str = "") -> str:
    name = f"{cfg.preset.name}_{cfg.variant}"
    if cfg.variant in ("cola", "cola_m"):
        if cfg.sigma_mode != "lowrank_only":
            name += f"_{cfg.sigma_mode}"
        if cfg.rank and cfg.rank != cfg.preset.rank:
            name += f"_r{cfg.rank}"
    if tag:
        name += f"_{tag}"
    return name


def emit(cfg: M.ModelCfg, out_root: str, serve: bool = False,
         cls_classes: int = 0, verbose: bool = True) -> str:
    """Build every artifact for one (preset, variant). Returns the dir."""
    p = cfg.preset
    name = artifact_name(cfg)
    adir = os.path.join(out_root, name)
    os.makedirs(adir, exist_ok=True)

    params = M.init_params(cfg, p.seed)
    opt = optim.opt_init(cfg, params)
    layout = StateLayout(cfg, params, opt)
    state0 = layout.state0()
    state_specs = [spec_of(x) for x in state0]
    f32 = lambda: jax.ShapeDtypeStruct((), jnp.float32)
    i32 = lambda s: jax.ShapeDtypeStruct(s, jnp.int32)

    sizes = {}
    mb = p.batch // p.n_micro
    tok_shape = (p.n_micro, mb, p.seq_len + (0 if p.is_encoder else 1))

    train_args = state_specs + [f32(), i32(tok_shape)]
    if p.is_encoder:
        train_args.append(i32(tok_shape))
    sizes["train_step"] = lower_to_file(
        build_train_step(cfg, layout), train_args,
        os.path.join(adir, "train_step.hlo.txt"))

    eval_bs = p.batch
    param_specs = state_specs[:layout.n_params]
    if not p.is_encoder:
        sizes["eval_step"] = lower_to_file(
            build_eval_step(cfg, layout),
            param_specs + [i32((eval_bs, p.seq_len + 1))],
            os.path.join(adir, "eval_step.hlo.txt"))
        sizes["activations"] = lower_to_file(
            build_activations(cfg, layout),
            param_specs + [i32((2, p.seq_len + 1))],
            os.path.join(adir, "activations.hlo.txt"))

    if cfg.variant == "galore":
        sizes["refresh_proj"] = lower_to_file(
            build_refresh(cfg, layout), state_specs + [i32(())],
            os.path.join(adir, "refresh_proj.hlo.txt"))

    serve_geom = {}
    if serve:
        max_len = p.seq_len
        prompt_len = max(8, p.seq_len // 4)
        serve_bs = 4
        sizes["prefill"] = lower_to_file(
            build_prefill(cfg, layout, max_len),
            param_specs + [i32((serve_bs, prompt_len))],
            os.path.join(adir, "prefill.hlo.txt"))
        kv = jax.ShapeDtypeStruct(
            (p.n_layers, serve_bs, max_len, p.n_heads, p.head_dim),
            jnp.float32)
        # pos is a per-row vector: every batch row decodes at its own KV
        # depth (barrier-free continuous batching; rust/src/serve/engine.rs).
        sizes["decode_step"] = lower_to_file(
            build_decode(cfg, layout),
            param_specs + [kv, kv, i32((serve_bs,)), i32((serve_bs,))],
            os.path.join(adir, "decode_step.hlo.txt"))
        # single-row admission: prefill one left-aligned window and splice
        # it into row `row` of the live caches (positions < keep retain the
        # row's imported prefix) without disturbing the other rows.
        sizes["prefill_row"] = lower_to_file(
            build_prefill_row(cfg, layout),
            param_specs + [kv, kv, i32((prompt_len,)), i32(()), i32(()),
                           i32(())],
            os.path.join(adir, "prefill_row.hlo.txt"))
        serve_geom = {"serve_batch": serve_bs, "prompt_len": prompt_len,
                      "max_len": max_len}

    cls_geom = {}
    if cls_classes > 0:
        assert p.is_encoder
        cls_train, cls_eval = build_cls(cfg, layout, cls_classes, lr=1e-4)
        d = p.d
        cw = jax.ShapeDtypeStruct((d, cls_classes), jnp.float32)
        sizes["cls_train"] = lower_to_file(
            cls_train,
            state_specs + [cw, cw, cw, f32(), i32((p.batch, p.seq_len)),
                           i32((p.batch,))],
            os.path.join(adir, "cls_train.hlo.txt"))
        sizes["cls_eval"] = lower_to_file(
            cls_eval,
            param_specs + [cw, i32((p.batch, p.seq_len)), i32((p.batch,))],
            os.path.join(adir, "cls_eval.hlo.txt"))
        cls_geom = {"n_classes": cls_classes, "cls_dim": d}

    # state0.npz — keys s000000.. preserve order through the npz round-trip.
    np.savez(os.path.join(adir, "state0.npz"),
             **{f"s{i:06d}": np.asarray(x) for i, x in enumerate(state0)})

    counts = M.count_params(cfg)
    manifest = {
        "name": name,
        "preset": p.to_dict(),
        "variant": cfg.variant,
        "sigma_mode": cfg.sigma_mode,
        "rank": cfg.r,
        "use_kernel": cfg.use_kernel,
        "objective": "mlm" if p.is_encoder else "lm",
        "n_state": layout.n_state,
        "n_params": layout.n_params,
        "param_names": layout.param_names,
        "opt_names": layout.opt_names,
        "state_shapes": [list(np.asarray(x).shape) for x in state0],
        "tokens_shape": list(tok_shape),
        "eval_batch": eval_bs,
        "n_total_params": counts["total"],
        "n_trainable_params": counts["trainable"],
        "hlo_bytes": sizes,
        **serve_geom,
        **cls_geom,
    }
    with open(os.path.join(adir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)

    if verbose:
        kb = {k: v // 1024 for k, v in sizes.items()}
        print(f"[aot] {name}: params={counts['total']:,} "
              f"state={layout.n_state} hlo_kb={kb}", flush=True)
    return adir


# ---------------------------------------------------------------------------
# Standard artifact sets
# ---------------------------------------------------------------------------

def standard_set() -> list[dict]:
    """Everything `make artifacts` builds (see DESIGN.md experiment index)."""
    jobs = []

    def j(**kw):
        jobs.append(kw)

    # tiny: full matrix of variants (pytest + quickstart + integration tests)
    for v in ("full", "gcp", "cola", "cola_m", "lora", "galore", "sltrain"):
        j(preset="tiny", variant=v, serve=(v in ("full", "cola")))
    for sm in ("both", "reduced", "fullrank_only"):
        j(preset="tiny", variant="cola", sigma_mode=sm)

    # p60m ladder: Tables 5/7/10 proxy runs
    for v in ("full", "gcp", "cola", "cola_m", "lora", "galore", "sltrain"):
        j(preset="p60m", variant=v)
    for sm in ("both", "reduced", "fullrank_only"):
        j(preset="p60m", variant="cola", sigma_mode=sm)
    j(preset="p60m", variant="cola", compute_frac=0.7)      # Table 7 0.7×
    j(preset="p60m_control", variant="full")

    # p130m: Table 5/7 second scale
    for v in ("full", "cola", "cola_m", "lora", "galore", "sltrain"):
        j(preset="p130m", variant=v)
    j(preset="p130m", variant="cola", compute_frac=0.7)
    j(preset="p130m_control", variant="full")

    # p350m: Table 7 third scale + over-train + serving (Table 11)
    for v in ("full", "cola", "cola_m", "sltrain"):
        j(preset="p350m", variant=v, serve=True)
    j(preset="p350m", variant="cola", compute_frac=0.7)
    j(preset="p350m_control", variant="full")

    # throughput scale (Fig 8 / Table 9) + e2e driver
    for v in ("full", "gcp", "cola", "cola_m"):
        j(preset="e2e", variant=v, serve=(v in ("full", "cola")))

    # BERT proxy (Table 8)
    j(preset="bert", variant="full", cls_classes=4)
    j(preset="bert", variant="cola", compute_frac=0.7, cls_classes=4)
    return jobs


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--preset", default=None)
    ap.add_argument("--variant", default="full")
    ap.add_argument("--sigma-mode", default="lowrank_only")
    ap.add_argument("--rank", type=int, default=0)
    ap.add_argument("--compute-frac", type=float, default=0.0)
    ap.add_argument("--serve", action="store_true")
    ap.add_argument("--cls-classes", type=int, default=0)
    ap.add_argument("--no-kernel", action="store_true",
                    help="use the jnp oracle path instead of pallas")
    ap.add_argument("--set", default=None, choices=(None, "standard", "tiny"),
                    help="build a predefined artifact set")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    if args.set:
        jobs = standard_set()
        if args.set == "tiny":
            jobs = [jb for jb in jobs if jb["preset"].startswith("tiny")]
        for jb in jobs:
            cfg = make_cfg(jb["preset"], jb["variant"],
                           jb.get("sigma_mode", "lowrank_only"),
                           jb.get("rank", 0), jb.get("compute_frac", 0.0))
            emit(cfg, args.out, serve=jb.get("serve", False),
                 cls_classes=jb.get("cls_classes", 0))
        # mark set completion for the Makefile's no-op check
        with open(os.path.join(args.out, f".stamp_{args.set}"), "w") as f:
            f.write("ok\n")
        return

    if not args.preset:
        ap.error("--preset or --set required")
    cfg = make_cfg(args.preset, args.variant, args.sigma_mode, args.rank,
                   args.compute_frac, use_kernel=not args.no_kernel)
    emit(cfg, args.out, serve=args.serve, cls_classes=args.cls_classes)


if __name__ == "__main__":
    main()
