"""Layer-2: LLaMA-family model in JAX with CoLA variants.

Every linear layer goes through `linear()`, which dispatches on the variant:

* ``full`` / ``gcp`` / ``control`` — ordinary full-rank weight.
* ``cola`` / ``cola_m``            — bottleneck auto-encoder  B·σ(A·x)
  (Eq. 3), σ placement per Table 10's four modes.
* ``lora``                         — frozen W0 + trainable B·A (ReLoRA's pure
  low-rank stage, the paper's compute baseline Eq. 8).
* ``sltrain``                      — B·A + fixed-support sparse residual
  (Eq. 10; support is a frozen random mask — see DESIGN.md §6).
* ``galore``                       — full-rank architecture (GaLore changes
  the optimizer, not the model — see optim.py).

Params are a flat ``dict[str, jnp.ndarray]``; ``param_order()`` fixes the
deterministic flattening the rust runtime relies on (manifest.json).
"""

from dataclasses import dataclass, replace

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from .kernels.cola_ae import cola_ae_dispatch
from .kernels.ref import sigma
from .presets import Preset


@dataclass(frozen=True)
class ModelCfg:
    """Architecture-level configuration (preset geometry + variant knobs)."""
    preset: Preset
    variant: str = "full"            # see presets.VARIANTS
    sigma_mode: str = "lowrank_only" # Table 10 ablation knob (cola only)
    use_kernel: bool = True          # pallas kernel vs jnp oracle for AEs
    rank: int = 0                    # 0 -> preset.rank
    sparse_density: float = 0.03     # sltrain sparse fraction
    # AE kernel token-block. 128 = MXU tile (the real-TPU plan, DESIGN.md §7).
    # On the CPU interpret path a block covering the whole token batch
    # collapses the pallas grid to 1 and removes per-block while-loop +
    # dynamic-slice overhead from the lowered HLO (§Perf L1).
    block_n: int = 128

    @property
    def r(self) -> int:
        return self.rank or self.preset.rank

    def with_rank(self, r: int) -> "ModelCfg":
        return replace(self, rank=r)


# ---------------------------------------------------------------------------
# Parameter construction
# ---------------------------------------------------------------------------

def _lin_names(cfg: ModelCfg, name: str):
    """Parameter names created by `linear` for layer `name`."""
    v = cfg.variant
    if v in ("cola", "cola_m"):
        return [f"{name}.A", f"{name}.B"]
    if v == "lora":
        return [f"{name}.W0", f"{name}.A", f"{name}.B"]
    if v == "sltrain":
        return [f"{name}.A", f"{name}.B", f"{name}.Sval", f"{name}.Smask"]
    return [f"{name}.W"]


#: params frozen during training (no grads / no optimizer state).
def is_frozen(cfg: ModelCfg, name: str) -> bool:
    if cfg.variant == "lora" and name.endswith(".W0"):
        return True
    if cfg.variant == "sltrain" and name.endswith(".Smask"):
        return True
    return False


def _init_lin(cfg: ModelCfg, key, name: str, d_in: int, d_out: int, params):
    """Initialize one logical linear layer into `params`."""
    r = cfg.r
    v = cfg.variant
    k1, k2, k3 = jax.random.split(key, 3)
    if v in ("cola", "cola_m"):
        # Spectral-ish init (Khodak et al. 2021): keep ‖BσA‖ comparable to a
        # 1/sqrt(d_in) full-rank init.
        params[f"{name}.A"] = jax.random.normal(k1, (d_in, r)) / jnp.sqrt(d_in)
        params[f"{name}.B"] = jax.random.normal(k2, (r, d_out)) / jnp.sqrt(r)
    elif v == "lora":
        params[f"{name}.W0"] = jax.random.normal(k1, (d_in, d_out)) / jnp.sqrt(d_in)
        params[f"{name}.A"] = jax.random.normal(k2, (d_in, r)) / jnp.sqrt(d_in)
        params[f"{name}.B"] = jnp.zeros((r, d_out))  # LoRA-style zero start
    elif v == "sltrain":
        params[f"{name}.A"] = jax.random.normal(k1, (d_in, r)) / jnp.sqrt(d_in)
        params[f"{name}.B"] = jax.random.normal(k2, (r, d_out)) / jnp.sqrt(r)
        mask = (jax.random.uniform(k3, (d_in, d_out)) < cfg.sparse_density)
        params[f"{name}.Sval"] = (
            jax.random.normal(k1, (d_in, d_out)) / jnp.sqrt(d_in))
        params[f"{name}.Smask"] = mask.astype(jnp.float32)
    else:
        params[f"{name}.W"] = jax.random.normal(k1, (d_in, d_out)) / jnp.sqrt(d_in)


def linear(cfg: ModelCfg, params, name: str, x, orig_act: str | None = None):
    """Apply the logical linear layer `name` to x under cfg.variant.

    orig_act: the nonlinearity the *original* architecture applies after this
    layer (e.g. silu on the SwiGLU gate), or None. CoLA's sigma_mode decides
    where σ actually lands (Table 10):

      lowrank_only  — σ inside the AE for every layer, original σ dropped.
      both          — σ inside every AE *and* the original σ kept.
      reduced       — σ inside the AE only where the original had one.
      fullrank_only — plain B·A factorization, only the original σ applied.
    """
    v = cfg.variant
    if v in ("cola", "cola_m"):
        mode = cfg.sigma_mode
        if mode == "lowrank_only":
            inner, outer = "silu", None
        elif mode == "both":
            inner, outer = "silu", orig_act
        elif mode == "reduced":
            inner = "silu" if orig_act else "identity"
            outer = None
        elif mode == "fullrank_only":
            inner, outer = "identity", orig_act
        else:
            raise ValueError(f"bad sigma_mode {mode}")
        y = cola_ae_dispatch(x, params[f"{name}.A"], params[f"{name}.B"],
                             act=inner, use_kernel=cfg.use_kernel,
                             block_n=cfg.block_n)
        # Tag the bottleneck output for CoLA-M's save-only-low-rank policy.
        # (The tag lands on the AE output here; the true r-dim tensor is
        # inside the kernel — cola_m.py documents the equivalence.)
        if outer:
            y = sigma(outer)(y)
        return y

    if v == "lora":
        y = x @ params[f"{name}.W0"]
        y = y + (x @ params[f"{name}.A"]) @ params[f"{name}.B"]
    elif v == "sltrain":
        w = params[f"{name}.A"] @ params[f"{name}.B"]
        w = w + params[f"{name}.Smask"] * params[f"{name}.Sval"]
        y = x @ w
    else:
        y = x @ params[f"{name}.W"]
    if orig_act:
        y = sigma(orig_act)(y)
    return y


# For CoLA-M we additionally need the *bottleneck* activations as named
# checkpoints. We re-derive them via a tagged wrapper around `linear` for the
# cola variants: tag the encoder output σ(A·x).
def linear_tagged(cfg: ModelCfg, params, name: str, x, orig_act=None):
    if cfg.variant not in ("cola", "cola_m"):
        return linear(cfg, params, name, x, orig_act)
    mode = cfg.sigma_mode
    inner = "silu"
    if mode == "reduced" and not orig_act:
        inner = "identity"
    if mode == "fullrank_only":
        inner = "identity"
    a, b = params[f"{name}.A"], params[f"{name}.B"]
    z = sigma(inner)(x @ a)
    z = checkpoint_name(z, "lowrank")          # <- the saved r-dim activation
    y = z @ b
    if mode == "both" and orig_act:
        y = sigma(orig_act)(y)
    if mode == "fullrank_only" and orig_act:
        y = sigma(orig_act)(y)
    return y


# ---------------------------------------------------------------------------
# Model blocks
# ---------------------------------------------------------------------------

def rmsnorm(params, name, x, eps=1e-6):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * params[f"{name}.g"]


def _rope(x, pos):
    """Rotary embedding. x: [B, T, H, hd]; pos: [T] (or scalar broadcast)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (10000.0 ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = pos[..., None].astype(jnp.float32) * freqs      # [T, half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[None, :, None, :]
    sin = sin[None, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)


def attention(cfg: ModelCfg, params, lname: str, x, pos, causal: bool,
              lin_fn, kv_cache=None, cache_pos=None):
    """Multi-head attention with RoPE.

    kv_cache: optional (k, v) of shape [B, maxT, H, hd] for decode;
    cache_pos: scalar index where the new token(s) land.
    Returns (out, new_kv_cache).
    """
    p = cfg.preset
    B, T, _ = x.shape
    H, hd = p.n_heads, p.head_dim

    q = lin_fn(cfg, params, f"{lname}.q", x).reshape(B, T, H, hd)
    k = lin_fn(cfg, params, f"{lname}.k", x).reshape(B, T, H, hd)
    v = lin_fn(cfg, params, f"{lname}.v", x).reshape(B, T, H, hd)

    q = _rope(q, pos)
    k = _rope(k, pos)

    if kv_cache is not None:
        ck, cv = kv_cache
        ck = jax.lax.dynamic_update_slice(ck, k, (0, cache_pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v, (0, cache_pos, 0, 0))
        k_all, v_all = ck, cv
        new_cache = (ck, cv)
        kv_len = ck.shape[1]
    else:
        k_all, v_all = k, v
        new_cache = None
        kv_len = T

    att = jnp.einsum("bqhd,bkhd->bhqk", q, k_all) / jnp.sqrt(float(hd))
    if kv_cache is not None:
        # Causal within the new block AND bounded by what the cache holds:
        # query at absolute position cache_pos+q may attend keys j <= that.
        qpos = cache_pos + jnp.arange(T)
        valid = jnp.arange(kv_len)[None, :] <= qpos[:, None]
        att = jnp.where(valid[None, None, :, :], att, -1e30)
    elif causal:
        mask = jnp.tril(jnp.ones((T, T), bool))
        att = jnp.where(mask[None, None], att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", att, v_all).reshape(B, T, H * hd)
    out = lin_fn(cfg, params, f"{lname}.o", out)
    return out, new_cache


def mlp(cfg: ModelCfg, params, lname: str, x, lin_fn):
    """SwiGLU MLP: down( silu(gate(x)) ⊙ up(x) ). Under CoLA each projection
    is an auto-encoder; the ⊙ stays in d_ff (Fig. 4)."""
    g = lin_fn(cfg, params, f"{lname}.gate", x, "silu")
    u = lin_fn(cfg, params, f"{lname}.up", x)
    return lin_fn(cfg, params, f"{lname}.down", g * u)


def block(cfg: ModelCfg, params, lname: str, x, pos, causal, lin_fn,
          kv_cache=None, cache_pos=None):
    h, new_cache = attention(cfg, params, f"{lname}.attn",
                             rmsnorm(params, f"{lname}.norm1", x),
                             pos, causal, lin_fn, kv_cache, cache_pos)
    x = x + h
    x = x + mlp(cfg, params, f"{lname}.mlp",
                rmsnorm(params, f"{lname}.norm2", x), lin_fn)
    return x, new_cache


# ---------------------------------------------------------------------------
# Init + ordering
# ---------------------------------------------------------------------------

def layer_shapes(cfg: ModelCfg):
    """(logical linear name, d_in, d_out) for every linear in the model."""
    p = cfg.preset
    out = []
    for i in range(p.n_layers):
        l = f"l{i}"
        out += [(f"{l}.attn.q", p.d, p.d), (f"{l}.attn.k", p.d, p.d),
                (f"{l}.attn.v", p.d, p.d), (f"{l}.attn.o", p.d, p.d),
                (f"{l}.mlp.gate", p.d, p.d_ff), (f"{l}.mlp.up", p.d, p.d_ff),
                (f"{l}.mlp.down", p.d_ff, p.d)]
    return out


def init_params(cfg: ModelCfg, seed: int = 0) -> dict:
    p = cfg.preset
    key = jax.random.PRNGKey(seed)
    params = {}
    key, k_emb, k_head = jax.random.split(key, 3)
    params["emb.tok"] = jax.random.normal(k_emb, (p.vocab, p.d)) * 0.02
    for i in range(p.n_layers):
        params[f"l{i}.norm1.g"] = jnp.ones(p.d)
        params[f"l{i}.norm2.g"] = jnp.ones(p.d)
    params["normf.g"] = jnp.ones(p.d)
    params["head.W"] = jax.random.normal(k_head, (p.d, p.vocab)) * 0.02
    for (name, d_in, d_out) in layer_shapes(cfg):
        key, k = jax.random.split(key)
        _init_lin(cfg, k, name, d_in, d_out, params)
    if cfg.preset.is_encoder:
        # MLM head reuses head.W; add a pooler for classification fine-tuning.
        key, k = jax.random.split(key)
        params["pool.W"] = jax.random.normal(k, (p.d, p.d)) * 0.02
    return params


def param_order(params: dict) -> list[str]:
    """Deterministic flattening order shared with the rust runtime."""
    return sorted(params.keys())


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def forward_hidden(cfg: ModelCfg, params, tokens, lin_fn=linear,
                   block_fn=None, taps=None):
    """tokens [B, T] int32 → final hidden [B, T, d].

    `block_fn` lets the AOT layer wrap blocks in remat (gcp / cola_m).
    `taps`: optional list collecting (name, activation) for spectrum probes.
    """
    p = cfg.preset
    B, T = tokens.shape
    x = params["emb.tok"][tokens]
    pos = jnp.arange(T)
    causal = not p.is_encoder
    for i in range(p.n_layers):
        if taps is not None:
            taps.append((f"l{i}.input", x.reshape(B * T, p.d)))
        bf = block_fn or (lambda c, pr, ln, xx, po: block(
            c, pr, ln, xx, po, causal, lin_fn)[0])
        x = bf(cfg, params, f"l{i}", x, pos)
    x = rmsnorm(params, "normf", x)
    if taps is not None:
        taps.append(("final", x.reshape(B * T, p.d)))
    return x


def logits_fn(cfg: ModelCfg, params, tokens, **kw):
    h = forward_hidden(cfg, params, tokens, **kw)
    return h @ params["head.W"]


def lm_loss(cfg: ModelCfg, params, tokens, block_fn=None):
    """tokens [B, T+1] → mean next-token NLL over all positions."""
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    lg = logits_fn(cfg, params, inp, block_fn=block_fn)
    lse = jax.nn.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def lm_loss_sum(cfg: ModelCfg, params, tokens):
    """Eval objective: (sum NLL, token count) for exact PPL aggregation."""
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    lg = logits_fn(cfg, params, inp)
    lse = jax.nn.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, tgt[..., None], axis=-1)[..., 0]
    nll = lse - gold
    return jnp.sum(nll), jnp.asarray(nll.size, jnp.float32)


def mlm_loss(cfg: ModelCfg, params, tokens, mask, block_fn=None):
    """BERT-proxy MLM: tokens [B,T] with `mask` [B,T] ∈ {0,1} marking
    positions to predict; masked positions were replaced by token 3 upstream
    (the rust data pipeline does the corruption)."""
    lg = logits_fn(cfg, params, tokens, block_fn=block_fn)
    lse = jax.nn.logsumexp(lg, axis=-1)
    # labels travel in a second channel: the pipeline sends original ids in
    # `mask`'s payload — here mask>=1 marks a target and (mask-1) is the id.
    tgt = jnp.maximum(mask - 1, 0)
    gold = jnp.take_along_axis(lg, tgt[..., None], axis=-1)[..., 0]
    nll = (lse - gold) * (mask > 0)
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask > 0), 1)


def cls_logits(cfg: ModelCfg, params, tokens, n_classes_w):
    """Sequence classification head for the GLUE-proxy: mean-pool final
    hidden → tanh pooler → class logits (weights passed separately so the
    backbone artifact is shared across tasks)."""
    h = forward_hidden(cfg, params, tokens)
    pooled = jnp.tanh(jnp.mean(h, axis=1) @ params["pool.W"])
    return pooled @ n_classes_w


# ---------------------------------------------------------------------------
# KV-cache serving path
# ---------------------------------------------------------------------------

def _rope_rows(x, pos):
    """Per-row rotary for one-token decode: x [B, 1, H, hd]; pos [B] i32 —
    each batch row rotates by its *own* position (barrier-free continuous
    batching: rows are at independent depths of their KV windows)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (10000.0 ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = pos[:, None].astype(jnp.float32) * freqs        # [B, half]
    cos = jnp.cos(ang)[:, None, None, :]
    sin = jnp.sin(ang)[:, None, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)


def _attn_decode_rows(cfg: ModelCfg, params, lname: str, x, pos, kv_cache):
    """One-token decode attention at per-row positions.

    x: [B, 1, d] (already normed); pos: [B] i32 (each row's KV write
    index); kv_cache: (k, v) each [B, maxT, H, hd]. The new k/v land at
    position pos[b] of row b (a per-row scatter), and row b's query attends
    exactly the keys j <= pos[b] — its own live prefix, nothing staler.
    Returns (out [B, 1, d_model], (ck, cv)).
    """
    p = cfg.preset
    B = x.shape[0]
    H, hd = p.n_heads, p.head_dim
    q = linear(cfg, params, f"{lname}.q", x).reshape(B, 1, H, hd)
    k = linear(cfg, params, f"{lname}.k", x).reshape(B, 1, H, hd)
    v = linear(cfg, params, f"{lname}.v", x).reshape(B, 1, H, hd)
    q = _rope_rows(q, pos)
    k = _rope_rows(k, pos)
    ck, cv = kv_cache

    def upd(cache_row, new_row, p_):              # [maxT,H,hd], [1,H,hd], i32
        return jax.lax.dynamic_update_slice(cache_row, new_row, (p_, 0, 0))

    ck = jax.vmap(upd)(ck, k, pos)
    cv = jax.vmap(upd)(cv, v, pos)
    maxT = ck.shape[1]
    att = jnp.einsum("bqhd,bkhd->bhqk", q, ck) / jnp.sqrt(float(hd))
    valid = jnp.arange(maxT)[None, :] <= pos[:, None]      # [B, maxT]
    att = jnp.where(valid[:, None, None, :], att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", att, cv).reshape(B, 1, H * hd)
    return linear(cfg, params, f"{lname}.o", out), (ck, cv)


def prefill(cfg: ModelCfg, params, tokens, max_len: int):
    """tokens [B, Tp] → (next_token [B] i32, k_caches, v_caches [L,B,maxT,H,hd])."""
    p = cfg.preset
    B, T = tokens.shape
    x = params["emb.tok"][tokens]
    pos = jnp.arange(T)
    ks, vs = [], []
    for i in range(p.n_layers):
        ck = jnp.zeros((B, max_len, p.n_heads, p.head_dim))
        cv = jnp.zeros((B, max_len, p.n_heads, p.head_dim))
        h, (ck, cv) = attention(cfg, params, f"l{i}.attn",
                                rmsnorm(params, f"l{i}.norm1", x), pos, True,
                                linear, (ck, cv), 0)
        x = x + h
        x = x + mlp(cfg, params, f"l{i}.mlp",
                    rmsnorm(params, f"l{i}.norm2", x), linear)
        ks.append(ck)
        vs.append(cv)
    x = rmsnorm(params, "normf", x)
    lg = x[:, -1] @ params["head.W"]
    nxt = jnp.argmax(lg, -1).astype(jnp.int32)
    return nxt, jnp.stack(ks), jnp.stack(vs)


def decode_step(cfg: ModelCfg, params, kc, vc, tok, pos):
    """One greedy decode step with device-resident KV cache.

    kc, vc: [L, B, maxT, H, hd]; tok: [B] i32; pos: [B] i32 — *per-row*
    positions: row b's token is written at kc[:, b, pos[b]] and attends keys
    j <= pos[b]. Rows advance independently, so a freshly admitted row can
    decode from its own (short) prefix while its neighbours are deep into
    theirs — no batch-wide position barrier. Returns (next_tok, kc', vc')."""
    p = cfg.preset
    x = params["emb.tok"][tok][:, None, :]          # [B, 1, d]
    nk, nv = [], []
    for i in range(p.n_layers):
        h, (ck, cv) = _attn_decode_rows(cfg, params, f"l{i}.attn",
                                        rmsnorm(params, f"l{i}.norm1", x),
                                        pos, (kc[i], vc[i]))
        x = x + h
        x = x + mlp(cfg, params, f"l{i}.mlp",
                    rmsnorm(params, f"l{i}.norm2", x), linear)
        nk.append(ck)
        nv.append(cv)
    x = rmsnorm(params, "normf", x)
    lg = x[:, 0] @ params["head.W"]
    nxt = jnp.argmax(lg, -1).astype(jnp.int32)
    return nxt, jnp.stack(nk), jnp.stack(nv)


def prefill_row(cfg: ModelCfg, params, kc, vc, window, row, length, keep):
    """Single-row prefill spliced into a *live* batch KV cache.

    kc, vc: [L, B, maxT, H, hd] — the batch's resident caches, other rows
    mid-decode; window: [Tp] i32, left-aligned (real tokens at 0..length,
    PAD after); row / length / keep: scalar i32. Runs the full-window
    forward for one sequence, then rewrites only row `row`: positions
    < keep retain the row's current state (an imported cached prefix),
    positions keep..length-1 take the freshly computed k/v, positions
    >= length are zeroed (so exported rows are byte-deterministic). Every
    other row's KV is untouched — admission is a row scatter, not a batch
    barrier. Returns (next_token scalar i32 from the logits at length-1,
    kc', vc')."""
    p = cfg.preset
    T = window.shape[0]
    H, hd = p.n_heads, p.head_dim
    maxT = kc.shape[2]
    x = params["emb.tok"][window][None]             # [1, T, d]
    pos = jnp.arange(T)
    causal = jnp.tril(jnp.ones((T, T), bool))[None, None]
    nk, nv = [], []
    for i in range(p.n_layers):
        lname = f"l{i}.attn"
        xn = rmsnorm(params, f"l{i}.norm1", x)
        q = linear(cfg, params, f"{lname}.q", xn).reshape(1, T, H, hd)
        k = linear(cfg, params, f"{lname}.k", xn).reshape(1, T, H, hd)
        v = linear(cfg, params, f"{lname}.v", xn).reshape(1, T, H, hd)
        q, k = _rope(q, pos), _rope(k, pos)
        att = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(float(hd))
        att = jax.nn.softmax(jnp.where(causal, att, -1e30), -1)
        h = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(1, T, H * hd)
        x = x + linear(cfg, params, f"{lname}.o", h)
        x = x + mlp(cfg, params, f"l{i}.mlp",
                    rmsnorm(params, f"l{i}.norm2", x), linear)
        nk.append(k[0])                              # [T, H, hd]
        nv.append(v[0])
    x = rmsnorm(params, "normf", x)
    lg = x[0] @ params["head.W"]                     # [T, vocab]
    last = jax.lax.dynamic_index_in_dim(lg, length - 1, 0, keepdims=False)
    nxt = jnp.argmax(last, -1).astype(jnp.int32)

    tpos = jnp.arange(maxT)[:, None, None]           # [maxT, 1, 1]

    def splice(cache, fresh_tl):                     # [B,maxT,H,hd], [T,H,hd]
        old_row = jax.lax.dynamic_index_in_dim(cache, row, 0, keepdims=False)
        new_row = jnp.zeros_like(old_row).at[:T].set(fresh_tl)
        merged = jnp.where(tpos < keep, old_row,
                           jnp.where(tpos < length, new_row, 0.0))
        return jax.lax.dynamic_update_slice(cache, merged[None],
                                            (row, 0, 0, 0))

    kc2 = jnp.stack([splice(kc[i], nk[i]) for i in range(p.n_layers)])
    vc2 = jnp.stack([splice(vc[i], nv[i]) for i in range(p.n_layers)])
    return nxt, kc2, vc2


def count_params(cfg: ModelCfg) -> dict:
    """Total / trainable parameter counts (Table 5's Param column)."""
    params = init_params(cfg, 0)
    total = sum(int(v.size) for v in params.values())
    trainable = sum(int(v.size) for k, v in params.items()
                    if not is_frozen(cfg, k))
    if cfg.variant == "sltrain":
        # only the sampled support of S is real parameters
        dense = sum(int(params[k].size) for k in params if k.endswith(".Sval"))
        total -= int(dense * (1 - cfg.sparse_density) * 2)  # Sval + Smask
        trainable -= int(dense * (1 - cfg.sparse_density))
    return {"total": total, "trainable": trainable}
