"""AOT pipeline: artifact files exist, HLO text parses basic invariants,
manifest agrees with state0.npz, and the lowered train step is runnable."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M, optim

ART = "/tmp/cola_test_artifacts"


@pytest.fixture(scope="module")
def tiny_cola_dir():
    cfg = aot.make_cfg("tiny", "cola")
    return aot.emit(cfg, ART, serve=True, verbose=False)


def test_files_exist(tiny_cola_dir):
    for f in ("train_step.hlo.txt", "eval_step.hlo.txt", "activations.hlo.txt",
              "prefill.hlo.txt", "decode_step.hlo.txt", "prefill_row.hlo.txt",
              "state0.npz", "manifest.json"):
        assert os.path.exists(os.path.join(tiny_cola_dir, f)), f


def test_manifest_consistent(tiny_cola_dir):
    man = json.load(open(os.path.join(tiny_cola_dir, "manifest.json")))
    npz = np.load(os.path.join(tiny_cola_dir, "state0.npz"))
    assert man["n_state"] == len(npz.files)
    assert man["n_params"] == len(man["param_names"])
    assert man["n_state"] == man["n_params"] + len(man["opt_names"])
    for i, shape in enumerate(man["state_shapes"]):
        assert list(npz[f"s{i:06d}"].shape) == shape
    # params occupy the first n_params slots in sorted-name order
    assert man["param_names"] == sorted(man["param_names"])


def test_hlo_text_is_parseable_module(tiny_cola_dir):
    text = open(os.path.join(tiny_cola_dir, "train_step.hlo.txt")).read()
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # tuple return (return_tuple=True) so the rust side can decompose
    assert "tuple(" in text or "ROOT" in text


def test_state0_roundtrip_order(tiny_cola_dir):
    """npz keys s000000.. must reconstruct the exact layout order."""
    cfg = aot.make_cfg("tiny", "cola")
    params = M.init_params(cfg, cfg.preset.seed)
    opt = optim.opt_init(cfg, params)
    layout = aot.StateLayout(cfg, params, opt)
    npz = np.load(os.path.join(tiny_cola_dir, "state0.npz"))
    flat = layout.state0()
    for i, x in enumerate(flat):
        np.testing.assert_array_equal(np.asarray(x), npz[f"s{i:06d}"])


def test_lowered_train_step_runs(tiny_cola_dir):
    """Execute the lowered HLO via jax's own runtime as a sanity check that
    the text is a complete, runnable module (the rust runtime_roundtrip
    integration test repeats this through PJRT-from-rust)."""
    man = json.load(open(os.path.join(tiny_cola_dir, "manifest.json")))
    npz = np.load(os.path.join(tiny_cola_dir, "state0.npz"))
    state = [jnp.asarray(npz[f"s{i:06d}"]) for i in range(man["n_state"])]
    cfg = aot.make_cfg("tiny", "cola")
    params = M.init_params(cfg, cfg.preset.seed)
    opt = optim.opt_init(cfg, params)
    layout = aot.StateLayout(cfg, params, opt)
    ts = aot.build_train_step(cfg, layout)
    toks = jax.random.randint(jax.random.PRNGKey(0),
                              man["tokens_shape"], 0, cfg.preset.vocab)
    out = ts(*state, jnp.float32(0), toks)
    assert len(out) == man["n_state"] + 2
    assert np.isfinite(float(out[man["n_state"]]))


def test_artifact_name_encodes_rank():
    cfg = aot.make_cfg("p60m", "cola", compute_frac=0.7)
    assert cfg.rank != cfg.preset.rank
    assert f"r{cfg.rank}" in aot.artifact_name(cfg)


def test_standard_set_covers_experiments():
    jobs = aot.standard_set()
    names = {(j["preset"], j["variant"]) for j in jobs}
    # Table 5 methods at the proxy ladder
    for v in ("full", "cola", "lora", "galore", "sltrain"):
        assert ("p60m", v) in names
    # Table 9 variants at throughput scale
    for v in ("full", "gcp", "cola", "cola_m"):
        assert ("e2e", v) in names
    # Table 8 encoder proxy
    assert any(j["preset"] == "bert" for j in jobs)


def test_galore_refresh_artifact(tmp_path):
    cfg = aot.make_cfg("tiny", "galore")
    d = aot.emit(cfg, str(tmp_path), verbose=False)
    assert os.path.exists(os.path.join(d, "refresh_proj.hlo.txt"))
    man = json.load(open(os.path.join(d, "manifest.json")))
    assert any(n.startswith("P::") for n in man["opt_names"])
