"""L1 correctness: Pallas kernel vs pure-jnp oracle, forward and VJP.

Hypothesis sweeps shapes/activations; tolerances are tight because both paths
compute in f32.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.cola_ae import cola_ae, vmem_plan, DEFAULT_BLOCK_N
from compile.kernels.ref import (cola_ae_ref, cola_ae_bottleneck_ref,
                                 cola_swiglu_mlp_ref, sigma)

ACTS = ["silu", "gelu", "relu", "identity"]


def _mats(key, n, d_in, r, d_out):
    k1, k2, k3 = jax.random.split(key, 3)
    x = jax.random.normal(k1, (n, d_in))
    a = jax.random.normal(k2, (d_in, r)) / np.sqrt(d_in)
    b = jax.random.normal(k3, (r, d_out)) / np.sqrt(r)
    return x, a, b


@pytest.mark.parametrize("act", ACTS)
def test_forward_matches_ref(act):
    x, a, b = _mats(jax.random.PRNGKey(0), 200, 64, 16, 96)
    got = cola_ae(x, a, b, act=act)
    want = cola_ae_ref(x, a, b, act)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("act", ACTS)
def test_vjp_matches_ref(act):
    x, a, b = _mats(jax.random.PRNGKey(1), 100, 32, 8, 48)
    f_k = lambda x, a, b: jnp.sum(jnp.sin(cola_ae(x, a, b, act=act)))
    f_r = lambda x, a, b: jnp.sum(jnp.sin(cola_ae_ref(x, a, b, act)))
    gk = jax.grad(f_k, argnums=(0, 1, 2))(x, a, b)
    gr = jax.grad(f_r, argnums=(0, 1, 2))(x, a, b)
    for u, v in zip(gk, gr):
        np.testing.assert_allclose(u, v, rtol=2e-4, atol=2e-5)


def test_leading_dims_flattened():
    x = jax.random.normal(jax.random.PRNGKey(2), (3, 5, 7, 32))
    _, a, b = _mats(jax.random.PRNGKey(3), 1, 32, 8, 20)
    got = cola_ae(x, a, b)
    want = cola_ae_ref(x, a, b)
    assert got.shape == (3, 5, 7, 20)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_rank_mismatch_raises():
    x = jnp.zeros((4, 8))
    a = jnp.zeros((8, 3))
    b = jnp.zeros((4, 8))  # expects rank 3
    with pytest.raises(AssertionError):
        cola_ae(x, a, b)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 300),
    d_in=st.sampled_from([8, 16, 32, 64, 128]),
    r=st.sampled_from([4, 8, 16, 32]),
    d_out=st.sampled_from([8, 24, 64, 160]),
    act=st.sampled_from(ACTS),
    block=st.sampled_from([32, 128, 256]),
)
def test_hypothesis_shape_sweep(n, d_in, r, d_out, act, block):
    """Any token count (incl. non-multiples of the block) and any geometry
    must agree with the oracle — this exercises the padding path."""
    x, a, b = _mats(jax.random.PRNGKey(n), n, d_in, r, d_out)
    got = cola_ae(x, a, b, act=act, block_n=block)
    want = cola_ae_ref(x, a, b, act)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(2, 120),
    d_in=st.sampled_from([16, 48]),
    r=st.sampled_from([4, 12]),
    act=st.sampled_from(ACTS),
)
def test_hypothesis_grad_sweep(n, d_in, r, act):
    x, a, b = _mats(jax.random.PRNGKey(n + 999), n, d_in, r, d_in)
    f_k = lambda a: jnp.sum(cola_ae(x, a, b, act=act, block_n=32) ** 2)
    f_r = lambda a: jnp.sum(cola_ae_ref(x, a, b, act) ** 2)
    np.testing.assert_allclose(jax.grad(f_k)(a), jax.grad(f_r)(a),
                               rtol=5e-4, atol=5e-5)


def test_dtype_f32_preserved():
    x, a, b = _mats(jax.random.PRNGKey(4), 10, 16, 4, 16)
    assert cola_ae(x, a, b).dtype == jnp.float32


def test_swiglu_composition_matches():
    """The MLP composition of three AEs (as the model uses it)."""
    key = jax.random.PRNGKey(7)
    ks = jax.random.split(key, 7)
    d, dff, r, n = 32, 88, 8, 50
    x = jax.random.normal(ks[0], (n, d))
    mk = lambda k, i, o: jax.random.normal(k, (i, o)) / np.sqrt(i)
    ag, bg = mk(ks[1], d, r), mk(ks[2], r, dff)
    au, bu = mk(ks[3], d, r), mk(ks[4], r, dff)
    ad, bd = mk(ks[5], dff, r), mk(ks[6], r, d)
    want = cola_swiglu_mlp_ref(x, ag, bg, au, bu, ad, bd)
    g = cola_ae(x, ag, bg)
    u = cola_ae(x, au, bu)
    got = cola_ae(g * u, ad, bd)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


def test_bottleneck_ref_is_encoder_half():
    x, a, b = _mats(jax.random.PRNGKey(8), 20, 16, 4, 16)
    z = cola_ae_bottleneck_ref(x, a)
    np.testing.assert_allclose(z @ b, cola_ae_ref(x, a, b), rtol=1e-6)


# ---------------------------------------------------------------------------
# VMEM planning (the TPU-side performance contract, DESIGN.md §7)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("d,r", [(512, 128), (1024, 256), (2048, 512)])
def test_vmem_fits_paper_scales(d, r):
    plan = vmem_plan(d, r, d, block_n=DEFAULT_BLOCK_N)
    assert plan["fits_16mib"], plan


def test_vmem_7b_needs_split():
    plan = vmem_plan(4096, 1024, 4096, block_n=DEFAULT_BLOCK_N)
    # the 7B AE tile exceeds VMEM only via the weight tiles — documented split
    assert plan["a_tile"] + plan["b_tile"] > 8 * 1024 * 1024
