"""L2 correctness: model shapes, variant equivalences, losses, KV-cache
decode consistency, and optimizer behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M, optim
from compile.aot import make_cfg, StateLayout, build_train_step
from compile.cola_m import block_fn_for
from compile.presets import PRESETS, SIGMA_MODES, paper_rank_for


def _toks(cfg, bs=2, extra=1, seed=0):
    p = cfg.preset
    return jax.random.randint(jax.random.PRNGKey(seed), (bs, p.seq_len + extra),
                              0, p.vocab)


# ---------------------------------------------------------------------------
# Shapes & parameter accounting
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("variant", ["full", "cola", "lora", "sltrain"])
def test_logits_shape(variant):
    cfg = make_cfg("tiny", variant)
    params = M.init_params(cfg, 0)
    toks = _toks(cfg, extra=0)
    lg = M.logits_fn(cfg, params, toks)
    assert lg.shape == (2, cfg.preset.seq_len, cfg.preset.vocab)


def test_cola_param_reduction():
    """CoLA must cut linear-layer parameters roughly in half at r=d/4
    (2dr + r(d+dff) vs d² + d·dff per attention+mlp pair)."""
    full = M.count_params(make_cfg("p60m", "full"))["total"]
    cola = M.count_params(make_cfg("p60m", "cola"))["total"]
    assert cola < full
    p = PRESETS["p60m"]
    emb = 2 * p.vocab * p.d
    assert (cola - emb) < 0.55 * (full - emb)


def test_lora_frozen_partition():
    cfg = make_cfg("tiny", "lora")
    params = M.init_params(cfg, 0)
    frozen = [k for k in params if M.is_frozen(cfg, k)]
    assert frozen and all(k.endswith(".W0") for k in frozen)
    counts = M.count_params(cfg)
    assert counts["trainable"] < counts["total"]


def test_sltrain_mask_frozen():
    cfg = make_cfg("tiny", "sltrain")
    params = M.init_params(cfg, 0)
    assert any(M.is_frozen(cfg, k) for k in params if k.endswith(".Smask"))


def test_param_order_deterministic():
    cfg = make_cfg("tiny", "cola")
    p1 = M.init_params(cfg, 0)
    p2 = M.init_params(cfg, 0)
    assert M.param_order(p1) == M.param_order(p2)
    for k in p1:
        np.testing.assert_array_equal(p1[k], p2[k])


# ---------------------------------------------------------------------------
# Variant equivalences
# ---------------------------------------------------------------------------

def test_cola_m_identical_to_cola():
    """Remat must not change numerics — loss and grads bit-comparable."""
    c1, c2 = make_cfg("tiny", "cola"), make_cfg("tiny", "cola_m")
    p = M.init_params(c1, 0)
    toks = _toks(c1)
    l1 = M.lm_loss(c1, p, toks, block_fn=block_fn_for(c1))
    l2 = M.lm_loss(c2, p, toks, block_fn=block_fn_for(c2))
    np.testing.assert_allclose(l1, l2, rtol=1e-6)
    g1 = jax.grad(lambda q: M.lm_loss(c1, q, toks, block_fn=block_fn_for(c1)))(p)
    g2 = jax.grad(lambda q: M.lm_loss(c2, q, toks, block_fn=block_fn_for(c2)))(p)
    for k in g1:
        np.testing.assert_allclose(g1[k], g2[k], rtol=2e-4, atol=2e-5)


def test_gcp_identical_to_full():
    c1, c2 = make_cfg("tiny", "full"), make_cfg("tiny", "gcp")
    p = M.init_params(c1, 0)
    toks = _toks(c1)
    l1 = M.lm_loss(c1, p, toks, block_fn=block_fn_for(c1))
    l2 = M.lm_loss(c2, p, toks, block_fn=block_fn_for(c2))
    np.testing.assert_allclose(l1, l2, rtol=1e-6)


def test_kernel_and_oracle_paths_agree():
    cfg_k = make_cfg("tiny", "cola")
    cfg_o = M.ModelCfg(preset=cfg_k.preset, variant="cola", use_kernel=False)
    p = M.init_params(cfg_k, 0)
    toks = _toks(cfg_k)
    np.testing.assert_allclose(M.lm_loss(cfg_k, p, toks),
                               M.lm_loss(cfg_o, p, toks), rtol=1e-5)


@pytest.mark.parametrize("mode", SIGMA_MODES)
def test_sigma_modes_forward(mode):
    cfg = make_cfg("tiny", "cola", sigma_mode=mode)
    p = M.init_params(cfg, 0)
    lg = M.logits_fn(cfg, p, _toks(cfg, extra=0))
    assert np.isfinite(np.asarray(lg)).all()


def test_sigma_modes_differ():
    """The four Table-10 placements are genuinely different functions."""
    outs = []
    for mode in SIGMA_MODES:
        cfg = make_cfg("tiny", "cola", sigma_mode=mode)
        p = M.init_params(cfg, 0)
        outs.append(np.asarray(M.logits_fn(cfg, p, _toks(cfg, extra=0))))
    for i in range(len(outs)):
        for j in range(i + 1, len(outs)):
            assert not np.allclose(outs[i], outs[j])


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def test_lm_loss_near_uniform_at_init():
    cfg = make_cfg("tiny", "full")
    p = M.init_params(cfg, 0)
    l = float(M.lm_loss(cfg, p, _toks(cfg)))
    assert abs(l - np.log(cfg.preset.vocab)) < 0.5


def test_eval_sum_matches_mean_loss():
    cfg = make_cfg("tiny", "cola")
    p = M.init_params(cfg, 0)
    toks = _toks(cfg)
    s, n = M.lm_loss_sum(cfg, p, toks)
    np.testing.assert_allclose(float(s) / float(n),
                               float(M.lm_loss(cfg, p, toks)), rtol=1e-5)


def test_mlm_loss_finite():
    cfg = make_cfg("bert", "cola")
    p = M.init_params(cfg, 0)
    pr = cfg.preset
    toks = jax.random.randint(jax.random.PRNGKey(0), (2, pr.seq_len), 4, pr.vocab)
    mask = jnp.zeros_like(toks).at[:, ::7].set(toks[:, ::7] + 1)
    l = float(M.mlm_loss(cfg, p, toks, mask))
    assert np.isfinite(l) and l > 0


# ---------------------------------------------------------------------------
# KV-cache decode vs full forward
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("variant", ["full", "cola"])
def test_decode_matches_full_forward(variant):
    """Greedy decode through (prefill + decode_step) must reproduce the
    argmax chain of repeated full forwards."""
    cfg = make_cfg("tiny", variant)
    p = M.init_params(cfg, 0)
    pr = cfg.preset
    B, Tp, steps, max_len = 2, 8, 4, 16
    prompt = jax.random.randint(jax.random.PRNGKey(3), (B, Tp), 0, pr.vocab)

    nxt, kc, vc = M.prefill(cfg, p, prompt, max_len)
    got = [np.asarray(nxt)]
    cur = prompt
    for s in range(steps):
        cur = jnp.concatenate([cur, nxt[:, None]], axis=1)
        pos = jnp.full((B,), Tp + s, jnp.int32)          # per-row positions
        nxt, kc, vc = M.decode_step(cfg, p, kc, vc, cur[:, -1], pos)
        got.append(np.asarray(nxt))

    # oracle: argmax of the full forward at each length
    cur = prompt
    for s in range(steps + 1):
        lg = M.logits_fn(cfg, p, cur)
        want = np.asarray(jnp.argmax(lg[:, -1], -1))
        np.testing.assert_array_equal(got[s], want, err_msg=f"step {s}")
        cur = jnp.concatenate([cur, jnp.asarray(got[s])[:, None]], 1)


def test_prefill_row_splices_without_touching_neighbours():
    """prefill_row rebuilds exactly one row of a live cache: the other rows'
    KV is byte-identical before/after, the spliced row matches a from-scratch
    batch prefill of the same window, and positions < keep retain whatever
    the row already held (an imported cached prefix)."""
    cfg = make_cfg("tiny", "full")
    p = M.init_params(cfg, 0)
    pr = cfg.preset
    B, Tp, max_len = 2, 8, 16
    prompt = jax.random.randint(jax.random.PRNGKey(5), (B, Tp), 0, pr.vocab)
    _, kc, vc = M.prefill(cfg, p, prompt, max_len)

    w = jax.random.randint(jax.random.PRNGKey(7), (Tp,), 0, pr.vocab)
    nxt, kc2, vc2 = M.prefill_row(cfg, p, kc, vc, w, 1, Tp, 0)
    # neighbour row untouched
    np.testing.assert_array_equal(np.asarray(kc2[:, 0]), np.asarray(kc[:, 0]))
    np.testing.assert_array_equal(np.asarray(vc2[:, 0]), np.asarray(vc[:, 0]))
    # spliced row == batch prefill of the same window
    nref, kref, vref = M.prefill(cfg, p, w[None], max_len)
    np.testing.assert_allclose(np.asarray(kc2[:, 1]), np.asarray(kref[:, 0]),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(vc2[:, 1]), np.asarray(vref[:, 0]),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(nxt), np.asarray(nref[0]))
    # keep: positions < keep survive verbatim (here: a sentinel-filled row)
    sk = kc.at[:, 1].set(7.0)
    sv = vc.at[:, 1].set(7.0)
    _, kc3, _ = M.prefill_row(cfg, p, sk, sv, w, 1, Tp, 3)
    np.testing.assert_array_equal(np.asarray(kc3[:, 1, :3]),
                                  np.full_like(np.asarray(kc3[:, 1, :3]), 7.0))
    np.testing.assert_allclose(np.asarray(kc3[:, 1, 3:Tp]),
                               np.asarray(kref[:, 0, 3:Tp]),
                               rtol=1e-5, atol=1e-5)


def test_decode_rows_advance_at_independent_positions():
    """A freshly admitted short row and a deep row decode in one batch: each
    row's next token must equal its own single-sequence reference chain."""
    cfg = make_cfg("tiny", "full")
    p = M.init_params(cfg, 0)
    pr = cfg.preset
    Tp, Ls, max_len = 8, 5, 16
    prompt = jax.random.randint(jax.random.PRNGKey(9), (2, Tp), 0, pr.vocab)
    n0, kc, vc = M.prefill(cfg, p, prompt, max_len)

    # admit a 5-token request into row 1 mid-flight (left-aligned window)
    short = jax.random.randint(jax.random.PRNGKey(11), (Ls,), 0, pr.vocab)
    w = jnp.concatenate([short, jnp.zeros((Tp - Ls,), short.dtype)])
    n1, kc, vc = M.prefill_row(cfg, p, kc, vc, w, 1, Ls, 0)

    feed = jnp.stack([n0[0], n1]).astype(jnp.int32)
    pos = jnp.asarray([Tp, Ls], jnp.int32)               # rows at depths 8, 5
    nxt, _, _ = M.decode_step(cfg, p, kc, vc, feed, pos)

    # row-0 reference: its own B=1 chain at position Tp
    r0n, r0k, r0v = M.prefill(cfg, p, prompt[:1], max_len)
    ref0, _, _ = M.decode_step(cfg, p, r0k, r0v, r0n,
                               jnp.asarray([Tp], jnp.int32))
    # row-1 reference: the short prompt's own B=1 chain at position Ls
    r1n, r1k, r1v = M.prefill(cfg, p, short[None], max_len)
    ref1, _, _ = M.decode_step(cfg, p, r1k, r1v, r1n,
                               jnp.asarray([Ls], jnp.int32))
    np.testing.assert_array_equal(np.asarray(n1), np.asarray(r1n[0]))
    np.testing.assert_array_equal(np.asarray(nxt),
                                  np.asarray([ref0[0], ref1[0]]))


# ---------------------------------------------------------------------------
# Optimizers
# ---------------------------------------------------------------------------

def test_cosine_schedule_shape():
    cfg = make_cfg("tiny", "full")
    p = cfg.preset
    warm = p.warmup_frac * p.total_steps
    lrs = [float(optim.cosine_lr(cfg, jnp.float32(s)))
           for s in range(p.total_steps)]
    peak = max(lrs)
    assert abs(peak - p.lr) / p.lr < 0.05
    assert lrs[0] < 0.3 * peak                       # warmup starts low
    assert lrs[-1] < 0.2 * peak                      # annealed at the end
    assert np.argmax(lrs) <= warm + 1


def test_adamw_decreases_loss():
    cfg = make_cfg("tiny", "cola")
    params = M.init_params(cfg, 0)
    opt = optim.opt_init(cfg, params)
    layout = StateLayout(cfg, params, opt)
    ts = jax.jit(build_train_step(cfg, layout))
    state = layout.state0()
    toks = _toks(cfg, bs=4)[None]
    first = last = None
    for i in range(6):
        out = ts(*state, jnp.float32(i), toks)
        state = list(out[:layout.n_state])
        loss = float(out[layout.n_state])
        first = first if first is not None else loss
        last = loss
    assert last < first - 0.1


def test_galore_state_is_lowrank():
    cfg = make_cfg("tiny", "galore")
    params = M.init_params(cfg, 0)
    opt = optim.opt_init(cfg, params)
    r = cfg.r
    mkeys = [k for k in opt if k.startswith("m::") and ".attn." in k]
    assert mkeys
    for k in mkeys:
        assert opt[k].shape[0] <= r
    # projections orthonormal
    pk = [k for k in opt if k.startswith("P::")][0]
    P = np.asarray(opt[pk])
    np.testing.assert_allclose(P.T @ P, np.eye(P.shape[1]), atol=1e-5)


def test_galore_refresh_changes_projection():
    cfg = make_cfg("tiny", "galore")
    params = M.init_params(cfg, 0)
    opt = optim.opt_init(cfg, params)
    new = optim.galore_refresh(cfg, opt, jnp.int32(42))
    pk = [k for k in opt if k.startswith("P::")][0]
    assert not np.allclose(np.asarray(opt[pk]), np.asarray(new[pk]))
    mk = "m::" + pk[3:]
    assert np.allclose(np.asarray(new[mk]), 0)


def test_frozen_params_not_updated():
    cfg = make_cfg("tiny", "lora")
    params = M.init_params(cfg, 0)
    opt = optim.opt_init(cfg, params)
    layout = StateLayout(cfg, params, opt)
    ts = jax.jit(build_train_step(cfg, layout))
    out = ts(*layout.state0(), jnp.float32(0), _toks(cfg, bs=4)[None])
    new_params = dict(zip(layout.param_names, out[:layout.n_params]))
    for k, v in params.items():
        if M.is_frozen(cfg, k):
            np.testing.assert_array_equal(np.asarray(v),
                                          np.asarray(new_params[k]))


def test_paper_rank_for_targets():
    """paper_rank_for must land near the requested compute fraction."""
    from compile.presets import _ffw
    for d in (128, 256, 512):
        for frac in (0.4, 0.7):
            r = paper_rank_for(d, frac)
            dff = _ffw(d)
            got = (48 * d * r + 18 * r * (d + dff)) / (24 * d * d + 18 * d * dff)
            assert abs(got - frac) < 0.15, (d, frac, r, got)
