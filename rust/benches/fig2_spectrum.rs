//! Figure 2 (+ Appendix A Figs 9-11) — activation spectra of a pre-trained
//! model: singular-value decay per block and full-dim vs effective rank
//! r(0.95). The paper measures GPT-2 small on WikiText2; we train the p60m
//! full-rank proxy and probe its block activations on held-out batches.

use cola::bench::{banner, bench_steps, proxy_note, require_artifacts};
use cola::config::TrainConfig;
use cola::coordinator::{RankProbe, Trainer};
use cola::data::BatchIter;

fn main() {
    if !require_artifacts(&["p60m_full"]) {
        return;
    }
    banner("Figure 2", "activation spectrum + effective rank of a trained model");
    proxy_note();

    let steps = bench_steps();
    let cfg = TrainConfig {
        artifact: "p60m_full".into(),
        steps,
        log_every: 100,
        ..TrainConfig::default()
    };
    let mut tr = Trainer::new(cfg).expect("trainer");
    let report = tr.run().expect("train");
    println!("trained p60m_full for {steps} steps (val ppl {:.2})\n", report.val_ppl);

    let man = tr.manifest().clone();
    let probe = RankProbe::new(&tr.art).expect("probe");
    let params = tr.params_literals().expect("params");
    let client = cola::runtime::client().unwrap();
    let bufs: Vec<xla::PjRtBuffer> = params
        .iter()
        .map(|l| client.buffer_from_host_literal(None, l).unwrap())
        .collect();

    let bpe = cola::coordinator::trainer::shared_bpe(man.preset.vocab).unwrap();
    let mut it = BatchIter::new(bpe, 777, man.preset.vocab);
    let toks = it.next_eval(2, man.preset.seq_len + 1);

    let spectra = probe.spectra(&bufs, &toks, 0.95).expect("spectra");
    println!("(a) singular-value decay (first 12 of each block input):");
    for s in &spectra {
        let head: Vec<String> = s
            .singular_values
            .iter()
            .take(12)
            .map(|x| format!("{x:.1}"))
            .collect();
        println!("  {:>10}: {}", s.name, head.join(" "));
    }
    println!("\n(b) full dimension vs effective rank r(0.95):");
    let mut all_low = true;
    for s in &spectra {
        let frac = s.effective_rank as f64 / s.full_dim as f64;
        println!(
            "  {:>10}: {:>4} / {:<4} ({:.0}%)",
            s.name,
            s.effective_rank,
            s.full_dim,
            frac * 100.0
        );
        // paper's claim: effective rank well below full dimension
        if s.name != "l0.input" && frac > 0.8 {
            all_low = false;
        }
    }
    assert!(all_low, "activations should be effectively low-rank");
    println!("\nshape check: r(0.95) << d across blocks (paper Fig. 2b) — OK");

    // decay check: energy concentrates in the top quarter of the spectrum
    for s in &spectra {
        let e = cola::linalg::spectrum_energy(&s.singular_values);
        let q = e[s.singular_values.len() / 4 - 1];
        println!("  {:>10}: top-25% singular values hold {:.0}% of energy", s.name, q * 100.0);
    }
}
