//! Table 11 — inference memory and throughput: full-rank vs SLTrain vs CoLA
//! through the serving pool (prefill + KV-cache decode, continuous batching).
//! Paper shape (A100, 1B/7B): CoLA ~1.6x tokens/s of full-rank at lower
//! memory; SLTrain slightly below full-rank throughput.

use cola::bench::{banner, proxy_note, require_artifacts};
use cola::config::ServeConfig;
use cola::data::{corpus::CorpusCfg, CorpusGen};
use cola::metrics::percentile;
use cola::serve::{InferenceService, ServicePool, SubmitOptions};
use std::time::Instant;

fn measure(artifact: &str, n_requests: usize, max_new: usize) -> (f64, f64, f64) {
    let cfg = ServeConfig {
        artifact: artifact.into(),
        max_new_tokens: max_new,
        queue_depth: n_requests.max(1),
        ..ServeConfig::default()
    };
    let pool = ServicePool::start(cfg).expect(artifact);
    let man = cola::runtime::ArtifactDir::open_named(artifact).unwrap().manifest;
    let bpe = cola::coordinator::trainer::shared_bpe(man.preset.vocab).unwrap();
    let mut gen = CorpusGen::new(CorpusCfg { seed: 5, ..CorpusCfg::default() });

    // warmup (compile + first batch)
    let opts = SubmitOptions { max_new_tokens: Some(4), ..Default::default() };
    pool.generate(bpe.encode(&gen.text(40)), opts).unwrap();

    // submit everything up front: continuous batching keeps the slot table
    // full as rows finish, instead of draining whole static batches
    let t0 = Instant::now();
    let mut streams = Vec::new();
    for _ in 0..n_requests {
        streams.push(pool.submit_wait(bpe.encode(&gen.text(40)), SubmitOptions::default()).unwrap());
    }
    let mut total_tokens = 0usize;
    let mut lat = Vec::new();
    for s in streams {
        let c = s.wait().unwrap();
        total_tokens += c.tokens.len();
        lat.push(c.timing.total.as_secs_f64() * 1000.0);
    }
    let secs = t0.elapsed().as_secs_f64();
    let p50 = percentile(&lat, 50.0).unwrap_or(f64::NAN);
    pool.shutdown();
    let rss = cola::metrics::peak_rss_bytes() as f64 / 1e9;
    (total_tokens as f64 / secs, p50, rss)
}

fn main() {
    let arts = ["p350m_full", "p350m_sltrain", "p350m_cola"];
    if !require_artifacts(&arts) {
        return;
    }
    banner("Table 11", "inference memory + throughput through the serving engine");
    proxy_note();

    // paper @1B BZ=32: full 5.74GB/21109 t/s; sltrain 4.18/20096; cola 3.84/34697
    let paper = [(5.74, 21109.0), (4.18, 20096.0), (3.84, 34697.0)];
    println!(
        "{:>14} {:>10} {:>10} {:>10}   {:>22}",
        "variant", "tok/s", "p50 ms", "proc RSS", "paper @1B (GB, tok/s)"
    );
    let mut tput = Vec::new();
    for (a, (pm, pt)) in arts.iter().zip(paper) {
        let (tps, p50, rss) = measure(a, 24, 16);
        println!(
            "{:>14} {:>10.0} {:>10.1} {:>7.2} GB   {pm:>8.2}, {pt:>8.0}",
            a.strip_prefix("p350m_").unwrap(),
            tps,
            p50,
            rss
        );
        tput.push(tps);
    }
    // model sizes (memory column at paper scale comes from the manifests)
    for a in arts {
        let m = cola::runtime::ArtifactDir::open_named(a).unwrap().manifest;
        println!(
            "  {a}: {:.2}M params ({} state tensors)",
            m.n_total_params as f64 / 1e6,
            m.n_state
        );
    }
    let ratio = tput[2] / tput[0];
    println!("\nCoLA / full inference throughput: {ratio:.2}x (paper: 1.64x)");
    if ratio > 1.0 {
        println!("ordering (CoLA > full) — OK");
    } else {
        println!(
            "ordering DEVIATION: at proxy width the per-token decode is \
             dispatch-bound, not GEMM-bound; the paper's gap is at 1B/7B widths"
        );
    }
    assert!(ratio > 0.8, "CoLA inference should never be materially slower");
}
