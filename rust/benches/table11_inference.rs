//! Table 11 — inference memory and throughput: full-rank vs SLTrain vs CoLA
//! served **side by side from one process** through a `ModelRouter` (one
//! continuous-batching pool per artifact — the multi-artifact deployment the
//! paper's halved CoLA model size makes cheap).
//! Paper shape (A100, 1B/7B): CoLA ~1.6x tokens/s of full-rank at lower
//! memory; SLTrain slightly below full-rank throughput.

use cola::bench::{banner, proxy_note, require_artifacts};
use cola::config::RouterConfig;
use cola::data::{corpus::CorpusCfg, CorpusGen};
use cola::metrics::percentile;
use cola::serve::{ModelRouter, SubmitOptions};
use std::time::Instant;

fn measure(router: &ModelRouter, model: &str, n_requests: usize) -> (f64, f64, f64) {
    let artifact = &router.pool(model).expect(model).config().artifact;
    let man = cola::runtime::ArtifactDir::open_named(artifact).unwrap().manifest;
    let bpe = cola::coordinator::trainer::shared_bpe(man.preset.vocab).unwrap();
    let mut gen = CorpusGen::new(CorpusCfg { seed: 5, ..CorpusCfg::default() });
    // a small cycled prompt set — the repeated-prefix traffic (system
    // prompts, retries) the KV prefix cache targets, and a fixed workload so
    // the three variants compare like for like
    let prompt_set: Vec<Vec<i32>> = (0..4).map(|_| bpe.encode(&gen.text(40))).collect();

    // warmup (compile + first batch)
    let opts = SubmitOptions { max_new_tokens: Some(4), ..Default::default() };
    router.generate(model, prompt_set[0].clone(), opts).unwrap();

    // submit everything up front: continuous batching keeps the slot table
    // full as rows finish, instead of draining whole static batches
    let t0 = Instant::now();
    let mut streams = Vec::new();
    for r in 0..n_requests {
        let prompt = prompt_set[r % prompt_set.len()].clone();
        streams.push(router.submit_wait(model, prompt, SubmitOptions::default()).unwrap());
    }
    let mut total_tokens = 0usize;
    let mut lat = Vec::new();
    for s in streams {
        let c = s.wait().unwrap();
        total_tokens += c.tokens.len();
        lat.push(c.timing.total.as_secs_f64() * 1000.0);
    }
    let secs = t0.elapsed().as_secs_f64();
    let p50 = percentile(&lat, 50.0).unwrap_or(f64::NAN);
    let rss = cola::metrics::peak_rss_bytes() as f64 / 1e9;
    (total_tokens as f64 / secs, p50, rss)
}

fn main() {
    let arts = ["p350m_full", "p350m_sltrain", "p350m_cola"];
    if !require_artifacts(&arts) {
        return;
    }
    banner("Table 11", "inference memory + throughput through the model router");
    proxy_note();

    // one router, three resident models — variants answer side by side
    let defaults = cola::config::ServeConfig {
        max_new_tokens: 16,
        queue_depth: 24,
        ..Default::default()
    };
    let models = arts
        .iter()
        .map(|a| {
            let name = a.strip_prefix("p350m_").unwrap().to_string();
            let cfg = cola::config::ServeConfig { artifact: (*a).into(), ..defaults.clone() };
            (name, cfg)
        })
        .collect();
    let rcfg = RouterConfig { defaults, models };
    let router = ModelRouter::start(&rcfg).expect("router start");

    // paper @1B BZ=32: full 5.74GB/21109 t/s; sltrain 4.18/20096; cola 3.84/34697
    let paper = [(5.74, 21109.0), (4.18, 20096.0), (3.84, 34697.0)];
    println!(
        "{:>14} {:>10} {:>10} {:>10}   {:>22}",
        "model", "tok/s", "p50 ms", "proc RSS", "paper @1B (GB, tok/s)"
    );
    let mut tput = Vec::new();
    let model_names: Vec<String> = router.models().iter().map(|s| s.to_string()).collect();
    for (name, (pm, pt)) in model_names.iter().zip(paper) {
        let (tps, p50, rss) = measure(&router, name, 24);
        println!("{name:>14} {tps:>10.0} {p50:>10.1} {rss:>7.2} GB   {pm:>8.2}, {pt:>8.0}");
        tput.push(tps);
    }
    // prefill-avoidance addendum: each model's workload cycles a 4-prompt
    // repeated-prefix set, so fresh admissions can hit the KV prefix cache;
    // mid-flight rows whose windows shifted still re-encode (per-row
    // positions are the ROADMAP follow-on), so hit rates here are the
    // honest steady-state mix, not the sequential-retry best case
    println!("\nprefill avoidance (per model):");
    for (name, s) in router.stats_by_model() {
        println!(
            "  {name:>10}: prefills {} real ({:.1}ms avg) + {} elided ({}) | kv hits {} ({})",
            s.prefill_calls,
            if s.prefill_calls > 0 {
                s.prefill_nanos as f64 / s.prefill_calls as f64 * 1e-6
            } else {
                0.0
            },
            s.prefills_elided,
            cola::metrics::fmt_pct(s.prefills_elided, s.prefill_calls + s.prefills_elided),
            s.kv_cache_hits,
            cola::metrics::fmt_pct(s.kv_cache_hits, s.kv_cache_hits + s.kv_cache_misses),
        );
    }

    // RSS above is process-wide with ALL THREE variants resident — the
    // side-by-side serving footprint, not per-variant.
    // model sizes (memory column at paper scale comes from the manifests)
    for a in arts {
        let m = cola::runtime::ArtifactDir::open_named(a).unwrap().manifest;
        println!(
            "  {a}: {:.2}M params ({} state tensors)",
            m.n_total_params as f64 / 1e6,
            m.n_state
        );
    }
    let ratio = tput[2] / tput[0];
    println!("\nCoLA / full inference throughput: {ratio:.2}x (paper: 1.64x)");
    if ratio > 1.0 {
        println!("ordering (CoLA > full) — OK");
    } else {
        println!(
            "ordering DEVIATION: at proxy width the per-token decode is \
             dispatch-bound, not GEMM-bound; the paper's gap is at 1B/7B widths"
        );
    }
    assert!(ratio > 0.8, "CoLA inference should never be materially slower");
    router.shutdown();
}
