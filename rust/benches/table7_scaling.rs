//! Table 7 — scaling behaviour: CoLA at 0.4x/0.7x compute vs full-rank vs a
//! "Control" (full-rank scaled down to CoLA's FLOPs by shrinking d/layers).
//! Paper shape: Control << CoLA@0.4x ≈ full-rank < CoLA@0.7x.

use cola::bench::{banner, bench_steps, proxy_note, require_artifacts};
use cola::coordinator::cached_or_train;
use cola::runtime::ArtifactDir;

fn rank_of(art: &str) -> String {
    ArtifactDir::open_named(art)
        .map(|a| format!("r={}", a.manifest.rank))
        .unwrap_or_default()
}

fn main() {
    banner("Table 7", "scaling behaviour: CoLA 0.4x/0.7x vs full vs control");
    proxy_note();

    // paper rows: (scale, full, control, cola@0.4, cola@0.7)
    let paper = [
        ("p60m", 34.06, 37.73, 34.04, 31.52),
        ("p130m", 24.36, 27.05, 24.48, 23.97),
        ("p350m", 18.80, 20.53, 19.40, 18.32),
    ];
    let steps = bench_steps();
    let full_sweep = std::env::var("COLA_BENCH_FULL").is_ok();

    for (scale, p_full, p_ctl, p_c4, p_c7) in paper {
        if scale != "p60m" && !full_sweep {
            println!("-- {scale}: set COLA_BENCH_FULL=1 to include (slow) --");
            continue;
        }
        // find the 0.7x artifact name (rank-suffixed)
        let root = std::env::var("COLA_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        let c7 = std::fs::read_dir(&root)
            .ok()
            .and_then(|rd| {
                rd.filter_map(|e| e.ok())
                    .map(|e| e.file_name().to_string_lossy().into_owned())
                    .find(|n| n.starts_with(&format!("{scale}_cola_r")))
            })
            .unwrap_or_default();
        let arts = [
            format!("{scale}_full"),
            format!("{scale}_control_full"),
            format!("{scale}_cola"),
            c7.clone(),
        ];
        let refs: Vec<&str> = arts.iter().map(String::as_str).collect();
        if c7.is_empty() || !require_artifacts(&refs) {
            continue;
        }

        println!("-- {scale}, {steps} steps --");
        println!("{:>16} {:>9} {:>9} {:>11}", "variant", "val PPL", "FLOPs", "paper PPL");
        let mut got = Vec::new();
        for (art, (label, flops, paperv)) in arts.iter().zip([
            ("Full-Rank", "1.0x", p_full),
            ("Control", "~0.4x", p_ctl),
            (&*format!("CoLA {}", rank_of(&arts[2])), "0.4x", p_c4),
            (&*format!("CoLA {}", rank_of(&c7)), "0.7x", p_c7),
        ]) {
            let r = cached_or_train(art, steps, 0).expect(art);
            println!("{label:>16} {:>9.2} {flops:>9} {paperv:>11.2}", r.val_ppl);
            got.push(r.val_ppl);
        }
        let (full, ctl, c4, c7v) = (got[0], got[1], got[2], got[3]);
        // paper's shape: control clearly worse; cola@0.4 ~ full. The 0.7x
        // advantage over 0.4x emerges at compute-optimal budgets (the extra
        // rank needs tokens to pay off); at the proxy's short budget we
        // require it within noise of both 0.4x and full-rank.
        assert!(ctl > full, "{scale}: control must underperform full-rank");
        assert!(ctl > c4, "{scale}: control must underperform CoLA at equal FLOPs");
        assert!(c4 < full * 1.10, "{scale}: CoLA@0.4x on par with full");
        assert!(c7v < c4 * 1.05 && c7v < full * 1.08, "{scale}: 0.7x within noise");
        println!("shape checks (control worst, CoLA@0.4x on-par-or-better, 0.7x within noise) — OK\n");
    }
}
