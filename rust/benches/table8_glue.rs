//! Table 8 — BERT-Large/GLUE proxy: MLM-pretrain the encoder (full vs CoLA
//! at 0.7x compute), then fine-tune the classification head on a suite of
//! synthetic tasks (DESIGN.md §6) and compare accuracies.
//! Paper shape: CoLA's pretrain loss <= full's; fine-tuned scores on par or
//! better on most tasks.

use cola::bench::{banner, bench_steps, proxy_note, require_artifacts};
use cola::config::TrainConfig;
use cola::coordinator::Trainer;
use cola::data::ClsTaskGen;
use cola::runtime::executor::{buf_f32, lit_f32, lit_i32, to_device};
use cola::runtime::ArtifactDir;

const N_TASKS: usize = 4;
const FT_STEPS: usize = 60;
const EVAL_BATCHES: usize = 8;

/// Fine-tune the cls head (and backbone) on one synthetic task; return
/// held-out accuracy.
fn finetune_task(art: &ArtifactDir, params0: &[xla::Literal], task: usize) -> f64 {
    let man = &art.manifest;
    let n_classes = man.n_classes.expect("cls artifact");
    let d = man.preset.d;
    let (bs, seq) = (man.preset.batch, man.preset.seq_len);
    let cls_train = art.step("cls_train").unwrap();
    let cls_eval = art.step("cls_eval").unwrap();

    // state = pretrained params + fresh opt zeros (from state0) + cls head
    let state0 = art.load_state0().unwrap();
    let client = cola::runtime::client().unwrap();
    let mut state: Vec<xla::PjRtBuffer> = Vec::with_capacity(man.n_state);
    for (i, lit) in state0.iter().enumerate() {
        let use_pre = i < man.n_params;
        let l = if use_pre { &params0[i] } else { lit };
        state.push(client.buffer_from_host_literal(None, l).unwrap());
    }
    // zero-init classifier head + its moments
    let zeros = vec![0f32; d * n_classes];
    let wlit = xla::Literal::vec1(&zeros).reshape(&[d as i64, n_classes as i64]).unwrap();
    let mut cls_w = to_device(&wlit).unwrap();
    let mut cls_m = to_device(&wlit).unwrap();
    let mut cls_v = to_device(&wlit).unwrap();

    let bpe = cola::coordinator::trainer::shared_bpe(man.preset.vocab).unwrap();
    let mut gen = ClsTaskGen::new(bpe.clone(), task, 11, n_classes, man.preset.vocab);

    for step in 0..FT_STEPS {
        let (toks, labels) = gen.next_batch(bs, seq);
        let tok_b = to_device(&lit_i32(&toks, &[bs as i64, seq as i64]).unwrap()).unwrap();
        let lbl_b = to_device(&lit_i32(&labels, &[bs as i64]).unwrap()).unwrap();
        let step_b = to_device(&lit_f32(step as f32)).unwrap();
        let mut refs: Vec<&xla::PjRtBuffer> = state.iter().collect();
        refs.extend([&cls_w, &cls_m, &cls_v, &step_b, &tok_b, &lbl_b]);
        let mut out = cls_train.run_b(&refs).unwrap();
        // outputs: state' + (cls_w, cls_m, cls_v, loss)
        let _loss = buf_f32(&out[man.n_state + 3]).unwrap();
        cls_v = out.remove(man.n_state + 2);
        cls_m = out.remove(man.n_state + 1);
        cls_w = out.remove(man.n_state);
        out.truncate(man.n_state);
        state = out;
    }

    // held-out eval (disjoint generator seed)
    let mut eval_gen = ClsTaskGen::new(bpe, task, 99, n_classes, man.preset.vocab);
    let mut correct = 0.0;
    let mut total = 0.0;
    for _ in 0..EVAL_BATCHES {
        let (toks, labels) = eval_gen.next_batch(bs, seq);
        let tok_b = to_device(&lit_i32(&toks, &[bs as i64, seq as i64]).unwrap()).unwrap();
        let lbl_b = to_device(&lit_i32(&labels, &[bs as i64]).unwrap()).unwrap();
        let mut refs: Vec<&xla::PjRtBuffer> = state[..man.n_params].iter().collect();
        refs.extend([&cls_w, &tok_b, &lbl_b]);
        let out = cls_eval.run_b(&refs).unwrap();
        correct += buf_f32(&out[0]).unwrap() as f64;
        total += buf_f32(&out[1]).unwrap() as f64;
    }
    correct / total
}

fn main() {
    // the cola bert artifact is rank-suffixed (0.7x compute)
    let root = std::env::var("COLA_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let bert_cola = std::fs::read_dir(&root)
        .ok()
        .and_then(|rd| {
            rd.filter_map(|e| e.ok())
                .map(|e| e.file_name().to_string_lossy().into_owned())
                .find(|n| n.starts_with("bert_cola"))
        })
        .unwrap_or_default();
    if bert_cola.is_empty() || !require_artifacts(&["bert_full", &bert_cola]) {
        return;
    }
    banner("Table 8", "BERT-proxy MLM pre-train + synthetic-GLUE fine-tune");
    proxy_note();

    let steps = bench_steps();
    let mut rows = Vec::new();
    for art_name in ["bert_full", bert_cola.as_str()] {
        let cfg = TrainConfig {
            artifact: art_name.into(),
            steps,
            log_every: 100,
            ..TrainConfig::default()
        };
        let mut tr = Trainer::new(cfg).expect(art_name);
        let rep = tr.run().expect(art_name);
        let params = tr.params_literals().expect("params");
        let art = &tr.art;

        let mut accs = Vec::new();
        for task in 0..N_TASKS {
            accs.push(finetune_task(art, &params, task));
        }
        let avg = accs.iter().sum::<f64>() / accs.len() as f64;
        println!(
            "{art_name:>14}: MLM loss {:.4} | task accs {} | avg {:.1}%",
            rep.final_loss,
            accs.iter().map(|a| format!("{:.1}%", a * 100.0)).collect::<Vec<_>>().join(" "),
            avg * 100.0
        );
        rows.push((rep.final_loss, avg));
    }
    println!(
        "\npaper: BERT-Large loss 1.263 vs CoLA 1.257; GLUE avg 82.7 vs 83.5 (CoLA wins 7/8)"
    );
    let (full_loss, full_avg) = rows[0];
    let (cola_loss, cola_avg) = rows[1];
    println!(
        "ours: loss {full_loss:.4} vs {cola_loss:.4}; avg acc {:.1}% vs {:.1}%",
        full_avg * 100.0,
        cola_avg * 100.0
    );
    // shape: CoLA pretrains comparably and fine-tunes comparably-or-better
    assert!(cola_loss < full_loss + 0.20, "CoLA MLM loss should be on par");
    if cola_avg >= full_avg - 0.02 {
        println!("shape checks (on-par MLM loss, on-par-or-better fine-tune) — OK");
    } else {
        println!(
            "fine-tune DEVIATION at proxy scale: avg acc {:.1}% vs {:.1}% \
             (paper's GLUE margin is +0.8 at BERT-Large scale)",
            cola_avg * 100.0,
            full_avg * 100.0
        );
    }
    assert!(cola_avg > full_avg - 0.10, "CoLA fine-tune grossly off");
}
