//! Table 2 — breakdown compute of a single LLaMA decoder layer (full-rank).
//! Also verifies the measured wall-clock of our full-rank train step scales
//! with the analytic FLOPs across proxy widths.

use cola::bench::{banner, require_artifacts};
use cola::costmodel::{table2_breakdown, Geometry, PaperPreset};
use cola::util::si;

fn main() {
    banner("Table 2", "per-layer FLOPs breakdown, full-rank training");

    for scale in ["llama60m", "llama350m", "llama1b", "llama7b"] {
        let p = PaperPreset::by_name(scale).unwrap();
        println!("-- {scale} (n = 1 seq × {} tokens) --", p.seq_len);
        println!("{}", cola::costmodel::tables::render_table2(p, 1));
    }

    // verify: the ratio fwd:bwd is 1:2 and totals match the closed forms
    let p = PaperPreset::by_name("llama1b").unwrap();
    let g = Geometry::from_paper(p, p.seq_len);
    let b = table2_breakdown(&g);
    assert!((b.total_backward() - 2.0 * b.total_forward()).abs() < 1.0);
    println!(
        "check: fwd {} + bwd {} = 3x fwd (paper's 2x rule) OK",
        si(b.total_forward()),
        si(b.total_backward())
    );

    // measured scaling sanity on proxies if artifacts exist
    if require_artifacts(&["p60m_full", "p130m_full"]) {
        use cola::coordinator::cached_or_train;
        let steps = 30;
        let a = cached_or_train("p60m_full", steps, 0).unwrap();
        let b2 = cached_or_train("p130m_full", steps, 0).unwrap();
        let meas = b2.secs_per_step / a.secs_per_step;
        // analytic FLOPs ratio between the two proxy geometries
        let ga = Geometry::new(128, 352, 32, 8 * 128, 4, 4);
        let gb = Geometry::new(192, 512, 48, 8 * 128, 6, 6);
        let flops_ratio = cola::costmodel::compute_total(cola::costmodel::Method::FullRank, &gb)
            / cola::costmodel::compute_total(cola::costmodel::Method::FullRank, &ga);
        println!(
            "measured step-time ratio p130m/p60m = {meas:.2}, analytic FLOPs ratio = {flops_ratio:.2}"
        );
    }
}
