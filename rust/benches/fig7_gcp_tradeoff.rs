//! Figure 7 — memory-saving vs re-computation tradeoff: heuristic GCP on
//! full-rank training swept stage by stage, vs CoLA-M's fixed point.
//! The paper's claim: similar memory saving at ~4.6x less recompute.

use cola::bench::banner;
use cola::costmodel::memory::gcp_tradeoff_sweep;
use cola::costmodel::{Geometry, PaperPreset};
use cola::util::si;

fn main() {
    banner("Figure 7", "GCP re-compute vs memory saving (LLaMA-1B, batch 16)");

    let p = PaperPreset::by_name("llama1b").unwrap();
    let g = Geometry::from_paper(p, p.tokens_per_batch(16));
    let rows = gcp_tradeoff_sweep(&g);
    let full_mem = rows[0].2;

    println!(
        "{:>12} {:>16} {:>16} {:>12}",
        "stage", "recompute/layer", "act-mem/layer", "mem saved"
    );
    for (name, rec, mem) in &rows {
        println!(
            "{name:>12} {:>16} {:>16} {:>11.0}%",
            si(*rec),
            si(*mem),
            (1.0 - mem / full_mem) * 100.0
        );
    }

    let gcp = rows.iter().find(|r| r.0 == "vanilla-gcp").unwrap();
    let cm = rows.iter().find(|r| r.0 == "cola-m").unwrap();
    let rec_ratio = gcp.1 / cm.1;
    let mem_gcp = 1.0 - gcp.2 / full_mem;
    let mem_cm = 1.0 - cm.2 / full_mem;
    println!(
        "\nCoLA-M: {:.0}% memory saved (GCP: {:.0}%) at {rec_ratio:.1}x less recompute (paper: 4.6x, 18.94GB vs 20.25GB)",
        mem_cm * 100.0,
        mem_gcp * 100.0
    );
    assert!(rec_ratio > 3.0, "recompute advantage should be large");
    assert!(mem_cm > 0.85, "CoLA-M should save most activation memory");
}
