//! Table 10 — σ-placement ablation: where the nonlinearity lives in the
//! auto-encoder decides performance. Paper shape at small scale:
//! Both σ ≈ LowRank-σ-only < Reduced < FullRank-σ-only (PPL ascending).

use cola::bench::{banner, bench_steps, proxy_note, require_artifacts};
use cola::coordinator::cached_or_train;

fn main() {
    let arts = [
        ("w/ Both sigma", "p60m_cola_both", 34.04),
        ("w/ Only Low-Rank sigma", "p60m_cola", 34.35),
        ("w/ Only Low-Rank sigma - Reduced", "p60m_cola_reduced", 35.41),
        ("w/ Only Full-Rank sigma", "p60m_cola_fullrank_only", 36.26),
    ];
    let names: Vec<&str> = arts.iter().map(|(_, a, _)| *a).collect();
    if !require_artifacts(&names) {
        return;
    }
    banner("Table 10", "sigma-placement ablation (p60m proxy, paper's 60M column)");
    proxy_note();

    let steps = bench_steps();
    println!("{:>36} {:>9} {:>11}", "variant", "val PPL", "paper PPL");
    let mut ppl = Vec::new();
    for (label, art, paper) in arts {
        let r = cached_or_train(art, steps, 0).expect(art);
        println!("{label:>36} {:>9.2} {paper:>11.2}", r.val_ppl);
        ppl.push(r.val_ppl);
    }
    // The paper's 60M ordering (both best … fullrank-only worst). σ placement
    // is the most scale-sensitive result in the paper — the authors
    // themselves report the "both" advantage vanishing by 350M — so at proxy
    // scale we check the core claim (a low-rank σ variant wins) and report
    // rather than hard-fail on the fine ordering.
    let best_lowrank = ppl[0].min(ppl[1]).min(ppl[2]);
    let fullrank_only = ppl[3];
    if best_lowrank <= fullrank_only {
        println!("\nshape check: a low-rank-σ variant is best (paper's core ablation) — OK");
    } else {
        println!(
            "\nshape DEVIATION at proxy scale: fullrank-only {fullrank_only:.2} < best low-rank σ {best_lowrank:.2} \
             (paper's ordering holds at 60M+ real scale; σ placement is scale-sensitive)"
        );
    }
    assert!(
        best_lowrank < fullrank_only * 1.10,
        "low-rank σ variants should at least be competitive"
    );
}
