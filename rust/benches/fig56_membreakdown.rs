//! Figures 5 & 6 — training-memory breakdown of LLaMA-1B: activations
//! dominate at realistic batch sizes (Fig 5), and the per-method breakdown
//! (Fig 6). Pure cost model at the paper scale, checked for the paper's
//! qualitative claims.

use cola::bench::banner;
use cola::costmodel::memory::{memory_breakdown, BF16};
use cola::costmodel::{tables, Geometry, Method, PaperPreset};

fn main() {
    banner("Figures 5 & 6", "memory breakdown, LLaMA-1B pre-training");

    let p = PaperPreset::by_name("llama1b").unwrap();

    println!("Fig 5 — breakdown vs sequence batch size (full-rank, GB):");
    println!(
        "{:>6} {:>8} {:>8} {:>10} {:>12} {:>8}",
        "batch", "model", "grads", "optimizer", "activations", "total"
    );
    for batch in [4usize, 8, 16, 32, 64] {
        let g = Geometry::from_paper(p, p.tokens_per_batch(batch));
        let mb = memory_breakdown(Method::FullRank, &g, p.vocab, BF16);
        println!(
            "{batch:>6} {:>8.2} {:>8.2} {:>10.2} {:>12.2} {:>8.2}",
            mb.model / 1e9,
            mb.grads / 1e9,
            mb.opt / 1e9,
            mb.activations / 1e9,
            mb.total() / 1e9
        );
    }
    // Fig 5's claim: activations dominate at large batch
    let g32 = Geometry::from_paper(p, p.tokens_per_batch(32));
    let mb = memory_breakdown(Method::FullRank, &g32, p.vocab, BF16);
    assert!(mb.activations > mb.model + mb.grads);
    println!("claim: activations dominate at batch>=32 — OK\n");

    println!("Fig 6 — per-method breakdown at batch 32 (GB):");
    println!("{}", tables::render_membreakdown(p, 32));

    // Table 5 Mem column (states only, BF16) across the ladder
    println!("Table 5's Mem column (model+grad+opt, BF16, GB):");
    println!("{:>10} {:>8} {:>8} {:>8} {:>8}", "scale", "full", "galore", "sltrain", "cola");
    let paper = [
        ("llama60m", [0.43, 0.36, 0.32, 0.32]),
        ("llama130m", [1.00, 0.79, 0.72, 0.70]),
        ("llama350m", [2.74, 1.90, 1.45, 1.38]),
        ("llama1b", [9.98, 6.60, 4.81, 4.54]),
    ];
    for (scale, want) in paper {
        let pp = PaperPreset::by_name(scale).unwrap();
        let g = Geometry::from_paper(pp, 1);
        let gb = |m: Method| memory_breakdown(m, &g, pp.vocab, BF16).states_only() / 1e9;
        let got = [gb(Method::FullRank), gb(Method::GaLore), gb(Method::SlTrain), gb(Method::Cola)];
        println!(
            "{scale:>10} {:>8.2} {:>8.2} {:>8.2} {:>8.2}   [paper: {:.2} {:.2} {:.2} {:.2}]",
            got[0], got[1], got[2], got[3], want[0], want[1], want[2], want[3]
        );
        // orderings must match the paper row
        assert!(got[0] > got[1] && got[1] > got[2] && got[2] > got[3], "{scale}");
        // full-rank absolute within 25% of the paper's number
        assert!((got[0] - want[0]).abs() / want[0] < 0.25, "{scale}: {} vs {}", got[0], want[0]);
    }
    println!("orderings match the paper at every scale — OK");
}
