//! Table 4 — memory & re-computation of full-rank+GCP vs CoLA vs CoLA-M,
//! with the measured peak-RSS counterpart on proxy models (train steps via
//! the real artifacts exercise the remat structure baked into the HLO).

use cola::bench::{banner, bench_steps, proxy_note, require_artifacts};
use cola::costmodel::memory::{activation_elems_per_layer, recompute_per_layer};
use cola::costmodel::{Geometry, Method, PaperPreset};
use cola::util::si;

fn main() {
    banner("Table 4", "memory / re-compute of checkpointing strategies");

    for scale in ["llama1b", "llama7b"] {
        let p = PaperPreset::by_name(scale).unwrap();
        println!("-- {scale}, per layer, single sequence --");
        println!("{}", cola::costmodel::tables::render_table4(p, 1));
    }

    // the paper's 4.6x recompute-reduction claim (Fig. 7 caption)
    let p = PaperPreset::by_name("llama1b").unwrap();
    let g = Geometry::from_paper(p, p.seq_len);
    let ratio =
        recompute_per_layer(Method::VanillaGcp, &g) / recompute_per_layer(Method::ColaM, &g);
    println!("re-compute reduction CoLA-M vs vanilla GCP: {ratio:.2}x (paper: 4.6x)");

    let m_full = activation_elems_per_layer(Method::FullRank, &g);
    let m_gcp = activation_elems_per_layer(Method::VanillaGcp, &g);
    let m_cm = activation_elems_per_layer(Method::ColaM, &g);
    println!(
        "activation memory/layer: full {} | gcp {} | cola-m {} elems",
        si(m_full),
        si(m_gcp),
        si(m_cm)
    );

    // measured counterpart on the e2e proxy: peak RSS ordering
    if require_artifacts(&["e2e_full", "e2e_gcp", "e2e_cola", "e2e_cola_m"]) {
        proxy_note();
        let steps = bench_steps().min(60);
        println!("{:>10} {:>12} {:>12}", "variant", "peak RSS", "sec/step");
        for v in ["e2e_full", "e2e_gcp", "e2e_cola", "e2e_cola_m"] {
            match cola::coordinator::cached_or_train_fresh(v, steps, 0) {
                Ok(r) => println!(
                    "{:>10} {:>9.2} GB {:>12.3}",
                    v.strip_prefix("e2e_").unwrap(),
                    r.peak_rss_bytes as f64 / 1e9,
                    r.secs_per_step
                ),
                Err(e) => println!("{v}: failed: {e:#}"),
            }
        }
        println!("(peak RSS is per-run high water in a fresh process tree; orderings map to the paper's GPU-memory column)");
    }
}
