//! Fig 8 + Table 9 — measured training throughput and memory: full-rank,
//! vanilla GCP, CoLA, CoLA-M, each in a fresh process on the e2e proxy.
//! Paper shape (H100, 1B/7B): CoLA > CoLA-M > full-rank > vanilla GCP on
//! tokens/s; CoLA-M ~1/3 the memory of full-rank.

use cola::bench::{banner, bench_steps, proxy_note, require_artifacts};
use cola::coordinator::cached_or_train_fresh;

fn main() {
    let arts = ["e2e_full", "e2e_gcp", "e2e_cola", "e2e_cola_m"];
    if !require_artifacts(&arts) {
        return;
    }
    banner("Fig 8 / Table 9", "training throughput + memory, measured end-to-end");
    proxy_note();

    // paper Table 9 @1B (BZ=64): mem GB / tok/s / FLOPs-x
    let paper = [
        ("full", 69.84, 12365.0, 1.00),
        ("gcp", 14.89, 8799.0, 1.68),
        ("cola", 66.46, 22979.0, 0.40),
        ("cola_m", 17.33, 16617.0, 0.55),
    ];

    let steps = bench_steps().min(60);
    println!(
        "{:>8} {:>10} {:>12} {:>10}   {:>24}",
        "variant", "tok/s", "sec/step", "peak RSS", "paper (mem GB, tok/s)"
    );
    let mut got = Vec::new();
    for (v, (pv, pmem, ptok, _)) in arts.iter().zip(paper) {
        let r = cached_or_train_fresh(v, steps, 0).expect(v);
        println!(
            "{:>8} {:>10.0} {:>12.3} {:>7.2} GB   {pv:>8}: {pmem:>6.1}, {ptok:>7.0}",
            v.strip_prefix("e2e_").unwrap(),
            r.tokens_per_sec,
            r.secs_per_step,
            r.peak_rss_bytes as f64 / 1e9
        );
        got.push((v.to_string(), r));
    }

    let tok = |n: &str| got.iter().find(|(v, _)| v == n).unwrap().1.tokens_per_sec;
    println!("\nthroughput ratios (ours vs paper @1B):");
    println!(
        "  CoLA / full:   {:.2}x  (paper 1.86x)",
        tok("e2e_cola") / tok("e2e_full")
    );
    println!(
        "  CoLA-M / full: {:.2}x  (paper 1.34x)",
        tok("e2e_cola_m") / tok("e2e_full")
    );
    println!(
        "  GCP / full:    {:.2}x  (paper 0.71x)",
        tok("e2e_gcp") / tok("e2e_full")
    );

    // the paper's ordering: cola > cola_m > full > gcp. The full-vs-gcp gap
    // is the smallest one (recompute is cheap relative to XLA-CPU GEMM
    // throughput at proxy width), so it is reported rather than asserted.
    assert!(tok("e2e_cola") > tok("e2e_full"), "CoLA must beat full-rank throughput");
    assert!(tok("e2e_cola_m") > tok("e2e_gcp"), "CoLA-M must beat vanilla GCP");
    if tok("e2e_full") > tok("e2e_gcp") {
        println!("ordering checks (CoLA > full > GCP; CoLA-M > GCP) — OK");
    } else {
        println!(
            "ordering: CoLA > full OK, CoLA-M > GCP OK; full vs GCP within noise \
             ({:.0} vs {:.0} tok/s) on this substrate",
            tok("e2e_full"),
            tok("e2e_gcp")
        );
    }
}
