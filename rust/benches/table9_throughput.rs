//! Fig 8 + Table 9 — measured training throughput and memory: full-rank,
//! vanilla GCP, CoLA, CoLA-M, each in a fresh process on the e2e proxy.
//! Paper shape (H100, 1B/7B): CoLA > CoLA-M > full-rank > vanilla GCP on
//! tokens/s; CoLA-M ~1/3 the memory of full-rank.
//!
//! A serving addendum compares decode throughput of the `ServicePool`'s
//! continuous batching against a seed-style static flush-and-wait load
//! pattern at equal `serve_bs` on the tiny artifact.

use cola::bench::{banner, bench_steps, proxy_note, require_artifacts};
use cola::config::ServeConfig;
use cola::coordinator::cached_or_train_fresh;
use cola::data::{corpus::CorpusCfg, CorpusGen};
use cola::serve::{InferenceService, ServicePool, SubmitOptions};

/// Drive one workload through a fresh pool. `static_groups` emulates the
/// retired flush-and-wait engine: submit exactly one batch worth of
/// requests, drain them all, then submit the next group — so finished rows
/// idle until the whole group completes. Continuous mode submits everything
/// up front and lets the slot table refill between decode steps.
fn serve_tok_per_sec(artifact: &str, static_groups: bool) -> f64 {
    let cfg = ServeConfig { artifact: artifact.into(), queue_depth: 64, ..Default::default() };
    let pool = ServicePool::start(cfg).expect(artifact);
    let man = cola::runtime::ArtifactDir::open_named(artifact).unwrap().manifest;
    let serve_bs = man.serve_batch.expect("serve artifact");
    let bpe = cola::coordinator::trainer::shared_bpe(man.preset.vocab).unwrap();
    let mut gen = CorpusGen::new(CorpusCfg { seed: 7, ..CorpusCfg::default() });

    let warm = SubmitOptions { max_new_tokens: Some(2), ..Default::default() };
    pool.generate(bpe.encode(&gen.text(40)), warm).unwrap();

    // heterogeneous budgets: static formation wastes void decodes on rows
    // that finish early; continuous batching refills them
    let reqs: Vec<(Vec<i32>, usize)> = (0..6 * serve_bs)
        .map(|i| (bpe.encode(&gen.text(40)), if i % 2 == 0 { 4 } else { 20 }))
        .collect();

    let t0 = std::time::Instant::now();
    let mut total_tokens = 0usize;
    if static_groups {
        for group in reqs.chunks(serve_bs) {
            let streams: Vec<_> = group
                .iter()
                .map(|(p, max_new)| {
                    let opts =
                        SubmitOptions { max_new_tokens: Some(*max_new), ..Default::default() };
                    pool.submit(p.clone(), opts).expect("static group fits the queue")
                })
                .collect();
            for s in streams {
                total_tokens += s.wait().unwrap().tokens.len();
            }
        }
    } else {
        let mut streams = Vec::new();
        for (p, max_new) in &reqs {
            let opts = SubmitOptions { max_new_tokens: Some(*max_new), ..Default::default() };
            streams.push(pool.submit_wait(p.clone(), opts).unwrap());
        }
        for s in streams {
            total_tokens += s.wait().unwrap().tokens.len();
        }
    }
    let secs = t0.elapsed().as_secs_f64();
    pool.shutdown();
    total_tokens as f64 / secs.max(1e-9)
}

fn serve_addendum() {
    let artifact = "tiny_cola";
    let root = std::env::var("COLA_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if !std::path::Path::new(&root).join(artifact).join("decode_step.hlo.txt").exists() {
        println!("\nserving addendum SKIP: `{artifact}` lacks serving steps (`make artifacts`)");
        return;
    }
    println!("\nserving addendum — decode throughput at equal serve_bs ({artifact}):");
    let stat = serve_tok_per_sec(artifact, true);
    let cont = serve_tok_per_sec(artifact, false);
    println!("  static flush-and-wait load: {stat:>7.0} tok/s");
    println!("  continuous batching:        {cont:>7.0} tok/s  ({:.2}x)", cont / stat);
    assert!(
        cont >= 0.9 * stat,
        "continuous batching must not fall below the static-batch path \
         ({cont:.0} vs {stat:.0} tok/s)"
    );
}

fn main() {
    let arts = ["e2e_full", "e2e_gcp", "e2e_cola", "e2e_cola_m"];
    if !require_artifacts(&arts) {
        return;
    }
    banner("Fig 8 / Table 9", "training throughput + memory, measured end-to-end");
    proxy_note();

    // paper Table 9 @1B (BZ=64): mem GB / tok/s / FLOPs-x
    let paper = [
        ("full", 69.84, 12365.0, 1.00),
        ("gcp", 14.89, 8799.0, 1.68),
        ("cola", 66.46, 22979.0, 0.40),
        ("cola_m", 17.33, 16617.0, 0.55),
    ];

    let steps = bench_steps().min(60);
    println!(
        "{:>8} {:>10} {:>12} {:>10}   {:>24}",
        "variant", "tok/s", "sec/step", "peak RSS", "paper (mem GB, tok/s)"
    );
    let mut got = Vec::new();
    for (v, (pv, pmem, ptok, _)) in arts.iter().zip(paper) {
        let r = cached_or_train_fresh(v, steps, 0).expect(v);
        println!(
            "{:>8} {:>10.0} {:>12.3} {:>7.2} GB   {pv:>8}: {pmem:>6.1}, {ptok:>7.0}",
            v.strip_prefix("e2e_").unwrap(),
            r.tokens_per_sec,
            r.secs_per_step,
            r.peak_rss_bytes as f64 / 1e9
        );
        got.push((v.to_string(), r));
    }

    let tok = |n: &str| got.iter().find(|(v, _)| v == n).unwrap().1.tokens_per_sec;
    println!("\nthroughput ratios (ours vs paper @1B):");
    println!(
        "  CoLA / full:   {:.2}x  (paper 1.86x)",
        tok("e2e_cola") / tok("e2e_full")
    );
    println!(
        "  CoLA-M / full: {:.2}x  (paper 1.34x)",
        tok("e2e_cola_m") / tok("e2e_full")
    );
    println!(
        "  GCP / full:    {:.2}x  (paper 0.71x)",
        tok("e2e_gcp") / tok("e2e_full")
    );

    // the paper's ordering: cola > cola_m > full > gcp. The full-vs-gcp gap
    // is the smallest one (recompute is cheap relative to XLA-CPU GEMM
    // throughput at proxy width), so it is reported rather than asserted.
    assert!(tok("e2e_cola") > tok("e2e_full"), "CoLA must beat full-rank throughput");
    assert!(tok("e2e_cola_m") > tok("e2e_gcp"), "CoLA-M must beat vanilla GCP");
    if tok("e2e_full") > tok("e2e_gcp") {
        println!("ordering checks (CoLA > full > GCP; CoLA-M > GCP) — OK");
    } else {
        println!(
            "ordering: CoLA > full OK, CoLA-M > GCP OK; full vs GCP within noise \
             ({:.0} vs {:.0} tok/s) on this substrate",
            tok("e2e_full"),
            tok("e2e_gcp")
        );
    }

    serve_addendum();
}
