//! Table 6 — LLaMA-7B pre-training: CoLA-M vs 8-bit Adam / 8-bit GaLore /
//! SLTrain. The 7B scale is unreachable on this substrate (DESIGN.md §6), so
//! this bench reproduces (a) the memory column analytically at the true 7B
//! geometry, and (b) the PPL-trajectory *shape* (CoLA(-M) below baselines
//! throughout training) on the p130m proxy via checkpointed eval curves.

use cola::bench::{banner, bench_steps, proxy_note, require_artifacts};
use cola::config::TrainConfig;
use cola::coordinator::Trainer;
use cola::costmodel::memory::{memory_breakdown, BF16};
use cola::costmodel::{Geometry, Method, PaperPreset};

fn main() {
    banner("Table 6", "7B-scale comparison (analytic memory + proxy trajectory)");

    let p = PaperPreset::by_name("llama7b").unwrap();
    // Paper: 8-bit Adam 72.59GB, 8-bit GaLore 65.16GB, SLTrain 60.91GB,
    // CoLA-M 26.82GB measured on a 94GB H100 at batch 16.
    let g = Geometry::from_paper(p, p.tokens_per_batch(16));
    println!("analytic total training memory at 7B, batch 16 (BF16, GB):");
    let rows = [
        (Method::FullRank, "Full-rank (bf16 Adam)", f64::NAN),
        (Method::GaLore, "GaLore", 65.16),
        (Method::SlTrain, "SLTrain", 60.91),
        (Method::Cola, "CoLA", f64::NAN),
        (Method::ColaM, "CoLA-M", 26.82),
    ];
    for (m, name, paper) in rows {
        let mb = memory_breakdown(m, &g, p.vocab, BF16);
        let note = if paper.is_nan() {
            String::new()
        } else {
            format!("   [paper: {paper:.2} GB, 8-bit states]")
        };
        println!(
            "  {name:>22}: {:>7.2} GB (act {:.1} + states {:.1}){note}",
            mb.total() / 1e9,
            mb.activations / 1e9,
            mb.states_only() / 1e9
        );
    }
    let cm = memory_breakdown(Method::ColaM, &g, p.vocab, BF16).total();
    let full = memory_breakdown(Method::FullRank, &g, p.vocab, BF16).total();
    println!(
        "CoLA-M cuts total memory to {:.0}% of full-rank (paper: ~1/3) ",
        cm / full * 100.0
    );
    assert!(cm < 0.45 * full);

    // trajectory shape on the proxy: CoLA at/below full-rank throughout.
    // NOTE: we use the p60m proxy here — at p130m's width the preset lr
    // (3e-3) destabilizes CoLA exactly as the paper reports for CoLA-1B/7B
    // (App. D lowers CoLA's lr to 2e-3/1e-3); see EXPERIMENTS.md.
    if !require_artifacts(&["p60m_full", "p60m_cola_m"]) {
        return;
    }
    proxy_note();
    let steps = bench_steps();
    let every = (steps / 5).max(1);
    println!("proxy PPL trajectory (p60m, eval every {every} steps):");
    let mut curves = Vec::new();
    for art in ["p60m_full", "p60m_cola_m"] {
        let cfg = TrainConfig {
            artifact: art.into(),
            steps,
            eval_every: every,
            eval_batches: 4,
            log_every: 0,
            ..TrainConfig::default()
        };
        let mut tr = Trainer::new(cfg).expect(art);
        let rep = tr.run().expect(art);
        println!(
            "  {art}: {}",
            rep.val_curve
                .iter()
                .map(|(s, p)| format!("{s}:{p:.1}"))
                .collect::<Vec<_>>()
                .join("  ")
        );
        curves.push(rep.val_curve);
    }
    // final point ordering: CoLA-M <= full * 1.1 (paper: strictly better)
    let full_last = curves[0].last().unwrap().1;
    let cm_last = curves[1].last().unwrap().1;
    println!("final: full {full_last:.2} vs cola_m {cm_last:.2} (paper 7B: ~14.6 vs 12.73)");
    assert!(cm_last < full_last * 1.15, "CoLA-M trajectory should track full-rank");
}
