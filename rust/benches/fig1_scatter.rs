//! Figure 1 — PPL vs compute-FLOPs vs model-size scatter at the 1B scale.
//! Analytic axes (params, FLOPs) at the paper scale + measured PPL points
//! from the proxy ladder (the shape claim: CoLA is the only method reducing
//! BOTH axes while holding full-rank-level perplexity).

use cola::bench::{banner, bench_steps, proxy_note, require_artifacts};
use cola::coordinator::cached_or_train;
use cola::costmodel::{tables, PaperPreset};
use cola::util::si;

fn main() {
    banner("Figure 1", "PPL vs FLOPs vs size (LLaMA-1B, token batch 256)");

    let p = PaperPreset::by_name("llama1b").unwrap();
    println!("analytic axes at the paper's scale:");
    println!("{:>10} {:>12} {:>14}", "method", "params", "FLOPs/batch");
    for (m, params, flops) in tables::fig1_rows(p, 256) {
        println!("{m:>10} {:>12} {:>14}", si(params), si(flops));
    }

    let arts = ["p60m_full", "p60m_cola", "p60m_lora", "p60m_galore", "p60m_sltrain"];
    if !require_artifacts(&arts) {
        return;
    }
    proxy_note();
    let steps = bench_steps();
    println!(
        "{:>10} {:>10} {:>12} {:>10}  (proxy p60m, {} steps)",
        "method", "val PPL", "params", "rel FLOPs", steps
    );
    let paper_ppl = [("full", 15.56), ("cola", 15.52), ("lora", 18.33),
                     ("galore", 15.64), ("sltrain", 16.14)];
    let mut results = Vec::new();
    for a in arts {
        let r = cached_or_train(a, steps, 0).expect(a);
        results.push((a.strip_prefix("p60m_").unwrap().to_string(), r));
    }
    let full_ppl = results.iter().find(|(n, _)| n == "full").unwrap().1.val_ppl;
    let full_par = results.iter().find(|(n, _)| n == "full").unwrap().1.n_total_params;
    for (name, r) in &results {
        let rel_flops = match name.as_str() {
            "cola" => 0.4,
            "lora" => 1.6,
            "galore" | "sltrain" => 1.1,
            _ => 1.0,
        };
        let paper = paper_ppl.iter().find(|(n, _)| n == name).map(|(_, p)| *p).unwrap();
        println!(
            "{name:>10} {:>10.2} {:>12} {rel_flops:>9.1}x   [paper@1B: {paper}]",
            r.val_ppl,
            si(r.n_total_params as f64)
        );
    }
    // shape assertions: cola ≈ full PPL at about half the params
    let cola = &results.iter().find(|(n, _)| n == "cola").unwrap().1;
    assert!(cola.val_ppl < full_ppl * 1.10, "CoLA should be ~on par with full-rank");
    assert!((cola.n_total_params as f64) < 0.8 * full_par as f64);
    println!("shape check: CoLA on-par PPL at reduced size+FLOPs — OK");
}
