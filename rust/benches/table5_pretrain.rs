//! Table 5 — validation perplexity / parameters / memory across efficient
//! pre-training methods, on the proxy ladder (p60m, p130m; p350m with
//! COLA_BENCH_FULL=1). Memory column is the analytic model+grad+opt estimate
//! in BF16 (the paper's convention); PPL and params are measured.

use cola::bench::{banner, bench_steps, proxy_note, require_artifacts};
use cola::coordinator::cached_or_train;
use cola::costmodel::memory::{memory_breakdown, BF16};
use cola::costmodel::{Geometry, Method, PaperPreset};
use cola::runtime::ArtifactDir;
use cola::util::si;

fn method_of(variant: &str) -> Method {
    match variant {
        "cola" | "cola_m" => Method::Cola,
        "lora" => Method::ReLora,
        "galore" => Method::GaLore,
        "sltrain" => Method::SlTrain,
        _ => Method::FullRank,
    }
}

fn main() {
    banner("Table 5", "PPL / params / memory across methods (proxy ladder)");
    proxy_note();

    // paper's Table 5 values for reference printing (60M / 130M columns)
    let paper: &[(&str, [f64; 2])] = &[
        ("full", [34.06, 24.36]),
        ("lora", [37.04, 29.37]),   // ReLoRA row
        ("galore", [34.88, 25.36]),
        ("sltrain", [34.15, 26.04]),
        ("cola", [34.04, 24.48]),
    ];

    let mut scales = vec![("p60m", "llama60m", 0usize)];
    if std::env::var("COLA_BENCH_FULL").is_ok() {
        // the full ladder: ~30 extra minutes of proxy training on one core
        scales.push(("p130m", "llama130m", 1));
        scales.push(("p350m", "llama350m", 2));
    }
    let steps = bench_steps();

    for (proxy, paper_scale, col) in &scales {
        let arts: Vec<String> = ["full", "lora", "galore", "sltrain", "cola"]
            .iter()
            .map(|v| format!("{proxy}_{v}"))
            .collect();
        let art_refs: Vec<&str> = arts.iter().map(String::as_str).collect();
        if !require_artifacts(&art_refs) {
            continue;
        }
        println!("-- {proxy} (paper column: {paper_scale}), {steps} steps --");
        println!(
            "{:>9} {:>9} {:>10} {:>10} {:>14}",
            "method", "val PPL", "params", "mem est", "paper PPL"
        );
        let pp = PaperPreset::by_name(paper_scale).unwrap();
        let mut rows = Vec::new();
        for (v, art) in ["full", "lora", "galore", "sltrain", "cola"].iter().zip(&arts) {
            let r = cached_or_train(art, steps, 0).expect(art);
            // analytic memory at the *paper* scale for this method (Table 5 Mem)
            let g = Geometry::from_paper(pp, 1);
            let mem = memory_breakdown(method_of(v), &g, pp.vocab, BF16).states_only() / 1e9;
            let paper_v = paper
                .iter()
                .find(|(n, _)| n == v)
                .map(|(_, x)| x[*col])
                .unwrap_or(f64::NAN);
            println!(
                "{v:>9} {:>9.2} {:>10} {:>8.2}GB {:>14.2}",
                r.val_ppl,
                si(r.n_total_params as f64),
                mem,
                paper_v
            );
            rows.push((v.to_string(), r));
        }
        // shape checks mirroring the paper's table. Note: at this proxy
        // scale + short budget, LoRA's frozen-W0 gives it 2.4x CoLA's
        // parameters — its raw PPL can lead early; the paper's ordering is
        // at compute-optimal budgets. The substrate-robust claims are
        // Pareto ones: nothing at <= CoLA's size matches its PPL, and CoLA
        // is on par with full-rank at ~half the parameters.
        let ppl = |name: &str| rows.iter().find(|(n, _)| n == name).unwrap().1.val_ppl;
        let par = |name: &str| rows.iter().find(|(n, _)| n == name).unwrap().1.n_total_params;
        assert!(ppl("cola") < ppl("full") * 1.10, "CoLA ~on-par with full-rank");
        assert!(ppl("cola") < ppl("galore") && ppl("cola") < ppl("sltrain"),
                "CoLA beats the equal-or-smaller efficient baselines");
        assert!(par("cola") < par("full"), "CoLA smallest model");
        assert!(par("cola") <= par("sltrain"), "CoLA <= SLTrain params");
        for (n, r) in &rows {
            if r.n_total_params <= par("cola") && n != "cola" {
                assert!(r.val_ppl >= ppl("cola"), "{n} pareto-dominates CoLA");
            }
        }
        println!("shape checks (CoLA on-par with full, pareto-undominated) — OK\n");
    }

    // artifact-level param truth for the table footer
    if require_artifacts(&["p60m_full", "p60m_cola"]) {
        let f = ArtifactDir::open_named("p60m_full").unwrap();
        let c = ArtifactDir::open_named("p60m_cola").unwrap();
        println!(
            "proxy param counts from manifests: full={} cola={} (ratio {:.2})",
            si(f.manifest.n_total_params as f64),
            si(c.manifest.n_total_params as f64),
            c.manifest.n_total_params as f64 / f.manifest.n_total_params as f64
        );
    }
}
