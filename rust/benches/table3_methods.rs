//! Table 3 — estimated compute of a single decoder layer per method,
//! plus the paper's headline claims: CoLA < full-rank iff r < 0.62d,
//! (Re)LoRA lower-bounded by CoLA, SLTrain/GaLore lower-bounded by full-rank.

use cola::bench::banner;
use cola::costmodel::{
    c_cola, c_full_rank, c_lora, cola_breakeven_rank, compute_total, Geometry, Method,
    PaperPreset, PAPER_PRESETS,
};

fn main() {
    banner("Table 3", "per-method training compute");

    for p in &PAPER_PRESETS {
        println!("-- {} --", p.name);
        println!("{}", cola::costmodel::tables::render_table3(p, 1));
    }

    println!("paper claims checked:");
    let p = PaperPreset::by_name("llama1b").unwrap();
    let g = Geometry::from_paper(p, p.seq_len);

    // 1) default rank halves compute
    let ratio = c_cola(&g) / c_full_rank(&g);
    println!("  C_CoLA/C_full @ r=d/4: {ratio:.2} (paper: ~0.4-0.5x)");
    assert!(ratio < 0.55);

    // 2) breakeven near 0.62d under dff = 2.5d
    let g25 = Geometry::new(2048, 5120, 512, g.n as usize, 32, 24);
    let be = cola_breakeven_rank(&g25) / g25.d;
    println!("  breakeven rank: {be:.3}d (paper: 0.62d)");
    assert!((be - 0.62).abs() < 0.02);

    // 3) orderings across every scale and a rank sweep
    for p in &PAPER_PRESETS {
        for rf in [8usize, 4, 2] {
            let mut g = Geometry::from_paper(p, p.seq_len);
            g.r = (p.d / rf) as f64;
            assert!(c_lora(&g) > c_cola(&g), "LoRA >= CoLA violated");
            assert!(compute_total(Method::SlTrain, &g) > compute_total(Method::FullRank, &g));
            assert!(compute_total(Method::GaLore, &g) > compute_total(Method::FullRank, &g));
        }
    }
    println!("  orderings (LoRA>CoLA, SLTrain/GaLore>Full) hold at every scale/rank: OK");
}
