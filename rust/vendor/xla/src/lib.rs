//! Compile stub for the PJRT `xla` bindings used by the cola coordinator.
//!
//! The real crate links a PJRT CPU plugin; this stand-in reproduces exactly
//! the API surface cola calls so the whole workspace builds and the hermetic
//! test tier (everything that never touches a compiled artifact) runs on a
//! machine with no XLA toolchain at all. The split of behaviour:
//!
//! - **Host-side literals are real.** `Literal::scalar` / `vec1` / `reshape`
//!   / `to_vec` / `get_first_element` round-trip actual bytes, so code that
//!   only marshals tensors (tests included) behaves faithfully.
//! - **Device entry points fail loudly.** `HloModuleProto::from_text_file`
//!   is the designated error point — anything needing a compiled artifact
//!   fails there with a recognisable message, which the artifact-gated tests
//!   already treat as "skip". `compile`, `execute*`, and npz I/O return the
//!   same `Error::Unavailable`.
//! - **Plumbing succeeds.** `PjRtClient::cpu` and `buffer_from_host_literal`
//!   work (a buffer is just an owned literal), so constructing a client or
//!   staging host data is never the thing that breaks.
//!
//! Swap this path dependency for the real bindings in rust/Cargo.toml to run
//! the artifact-backed paths; no cola source changes are needed.

use std::fmt;
use std::path::Path;

/// Stub error. `Unavailable` marks an operation that needs the real PJRT
/// runtime; `Shape` marks a genuine caller bug the stub can detect.
#[derive(Debug)]
pub enum Error {
    Unavailable(&'static str),
    Shape(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => {
                write!(f, "xla stub: {what} requires the real PJRT bindings")
            }
            Error::Shape(msg) => write!(f, "xla stub: {msg}"),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

mod sealed {
    pub trait Sealed {}
}

/// Element types a `Literal` can hold. Sealed: the stub supports exactly the
/// types cola marshals.
pub trait NativeType: sealed::Sealed + Copy + Default {
    const KIND: &'static str;
    const SIZE: usize;
    fn to_le(&self, out: &mut Vec<u8>);
    fn from_le(bytes: &[u8]) -> Self;
}

macro_rules! native {
    ($ty:ty, $kind:literal) => {
        impl sealed::Sealed for $ty {}
        impl NativeType for $ty {
            const KIND: &'static str = $kind;
            const SIZE: usize = std::mem::size_of::<$ty>();
            fn to_le(&self, out: &mut Vec<u8>) {
                out.extend_from_slice(&self.to_le_bytes());
            }
            fn from_le(bytes: &[u8]) -> Self {
                let mut buf = [0u8; std::mem::size_of::<$ty>()];
                buf.copy_from_slice(bytes);
                <$ty>::from_le_bytes(buf)
            }
        }
    };
}

native!(f32, "f32");
native!(f64, "f64");
native!(i32, "i32");
native!(i64, "i64");
native!(u8, "u8");

/// A host tensor: little-endian bytes + element kind + dims. Fully
/// functional — this is the part of the API the hermetic tier exercises.
#[derive(Clone, Debug)]
pub struct Literal {
    kind: &'static str,
    elem_size: usize,
    data: Vec<u8>,
    dims: Vec<i64>,
}

impl Literal {
    pub fn scalar<T: NativeType>(x: T) -> Self {
        let mut data = Vec::with_capacity(T::SIZE);
        x.to_le(&mut data);
        Self { kind: T::KIND, elem_size: T::SIZE, data, dims: Vec::new() }
    }

    pub fn vec1<T: NativeType>(xs: &[T]) -> Self {
        let mut data = Vec::with_capacity(xs.len() * T::SIZE);
        for x in xs {
            x.to_le(&mut data);
        }
        Self { kind: T::KIND, elem_size: T::SIZE, data, dims: vec![xs.len() as i64] }
    }

    pub fn element_count(&self) -> usize {
        self.data.len() / self.elem_size.max(1)
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Self> {
        let want: i64 = dims.iter().product();
        if want < 0 || want as usize != self.element_count() {
            return Err(Error::Shape(format!(
                "cannot reshape {} elements to {dims:?}",
                self.element_count()
            )));
        }
        let mut out = self.clone();
        out.dims = dims.to_vec();
        Ok(out)
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.kind != T::KIND {
            return Err(Error::Shape(format!(
                "literal holds {}, asked for {}",
                self.kind,
                T::KIND
            )));
        }
        Ok(self.data.chunks_exact(T::SIZE).map(T::from_le).collect())
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        self.to_vec::<T>()?
            .first()
            .copied()
            .ok_or_else(|| Error::Shape("empty literal".to_string()))
    }

    /// npz persistence needs the real crate's zip/npy codec.
    pub fn write_npz<P: AsRef<Path>>(
        _entries: &[(String, &Literal)],
        _path: P,
    ) -> Result<()> {
        Err(Error::Unavailable("Literal::write_npz"))
    }
}

/// Deserialisation contexts for raw-byte loaders. Only the `Literal`
/// implementation (context `()`) exists in the stub.
pub trait FromRawBytes: Sized {
    type Context: ?Sized;
    fn read_npz<P: AsRef<Path>>(path: P, context: &Self::Context)
        -> Result<Vec<(String, Self)>>;
}

impl FromRawBytes for Literal {
    type Context = ();
    fn read_npz<P: AsRef<Path>>(_path: P, _context: &()) -> Result<Vec<(String, Self)>> {
        Err(Error::Unavailable("Literal::read_npz"))
    }
}

/// Parsed HLO module. `from_text_file` is the stub's designated failure
/// point for every artifact-backed path.
#[derive(Clone, Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<Self> {
        Err(Error::Unavailable("HloModuleProto::from_text_file"))
    }
}

#[derive(Clone, Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation(())
    }
}

/// PJRT client handle. Construction succeeds (cola creates one per worker
/// thread eagerly); only `compile` needs the real runtime.
#[derive(Clone, Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Ok(PjRtClient(()))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Unavailable("PjRtClient::compile"))
    }

    /// Staging host data always works: a stub buffer is an owned literal.
    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        lit: &Literal,
    ) -> Result<PjRtBuffer> {
        Ok(PjRtBuffer(lit.clone()))
    }
}

/// Device buffer — in the stub, host memory wearing a device costume.
#[derive(Debug)]
pub struct PjRtBuffer(Literal);

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.0.clone())
    }
}

/// Compiled executable. Unconstructable in the stub (`compile` always
/// errors), so the execute bodies are unreachable by design.
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<A: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[A],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unavailable("PjRtLoadedExecutable::execute"))
    }

    pub fn execute_b<A: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        _args: &[A],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let shaped = lit.reshape(&[2, 3]).unwrap();
        assert_eq!(shaped.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(shaped.get_first_element::<f32>().unwrap(), 1.0);
        assert!(lit.reshape(&[7]).is_err(), "element count must be conserved");
        assert!(lit.to_vec::<i32>().is_err(), "kind mismatch is caught");
    }

    #[test]
    fn buffers_carry_literals_and_device_paths_fail_loudly() {
        let c = PjRtClient::cpu().unwrap();
        let buf = c
            .buffer_from_host_literal(None, &Literal::scalar(41i32))
            .unwrap();
        assert_eq!(buf.to_literal_sync().unwrap().to_vec::<i32>().unwrap(), vec![41]);
        let err = HloModuleProto::from_text_file("missing.hlo.txt").unwrap_err();
        assert!(err.to_string().contains("real PJRT"), "got: {err}");
    }
}
