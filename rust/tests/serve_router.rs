//! Hermetic serving-tier integration: `ModelRouter` → `ServicePool`s →
//! `MockBackend`. Everything the artifact-backed `serve_integration` suite
//! can only check when `make artifacts` has run — router dispatch,
//! continuous batching, streaming, cancellation, deadlines, QueueFull
//! backpressure, engine failure + recovery — runs here deterministically
//! under `cargo test -q` with **zero** PJRT/artifact dependency.
//!
//! Determinism: `MockBackend`'s token rule is a pure function of a row's
//! last real token, so every completion is an exact, precomputable
//! arithmetic progression regardless of how rows interleave in the slot
//! table (see `serve::mock`).

use cola::config::ServeConfig;
use cola::serve::{
    BreakerState, EngineBackend, FaultKind, FaultPlan, FaultSchedule, FinishReason,
    InferenceService, MockBackend, ModelRouter, RouteError, ServicePool, StreamEvent,
    SubmitError, SubmitOptions,
};
use std::time::Duration;

fn cfg(workers: usize, queue_depth: usize) -> ServeConfig {
    ServeConfig {
        artifact: "mock".into(),
        max_new_tokens: 8,
        workers,
        queue_depth,
        ..ServeConfig::default()
    }
}

fn pool(cfg: ServeConfig, mock: MockBackend) -> ServicePool {
    ServicePool::start_with(cfg, mock.factory()).unwrap()
}

/// A pool whose every worker backend is wrapped in the scripted fault plan.
fn fault_pool(cfg: ServeConfig, mock: MockBackend, plan: FaultPlan) -> ServicePool {
    ServicePool::start_with(cfg, move |w| {
        Ok(Box::new(plan.wrap(mock.clone(), w)) as Box<dyn EngineBackend>)
    })
    .unwrap()
}

fn opts(max_new: usize) -> SubmitOptions {
    SubmitOptions { max_new_tokens: Some(max_new), ..Default::default() }
}

/// Counters are bumped just *after* the worker streams a request's terminal
/// `Done`, so asserts that follow a `wait()` poll briefly instead of racing
/// that window.
fn eventually(what: &str, mut cond: impl FnMut() -> bool) {
    for _ in 0..1000 {
        if cond() {
            return;
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    panic!("not reached within 1s: {what}");
}

#[test]
fn router_dispatches_by_model_name_to_distinct_backends() {
    let a = MockBackend::new(2, 4, 8).stride(1).vocab(10_000);
    let b = MockBackend::new(2, 4, 8).stride(5).vocab(10_000);
    let router = ModelRouter::from_pools(vec![
        ("a".into(), pool(cfg(1, 8), a.clone())),
        ("b".into(), pool(cfg(1, 8), b.clone())),
    ])
    .unwrap();
    assert_eq!(router.models(), vec!["a", "b"]);

    let ca = router.generate("a", vec![10], opts(3)).unwrap();
    assert_eq!(ca.tokens, a.expected_stream(10, 3));
    assert_eq!(ca.tokens, vec![11, 12, 13]);
    assert_eq!(ca.finish_reason, FinishReason::Length);

    let cb = router.generate("b", vec![10], opts(3)).unwrap();
    assert_eq!(cb.tokens, b.expected_stream(10, 3));
    assert_eq!(cb.tokens, vec![15, 20, 25], "model `b` has its own backend");
    router.shutdown();
}

#[test]
fn unknown_model_is_a_typed_route_error() {
    let router = ModelRouter::from_pools(vec![(
        "only".into(),
        pool(cfg(1, 4), MockBackend::new(1, 2, 4)),
    )])
    .unwrap();
    match router.submit("ghost", vec![1], opts(2)) {
        Err(RouteError::UnknownModel(m)) => {
            assert_eq!(m, "ghost");
            assert_eq!(
                RouteError::UnknownModel(m).to_string(),
                "unknown model `ghost`"
            );
        }
        other => panic!("expected UnknownModel, got {:?}", other.map(|_| ())),
    }
    assert!(router.generate("ghost", vec![1], opts(2)).is_err());
    assert!(matches!(router.stats("ghost"), Err(RouteError::UnknownModel(_))));
    router.shutdown();
}

#[test]
fn duplicate_model_names_are_rejected() {
    let p1 = pool(cfg(0, 2), MockBackend::new(1, 2, 4));
    let p2 = pool(cfg(0, 2), MockBackend::new(1, 2, 4));
    assert!(ModelRouter::from_pools(vec![("m".into(), p1), ("m".into(), p2)]).is_err());
    assert!(ModelRouter::from_pools(vec![]).is_err(), "empty router is refused");
}

#[test]
fn backpressure_is_per_model() {
    // `a` is admission-only (workers=0) with a depth-1 queue: it fills
    // deterministically. `b` keeps serving regardless.
    let router = ModelRouter::from_pools(vec![
        ("a".into(), pool(cfg(0, 1), MockBackend::new(1, 2, 4))),
        ("b".into(), pool(cfg(1, 8), MockBackend::new(1, 2, 4))),
    ])
    .unwrap();

    let queued = router.submit("a", vec![1], opts(2)).unwrap();
    match router.submit("a", vec![2], opts(2)) {
        Err(RouteError::Submit(SubmitError::QueueFull)) => {}
        other => panic!("expected QueueFull on `a`, got {:?}", other.map(|_| ())),
    }
    // `a` saturated; `b` unaffected
    let cb = router.generate("b", vec![7], opts(2)).unwrap();
    assert_eq!(cb.finish_reason, FinishReason::Length);

    let sa = router.stats("a").unwrap();
    let sb = router.stats("b").unwrap();
    assert_eq!(sa.rejected, 1);
    assert_eq!(sa.queue_depth, 1);
    assert_eq!(sb.rejected, 0, "b never saw a's backpressure");

    // shutdown sheds a's queued request rather than hanging its client
    router.shutdown();
    assert_eq!(queued.wait().unwrap().finish_reason, FinishReason::Cancelled);
}

#[test]
fn per_model_and_aggregate_stats_line_up() {
    let router = ModelRouter::from_pools(vec![
        ("a".into(), pool(cfg(1, 8), MockBackend::new(2, 4, 8))),
        ("b".into(), pool(cfg(1, 8), MockBackend::new(2, 4, 8).stride(3))),
    ])
    .unwrap();
    for i in 0..3 {
        router.generate("a", vec![10 + i], opts(2)).unwrap();
    }
    for i in 0..2 {
        router.generate("b", vec![50 + i], opts(4)).unwrap();
    }
    eventually("both pools tally completions", || {
        router.stats("a").unwrap().completed == 3 && router.stats("b").unwrap().completed == 2
    });
    let sa = router.stats("a").unwrap();
    let sb = router.stats("b").unwrap();
    assert_eq!(sa.submitted, 3);
    assert_eq!(sb.submitted, 2);
    assert!(sa.decoded_tokens > 0 && sb.decoded_tokens > 0);

    let agg = router.aggregate_stats();
    assert_eq!(agg.submitted, 5);
    assert_eq!(agg.completed, 5);
    assert_eq!(agg.workers, 2);
    assert_eq!(agg.decoded_tokens, sa.decoded_tokens + sb.decoded_tokens);
    assert_eq!(agg.queue_capacity, 16);
    assert!(agg.decode_tokens_per_sec > 0.0);

    let by_model = router.stats_by_model();
    assert_eq!(by_model.len(), 2);
    assert_eq!(by_model[0].0, "a");
    assert_eq!(by_model[1].0, "b");
    assert_eq!(by_model[0].1.completed, 3);
    router.shutdown();
}

#[test]
fn continuous_batching_completes_mixed_budgets_with_exact_streams() {
    // 6 requests through a 2-slot table: short rows vacate and refill while
    // long rows keep decoding; outputs stay exact regardless of interleaving.
    let mock = MockBackend::new(2, 4, 8).vocab(10_000);
    let router =
        ModelRouter::from_pools(vec![("m".into(), pool(cfg(1, 16), mock.clone()))]).unwrap();
    let mut streams = Vec::new();
    for i in 0..6u32 {
        let max_new = if i % 2 == 0 { 3 } else { 7 };
        let last = 100 + 10 * i as i32;
        streams.push((last, max_new, router.submit("m", vec![9, last], opts(max_new)).unwrap()));
    }
    for (last, max_new, s) in streams {
        let c = s.wait().unwrap();
        assert_eq!(c.finish_reason, FinishReason::Length);
        assert_eq!(c.tokens, mock.expected_stream(last, max_new), "row seeded with {last}");
    }
    eventually("all 6 completions tallied", || router.stats("m").unwrap().completed == 6);
    eventually("occupancy gauge returns to zero", || router.stats("m").unwrap().active == 0);
    router.shutdown();
}

#[test]
fn streaming_yields_every_token_before_done() {
    let mock = MockBackend::new(1, 3, 6);
    let router =
        ModelRouter::from_pools(vec![("m".into(), pool(cfg(1, 4), mock.clone()))]).unwrap();
    let mut stream = router.submit("m", vec![40], opts(5)).unwrap();
    let mut streamed = Vec::new();
    let done = loop {
        match stream.recv() {
            Some(StreamEvent::Token(t)) => streamed.push(t),
            Some(StreamEvent::Done(c)) => break c,
            None => panic!("stream dropped before Done"),
        }
    };
    assert_eq!(streamed, mock.expected_stream(40, 5));
    assert_eq!(streamed, done.tokens, "stream and completion agree");
    assert!(stream.recv().is_none(), "stream exhausted after Done");
    assert!(done.timing.first_token.is_some());
    assert!(done.timing.first_token.unwrap() <= done.timing.total);
    router.shutdown();
}

#[test]
fn stop_token_ends_generation_early() {
    let mock = MockBackend::new(1, 3, 6);
    let router =
        ModelRouter::from_pools(vec![("m".into(), pool(cfg(1, 4), mock.clone()))]).unwrap();
    // rule: 20 → 21, 22, ... so stop on 22
    let o = SubmitOptions { stop_tokens: vec![22], ..opts(10) };
    let c = router.generate("m", vec![20], o).unwrap();
    assert_eq!(c.finish_reason, FinishReason::Stop);
    assert_eq!(c.tokens, vec![21, 22], "stops at and includes the stop token");
    router.shutdown();
}

#[test]
fn cancel_mid_flight_delivers_partial_output() {
    let mock = MockBackend::new(1, 4, 64).step_delay(Duration::from_millis(2));
    let router = ModelRouter::from_pools(vec![("m".into(), pool(cfg(1, 4), mock))]).unwrap();
    let mut stream = router.submit("m", vec![5], opts(100_000)).unwrap();
    match stream.recv() {
        Some(StreamEvent::Token(t)) => assert_eq!(t, 6, "first token is deterministic"),
        other => panic!("expected a first token, got {other:?}"),
    }
    stream.cancel();
    let c = stream.wait().unwrap();
    assert_eq!(c.finish_reason, FinishReason::Cancelled);
    assert!(!c.tokens.is_empty(), "partial output is delivered");
    assert!(c.tokens.len() < 100_000, "cancel actually cut generation short");
    eventually("cancellation tallied", || router.stats("m").unwrap().cancelled == 1);
    router.shutdown();
}

#[test]
fn deadline_expires_mid_decode() {
    let mock = MockBackend::new(1, 4, 64).step_delay(Duration::from_millis(2));
    let router = ModelRouter::from_pools(vec![("m".into(), pool(cfg(1, 4), mock))]).unwrap();
    let o = SubmitOptions { deadline: Some(Duration::from_millis(30)), ..opts(1_000_000) };
    let c = router.generate("m", vec![5], o).unwrap();
    assert_eq!(c.finish_reason, FinishReason::DeadlineExpired);
    assert!(c.tokens.len() < 1_000_000);
    eventually("expiry tallied", || router.stats("m").unwrap().expired == 1);
    router.shutdown();
}

#[test]
fn default_deadline_comes_from_pool_config() {
    let mock = MockBackend::new(1, 4, 64).step_delay(Duration::from_millis(2));
    let mut c = cfg(1, 4);
    c.default_deadline_ms = 25;
    let router = ModelRouter::from_pools(vec![("m".into(), pool(c, mock))]).unwrap();
    let done = router.generate("m", vec![5], opts(1_000_000)).unwrap();
    assert_eq!(done.finish_reason, FinishReason::DeadlineExpired);
    router.shutdown();
}

#[test]
fn generation_runs_past_the_static_kv_window() {
    // max_len 6 with prompt_len 4 → only 2 decode positions per prefill;
    // a 12-token generation forces several sliding-window rollovers, and
    // the arithmetic stream must come through unbroken.
    let mock = MockBackend::new(1, 4, 6).stride(3).vocab(10_000);
    let router =
        ModelRouter::from_pools(vec![("m".into(), pool(cfg(1, 4), mock.clone()))]).unwrap();
    let c = router.generate("m", vec![100], opts(12)).unwrap();
    assert_eq!(c.finish_reason, FinishReason::Length);
    assert_eq!(c.tokens, mock.expected_stream(100, 12));
    assert_eq!(c.tokens.first(), Some(&103));
    assert_eq!(c.tokens.last(), Some(&136));
    router.shutdown();
}

#[test]
fn zero_token_budget_completes_empty() {
    let router =
        ModelRouter::from_pools(vec![("m".into(), pool(cfg(1, 4), MockBackend::new(1, 2, 4)))])
            .unwrap();
    let c = router.generate("m", vec![5, 6], opts(0)).unwrap();
    assert!(c.tokens.is_empty(), "max_new_tokens=0 must not leak the prefill token");
    assert_eq!(c.finish_reason, FinishReason::Length);
    router.shutdown();
}

#[test]
fn injected_decode_failure_redispatches_transparently() {
    // bs=1 so decode-call counting is exact: prefill → token 1, decode
    // calls 1,2 → tokens 2,3, decode call 3 → injected failure. The batch
    // fails, the request is salvaged with its 3 streamed tokens folded back
    // in, requeued at the front, and resumed — the client sees the same
    // byte-identical 10-token stream a fault-free run produces.
    let mock = MockBackend::new(1, 4, 64);
    let plan = FaultPlan::seeded(11).inject(FaultKind::DecodeError, FaultSchedule::Once(3));
    let router = ModelRouter::from_pools(vec![(
        "m".into(),
        fault_pool(cfg(1, 4), mock.clone(), plan),
    )])
    .unwrap();
    let c = router.generate("m", vec![30], opts(10)).unwrap();
    assert_eq!(c.finish_reason, FinishReason::Length, "the fault is invisible to the client");
    assert_eq!(c.tokens, mock.expected_stream(30, 10), "stream identical to a fault-free run");
    eventually("redispatch tallied", || router.stats("m").unwrap().requests_redispatched == 1);
    let s = router.stats("m").unwrap();
    assert_eq!(s.retries, 1);
    assert_eq!(s.failed, 0, "no request failed");
    assert_eq!(s.completed, 1);
    router.shutdown();
}

#[test]
fn exhausted_retry_budget_is_a_typed_error_with_partial_tokens() {
    // retry_budget=0: the first batch fault fails the request outright,
    // delivering the tokens streamed so far and the retry count.
    let mut c1 = cfg(1, 4);
    c1.retry_budget = 0;
    let mock = MockBackend::new(1, 4, 64);
    let plan = FaultPlan::seeded(11).inject(FaultKind::DecodeError, FaultSchedule::Once(3));
    let router =
        ModelRouter::from_pools(vec![("m".into(), fault_pool(c1, mock.clone(), plan))]).unwrap();
    let c = router.generate("m", vec![30], opts(10)).unwrap();
    assert_eq!(c.finish_reason, FinishReason::Error { retries: 0 });
    assert_eq!(c.tokens, mock.expected_stream(30, 3), "partial tokens are delivered");
    eventually("batch failure tallied", || router.stats("m").unwrap().failed == 1);

    // one-shot fault cleared: the pool serves normally again
    let c2 = router.generate("m", vec![60], opts(10)).unwrap();
    assert_eq!(c2.finish_reason, FinishReason::Length);
    assert_eq!(c2.tokens, mock.expected_stream(60, 10));
    eventually("recovery completion tallied", || router.stats("m").unwrap().completed == 1);
    router.shutdown();
}

#[test]
fn worker_panic_restarts_the_worker_and_the_stream_survives() {
    // The injected panic fires on decode call 4 of *each* backend instance,
    // so the respawned worker panics again on its own 4th call: the request
    // rides two salvage→redispatch cycles (prefill + 3 decodes = 4 tokens
    // per cycle, then 2 on the last) and still completes byte-identically
    // within the default retry budget of 2.
    let mock = MockBackend::new(1, 4, 64);
    let plan = FaultPlan::seeded(5).inject(FaultKind::WorkerPanic, FaultSchedule::Once(4));
    let router = ModelRouter::from_pools(vec![(
        "m".into(),
        fault_pool(cfg(1, 4), mock.clone(), plan),
    )])
    .unwrap();
    let c = router.generate("m", vec![30], opts(10)).unwrap();
    assert_eq!(c.finish_reason, FinishReason::Length);
    assert_eq!(c.tokens, mock.expected_stream(30, 10), "stream identical to a fault-free run");
    eventually("restarts tallied", || router.stats("m").unwrap().worker_restarts == 2);
    let s = router.stats("m").unwrap();
    assert_eq!(s.worker_panics, 2, "both panics were caught");
    assert_eq!(s.requests_redispatched, 2);
    assert_eq!(s.failed, 0);
    router.shutdown();
}

#[test]
fn repeated_faults_open_the_breaker_and_a_probe_recovers_it() {
    // open_after=1: the first batch fault trips the breaker straight to
    // Open. Router submits then fail fast with CircuitOpen until the
    // cooldown admits a half-open probe, whose success recovers the pool.
    let mut c1 = cfg(1, 8);
    c1.retry_budget = 0;
    c1.breaker_open_after = 1;
    c1.breaker_recover_after = 1;
    c1.breaker_cooldown_ms = 150;
    let mock = MockBackend::new(1, 4, 64);
    let plan = FaultPlan::seeded(3).inject(FaultKind::DecodeError, FaultSchedule::Once(2));
    let router =
        ModelRouter::from_pools(vec![("m".into(), fault_pool(c1, mock.clone(), plan))]).unwrap();

    let c = router.generate("m", vec![30], opts(6)).unwrap();
    assert!(matches!(c.finish_reason, FinishReason::Error { .. }));
    eventually("breaker opened", || {
        router.stats("m").unwrap().breaker_state == BreakerState::Open
    });
    match router.submit("m", vec![40], opts(2)) {
        Err(RouteError::CircuitOpen(m)) => {
            assert_eq!(m, "m");
            assert_eq!(
                RouteError::CircuitOpen(m).to_string(),
                "circuit breaker open for model `m`"
            );
        }
        other => panic!("expected CircuitOpen, got {:?}", other.map(|_| ())),
    }

    // After the cooldown a probe is admitted; its success closes the loop.
    std::thread::sleep(Duration::from_millis(180));
    let probe = router.generate("m", vec![50], opts(3)).unwrap();
    assert_eq!(probe.finish_reason, FinishReason::Length);
    assert_eq!(probe.tokens, mock.expected_stream(50, 3));
    eventually("breaker recovered", || {
        router.stats("m").unwrap().breaker_state == BreakerState::Healthy
    });
    let s = router.stats("m").unwrap();
    assert!(s.breaker_opens >= 1, "opens: {}", s.breaker_opens);
    assert!(s.breaker_recoveries >= 1, "recoveries: {}", s.breaker_recoveries);
    router.shutdown();
}

#[test]
fn expired_deadline_is_shed_at_pop_without_burning_a_prefill() {
    // A request whose deadline already passed when it reaches the head of
    // the queue is shed before any backend work happens.
    let router =
        ModelRouter::from_pools(vec![("m".into(), pool(cfg(1, 4), MockBackend::new(1, 4, 64)))])
            .unwrap();
    let o = SubmitOptions { deadline: Some(Duration::ZERO), ..opts(10) };
    let c = router.generate("m", vec![5], o).unwrap();
    assert_eq!(c.finish_reason, FinishReason::DeadlineExpired);
    assert!(c.tokens.is_empty());
    eventually("expiry shed tallied", || router.stats("m").unwrap().shed_expired == 1);
    let s = router.stats("m").unwrap();
    assert_eq!(s.prefill_calls, 0, "the dead request never reached the backend");
    router.shutdown();
}

#[test]
fn infeasible_deadline_is_shed_by_the_ewma_estimator() {
    // Request A seeds the prefill/decode EWMAs (~5 ms per decode step).
    // Request B then asks for 1000 tokens inside 200 ms — infeasible by
    // orders of magnitude — and is shed at pop time with Shed, before any
    // prefill. Its 200 ms deadline is comfortably unexpired at pop, so this
    // exercises the estimator, not the expiry path.
    let mock = MockBackend::new(1, 4, 64).step_delay(Duration::from_millis(5));
    let router =
        ModelRouter::from_pools(vec![("m".into(), pool(cfg(1, 8), mock))]).unwrap();
    let a = router.generate("m", vec![5], opts(4)).unwrap();
    assert_eq!(a.finish_reason, FinishReason::Length);

    let o = SubmitOptions { deadline: Some(Duration::from_millis(200)), ..opts(1000) };
    let b = router.generate("m", vec![6], o).unwrap();
    assert_eq!(b.finish_reason, FinishReason::Shed);
    assert!(b.tokens.is_empty());
    eventually("infeasible shed tallied", || router.stats("m").unwrap().shed_infeasible == 1);
    assert_eq!(router.stats("m").unwrap().shed_expired, 0, "shed by the estimator, not expiry");
    router.shutdown();
}

#[test]
fn admission_only_pool_refuses_submit_wait_with_typed_error() {
    let p = pool(cfg(0, 2), MockBackend::new(1, 2, 4));
    let err = p.submit_wait(vec![1], opts(2)).unwrap_err();
    assert_eq!(
        err.downcast_ref::<SubmitError>(),
        Some(&SubmitError::AdmissionOnly),
        "submit_wait on workers=0 must fail with the typed variant, got: {err:#}"
    );
    assert!(err.to_string().contains("admission-only"), "{err}");
    // non-blocking submit still queues (backpressure testing stays possible)
    assert!(p.submit(vec![1], opts(2)).is_ok());
    p.shutdown();
}

#[test]
fn per_model_shutdown_drains_one_pool_and_spares_the_rest() {
    let router = ModelRouter::from_pools(vec![
        ("a".into(), pool(cfg(1, 4), MockBackend::new(1, 2, 4))),
        ("b".into(), pool(cfg(1, 4), MockBackend::new(1, 2, 4))),
    ])
    .unwrap();
    router.shutdown_model("a").unwrap();
    match router.submit("a", vec![1], opts(2)) {
        Err(RouteError::Submit(SubmitError::ShuttingDown)) => {}
        other => panic!("expected ShuttingDown on `a`, got {:?}", other.map(|_| ())),
    }
    // `a` stays listed (its stats remain readable), `b` still serves
    assert_eq!(router.models(), vec!["a", "b"]);
    assert!(router.stats("a").is_ok());
    let c = router.generate("b", vec![8], opts(2)).unwrap();
    assert_eq!(c.tokens, vec![9, 10]);
    assert!(matches!(router.shutdown_model("ghost"), Err(RouteError::UnknownModel(_))));
    router.shutdown(); // full shutdown is idempotent over the drained pool
}

#[test]
fn router_pool_exposes_inference_service_surface() {
    // The router composes ServicePools; the single-pool trait surface stays
    // available for embedders that hold a pool directly.
    let p = pool(cfg(1, 4), MockBackend::new(1, 2, 4));
    let c = p.generate(vec![3], opts(2)).unwrap();
    assert_eq!(c.tokens, vec![4, 5]);
    eventually("completion tallied", || p.stats().completed == 1);
    p.shutdown();
}
