//! Hermetic serving-tier integration: `ModelRouter` → `ServicePool`s →
//! `MockBackend`. Everything the artifact-backed `serve_integration` suite
//! can only check when `make artifacts` has run — router dispatch,
//! continuous batching, streaming, cancellation, deadlines, QueueFull
//! backpressure, engine failure + recovery — runs here deterministically
//! under `cargo test -q` with **zero** PJRT/artifact dependency.
//!
//! Determinism: `MockBackend`'s token rule is a pure function of a row's
//! last real token, so every completion is an exact, precomputable
//! arithmetic progression regardless of how rows interleave in the slot
//! table (see `serve::mock`).

use cola::config::ServeConfig;
use cola::serve::{
    FinishReason, InferenceService, MockBackend, ModelRouter, RouteError, ServicePool,
    StreamEvent, SubmitError, SubmitOptions,
};
use std::time::Duration;

fn cfg(workers: usize, queue_depth: usize) -> ServeConfig {
    ServeConfig {
        artifact: "mock".into(),
        max_new_tokens: 8,
        workers,
        queue_depth,
        ..ServeConfig::default()
    }
}

fn pool(cfg: ServeConfig, mock: MockBackend) -> ServicePool {
    ServicePool::start_with(cfg, mock.factory()).unwrap()
}

fn opts(max_new: usize) -> SubmitOptions {
    SubmitOptions { max_new_tokens: Some(max_new), ..Default::default() }
}

/// Counters are bumped just *after* the worker streams a request's terminal
/// `Done`, so asserts that follow a `wait()` poll briefly instead of racing
/// that window.
fn eventually(what: &str, mut cond: impl FnMut() -> bool) {
    for _ in 0..1000 {
        if cond() {
            return;
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    panic!("not reached within 1s: {what}");
}

#[test]
fn router_dispatches_by_model_name_to_distinct_backends() {
    let a = MockBackend::new(2, 4, 8).stride(1).vocab(10_000);
    let b = MockBackend::new(2, 4, 8).stride(5).vocab(10_000);
    let router = ModelRouter::from_pools(vec![
        ("a".into(), pool(cfg(1, 8), a.clone())),
        ("b".into(), pool(cfg(1, 8), b.clone())),
    ])
    .unwrap();
    assert_eq!(router.models(), vec!["a", "b"]);

    let ca = router.generate("a", vec![10], opts(3)).unwrap();
    assert_eq!(ca.tokens, a.expected_stream(10, 3));
    assert_eq!(ca.tokens, vec![11, 12, 13]);
    assert_eq!(ca.finish_reason, FinishReason::Length);

    let cb = router.generate("b", vec![10], opts(3)).unwrap();
    assert_eq!(cb.tokens, b.expected_stream(10, 3));
    assert_eq!(cb.tokens, vec![15, 20, 25], "model `b` has its own backend");
    router.shutdown();
}

#[test]
fn unknown_model_is_a_typed_route_error() {
    let router = ModelRouter::from_pools(vec![(
        "only".into(),
        pool(cfg(1, 4), MockBackend::new(1, 2, 4)),
    )])
    .unwrap();
    match router.submit("ghost", vec![1], opts(2)) {
        Err(RouteError::UnknownModel(m)) => {
            assert_eq!(m, "ghost");
            assert_eq!(
                RouteError::UnknownModel(m).to_string(),
                "unknown model `ghost`"
            );
        }
        other => panic!("expected UnknownModel, got {:?}", other.map(|_| ())),
    }
    assert!(router.generate("ghost", vec![1], opts(2)).is_err());
    assert!(matches!(router.stats("ghost"), Err(RouteError::UnknownModel(_))));
    router.shutdown();
}

#[test]
fn duplicate_model_names_are_rejected() {
    let p1 = pool(cfg(0, 2), MockBackend::new(1, 2, 4));
    let p2 = pool(cfg(0, 2), MockBackend::new(1, 2, 4));
    assert!(ModelRouter::from_pools(vec![("m".into(), p1), ("m".into(), p2)]).is_err());
    assert!(ModelRouter::from_pools(vec![]).is_err(), "empty router is refused");
}

#[test]
fn backpressure_is_per_model() {
    // `a` is admission-only (workers=0) with a depth-1 queue: it fills
    // deterministically. `b` keeps serving regardless.
    let router = ModelRouter::from_pools(vec![
        ("a".into(), pool(cfg(0, 1), MockBackend::new(1, 2, 4))),
        ("b".into(), pool(cfg(1, 8), MockBackend::new(1, 2, 4))),
    ])
    .unwrap();

    let queued = router.submit("a", vec![1], opts(2)).unwrap();
    match router.submit("a", vec![2], opts(2)) {
        Err(RouteError::Submit(SubmitError::QueueFull)) => {}
        other => panic!("expected QueueFull on `a`, got {:?}", other.map(|_| ())),
    }
    // `a` saturated; `b` unaffected
    let cb = router.generate("b", vec![7], opts(2)).unwrap();
    assert_eq!(cb.finish_reason, FinishReason::Length);

    let sa = router.stats("a").unwrap();
    let sb = router.stats("b").unwrap();
    assert_eq!(sa.rejected, 1);
    assert_eq!(sa.queue_depth, 1);
    assert_eq!(sb.rejected, 0, "b never saw a's backpressure");

    // shutdown sheds a's queued request rather than hanging its client
    router.shutdown();
    assert_eq!(queued.wait().unwrap().finish_reason, FinishReason::Cancelled);
}

#[test]
fn per_model_and_aggregate_stats_line_up() {
    let router = ModelRouter::from_pools(vec![
        ("a".into(), pool(cfg(1, 8), MockBackend::new(2, 4, 8))),
        ("b".into(), pool(cfg(1, 8), MockBackend::new(2, 4, 8).stride(3))),
    ])
    .unwrap();
    for i in 0..3 {
        router.generate("a", vec![10 + i], opts(2)).unwrap();
    }
    for i in 0..2 {
        router.generate("b", vec![50 + i], opts(4)).unwrap();
    }
    eventually("both pools tally completions", || {
        router.stats("a").unwrap().completed == 3 && router.stats("b").unwrap().completed == 2
    });
    let sa = router.stats("a").unwrap();
    let sb = router.stats("b").unwrap();
    assert_eq!(sa.submitted, 3);
    assert_eq!(sb.submitted, 2);
    assert!(sa.decoded_tokens > 0 && sb.decoded_tokens > 0);

    let agg = router.aggregate_stats();
    assert_eq!(agg.submitted, 5);
    assert_eq!(agg.completed, 5);
    assert_eq!(agg.workers, 2);
    assert_eq!(agg.decoded_tokens, sa.decoded_tokens + sb.decoded_tokens);
    assert_eq!(agg.queue_capacity, 16);
    assert!(agg.decode_tokens_per_sec > 0.0);

    let by_model = router.stats_by_model();
    assert_eq!(by_model.len(), 2);
    assert_eq!(by_model[0].0, "a");
    assert_eq!(by_model[1].0, "b");
    assert_eq!(by_model[0].1.completed, 3);
    router.shutdown();
}

#[test]
fn continuous_batching_completes_mixed_budgets_with_exact_streams() {
    // 6 requests through a 2-slot table: short rows vacate and refill while
    // long rows keep decoding; outputs stay exact regardless of interleaving.
    let mock = MockBackend::new(2, 4, 8).vocab(10_000);
    let router =
        ModelRouter::from_pools(vec![("m".into(), pool(cfg(1, 16), mock.clone()))]).unwrap();
    let mut streams = Vec::new();
    for i in 0..6u32 {
        let max_new = if i % 2 == 0 { 3 } else { 7 };
        let last = 100 + 10 * i as i32;
        streams.push((last, max_new, router.submit("m", vec![9, last], opts(max_new)).unwrap()));
    }
    for (last, max_new, s) in streams {
        let c = s.wait().unwrap();
        assert_eq!(c.finish_reason, FinishReason::Length);
        assert_eq!(c.tokens, mock.expected_stream(last, max_new), "row seeded with {last}");
    }
    eventually("all 6 completions tallied", || router.stats("m").unwrap().completed == 6);
    eventually("occupancy gauge returns to zero", || router.stats("m").unwrap().active == 0);
    router.shutdown();
}

#[test]
fn streaming_yields_every_token_before_done() {
    let mock = MockBackend::new(1, 3, 6);
    let router =
        ModelRouter::from_pools(vec![("m".into(), pool(cfg(1, 4), mock.clone()))]).unwrap();
    let mut stream = router.submit("m", vec![40], opts(5)).unwrap();
    let mut streamed = Vec::new();
    let done = loop {
        match stream.recv() {
            Some(StreamEvent::Token(t)) => streamed.push(t),
            Some(StreamEvent::Done(c)) => break c,
            None => panic!("stream dropped before Done"),
        }
    };
    assert_eq!(streamed, mock.expected_stream(40, 5));
    assert_eq!(streamed, done.tokens, "stream and completion agree");
    assert!(stream.recv().is_none(), "stream exhausted after Done");
    assert!(done.timing.first_token.is_some());
    assert!(done.timing.first_token.unwrap() <= done.timing.total);
    router.shutdown();
}

#[test]
fn stop_token_ends_generation_early() {
    let mock = MockBackend::new(1, 3, 6);
    let router =
        ModelRouter::from_pools(vec![("m".into(), pool(cfg(1, 4), mock.clone()))]).unwrap();
    // rule: 20 → 21, 22, ... so stop on 22
    let o = SubmitOptions { stop_tokens: vec![22], ..opts(10) };
    let c = router.generate("m", vec![20], o).unwrap();
    assert_eq!(c.finish_reason, FinishReason::Stop);
    assert_eq!(c.tokens, vec![21, 22], "stops at and includes the stop token");
    router.shutdown();
}

#[test]
fn cancel_mid_flight_delivers_partial_output() {
    let mock = MockBackend::new(1, 4, 64).step_delay(Duration::from_millis(2));
    let router = ModelRouter::from_pools(vec![("m".into(), pool(cfg(1, 4), mock))]).unwrap();
    let mut stream = router.submit("m", vec![5], opts(100_000)).unwrap();
    match stream.recv() {
        Some(StreamEvent::Token(t)) => assert_eq!(t, 6, "first token is deterministic"),
        other => panic!("expected a first token, got {other:?}"),
    }
    stream.cancel();
    let c = stream.wait().unwrap();
    assert_eq!(c.finish_reason, FinishReason::Cancelled);
    assert!(!c.tokens.is_empty(), "partial output is delivered");
    assert!(c.tokens.len() < 100_000, "cancel actually cut generation short");
    eventually("cancellation tallied", || router.stats("m").unwrap().cancelled == 1);
    router.shutdown();
}

#[test]
fn deadline_expires_mid_decode() {
    let mock = MockBackend::new(1, 4, 64).step_delay(Duration::from_millis(2));
    let router = ModelRouter::from_pools(vec![("m".into(), pool(cfg(1, 4), mock))]).unwrap();
    let o = SubmitOptions { deadline: Some(Duration::from_millis(30)), ..opts(1_000_000) };
    let c = router.generate("m", vec![5], o).unwrap();
    assert_eq!(c.finish_reason, FinishReason::DeadlineExpired);
    assert!(c.tokens.len() < 1_000_000);
    eventually("expiry tallied", || router.stats("m").unwrap().expired == 1);
    router.shutdown();
}

#[test]
fn default_deadline_comes_from_pool_config() {
    let mock = MockBackend::new(1, 4, 64).step_delay(Duration::from_millis(2));
    let mut c = cfg(1, 4);
    c.default_deadline_ms = 25;
    let router = ModelRouter::from_pools(vec![("m".into(), pool(c, mock))]).unwrap();
    let done = router.generate("m", vec![5], opts(1_000_000)).unwrap();
    assert_eq!(done.finish_reason, FinishReason::DeadlineExpired);
    router.shutdown();
}

#[test]
fn generation_runs_past_the_static_kv_window() {
    // max_len 6 with prompt_len 4 → only 2 decode positions per prefill;
    // a 12-token generation forces several sliding-window rollovers, and
    // the arithmetic stream must come through unbroken.
    let mock = MockBackend::new(1, 4, 6).stride(3).vocab(10_000);
    let router =
        ModelRouter::from_pools(vec![("m".into(), pool(cfg(1, 4), mock.clone()))]).unwrap();
    let c = router.generate("m", vec![100], opts(12)).unwrap();
    assert_eq!(c.finish_reason, FinishReason::Length);
    assert_eq!(c.tokens, mock.expected_stream(100, 12));
    assert_eq!(c.tokens.first(), Some(&103));
    assert_eq!(c.tokens.last(), Some(&136));
    router.shutdown();
}

#[test]
fn zero_token_budget_completes_empty() {
    let router =
        ModelRouter::from_pools(vec![("m".into(), pool(cfg(1, 4), MockBackend::new(1, 2, 4)))])
            .unwrap();
    let c = router.generate("m", vec![5, 6], opts(0)).unwrap();
    assert!(c.tokens.is_empty(), "max_new_tokens=0 must not leak the prefill token");
    assert_eq!(c.finish_reason, FinishReason::Length);
    router.shutdown();
}

#[test]
fn injected_engine_failure_fails_the_batch_and_recovers() {
    // bs=1 so decode-call counting is exact: prefill → token 1, decode
    // calls 1,2 → tokens 2,3, decode call 3 → injected failure.
    let mock = MockBackend::new(1, 4, 64).fail_after(3);
    let router =
        ModelRouter::from_pools(vec![("m".into(), pool(cfg(1, 4), mock.clone()))]).unwrap();
    let c = router.generate("m", vec![30], opts(10)).unwrap();
    assert_eq!(c.finish_reason, FinishReason::Error);
    assert_eq!(c.tokens, mock.expected_stream(30, 3), "partial tokens are delivered");
    eventually("batch failure tallied", || router.stats("m").unwrap().failed == 1);

    // one-shot trigger cleared: the pool serves normally again
    let c2 = router.generate("m", vec![60], opts(10)).unwrap();
    assert_eq!(c2.finish_reason, FinishReason::Length);
    assert_eq!(c2.tokens, mock.expected_stream(60, 10));
    eventually("recovery completion tallied", || router.stats("m").unwrap().completed == 1);
    router.shutdown();
}

#[test]
fn per_model_shutdown_drains_one_pool_and_spares_the_rest() {
    let router = ModelRouter::from_pools(vec![
        ("a".into(), pool(cfg(1, 4), MockBackend::new(1, 2, 4))),
        ("b".into(), pool(cfg(1, 4), MockBackend::new(1, 2, 4))),
    ])
    .unwrap();
    router.shutdown_model("a").unwrap();
    match router.submit("a", vec![1], opts(2)) {
        Err(RouteError::Submit(SubmitError::ShuttingDown)) => {}
        other => panic!("expected ShuttingDown on `a`, got {:?}", other.map(|_| ())),
    }
    // `a` stays listed (its stats remain readable), `b` still serves
    assert_eq!(router.models(), vec!["a", "b"]);
    assert!(router.stats("a").is_ok());
    let c = router.generate("b", vec![8], opts(2)).unwrap();
    assert_eq!(c.tokens, vec![9, 10]);
    assert!(matches!(router.shutdown_model("ghost"), Err(RouteError::UnknownModel(_))));
    router.shutdown(); // full shutdown is idempotent over the drained pool
}

#[test]
fn router_pool_exposes_inference_service_surface() {
    // The router composes ServicePools; the single-pool trait surface stays
    // available for embedders that hold a pool directly.
    let p = pool(cfg(1, 4), MockBackend::new(1, 2, 4));
    let c = p.generate(vec![3], opts(2)).unwrap();
    assert_eq!(c.tokens, vec![4, 5]);
    eventually("completion tallied", || p.stats().completed == 1);
    p.shutdown();
}
