//! Data-pipeline integration: corpus → BPE → batches is deterministic,
//! learnable, and produces tensors the artifacts accept.

use cola::data::corpus::{CorpusCfg, CorpusGen};
use cola::data::{BatchIter, Bpe, ClsTaskGen, MlmBatchIter};

fn bpe(vocab: usize) -> Bpe {
    let text = CorpusGen::new(CorpusCfg { seed: 42, ..CorpusCfg::default() }).text(150_000);
    Bpe::train(&text, vocab)
}

#[test]
fn end_to_end_token_stream_statistics() {
    let bpe = bpe(1024);
    let mut it = BatchIter::new(bpe, 0, 1024);
    let batch = it.next_batch(&[1, 32, 129]);
    // heavy-tailed: the top-32 tokens should cover most of the stream
    let mut counts = std::collections::HashMap::new();
    for &t in &batch {
        *counts.entry(t).or_insert(0usize) += 1;
    }
    let mut freq: Vec<usize> = counts.values().copied().collect();
    freq.sort_unstable_by(|a, b| b.cmp(a));
    let top32: usize = freq.iter().take(32).sum();
    assert!(
        top32 as f64 > 0.35 * batch.len() as f64,
        "token distribution not heavy-tailed"
    );
    // and non-degenerate: many distinct tokens in play
    assert!(counts.len() > 100, "only {} distinct tokens", counts.len());
}

#[test]
fn train_and_val_streams_differ() {
    let b = bpe(1024);
    let mut train = BatchIter::new(b.clone(), 0, 1024);
    let mut val = BatchIter::new(b, 1_000_003, 1024);
    assert_ne!(train.next_batch(&[1, 8, 64]), val.next_batch(&[1, 8, 64]));
}

#[test]
fn bigram_predictability_survives_tokenization() {
    // the LM signal the trainers learn: token bigrams carry information
    let b = bpe(512);
    let mut it = BatchIter::new(b, 3, 512);
    let toks = it.next_batch(&[1, 1, 20_000]);
    let mut uni = std::collections::HashMap::new();
    let mut bi = std::collections::HashMap::new();
    for w in toks.windows(2) {
        *uni.entry(w[0]).or_insert(0f64) += 1.0;
        *bi.entry((w[0], w[1])).or_insert(0f64) += 1.0;
    }
    let n = (toks.len() - 1) as f64;
    let h_uni: f64 = uni.values().map(|c| -(c / n) * (c / n).log2()).sum();
    let h_joint: f64 = bi.values().map(|c| -(c / n) * (c / n).log2()).sum();
    let h_cond = h_joint - h_uni;
    assert!(
        h_cond < h_uni - 0.5,
        "tokenized stream lost its structure: H={h_uni:.2}, H(cond)={h_cond:.2}"
    );
}

#[test]
fn mlm_labels_recover_original_tokens() {
    let b = bpe(512);
    let mut lm = BatchIter::new(b.clone(), 5, 512);
    let mut mlm = MlmBatchIter::new(b, 5, 512);
    let plain = lm.next_batch(&[1, 4, 64]);
    let (masked, labels) = mlm.next_batch(&[1, 4, 64]);
    // where not masked, tokens agree with the plain stream; where masked,
    // the label channel carries the original token + 1
    for i in 0..plain.len() {
        if labels[i] > 0 {
            assert_eq!(labels[i] - 1, plain[i]);
            assert_eq!(masked[i], cola::data::tokenizer::MASK);
        } else {
            assert_eq!(masked[i], plain[i]);
        }
    }
}

#[test]
fn cls_tasks_are_distinct_and_balancedish() {
    let b = bpe(512);
    let mut dists = Vec::new();
    for task in 0..4 {
        let mut g = ClsTaskGen::new(b.clone(), task, 1, 4, 512);
        let (_, labels) = g.next_batch(128, 32);
        let mut hist = [0usize; 4];
        for &l in &labels {
            hist[l as usize] += 1;
        }
        // no empty class in 128 samples (fully degenerate task would be)
        assert!(hist.iter().filter(|&&c| c > 0).count() >= 2, "task {task}: {hist:?}");
        dists.push(labels);
    }
    // different tasks label the same generator stream differently
    assert!(dists.windows(2).any(|w| w[0] != w[1]));
}

#[test]
fn bpe_cache_roundtrip_via_shared_helper() {
    let tmp = std::env::temp_dir().join("cola_test_datacache");
    std::fs::create_dir_all(&tmp).unwrap();
    // SAFETY: test-local env var; tests in this binary run serially enough
    unsafe { std::env::set_var("COLA_DATA_CACHE", &tmp) };
    let a = cola::coordinator::trainer::shared_bpe(512).unwrap();
    let b = cola::coordinator::trainer::shared_bpe(512).unwrap(); // cache hit
    assert_eq!(a.encode("zalu bani koto"), b.encode("zalu bani koto"));
    std::fs::remove_dir_all(&tmp).ok();
}
