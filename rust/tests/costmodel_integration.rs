//! Cost-model integration: the analytic formulas must agree with the real
//! artifacts' parameter counts and with each other across scales, and the
//! paper's headline constants must fall out.

use cola::costmodel::memory::{activation_elems_per_layer, memory_breakdown, BF16};
use cola::costmodel::{
    c_cola, c_full_rank, cola_breakeven_rank, compute_total, params_total, Geometry, Method,
    PaperPreset, PAPER_PRESETS,
};
use cola::runtime::ArtifactDir;

#[test]
fn analytic_params_match_artifact_manifests() {
    // the python side counts parameters exactly; the analytic model must
    // agree within 3% for full-rank and CoLA at every proxy scale.
    for (preset, d, dff, r, layers, heads, vocab) in [
        ("p60m", 128usize, 352usize, 32usize, 4usize, 4usize, 1024usize),
        ("p130m", 192, 512, 48, 6, 6, 2048),
        ("p350m", 256, 688, 64, 8, 8, 2048),
    ] {
        for (variant, method) in [("full", Method::FullRank), ("cola", Method::Cola)] {
            let name = format!("{preset}_{variant}");
            let Ok(art) = ArtifactDir::open_named(&name) else {
                eprintln!("skipping {name} (run `make artifacts`)");
                return;
            };
            let g = Geometry::new(d, dff, r, 1, heads, layers);
            let analytic = params_total(method, &g, vocab)
                // + norms (2 per layer + final) the closed form omits
                + (2 * layers + 1) as f64 * d as f64;
            let actual = art.manifest.n_total_params as f64;
            let rel = (analytic - actual).abs() / actual;
            assert!(rel < 0.03, "{name}: analytic {analytic:.0} vs manifest {actual:.0}");
        }
    }
}

#[test]
fn paper_headline_constants() {
    // 2x compute reduction at the paper's default ranks, 1B scale
    let p = PaperPreset::by_name("llama1b").unwrap();
    let g = Geometry::from_paper(p, p.seq_len);
    let ratio = c_cola(&g) / c_full_rank(&g);
    assert!((0.35..0.50).contains(&ratio), "CoLA-1B compute ratio {ratio}");

    // Eq. (7) gives C_CoLA-1B ≈ 16.5nd² + 12n²d + 1.8nd·dff. The 16.5nd²
    // term follows exactly from Eq. (6) at r=d/4 (66ndr = 16.5nd²); the
    // 1.8nd·dff term uses the paper's loose r≈dff/10 regrouping and
    // underestimates the exact 18nr·dff at the true 1B geometry, so we
    // check the exact-term identity and require Eq. 7 to be a lower bound
    // of the same magnitude.
    let exact = c_cola(&g);
    let gemm_sq = (48.0 + 18.0) * g.n * g.d * g.r; // = 16.5nd² at r=d/4
    assert!((gemm_sq - 16.5 * g.n * g.d * g.d).abs() / gemm_sq < 1e-9);
    let eq7 = 16.5 * g.n * g.d * g.d + 12.0 * g.n * g.n * g.d + 1.8 * g.n * g.d * g.d_ff;
    assert!(eq7 <= exact && exact < 1.35 * eq7, "Eq.7: {exact:.3e} vs {eq7:.3e}");

    // breakeven 0.62d at dff=2.5d
    let g25 = Geometry::new(1024, 2560, 256, 256, 16, 24);
    assert!((cola_breakeven_rank(&g25) / g25.d - 0.62).abs() < 0.02);
}

#[test]
fn memory_model_scales_monotonically() {
    for p in &PAPER_PRESETS {
        let g8 = Geometry::from_paper(p, p.tokens_per_batch(8));
        let g32 = Geometry::from_paper(p, p.tokens_per_batch(32));
        for m in Method::ALL {
            // activations grow with batch; states don't
            assert!(
                activation_elems_per_layer(m, &g32) > activation_elems_per_layer(m, &g8),
                "{:?}",
                m
            );
            let s8 = memory_breakdown(m, &g8, p.vocab, BF16).states_only();
            let s32 = memory_breakdown(m, &g32, p.vocab, BF16).states_only();
            assert!((s8 - s32).abs() < 1.0, "{:?} states depend on batch", m);
        }
    }
}

#[test]
fn compute_monotone_in_rank_for_lowrank_methods() {
    let p = PaperPreset::by_name("llama350m").unwrap();
    let mut prev = 0.0;
    for r in [64usize, 128, 256, 512] {
        let mut g = Geometry::from_paper(p, p.seq_len);
        g.r = r as f64;
        let c = compute_total(Method::Cola, &g);
        assert!(c > prev);
        prev = c;
    }
}

#[test]
fn vmem_plans_match_design_doc() {
    // DESIGN.md §7 table is generated from this function — keep them honest.
    for (name, fits) in [("llama60m", true), ("llama1b", true), ("llama7b", false)] {
        let p = PaperPreset::by_name(name).unwrap();
        assert_eq!(p.vmem_plan(128).3, fits, "{name}");
    }
}
