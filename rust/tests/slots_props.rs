//! Property-style tests for `SlotTable` invariants: whatever sequence of
//! admit / push_token / sweep / fail_all the engine throws at it, the table
//! must keep `active + free == size`, refill the lowest free slot first,
//! resolve every admitted request exactly once, and produce left-aligned
//! context windows (real tokens at offsets `0..len`, trailing pad) that
//! match the tail of `prompt ++ generated` — including past each row's own
//! `pos == max_len` rollover, where the window is all that survives. Decode
//! positions are *per row*: one row's encode, decode or rollover never
//! moves a neighbour's position.
//!
//! Hermetic: no artifact, no PJRT — the table is pure bookkeeping.

use cola::serve::sync::Flag;
use cola::serve::{FinishReason, QueuedRequest, SlotTable, StreamEvent};
use cola::util::rng::Rng;
use std::sync::mpsc::{channel, Receiver};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn mk_req(
    prompt: Vec<i32>,
    max_new: usize,
    stop: Vec<i32>,
    deadline: Option<Instant>,
) -> (QueuedRequest, Receiver<StreamEvent>, Arc<Flag>) {
    let (tx, rx) = channel();
    let cancel = Arc::new(Flag::new());
    let req = QueuedRequest {
        prompt,
        max_new_tokens: max_new,
        stop_tokens: stop,
        deadline,
        submitted_at: Instant::now(),
        tx,
        cancel: cancel.clone(),
    };
    (req, rx, cancel)
}

fn drain(rx: &Receiver<StreamEvent>) -> (Vec<i32>, Vec<FinishReason>) {
    let (mut toks, mut dones) = (Vec::new(), Vec::new());
    while let Ok(ev) = rx.try_recv() {
        match ev {
            StreamEvent::Token(t) => toks.push(t),
            StreamEvent::Done(c) => dones.push(c.finish_reason),
        }
    }
    (toks, dones)
}

/// The invariant bundle checked after every operation.
fn check_invariants(tbl: &SlotTable) {
    assert_eq!(tbl.active() + tbl.free(), tbl.size(), "active + free == size");
    let occ = tbl.occupied();
    assert_eq!(occ.len(), tbl.active(), "occupied() agrees with active()");
    assert!(occ.windows(2).all(|w| w[0] < w[1]), "occupied indices strictly increasing");
    assert!(occ.iter().all(|&i| i < tbl.size()), "occupied indices in range");
    assert_eq!(tbl.feed_tokens(-7).len(), tbl.size(), "feed covers every row");
    // the non-allocating hot-path variants agree with the snapshots
    assert_eq!(tbl.occupied_iter().collect::<Vec<_>>(), occ, "occupied_iter == occupied");
    let mut scratch = vec![77usize; 1];
    tbl.occupied_into(&mut scratch);
    assert_eq!(scratch, occ, "occupied_into == occupied");
    let mut feed = Vec::new();
    tbl.feed_tokens_into(-7, &mut feed);
    assert_eq!(feed, tbl.feed_tokens(-7), "feed_tokens_into == feed_tokens");
    for &i in &occ {
        let mut w = vec![0i32; 5];
        tbl.write_window(i, -3, &mut w);
        assert_eq!(w, tbl.window(i, 5, -3), "write_window == window");
    }
}

#[test]
fn random_op_sequences_keep_invariants_and_resolve_every_request() {
    for seed in 0..12u64 {
        let mut rng = Rng::new(seed);
        let size = rng.range(1, 5);
        let mut tbl = SlotTable::new(size);
        let now = Instant::now();

        let mut admitted = 0usize;
        let mut resolved_rxs: Vec<Receiver<StreamEvent>> = Vec::new();
        let mut live: Vec<(usize, Receiver<StreamEvent>, Arc<Flag>)> = Vec::new();

        for step in 0..200 {
            let t = now + Duration::from_millis(step as u64);
            match rng.below(10) {
                // admit into a free slot
                0..=3 => {
                    if tbl.free() > 0 {
                        let max_new = rng.range(1, 6);
                        let prompt: Vec<i32> = (0..rng.range(1, 4)).map(|x| x as i32 + 4).collect();
                        let (req, rx, cancel) = mk_req(prompt, max_new, vec![], None);
                        let slot = tbl.admit(req, t).expect("free slot admits");
                        assert!(
                            !live.iter().any(|(s, _, _)| *s == slot),
                            "admitted into an occupied slot"
                        );
                        admitted += 1;
                        live.push((slot, rx, cancel));
                    } else {
                        let (req, _rx, _) = mk_req(vec![1], 1, vec![], None);
                        assert!(tbl.admit(req, t).is_none(), "full table must refuse");
                    }
                }
                // push a token to a random occupied row
                4..=7 => {
                    if !live.is_empty() {
                        let k = rng.below(live.len());
                        let slot = live[k].0;
                        let tok = rng.below(500) as i32;
                        if tbl.push_token(slot, tok, t).is_some() {
                            let (_, rx, _) = live.swap_remove(k);
                            resolved_rxs.push(rx);
                        }
                    }
                }
                // cancel a random row, then sweep
                8 => {
                    if !live.is_empty() {
                        let k = rng.below(live.len());
                        live[k].2.set();
                        let mut vac = Vec::new();
                        let (cancelled, expired) = tbl.sweep(t, &mut vac);
                        assert_eq!(expired, 0, "no deadlines in this sequence");
                        assert_eq!(cancelled, 1, "exactly the flagged row vacates");
                        assert_eq!(vac, vec![live[k].0], "sweep reports the vacated row");
                        let (_, rx, _) = live.swap_remove(k);
                        resolved_rxs.push(rx);
                    }
                }
                // batch failure
                _ => {
                    let n = tbl.fail_all(t);
                    assert_eq!(n, live.len(), "fail_all vacates exactly the occupied rows");
                    assert_eq!(tbl.active(), 0);
                    for (_, rx, _) in live.drain(..) {
                        resolved_rxs.push(rx);
                    }
                }
            }
            check_invariants(&tbl);
        }

        // close out whatever is still running
        let n = tbl.fail_all(now + Duration::from_secs(1));
        assert_eq!(n, live.len());
        for (_, rx, _) in live.drain(..) {
            resolved_rxs.push(rx);
        }
        check_invariants(&tbl);
        assert_eq!(tbl.active(), 0);

        // every admitted request resolved exactly once
        assert_eq!(resolved_rxs.len(), admitted, "seed {seed}");
        for rx in &resolved_rxs {
            let (_, dones) = drain(rx);
            assert_eq!(dones.len(), 1, "exactly one Done per request (seed {seed})");
        }
    }
}

#[test]
fn refill_always_takes_the_lowest_free_slot() {
    let mut rng = Rng::new(99);
    let now = Instant::now();
    for _ in 0..30 {
        let size = rng.range(2, 6);
        let mut tbl = SlotTable::new(size);
        let mut cancels = Vec::new();
        for _ in 0..size {
            let (req, _rx, cancel) = mk_req(vec![1], 100, vec![], None);
            tbl.admit(req, now).unwrap();
            cancels.push((cancel, _rx));
        }
        // vacate a random subset
        let mut freed: Vec<usize> = Vec::new();
        for (i, (cancel, _)) in cancels.iter().enumerate() {
            if rng.below(2) == 0 {
                cancel.set();
                freed.push(i);
            }
        }
        let mut vac = Vec::new();
        tbl.sweep(now, &mut vac);
        assert_eq!(vac, freed, "sweep reports the vacated rows in index order");
        assert_eq!(tbl.free(), freed.len());
        // refills land lowest-first, in order
        for &want in &freed {
            let (req, _rx2, _) = mk_req(vec![2], 100, vec![], None);
            assert_eq!(tbl.admit(req, now), Some(want), "lowest free slot first");
        }
        assert_eq!(tbl.free(), 0);
    }
}

#[test]
fn window_matches_prompt_plus_generated_at_every_length() {
    // Covers the single-row prefill math the engine relies on at admission
    // and at each row's own `pos == max_len` rollover: the window must be
    // the most recent `prompt_len` tokens of `prompt ++ generated`,
    // left-aligned (real tokens at offsets 0..len, trailing pad — the
    // alignment the KV prefix cache's chunked keying depends on).
    const PAD: i32 = 0;
    let mut rng = Rng::new(7);
    let now = Instant::now();
    for _ in 0..20 {
        let prompt_len = rng.range(1, 8);
        let prompt: Vec<i32> = (0..rng.range(1, 12)).map(|_| rng.range(4, 250) as i32).collect();
        let mut tbl = SlotTable::new(1);
        let (req, _rx, _) = mk_req(prompt.clone(), 64, vec![], None);
        tbl.admit(req, now).unwrap();

        let mut context = prompt.clone();
        for step in 0..40 {
            // expected: tail of the context at offsets 0..take, then pad
            let take = context.len().min(prompt_len);
            let mut want: Vec<i32> = context[context.len() - take..].to_vec();
            want.resize(prompt_len, PAD);
            assert_eq!(tbl.window(0, prompt_len, PAD), want, "step {step}");
            assert_eq!(tbl.real_len(0, prompt_len), take, "step {step}");
            // feed is the last generated token (or pad before any decode)
            let want_feed =
                if context.len() > prompt.len() { *context.last().unwrap() } else { PAD };
            assert_eq!(tbl.feed_tokens(PAD), vec![want_feed]);

            let tok = rng.range(4, 250) as i32;
            assert!(tbl.push_token(0, tok, now).is_none(), "budget not exhausted");
            context.push(tok);
        }
    }
}

#[test]
fn rows_roll_over_independently_under_random_decode_schedules() {
    // Per-row position invariant: whatever interleaving of encodes and
    // per-row decode bumps happens, each row's position is exactly
    // `len + its own bump count`, and `first_rollover` fires for precisely
    // the rows that individually exhausted `max_len` — a neighbour deep
    // into its window never drags a shallow row over the barrier.
    let now = Instant::now();
    for seed in 0..10u64 {
        let mut rng = Rng::new(seed);
        let size = rng.range(2, 5);
        let max_len = rng.range(6, 12);
        let mut tbl = SlotTable::new(size);
        let mut want_pos = vec![0usize; size];
        for i in 0..size {
            let plen = rng.range(1, 4);
            let (req, _rx, _) = mk_req(vec![5; plen], 1000, vec![], None);
            tbl.admit(req, now).unwrap();
            tbl.set_row_live(i, plen);
            want_pos[i] = plen;
        }
        for _ in 0..60 {
            let i = rng.below(size);
            if want_pos[i] < max_len {
                tbl.bump_pos(i);
                want_pos[i] += 1;
            }
            let mut got = Vec::new();
            tbl.positions_into(&mut got);
            assert_eq!(got, want_pos, "seed {seed}");
            let want_roll = want_pos.iter().position(|&p| p >= max_len);
            assert_eq!(tbl.first_rollover(max_len), want_roll, "seed {seed}");
        }
    }
}

#[test]
fn refill_into_lowest_slot_leaves_neighbour_positions_untouched() {
    // Admission is barrier-free: vacating and refilling one row must not
    // move any other row's decode position, window, or live status.
    let now = Instant::now();
    let mut tbl = SlotTable::new(3);
    let mut cancels = Vec::new();
    for i in 0..3 {
        let (req, _rx, cancel) = mk_req(vec![10 + i as i32, 20 + i as i32], 100, vec![], None);
        tbl.admit(req, now).unwrap();
        tbl.set_row_live(i, 2); // prompt is 2 tokens; all rows live at pos 2
        cancels.push((cancel, _rx));
    }
    // stagger the depths: rows end at positions 2, 3, 5
    tbl.bump_pos(1);
    tbl.bump_pos(2);
    tbl.bump_pos(2);
    tbl.bump_pos(2);
    let mut before = Vec::new();
    tbl.positions_into(&mut before);
    assert_eq!(before, vec![2, 3, 5]);
    let w1_before = tbl.window(1, 4, 0);
    let w2_before = tbl.window(2, 4, 0);

    // vacate row 0 (cancel + sweep), refill it with a fresh admission
    cancels[0].0.set();
    let mut vac = Vec::new();
    assert_eq!(tbl.sweep(now, &mut vac), (1, 0));
    assert_eq!(vac, vec![0]);
    let (req, _rx, _c) = mk_req(vec![7, 8, 9], 100, vec![], None);
    assert_eq!(tbl.admit(req, now), Some(0), "lowest free slot refills first");
    assert!(tbl.has_fresh());
    assert_eq!(tbl.first_fresh(), Some(0), "the refill is fresh until encoded");

    // neighbours: positions, windows, and live status are untouched
    let mut after = Vec::new();
    tbl.positions_into(&mut after);
    assert_eq!(after, vec![0, 3, 5], "only row 0's position reset");
    assert_eq!(tbl.window(1, 4, 0), w1_before);
    assert_eq!(tbl.window(2, 4, 0), w2_before);
    assert_eq!(tbl.live_rows(), 2, "rows 1 and 2 stayed live through the refill");

    // encoding the refill starts its own position without touching others
    tbl.set_row_live(0, tbl.real_len(0, 4));
    tbl.positions_into(&mut after);
    assert_eq!(after, vec![3, 3, 5]);
}

#[test]
fn stop_token_and_budget_resolution_is_exclusive_and_final() {
    let now = Instant::now();
    // stop token wins even on the budget-exhausting push
    let mut tbl = SlotTable::new(1);
    let (req, rx, _) = mk_req(vec![1], 2, vec![9], None);
    tbl.admit(req, now).unwrap();
    assert!(tbl.push_token(0, 5, now).is_none());
    assert_eq!(tbl.push_token(0, 9, now), Some(FinishReason::Stop));
    let (toks, dones) = drain(&rx);
    assert_eq!(toks, vec![5, 9]);
    assert_eq!(dones, vec![FinishReason::Stop]);
    // the vacated row ignores further pushes
    assert!(tbl.push_token(0, 7, now).is_none());
    let (toks, dones) = drain(&rx);
    assert!(toks.is_empty() && dones.is_empty(), "no events after resolution");
}

#[test]
fn sweep_prefers_cancel_over_deadline_and_counts_both() {
    let now = Instant::now();
    let mut tbl = SlotTable::new(3);
    let past = now - Duration::from_millis(1);
    // row 0: cancelled AND expired → counted as cancelled
    let (r0, rx0, c0) = mk_req(vec![1], 10, vec![], Some(past));
    // row 1: expired only
    let (r1, rx1, _) = mk_req(vec![2], 10, vec![], Some(past));
    // row 2: healthy
    let (r2, rx2, _) = mk_req(vec![3], 10, vec![], None);
    tbl.admit(r0, now).unwrap();
    tbl.admit(r1, now).unwrap();
    tbl.admit(r2, now).unwrap();
    c0.set();
    let mut vac = Vec::new();
    assert_eq!(tbl.sweep(now, &mut vac), (1, 1));
    assert_eq!(vac, vec![0, 1], "both vacated rows reported");
    assert_eq!(tbl.occupied(), vec![2], "healthy row survives");
    assert_eq!(drain(&rx0).1, vec![FinishReason::Cancelled]);
    assert_eq!(drain(&rx1).1, vec![FinishReason::DeadlineExpired]);
    assert!(drain(&rx2).1.is_empty());
}
