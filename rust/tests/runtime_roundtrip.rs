//! Integration: python-AOT artifacts load, compile and execute through the
//! rust PJRT runtime, and the training step makes progress.
//!
//! Requires `make artifacts` (or COLA_ARTIFACTS pointing at an artifact
//! root containing the tiny_* set).

use cola::runtime::executor::{lit_f32, lit_i32};
use cola::runtime::ArtifactDir;
use cola::util::rng::Rng;

fn art(name: &str) -> ArtifactDir {
    ArtifactDir::open_named(name).expect("run `make artifacts` first")
}

fn random_tokens(rng: &mut Rng, shape: &[usize], vocab: usize) -> Vec<i32> {
    (0..shape.iter().product::<usize>())
        .map(|_| rng.below(vocab) as i32)
        .collect()
}

#[test]
fn tiny_cola_train_step_runs_and_learns() {
    let a = art("tiny_cola");
    let m = &a.manifest;
    assert_eq!(m.variant, "cola");
    m.validate().unwrap();

    let step = a.step("train_step").unwrap();
    let state0 = a.load_state0().unwrap();
    assert_eq!(state0.len(), m.n_state);

    // fixed batch: loss must drop when repeatedly trained on it
    let mut rng = Rng::new(1);
    let toks = random_tokens(&mut rng, &m.tokens_shape, m.preset.vocab);
    let dims: Vec<i64> = m.tokens_shape.iter().map(|&x| x as i64).collect();
    let tok_lit = lit_i32(&toks, &dims).unwrap();

    // step 0 from literals, then keep state on device
    let mut args: Vec<xla::Literal> = state0;
    args.push(lit_f32(0.0));
    args.push(tok_lit.clone());
    let out = step.run(&args).unwrap();
    assert_eq!(out.len(), m.n_state + 2, "state' + (loss, gnorm)");

    let first_loss = cola::runtime::executor::buf_f32(&out[m.n_state]).unwrap();
    assert!(first_loss.is_finite());
    // near-uniform at init: ln(vocab) ± 0.5
    let uniform = (m.preset.vocab as f32).ln();
    assert!(
        (first_loss - uniform).abs() < 0.7,
        "init loss {first_loss} vs ln(V)={uniform}"
    );

    let mut state: Vec<xla::PjRtBuffer> = out;
    let mut last_loss = first_loss;
    for i in 1..8 {
        let step_lit = cola::runtime::executor::to_device(&lit_f32(i as f32)).unwrap();
        let tok_buf = cola::runtime::executor::to_device(&tok_lit).unwrap();
        let mut refs: Vec<&xla::PjRtBuffer> = state[..m.n_state].iter().collect();
        refs.push(&step_lit);
        refs.push(&tok_buf);
        let out = step.run_b(&refs).unwrap();
        last_loss = cola::runtime::executor::buf_f32(&out[m.n_state]).unwrap();
        state = out;
    }
    assert!(
        last_loss < first_loss - 0.3,
        "no learning: {first_loss} -> {last_loss}"
    );
}

#[test]
fn eval_step_matches_train_loss_scale() {
    let a = art("tiny_cola");
    let m = &a.manifest;
    let eval = a.step("eval_step").unwrap();
    let state0 = a.load_state0().unwrap();

    let mut rng = Rng::new(2);
    let shape = [m.eval_batch, m.preset.seq_len + 1];
    let toks = random_tokens(&mut rng, &shape, m.preset.vocab);
    let lit = lit_i32(&toks, &[shape[0] as i64, shape[1] as i64]).unwrap();

    let mut args: Vec<xla::Literal> = state0.into_iter().take(m.n_params).collect();
    args.push(lit);
    let out = eval.run(&args).unwrap();
    assert_eq!(out.len(), 2);
    let sum = cola::runtime::executor::buf_f32(&out[0]).unwrap();
    let count = cola::runtime::executor::buf_f32(&out[1]).unwrap();
    assert_eq!(count as usize, m.eval_batch * m.preset.seq_len);
    let mean = sum / count;
    let uniform = (m.preset.vocab as f32).ln();
    assert!((mean - uniform).abs() < 0.7, "eval mean {mean}");
}

#[test]
fn activations_tap_shapes() {
    let a = art("tiny_cola");
    let m = &a.manifest;
    let acts = a.step("activations").unwrap();
    let state0 = a.load_state0().unwrap();

    let mut rng = Rng::new(3);
    let shape = [2usize, m.preset.seq_len + 1];
    let toks = random_tokens(&mut rng, &shape, m.preset.vocab);
    let lit = lit_i32(&toks, &[2, shape[1] as i64]).unwrap();

    let mut args: Vec<xla::Literal> = state0.into_iter().take(m.n_params).collect();
    args.push(lit);
    let out = acts.run(&args).unwrap();
    // one tap per layer + final
    assert_eq!(out.len(), m.preset.n_layers + 1);
    let v = cola::runtime::executor::buf_f32_vec(&out[0]).unwrap();
    assert_eq!(v.len(), 2 * m.preset.seq_len * m.preset.d);
    assert!(v.iter().all(|x| x.is_finite()));
}

#[test]
fn full_and_gcp_agree() {
    // vanilla GCP is a memory strategy: same math as full-rank.
    let af = art("tiny_full");
    let ag = art("tiny_gcp");
    let mf = &af.manifest;

    let mut rng = Rng::new(4);
    let toks = random_tokens(&mut rng, &mf.tokens_shape, mf.preset.vocab);
    let dims: Vec<i64> = mf.tokens_shape.iter().map(|&x| x as i64).collect();
    let lit = lit_i32(&toks, &dims).unwrap();

    let mut loss = Vec::new();
    for a in [&af, &ag] {
        let step = a.step("train_step").unwrap();
        let mut args = a.load_state0().unwrap();
        args.push(lit_f32(0.0));
        args.push(lit.clone());
        let out = step.run(&args).unwrap();
        loss.push(cola::runtime::executor::buf_f32(&out[a.manifest.n_state]).unwrap());
    }
    assert!(
        (loss[0] - loss[1]).abs() < 1e-4,
        "full {} vs gcp {}",
        loss[0],
        loss[1]
    );
}

#[test]
fn galore_refresh_proj_is_loadable() {
    let a = art("tiny_galore");
    let m = &a.manifest;
    assert!(a.has_step("refresh_proj"));
    let refresh = a.step("refresh_proj").unwrap();
    let state0 = a.load_state0().unwrap();
    let mut args: Vec<xla::Literal> = state0;
    args.push(xla::Literal::scalar(7i32));
    let out = refresh.run(&args).unwrap();
    assert_eq!(out.len(), m.n_state);
}

#[test]
fn manifest_validation_catches_corruption() {
    let a = art("tiny_cola");
    let mut m = a.manifest.clone();
    m.n_state += 1;
    assert!(m.validate().is_err());
}
