//! Multi-producer stress tests for the bounded admission queue: QueueFull
//! under contention, FIFO-within-band fairness across concurrent producers,
//! and close/drain conservation while producers and consumers race.
//!
//! Hermetic: the queue is plain synchronisation, no artifact or PJRT.

use cola::serve::queue::PushError;
use cola::serve::BoundedQueue;
use std::collections::HashSet;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

/// (producer id, per-producer sequence number)
type Item = (usize, usize);

#[test]
fn concurrent_producers_hit_queue_full_and_keep_per_producer_fifo() {
    const PRODUCERS: usize = 3;
    const PER_PRODUCER: usize = 200;
    let q: Arc<BoundedQueue<Item>> = Arc::new(BoundedQueue::new(4));
    let rejections = Arc::new(AtomicU64::new(0));

    let mut handles = Vec::new();
    for p in 0..PRODUCERS {
        let q = q.clone();
        let rejections = rejections.clone();
        handles.push(thread::spawn(move || {
            for seq in 0..PER_PRODUCER {
                let mut item = (p, seq);
                loop {
                    match q.push(item, false) {
                        Ok(()) => break,
                        Err(PushError::Full(back)) => {
                            rejections.fetch_add(1, Ordering::Relaxed);
                            item = back;
                            thread::yield_now();
                        }
                        Err(PushError::Closed(_)) => {
                            panic!("queue closed mid-test")
                        }
                    }
                }
            }
        }));
    }

    // One consumer, slow to start so the tiny queue is guaranteed to fill
    // while producers hammer it.
    thread::sleep(Duration::from_millis(20));
    let mut popped: Vec<Item> = Vec::new();
    let expect = PRODUCERS * PER_PRODUCER;
    while popped.len() < expect {
        if let Some(it) = q.pop_blocking() {
            popped.push(it);
        } else {
            panic!("queue closed before draining");
        }
    }
    for h in handles {
        h.join().unwrap();
    }

    assert!(
        rejections.load(Ordering::Relaxed) > 0,
        "a depth-4 queue under 3 fast producers must exert backpressure"
    );
    // conservation: every item exactly once
    let unique: HashSet<Item> = popped.iter().copied().collect();
    assert_eq!(unique.len(), expect, "no duplicates, no losses");
    // FIFO within the band: each producer's sequence numbers pop in order
    // (retries re-push the same item, never reorder a producer's stream)
    for p in 0..PRODUCERS {
        let seqs: Vec<usize> = popped.iter().filter(|(pp, _)| *pp == p).map(|&(_, s)| s).collect();
        assert_eq!(seqs.len(), PER_PRODUCER);
        assert!(seqs.windows(2).all(|w| w[0] < w[1]), "producer {p} popped out of order");
    }
}

#[test]
fn high_band_drains_first_even_after_contended_interleaved_pushes() {
    const PRODUCERS: usize = 4;
    const PER_PRODUCER: usize = 50;
    // capacity fits everything: this test is about band ordering, not Full
    let q: Arc<BoundedQueue<Item>> = Arc::new(BoundedQueue::new(PRODUCERS * PER_PRODUCER));
    let mut handles = Vec::new();
    for p in 0..PRODUCERS {
        let q = q.clone();
        // producers 0/1 submit High, 2/3 Normal, racing each other
        handles.push(thread::spawn(move || {
            for seq in 0..PER_PRODUCER {
                q.push((p, seq), p < 2).unwrap();
                if seq % 16 == 0 {
                    thread::yield_now();
                }
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let mut order = Vec::new();
    while let Some(it) = q.try_pop() {
        order.push(it);
    }
    assert_eq!(order.len(), PRODUCERS * PER_PRODUCER);
    let first_normal = order.iter().position(|&(p, _)| p >= 2).unwrap();
    assert!(
        order[..first_normal].iter().all(|&(p, _)| p < 2)
            && order[first_normal..].iter().all(|&(p, _)| p >= 2),
        "every High item pops before any Normal item"
    );
    // FIFO within each band, per producer
    for p in 0..PRODUCERS {
        let seqs: Vec<usize> = order.iter().filter(|(pp, _)| *pp == p).map(|&(_, s)| s).collect();
        assert!(seqs.windows(2).all(|w| w[0] < w[1]), "producer {p} reordered");
    }
}

#[test]
fn close_under_contention_conserves_every_item_exactly_once() {
    const PRODUCERS: usize = 4;
    const PER_PRODUCER: usize = 300;
    let q: Arc<BoundedQueue<Item>> = Arc::new(BoundedQueue::new(8));

    // consumers drain until close
    let mut consumers = Vec::new();
    for _ in 0..2 {
        let q = q.clone();
        consumers.push(thread::spawn(move || {
            let mut got = Vec::new();
            while let Some(it) = q.pop_blocking() {
                got.push(it);
            }
            got
        }));
    }
    // producers retry on Full, stop on Closed and report what never entered
    let mut producers = Vec::new();
    for p in 0..PRODUCERS {
        let q = q.clone();
        producers.push(thread::spawn(move || {
            let mut refused = Vec::new();
            'outer: for seq in 0..PER_PRODUCER {
                let mut item = (p, seq);
                loop {
                    match q.push(item, seq % 5 == 0) {
                        Ok(()) => break,
                        Err(PushError::Full(back)) => {
                            item = back;
                            thread::yield_now();
                        }
                        Err(PushError::Closed(back)) => {
                            refused.push(back);
                            // everything after this is refused too
                            refused.extend(((back.1 + 1)..PER_PRODUCER).map(|s| (p, s)));
                            break 'outer;
                        }
                    }
                }
            }
            refused
        }));
    }

    // let the race develop, then slam the door
    thread::sleep(Duration::from_millis(15));
    let leftover = q.close();

    let mut seen: Vec<Item> = leftover;
    for c in consumers {
        seen.extend(c.join().unwrap());
    }
    let mut refused_total = 0usize;
    for p in producers {
        let refused = p.join().unwrap();
        refused_total += refused.len();
        seen.extend(refused);
    }
    // Consumers may park AFTER close drained the leftovers — but every item
    // a producer successfully pushed must surface exactly once somewhere.
    let unique: HashSet<Item> = seen.iter().copied().collect();
    assert_eq!(unique.len(), seen.len(), "an item surfaced twice");
    assert_eq!(
        seen.len(),
        PRODUCERS * PER_PRODUCER,
        "popped + leftover + refused covers every item exactly once \
         (refused {refused_total})"
    );
    // and the queue stays closed
    assert!(matches!(q.push((0, 0), false), Err(PushError::Closed(_))));
}

#[test]
fn cancel_raised_while_consumer_is_parked_is_discarded_on_wake() {
    // Deterministic cancel-during-blocked-pop: the consumer parks on an
    // empty queue, the cancel flag of a not-yet-pushed request is raised
    // while it is parked, and both that request and a live one are then
    // pushed. Whatever order the consumer wakes in, it must serve exactly
    // the live request, discard the cancelled one (the engine's sweep
    // semantics), and park again until close wakes it with `None`.
    use cola::serve::sync::Flag;
    let q: Arc<BoundedQueue<(usize, Arc<Flag>)>> = Arc::new(BoundedQueue::new(4));
    let consumer = {
        let q = q.clone();
        thread::spawn(move || {
            let (mut served, mut discarded) = (Vec::new(), Vec::new());
            while let Some((id, cancel)) = q.pop_blocking() {
                if cancel.poll() {
                    discarded.push(id);
                } else {
                    served.push(id);
                }
            }
            (served, discarded)
        })
    };
    // let the consumer reach pop_blocking and park
    thread::sleep(Duration::from_millis(10));
    let dead = Arc::new(Flag::new());
    let live = Arc::new(Flag::new());
    dead.set(); // cancelled while the consumer is parked
    q.push((1, dead), false).unwrap();
    q.push((2, live), true).unwrap();
    // drain both, then unblock the final parked pop
    while !q.is_empty() {
        thread::sleep(Duration::from_millis(1));
    }
    let leftover = q.close();
    assert!(leftover.is_empty(), "the consumer drained everything");
    let (served, discarded) = consumer.join().unwrap();
    assert_eq!(served, vec![2], "only the live request is served");
    assert_eq!(discarded, vec![1], "the cancelled request is dropped, not decoded");
}
