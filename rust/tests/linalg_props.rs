//! Property tests for the SVD/effective-rank substrate (in-tree harness —
//! proptest is not in the offline vendor set; randomized cases are seeded
//! and exhaustively checked against algebraic invariants).

use cola::linalg::{effective_rank, singular_values, spectrum_energy, Mat};
use cola::util::rng::Rng;

fn random_mat(rng: &mut Rng, n: usize, c: usize, scale: f64) -> Mat {
    Mat::from_rows(n, c, (0..n * c).map(|_| rng.normal() * scale).collect())
}

/// Property: Σσᵢ² == ‖A‖_F² (Frobenius identity) across 40 random shapes.
#[test]
fn prop_frobenius_identity() {
    let mut rng = Rng::new(101);
    for case in 0..40 {
        let n = rng.range(1, 120);
        let c = rng.range(1, 40);
        let m = random_mat(&mut rng, n, c, 1.0 + (case % 5) as f64);
        let sv = singular_values(&m);
        let fro = m.frobenius_sq();
        let sum: f64 = sv.iter().map(|s| s * s).sum();
        assert!(
            (sum - fro).abs() <= 1e-8 * fro.max(1.0),
            "case {case} ({n}x{c}): {sum} vs {fro}"
        );
    }
}

/// Property: singular values are non-negative and sorted descending.
#[test]
fn prop_sorted_nonnegative() {
    let mut rng = Rng::new(102);
    for _ in 0..30 {
        let (n, c) = (rng.range(2, 60), rng.range(2, 30));
        let m = random_mat(&mut rng, n, c, 2.0);
        let sv = singular_values(&m);
        assert!(sv.iter().all(|&s| s >= 0.0));
        assert!(sv.windows(2).all(|w| w[0] >= w[1] - 1e-12));
    }
}

/// Property: scaling A by k scales every σ by |k|.
#[test]
fn prop_scaling_equivariance() {
    let mut rng = Rng::new(103);
    for _ in 0..20 {
        let (n, c) = (rng.range(3, 50), rng.range(2, 20));
        let m = random_mat(&mut rng, n, c, 1.0);
        let k = 0.1 + rng.f64() * 5.0;
        let scaled = Mat::from_rows(m.rows, m.cols, m.data.iter().map(|x| x * k).collect());
        let s1 = singular_values(&m);
        let s2 = singular_values(&scaled);
        for (a, b) in s1.iter().zip(&s2) {
            assert!((a * k - b).abs() < 1e-8 * (1.0 + b), "{a} * {k} != {b}");
        }
    }
}

/// Property: appending duplicate rows cannot increase the number of nonzero
/// singular values (rank is invariant under row duplication).
#[test]
fn prop_rank_invariant_row_dup() {
    let mut rng = Rng::new(104);
    for _ in 0..15 {
        let n = rng.range(4, 30);
        let c = rng.range(2, 12);
        let m = random_mat(&mut rng, n, c, 1.0);
        let mut dup_data = m.data.clone();
        dup_data.extend_from_slice(&m.data[..c]); // duplicate row 0
        let dup = Mat::from_rows(n + 1, c, dup_data);
        // numeric-rank threshold: zero eigenvalues of the Gram matrix come
        // out around 1e-8·σ₀² after Jacobi roundoff, so count σ > 1e-6·σ₀.
        let nz = |sv: &[f64]| sv.iter().filter(|&&s| s > 1e-6 * sv[0].max(1e-300)).count();
        assert_eq!(nz(&singular_values(&m)), nz(&singular_values(&dup)));
    }
}

/// Property: planting a rank-k structure bounds r(α) by ~k under low noise.
#[test]
fn prop_effective_rank_detects_planted_rank() {
    let mut rng = Rng::new(105);
    for k in [1usize, 2, 4, 8] {
        let (n, c) = (300, 32);
        let u: Vec<f64> = (0..n * k).map(|_| rng.normal()).collect();
        let v: Vec<f64> = (0..k * c).map(|_| rng.normal()).collect();
        let mut m = Mat::zeros(n, c);
        for i in 0..n {
            for j in 0..c {
                let mut s = 0.0;
                for l in 0..k {
                    s += u[i * k + l] * v[l * c + j];
                }
                *m.at_mut(i, j) = s + 1e-3 * rng.normal();
            }
        }
        let sv = singular_values(&m);
        let r = effective_rank(&sv, 0.95);
        assert!(r <= k + 1, "planted rank {k}, detected {r}");
    }
}

/// Property: energy curve is a CDF (monotone, ends at 1), and r(α) is its
/// generalized inverse.
#[test]
fn prop_energy_curve_vs_effective_rank() {
    let mut rng = Rng::new(106);
    for _ in 0..20 {
        let (n, c) = (rng.range(5, 80), rng.range(2, 25));
        let m = random_mat(&mut rng, n, c, 1.5);
        let sv = singular_values(&m);
        let e = spectrum_energy(&sv);
        assert!(e.windows(2).all(|w| w[1] >= w[0] - 1e-12));
        assert!((e.last().unwrap() - 1.0).abs() < 1e-9);
        for alpha in [0.5, 0.9, 0.99] {
            let r = effective_rank(&sv, alpha);
            assert!(e[r - 1] >= alpha - 1e-12);
            if r > 1 {
                assert!(e[r - 2] < alpha);
            }
        }
    }
}

/// Property: orthogonal-ish column rotation preserves the spectrum (tested
/// via permutations, which are exactly orthogonal).
#[test]
fn prop_column_permutation_invariance() {
    let mut rng = Rng::new(107);
    for _ in 0..15 {
        let (n, c) = (rng.range(4, 40), rng.range(2, 15));
        let m = random_mat(&mut rng, n, c, 1.0);
        let mut perm: Vec<usize> = (0..c).collect();
        rng.shuffle(&mut perm);
        let mut p = Mat::zeros(n, c);
        for i in 0..n {
            for (j2, &j) in perm.iter().enumerate() {
                *p.at_mut(i, j2) = m.at(i, j);
            }
        }
        let s1 = singular_values(&m);
        let s2 = singular_values(&p);
        for (a, b) in s1.iter().zip(&s2) {
            assert!((a - b).abs() < 1e-8 * (1.0 + a));
        }
    }
}
