//! Property tests for the KV snapshot codecs (`serve::kvcodec`): seeded
//! random planes across shapes and ranks, checking each codec's documented
//! error contract plus exact byte accounting.
//!
//! - `F32` round-trips byte-identically (bit-level, including negative
//!   zero and subnormals).
//! - `F16` reconstructs every finite value within half an f16 ulp (the
//!   round-to-nearest-even bound), and payloads that are f16-exact
//!   round-trip bit-identically.
//! - `RankR` reconstructs each plane with max-abs error bounded by the
//!   truncated spectral tail √(Σ_{i>r} σᵢ²) — the Eckart–Young Frobenius
//!   bound, which dominates the per-entry error — and is *exact* (to float
//!   tolerance) on planes whose true rank is ≤ r.
//! - For every codec, `encoded_bytes()` equals the serialized size and
//!   serialize → deserialize is the identity.

use cola::linalg::{singular_values, Mat};
use cola::serve::kvcodec::{
    encode_row, f16_to_f32, f32_row_bytes, f32_to_f16, EncodedKvRow, KvCodec, PlaneGeom,
};
use cola::serve::KvRowState;
use cola::util::rng::Rng;

/// Shapes swept by every property: (layers, rows, cols).
const SHAPES: [(usize, usize, usize); 4] = [(1, 4, 6), (1, 8, 16), (2, 5, 3), (3, 7, 7)];

/// A random full-spectrum plane set for `geom`, values in roughly [-4, 4].
fn random_row(rng: &mut Rng, geom: PlaneGeom) -> KvRowState {
    let n = geom.elems();
    let mk = |rng: &mut Rng| (0..n).map(|_| (rng.f64() * 8.0 - 4.0) as f32).collect::<Vec<f32>>();
    KvRowState { k: mk(rng), v: mk(rng) }
}

/// A plane set of exact rank ≤ `r` per (layer) plane: sum of r outer
/// products with random factors.
fn low_rank_row(rng: &mut Rng, geom: PlaneGeom, r: usize) -> KvRowState {
    let mk = |rng: &mut Rng| {
        let mut data = vec![0.0f32; geom.elems()];
        for plane in data.chunks_mut(geom.rows * geom.cols) {
            for _ in 0..r {
                let u: Vec<f64> = (0..geom.rows).map(|_| rng.normal()).collect();
                let w: Vec<f64> = (0..geom.cols).map(|_| rng.normal()).collect();
                for i in 0..geom.rows {
                    for j in 0..geom.cols {
                        plane[i * geom.cols + j] += (u[i] * w[j]) as f32;
                    }
                }
            }
        }
        data
    };
    KvRowState { k: mk(rng), v: mk(rng) }
}

fn decode(enc: &EncodedKvRow) -> KvRowState {
    let mut out = KvRowState::default();
    enc.decode_into(&mut out);
    out
}

/// Serialized size must match `encoded_bytes()` exactly, and the serialized
/// form must deserialize back to the same encoding.
fn assert_bytes_exact(enc: &EncodedKvRow) {
    let buf = enc.serialize();
    assert_eq!(
        buf.len() as u64,
        enc.encoded_bytes(),
        "encoded_bytes must equal the serialized size"
    );
    let back = EncodedKvRow::deserialize(&buf).expect("round-trip deserialize");
    assert_eq!(&back, enc, "serialize → deserialize must be the identity");
}

#[test]
fn f32_codec_is_byte_identical_on_random_planes() {
    let mut rng = Rng::new(0xF32_001);
    for (layers, rows, cols) in SHAPES {
        let geom = PlaneGeom { layers, rows, cols };
        for _ in 0..8 {
            let kv = random_row(&mut rng, geom);
            let enc = encode_row(&kv, KvCodec::F32, geom).unwrap();
            let dec = decode(&enc);
            // bit-level identity, not just PartialEq (which is fine for
            // NaN-free data but weaker in principle)
            let bits = |xs: &[f32]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&dec.k), bits(&kv.k));
            assert_eq!(bits(&dec.v), bits(&kv.v));
            assert_eq!(enc.encoded_bytes(), f32_row_bytes(&kv), "f32 saves nothing");
            assert_bytes_exact(&enc);
        }
    }
}

#[test]
fn f16_codec_is_within_half_ulp_on_random_planes() {
    let mut rng = Rng::new(0xF16_002);
    for (layers, rows, cols) in SHAPES {
        let geom = PlaneGeom { layers, rows, cols };
        for _ in 0..8 {
            let kv = random_row(&mut rng, geom);
            let enc = encode_row(&kv, KvCodec::F16, geom).unwrap();
            let dec = decode(&enc);
            for (orig, got) in kv.k.iter().chain(&kv.v).zip(dec.k.iter().chain(&dec.v)) {
                // RNE error bound: half the spacing of f16 at this magnitude.
                // Values here are in [-4, 4], normal f16 range, so the ulp is
                // 2^(floor(log2 |x|) - 10); use the next power of two above
                // |x| for a safe (slightly loose at exact powers) bound.
                let ulp = (orig.abs().max(f16_min_normal()) * 2.0) / 1024.0;
                assert!(
                    (orig - got).abs() <= 0.5 * ulp + f32::EPSILON,
                    "f16 error above half ulp: {orig} -> {got}"
                );
            }
            assert!(
                enc.encoded_bytes() < f32_row_bytes(&kv),
                "f16 must compress the f32 baseline"
            );
            assert_bytes_exact(&enc);
        }
    }
}

fn f16_min_normal() -> f32 {
    1.0 / 16384.0 // 2^-14
}

#[test]
fn f16_exact_payloads_round_trip_bit_identically() {
    // Small integers are exactly representable in f16, so the codec must
    // reproduce them bit-for-bit (the mock backend's token planes rely on
    // this for the cache-on/off byte-identity gate).
    let geom = PlaneGeom { layers: 1, rows: 2, cols: 4 };
    let vals: Vec<f32> = vec![0.0, 1.0, -1.0, 255.0, 256.0, 2048.0, -2048.0, 0.5];
    let kv = KvRowState { k: vals.clone(), v: vals.iter().map(|x| -x).collect() };
    let enc = encode_row(&kv, KvCodec::F16, geom).unwrap();
    let dec = decode(&enc);
    assert_eq!(dec, kv, "f16-exact payload must survive bit-identically");
    // and the scalar conversions agree with a brute-force nearest search
    let mut rng = Rng::new(0xF16_003);
    for _ in 0..2000 {
        let x = (rng.f64() * 8.0 - 4.0) as f32;
        let h = f32_to_f16(x);
        let y = f16_to_f32(h);
        let d = (x - y).abs();
        // y must be at least as close to x as its same-sign f16 neighbours
        for nb in [h.wrapping_add(1), h.wrapping_sub(1)] {
            if nb & 0x8000 != h & 0x8000 {
                continue; // crossed the sign boundary in bit order
            }
            let z = f16_to_f32(nb);
            if !z.is_finite() {
                continue;
            }
            assert!(
                d <= (x - z).abs() + f32::EPSILON,
                "{x} encoded to {h:#06x} ({y}) but neighbour {z} is closer"
            );
        }
    }
}

/// Max-abs reconstruction error of `enc` against `kv`.
fn max_abs_err(kv: &KvRowState, dec: &KvRowState) -> f64 {
    kv.k.iter()
        .chain(&kv.v)
        .zip(dec.k.iter().chain(&dec.v))
        .map(|(a, b)| (a - b).abs() as f64)
        .fold(0.0, f64::max)
}

/// √(Σ_{i>r} σᵢ²) maximised over the planes of both the k and v payloads —
/// the Eckart–Young Frobenius norm of the optimal rank-r residual, which
/// upper-bounds every entry of the actual residual.
fn spectral_tail(kv: &KvRowState, geom: PlaneGeom, r: usize) -> f64 {
    let mut worst = 0.0f64;
    for data in [&kv.k, &kv.v] {
        for plane in data.chunks(geom.rows * geom.cols) {
            let m = Mat::from_f32(geom.rows, geom.cols, plane);
            let sv = singular_values(&m);
            let tail: f64 = sv.iter().skip(r).map(|s| s * s).sum();
            worst = worst.max(tail.sqrt());
        }
    }
    worst
}

#[test]
fn rankr_error_is_bounded_by_the_spectral_tail() {
    let mut rng = Rng::new(0x9A_4C);
    for (layers, rows, cols) in SHAPES {
        let geom = PlaneGeom { layers, rows, cols };
        for r in 1..=rows.min(cols) {
            let kv = random_row(&mut rng, geom);
            let enc = encode_row(&kv, KvCodec::RankR { rank: r }, geom).unwrap();
            let dec = decode(&enc);
            let bound = spectral_tail(&kv, geom, r);
            let err = max_abs_err(&kv, &dec);
            // f32 factor storage adds rounding on top of the exact bound
            let slack = 1e-4 * (1.0 + bound);
            assert!(
                err <= bound + slack,
                "rank-{r} {rows}x{cols}: max abs {err} above spectral tail {bound}"
            );
            assert_bytes_exact(&enc);
        }
    }
}

#[test]
fn rankr_is_exact_on_low_rank_planes_and_compresses() {
    let mut rng = Rng::new(0x10_44);
    for (layers, rows, cols) in SHAPES {
        let geom = PlaneGeom { layers, rows, cols };
        let true_rank = 2.min(rows).min(cols);
        let kv = low_rank_row(&mut rng, geom, true_rank);
        for r in true_rank..=rows.min(cols) {
            let enc = encode_row(&kv, KvCodec::RankR { rank: r }, geom).unwrap();
            let err = max_abs_err(&kv, &decode(&enc));
            assert!(
                err <= 1e-4,
                "rank-{r} must be exact on rank-{true_rank} {rows}x{cols} planes, got {err}"
            );
        }
        // and at a compressing rank the bytes actually shrink for shapes
        // where r(rows + cols) < rows * cols
        if true_rank * (rows + cols) < rows * cols {
            let enc = encode_row(&kv, KvCodec::RankR { rank: true_rank }, geom).unwrap();
            assert!(
                enc.encoded_bytes() < f32_row_bytes(&kv),
                "rank-{true_rank} must compress {rows}x{cols}"
            );
        }
    }
}

#[test]
fn encoded_bytes_formula_matches_across_codecs_and_shapes() {
    let mut rng = Rng::new(0xBE_7E5);
    for (layers, rows, cols) in SHAPES {
        let geom = PlaneGeom { layers, rows, cols };
        let n = geom.elems() as u64;
        let kv = random_row(&mut rng, geom);
        for (codec, want_plane) in [
            (KvCodec::F32, 5 + 4 * n),
            (KvCodec::F16, 5 + 2 * n),
            (
                KvCodec::RankR { rank: 2 },
                17 + 4 * (layers as u64) * 2 * (rows as u64 + cols as u64),
            ),
        ] {
            let enc = encode_row(&kv, codec, geom).unwrap();
            assert_eq!(enc.encoded_bytes(), 2 * want_plane, "codec {codec:?} on {geom:?}");
            assert_bytes_exact(&enc);
        }
    }
}
