//! Exhaustive interleaving checks of the serving primitives against their
//! pure reference models (`cola::serve::model`).
//!
//! The explorer enumerates *every* schedulable interleaving of small
//! per-thread op sequences and replays each one on the real type, comparing
//! observations step by step with the model — see the module docs of
//! `serve::model` for why mutex-serialisation makes this a full
//! linearizability check rather than a sampling stress test.
//!
//! Alongside the real types, deliberately-broken SUT wrappers pin the
//! *minimal counterexamples* the explorer found for three injected bugs
//! (a band-confusion `try_pop_high`, a no-promotion LRU, and a circuit
//! breaker that forgets to gate while a probe is in flight) — failing-seed
//! regressions proving the checker detects real divergences, not just
//! agreeing with everything.

use cola::serve::kvcache::hash_tokens;
use cola::serve::model::{
    check_cache_sequences, check_cache_sequences_budgeted, explore_breaker, explore_queue,
    model_row_bytes, BreakerObs, BreakerOp, BreakerSut, CacheDivergence, CacheModel, CacheObs,
    CacheOp, CacheSut, Divergence, QueueModel, QueueObs, QueueOp, QueueSut,
};
use cola::serve::{
    BoundedQueue, BreakerSnapshot, BreakerState, CircuitBreaker, KvCodec, KvPrefixCache,
    PlaneGeom,
};
use std::time::Duration;

/// n! / (k1! k2! ... ) — the number of distinct merges of the per-thread
/// sequences, used to prove the explorer's enumeration is exhaustive.
fn multinomial(lens: &[usize]) -> usize {
    let n: usize = lens.iter().sum();
    let mut num = 1usize;
    for k in 2..=n {
        num *= k;
    }
    for &l in lens {
        for k in 2..=l {
            num /= k;
        }
    }
    num
}

// ---------------------------------------------------------------------------
// Queue: the real BoundedQueue is linearizable w.r.t. the model
// ---------------------------------------------------------------------------

#[test]
fn queue_nonblocking_ops_exhaustive_three_threads() {
    // Non-blocking ops only → every merge is schedulable, so the schedule
    // count must equal the multinomial exactly: enumeration is exhaustive.
    let threads = vec![
        vec![QueueOp::Push(1, false), QueueOp::Push(2, true)],
        vec![QueueOp::TryPop, QueueOp::TryPopHigh],
        vec![QueueOp::Push(3, false), QueueOp::Close],
    ];
    let report = explore_queue(2, &threads, &|| BoundedQueue::new(2));
    assert_eq!(report.schedules, multinomial(&[2, 2, 2]), "6!/(2!2!2!) = 90 merges");
    assert_eq!(report.deadlocks, 0);
    assert!(report.divergence.is_none(), "divergence: {:?}", report.divergence);
}

#[test]
fn queue_capacity_and_close_edges_exhaustive() {
    // Capacity 1 forces Full observations; Close races against both.
    let threads = vec![
        vec![QueueOp::Push(10, false), QueueOp::Push(11, false)],
        vec![QueueOp::Close, QueueOp::TryPop],
        vec![QueueOp::Push(12, true)],
    ];
    let report = explore_queue(1, &threads, &|| BoundedQueue::new(1));
    assert_eq!(report.schedules, multinomial(&[2, 2, 1]), "5!/(2!2!1!) = 30 merges");
    assert!(report.divergence.is_none(), "divergence: {:?}", report.divergence);
}

#[test]
fn queue_blocking_pop_linearises_or_deadlocks_exactly() {
    // A consumer of two blocking pops against one producer + closer.
    let threads = vec![
        vec![QueueOp::PopBlocking, QueueOp::PopBlocking],
        vec![QueueOp::Push(7, false), QueueOp::Close],
    ];
    let report = explore_queue(2, &threads, &|| BoundedQueue::new(2));
    // PopBlocking is gated on (non-empty || closed), so fewer than the
    // unconstrained 4!/(2!2!) = 6 merges complete; the rest are pruned at
    // the gate, never deadlocked (Close always eventually runs).
    assert!(report.schedules > 0 && report.schedules < 6, "got {}", report.schedules);
    assert_eq!(report.deadlocks, 0);
    assert!(report.divergence.is_none(), "divergence: {:?}", report.divergence);
}

#[test]
fn queue_try_pop_high_after_close_is_empty_everywhere() {
    // Satellite edge: after Close drains the queue, try_pop_high must
    // observe Empty in every interleaving — checked exhaustively rather
    // than as one hand-picked ordering.
    let threads = vec![
        vec![QueueOp::Push(1, true), QueueOp::Close],
        vec![QueueOp::TryPopHigh, QueueOp::TryPopHigh],
    ];
    let report = explore_queue(4, &threads, &|| BoundedQueue::new(4));
    assert_eq!(report.schedules, multinomial(&[2, 2]));
    assert!(report.divergence.is_none(), "divergence: {:?}", report.divergence);
    // and the directed sequential case, for a readable failure mode:
    let q = BoundedQueue::new(4);
    q.push(1, true).unwrap();
    assert_eq!(q.close(), vec![1], "close hands the high item back");
    assert_eq!(q.try_pop_high(), None, "nothing is poppable after close drained");
    assert_eq!(q.try_pop(), None);
}

// ---------------------------------------------------------------------------
// Queue: failing-seed regression — a buggy SUT must be caught
// ---------------------------------------------------------------------------

/// Bug injection: `try_pop_high` falls through to the normal band (the exact
/// confusion `BoundedQueue::try_pop_high`'s doc warns against).
struct BandConfusedQueue(BoundedQueue<i32>);

impl QueueSut for BandConfusedQueue {
    fn apply(&self, op: QueueOp) -> QueueObs {
        match op {
            QueueOp::TryPopHigh => {
                self.0.try_pop().map_or(QueueObs::Empty, QueueObs::Item)
            }
            other => self.0.apply(other),
        }
    }
}

#[test]
fn explorer_catches_band_confused_try_pop_high() {
    let threads = vec![
        vec![QueueOp::Push(5, false)],
        vec![QueueOp::TryPopHigh],
    ];
    let report =
        explore_queue(2, &threads, &|| BandConfusedQueue(BoundedQueue::new(2)));
    let d: Divergence = report.divergence.expect("the injected bug must be found");
    // Minimal counterexample, pinned: push(5, normal) then try_pop_high.
    assert_eq!(
        d.schedule.iter().map(|&(_, op)| op).collect::<Vec<_>>(),
        vec![QueueOp::Push(5, false), QueueOp::TryPopHigh]
    );
    assert_eq!(d.step, 1);
    assert_eq!(d.expected, QueueObs::Empty, "high band is empty");
    assert_eq!(d.actual, QueueObs::Item(5), "buggy SUT leaked the normal item");
}

// ---------------------------------------------------------------------------
// KV prefix cache: the real KvPrefixCache matches the MRU-list model
// ---------------------------------------------------------------------------

/// Window table shared by the cache checks. `check_cache_sequences` keys the
/// model by index while the real cache keys by FNV hash, so distinctness of
/// the hashes is a precondition — asserted in each test.
fn windows() -> Vec<Vec<i32>> {
    vec![vec![1, 2, 3], vec![4, 5], vec![6], vec![7, 8, 9]]
}

fn assert_collision_free(ws: &[Vec<i32>]) {
    for a in 0..ws.len() {
        for b in (a + 1)..ws.len() {
            assert_ne!(
                hash_tokens(&ws[a]),
                hash_tokens(&ws[b]),
                "window table must be collision-free for the index-keyed model"
            );
        }
    }
}

#[test]
fn kvcache_exhaustive_sequences_match_model() {
    let ws = windows();
    assert_collision_free(&ws);
    // Alphabet: insert/probe over 3 windows with distinct tokens; depth 5
    // over 7 ops = 16807 sequences, each replayed on a fresh cache of
    // capacity 2 so evictions and promotions are constantly exercised.
    let alphabet = vec![
        CacheOp::Insert(0, 100),
        CacheOp::Insert(1, 101),
        CacheOp::Insert(2, 102),
        CacheOp::Insert(0, 200), // refresh with a new token
        CacheOp::Probe(0),
        CacheOp::Probe(1),
        CacheOp::Probe(2),
    ];
    let (checked, div) =
        check_cache_sequences(2, &ws, &alphabet, 5, &|| KvPrefixCache::new(2));
    assert_eq!(checked, 7usize.pow(5), "odometer covered the full 7^5 space");
    assert!(div.is_none(), "divergence: {div:?}");
}

#[test]
fn kvcache_capacity_one_thrash_matches_model() {
    let ws = windows();
    assert_collision_free(&ws);
    let alphabet = vec![
        CacheOp::Insert(0, 10),
        CacheOp::Insert(3, 13),
        CacheOp::Probe(0),
        CacheOp::Probe(3),
    ];
    let (checked, div) =
        check_cache_sequences(1, &ws, &alphabet, 6, &|| KvPrefixCache::new(1));
    assert_eq!(checked, 4usize.pow(6));
    assert!(div.is_none(), "divergence: {div:?}");
}

// ---------------------------------------------------------------------------
// KV cache: failing-seed regression — a broken model must be caught
// ---------------------------------------------------------------------------

/// Bug injection: an LRU that forgets to promote on probe hits (the classic
/// "reads don't refresh recency" cache bug).
struct NoPromoteCache {
    model: CacheModel,
}

impl CacheSut for NoPromoteCache {
    fn apply(&mut self, op: CacheOp, _windows: &[Vec<i32>]) -> CacheObs {
        match op {
            // Probe without promotion: read the answer off a clone, so the
            // recency list is left untouched.
            CacheOp::Probe(_) => self.model.clone().apply(op),
            insert => self.model.apply(insert),
        }
    }

    fn bytes_resident(&self) -> u64 {
        self.model.bytes_resident()
    }
}

#[test]
fn checker_catches_probe_without_promotion() {
    let ws = windows();
    assert_collision_free(&ws);
    // Failing seed, pinned: fill to capacity, probe-hit the LRU entry
    // (promoting it — but not in the buggy cache), insert a third window.
    // Correct semantics evict window 1 (demoted by the promotion); the
    // buggy cache evicts window 0. Windows have distinct encoded sizes, so
    // the wrong victim shows up immediately in the insert's released-bytes
    // observation — one step *before* the probe-of-the-ghost would flip
    // hit/miss.
    let seed = [
        CacheOp::Insert(0, 10),
        CacheOp::Insert(1, 11),
        CacheOp::Probe(0),
        CacheOp::Insert(2, 12),
        CacheOp::Probe(1),
    ];
    let mut model = CacheModel::new(2);
    let mut buggy = NoPromoteCache { model: CacheModel::new(2) };
    let mut first_divergence = None;
    for (step, &op) in seed.iter().enumerate() {
        let expected = model.apply(op);
        let actual = buggy.apply(op, &ws);
        if expected != actual && first_divergence.is_none() {
            first_divergence = Some((step, expected, actual));
        }
    }
    assert_eq!(
        first_divergence,
        Some((
            3,
            CacheObs::Inserted { evicted: 1, released: model_row_bytes(1) },
            CacheObs::Inserted { evicted: 1, released: model_row_bytes(0) },
        )),
        "the eviction's released bytes betray the wrong LRU victim"
    );
    // And the exhaustive driver finds the bug on its own from the same
    // alphabet, without being handed the seed.
    let alphabet = vec![
        CacheOp::Insert(0, 10),
        CacheOp::Insert(1, 11),
        CacheOp::Insert(2, 12),
        CacheOp::Probe(0),
        CacheOp::Probe(1),
    ];
    let (_, div) = check_cache_sequences(2, &ws, &alphabet, 5, &|| NoPromoteCache {
        model: CacheModel::new(2),
    });
    let d: CacheDivergence = div.expect("the injected bug must be found");
    assert!(
        matches!(
            (&d.expected, &d.actual),
            (CacheObs::Hit(_), CacheObs::Miss)
                | (CacheObs::Miss, CacheObs::Hit(_))
                | (CacheObs::Inserted { .. }, CacheObs::Inserted { .. })
        ),
        "divergence must be a hit/miss flip or a wrong-victim eviction, got {:?} vs {:?}",
        d.expected,
        d.actual
    );
}

// ---------------------------------------------------------------------------
// KV cache: byte accounting under exhaustive insert/probe/evict sequences
// ---------------------------------------------------------------------------

/// SUT wrapper that re-derives resident bytes from the observations alone
/// (`bytes_in − bytes_out`), asserting the conservation law against the real
/// cache's own meter after *every* op of *every* exhaustive sequence.
struct LedgerCache {
    inner: KvPrefixCache,
    bytes_in: u64,
    bytes_out: u64,
}

impl CacheSut for LedgerCache {
    fn apply(&mut self, op: CacheOp, windows: &[Vec<i32>]) -> CacheObs {
        let obs = self.inner.apply(op, windows);
        match (op, obs) {
            (CacheOp::Insert(w, _), CacheObs::Inserted { released, .. }) => {
                self.bytes_in += model_row_bytes(w);
                self.bytes_out += released;
            }
            (CacheOp::EvictLru, CacheObs::Evicted(Some(b))) => self.bytes_out += b,
            _ => {}
        }
        assert_eq!(
            self.bytes_in - self.bytes_out,
            self.inner.bytes_resident(),
            "bytes_inserted − bytes_released must equal bytes_resident"
        );
        obs
    }

    fn bytes_resident(&self) -> u64 {
        self.inner.bytes_resident()
    }
}

#[test]
fn kvcache_byte_budget_exhaustive_matches_model() {
    let ws = windows();
    assert_collision_free(&ws);
    // Windows 0..=2 cost 18/26/34 encoded bytes. A 64-byte budget under a
    // slack entry cap (8) makes eviction *byte-driven*: windows 0+1 fit
    // (44 B) but adding window 2 forces evictions an entry cap of 8 would
    // never make — and EvictLru exercises the explicit path. Every step of
    // every sequence also checks the conservation ledger via `LedgerCache`.
    let alphabet = vec![
        CacheOp::Insert(0, 100),
        CacheOp::Insert(1, 101),
        CacheOp::Insert(2, 102),
        CacheOp::Insert(1, 201), // refresh: releases the replaced payload
        CacheOp::Probe(0),
        CacheOp::Probe(2),
        CacheOp::EvictLru,
    ];
    let (checked, div) = check_cache_sequences_budgeted(8, 64, &ws, &alphabet, 5, &|| {
        LedgerCache {
            inner: KvPrefixCache::with_codec(8, 64, KvCodec::F32, PlaneGeom::flat(0)),
            bytes_in: 0,
            bytes_out: 0,
        }
    });
    assert_eq!(checked, 7usize.pow(5), "odometer covered the full 7^5 space");
    assert!(div.is_none(), "divergence: {div:?}");
}

/// Bug injection: a byte ledger that forgets a refresh releases the replaced
/// payload (the double-count the `bytes_released` field exists to prevent).
struct DoubleCountRefreshCache {
    inner: KvPrefixCache,
    ledger: u64,
}

impl CacheSut for DoubleCountRefreshCache {
    fn apply(&mut self, op: CacheOp, windows: &[Vec<i32>]) -> CacheObs {
        let obs = self.inner.apply(op, windows);
        match (op, obs) {
            (CacheOp::Insert(w, _), CacheObs::Inserted { evicted, released }) => {
                self.ledger += model_row_bytes(w);
                // BUG: only subtracts when entries were evicted, so a pure
                // refresh double-counts the window's payload
                if evicted > 0 {
                    self.ledger -= released;
                }
            }
            (CacheOp::EvictLru, CacheObs::Evicted(Some(b))) => self.ledger -= b,
            _ => {}
        }
        obs
    }

    fn bytes_resident(&self) -> u64 {
        self.ledger
    }
}

#[test]
fn budgeted_checker_catches_refresh_double_count() {
    let ws = windows();
    assert_collision_free(&ws);
    let alphabet = vec![
        CacheOp::Insert(0, 100),
        CacheOp::Insert(0, 200), // the refresh the buggy ledger fumbles
        CacheOp::Probe(0),
        CacheOp::EvictLru,
    ];
    let (_, div) = check_cache_sequences_budgeted(4, 0, &ws, &alphabet, 3, &|| {
        DoubleCountRefreshCache { inner: KvPrefixCache::new(4), ledger: 0 }
    });
    let d = div.expect("the ledger bug must be found");
    // Minimal counterexample: insert then refresh (the odometer's very
    // first sequence repeats `Insert(0, 100)`) — the buggy ledger holds two
    // payloads' worth of bytes for one resident entry.
    assert_eq!(d.step, 1, "found past the minimal refresh counterexample: {d:?}");
    assert!(
        matches!(d.sequence[0], CacheOp::Insert(0, _))
            && matches!(d.sequence[1], CacheOp::Insert(0, _)),
        "counterexample must be an insert followed by its refresh: {d:?}"
    );
    assert_eq!(d.expected, CacheObs::Bytes(model_row_bytes(0)));
    assert_eq!(d.actual, CacheObs::Bytes(2 * model_row_bytes(0)));
}

// ---------------------------------------------------------------------------
// Circuit breaker: the real CircuitBreaker matches the transition model
// ---------------------------------------------------------------------------

fn mk_breaker(open_after: u32, recover_after: u32) -> CircuitBreaker {
    // Cooldown is irrelevant under the model: `Admit { cooled }` pins the
    // wall-clock predicate, so every admission path is schedulable.
    CircuitBreaker::new(open_after, recover_after, Duration::ZERO)
}

#[test]
fn breaker_probe_races_success_and_failure_exhaustive() {
    // Two failures trip the breaker open; a probe admit races a success and
    // a denied (still-cooling) admit. All ops non-blocking → the schedule
    // count must equal the multinomial exactly: enumeration is exhaustive.
    let threads = vec![
        vec![BreakerOp::Failure, BreakerOp::Failure],
        vec![BreakerOp::Admit { cooled: true }, BreakerOp::Success],
        vec![BreakerOp::Admit { cooled: false }],
    ];
    let report = explore_breaker(2, 1, &threads, &|| mk_breaker(2, 1));
    assert_eq!(report.schedules, multinomial(&[2, 2, 1]), "5!/(2!2!1!) = 30 merges");
    assert!(report.divergence.is_none(), "divergence: {:?}", report.divergence);
}

#[test]
fn breaker_recovery_streaks_race_failures_exhaustive() {
    // recover_after=2 makes the Degraded → Healthy streak order-sensitive:
    // a failure anywhere inside the success run resets it. Every one of the
    // 10 merges must still linearise against the model.
    let threads = vec![
        vec![BreakerOp::Failure, BreakerOp::Success, BreakerOp::Failure],
        vec![BreakerOp::Success, BreakerOp::Admit { cooled: true }],
    ];
    let report = explore_breaker(1, 2, &threads, &|| mk_breaker(1, 2));
    assert_eq!(report.schedules, multinomial(&[3, 2]));
    assert!(report.divergence.is_none(), "divergence: {:?}", report.divergence);
}

#[test]
fn breaker_disabled_never_transitions_exhaustive() {
    // open_after=0 disables the breaker: every op in every order must
    // observe Healthy and admit, and the final tallies must all be zero.
    let threads = vec![
        vec![BreakerOp::Failure, BreakerOp::Failure],
        vec![BreakerOp::Admit { cooled: true }, BreakerOp::Failure],
    ];
    let report = explore_breaker(0, 1, &threads, &|| mk_breaker(0, 1));
    assert_eq!(report.schedules, multinomial(&[2, 2]));
    assert!(report.divergence.is_none(), "divergence: {:?}", report.divergence);
}

// ---------------------------------------------------------------------------
// Circuit breaker: failing-seed regression — a buggy SUT must be caught
// ---------------------------------------------------------------------------

/// Bug injection: admission forgets the `HalfOpen` gate, so a second
/// request is admitted while the probe is still in flight (the classic
/// thundering-probe bug half-open state exists to prevent).
struct DoubleProbeBreaker(CircuitBreaker);

impl BreakerSut for DoubleProbeBreaker {
    fn apply(&self, op: BreakerOp) -> BreakerObs {
        if let BreakerOp::Admit { cooled } = op {
            if self.0.state() == BreakerState::HalfOpen {
                // BUG: should deny until the probe resolves
                return BreakerObs::Admit { admitted: true, state: BreakerState::HalfOpen };
            }
            let admitted = self.0.admit_with(cooled);
            return BreakerObs::Admit { admitted, state: self.0.state() };
        }
        self.0.apply(op)
    }

    fn snapshot(&self) -> BreakerSnapshot {
        BreakerSut::snapshot(&self.0)
    }
}

#[test]
fn explorer_catches_double_probe_admission() {
    let threads = vec![
        vec![BreakerOp::Failure],
        vec![BreakerOp::Admit { cooled: true }],
        vec![BreakerOp::Admit { cooled: true }],
    ];
    let report = explore_breaker(1, 1, &threads, &|| DoubleProbeBreaker(mk_breaker(1, 1)));
    let d = report.divergence.expect("the injected bug must be found");
    // Minimal counterexample, pinned: trip open, admit the probe, then the
    // second admit must be denied — the buggy SUT lets it through.
    assert_eq!(
        d.schedule.iter().map(|&(_, op)| op).collect::<Vec<_>>(),
        vec![
            BreakerOp::Failure,
            BreakerOp::Admit { cooled: true },
            BreakerOp::Admit { cooled: true },
        ]
    );
    assert_eq!(d.step, 2);
    assert_eq!(d.expected, BreakerObs::Admit { admitted: false, state: BreakerState::HalfOpen });
    assert_eq!(d.actual, BreakerObs::Admit { admitted: true, state: BreakerState::HalfOpen });
}

// ---------------------------------------------------------------------------
// Cross-check: model vs model determinism guard
// ---------------------------------------------------------------------------

#[test]
fn queue_model_is_deterministic_under_replay() {
    // The explorer replays schedules on a *fresh* model; this guards the
    // assumption that QueueModel::apply is a pure function of its state.
    let ops = [
        QueueOp::Push(1, true),
        QueueOp::Push(2, false),
        QueueOp::TryPop,
        QueueOp::Close,
        QueueOp::PopBlocking,
    ];
    let run = || {
        let mut m = QueueModel::new(2);
        ops.iter().map(|&op| m.apply(op)).collect::<Vec<_>>()
    };
    assert_eq!(run(), run());
}
