//! Hermetic prefill-avoidance integration: KV prefix cache + chunked
//! admission over `MockBackend`, zero artifacts.
//!
//! The mock's KV-row seam is deterministic (a row's snapshot is a pure
//! encoding of its window, see `serve::mock`), so these tests can assert
//! the strongest property an inference cache has to offer: **streamed
//! outputs are byte-identical with the cache on and off**, while the
//! `prefill_calls` / `prefills_elided` / `kv_cache_*` counters prove the
//! forward passes were actually avoided. Chunked admission is pinned the
//! same way — deterministic per-step delays turn admission pacing into
//! observable queue-wait gaps in each completion's timing.

use cola::config::ServeConfig;
use cola::serve::{
    FinishReason, InferenceService, KvCodecKind, MockBackend, Priority, ServicePool,
    StreamEvent, SubmitOptions,
};
use std::time::Duration;

fn cfg(workers: usize, queue_depth: usize) -> ServeConfig {
    ServeConfig {
        artifact: "mock".into(),
        max_new_tokens: 8,
        workers,
        queue_depth,
        ..ServeConfig::default()
    }
}

fn opts(max_new: usize) -> SubmitOptions {
    SubmitOptions { max_new_tokens: Some(max_new), ..Default::default() }
}

/// Counters are bumped just *after* the worker streams a request's terminal
/// `Done`, so asserts that follow a `wait()` poll briefly instead of racing
/// that window.
fn eventually(what: &str, mut cond: impl FnMut() -> bool) {
    for _ in 0..1000 {
        if cond() {
            return;
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    panic!("not reached within 1s: {what}");
}

#[test]
fn repeated_prompts_elide_join_prefills_and_rollovers() {
    // max_len 10 with prompt_len 6 → 4 decode positions per prefill, so a
    // 12-token generation crosses 3 join boundaries (1 admission + 2
    // rollovers). The stream is deterministic, so a retry of the same
    // prompt reproduces the same windows — every boundary of requests
    // 2..N must be served from the cache.
    let mock = MockBackend::new(2, 6, 10)
        .vocab(50_000)
        .prefill_delay(Duration::from_millis(2));
    let pool = ServicePool::start_with(cfg(1, 8), mock.clone().factory()).unwrap();
    let prompt: Vec<i32> = vec![11, 12, 13, 14, 15, 16];
    let n = 4;
    for _ in 0..n {
        let c = pool.generate(prompt.clone(), opts(12)).unwrap();
        assert_eq!(c.finish_reason, FinishReason::Length);
        assert_eq!(c.tokens, mock.expected_stream(16, 12), "cached KV must not alter output");
    }
    eventually("all completions tallied", || pool.stats().completed == n as u64);
    let s = pool.stats();
    let boundaries = s.prefill_calls + s.prefills_elided;
    assert_eq!(s.prefill_calls, 3, "only the first request pays real prefills");
    assert_eq!(s.prefills_elided, 3 * (n as u64 - 1), "every retry boundary is elided");
    assert!(
        2 * s.prefills_elided >= boundaries,
        "ISSUE 5 acceptance: >=50% of join prefills avoided ({}/{boundaries})",
        s.prefills_elided
    );
    assert!(s.kv_cache_hits >= s.prefills_elided, "elisions are served by row hits");
    assert_eq!(s.kv_cache_misses, 3, "one cold miss per distinct window");
    assert!(
        s.prefill_nanos >= s.prefill_calls * 1_500_000,
        "real prefills are timed (got {}ns over {} calls)",
        s.prefill_nanos,
        s.prefill_calls
    );
    pool.shutdown();
}

#[test]
fn streams_are_byte_identical_with_cache_on_and_off() {
    // Mixed budgets + concurrent submissions: joins happen with in-flight
    // rows (whose shifted windows can never be cache-served), retries of
    // finished prompts hit, and the outputs must be exactly the streams the
    // cache-disabled pool produces.
    let mock = MockBackend::new(2, 4, 12).vocab(10_000);
    let workload = |kv_cache_entries: usize| -> (Vec<Vec<i32>>, cola::serve::ServiceStats) {
        let mut c = cfg(1, 16);
        c.kv_cache_entries = kv_cache_entries;
        let pool = ServicePool::start_with(c, mock.clone().factory()).unwrap();
        let mut streams = Vec::new();
        for i in 0..8u32 {
            let last = 100 + 10 * (i % 3) as i32; // repeated prefixes
            let max_new = if i % 2 == 0 { 3 } else { 9 };
            streams.push(pool.submit(vec![9, last], opts(max_new)).unwrap());
        }
        let outs: Vec<Vec<i32>> = streams.into_iter().map(|s| s.wait().unwrap().tokens).collect();
        eventually("completions tallied", || pool.stats().completed == 8);
        let stats = pool.stats();
        pool.shutdown();
        (outs, stats)
    };
    let (on, s_on) = workload(64);
    let (off, s_off) = workload(0);
    assert_eq!(on, off, "prefix cache changed streamed outputs");
    for (i, (last, max_new)) in
        (0..8u32).map(|i| (100 + 10 * (i % 3) as i32, if i % 2 == 0 { 3 } else { 9 })).enumerate()
    {
        assert_eq!(on[i], mock.expected_stream(last, max_new), "request {i} exact");
    }
    assert_eq!(s_off.prefills_elided, 0, "disabled cache must never elide");
    assert_eq!(s_off.kv_cache_hits + s_off.kv_cache_misses, 0, "disabled cache never probes");
    assert!(
        s_on.kv_cache_hits + s_on.kv_cache_misses > 0,
        "enabled cache probes at every boundary"
    );
}

#[test]
fn lossy_codecs_preserve_streams_and_save_bytes() {
    // The mock's planes are rank-≤3 with token bytes at f16-exact
    // magnitudes, so both lossy codecs must reproduce every stream the
    // lossless pool produces — while `kv_bytes_saved` proves the resident
    // payloads actually shrank against the f32 baseline.
    let mock = MockBackend::new(2, 6, 10).vocab(20_000).prefill_delay(Duration::from_millis(1));
    let run = |codec: KvCodecKind, rank: usize| -> (Vec<Vec<i32>>, cola::serve::ServiceStats) {
        let mut c = cfg(1, 8);
        c.kv_cache_entries = 64;
        c.kv_codec = codec;
        c.kv_rank = rank;
        let pool = ServicePool::start_with(c, mock.clone().factory()).unwrap();
        let mut outs = Vec::new();
        for round in 0..3 {
            for p in [21, 22, 23] {
                let done = pool.generate(vec![p, p + 1], opts(10)).unwrap();
                assert_eq!(done.finish_reason, FinishReason::Length, "round {round} prompt {p}");
                outs.push(done.tokens);
            }
        }
        eventually("completions tallied", || pool.stats().completed == 9);
        let stats = pool.stats();
        assert!(stats.kv_bytes_resident > 0, "{codec:?}: encoded rows are resident");
        pool.shutdown();
        (outs, stats)
    };
    let (base, s_f32) = run(KvCodecKind::F32, 0);
    for (codec, rank) in [(KvCodecKind::F16, 0), (KvCodecKind::RankR, 3)] {
        let (outs, s) = run(codec, rank);
        assert_eq!(outs, base, "{codec:?} altered streamed outputs");
        assert!(s.prefills_elided > 0, "{codec:?}: retries must still be cache-served");
        assert!(
            s.kv_bytes_saved > 0,
            "{codec:?} must store fewer bytes than the f32 baseline"
        );
        assert!(
            s.kv_bytes_resident < s_f32.kv_bytes_resident,
            "{codec:?}: same rows, smaller residency ({} vs f32's {})",
            s.kv_bytes_resident,
            s_f32.kv_bytes_resident
        );
        assert!(s.kv_decode_nanos > 0, "{codec:?}: cached-row decode is timed");
    }
    assert_eq!(s_f32.kv_bytes_saved, 0, "f32 is the baseline — it saves nothing");
    pool_parity_sanity(&base, &mock);
}

/// The parity baseline itself must match the mock's closed-form streams.
fn pool_parity_sanity(base: &[Vec<i32>], mock: &MockBackend) {
    let mut i = 0;
    for _round in 0..3 {
        for p in [21, 22, 23] {
            assert_eq!(base[i], mock.expected_stream(p + 1, 10), "prompt {p} exact");
            i += 1;
        }
    }
}

#[test]
fn tiny_cache_evicts_and_stays_correct() {
    // Capacity 1 with two alternating prompts: every boundary misses, every
    // insert evicts — the degenerate cache still never corrupts a stream.
    let mock = MockBackend::new(1, 4, 16).vocab(5_000);
    let mut c = cfg(1, 4);
    c.kv_cache_entries = 1;
    let pool = ServicePool::start_with(c, mock.clone().factory()).unwrap();
    for i in 0..6 {
        let p = if i % 2 == 0 { 200 } else { 300 };
        let done = pool.generate(vec![p], opts(4)).unwrap();
        assert_eq!(done.tokens, mock.expected_stream(p, 4));
    }
    eventually("completions tallied", || pool.stats().completed == 6);
    let s = pool.stats();
    assert!(s.kv_cache_evictions >= 4, "alternating prompts thrash a 1-row cache");
    assert_eq!(s.prefills_elided, 0, "nothing survives long enough to be reused");
    assert_eq!(s.prefill_calls, 6);
    pool.shutdown();

    // same traffic, same tiny cache, but a single repeated prompt: the one
    // resident row is exactly what every retry needs
    let pool = {
        let mut c = cfg(1, 4);
        c.kv_cache_entries = 1;
        ServicePool::start_with(c, mock.clone().factory()).unwrap()
    };
    for _ in 0..4 {
        let done = pool.generate(vec![400], opts(4)).unwrap();
        assert_eq!(done.tokens, mock.expected_stream(400, 4));
    }
    eventually("completions tallied", || pool.stats().completed == 4);
    let s = pool.stats();
    assert_eq!(s.prefill_calls, 1);
    assert_eq!(s.prefills_elided, 3);
    pool.shutdown();
}

#[test]
fn join_chunk_paces_normal_admissions_per_decode_step() {
    // Per-row admission leaves no batch prefill to count, so chunk pacing
    // shows up in *queue wait*: join_chunk=1 admits one Normal request per
    // decode step, so of three requests queued behind a live row, the third
    // is admitted two full (step-delayed) decode steps after the first.
    // join_chunk=0 admits the whole burst at the first post-step refill, so
    // their admission times collapse onto one boundary.
    const STEP: Duration = Duration::from_millis(15);
    let run = |join_chunk: usize| -> Vec<Duration> {
        let mock = MockBackend::new(4, 4, 64).vocab(9_000).step_delay(STEP);
        let mut c = cfg(1, 16);
        c.join_chunk = join_chunk;
        c.kv_cache_entries = 0;
        let pool = ServicePool::start_with(c, mock.clone().factory()).unwrap();
        // A occupies a row and keeps decoding while the burst queues behind
        // it (24-token budget ≫ the burst's admission horizon).
        let mut a = pool.submit(vec![50], opts(24)).unwrap();
        assert!(matches!(a.recv(), Some(StreamEvent::Token(_))), "A went live");
        let burst: Vec<_> =
            (1..4).map(|i| pool.submit(vec![50 + 100 * i], opts(4)).unwrap()).collect();
        let mut queued = Vec::new();
        for (i, s) in burst.into_iter().enumerate() {
            let done = s.wait().unwrap();
            assert_eq!(
                done.tokens,
                mock.expected_stream(50 + 100 * (i as i32 + 1), 4),
                "chunked admission must not alter streams"
            );
            queued.push(done.timing.queued);
        }
        a.cancel();
        eventually("A cancelled", || {
            let st = pool.stats();
            st.cancelled + st.completed >= 4
        });
        pool.shutdown();
        queued
    };
    let paced = run(1);
    let merged = run(0);
    assert!(
        paced[2] >= paced[0] + 2 * STEP - Duration::from_millis(5),
        "join_chunk=1 spaces admissions by full decode steps ({paced:?})"
    );
    assert!(
        merged[2] <= merged[0] + STEP,
        "join_chunk=0 admits the queued burst at one boundary ({merged:?})"
    );
}

#[test]
fn shared_system_prefix_is_reused_across_request_lengths() {
    // prompt_len 8 → the engine keys cached rows in chunks of 4: requests
    // that share a 4-token system prefix but continue differently (and have
    // *different total lengths*) splice the cached chunk at import and
    // prefill only their tail. Left-aligned windows put the shared prefix
    // at the same offsets for every length — the property this relies on.
    let mock = MockBackend::new(2, 8, 20).vocab(40_000);
    let sys = [900, 901, 902, 903];
    let prompts: Vec<Vec<i32>> = [vec![910], vec![920, 921], vec![930, 931, 932]]
        .into_iter()
        .map(|tail| sys.iter().copied().chain(tail).collect())
        .collect();
    let run = |entries: usize| -> (Vec<Vec<i32>>, cola::serve::ServiceStats) {
        let mut c = cfg(1, 8);
        c.kv_cache_entries = entries;
        let pool = ServicePool::start_with(c, mock.clone().factory()).unwrap();
        let outs: Vec<Vec<i32>> = prompts
            .iter()
            .map(|p| pool.generate(p.clone(), opts(5)).unwrap().tokens)
            .collect();
        eventually("completions tallied", || pool.stats().completed == 3);
        let stats = pool.stats();
        pool.shutdown();
        (outs, stats)
    };
    let (on, s_on) = run(64);
    let (off, s_off) = run(0);
    assert_eq!(on, off, "partial-prefix splices changed streamed outputs");
    for (i, p) in prompts.iter().enumerate() {
        assert_eq!(on[i], mock.expected_stream(*p.last().unwrap(), 5), "request {i} exact");
    }
    assert!(
        s_on.partial_prefix_hits >= 2,
        "both longer requests reuse the shared chunk (got {})",
        s_on.partial_prefix_hits
    );
    assert!(
        s_on.partial_prefix_tokens_saved >= 8,
        "each splice imports the 4-token system chunk (got {})",
        s_on.partial_prefix_tokens_saved
    );
    assert_eq!(s_on.prefill_calls, 3, "every distinct tail still pays its own prefill");
    assert_eq!(s_on.prefills_elided, 0, "no window repeats exactly — only partial reuse");
    assert_eq!(s_off.partial_prefix_hits, 0, "disabled cache never probes prefixes");
}

#[test]
fn high_priority_overtakes_a_low_burst_under_chunked_admission() {
    // Four Low requests and one High are all queued during the first slow
    // prefill. At the next boundary the engine pops the High band first and
    // never chunk-limits it, so the High request joins immediately and
    // finishes its 2 tokens while every 60-token Low is still decoding.
    let mock = MockBackend::new(4, 4, 256)
        .vocab(30_000)
        .prefill_delay(Duration::from_millis(40))
        .step_delay(Duration::from_millis(2));
    let mut c = cfg(1, 16);
    c.join_chunk = 1;
    let pool = ServicePool::start_with(c, mock.clone().factory()).unwrap();

    let lows: Vec<_> =
        (0..4).map(|i| pool.submit(vec![1000 + i], opts(60)).unwrap()).collect();
    let high = pool
        .submit(
            vec![7777],
            SubmitOptions { priority: Priority::High, ..opts(2) },
        )
        .unwrap();

    let done = high.wait().unwrap();
    assert_eq!(done.finish_reason, FinishReason::Length);
    assert_eq!(done.tokens, mock.expected_stream(7777, 2));
    // Head-of-line bound: when the High request resolves, no Low has had
    // time to produce its 60 tokens — at most the High itself is tallied.
    assert!(
        pool.stats().completed <= 1,
        "High finished behind a Low ({} completions already)",
        pool.stats().completed
    );

    for s in &lows {
        s.cancel();
    }
    eventually("low burst cancelled", || {
        let st = pool.stats();
        st.cancelled + st.completed >= 5
    });
    pool.shutdown();
}
