//! Trainer integration: the full coordinator loop over real artifacts —
//! learning progress, eval, checkpoint roundtrips, variant equivalences,
//! and failure handling. Requires `make artifacts` (tiny_* set).

use cola::config::TrainConfig;
use cola::coordinator::Trainer;

fn cfg(artifact: &str, steps: usize) -> TrainConfig {
    TrainConfig {
        artifact: artifact.into(),
        steps,
        eval_batches: 2,
        log_every: 0,
        out_dir: std::env::temp_dir().join("cola_trainer_test"),
        ..TrainConfig::default()
    }
}

fn have(artifact: &str) -> bool {
    let root = std::env::var("COLA_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    std::path::Path::new(&root).join(artifact).join("manifest.json").exists()
}

#[test]
fn training_reduces_val_ppl() {
    if !have("tiny_cola") {
        eprintln!("skip: run `make artifacts`");
        return;
    }
    let mut tr = Trainer::new(cfg("tiny_cola", 0)).unwrap(); // preset steps (60)
    let before = tr.evaluate(2).unwrap();
    let report = tr.run().unwrap();
    assert!(report.val_ppl < before * 0.7, "{before} -> {}", report.val_ppl);
    assert!(report.tokens_per_sec > 0.0);
    assert_eq!(report.steps, 60);
}

#[test]
fn checkpoint_roundtrip_preserves_eval() {
    if !have("tiny_full") {
        return;
    }
    let mut tr = Trainer::new(cfg("tiny_full", 10)).unwrap();
    tr.run().unwrap();
    let ppl1 = tr.evaluate(2).unwrap();
    let path = std::env::temp_dir().join("cola_ckpt_test.npz");
    tr.save_checkpoint(&path).unwrap();

    // fresh trainer, restore, same eval
    let mut tr2 = Trainer::new(cfg("tiny_full", 10)).unwrap();
    let fresh = tr2.evaluate(2).unwrap();
    assert!((fresh - ppl1).abs() > 1e-6, "fresh state should differ");
    tr2.load_checkpoint(&path).unwrap();
    let ppl2 = tr2.evaluate(2).unwrap();
    assert!(
        (ppl1 - ppl2).abs() < 1e-3 * ppl1,
        "checkpoint not faithful: {ppl1} vs {ppl2}"
    );
    std::fs::remove_file(&path).ok();
}

#[test]
fn cola_and_cola_m_learn_identically() {
    // same seed + same data stream => same loss trajectory (remat is
    // numerics-preserving); this is the strongest CoLA-M correctness check
    // at the integration level.
    if !have("tiny_cola") || !have("tiny_cola_m") {
        return;
    }
    let mut l1 = Vec::new();
    let mut l2 = Vec::new();
    for (art, sink) in [("tiny_cola", &mut l1), ("tiny_cola_m", &mut l2)] {
        let mut tr = Trainer::new(cfg(art, 0)).unwrap();
        for _ in 0..6 {
            sink.push(tr.train_step().unwrap().0);
        }
    }
    for (a, b) in l1.iter().zip(&l2) {
        assert!((a - b).abs() < 2e-3 * a.abs().max(1.0), "{l1:?} vs {l2:?}");
    }
}

#[test]
fn galore_trains_with_refresh() {
    if !have("tiny_galore") {
        return;
    }
    let mut c = cfg("tiny_galore", 12);
    c.galore_refresh_every = 5; // exercise the refresh path twice
    let mut tr = Trainer::new(c).unwrap();
    let report = tr.run().unwrap();
    assert!(report.final_loss < 6.5, "galore diverged: {}", report.final_loss);
}

#[test]
fn lora_and_sltrain_train() {
    for art in ["tiny_lora", "tiny_sltrain"] {
        if !have(art) {
            continue;
        }
        let mut tr = Trainer::new(cfg(art, 10)).unwrap();
        let report = tr.run().unwrap();
        assert!(report.final_loss.is_finite(), "{art}");
        assert!(report.final_loss < 6.5, "{art}: {}", report.final_loss);
    }
}

#[test]
fn bert_mlm_objective_trains() {
    if !have("bert_full") {
        return;
    }
    let mut tr = Trainer::new(cfg("bert_full", 8)).unwrap();
    let mut losses = Vec::new();
    for _ in 0..8 {
        losses.push(tr.train_step().unwrap().0);
    }
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "MLM not learning: {losses:?}"
    );
}

#[test]
fn missing_artifact_is_clear_error() {
    let Err(err) = Trainer::new(cfg("no_such_artifact", 1)) else {
        panic!("expected error for missing artifact");
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("no_such_artifact") && msg.contains("make artifacts"), "{msg}");
}

#[test]
fn rank_probe_returns_all_taps() {
    if !have("tiny_cola") {
        return;
    }
    let mut tr = Trainer::new(cfg("tiny_cola", 2)).unwrap();
    tr.run().unwrap();
    let ranks = tr.rank_probe(0.95).unwrap();
    assert_eq!(ranks.len(), tr.manifest().preset.n_layers + 1);
    for (name, r, d) in &ranks {
        assert!(*r >= 1 && r <= d, "{name}: {r}/{d}");
    }
}

#[test]
fn deterministic_given_seed() {
    if !have("tiny_full") {
        return;
    }
    let run = || {
        let mut tr = Trainer::new(cfg("tiny_full", 5)).unwrap();
        let mut v = Vec::new();
        for _ in 0..5 {
            v.push(tr.train_step().unwrap().0);
        }
        v
    };
    assert_eq!(run(), run());
}
