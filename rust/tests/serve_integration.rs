//! Serving-engine integration: spawn the engine on a real artifact, push
//! concurrent requests through the dynamic batcher, check responses and
//! engine lifecycle. Requires `make artifacts` (tiny_cola built with
//! --serve).

use cola::config::ServeConfig;
use cola::serve::Engine;

fn have(artifact: &str, step: &str) -> bool {
    let root = std::env::var("COLA_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    std::path::Path::new(&root)
        .join(artifact)
        .join(format!("{step}.hlo.txt"))
        .exists()
}

fn spawn(artifact: &str) -> Option<(cola::serve::EngineHandle, std::thread::JoinHandle<()>)> {
    if !have(artifact, "decode_step") {
        eprintln!("skip: artifact {artifact} lacks serving steps (`make artifacts`)");
        return None;
    }
    let cfg = ServeConfig {
        artifact: artifact.into(),
        max_new_tokens: 8,
        max_wait_ms: 2,
    };
    Some(Engine::spawn(cfg).unwrap())
}

#[test]
fn single_request_roundtrip() {
    let Some((engine, join)) = spawn("tiny_cola") else { return };
    let resp = engine.generate(vec![5, 6, 7, 8], 6).unwrap();
    assert_eq!(resp.tokens.len(), 6);
    let man = cola::runtime::ArtifactDir::open_named("tiny_cola").unwrap().manifest;
    assert!(resp.tokens.iter().all(|&t| (0..man.preset.vocab as i32).contains(&t)));
    assert!(resp.latency.as_secs_f64() > 0.0);
    drop(engine);
    let _ = join.join();
}

#[test]
fn decode_is_deterministic_for_same_prompt() {
    let Some((engine, join)) = spawn("tiny_cola") else { return };
    let a = engine.generate(vec![10, 11, 12, 13, 14], 6).unwrap();
    let b = engine.generate(vec![10, 11, 12, 13, 14], 6).unwrap();
    assert_eq!(a.tokens, b.tokens, "greedy decode must be deterministic");
    drop(engine);
    let _ = join.join();
}

#[test]
fn concurrent_clients_are_batched() {
    let Some((engine, join)) = spawn("tiny_cola") else { return };
    // warmup compile
    engine.generate(vec![1, 2, 3], 2).unwrap();

    let mut clients = Vec::new();
    for c in 0..3 {
        let engine = engine.clone();
        clients.push(std::thread::spawn(move || {
            let mut out = Vec::new();
            for i in 0..4 {
                let prompt = vec![c * 37 + i + 4; 5];
                out.push(engine.generate(prompt, 4).unwrap());
            }
            out
        }));
    }
    let mut tps = Vec::new();
    for c in clients {
        for resp in c.join().unwrap() {
            assert_eq!(resp.tokens.len(), 4);
            tps.push(resp.batch_tokens_per_sec);
        }
    }
    assert!(tps.iter().all(|&t| t > 0.0));
    drop(engine);
    let _ = join.join();
}

#[test]
fn long_prompts_are_truncated_not_fatal() {
    let Some((engine, join)) = spawn("tiny_cola") else { return };
    let long: Vec<i32> = (4..200).collect(); // much longer than prompt_len
    let resp = engine.generate(long, 4).unwrap();
    assert_eq!(resp.tokens.len(), 4);
    drop(engine);
    let _ = join.join();
}

#[test]
fn engine_shuts_down_cleanly_on_handle_drop() {
    let Some((engine, join)) = spawn("tiny_cola") else { return };
    engine.generate(vec![4, 5], 2).unwrap();
    drop(engine);
    // join must complete (channel closed -> engine loop exits)
    join.join().unwrap();
}

#[test]
fn spawn_fails_fast_on_missing_artifact() {
    let cfg = ServeConfig {
        artifact: "definitely_missing".into(),
        ..ServeConfig::default()
    };
    assert!(Engine::spawn(cfg).is_err());
}
