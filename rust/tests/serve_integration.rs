//! Serving integration: bring up a `ServicePool` on a real artifact and
//! exercise the `InferenceService` surface end-to-end — streaming,
//! cancellation, deadline expiry, and QueueFull backpressure through the
//! continuous-batching engine. Requires `make artifacts` (tiny_cola built
//! with --serve); every test skips cleanly when the artifact is missing.
//!
//! The same scheduling surface runs hermetically (no artifact) in
//! `serve_router.rs` via `MockBackend`; this suite is the PJRT-backed
//! (`PjrtBackend`) counterpart that additionally checks real-model
//! properties like greedy-decode determinism and vocab bounds.

use cola::config::ServeConfig;
use cola::serve::{
    FinishReason, InferenceService, ServicePool, StreamEvent, SubmitError, SubmitOptions,
};
use std::time::Duration;

fn have(artifact: &str, step: &str) -> bool {
    let root = std::env::var("COLA_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    std::path::Path::new(&root)
        .join(artifact)
        .join(format!("{step}.hlo.txt"))
        .exists()
}

fn start(artifact: &str, tweak: impl FnOnce(&mut ServeConfig)) -> Option<ServicePool> {
    if !have(artifact, "decode_step") {
        eprintln!("skip: artifact {artifact} lacks serving steps (`make artifacts`)");
        return None;
    }
    let mut cfg = ServeConfig { artifact: artifact.into(), ..ServeConfig::default() };
    tweak(&mut cfg);
    Some(ServicePool::start(cfg).unwrap())
}

fn opts(max_new: usize) -> SubmitOptions {
    SubmitOptions { max_new_tokens: Some(max_new), ..Default::default() }
}

#[test]
fn single_request_roundtrip() {
    let Some(pool) = start("tiny_cola", |_| {}) else { return };
    let c = pool.generate(vec![5, 6, 7, 8], opts(6)).unwrap();
    assert_eq!(c.tokens.len(), 6);
    assert_eq!(c.finish_reason, FinishReason::Length);
    let man = cola::runtime::ArtifactDir::open_named("tiny_cola").unwrap().manifest;
    assert!(c.tokens.iter().all(|&t| (0..man.preset.vocab as i32).contains(&t)));
    assert!(c.timing.total.as_secs_f64() > 0.0);
    assert!(c.timing.first_token.is_some());
    assert!(c.timing.first_token.unwrap() <= c.timing.total);
    pool.shutdown();
}

#[test]
fn decode_is_deterministic_for_same_prompt() {
    let Some(pool) = start("tiny_cola", |_| {}) else { return };
    let a = pool.generate(vec![10, 11, 12, 13, 14], opts(6)).unwrap();
    let b = pool.generate(vec![10, 11, 12, 13, 14], opts(6)).unwrap();
    assert_eq!(a.tokens, b.tokens, "greedy decode must be deterministic");
    pool.shutdown();
}

#[test]
fn streaming_yields_tokens_incrementally() {
    let Some(pool) = start("tiny_cola", |_| {}) else { return };
    let mut stream = pool.submit(vec![3, 4, 5], opts(5)).unwrap();
    let mut streamed = Vec::new();
    let done = loop {
        match stream.recv() {
            Some(StreamEvent::Token(t)) => streamed.push(t),
            Some(StreamEvent::Done(c)) => break c,
            None => panic!("stream dropped before Done"),
        }
    };
    assert_eq!(streamed.len(), 5, "every decoded token is streamed");
    assert_eq!(streamed, done.tokens, "stream and completion agree");
    assert!(stream.recv().is_none(), "stream is exhausted after Done");
    pool.shutdown();
}

#[test]
fn stop_token_ends_generation_early() {
    let Some(pool) = start("tiny_cola", |_| {}) else { return };
    // learn what greedy decode emits, then re-run with that token as a stop
    let probe = pool.generate(vec![20, 21, 22], opts(6)).unwrap();
    assert_eq!(probe.tokens.len(), 6);
    let stop = probe.tokens[2];
    let o = SubmitOptions { stop_tokens: vec![stop], ..opts(6) };
    let c = pool.generate(vec![20, 21, 22], o).unwrap();
    assert_eq!(c.finish_reason, FinishReason::Stop);
    // cut at the FIRST occurrence (an untrained model may repeat tokens)
    let first = probe.tokens.iter().position(|&t| t == stop).unwrap();
    assert_eq!(c.tokens, probe.tokens[..=first].to_vec(), "stops at and includes the stop token");
    pool.shutdown();
}

#[test]
fn concurrent_submits_all_complete_via_continuous_batching() {
    let Some(pool) = start("tiny_cola", |c| c.queue_depth = 64) else { return };
    // warmup compile so the workload below exercises steady-state decode
    pool.generate(vec![1, 2, 3], opts(2)).unwrap();

    // heterogeneous budgets force slot turnover (short rows vacate and
    // refill while long rows keep decoding)
    let mut streams = Vec::new();
    for i in 0..12u32 {
        let max_new = if i % 2 == 0 { 3 } else { 9 };
        let prompt = vec![(i as i32) * 37 % 200 + 4; 5];
        streams.push(pool.submit(prompt, opts(max_new)).unwrap());
    }
    for (i, s) in streams.into_iter().enumerate() {
        let c = s.wait().unwrap();
        let want = if i % 2 == 0 { 3 } else { 9 };
        assert_eq!(c.tokens.len(), want, "request {i}");
        assert_eq!(c.finish_reason, FinishReason::Length);
    }
    let stats = pool.stats();
    assert!(stats.completed >= 13, "12 requests + warmup completed");
    assert!(stats.decoded_tokens > 0);
    assert!(stats.decode_tokens_per_sec > 0.0);
    pool.shutdown();
}

#[test]
fn long_prompts_are_truncated_not_fatal() {
    let Some(pool) = start("tiny_cola", |_| {}) else { return };
    let long: Vec<i32> = (4..200).collect(); // much longer than prompt_len
    let c = pool.generate(long, opts(4)).unwrap();
    assert_eq!(c.tokens.len(), 4);
    pool.shutdown();
}

#[test]
fn generation_can_exceed_the_static_kv_window() {
    let Some(pool) = start("tiny_cola", |_| {}) else { return };
    let man = cola::runtime::ArtifactDir::open_named("tiny_cola").unwrap().manifest;
    let max_len = man.max_len.unwrap_or(man.preset.seq_len);
    // the retired engine capped max_new at max_len - prompt_len; the
    // sliding-window rollover re-prefills instead
    let c = pool.generate(vec![5, 6, 7], opts(max_len + 8)).unwrap();
    assert_eq!(c.tokens.len(), max_len + 8);
    pool.shutdown();
}

#[test]
fn cancellation_mid_decode() {
    let Some(pool) = start("tiny_cola", |_| {}) else { return };
    let mut stream = pool.submit(vec![4, 5, 6], opts(100_000)).unwrap();
    // wait for the first streamed token so we know the row is decoding
    match stream.recv() {
        Some(StreamEvent::Token(_)) => {}
        other => panic!("expected a first token, got {other:?}"),
    }
    stream.cancel();
    let c = stream.wait().unwrap();
    assert_eq!(c.finish_reason, FinishReason::Cancelled);
    assert!(!c.tokens.is_empty(), "partial output is delivered");
    assert!(c.tokens.len() < 100_000, "cancel actually cut generation short");
    pool.shutdown();
}

#[test]
fn deadline_expires_mid_decode() {
    let Some(pool) = start("tiny_cola", |_| {}) else { return };
    // warmup so compile time doesn't eat the deadline budget
    pool.generate(vec![1, 2], opts(2)).unwrap();
    let o = SubmitOptions { deadline: Some(Duration::from_millis(30)), ..opts(1_000_000) };
    let c = pool.generate(vec![7, 8, 9], o).unwrap();
    assert_eq!(c.finish_reason, FinishReason::DeadlineExpired);
    assert!(c.tokens.len() < 1_000_000);
    pool.shutdown();
}

#[test]
fn default_deadline_comes_from_config() {
    let Some(pool) = start("tiny_cola", |c| c.default_deadline_ms = 30) else { return };
    pool.generate(vec![1, 2], opts(2)).ok(); // warmup may itself expire; ignore
    let c = pool.generate(vec![7, 8, 9], opts(1_000_000)).unwrap();
    assert_eq!(c.finish_reason, FinishReason::DeadlineExpired);
    pool.shutdown();
}

#[test]
fn queue_full_backpressure_and_shutdown_shedding() {
    // workers = 0: admission-only pool, so the queue deterministically
    // fills and QueueFull surfaces on the exact submit that exceeds it
    let Some(pool) = start("tiny_cola", |c| {
        c.workers = 0;
        c.queue_depth = 2;
    }) else {
        return;
    };
    let s1 = pool.submit(vec![1], opts(4)).unwrap();
    let s2 = pool.submit(vec![2], opts(4)).unwrap();
    match pool.submit(vec![3], opts(4)) {
        Err(SubmitError::QueueFull) => {}
        other => panic!("expected QueueFull, got {:?}", other.map(|_| ())),
    }
    let stats = pool.stats();
    assert_eq!(stats.queue_depth, 2);
    assert_eq!(stats.queue_capacity, 2);
    assert_eq!(stats.submitted, 2);
    assert_eq!(stats.rejected, 1);

    // shutdown sheds queued work as Cancelled rather than hanging clients
    pool.shutdown();
    let c1 = s1.wait().unwrap();
    let c2 = s2.wait().unwrap();
    assert_eq!(c1.finish_reason, FinishReason::Cancelled);
    assert_eq!(c2.finish_reason, FinishReason::Cancelled);
    assert!(c1.tokens.is_empty());

    // and the pool refuses new work after shutdown
    match pool.submit(vec![4], opts(4)) {
        Err(SubmitError::ShuttingDown) => {}
        other => panic!("expected ShuttingDown, got {:?}", other.map(|_| ())),
    }
}

#[test]
fn priority_submits_are_accepted_and_shed_cleanly() {
    // NOTE: high-before-normal pop ordering is asserted deterministically in
    // the `serve::queue` unit tests; end-to-end completion order through a
    // live worker is timing-dependent, so here we only exercise the
    // priority-carrying submit path and shutdown shedding.
    let Some(pool) = start("tiny_cola", |c| {
        c.workers = 0;
        c.queue_depth = 8;
    }) else {
        return;
    };
    let normal = pool.submit(vec![1], opts(4)).unwrap();
    let high = pool
        .submit(vec![2], SubmitOptions { priority: cola::serve::Priority::High, ..opts(4) })
        .unwrap();
    assert_eq!(pool.stats().queue_depth, 2);
    pool.shutdown();
    assert_eq!(high.wait().unwrap().finish_reason, FinishReason::Cancelled);
    assert_eq!(normal.wait().unwrap().finish_reason, FinishReason::Cancelled);
}

#[test]
fn zero_token_budget_completes_empty() {
    let Some(pool) = start("tiny_cola", |_| {}) else { return };
    let c = pool.generate(vec![5, 6], opts(0)).unwrap();
    assert!(c.tokens.is_empty(), "max_new_tokens=0 must not leak the prefill token");
    assert_eq!(c.finish_reason, FinishReason::Length);
    pool.shutdown();
}

#[test]
fn shutdown_is_idempotent_and_drains_in_flight_work() {
    let Some(pool) = start("tiny_cola", |_| {}) else { return };
    let s = pool.submit(vec![4, 5], opts(2)).unwrap();
    pool.shutdown();
    // admitted-or-queued work resolves rather than hanging
    let c = s.wait().unwrap();
    assert!(matches!(c.finish_reason, FinishReason::Length | FinishReason::Cancelled));
    pool.shutdown(); // second call is a no-op
}

#[test]
fn start_fails_fast_on_missing_artifact() {
    let cfg = ServeConfig { artifact: "definitely_missing".into(), ..ServeConfig::default() };
    assert!(ServicePool::start(cfg).is_err());
}
