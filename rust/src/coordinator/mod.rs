//! Coordinator: the training-side runtime (the paper's system contribution
//! lives in the architecture + CoLA-M checkpointing baked into the AOT
//! artifacts; this layer owns everything around the compiled step functions:
//! data streaming, the functional state loop, schedules, evaluation,
//! checkpointing, rank probes, and run-result caching for the benches).

pub mod checkpoint;
pub mod rank_probe;
pub mod runcache;
pub mod trainer;

pub use rank_probe::RankProbe;
pub use runcache::{cached_or_train, cached_or_train_fresh, RunResult};
pub use trainer::{Trainer, TrainReport};
