//! Checkpointing: full training state (params + optimizer) as npz, using the
//! same `s%06d` key convention as state0.npz so checkpoints and initial
//! states are interchangeable.

use crate::runtime::Manifest;
use anyhow::{Context, Result};
use std::path::Path;
use xla::FromRawBytes;

pub fn save(man: &Manifest, state: &[xla::PjRtBuffer], path: &Path) -> Result<()> {
    anyhow::ensure!(state.len() >= man.n_state, "state too short");
    if let Some(p) = path.parent() {
        std::fs::create_dir_all(p)?;
    }
    let lits: Vec<xla::Literal> = state[..man.n_state]
        .iter()
        .map(|b| Ok(b.to_literal_sync()?))
        .collect::<Result<_>>()?;
    let named: Vec<(String, &xla::Literal)> = lits
        .iter()
        .enumerate()
        .map(|(i, l)| (format!("s{i:06}"), l))
        .collect();
    xla::Literal::write_npz(&named, path)
        .with_context(|| format!("writing {}", path.display()))?;
    Ok(())
}

pub fn load(man: &Manifest, path: &Path) -> Result<Vec<xla::PjRtBuffer>> {
    let mut entries = xla::Literal::read_npz(path, &())
        .with_context(|| format!("reading {}", path.display()))?;
    entries.sort_by(|a, b| a.0.cmp(&b.0));
    anyhow::ensure!(
        entries.len() == man.n_state,
        "checkpoint has {} arrays, manifest wants {}",
        entries.len(),
        man.n_state
    );
    let client = crate::runtime::client()?;
    entries
        .into_iter()
        .map(|(_, l)| Ok(client.buffer_from_host_literal(None, &l)?))
        .collect()
}

/// Extract just the parameter literals from a checkpoint, keyed by name —
/// used to splice a pre-trained backbone into a fine-tuning artifact
/// (Table 8 GLUE-proxy flow).
pub fn load_params_by_name(
    man: &Manifest,
    path: &Path,
) -> Result<std::collections::HashMap<String, xla::Literal>> {
    let mut entries = xla::Literal::read_npz(path, &())?;
    entries.sort_by(|a, b| a.0.cmp(&b.0));
    anyhow::ensure!(entries.len() >= man.n_params, "not enough arrays");
    Ok(entries
        .into_iter()
        .take(man.n_params)
        .enumerate()
        .map(|(i, (_, l))| (man.param_names[i].clone(), l))
        .collect())
}
