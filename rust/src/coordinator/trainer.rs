//! The training loop: device-resident functional state, streaming batches,
//! periodic eval / checkpoint / galore-refresh / rank probes.

use crate::config::TrainConfig;
use crate::coordinator::checkpoint;
use crate::coordinator::rank_probe::RankProbe;
use crate::data::{corpus::CorpusCfg, Bpe, BatchIter, CorpusGen, MlmBatchIter};
use crate::metrics::{self, Ema, Throughput};
use crate::runtime::executor::{buf_f32, lit_f32, lit_i32, to_device};
use crate::runtime::{ArtifactDir, StepFn};
use crate::util::json::Json;
use anyhow::Result;
use std::path::PathBuf;

/// Final report of a training run (what the benches consume).
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub artifact: String,
    pub steps: usize,
    pub final_loss: f64,
    pub val_ppl: f64,
    pub tokens_per_sec: f64,
    pub secs_per_step: f64,
    pub peak_rss_bytes: usize,
    pub loss_curve: Vec<(usize, f64)>,
    pub val_curve: Vec<(usize, f64)>,
    pub n_total_params: usize,
}

/// Trainer owns the artifact, the device-resident state and the data stream.
pub struct Trainer {
    pub art: ArtifactDir,
    cfg: TrainConfig,
    train_fn: StepFn,
    eval_fn: Option<StepFn>,
    refresh_fn: Option<StepFn>,
    state: Vec<xla::PjRtBuffer>,
    lm_iter: Option<BatchIter>,
    mlm_iter: Option<MlmBatchIter>,
    val_iter: Option<BatchIter>,
    pub bpe: Bpe,
    step: usize,
}

/// Train (or load) the shared BPE tokenizer for a vocab size, cached on disk.
pub fn shared_bpe(vocab: usize) -> Result<Bpe> {
    let cache = PathBuf::from(
        std::env::var("COLA_DATA_CACHE").unwrap_or_else(|_| "data_cache".into()),
    )
    .join(format!("bpe_{vocab}.json"));
    if cache.exists() {
        return Bpe::load(&cache);
    }
    metrics::log_info(&format!("training BPE vocab={vocab} (cached at {})", cache.display()));
    let text = CorpusGen::new(CorpusCfg { seed: 42, ..CorpusCfg::default() }).text(400_000);
    let bpe = Bpe::train(&text, vocab);
    bpe.save(&cache)?;
    Ok(bpe)
}

impl Trainer {
    pub fn new(cfg: TrainConfig) -> Result<Self> {
        let art = ArtifactDir::open_named(&cfg.artifact)?;
        let man = art.manifest.clone();
        let train_fn = art.step("train_step")?;
        let eval_fn = if art.has_step("eval_step") { Some(art.step("eval_step")?) } else { None };
        let refresh_fn = if man.variant == "galore" && art.has_step("refresh_proj") {
            Some(art.step("refresh_proj")?)
        } else {
            None
        };
        let state = art.load_state0_buffers()?;
        let bpe = shared_bpe(man.preset.vocab)?;

        let (lm_iter, mlm_iter) = if man.objective == "mlm" {
            (None, Some(MlmBatchIter::new(bpe.clone(), cfg.seed, man.preset.vocab)))
        } else {
            (Some(BatchIter::new(bpe.clone(), cfg.seed, man.preset.vocab)), None)
        };
        let val_iter = if man.objective == "lm" {
            Some(BatchIter::new(bpe.clone(), cfg.seed + 1_000_003, man.preset.vocab))
        } else {
            None
        };

        Ok(Self {
            art,
            cfg,
            train_fn,
            eval_fn,
            refresh_fn,
            state,
            lm_iter,
            mlm_iter,
            val_iter,
            bpe,
            step: 0,
        })
    }

    pub fn manifest(&self) -> &crate::runtime::Manifest {
        &self.art.manifest
    }

    fn tokens_per_step(&self) -> u64 {
        self.art.manifest.tokens_shape.iter().product::<usize>() as u64
    }

    /// One optimizer step. Returns (loss, grad_norm).
    pub fn train_step(&mut self) -> Result<(f32, f32)> {
        Ok(self.train_step_opt(true)?.expect("read_loss=true"))
    }

    /// One optimizer step. When `read_loss` is false the loss/grad-norm
    /// buffers are left on device (no host sync — the hot-loop mode; §Perf
    /// L3) and `None` is returned.
    pub fn train_step_opt(&mut self, read_loss: bool) -> Result<Option<(f32, f32)>> {
        let man = &self.art.manifest;
        let shape = &man.tokens_shape;
        let dims: Vec<i64> = shape.iter().map(|&x| x as i64).collect();

        let step_buf = to_device(&lit_f32(self.step as f32))?;
        let mut extra: Vec<xla::PjRtBuffer> = vec![step_buf];
        if let Some(it) = self.mlm_iter.as_mut() {
            let (toks, mask) = it.next_batch(shape);
            extra.push(to_device(&lit_i32(&toks, &dims)?)?);
            extra.push(to_device(&lit_i32(&mask, &dims)?)?);
        } else {
            let toks = self.lm_iter.as_mut().unwrap().next_batch(shape);
            extra.push(to_device(&lit_i32(&toks, &dims)?)?);
        }

        let mut refs: Vec<&xla::PjRtBuffer> = self.state.iter().collect();
        refs.extend(extra.iter());
        let out = self.train_fn.run_b(&refs)?;
        anyhow::ensure!(
            out.len() == man.n_state + 2,
            "train_step returned {} buffers, want {}",
            out.len(),
            man.n_state + 2
        );
        let loss_gnorm = if read_loss {
            let loss = buf_f32(&out[man.n_state])?;
            let gnorm = buf_f32(&out[man.n_state + 1])?;
            anyhow::ensure!(loss.is_finite(), "loss diverged at step {}: {loss}", self.step);
            Some((loss, gnorm))
        } else {
            None
        };
        self.state = out;
        self.state.truncate(man.n_state);
        self.step += 1;

        // galore projection refresh (in-graph; seeded by the step index)
        if let Some(refresh) = &self.refresh_fn {
            if self.cfg.galore_refresh_every > 0 && self.step % self.cfg.galore_refresh_every == 0
            {
                let seed = to_device(&xla::Literal::scalar(self.step as i32))?;
                let mut refs: Vec<&xla::PjRtBuffer> = self.state.iter().collect();
                refs.push(&seed);
                let out = refresh.run_b(&refs)?;
                anyhow::ensure!(out.len() == man.n_state, "refresh arity");
                self.state = out;
            }
        }
        Ok(loss_gnorm)
    }

    /// Validation perplexity over `n_batches` held-out batches.
    pub fn evaluate(&mut self, n_batches: usize) -> Result<f64> {
        let man = &self.art.manifest;
        let Some(eval) = &self.eval_fn else {
            anyhow::bail!("artifact has no eval_step");
        };
        let bs = man.eval_batch;
        let seq1 = man.preset.seq_len + 1;
        let mut sum = 0.0f64;
        let mut count = 0.0f64;
        for _ in 0..n_batches {
            let toks = self.val_iter.as_mut().unwrap().next_eval(bs, seq1);
            let lit = lit_i32(&toks, &[bs as i64, seq1 as i64])?;
            let tok_buf = to_device(&lit)?;
            let mut refs: Vec<&xla::PjRtBuffer> =
                self.state[..man.n_params].iter().collect();
            refs.push(&tok_buf);
            let out = eval.run_b(&refs)?;
            sum += buf_f32(&out[0])? as f64;
            count += buf_f32(&out[1])? as f64;
        }
        Ok((sum / count).exp())
    }

    /// Spectrum probe on current params (Fig. 2): per-tap effective ranks.
    pub fn rank_probe(&mut self, alpha: f64) -> Result<Vec<(String, usize, usize)>> {
        let probe = RankProbe::new(&self.art)?;
        let toks = self
            .val_iter
            .as_mut()
            .map(|it| it.next_eval(2, self.art.manifest.preset.seq_len + 1))
            .unwrap_or_default();
        probe.run(&self.state[..self.art.manifest.n_params], &toks, alpha)
    }

    /// Save a checkpoint of the full state.
    pub fn save_checkpoint(&self, path: &std::path::Path) -> Result<()> {
        checkpoint::save(&self.art.manifest, &self.state, path)
    }

    /// Restore state from a checkpoint.
    pub fn load_checkpoint(&mut self, path: &std::path::Path) -> Result<()> {
        self.state = checkpoint::load(&self.art.manifest, path)?;
        Ok(())
    }

    /// Current params as host literals (for the serve engine / fine-tuning).
    pub fn params_literals(&self) -> Result<Vec<xla::Literal>> {
        self.state[..self.art.manifest.n_params]
            .iter()
            .map(|b| Ok(b.to_literal_sync()?))
            .collect()
    }

    /// The full training loop per the config.
    pub fn run(&mut self) -> Result<TrainReport> {
        let total = if self.cfg.steps > 0 {
            self.cfg.steps
        } else {
            self.art.manifest.preset.total_steps
        };
        let mut thr = Throughput::new();
        let mut ema = Ema::new(0.05);
        let mut loss_curve = Vec::new();
        let mut val_curve = Vec::new();
        let log_path = self.cfg.out_dir.join(format!("{}.jsonl", self.art.manifest.name));

        let mut last_loss = f64::NAN;
        while self.step < total {
            // host-sync (loss read) only at observation points — the hot
            // loop otherwise chains device buffers without blocking (§Perf L3)
            let observe = total - self.step <= 1
                || (self.cfg.log_every > 0 && (self.step + 1) % self.cfg.log_every == 0)
                || (self.cfg.eval_every > 0 && (self.step + 1) % self.cfg.eval_every == 0);
            let Some((loss, gnorm)) = self.train_step_opt(observe)? else {
                thr.record(self.tokens_per_step());
                continue;
            };
            last_loss = ema.update(loss as f64);
            thr.record(self.tokens_per_step());

            if self.cfg.log_every > 0 && self.step % self.cfg.log_every == 0 {
                metrics::log_info(&format!(
                    "{} step {}/{} loss {:.4} (ema {:.4}) gnorm {:.3} {:.0} tok/s",
                    self.art.manifest.name,
                    self.step,
                    total,
                    loss,
                    last_loss,
                    gnorm,
                    thr.tokens_per_sec()
                ));
                loss_curve.push((self.step, last_loss));
                metrics::append_jsonl(
                    &log_path,
                    &Json::obj(vec![
                        ("step", Json::num(self.step as f64)),
                        ("loss", Json::num(loss as f64)),
                        ("gnorm", Json::num(gnorm as f64)),
                        ("tok_s", Json::num(thr.tokens_per_sec())),
                    ]),
                )?;
            }
            if self.cfg.eval_every > 0 && self.step % self.cfg.eval_every == 0 {
                if self.eval_fn.is_some() {
                    let ppl = self.evaluate(self.cfg.eval_batches)?;
                    val_curve.push((self.step, ppl));
                    metrics::log_info(&format!(
                        "{} step {} val_ppl {:.3}",
                        self.art.manifest.name, self.step, ppl
                    ));
                }
            }
            if self.cfg.checkpoint_every > 0 && self.step % self.cfg.checkpoint_every == 0 {
                let p = self
                    .cfg
                    .out_dir
                    .join(format!("{}_step{}.npz", self.art.manifest.name, self.step));
                self.save_checkpoint(&p)?;
            }
            if self.cfg.rank_probe_every > 0 && self.step % self.cfg.rank_probe_every == 0 {
                if self.art.has_step("activations") {
                    let ranks = self.rank_probe(0.95)?;
                    let s: Vec<String> = ranks
                        .iter()
                        .map(|(n, r, d)| format!("{n}:{r}/{d}"))
                        .collect();
                    metrics::log_info(&format!(
                        "{} step {} r(0.95): {}",
                        self.art.manifest.name,
                        self.step,
                        s.join(" ")
                    ));
                }
            }
        }

        let val_ppl = if self.eval_fn.is_some() {
            self.evaluate(self.cfg.eval_batches)?
        } else {
            last_loss.exp()
        };
        val_curve.push((self.step, val_ppl));

        Ok(TrainReport {
            artifact: self.art.manifest.name.clone(),
            steps: self.step,
            final_loss: last_loss,
            val_ppl,
            tokens_per_sec: thr.tokens_per_sec(),
            secs_per_step: thr.secs_per_step(),
            peak_rss_bytes: metrics::peak_rss_bytes(),
            loss_curve,
            val_curve,
            n_total_params: self.art.manifest.n_total_params,
        })
    }
}
