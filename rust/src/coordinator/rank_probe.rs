//! Rank probe: runs the `activations` artifact, SVDs each tap and reports
//! the paper's effective rank r(α) (Fig. 2 / Appendix A analytics).

use crate::linalg::{effective_rank, singular_values, Mat};
use crate::runtime::executor::{buf_f32_vec, lit_i32, to_device};
use crate::runtime::{ArtifactDir, StepFn};
use anyhow::Result;

pub struct RankProbe {
    acts_fn: StepFn,
    n_layers: usize,
    d: usize,
    seq_len: usize,
}

/// Full spectrum of one tap (for Fig. 2a curves).
#[derive(Clone, Debug)]
pub struct TapSpectrum {
    pub name: String,
    pub singular_values: Vec<f64>,
    pub effective_rank: usize,
    pub full_dim: usize,
}

impl RankProbe {
    pub fn new(art: &ArtifactDir) -> Result<Self> {
        let man = &art.manifest;
        Ok(Self {
            acts_fn: art.step("activations")?,
            n_layers: man.preset.n_layers,
            d: man.preset.d,
            seq_len: man.preset.seq_len,
        })
    }

    fn tap_name(&self, i: usize) -> String {
        if i < self.n_layers {
            format!("l{i}.input")
        } else {
            "final".into()
        }
    }

    /// Run taps for `tokens` ([2, seq+1] flat) and return
    /// (tap name, r(alpha), full dim) per tap.
    pub fn run(
        &self,
        params: &[xla::PjRtBuffer],
        tokens: &[i32],
        alpha: f64,
    ) -> Result<Vec<(String, usize, usize)>> {
        Ok(self
            .spectra(params, tokens, alpha)?
            .into_iter()
            .map(|t| (t.name, t.effective_rank, t.full_dim))
            .collect())
    }

    /// Full spectra per tap.
    pub fn spectra(
        &self,
        params: &[xla::PjRtBuffer],
        tokens: &[i32],
        alpha: f64,
    ) -> Result<Vec<TapSpectrum>> {
        let seq1 = self.seq_len + 1;
        anyhow::ensure!(tokens.len() == 2 * seq1, "probe batch must be [2, seq+1]");
        let tok = to_device(&lit_i32(tokens, &[2, seq1 as i64])?)?;
        let mut refs: Vec<&xla::PjRtBuffer> = params.iter().collect();
        refs.push(&tok);
        let out = self.acts_fn.run_b(&refs)?;
        anyhow::ensure!(out.len() == self.n_layers + 1, "tap count");

        let n_rows = 2 * self.seq_len;
        let mut result = Vec::with_capacity(out.len());
        for (i, buf) in out.iter().enumerate() {
            let data = buf_f32_vec(buf)?;
            let m = Mat::from_f32(n_rows, self.d, &data);
            let sv = singular_values(&m);
            let er = effective_rank(&sv, alpha);
            result.push(TapSpectrum {
                name: self.tap_name(i),
                singular_values: sv,
                effective_rank: er,
                full_dim: self.d,
            });
        }
        Ok(result)
    }
}
