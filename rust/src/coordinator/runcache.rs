//! Run-result cache: benches share training runs (Table 5 and Table 7 both
//! need p60m_full, etc.), so completed runs are memoized on disk keyed by
//! (artifact, steps, seed).

use crate::config::TrainConfig;
use crate::coordinator::trainer::{TrainReport, Trainer};
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::path::PathBuf;

/// The cached subset of a TrainReport.
#[derive(Clone, Debug)]
pub struct RunResult {
    pub artifact: String,
    pub steps: usize,
    pub val_ppl: f64,
    pub final_loss: f64,
    pub tokens_per_sec: f64,
    pub secs_per_step: f64,
    pub peak_rss_bytes: usize,
    pub n_total_params: usize,
    pub val_curve: Vec<(usize, f64)>,
}

impl From<&TrainReport> for RunResult {
    fn from(r: &TrainReport) -> Self {
        Self {
            artifact: r.artifact.clone(),
            steps: r.steps,
            val_ppl: r.val_ppl,
            final_loss: r.final_loss,
            tokens_per_sec: r.tokens_per_sec,
            secs_per_step: r.secs_per_step,
            peak_rss_bytes: r.peak_rss_bytes,
            n_total_params: r.n_total_params,
            val_curve: r.val_curve.clone(),
        }
    }
}

fn cache_path(artifact: &str, steps: usize, seed: u64) -> PathBuf {
    let root = std::env::var("COLA_RUN_CACHE").unwrap_or_else(|_| "runs/cache".into());
    PathBuf::from(root).join(format!("{artifact}_s{steps}_seed{seed}.json"))
}

fn to_json(r: &RunResult) -> Json {
    Json::obj(vec![
        ("artifact", Json::s(&r.artifact)),
        ("steps", Json::num(r.steps as f64)),
        ("val_ppl", Json::num(r.val_ppl)),
        ("final_loss", Json::num(r.final_loss)),
        ("tokens_per_sec", Json::num(r.tokens_per_sec)),
        ("secs_per_step", Json::num(r.secs_per_step)),
        ("peak_rss_bytes", Json::num(r.peak_rss_bytes as f64)),
        ("n_total_params", Json::num(r.n_total_params as f64)),
        (
            "val_curve",
            Json::Arr(
                r.val_curve
                    .iter()
                    .map(|(s, p)| Json::Arr(vec![Json::num(*s as f64), Json::num(*p)]))
                    .collect(),
            ),
        ),
    ])
}

fn from_json(j: &Json) -> Result<RunResult> {
    Ok(RunResult {
        artifact: j.req("artifact")?.as_str().unwrap_or("").into(),
        steps: j.req("steps")?.as_usize().context("steps")?,
        val_ppl: j.req("val_ppl")?.as_f64().context("val_ppl")?,
        final_loss: j.req("final_loss")?.as_f64().context("final_loss")?,
        tokens_per_sec: j.req("tokens_per_sec")?.as_f64().context("tps")?,
        secs_per_step: j.req("secs_per_step")?.as_f64().context("sps")?,
        peak_rss_bytes: j.req("peak_rss_bytes")?.as_usize().unwrap_or(0),
        n_total_params: j.req("n_total_params")?.as_usize().unwrap_or(0),
        val_curve: j
            .req("val_curve")?
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .map(|row| {
                let v = row.as_arr().unwrap_or(&[]);
                (
                    v.first().and_then(Json::as_usize).unwrap_or(0),
                    v.get(1).and_then(Json::as_f64).unwrap_or(f64::NAN),
                )
            })
            .collect(),
    })
}

/// Return a cached result for (artifact, steps, seed), or train and cache.
pub fn cached_or_train(artifact: &str, steps: usize, seed: u64) -> Result<RunResult> {
    let path = cache_path(artifact, steps, seed);
    if path.exists() {
        let j = Json::parse(&std::fs::read_to_string(&path)?)
            .with_context(|| format!("parsing {}", path.display()))?;
        if let Ok(r) = from_json(&j) {
            crate::metrics::log_info(&format!(
                "runcache hit: {artifact} steps={steps} val_ppl={:.3}",
                r.val_ppl
            ));
            return Ok(r);
        }
    }
    let cfg = TrainConfig {
        artifact: artifact.to_string(),
        steps,
        seed,
        eval_every: 0,
        eval_batches: 8,
        ..TrainConfig::default()
    };
    let mut tr = Trainer::new(cfg)?;
    let report = tr.run()?;
    let result = RunResult::from(&report);
    if let Some(p) = path.parent() {
        std::fs::create_dir_all(p)?;
    }
    std::fs::write(&path, to_json(&result).to_string())?;
    Ok(result)
}

/// Like `cached_or_train`, but runs the training in a fresh subprocess (via
/// the `cola train-cached` subcommand) so peak-RSS measurements are not
/// contaminated by earlier variants in the same bench process. Falls back to
/// in-process training when the binary is unavailable.
pub fn cached_or_train_fresh(artifact: &str, steps: usize, seed: u64) -> Result<RunResult> {
    let path = cache_path(artifact, steps, seed);
    if path.exists() {
        if let Ok(j) = Json::parse(&std::fs::read_to_string(&path)?) {
            if let Ok(r) = from_json(&j) {
                return Ok(r);
            }
        }
    }
    let bin = std::env::var("COLA_BIN").unwrap_or_else(|_| "target/release/cola".into());
    if std::path::Path::new(&bin).exists() {
        let status = std::process::Command::new(&bin)
            .args([
                "train-cached",
                "--artifact",
                artifact,
                "--steps",
                &steps.to_string(),
                "--seed",
                &seed.to_string(),
            ])
            .status()
            .context("spawning cola train-cached")?;
        anyhow::ensure!(status.success(), "train-cached {artifact} failed");
        let j = Json::parse(&std::fs::read_to_string(&path)?)?;
        return from_json(&j);
    }
    cached_or_train(artifact, steps, seed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip() {
        let r = RunResult {
            artifact: "x".into(),
            steps: 10,
            val_ppl: 12.5,
            final_loss: 2.5,
            tokens_per_sec: 1000.0,
            secs_per_step: 0.5,
            peak_rss_bytes: 1 << 30,
            n_total_params: 123,
            val_curve: vec![(5, 20.0), (10, 12.5)],
        };
        let j = to_json(&r);
        let r2 = from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(r2.steps, 10);
        assert_eq!(r2.val_curve.len(), 2);
        assert!((r2.val_ppl - 12.5).abs() < 1e-12);
    }
}
