//! Host-side KV prefix cache: prefill avoidance for the serving engine.
//!
//! Every join prefill re-encodes each occupied row's full context window —
//! compute the paper's low-rank activations already halved, re-spent at
//! every admission and KV-window rollover. But a row's post-prefill KV
//! state is a pure function of its window tokens (the prefill initialises
//! each row's cache from zeros, and causal attention never crosses rows),
//! so identical windows always produce identical per-row KV slices and the
//! same next token. [`KvPrefixCache`] exploits that: a bounded LRU from
//! window-token hash to `(host KV row snapshot, next token)`, filled after
//! real prefills via [`EngineBackend::export_kv_rows`] and consulted at
//! every join boundary. When *all* occupied rows hit, the engine skips the
//! prefill entirely and restores the rows with
//! [`EngineBackend::import_kv_rows`] — repeated prefixes (system prompts,
//! retries, deterministic re-generations after a rollover) cost one host
//! transfer instead of one full forward pass.
//!
//! [`EngineBackend::export_kv_rows`]: crate::serve::engine::EngineBackend::export_kv_rows
//! [`EngineBackend::import_kv_rows`]: crate::serve::engine::EngineBackend::import_kv_rows
//!
//! Design notes:
//! - Entries verify the full window on lookup — the hash is the index, not
//!   the identity, so a 64-bit collision degrades to a miss, never to
//!   serving another prompt's KV state.
//! - The cache is worker-local (constructed inside the engine loop), so it
//!   needs no locking and its lifetime matches the backend whose geometry
//!   produced the snapshots.
//! - Probing and reading are split ([`probe`](KvPrefixCache::probe) touches
//!   the LRU order and returns an index; [`peek`](KvPrefixCache::peek) is a
//!   shared borrow) so the engine can collect every occupied row's entry
//!   before handing the batch to `import_kv_rows`.

use std::collections::HashMap;

/// Sentinel for "no neighbour" in the intrusive LRU list.
const NIL: usize = usize::MAX;

/// Host-side snapshot of one row's post-prefill KV state, plus the next
/// token that prefill produced for the row. Payload layout is
/// backend-defined (`[n_layers * max_len * n_heads * head_dim]` f32 per
/// plane for the PJRT backend); the cache only moves it.
#[derive(Clone, Debug, PartialEq)]
pub struct KvRowState {
    /// Key-cache plane for this row.
    pub k: Vec<f32>,
    /// Value-cache plane for this row.
    pub v: Vec<f32>,
}

/// FNV-1a offset basis — `hash_tokens(&[])`.
const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

/// Fold one token into an FNV-1a state (little-endian bytes). `SlotTable`
/// hashes windows incrementally from its segments with this, so a window
/// never has to be materialised just to be keyed.
pub fn fold_token(mut h: u64, t: i32) -> u64 {
    for b in t.to_le_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// FNV-1a over the window tokens: the cache key. Kept `pub` so
/// `SlotTable::window_hash` and out-of-crate harnesses hash windows exactly
/// the way the cache does.
pub fn hash_tokens(tokens: &[i32]) -> u64 {
    tokens.iter().fold(FNV_OFFSET, |h, &t| fold_token(h, t))
}

struct Entry {
    hash: u64,
    window: Vec<i32>,
    kv: KvRowState,
    next_token: i32,
    /// Towards MRU (the entry more recently used than this one).
    prev: usize,
    /// Towards LRU.
    next: usize,
}

/// Counter deltas from one cache operation, tallied into the pool's shared
/// counters by the engine.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheEvents {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

/// Bounded LRU of per-row KV snapshots keyed by window-token hash.
pub struct KvPrefixCache {
    cap: usize,
    /// hash → slab index. One entry per hash: a colliding insert replaces
    /// the resident entry (verified windows make this safe, merely lossy).
    map: HashMap<u64, usize>,
    slab: Vec<Entry>,
    free: Vec<usize>,
    head: usize,
    tail: usize,
}

impl KvPrefixCache {
    /// A cache holding at most `capacity` rows (`capacity >= 1`; a capacity
    /// of 0 means "disabled" and is handled by the engine, which then never
    /// constructs one).
    pub fn new(capacity: usize) -> Self {
        let cap = capacity.max(1);
        Self {
            cap,
            map: HashMap::with_capacity(cap),
            slab: Vec::with_capacity(cap),
            free: Vec::new(),
            head: NIL,
            tail: NIL,
        }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Unlink `i` from the recency list.
    fn unlink(&mut self, i: usize) {
        let (p, n) = (self.slab[i].prev, self.slab[i].next);
        if p == NIL {
            self.head = n;
        } else {
            self.slab[p].next = n;
        }
        if n == NIL {
            self.tail = p;
        } else {
            self.slab[n].prev = p;
        }
    }

    /// Link `i` at the MRU head.
    fn push_front(&mut self, i: usize) {
        self.slab[i].prev = NIL;
        self.slab[i].next = self.head;
        if self.head != NIL {
            self.slab[self.head].prev = i;
        }
        self.head = i;
        if self.tail == NIL {
            self.tail = i;
        }
    }

    /// Look up a window. On a verified hit the entry moves to the MRU head
    /// and its slab index is returned — read it with [`peek`](Self::peek)
    /// (a shared borrow, so a whole batch of probed rows can be read at
    /// once). A hash collision with a different window is a miss.
    pub fn probe(&mut self, hash: u64, window: &[i32]) -> Option<usize> {
        let &i = self.map.get(&hash)?;
        if self.slab[i].window != window {
            return None;
        }
        if self.head != i {
            self.unlink(i);
            self.push_front(i);
        }
        Some(i)
    }

    /// The KV snapshot and next token behind a [`probe`](Self::probe)d
    /// index. Indices stay valid until the next `insert`.
    pub fn peek(&self, idx: usize) -> (&KvRowState, i32) {
        let e = &self.slab[idx];
        (&e.kv, e.next_token)
    }

    /// Insert (or refresh) the snapshot for a window, evicting the LRU
    /// entry when the cache is full. Returns how many entries were evicted
    /// (0 or 1).
    pub fn insert(&mut self, hash: u64, window: Vec<i32>, kv: KvRowState, next_token: i32) -> u64 {
        if let Some(&i) = self.map.get(&hash) {
            // refresh (or hash-collision replacement — last writer wins)
            let e = &mut self.slab[i];
            e.window = window;
            e.kv = kv;
            e.next_token = next_token;
            if self.head != i {
                self.unlink(i);
                self.push_front(i);
            }
            return 0;
        }
        let mut evicted = 0;
        if self.map.len() >= self.cap {
            let lru = self.tail;
            debug_assert_ne!(lru, NIL, "full cache must have a tail");
            self.unlink(lru);
            self.map.remove(&self.slab[lru].hash);
            self.free.push(lru);
            evicted = 1;
        }
        let i = match self.free.pop() {
            Some(i) => {
                self.slab[i] = Entry { hash, window, kv, next_token, prev: NIL, next: NIL };
                i
            }
            None => {
                self.slab.push(Entry { hash, window, kv, next_token, prev: NIL, next: NIL });
                self.slab.len() - 1
            }
        };
        self.map.insert(hash, i);
        self.push_front(i);
        evicted
    }

    /// MRU-first window snapshots (test/debug aid).
    #[cfg(test)]
    fn recency_order(&self) -> Vec<&[i32]> {
        let mut out = Vec::new();
        let mut i = self.head;
        while i != NIL {
            out.push(self.slab[i].window.as_slice());
            i = self.slab[i].next;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(x: f32) -> KvRowState {
        KvRowState { k: vec![x; 4], v: vec![-x; 4] }
    }

    fn put(c: &mut KvPrefixCache, w: &[i32], next: i32) -> u64 {
        c.insert(hash_tokens(w), w.to_vec(), row(next as f32), next)
    }

    fn get(c: &mut KvPrefixCache, w: &[i32]) -> Option<i32> {
        c.probe(hash_tokens(w), w).map(|i| c.peek(i).1)
    }

    #[test]
    fn hash_is_stable_and_window_sensitive() {
        assert_eq!(hash_tokens(&[1, 2, 3]), hash_tokens(&[1, 2, 3]));
        assert_ne!(hash_tokens(&[1, 2, 3]), hash_tokens(&[3, 2, 1]));
        assert_ne!(hash_tokens(&[0]), hash_tokens(&[0, 0]), "padding length matters");
    }

    #[test]
    fn hit_returns_snapshot_and_next_token() {
        let mut c = KvPrefixCache::new(4);
        assert!(get(&mut c, &[1, 2]).is_none(), "cold cache misses");
        put(&mut c, &[1, 2], 3);
        let i = c.probe(hash_tokens(&[1, 2]), &[1, 2]).unwrap();
        let (kv, next) = c.peek(i);
        assert_eq!(next, 3);
        assert_eq!(kv, &row(3.0));
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let mut c = KvPrefixCache::new(2);
        assert_eq!(put(&mut c, &[1], 10), 0);
        assert_eq!(put(&mut c, &[2], 20), 0);
        // touch [1] so [2] is LRU
        assert_eq!(get(&mut c, &[1]), Some(10));
        assert_eq!(put(&mut c, &[3], 30), 1, "insert past capacity evicts");
        assert_eq!(c.len(), 2);
        assert!(get(&mut c, &[2]).is_none(), "LRU entry [2] was evicted");
        assert_eq!(get(&mut c, &[1]), Some(10));
        assert_eq!(get(&mut c, &[3]), Some(30));
    }

    #[test]
    fn refresh_updates_payload_without_eviction() {
        let mut c = KvPrefixCache::new(2);
        put(&mut c, &[5], 1);
        assert_eq!(put(&mut c, &[5], 2), 0, "same window refreshes in place");
        assert_eq!(c.len(), 1);
        assert_eq!(get(&mut c, &[5]), Some(2));
    }

    #[test]
    fn recency_order_tracks_probes_and_inserts() {
        let mut c = KvPrefixCache::new(3);
        put(&mut c, &[1], 1);
        put(&mut c, &[2], 2);
        put(&mut c, &[3], 3);
        assert_eq!(c.recency_order(), vec![&[3][..], &[2], &[1]]);
        get(&mut c, &[1]);
        assert_eq!(c.recency_order(), vec![&[1][..], &[3], &[2]]);
    }

    #[test]
    fn collision_with_different_window_is_a_verified_miss() {
        let mut c = KvPrefixCache::new(2);
        let h = hash_tokens(&[7, 8]);
        c.insert(h, vec![7, 8], row(1.0), 1);
        // same hash, different tokens: must NOT serve the resident entry
        assert!(c.probe(h, &[9, 9]).is_none());
        assert!(c.probe(h, &[7, 8]).is_some(), "the real window still hits");
    }

    #[test]
    fn slab_slots_are_reused_after_eviction() {
        let mut c = KvPrefixCache::new(2);
        for x in 0..20 {
            put(&mut c, &[x], x);
        }
        assert_eq!(c.len(), 2);
        assert!(c.slab.len() <= 3, "evicted slots recycle instead of growing the slab");
        assert_eq!(get(&mut c, &[19]), Some(19));
        assert_eq!(get(&mut c, &[18]), Some(18));
    }

    #[test]
    fn single_entry_cache_works() {
        let mut c = KvPrefixCache::new(1);
        put(&mut c, &[1], 1);
        assert_eq!(put(&mut c, &[2], 2), 1);
        assert!(get(&mut c, &[1]).is_none());
        assert_eq!(get(&mut c, &[2]), Some(2));
    }

    /// Eviction-accounting conservation under random thrash: across a long
    /// mixed probe/insert workload over 3x-capacity distinct windows,
    /// hits + misses == probes, every probe outcome agrees with the actual
    /// resident set, occupancy never exceeds capacity, and every *new*
    /// insert is conserved as either a still-resident entry or a reported
    /// eviction (`new_inserts == evictions + len`).
    #[test]
    fn eviction_accounting_is_conserved_under_thrash() {
        use crate::util::rng::Rng;
        use std::collections::{HashMap, HashSet};
        const CAP: usize = 8;
        let windows: Vec<Vec<i32>> = (0..24).map(|w| vec![w, 7 * w + 1, 3]).collect();
        for a in 0..windows.len() {
            for b in (a + 1)..windows.len() {
                assert_ne!(hash_tokens(&windows[a]), hash_tokens(&windows[b]));
            }
        }
        let mut rng = Rng::new(0xC0_1A);
        let mut c = KvPrefixCache::new(CAP);
        let mut latest: HashMap<u64, i32> = HashMap::new();
        let (mut probes, mut hits, mut misses) = (0u64, 0u64, 0u64);
        let (mut new_inserts, mut refreshes, mut evictions) = (0u64, 0u64, 0u64);
        for step in 0..4000 {
            let w = &windows[rng.below(windows.len())];
            let h = hash_tokens(w);
            let resident: HashSet<u64> =
                c.recency_order().iter().map(|w| hash_tokens(w)).collect();
            if rng.f64() < 0.5 {
                probes += 1;
                match c.probe(h, w) {
                    Some(i) => {
                        hits += 1;
                        assert!(resident.contains(&h), "hit on a non-resident window");
                        assert_eq!(c.peek(i).1, latest[&h], "stale token served");
                    }
                    None => {
                        misses += 1;
                        assert!(!resident.contains(&h), "miss on a resident window");
                    }
                }
            } else {
                let pre_len = c.len();
                let tok = step as i32;
                let ev = c.insert(h, w.clone(), row(tok as f32), tok);
                latest.insert(h, tok);
                if resident.contains(&h) {
                    refreshes += 1;
                    assert_eq!(ev, 0, "a refresh never evicts");
                    assert_eq!(c.len(), pre_len, "a refresh never changes occupancy");
                } else {
                    new_inserts += 1;
                    if pre_len == CAP {
                        assert_eq!(ev, 1, "insert at capacity evicts exactly one");
                        assert_eq!(c.len(), CAP);
                    } else {
                        assert_eq!(ev, 0, "no eviction below capacity");
                        assert_eq!(c.len(), pre_len + 1);
                    }
                    evictions += ev;
                }
            }
            assert!(c.len() <= CAP, "occupancy above capacity");
        }
        assert_eq!(hits + misses, probes, "every probe is a hit xor a miss");
        assert_eq!(
            new_inserts,
            evictions + c.len() as u64,
            "every new insert is still resident or was evicted (refreshes {refreshes})"
        );
        assert!(hits > 0 && misses > 0 && evictions > 0, "the workload exercised all paths");
    }
}
