//! Host-side KV prefix cache: prefill avoidance for the serving engine.
//!
//! Every single-row encode (admission or per-row rollover) re-encodes that
//! row's full context window — compute the paper's low-rank activations
//! already halved, re-spent at every join and KV-window rollover. But a
//! row's post-encode KV state is a pure function of its window tokens (the
//! encode rebuilds the row from zeros, and causal attention never crosses
//! rows), so identical windows always produce identical per-row KV slices
//! and the same next token. [`KvPrefixCache`] exploits that: a bounded LRU
//! from window-token hash to `(encoded KV row snapshot, next token)`,
//! filled after real encodes via [`EngineBackend::export_kv_row`] and
//! consulted before every encode. A whole-window hit skips the forward
//! pass entirely and restores the row with
//! [`EngineBackend::import_kv_row`] — repeated prefixes (system prompts,
//! retries, deterministic re-generations after a rollover) cost one host
//! transfer instead of one forward pass. Windows are **left-aligned**
//! (real tokens at offsets `0..len`, trailing pad), so causality gives a
//! second, partial reuse axis: the KV at positions `< b` depends only on
//! tokens `0..b`, and the chunked prefix index below turns that into
//! longest-cached-prefix lookups across requests of *different* lengths.
//!
//! [`EngineBackend::export_kv_row`]: crate::serve::engine::EngineBackend::export_kv_row
//! [`EngineBackend::import_kv_row`]: crate::serve::engine::EngineBackend::import_kv_row
//!
//! # Chunked prefix hash chain
//!
//! With [`with_chunk`](KvPrefixCache::with_chunk) enabled, every resident
//! entry is additionally indexed under `hash(window[..b])` at each chunk
//! boundary `b ≤ len`. [`probe_prefix`](KvPrefixCache::probe_prefix) walks
//! boundaries longest-first and returns `(entry, b)` for the longest
//! *verified* cached prefix — the engine then imports that prefix's KV and
//! prefills only the tail (`keep = b`). Collisions in the boundary index
//! are resolved latest-insert-wins and every candidate is verified
//! token-by-token against the probing window, so a collision degrades to a
//! shorter hit or a miss, never to another prompt's KV.
//!
//! # Byte budgeting and codecs
//!
//! Entries are stored **encoded** through a [`KvCodec`] (`f32` lossless,
//! `f16` half-precision, `rankr` low-rank — see [`kvcodec`] for the error
//! contract of each) and the cache budgets the *encoded payload bytes*, not
//! just the entry count: [`KvPrefixCache::insert`] evicts LRU entries until
//! both the entry cap and the byte budget fit. Byte accounting is exact —
//! [`bytes_resident`](KvPrefixCache::bytes_resident) is the sum of
//! `encoded_bytes()` over resident entries, and every insert reports the
//! bytes it released (evictions plus refresh replacement), so
//! `bytes_inserted − bytes_released == bytes_resident` holds as an
//! invariant (checked exhaustively in `tests/serve_interleave.rs`). One
//! soft spot, by design: a single entry larger than the whole budget is
//! still admitted once the cache is empty (mirroring the `capacity >= 1`
//! floor) — refusing it would disable caching entirely for that geometry.
//!
//! Design notes:
//! - Entries verify the full window on lookup — the hash is the index, not
//!   the identity, so a 64-bit collision degrades to a miss, never to
//!   serving another prompt's KV state.
//! - The cache is worker-local (constructed inside the engine loop), so it
//!   needs no locking and its lifetime matches the backend whose geometry
//!   produced the snapshots.
//! - Probing and reading are split ([`probe`](KvPrefixCache::probe) and
//!   [`probe_prefix`](KvPrefixCache::probe_prefix) touch the LRU order and
//!   return an index; [`decode_into`](KvPrefixCache::decode_into) is a
//!   shared borrow) so the engine decodes into one reused scratch row
//!   before each `import_kv_row`.

use crate::serve::kvcodec::{self, EncodedKvRow, EncodedPlane, KvCodec, PlaneGeom};
use anyhow::Result;
use std::collections::HashMap;

/// Sentinel for "no neighbour" in the intrusive LRU list.
const NIL: usize = usize::MAX;

/// Host-side snapshot of one row's post-prefill KV state, plus the next
/// token that prefill produced for the row. Payload layout is
/// backend-defined (`[n_layers * max_len * n_heads * head_dim]` f32 per
/// plane for the PJRT backend); the cache encodes it on insert and decodes
/// it back on import.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct KvRowState {
    /// Key-cache plane for this row.
    pub k: Vec<f32>,
    /// Value-cache plane for this row.
    pub v: Vec<f32>,
}

/// FNV-1a offset basis — `hash_tokens(&[])`.
const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

/// Fold one token into an FNV-1a state (little-endian bytes). `SlotTable`
/// hashes windows incrementally from its segments with this, so a window
/// never has to be materialised just to be keyed.
pub fn fold_token(mut h: u64, t: i32) -> u64 {
    for b in t.to_le_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// FNV-1a over the window tokens: the cache key. Kept `pub` so
/// `SlotTable::window_hash` and out-of-crate harnesses hash windows exactly
/// the way the cache does.
pub fn hash_tokens(tokens: &[i32]) -> u64 {
    tokens.iter().fold(FNV_OFFSET, |h, &t| fold_token(h, t))
}

struct Entry {
    hash: u64,
    window: Vec<i32>,
    /// Real (non-pad) tokens at the head of `window` — the prefix of the
    /// row's KV snapshot that is valid for *any* window sharing those
    /// tokens (causal attention: KV at position `p` depends only on tokens
    /// `0..=p`). Everything past `len` is padding state.
    len: usize,
    enc: EncodedKvRow,
    next_token: i32,
    /// Exact serialized size of `enc` — the unit of the byte budget.
    bytes: u64,
    /// Chunk-boundary hashes of `window[..b]` registered in `prefix_map`
    /// while this entry is resident, so eviction can unregister them.
    prefix_hashes: Vec<u64>,
    /// Towards MRU (the entry more recently used than this one).
    prev: usize,
    /// Towards LRU.
    next: usize,
}

/// Counter deltas from one cache operation, tallied into the pool's shared
/// counters by the engine.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheEvents {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

/// What one [`KvPrefixCache::insert`] did, for exact byte accounting.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct InsertOutcome {
    /// Entries evicted to make room (0 for a refresh or an in-budget insert).
    pub evicted: u64,
    /// Bytes released: evicted entries' payloads plus, on a refresh, the
    /// replaced payload. `bytes_inserted − Σ bytes_released` always equals
    /// [`bytes_resident`](KvPrefixCache::bytes_resident).
    pub bytes_released: u64,
    /// Encoded size of the inserted payload.
    pub bytes_inserted: u64,
    /// How many bytes the codec saved vs. the lossless f32 baseline for
    /// this payload (0 for the `F32` codec).
    pub bytes_saved: u64,
}

/// Bounded LRU of encoded per-row KV snapshots keyed by window-token hash,
/// budgeted by entry count **and** encoded bytes.
pub struct KvPrefixCache {
    cap: usize,
    /// Byte budget over encoded payloads; 0 means unlimited.
    max_bytes: u64,
    codec: KvCodec,
    geom: PlaneGeom,
    /// Sum of `bytes` over resident entries.
    bytes: u64,
    /// hash → slab index. One entry per hash: a colliding insert replaces
    /// the resident entry (verified windows make this safe, merely lossy).
    map: HashMap<u64, usize>,
    /// Prefix-chain granularity in tokens; 0 disables prefix keying (the
    /// pre-chunking behaviour, and what the exhaustive interleaving model
    /// in `serve::model` checks against).
    chunk: usize,
    /// `hash(window[..b]) → slab index` for every chunk boundary `b` of
    /// every resident entry (latest insert wins on collision). Lookups are
    /// verified token-by-token, so a collision degrades to a shorter hit
    /// or a miss, never to serving another prompt's KV prefix.
    prefix_map: HashMap<u64, usize>,
    slab: Vec<Entry>,
    free: Vec<usize>,
    head: usize,
    tail: usize,
}

impl KvPrefixCache {
    /// A cache holding at most `capacity` rows (`capacity >= 1`; a capacity
    /// of 0 means "disabled" and is handled by the engine, which then never
    /// constructs one), storing lossless `f32` with no byte budget — the
    /// pre-codec behaviour.
    pub fn new(capacity: usize) -> Self {
        Self::with_codec(capacity, 0, KvCodec::F32, PlaneGeom::flat(0))
    }

    /// A cache with an explicit codec, plane geometry, and byte budget
    /// (`max_bytes == 0` means unlimited). `geom` is only consulted by the
    /// rank-r codec, which needs the matrix structure of each plane.
    pub fn with_codec(capacity: usize, max_bytes: u64, codec: KvCodec, geom: PlaneGeom) -> Self {
        let cap = capacity.max(1);
        Self {
            cap,
            max_bytes,
            codec,
            geom,
            bytes: 0,
            map: HashMap::with_capacity(cap),
            chunk: 0,
            prefix_map: HashMap::new(),
            slab: Vec::with_capacity(cap),
            free: Vec::new(),
            head: NIL,
            tail: NIL,
        }
    }

    /// Enable chunked prefix keying: every resident entry is additionally
    /// indexed at real-token boundaries `chunk, 2·chunk, …` so
    /// [`probe_prefix`](Self::probe_prefix) can return the longest cached
    /// prefix of a window that misses whole. 0 disables (the default).
    pub fn with_chunk(mut self, chunk: usize) -> Self {
        self.chunk = chunk;
        self
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Sum of encoded payload bytes over resident entries.
    pub fn bytes_resident(&self) -> u64 {
        self.bytes
    }

    /// Unlink `i` from the recency list.
    fn unlink(&mut self, i: usize) {
        let (p, n) = (self.slab[i].prev, self.slab[i].next);
        if p == NIL {
            self.head = n;
        } else {
            self.slab[p].next = n;
        }
        if n == NIL {
            self.tail = p;
        } else {
            self.slab[n].prev = p;
        }
    }

    /// Link `i` at the MRU head.
    fn push_front(&mut self, i: usize) {
        self.slab[i].prev = NIL;
        self.slab[i].next = self.head;
        if self.head != NIL {
            self.slab[self.head].prev = i;
        }
        self.head = i;
        if self.tail == NIL {
            self.tail = i;
        }
    }

    /// Look up a window. On a verified hit the entry moves to the MRU head
    /// and its slab index is returned — read it with
    /// [`decode_into`](Self::decode_into) (a shared borrow, so a whole
    /// batch of probed rows can be read at once). A hash collision with a
    /// different window is a miss.
    pub fn probe(&mut self, hash: u64, window: &[i32]) -> Option<usize> {
        let &i = self.map.get(&hash)?;
        if self.slab[i].window != window {
            return None;
        }
        if self.head != i {
            self.unlink(i);
            self.push_front(i);
        }
        Some(i)
    }

    /// Longest-cached-prefix lookup for a window that missed whole: walk
    /// the chunk boundaries of `window[..len]` from longest to shortest and
    /// return `(slab index, prefix_len)` for the first resident entry whose
    /// real tokens verifiably share that prefix. The engine then imports
    /// the cached row and prefills only the tail (`keep = prefix_len`). A
    /// hit promotes the donor entry to MRU — it proved itself useful even
    /// though its own window differs. Returns `None` when chunking is
    /// disabled, `len < chunk`, or no boundary matches.
    pub fn probe_prefix(&mut self, window: &[i32], len: usize) -> Option<(usize, usize)> {
        if self.chunk == 0 || len < self.chunk {
            return None;
        }
        let len = len.min(window.len());
        let mut b = (len / self.chunk) * self.chunk;
        while b >= self.chunk {
            let h = hash_tokens(&window[..b]);
            if let Some(&i) = self.prefix_map.get(&h) {
                let e = &self.slab[i];
                if e.len >= b && e.window[..b] == window[..b] {
                    if self.head != i {
                        self.unlink(i);
                        self.push_front(i);
                    }
                    return Some((i, b));
                }
            }
            b -= self.chunk;
        }
        None
    }

    /// The encoded snapshot and next token behind a [`probe`](Self::probe)d
    /// index. Indices stay valid until the next `insert`.
    pub fn peek(&self, idx: usize) -> (&EncodedKvRow, i32) {
        let e = &self.slab[idx];
        (&e.enc, e.next_token)
    }

    /// Decode the snapshot behind a probed index into `out` (cleared
    /// first), so the engine can reuse per-slot scratch buffers across
    /// imports instead of allocating on every elided prefill.
    pub fn decode_into(&self, idx: usize, out: &mut KvRowState) {
        self.slab[idx].enc.decode_into(out);
    }

    /// Evict the least-recently-used entry, returning the bytes it freed
    /// (`None` when the cache is empty). Exposed so harnesses can drive the
    /// eviction path directly; `insert` uses the same mechanism.
    pub fn evict_lru(&mut self) -> Option<u64> {
        let lru = self.tail;
        if lru == NIL {
            return None;
        }
        Some(self.evict_index(lru))
    }

    /// Unregister `i`'s chunk-boundary hashes, but only where the prefix
    /// map still points at `i` — a later insert may have claimed a shared
    /// boundary (latest wins), and that claim must survive `i`'s eviction.
    fn drop_prefix_keys(&mut self, i: usize) {
        for h_idx in 0..self.slab[i].prefix_hashes.len() {
            let h = self.slab[i].prefix_hashes[h_idx];
            if self.prefix_map.get(&h) == Some(&i) {
                self.prefix_map.remove(&h);
            }
        }
        self.slab[i].prefix_hashes.clear();
    }

    fn evict_index(&mut self, i: usize) -> u64 {
        self.unlink(i);
        self.map.remove(&self.slab[i].hash);
        self.drop_prefix_keys(i);
        let e = &mut self.slab[i];
        let freed = e.bytes;
        // drop the payload now — a slot can sit on the free list for a
        // while, and the byte budget is about real resident memory
        e.window = Vec::new();
        e.len = 0;
        e.enc = EncodedKvRow { k: EncodedPlane::F32(Vec::new()), v: EncodedPlane::F32(Vec::new()) };
        e.bytes = 0;
        self.free.push(i);
        self.bytes -= freed;
        freed
    }

    fn over_budget(&self) -> bool {
        self.max_bytes > 0 && self.bytes > self.max_bytes
    }

    /// Register `i`'s chunk boundaries in the prefix map (latest insert
    /// wins a shared boundary) and remember them on the entry for eviction.
    fn register_prefix_keys(&mut self, i: usize) {
        if self.chunk == 0 {
            return;
        }
        let len = self.slab[i].len.min(self.slab[i].window.len());
        let mut b = self.chunk;
        while b <= len {
            let h = hash_tokens(&self.slab[i].window[..b]);
            self.prefix_map.insert(h, i);
            self.slab[i].prefix_hashes.push(h);
            b += self.chunk;
        }
    }

    /// Insert (or refresh) the snapshot for a window whose first `len`
    /// tokens are real (the rest padding), encoding it under the cache's
    /// codec and evicting LRU entries until both the entry cap and the
    /// byte budget fit. Errors only on codec misuse (a rank-r geometry
    /// that does not match the payload), never on capacity.
    pub fn insert(
        &mut self,
        hash: u64,
        window: Vec<i32>,
        len: usize,
        kv: &KvRowState,
        next_token: i32,
    ) -> Result<InsertOutcome> {
        let enc = kvcodec::encode_row(kv, self.codec, self.geom)?;
        let new_bytes = enc.encoded_bytes();
        let len = len.min(window.len());
        let mut out = InsertOutcome {
            evicted: 0,
            bytes_released: 0,
            bytes_inserted: new_bytes,
            bytes_saved: kvcodec::f32_row_bytes(kv).saturating_sub(new_bytes),
        };
        if let Some(&i) = self.map.get(&hash) {
            // refresh (or hash-collision replacement — last writer wins):
            // the window (and so its chunk boundaries) may have changed
            self.drop_prefix_keys(i);
            let e = &mut self.slab[i];
            out.bytes_released += e.bytes;
            self.bytes = self.bytes - e.bytes + new_bytes;
            e.window = window;
            e.len = len;
            e.enc = enc;
            e.next_token = next_token;
            e.bytes = new_bytes;
            if self.head != i {
                self.unlink(i);
                self.push_front(i);
            }
            self.register_prefix_keys(i);
            // a grown payload can overflow the budget: shrink, but never
            // evict the entry just refreshed (it is the MRU head anyway)
            while self.over_budget() && self.tail != i {
                out.bytes_released += self.evict_index(self.tail);
                out.evicted += 1;
            }
            return Ok(out);
        }
        while self.map.len() >= self.cap {
            out.bytes_released += self.evict_index(self.tail);
            out.evicted += 1;
        }
        while self.max_bytes > 0
            && !self.map.is_empty()
            && self.bytes + new_bytes > self.max_bytes
        {
            out.bytes_released += self.evict_index(self.tail);
            out.evicted += 1;
        }
        let entry = Entry {
            hash,
            window,
            len,
            enc,
            next_token,
            bytes: new_bytes,
            prefix_hashes: Vec::new(),
            prev: NIL,
            next: NIL,
        };
        let i = match self.free.pop() {
            Some(i) => {
                self.slab[i] = entry;
                i
            }
            None => {
                self.slab.push(entry);
                self.slab.len() - 1
            }
        };
        self.map.insert(hash, i);
        self.push_front(i);
        self.bytes += new_bytes;
        self.register_prefix_keys(i);
        Ok(out)
    }

    /// MRU-first window snapshots (test/debug aid).
    #[cfg(test)]
    fn recency_order(&self) -> Vec<&[i32]> {
        let mut out = Vec::new();
        let mut i = self.head;
        while i != NIL {
            out.push(self.slab[i].window.as_slice());
            i = self.slab[i].next;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(x: f32) -> KvRowState {
        KvRowState { k: vec![x; 4], v: vec![-x; 4] }
    }

    /// Encoded f32 size of `row(_)`: two planes of 4 f32 each.
    const ROW_BYTES: u64 = 2 * (5 + 4 * 4);

    fn put(c: &mut KvPrefixCache, w: &[i32], next: i32) -> u64 {
        c.insert(hash_tokens(w), w.to_vec(), w.len(), &row(next as f32), next).unwrap().evicted
    }

    fn get(c: &mut KvPrefixCache, w: &[i32]) -> Option<i32> {
        c.probe(hash_tokens(w), w).map(|i| c.peek(i).1)
    }

    #[test]
    fn hash_is_stable_and_window_sensitive() {
        assert_eq!(hash_tokens(&[1, 2, 3]), hash_tokens(&[1, 2, 3]));
        assert_ne!(hash_tokens(&[1, 2, 3]), hash_tokens(&[3, 2, 1]));
        assert_ne!(hash_tokens(&[0]), hash_tokens(&[0, 0]), "padding length matters");
    }

    #[test]
    fn hit_returns_snapshot_and_next_token() {
        let mut c = KvPrefixCache::new(4);
        assert!(get(&mut c, &[1, 2]).is_none(), "cold cache misses");
        put(&mut c, &[1, 2], 3);
        let i = c.probe(hash_tokens(&[1, 2]), &[1, 2]).unwrap();
        let (_, next) = c.peek(i);
        assert_eq!(next, 3);
        let mut kv = KvRowState::default();
        c.decode_into(i, &mut kv);
        assert_eq!(kv, row(3.0), "f32 codec decodes bit-identically");
        assert_eq!(c.len(), 1);
        assert_eq!(c.bytes_resident(), ROW_BYTES);
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let mut c = KvPrefixCache::new(2);
        assert_eq!(put(&mut c, &[1], 10), 0);
        assert_eq!(put(&mut c, &[2], 20), 0);
        // touch [1] so [2] is LRU
        assert_eq!(get(&mut c, &[1]), Some(10));
        assert_eq!(put(&mut c, &[3], 30), 1, "insert past capacity evicts");
        assert_eq!(c.len(), 2);
        assert!(get(&mut c, &[2]).is_none(), "LRU entry [2] was evicted");
        assert_eq!(get(&mut c, &[1]), Some(10));
        assert_eq!(get(&mut c, &[3]), Some(30));
    }

    #[test]
    fn refresh_updates_payload_without_eviction() {
        let mut c = KvPrefixCache::new(2);
        put(&mut c, &[5], 1);
        let out = c.insert(hash_tokens(&[5]), vec![5], 1, &row(2.0), 2).unwrap();
        assert_eq!(out.evicted, 0, "same window refreshes in place");
        assert_eq!(out.bytes_released, ROW_BYTES, "the replaced payload is released");
        assert_eq!(c.len(), 1);
        assert_eq!(c.bytes_resident(), ROW_BYTES);
        assert_eq!(get(&mut c, &[5]), Some(2));
    }

    #[test]
    fn recency_order_tracks_probes_and_inserts() {
        let mut c = KvPrefixCache::new(3);
        put(&mut c, &[1], 1);
        put(&mut c, &[2], 2);
        put(&mut c, &[3], 3);
        assert_eq!(c.recency_order(), vec![&[3][..], &[2], &[1]]);
        get(&mut c, &[1]);
        assert_eq!(c.recency_order(), vec![&[1][..], &[3], &[2]]);
    }

    #[test]
    fn collision_with_different_window_is_a_verified_miss() {
        let mut c = KvPrefixCache::new(2);
        let h = hash_tokens(&[7, 8]);
        c.insert(h, vec![7, 8], 2, &row(1.0), 1).unwrap();
        // same hash, different tokens: must NOT serve the resident entry
        assert!(c.probe(h, &[9, 9]).is_none());
        assert!(c.probe(h, &[7, 8]).is_some(), "the real window still hits");
    }

    #[test]
    fn slab_slots_are_reused_after_eviction() {
        let mut c = KvPrefixCache::new(2);
        for x in 0..20 {
            put(&mut c, &[x], x);
        }
        assert_eq!(c.len(), 2);
        assert!(c.slab.len() <= 3, "evicted slots recycle instead of growing the slab");
        assert_eq!(get(&mut c, &[19]), Some(19));
        assert_eq!(get(&mut c, &[18]), Some(18));
    }

    #[test]
    fn single_entry_cache_works() {
        let mut c = KvPrefixCache::new(1);
        put(&mut c, &[1], 1);
        assert_eq!(put(&mut c, &[2], 2), 1);
        assert!(get(&mut c, &[1]).is_none());
        assert_eq!(get(&mut c, &[2]), Some(2));
    }

    #[test]
    fn byte_budget_evicts_until_the_new_entry_fits() {
        // Budget for exactly two rows; the entry cap is slack.
        let c_budget = 2 * ROW_BYTES;
        let mut c = KvPrefixCache::with_codec(16, c_budget, KvCodec::F32, PlaneGeom::flat(4));
        assert_eq!(put(&mut c, &[1], 1), 0);
        assert_eq!(put(&mut c, &[2], 2), 0);
        assert_eq!(c.bytes_resident(), c_budget);
        assert_eq!(put(&mut c, &[3], 3), 1, "third row exceeds the byte budget");
        assert_eq!(c.len(), 2);
        assert_eq!(c.bytes_resident(), c_budget);
        assert!(get(&mut c, &[1]).is_none(), "LRU went first");
        assert_eq!(get(&mut c, &[2]), Some(2));
        assert_eq!(get(&mut c, &[3]), Some(3));
    }

    #[test]
    fn oversized_entry_is_admitted_into_an_empty_cache() {
        // Budget below one row: everything resident is evicted, then the
        // row is admitted anyway (the documented capacity >= 1 floor).
        let mut c = KvPrefixCache::with_codec(16, ROW_BYTES / 2, KvCodec::F32, PlaneGeom::flat(4));
        assert_eq!(put(&mut c, &[1], 1), 0);
        assert_eq!(c.len(), 1, "oversized row admitted while empty");
        let out = c.insert(hash_tokens(&[2]), vec![2], 1, &row(2.0), 2).unwrap();
        assert_eq!(out.evicted, 1, "the resident oversized row makes room first");
        assert_eq!(c.len(), 1);
        assert_eq!(c.bytes_resident(), ROW_BYTES);
    }

    #[test]
    fn evict_lru_frees_bytes_and_reports_them() {
        let mut c = KvPrefixCache::new(4);
        put(&mut c, &[1], 1);
        put(&mut c, &[2], 2);
        assert_eq!(c.evict_lru(), Some(ROW_BYTES));
        assert_eq!(c.len(), 1);
        assert_eq!(c.bytes_resident(), ROW_BYTES);
        assert!(get(&mut c, &[1]).is_none(), "eviction took the LRU entry");
        assert_eq!(c.evict_lru(), Some(ROW_BYTES));
        assert_eq!(c.evict_lru(), None, "empty cache has nothing to evict");
        assert_eq!(c.bytes_resident(), 0);
    }

    #[test]
    fn f16_codec_doubles_entries_per_byte() {
        let f16_row = 2 * (5 + 2 * 4);
        let mut c = KvPrefixCache::with_codec(16, 2 * ROW_BYTES, KvCodec::F16, PlaneGeom::flat(4));
        for w in 1..=4 {
            put(&mut c, &[w], w);
        }
        assert_eq!(c.len(), 4, "the f16 budget holds twice the f32 rows");
        assert_eq!(c.bytes_resident(), 4 * f16_row);
        let out = c.insert(hash_tokens(&[9]), vec![9], 1, &row(9.0), 9).unwrap();
        assert_eq!(out.bytes_saved, ROW_BYTES - f16_row);
        let i = c.probe(hash_tokens(&[2]), &[2]).unwrap();
        let mut kv = KvRowState::default();
        c.decode_into(i, &mut kv);
        assert_eq!(kv, row(2.0), "small integers survive f16 exactly");
    }

    #[test]
    fn prefix_probe_returns_longest_verified_prefix() {
        let mut c = KvPrefixCache::new(8).with_chunk(2);
        // entry: 6 real tokens, chunk boundaries at 2/4/6
        put(&mut c, &[10, 11, 12, 13, 14, 15], 1);
        // shorter window sharing 4 real tokens → longest boundary ≤ 4 is 4
        assert_eq!(c.probe_prefix(&[10, 11, 12, 13, 99, 0], 4), Some((0, 4)));
        // only the first chunk shared → falls back to boundary 2
        assert_eq!(c.probe_prefix(&[10, 11, 99, 98, 97, 0], 5), Some((0, 2)));
        // nothing shared → miss
        assert!(c.probe_prefix(&[77, 78, 79, 0, 0, 0], 3).is_none());
        // below one chunk of real tokens → no boundary to try
        assert!(c.probe_prefix(&[10, 11, 12, 0, 0, 0], 1).is_none());
        // a probe len past the window clamps instead of slicing out of range
        assert_eq!(c.probe_prefix(&[10, 11], 9), Some((0, 2)));
    }

    #[test]
    fn prefix_probe_never_matches_into_padding_state() {
        let mut c = KvPrefixCache::new(8).with_chunk(2);
        // entry has only 3 real tokens; window[3] is padding state
        c.insert(
            hash_tokens(&[10, 11, 12, 0]),
            vec![10, 11, 12, 0],
            3,
            &row(1.0),
            1,
        )
        .unwrap();
        // boundary 4 would need 4 real tokens — only boundary 2 may hit,
        // even when the probed window matches the stored one byte-for-byte
        assert_eq!(c.probe_prefix(&[10, 11, 12, 0], 4), Some((0, 2)));
    }

    #[test]
    fn prefix_keys_follow_eviction_and_latest_insert_wins() {
        let mut c = KvPrefixCache::new(2).with_chunk(2);
        put(&mut c, &[1, 2, 3, 4], 10);
        // same first chunk: the newer entry claims boundary 2
        put(&mut c, &[1, 2, 9, 9], 20);
        let (i, b) = c.probe_prefix(&[1, 2, 5, 5], 2).unwrap();
        assert_eq!(b, 2);
        assert_eq!(c.peek(i).1, 20, "latest insert owns the shared boundary");
        // boundary 4 of the older entry still resolves to it
        assert_eq!(c.probe_prefix(&[1, 2, 3, 4], 4).map(|(i, b)| (c.peek(i).1, b)), Some((10, 4)));
        // evicting both (capacity 2) must unregister their boundaries
        put(&mut c, &[7, 7, 7, 7], 30);
        put(&mut c, &[8, 8, 8, 8], 40);
        assert!(c.probe_prefix(&[1, 2, 3, 4], 4).is_none(), "evicted prefixes are gone");
        assert_eq!(c.probe_prefix(&[8, 8, 1, 1], 2).map(|(_, b)| b), Some(2));
    }

    #[test]
    fn prefix_probe_is_disabled_at_chunk_zero() {
        let mut c = KvPrefixCache::new(4);
        put(&mut c, &[1, 2, 3, 4], 10);
        assert!(c.probe_prefix(&[1, 2, 3, 4], 4).is_none(), "chunk 0 = whole-window only");
    }

    /// Eviction-accounting conservation under random thrash: across a long
    /// mixed probe/insert workload over 3x-capacity distinct windows,
    /// hits + misses == probes, every probe outcome agrees with the actual
    /// resident set, occupancy never exceeds capacity, every *new* insert
    /// is conserved as either a still-resident entry or a reported eviction
    /// (`new_inserts == evictions + len`), and the byte ledger balances:
    /// `bytes_inserted − bytes_released == bytes_resident`.
    #[test]
    fn eviction_accounting_is_conserved_under_thrash() {
        use crate::util::rng::Rng;
        use std::collections::{HashMap, HashSet};
        const CAP: usize = 8;
        let windows: Vec<Vec<i32>> = (0..24).map(|w| vec![w, 7 * w + 1, 3]).collect();
        for a in 0..windows.len() {
            for b in (a + 1)..windows.len() {
                assert_ne!(hash_tokens(&windows[a]), hash_tokens(&windows[b]));
            }
        }
        let mut rng = Rng::new(0xC0_1A);
        let mut c = KvPrefixCache::new(CAP);
        let mut latest: HashMap<u64, i32> = HashMap::new();
        let (mut probes, mut hits, mut misses) = (0u64, 0u64, 0u64);
        let (mut new_inserts, mut refreshes, mut evictions) = (0u64, 0u64, 0u64);
        let (mut bytes_in, mut bytes_out) = (0u64, 0u64);
        for step in 0..4000 {
            let w = &windows[rng.below(windows.len())];
            let h = hash_tokens(w);
            let resident: HashSet<u64> =
                c.recency_order().iter().map(|w| hash_tokens(w)).collect();
            if rng.f64() < 0.5 {
                probes += 1;
                match c.probe(h, w) {
                    Some(i) => {
                        hits += 1;
                        assert!(resident.contains(&h), "hit on a non-resident window");
                        assert_eq!(c.peek(i).1, latest[&h], "stale token served");
                    }
                    None => {
                        misses += 1;
                        assert!(!resident.contains(&h), "miss on a resident window");
                    }
                }
            } else {
                let pre_len = c.len();
                let tok = step as i32;
                let out = c.insert(h, w.clone(), w.len(), &row(tok as f32), tok).unwrap();
                bytes_in += out.bytes_inserted;
                bytes_out += out.bytes_released;
                latest.insert(h, tok);
                if resident.contains(&h) {
                    refreshes += 1;
                    assert_eq!(out.evicted, 0, "a refresh never evicts");
                    assert_eq!(c.len(), pre_len, "a refresh never changes occupancy");
                } else {
                    new_inserts += 1;
                    if pre_len == CAP {
                        assert_eq!(out.evicted, 1, "insert at capacity evicts exactly one");
                        assert_eq!(c.len(), CAP);
                    } else {
                        assert_eq!(out.evicted, 0, "no eviction below capacity");
                        assert_eq!(c.len(), pre_len + 1);
                    }
                    evictions += out.evicted;
                }
            }
            assert!(c.len() <= CAP, "occupancy above capacity");
            assert_eq!(
                bytes_in - bytes_out,
                c.bytes_resident(),
                "byte ledger must balance at every step"
            );
            assert_eq!(c.bytes_resident(), c.len() as u64 * ROW_BYTES);
        }
        assert_eq!(hits + misses, probes, "every probe is a hit xor a miss");
        assert_eq!(
            new_inserts,
            evictions + c.len() as u64,
            "every new insert is still resident or was evicted (refreshes {refreshes})"
        );
        assert!(hits > 0 && misses > 0 && evictions > 0, "the workload exercised all paths");
    }
}
