//! The public serving API: [`InferenceService`] implemented by
//! [`ServicePool`], a pool of single-artifact engine workers behind a
//! bounded admission queue.
//!
//! Callers [`submit`](InferenceService::submit) a prompt with typed
//! [`SubmitOptions`] and get back a [`TokenStream`]: tokens arrive as they
//! decode, the request can be cancelled mid-flight, and the stream resolves
//! to a typed [`Completion`] with a finish reason and timing breakdown.
//! Admission is explicitly backpressured — when the queue is at
//! `queue_depth` the submit fails with [`SubmitError::QueueFull`] instead of
//! buffering unboundedly.

use crate::config::ServeConfig;
use crate::metrics;
use crate::runtime::ArtifactDir;
use crate::serve::engine;
use crate::serve::queue::{BoundedQueue, PushError};
use crate::serve::slots;
use crate::serve::supervisor::{BreakerState, CircuitBreaker, Supervisor};
use crate::serve::sync::{
    self, Arc, channel, Countdown, Counter, Ewma, Flag, Gauge, JoinHandle, LockRank, Mutex,
    Receiver, Sender,
};
use anyhow::{Context, Result};
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------------
// Request-side types
// ---------------------------------------------------------------------------

/// Scheduling class: `High` drains before `Normal`; FIFO within a class.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Priority {
    High,
    #[default]
    Normal,
}

/// Per-request knobs. `None` fields fall back to the pool's [`ServeConfig`].
#[derive(Clone, Debug, Default)]
pub struct SubmitOptions {
    /// Cap on generated tokens; `None` → `ServeConfig::max_new_tokens`.
    pub max_new_tokens: Option<usize>,
    /// Generation stops when one of these is produced (the stop token is
    /// included in the output). Empty = run to the length cap.
    pub stop_tokens: Vec<i32>,
    /// Wall-clock budget from submit time; `None` →
    /// `ServeConfig::default_deadline_ms` (0 there = unbounded).
    pub deadline: Option<Duration>,
    pub priority: Priority,
}

/// Why a submit was refused. `QueueFull` and `ShuttingDown` are retryable;
/// `AdmissionOnly` is a configuration fact that never clears on its own.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// The admission queue is at `queue_depth` — shed load or retry later.
    QueueFull,
    /// The pool is shutting down (or already shut down).
    ShuttingDown,
    /// The pool has `workers == 0`: it admits and queues but never drains,
    /// so a blocking submit could never return. Typed instead of a runtime
    /// assert so a misconfigured pool cannot panic its caller.
    AdmissionOnly,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull => write!(f, "admission queue full"),
            SubmitError::ShuttingDown => write!(f, "service shutting down"),
            SubmitError::AdmissionOnly => {
                write!(f, "admission-only pool (workers=0) never drains its queue")
            }
        }
    }
}

impl std::error::Error for SubmitError {}

/// How a request ended.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// Hit its `max_new_tokens` budget.
    Length,
    /// Produced a stop token.
    Stop,
    /// Cancelled via [`TokenStream::cancel`] / [`CancelHandle`], or shed at
    /// shutdown before running.
    Cancelled,
    /// Its deadline passed (while queued or mid-decode; partial tokens are
    /// still delivered).
    DeadlineExpired,
    /// Shed at admission: the pool's EWMA-measured prefill/decode rates say
    /// the deadline cannot be met, so no prefill is burned on it.
    Shed,
    /// The engine failed while this request was in flight and its retry
    /// budget is spent; `retries` says how many redispatches were attempted
    /// before giving up (partial tokens are still delivered).
    Error {
        retries: u32,
    },
}

/// Where the request's wall-clock went (all measured from submit).
#[derive(Clone, Copy, Debug)]
pub struct Timing {
    /// Time spent in the admission queue before a slot picked it up.
    pub queued: Duration,
    /// Time to first streamed token (`None` if it never produced one).
    pub first_token: Option<Duration>,
    /// End-to-end latency.
    pub total: Duration,
}

/// Final result of one request.
#[derive(Clone, Debug)]
pub struct Completion {
    pub tokens: Vec<i32>,
    pub finish_reason: FinishReason,
    pub timing: Timing,
}

/// One streamed event: a decoded token, or the terminal completion.
#[derive(Clone, Debug)]
pub enum StreamEvent {
    Token(i32),
    Done(Completion),
}

/// Clonable cancel switch detached from the stream (so one thread can wait
/// while another cancels).
#[derive(Clone)]
pub struct CancelHandle(Arc<Flag>);

impl CancelHandle {
    pub fn cancel(&self) {
        self.0.set();
    }

    pub fn is_cancelled(&self) -> bool {
        self.0.get()
    }
}

/// Receiving side of one request: yields tokens as the engine decodes them
/// and resolves to a [`Completion`].
pub struct TokenStream {
    rx: Receiver<StreamEvent>,
    cancel: Arc<Flag>,
    done: Option<Completion>,
    disconnected: bool,
}

impl TokenStream {
    /// Blocking receive of the next event. Returns `None` once the terminal
    /// [`StreamEvent::Done`] has been consumed (or if the engine dropped the
    /// request — see [`TokenStream::wait`] for the error-reporting variant).
    pub fn recv(&mut self) -> Option<StreamEvent> {
        if self.done.is_some() || self.disconnected {
            return None;
        }
        match self.rx.recv() {
            Ok(ev) => {
                if let StreamEvent::Done(c) = &ev {
                    self.done = Some(c.clone());
                }
                Some(ev)
            }
            Err(_) => {
                self.disconnected = true;
                None
            }
        }
    }

    /// Request cancellation; the engine vacates the row at the next decode
    /// step and the stream resolves with [`FinishReason::Cancelled`].
    pub fn cancel(&self) {
        self.cancel.set();
    }

    /// A clonable cancel switch for this request.
    pub fn cancel_handle(&self) -> CancelHandle {
        CancelHandle(self.cancel.clone())
    }

    /// Drain the stream to its terminal completion (blocking).
    pub fn wait(mut self) -> Result<Completion> {
        if let Some(c) = self.done.take() {
            return Ok(c);
        }
        loop {
            match self.rx.recv() {
                Ok(StreamEvent::Token(_)) => continue,
                Ok(StreamEvent::Done(c)) => return Ok(c),
                Err(_) => anyhow::bail!("serve worker dropped the request stream"),
            }
        }
    }
}

/// A submitted request as it crosses into the worker threads — the
/// engine-side twin of a [`TokenStream`]. Public so out-of-crate harnesses
/// (property tests, custom `EngineBackend` schedulers) can drive a
/// `SlotTable` directly; in normal operation only `ServicePool::submit`
/// constructs these.
pub struct QueuedRequest {
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    pub stop_tokens: Vec<i32>,
    pub deadline: Option<Instant>,
    pub submitted_at: Instant,
    /// Stream events (tokens, then the terminal completion) go out here.
    pub tx: Sender<StreamEvent>,
    /// Cooperative cancel flag shared with the [`TokenStream`].
    pub cancel: Arc<Flag>,
    /// Tokens already streamed to the client before a worker fault salvaged
    /// this request (empty on first admission). `SlotTable::admit` folds
    /// them back into the row's context so a redispatched request resumes
    /// exactly where its stream paused instead of re-sending tokens.
    pub emitted: Vec<i32>,
    /// How many times this request has been redispatched after worker
    /// faults; checked against `ServeConfig::retry_budget`.
    pub retries: u32,
}

// ---------------------------------------------------------------------------
// Service trait + pool
// ---------------------------------------------------------------------------

/// Counter/gauge snapshot of a pool (see [`InferenceService::stats`]).
#[derive(Clone, Copy, Debug)]
pub struct ServiceStats {
    pub workers: usize,
    /// Requests currently waiting for a slot.
    pub queue_depth: usize,
    pub queue_capacity: usize,
    /// Rows currently decoding across all workers.
    pub active: usize,
    pub submitted: u64,
    /// Finished with `Length` or `Stop`.
    pub completed: u64,
    pub cancelled: u64,
    pub expired: u64,
    /// Submits refused with `QueueFull`.
    pub rejected: u64,
    /// Finished with `Error` (engine batch failure).
    pub failed: u64,
    /// Useful (non-dummy) tokens produced by decode steps.
    pub decoded_tokens: u64,
    /// Useful tokens per second of *aggregate worker busy time* — a
    /// per-worker average, not wall-clock pool throughput (with N busy
    /// workers, wall-clock throughput is up to N× this).
    pub decode_tokens_per_sec: f64,
    /// Real (non-elided) single-row prefills executed by the backend.
    pub prefill_calls: u64,
    /// Row encodes served entirely from the KV prefix cache — no forward
    /// pass ran (see `serve::kvcache`).
    pub prefills_elided: u64,
    /// Worker busy-time spent inside real prefill calls.
    pub prefill_nanos: u64,
    /// Rows admitted and encoded while at least one other row of the same
    /// batch kept its decode state — the barrier-free joins that would each
    /// have forced a whole-batch re-prefill under the shared-`pos` engine.
    pub rows_joined_midflight: u64,
    /// Whole-window cache misses whose longest cached prefix chunk hit, so
    /// only the window tail was prefilled (see `serve::kvcache`).
    pub partial_prefix_hits: u64,
    /// Window positions restored from cached prefixes instead of being
    /// re-prefilled, summed over partial-prefix hits.
    pub partial_prefix_tokens_saved: u64,
    /// Total admission→row-live latency, summed over fresh joins: how long
    /// admitted requests waited for their single-row encode (queue wait
    /// before admission is reported per-request via `Timing::queued`).
    pub join_wait_nanos: u64,
    /// Per-row KV prefix-cache lookups that found the window.
    pub kv_cache_hits: u64,
    /// Per-row KV prefix-cache lookups that missed.
    pub kv_cache_misses: u64,
    /// Rows evicted from the KV prefix cache (LRU, bounded capacity and/or
    /// byte budget).
    pub kv_cache_evictions: u64,
    /// Encoded bytes currently resident in the KV prefix caches across all
    /// workers (exact: `encoded_bytes()` of every live entry).
    pub kv_bytes_resident: u64,
    /// Cumulative bytes saved by the KV codec versus raw f32 snapshots
    /// (`f32_row_bytes − encoded_bytes`, summed over inserts).
    pub kv_bytes_saved: u64,
    /// Worker busy-time spent decoding cached KV rows on elided prefills.
    pub kv_decode_nanos: u64,
    /// Worker panics caught by the supervised worker loop or observed at
    /// shutdown join time.
    pub worker_panics: u64,
    /// Workers respawned after a fatal worker error (restart budget).
    pub worker_restarts: u64,
    /// In-flight requests salvaged from a faulted worker and requeued.
    pub requests_redispatched: u64,
    /// Total redispatch attempts summed over requests (a request salvaged
    /// twice counts twice).
    pub retries: u64,
    /// Requests shed at admission because the EWMA rate estimates said
    /// their deadline was infeasible (`FinishReason::Shed`).
    pub shed_infeasible: u64,
    /// Requests whose deadline had already passed when a worker popped them
    /// (subset of `expired`; they never burned a prefill).
    pub shed_expired: u64,
    /// Circuit-breaker state at snapshot time.
    pub breaker_state: BreakerState,
    /// Transitions into `Open` (including probe failures re-opening).
    pub breaker_opens: u64,
    /// Transitions back to `Healthy` from a non-healthy state.
    pub breaker_recoveries: u64,
}

#[derive(Default)]
pub(crate) struct Counters {
    pub(crate) submitted: Counter,
    pub(crate) completed: Counter,
    pub(crate) cancelled: Counter,
    pub(crate) expired: Counter,
    pub(crate) rejected: Counter,
    pub(crate) failed: Counter,
    pub(crate) decoded_tokens: Counter,
    pub(crate) decode_nanos: Counter,
    pub(crate) prefill_calls: Counter,
    pub(crate) prefills_elided: Counter,
    pub(crate) prefill_nanos: Counter,
    pub(crate) rows_joined_midflight: Counter,
    pub(crate) partial_prefix_hits: Counter,
    pub(crate) partial_prefix_tokens_saved: Counter,
    pub(crate) join_wait_nanos: Counter,
    pub(crate) kv_cache_hits: Counter,
    pub(crate) kv_cache_misses: Counter,
    pub(crate) kv_cache_evictions: Counter,
    pub(crate) kv_bytes_saved: Counter,
    pub(crate) kv_decode_nanos: Counter,
    pub(crate) kv_bytes_resident: Gauge,
    pub(crate) active: Gauge,
    pub(crate) live_workers: Countdown,
    pub(crate) worker_panics: Counter,
    pub(crate) worker_restarts: Counter,
    pub(crate) requests_redispatched: Counter,
    pub(crate) retries: Counter,
    pub(crate) shed_infeasible: Counter,
    pub(crate) shed_expired: Counter,
    /// EWMA nanoseconds per real prefill call (admission feasibility input).
    pub(crate) prefill_ewma: Ewma,
    /// EWMA nanoseconds per decoded token (admission feasibility input).
    pub(crate) decode_ewma: Ewma,
}

/// State shared between the submit side and every worker thread.
pub(crate) struct Shared {
    pub(crate) queue: BoundedQueue<QueuedRequest>,
    pub(crate) counters: Counters,
    pub(crate) supervisor: Supervisor,
}

/// A generation service: submit prompts, observe load, shut down.
pub trait InferenceService {
    /// Enqueue a prompt for generation. Non-blocking: backpressure surfaces
    /// as [`SubmitError::QueueFull`].
    fn submit(&self, prompt: Vec<i32>, opts: SubmitOptions) -> Result<TokenStream, SubmitError>;

    /// Snapshot of queue/slot occupancy and lifetime counters.
    fn stats(&self) -> ServiceStats;

    /// Stop admissions, resolve queued requests as `Cancelled`, finish
    /// in-flight rows, and join the workers. Idempotent.
    fn shutdown(&self);
}

/// [`InferenceService`] over N engine worker threads sharing one admission
/// queue. PJRT objects are `Rc`-based (not `Send`), so each worker owns its
/// own client, compiled executables, params and KV caches (see
/// `runtime::client()`); the pool only ever touches the queue and counters.
pub struct ServicePool {
    cfg: ServeConfig,
    shared: Arc<Shared>,
    workers: Mutex<Vec<JoinHandle<()>>>,
}

impl ServicePool {
    /// Validate the artifact and spawn `cfg.workers` PJRT engine threads.
    ///
    /// Fails fast (before any thread starts) when the artifact is missing or
    /// was not built with `--serve`. `workers == 0` is allowed: the pool
    /// only admits/queues, which is useful for exercising backpressure.
    pub fn start(cfg: ServeConfig) -> Result<Self> {
        let art = ArtifactDir::open_named(&cfg.artifact)?;
        art.manifest
            .serve_batch
            .context("artifact not built with --serve (no serve_batch in manifest)")?;
        let artifact = cfg.artifact.clone();
        Self::start_with(cfg, move |_worker| {
            let backend = engine::PjrtBackend::open(&artifact)?;
            Ok(Box::new(backend) as Box<dyn engine::EngineBackend>)
        })
    }

    /// Spawn `cfg.workers` engine threads over an arbitrary
    /// [`EngineBackend`](engine::EngineBackend) factory. The factory runs
    /// *inside* each worker thread (backends may hold non-`Send` state, as
    /// the PJRT backend does) and receives the worker index.
    ///
    /// This is the artifact-free entry point: hand it a
    /// [`MockBackend`](crate::serve::mock::MockBackend) factory and the full
    /// scheduling surface runs hermetically.
    pub fn start_with<F>(cfg: ServeConfig, factory: F) -> Result<Self>
    where
        F: Fn(usize) -> Result<Box<dyn engine::EngineBackend>> + Send + Sync + 'static,
    {
        let shared = Arc::new(Shared {
            queue: BoundedQueue::new(cfg.queue_depth),
            counters: Counters::default(),
            supervisor: Supervisor::new(
                cfg.restart_budget,
                CircuitBreaker::new(
                    cfg.breaker_open_after,
                    cfg.breaker_recover_after,
                    Duration::from_millis(cfg.breaker_cooldown_ms),
                ),
            ),
        });
        shared.counters.live_workers.set(cfg.workers);
        let factory = Arc::new(factory);
        let mut handles = Vec::new();
        for w in 0..cfg.workers {
            let factory = factory.clone();
            let shared = shared.clone();
            let eopts = engine::EngineOptions {
                kv_cache_entries: cfg.kv_cache_entries,
                kv_cache_bytes: cfg.kv_cache_bytes,
                kv_codec: cfg.kv_codec.with_rank(cfg.kv_rank),
                join_chunk: cfg.join_chunk,
                retry_budget: cfg.retry_budget,
            };
            handles.push(sync::spawn_named(&format!("cola-serve-{w}"), move || {
                // Supervision loop: a worker that dies (panic caught inside
                // `run_worker`, persistent backend errors, or a factory
                // failure) is respawned with a *fresh* backend while the
                // pool-wide restart budget lasts. In-flight requests were
                // already salvaged back into the queue by `run_worker`.
                loop {
                    let res = (*factory)(w).and_then(|mut backend| {
                        engine::run_worker(backend.as_mut(), &shared, &eopts)
                    });
                    match res {
                        Ok(()) => break, // queue closed: clean exit
                        Err(e) => {
                            metrics::log_info(&format!("serve worker {w} died: {e:#}"));
                            shared.supervisor.breaker.record_failure();
                            if !shared.supervisor.try_restart() {
                                metrics::log_info(&format!(
                                    "serve worker {w}: restart budget spent; not respawning"
                                ));
                                break;
                            }
                            shared.counters.worker_restarts.add(1);
                        }
                    }
                }
                // Last worker out closes the shop: otherwise a pool whose
                // workers all died (e.g. artifact compile failure) would
                // leave queued clients blocked forever and submitters
                // spinning on QueueFull.
                if shared.counters.live_workers.arrive() {
                    let now = Instant::now();
                    for req in shared.queue.close() {
                        let retries = req.retries;
                        slots::complete_unstarted(req, FinishReason::Error { retries }, now);
                        shared.counters.failed.add(1);
                    }
                }
            })?);
        }
        Ok(Self { cfg, shared, workers: Mutex::new(LockRank::PoolWorkers, handles) })
    }

    /// The configuration this pool was started with.
    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    /// Blocking convenience: submit and wait for the completion.
    pub fn generate(&self, prompt: Vec<i32>, opts: SubmitOptions) -> Result<Completion> {
        self.submit(prompt, opts)
            .map_err(|e| anyhow::anyhow!("submit failed: {e}"))?
            .wait()
    }

    /// Blocking submit: rides out `QueueFull` backpressure (sleep + retry)
    /// until the request is admitted; fails if the pool is shutting down.
    /// Refused outright with the typed [`SubmitError::AdmissionOnly`] on a
    /// `workers == 0` pool, where the queue never drains and the retry loop
    /// could never return.
    pub fn submit_wait(&self, prompt: Vec<i32>, opts: SubmitOptions) -> Result<TokenStream> {
        if self.cfg.workers == 0 {
            return Err(SubmitError::AdmissionOnly.into());
        }
        loop {
            match self.submit(prompt.clone(), opts.clone()) {
                Ok(s) => return Ok(s),
                Err(SubmitError::QueueFull) => {
                    sync::sleep(Duration::from_millis(1));
                }
                Err(e) => anyhow::bail!("submit failed: {e}"),
            }
        }
    }

    /// Circuit-breaker admission check (may move `Open` → `HalfOpen` when
    /// the cooldown has elapsed, admitting one probe). `ModelRouter`
    /// consults this before queueing; direct `submit` on the pool
    /// deliberately bypasses it so local harnesses can keep driving a pool
    /// whose breaker is open.
    pub fn breaker_admit(&self) -> bool {
        self.shared.supervisor.breaker.try_admit()
    }

    /// Current circuit-breaker state.
    pub fn breaker_state(&self) -> BreakerState {
        self.shared.supervisor.breaker.state()
    }
}

impl InferenceService for ServicePool {
    fn submit(&self, prompt: Vec<i32>, opts: SubmitOptions) -> Result<TokenStream, SubmitError> {
        let now = Instant::now();
        let (tx, rx) = channel();
        let cancel = Arc::new(Flag::new());
        let deadline = opts
            .deadline
            .or_else(|| {
                (self.cfg.default_deadline_ms > 0)
                    .then(|| Duration::from_millis(self.cfg.default_deadline_ms))
            })
            .map(|d| now + d);
        let req = QueuedRequest {
            prompt,
            max_new_tokens: opts.max_new_tokens.unwrap_or(self.cfg.max_new_tokens),
            stop_tokens: opts.stop_tokens,
            deadline,
            submitted_at: now,
            tx,
            cancel: cancel.clone(),
            emitted: Vec::new(),
            retries: 0,
        };
        match self.shared.queue.push(req, opts.priority == Priority::High) {
            Ok(()) => {
                self.shared.counters.submitted.add(1);
                Ok(TokenStream { rx, cancel, done: None, disconnected: false })
            }
            Err(PushError::Full(_)) => {
                self.shared.counters.rejected.add(1);
                Err(SubmitError::QueueFull)
            }
            Err(PushError::Closed(_)) => Err(SubmitError::ShuttingDown),
        }
    }

    fn stats(&self) -> ServiceStats {
        let c = &self.shared.counters;
        let decode_secs = c.decode_nanos.get() as f64 * 1e-9;
        let decoded = c.decoded_tokens.get();
        let breaker = self.shared.supervisor.breaker.snapshot();
        ServiceStats {
            workers: self.cfg.workers,
            queue_depth: self.shared.queue.len(),
            queue_capacity: self.shared.queue.capacity(),
            active: c.active.get(),
            submitted: c.submitted.get(),
            completed: c.completed.get(),
            cancelled: c.cancelled.get(),
            expired: c.expired.get(),
            rejected: c.rejected.get(),
            failed: c.failed.get(),
            decoded_tokens: decoded,
            decode_tokens_per_sec: if decode_secs > 0.0 {
                decoded as f64 / decode_secs
            } else {
                0.0
            },
            prefill_calls: c.prefill_calls.get(),
            prefills_elided: c.prefills_elided.get(),
            prefill_nanos: c.prefill_nanos.get(),
            rows_joined_midflight: c.rows_joined_midflight.get(),
            partial_prefix_hits: c.partial_prefix_hits.get(),
            partial_prefix_tokens_saved: c.partial_prefix_tokens_saved.get(),
            join_wait_nanos: c.join_wait_nanos.get(),
            kv_cache_hits: c.kv_cache_hits.get(),
            kv_cache_misses: c.kv_cache_misses.get(),
            kv_cache_evictions: c.kv_cache_evictions.get(),
            kv_bytes_resident: c.kv_bytes_resident.get() as u64,
            kv_bytes_saved: c.kv_bytes_saved.get(),
            kv_decode_nanos: c.kv_decode_nanos.get(),
            worker_panics: c.worker_panics.get(),
            worker_restarts: c.worker_restarts.get(),
            requests_redispatched: c.requests_redispatched.get(),
            retries: c.retries.get(),
            shed_infeasible: c.shed_infeasible.get(),
            shed_expired: c.shed_expired.get(),
            breaker_state: breaker.state,
            breaker_opens: breaker.opens,
            breaker_recoveries: breaker.recoveries,
        }
    }

    fn shutdown(&self) {
        let now = Instant::now();
        let shed = self.shared.queue.close();
        for req in shed {
            slots::complete_unstarted(req, FinishReason::Cancelled, now);
            self.shared.counters.cancelled.add(1);
        }
        let handles: Vec<_> = self.workers.lock_or_poisoned().drain(..).collect();
        for h in handles {
            // A panic that escaped the supervised loop (e.g. inside the
            // backend factory) surfaces here: log the payload and count it
            // instead of silently discarding the join result.
            if let Err(payload) = h.join() {
                let msg = payload
                    .downcast_ref::<&str>()
                    .map(|s| (*s).to_string())
                    .or_else(|| payload.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "non-string panic payload".to_string());
                metrics::log_info(&format!("serve worker panicked: {msg}"));
                self.shared.counters.worker_panics.add(1);
            }
        }
    }
}

impl Drop for ServicePool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn submit_options_defaults_defer_to_config() {
        let o = SubmitOptions::default();
        assert!(o.max_new_tokens.is_none());
        assert!(o.deadline.is_none());
        assert!(o.stop_tokens.is_empty());
        assert_eq!(o.priority, Priority::Normal);
    }

    #[test]
    fn submit_error_displays() {
        assert_eq!(SubmitError::QueueFull.to_string(), "admission queue full");
        assert_eq!(SubmitError::ShuttingDown.to_string(), "service shutting down");
        assert_eq!(
            SubmitError::AdmissionOnly.to_string(),
            "admission-only pool (workers=0) never drains its queue"
        );
    }

    #[test]
    fn finish_reason_error_carries_the_retry_count() {
        let a = FinishReason::Error { retries: 2 };
        assert_eq!(a, FinishReason::Error { retries: 2 });
        assert_ne!(a, FinishReason::Error { retries: 0 });
        assert_ne!(a, FinishReason::Shed);
    }
}
