//! Pool supervision: the circuit breaker and worker restart budget.
//!
//! A [`Supervisor`] lives in each pool's shared state. Worker threads report
//! outcomes to its [`CircuitBreaker`] (completions are successes; batch
//! failures, panics, and factory errors are failures) and consult its
//! restart budget when a worker dies; `ModelRouter` consults the breaker at
//! submit time and returns `RouteError::CircuitOpen` instead of queueing
//! into a pool that is known-dead (see `docs/robustness.md`).
//!
//! # Breaker state machine
//!
//! ```text
//!            failure (×1)              failure (consec ≥ open_after)
//!  Healthy ───────────────► Degraded ─────────────────────────────► Open
//!     ▲                        │  ▲                                 │  ▲
//!     │ success (consec ≥      │  │                cooldown elapsed │  │ probe
//!     │   recover_after)       │  │ (admits stay                    ▼  │ fails
//!     └────────────────────────┘  │  open)                       HalfOpen
//!     ▲                           │                                 │
//!     └───────────────────────────┴── probe succeeds ───────────────┘
//! ```
//!
//! `Healthy` and `Degraded` admit every request (`Degraded` is an
//! observability state: something is failing but the pool still serves).
//! `Open` denies all traffic until `cooldown` has elapsed since it opened,
//! then admits exactly one **probe**; while that probe is in flight further
//! admits are denied (`HalfOpen`). The probe's outcome decides: success →
//! `Healthy` (a recovery), failure → back to `Open` with a fresh cooldown.
//! Any success also counts as probe resolution — a completion from an
//! older in-flight request is just as much evidence of health.
//!
//! The transition rules are deliberately a pure function of
//! `(state, op, cooldown_elapsed)` — `serve::model::BreakerModel` mirrors
//! them exactly and `tests/serve_interleave.rs` checks the real type against
//! the model under exhaustive interleavings of concurrent
//! success/failure/probe ops.

use crate::serve::sync::{self, LockRank};
use std::time::{Duration, Instant};

/// Observable breaker state, in increasing order of severity.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum BreakerState {
    /// Everything fine; all requests admitted.
    Healthy,
    /// Recent failures below the open threshold; still admitting.
    Degraded,
    /// A probe is in flight; all other requests denied.
    HalfOpen,
    /// Failure threshold crossed; all requests denied until cooldown.
    Open,
}

impl BreakerState {
    /// Stable lowercase label for stats output.
    pub fn as_str(self) -> &'static str {
        match self {
            BreakerState::Healthy => "healthy",
            BreakerState::Degraded => "degraded",
            BreakerState::HalfOpen => "half-open",
            BreakerState::Open => "open",
        }
    }
}

/// Point-in-time copy of the breaker's state and transition tallies, taken
/// under the lock so the fields are mutually consistent.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct BreakerSnapshot {
    pub state: BreakerState,
    /// Transitions into `Degraded`.
    pub degraded: u64,
    /// Transitions into `Open` (including probe failures re-opening).
    pub opens: u64,
    /// Transitions into `HalfOpen` (probes admitted).
    pub half_opens: u64,
    /// Transitions into `Healthy` from a non-healthy state.
    pub recoveries: u64,
}

impl Default for BreakerState {
    fn default() -> Self {
        BreakerState::Healthy
    }
}

struct BreakerInner {
    state: BreakerState,
    consec_failures: u32,
    consec_successes: u32,
    /// When the breaker last entered `Open` — the cooldown epoch.
    opened_at: Option<Instant>,
    degraded: u64,
    opens: u64,
    half_opens: u64,
    recoveries: u64,
}

/// Per-pool circuit breaker. All methods are total and self-contained: each
/// takes the state lock, applies one transition, and releases — the lock is
/// never held across a call out of this module.
pub struct CircuitBreaker {
    breaker: sync::Mutex<BreakerInner>,
    /// Consecutive failures that trip `Degraded` → `Open`. 0 disables the
    /// breaker entirely (always `Healthy`, always admitting).
    open_after: u32,
    /// Consecutive successes that recover `Degraded` → `Healthy`.
    recover_after: u32,
    /// How long `Open` denies traffic before admitting a probe.
    cooldown: Duration,
}

impl CircuitBreaker {
    pub fn new(open_after: u32, recover_after: u32, cooldown: Duration) -> Self {
        Self {
            breaker: sync::Mutex::new(
                LockRank::BreakerState,
                BreakerInner {
                    state: BreakerState::Healthy,
                    consec_failures: 0,
                    consec_successes: 0,
                    opened_at: None,
                    degraded: 0,
                    opens: 0,
                    half_opens: 0,
                    recoveries: 0,
                },
            ),
            open_after,
            recover_after: recover_after.max(1),
            cooldown,
        }
    }

    /// Record a successful unit of work (a request completing normally).
    pub fn record_success(&self) {
        if self.open_after == 0 {
            return;
        }
        let mut b = self.breaker.lock_or_poisoned();
        b.consec_failures = 0;
        b.consec_successes = b.consec_successes.saturating_add(1);
        match b.state {
            BreakerState::Degraded if b.consec_successes >= self.recover_after => {
                b.state = BreakerState::Healthy;
                b.recoveries += 1;
            }
            // A success while a probe is in flight resolves the probe —
            // whether it came from the probe itself or an older request,
            // the pool demonstrably completes work again.
            BreakerState::HalfOpen => {
                b.state = BreakerState::Healthy;
                b.recoveries += 1;
            }
            _ => {}
        }
    }

    /// Record a failure (batch error, worker panic, or factory error).
    pub fn record_failure(&self) {
        if self.open_after == 0 {
            return;
        }
        let mut b = self.breaker.lock_or_poisoned();
        b.consec_successes = 0;
        b.consec_failures = b.consec_failures.saturating_add(1);
        match b.state {
            BreakerState::Healthy => {
                b.state = BreakerState::Degraded;
                b.degraded += 1;
                if b.consec_failures >= self.open_after {
                    b.state = BreakerState::Open;
                    b.opens += 1;
                    b.opened_at = Some(Instant::now());
                }
            }
            BreakerState::Degraded if b.consec_failures >= self.open_after => {
                b.state = BreakerState::Open;
                b.opens += 1;
                b.opened_at = Some(Instant::now());
            }
            // The probe failed: re-open with a fresh cooldown epoch.
            BreakerState::HalfOpen => {
                b.state = BreakerState::Open;
                b.opens += 1;
                b.opened_at = Some(Instant::now());
            }
            _ => {}
        }
    }

    /// Should a new request be admitted right now? Wall-clock entry point:
    /// computes cooldown expiry and defers to [`admit_with`](Self::admit_with).
    pub fn try_admit(&self) -> bool {
        if self.open_after == 0 {
            return true;
        }
        let cooled = {
            let b = self.breaker.lock_or_poisoned();
            match (b.state, b.opened_at) {
                (BreakerState::Open, Some(at)) => at.elapsed() >= self.cooldown,
                (BreakerState::Open, None) => true,
                _ => false,
            }
        };
        self.admit_with(cooled)
    }

    /// The deterministic admission transition: a pure function of
    /// `(state, cooldown_elapsed)`, exposed so the exhaustive interleaving
    /// harness can drive it without a wall clock. `Open` + elapsed cooldown
    /// admits one probe and moves to `HalfOpen`; `HalfOpen` denies until the
    /// probe resolves; `Healthy`/`Degraded` always admit.
    pub fn admit_with(&self, cooldown_elapsed: bool) -> bool {
        if self.open_after == 0 {
            return true;
        }
        let mut b = self.breaker.lock_or_poisoned();
        match b.state {
            BreakerState::Healthy | BreakerState::Degraded => true,
            BreakerState::Open if cooldown_elapsed => {
                b.state = BreakerState::HalfOpen;
                b.half_opens += 1;
                true
            }
            BreakerState::Open | BreakerState::HalfOpen => false,
        }
    }

    /// Current state (one atomic-under-lock read).
    pub fn state(&self) -> BreakerState {
        self.breaker.lock_or_poisoned().state
    }

    /// Consistent copy of state + transition tallies for stats.
    pub fn snapshot(&self) -> BreakerSnapshot {
        let b = self.breaker.lock_or_poisoned();
        BreakerSnapshot {
            state: b.state,
            degraded: b.degraded,
            opens: b.opens,
            half_opens: b.half_opens,
            recoveries: b.recoveries,
        }
    }
}

struct Lifecycle {
    restarts_used: u32,
}

/// Per-pool supervision state: the circuit breaker plus the worker restart
/// budget. Worker threads call [`try_restart`](Self::try_restart) after a
/// fatal worker error (panic or persistent backend failure); the budget is
/// pool-wide, so a crash-looping fleet converges to a drained pool instead
/// of spinning forever.
pub struct Supervisor {
    lifecycle: sync::Mutex<Lifecycle>,
    restart_budget: u32,
    pub breaker: CircuitBreaker,
}

impl Supervisor {
    pub fn new(restart_budget: u32, breaker: CircuitBreaker) -> Self {
        Self {
            lifecycle: sync::Mutex::new(
                LockRank::SupervisorLifecycle,
                Lifecycle { restarts_used: 0 },
            ),
            restart_budget,
            breaker,
        }
    }

    /// Claim one restart from the pool-wide budget; `false` means the budget
    /// is exhausted and the caller should let the worker die for good.
    pub fn try_restart(&self) -> bool {
        let mut l = self.lifecycle.lock_or_poisoned();
        if l.restarts_used >= self.restart_budget {
            return false;
        }
        l.restarts_used += 1;
        true
    }

    /// Restarts claimed so far (stats).
    pub fn restarts_used(&self) -> u32 {
        self.lifecycle.lock_or_poisoned().restarts_used
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn breaker() -> CircuitBreaker {
        CircuitBreaker::new(3, 2, Duration::from_millis(0))
    }

    #[test]
    fn failures_walk_healthy_degraded_open_and_probe_recovers() {
        let b = breaker();
        assert_eq!(b.state(), BreakerState::Healthy);
        b.record_failure();
        assert_eq!(b.state(), BreakerState::Degraded, "first failure degrades");
        assert!(b.try_admit(), "degraded still admits");
        b.record_failure();
        b.record_failure();
        assert_eq!(b.state(), BreakerState::Open, "third consecutive failure opens");
        assert!(!b.admit_with(false), "open + cooling denies");
        assert!(b.admit_with(true), "cooldown elapsed admits one probe");
        assert_eq!(b.state(), BreakerState::HalfOpen);
        assert!(!b.admit_with(true), "second request denied while probe in flight");
        b.record_success();
        assert_eq!(b.state(), BreakerState::Healthy, "probe success closes");
        let s = b.snapshot();
        assert_eq!((s.degraded, s.opens, s.half_opens, s.recoveries), (1, 1, 1, 1));
    }

    #[test]
    fn probe_failure_reopens() {
        let b = breaker();
        for _ in 0..3 {
            b.record_failure();
        }
        assert!(b.admit_with(true));
        b.record_failure();
        assert_eq!(b.state(), BreakerState::Open, "probe failure re-opens");
        assert_eq!(b.snapshot().opens, 2);
    }

    #[test]
    fn degraded_recovers_after_consecutive_successes() {
        let b = breaker();
        b.record_failure();
        b.record_success();
        assert_eq!(b.state(), BreakerState::Degraded, "one success is not enough");
        b.record_success();
        assert_eq!(b.state(), BreakerState::Healthy);
        // and a failure in between resets the success streak
        b.record_failure();
        b.record_success();
        b.record_failure();
        b.record_success();
        assert_eq!(b.state(), BreakerState::Degraded);
    }

    #[test]
    fn open_after_zero_disables_the_breaker() {
        let b = CircuitBreaker::new(0, 2, Duration::from_millis(0));
        for _ in 0..10 {
            b.record_failure();
        }
        assert_eq!(b.state(), BreakerState::Healthy);
        assert!(b.try_admit());
    }

    #[test]
    fn wall_clock_cooldown_gates_the_probe() {
        let b = CircuitBreaker::new(1, 1, Duration::from_millis(50));
        b.record_failure();
        assert_eq!(b.state(), BreakerState::Open, "open_after=1 opens immediately");
        assert!(!b.try_admit(), "cooldown not elapsed");
        sync::sleep(Duration::from_millis(60));
        assert!(b.try_admit(), "cooldown elapsed admits the probe");
        assert_eq!(b.state(), BreakerState::HalfOpen);
    }

    #[test]
    fn restart_budget_is_pool_wide_and_exhausts() {
        let s = Supervisor::new(2, breaker());
        assert!(s.try_restart());
        assert!(s.try_restart());
        assert!(!s.try_restart(), "budget of 2 exhausted");
        assert_eq!(s.restarts_used(), 2);
    }

    #[test]
    fn breaker_severity_order_supports_fleet_aggregation() {
        assert!(BreakerState::Healthy < BreakerState::Degraded);
        assert!(BreakerState::Degraded < BreakerState::HalfOpen);
        assert!(BreakerState::HalfOpen < BreakerState::Open);
    }
}
