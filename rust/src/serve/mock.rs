//! A deterministic, artifact-free [`EngineBackend`]: the hermetic test
//! harness for the serving tier.
//!
//! `MockBackend` generates scripted token streams with a pure arithmetic
//! rule — the token after `t` is `(t + stride) % vocab` — evaluated on
//! whatever the scheduler feeds it. The engine's single-row prefill hands
//! over each row's left-aligned window plus its real length, so the window
//! token at `len - 1` is always the row's most recent real token and a
//! row's stream is the arithmetic progression `p + stride, p + 2·stride, …`
//! (mod `vocab`) from its last prompt token `p`, *regardless* of when
//! neighbours join, vacate, or the row's own KV window rolls over. Tests
//! can therefore predict exact outputs while exercising the real
//! continuous-batching machinery: router dispatch, mid-flight slot joins,
//! streaming, cancellation, deadlines, and backpressure — all under
//! `cargo test -q` with no PJRT artifact on disk.
//!
//! The mock also keeps its **own** per-row position model (`row_pos`,
//! advanced on every decode step of a live row) and asserts the
//! scheduler-supplied per-row `pos` vector against it, erroring on any
//! divergence — a scheduler that feeds a stale position, decodes a fresh
//! row before its encode, or runs a row past `max_len` without a rollover
//! fails tests instead of passing silently.
//!
//! The KV-row seam is implemented deterministically too: a row's
//! "KV snapshot" is a pure function of its last prefilled window. Each
//! window token `t` at position `j` becomes one row of a
//! `prompt_len × MOCK_KV_COLS` plane, built as a rank-≤3 linear combination
//! of three fixed direction vectors `U`/`W`/`Z` with per-(token, position)
//! pseudo-noise: `k[j] = lo·U + hi·W + n·Z` and `v[j] = hi·U + lo·W + n·Z`,
//! where `lo = t & 0xff`, `hi = t >> 8`. The planes are deliberately
//! **non-constant and spectrum-rich** (so compression tests cannot pass
//! vacuously on all-equal data) yet exactly low-rank by construction — the
//! rank-r codec with `rank >= 3` reconstructs them to numerical noise.
//! Because `U[0..2] = [1, 0]` and `W[0..2] = [0, 1]`, columns 0 and 1 carry
//! `lo`/`hi` verbatim (integers ≤ 2048, hence f16-exact); import recovers
//! each token as `round(k[j][1])·256 + round(k[j][0])`, *requires* the
//! round-off error to stay ≤ 0.25, and cross-checks the swapped `v`
//! encoding — so a corrupted or over-lossy snapshot is rejected instead of
//! silently serving wrong KV state. Export → import therefore round-trips
//! exactly under `f32`/`f16` and within the documented token-level contract
//! under `rankr`, and the engine's **elided** row encodes (restored from
//! the [`KvPrefixCache`](crate::serve::kvcache::KvPrefixCache) instead of
//! re-prefilled) must reproduce byte-identical streams to real encodes —
//! which is precisely what the prefix-cache integration tests assert.
//! Partial-prefix splices (`prefill_row` with `keep > 0`) additionally
//! verify the kept tokens against the row's resident state.
//!
//! Knobs:
//! - [`step_delay`](MockBackend::step_delay): per-decode-step latency, so
//!   mid-flight cancellation and deadline expiry have time to land;
//! - [`prefill_delay`](MockBackend::prefill_delay): per-prefill latency, so
//!   prefill avoidance shows up in throughput and `prefill_nanos`, and so
//!   bursts deterministically queue up during a join boundary;
//! - [`stride`](MockBackend::stride) / [`vocab`](MockBackend::vocab): make
//!   streams distinguishable per model when several mock pools sit behind
//!   one `ModelRouter`.
//!
//! Fault injection is *not* a mock knob: wrap any backend (this one
//! included) in a [`FaultInjectingBackend`](crate::serve::fault) driven by
//! a seeded `FaultPlan` — scripted decode/prefill errors, KV corruption,
//! latency spikes, hangs, and worker panics, with one-shot/every-Nth/
//! probabilistic schedules.

use crate::serve::engine::EngineBackend;
use crate::serve::kvcache::KvRowState;
use crate::serve::kvcodec::PlaneGeom;
use anyhow::Result;
use std::time::Duration;

/// Columns of the mock KV planes: each window token expands into one
/// `MOCK_KV_COLS`-wide plane row (see the module docs for the encoding).
pub const MOCK_KV_COLS: usize = 16;

/// Direction vector carrying the token's low byte (`U[0] = 1`).
fn dir_u(c: usize) -> f32 {
    match c {
        0 => 1.0,
        1 => 0.0,
        _ => 1.0 / c as f32,
    }
}

/// Direction vector carrying the token's high byte (`W[1] = 1`).
fn dir_w(c: usize) -> f32 {
    match c {
        0 => 0.0,
        1 => 1.0,
        _ => 1.0 / (c * c) as f32,
    }
}

/// Noise direction: zero on the token-carrying columns 0 and 1, so the
/// pseudo-noise can never corrupt token recovery.
fn dir_z(c: usize) -> f32 {
    match c {
        0 | 1 => 0.0,
        _ => 1.0 / (c + 1) as f32,
    }
}

/// Deterministic pseudo-noise in [-4, 4) per (token, position) — a
/// splitmix64-style scramble, so identical windows always produce identical
/// planes while distinct tokens get visibly distinct spectra.
fn plane_noise(t: i32, j: usize) -> f32 {
    let mut z = (t as u64)
        .wrapping_mul(0x9e37_79b9_7f4a_7c15)
        .wrapping_add((j as u64).wrapping_mul(0x85eb_ca6b_c2b2_ae63));
    z ^= z >> 30;
    z = z.wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z ^= z >> 27;
    z = z.wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^= z >> 31;
    ((z >> 40) as f32 / (1u64 << 24) as f32) * 8.0 - 4.0
}

/// Deterministic scripted backend (see module docs). `Clone` so one
/// configured instance can serve as the template for every worker in a
/// pool — see [`MockBackend::factory`].
#[derive(Clone, Debug)]
pub struct MockBackend {
    batch: usize,
    prompt_len: usize,
    max_len: usize,
    stride: i32,
    vocab: i32,
    step_delay: Duration,
    prefill_delay: Duration,
    /// Last encoded (or imported) `[batch * prompt_len]` windows — the
    /// mock's entire "KV state", encoded/exported/imported per row.
    windows: Vec<i32>,
    /// Whether each row holds real encoded state (a vacated row goes back
    /// to `false` and is ignored by the position checks).
    live: Vec<bool>,
    /// The mock's own per-row position model: where the next decode step
    /// of each live row *must* write. `prefill_row`/`import_kv_row` reset
    /// it to the row's real length; every decode step advances it.
    row_pos: Vec<usize>,
}

impl MockBackend {
    /// A backend with the given batch geometry; token rule `t → t + 1`
    /// (mod 1009), zero step latency, no failure injection.
    pub fn new(batch: usize, prompt_len: usize, max_len: usize) -> Self {
        assert!(batch > 0 && prompt_len > 0 && max_len >= prompt_len, "degenerate mock geometry");
        Self {
            batch,
            prompt_len,
            max_len,
            stride: 1,
            vocab: 1009,
            step_delay: Duration::ZERO,
            prefill_delay: Duration::ZERO,
            windows: vec![crate::data::tokenizer::PAD; batch * prompt_len],
            live: vec![false; batch],
            row_pos: vec![0; batch],
        }
    }

    /// Token-rule increment: next token = `(t + stride) % vocab`.
    pub fn stride(mut self, stride: i32) -> Self {
        assert!(stride > 0, "stride must be positive");
        self.stride = stride;
        self
    }

    /// Token-rule modulus (tokens stay in `[0, vocab)`).
    pub fn vocab(mut self, vocab: i32) -> Self {
        assert!(vocab > 1, "vocab must exceed 1");
        self.vocab = vocab;
        self
    }

    /// Sleep this long inside every decode step — controllable latency for
    /// deadline/cancellation tests.
    pub fn step_delay(mut self, d: Duration) -> Self {
        self.step_delay = d;
        self
    }

    /// Sleep this long inside every *real* row encode (`prefill_row`) —
    /// cache-restored rows skip it, which is how the hermetic benchmarks
    /// make prefill avoidance (and the O(1)-in-occupancy join cost)
    /// measurable.
    pub fn prefill_delay(mut self, d: Duration) -> Self {
        self.prefill_delay = d;
        self
    }

    /// A `ServicePool::start_with` factory that hands each worker its own
    /// clone of this backend.
    pub fn factory(
        self,
    ) -> impl Fn(usize) -> Result<Box<dyn EngineBackend>> + Send + Sync + 'static {
        move |_worker| Ok(Box::new(self.clone()) as Box<dyn EngineBackend>)
    }

    /// The scripted stream for a row whose last real token is `t`: its
    /// next `n` tokens under this backend's rule. Test helper.
    pub fn expected_stream(&self, t: i32, n: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(n);
        let mut cur = t;
        for _ in 0..n {
            cur = self.next_token(cur);
            out.push(cur);
        }
        out
    }

    fn next_token(&self, t: i32) -> i32 {
        (t + self.stride).rem_euclid(self.vocab)
    }
}

impl EngineBackend for MockBackend {
    fn batch_size(&self) -> usize {
        self.batch
    }

    fn prompt_len(&self) -> usize {
        self.prompt_len
    }

    fn max_len(&self) -> usize {
        self.max_len
    }

    fn describe(&self) -> String {
        format!(
            "mock bs={} prompt_len={} max_len={} stride={}",
            self.batch, self.prompt_len, self.max_len, self.stride
        )
    }

    fn prefill_row(&mut self, row: usize, window: &[i32], len: usize, keep: usize) -> Result<i32> {
        anyhow::ensure!(row < self.batch, "prefill_row row {row} out of range");
        anyhow::ensure!(
            window.len() == self.prompt_len,
            "prefill_row window is [prompt_len] ({} != {})",
            window.len(),
            self.prompt_len
        );
        anyhow::ensure!(
            0 < len && len <= self.prompt_len && keep <= len,
            "prefill_row wants 0 < len <= prompt_len and keep <= len (len {len}, keep {keep})"
        );
        if keep > 0 {
            // partial-prefix splice: the retained KV positions must belong
            // to this row and agree with the new window token-for-token
            anyhow::ensure!(self.live[row], "prefill_row keeps KV of a row that holds none");
            let stored = &self.windows[row * self.prompt_len..row * self.prompt_len + keep];
            anyhow::ensure!(
                stored == &window[..keep],
                "partial-prefix splice mismatch on row {row}: kept {stored:?} vs {:?}",
                &window[..keep]
            );
        }
        if !self.prefill_delay.is_zero() {
            crate::serve::sync::sleep(self.prefill_delay);
        }
        self.windows[row * self.prompt_len..(row + 1) * self.prompt_len].copy_from_slice(window);
        self.live[row] = true;
        self.row_pos[row] = len;
        Ok(self.next_token(window[len - 1]))
    }

    // lint: hot-path-end — stands in for the model-execution boundary; its
    // paced sleep and per-step collect model backend cost, not scheduling.
    fn decode_step(&mut self, feed: &[i32], pos: &[usize]) -> Result<Vec<i32>> {
        anyhow::ensure!(feed.len() == self.batch, "decode feed is one token per row");
        anyhow::ensure!(pos.len() == self.batch, "decode pos is one position per row");
        if !self.step_delay.is_zero() {
            crate::serve::sync::sleep(self.step_delay);
        }
        // The position checks are the mock's whole point as a test oracle:
        // a scheduler position that disagrees with the mock's own per-row
        // model is a scheduling bug, surfaced as a batch failure.
        for r in 0..self.batch {
            if !self.live[r] {
                continue; // vacated/never-encoded rows decode junk the scheduler ignores
            }
            anyhow::ensure!(
                pos[r] == self.row_pos[r],
                "row {r} decodes at position {} but its KV state is at {}",
                pos[r],
                self.row_pos[r]
            );
            anyhow::ensure!(
                pos[r] < self.max_len,
                "row {r} decodes at position {} past max_len {} without a rollover",
                pos[r],
                self.max_len
            );
            self.row_pos[r] += 1;
        }
        Ok(feed.iter().map(|&t| self.next_token(t)).collect())
    }

    fn kv_row_elems(&self) -> usize {
        self.prompt_len * MOCK_KV_COLS
    }

    fn kv_row_geom(&self) -> PlaneGeom {
        PlaneGeom { layers: 1, rows: self.prompt_len, cols: MOCK_KV_COLS }
    }

    fn export_kv_row(&mut self, row: usize) -> Result<KvRowState> {
        anyhow::ensure!(row < self.batch, "export row {row} out of range");
        anyhow::ensure!(self.live[row], "export_kv_row of a row that holds no KV state");
        let w = &self.windows[row * self.prompt_len..(row + 1) * self.prompt_len];
        let mut k = Vec::with_capacity(self.prompt_len * MOCK_KV_COLS);
        let mut v = Vec::with_capacity(self.prompt_len * MOCK_KV_COLS);
        for (j, &t) in w.iter().enumerate() {
            let lo = (t & 0xff) as f32;
            let hi = (t >> 8) as f32;
            let n = plane_noise(t, j);
            for c in 0..MOCK_KV_COLS {
                k.push(lo * dir_u(c) + hi * dir_w(c) + n * dir_z(c));
                v.push(hi * dir_u(c) + lo * dir_w(c) + n * dir_z(c));
            }
        }
        Ok(KvRowState { k, v })
    }

    fn import_kv_row(&mut self, row: usize, kv: &KvRowState, len: usize) -> Result<()> {
        anyhow::ensure!(row < self.batch, "import row {row} out of range");
        anyhow::ensure!(
            0 < len && len <= self.prompt_len,
            "import_kv_row wants 0 < len <= prompt_len (len {len})"
        );
        let elems = self.prompt_len * MOCK_KV_COLS;
        anyhow::ensure!(
            kv.k.len() == elems && kv.v.len() == elems,
            "KV row snapshot has {} elems, mock wants {elems}",
            kv.k.len(),
        );
        // recover the snapshotted window, exactly as if it had just been
        // encoded into this row
        for j in 0..self.prompt_len {
            let (k0, k1) = (kv.k[j * MOCK_KV_COLS], kv.k[j * MOCK_KV_COLS + 1]);
            let (v0, v1) = (kv.v[j * MOCK_KV_COLS], kv.v[j * MOCK_KV_COLS + 1]);
            let (lo, hi) = (k0.round(), k1.round());
            anyhow::ensure!(
                (k0 - lo).abs() <= 0.25 && (k1 - hi).abs() <= 0.25,
                "KV snapshot too lossy to recover tokens (row {row} pos {j}: k = ({k0}, {k1}))"
            );
            anyhow::ensure!(
                (v0 - hi).abs() <= 0.25 && (v1 - lo).abs() <= 0.25,
                "mock KV snapshot violates the k/v cross-encoding invariant"
            );
            self.windows[row * self.prompt_len + j] = (hi as i32) * 256 + lo as i32;
        }
        self.live[row] = true;
        self.row_pos[row] = len;
        Ok(())
    }

    fn vacate_row(&mut self, row: usize) {
        if row < self.batch {
            self.live[row] = false;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefill_row_reads_the_token_at_len() {
        let mut b = MockBackend::new(2, 3, 8);
        // left-aligned windows: [5, 6, pad] (len 2) and [1, 2, 3] (len 3)
        assert_eq!(b.prefill_row(0, &[5, 6, 0], 2, 0).unwrap(), 7);
        assert_eq!(b.prefill_row(1, &[1, 2, 3], 3, 0).unwrap(), 4);
    }

    #[test]
    fn decode_applies_rule_per_row() {
        let mut b = MockBackend::new(3, 2, 4).stride(10).vocab(25);
        b.prefill_row(0, &[1, 0], 1, 0).unwrap();
        b.prefill_row(1, &[20, 0], 1, 0).unwrap();
        // row 2 stays vacant: junk in, junk out, no position check
        let next = b.decode_step(&[1, 20, 0], &[1, 1, 0]).unwrap();
        assert_eq!(next, vec![11, 5, 10], "wraps at vocab");
    }

    #[test]
    fn expected_stream_matches_rule() {
        let b = MockBackend::new(1, 2, 4).stride(7).vocab(100);
        assert_eq!(b.expected_stream(95, 3), vec![2, 9, 16]);
    }

    #[test]
    fn fault_wrapper_injects_into_the_mock() {
        // Failure injection moved out of the mock into `serve::fault`; this
        // pins the composition: a wrapped mock still position-checks, and
        // the scripted decode fault fires exactly once.
        use crate::serve::fault::{FaultKind, FaultPlan, FaultSchedule};
        let plan = FaultPlan::seeded(7).inject(FaultKind::DecodeError, FaultSchedule::Once(2));
        let mut b = plan.wrap(MockBackend::new(1, 2, 8), 0);
        b.prefill_row(0, &[1, 0], 1, 0).unwrap();
        assert!(b.decode_step(&[1], &[1]).is_ok());
        assert!(b.decode_step(&[2], &[2]).is_err(), "scripted fault fires on call 2");
        assert!(b.decode_step(&[2], &[2]).is_ok(), "one-shot: clears after firing");
    }

    #[test]
    fn shape_mismatches_are_errors_not_panics() {
        let mut b = MockBackend::new(2, 3, 8);
        assert!(b.prefill_row(0, &[1, 2], 2, 0).is_err(), "short window");
        assert!(b.prefill_row(2, &[1, 2, 3], 3, 0).is_err(), "row out of range");
        assert!(b.prefill_row(0, &[1, 2, 3], 0, 0).is_err(), "empty row");
        assert!(b.prefill_row(0, &[1, 2, 3], 2, 3).is_err(), "keep > len");
        assert!(b.decode_step(&[1], &[0, 0]).is_err(), "short feed");
        assert!(b.decode_step(&[1, 2], &[0]).is_err(), "short pos");
    }

    #[test]
    fn scheduler_positions_are_asserted_per_row() {
        let mut b = MockBackend::new(2, 3, 4);
        b.prefill_row(0, &[1, 2, 0], 2, 0).unwrap();
        assert!(b.decode_step(&[5, 0], &[1, 0]).is_err(), "stale position must fail");
        assert!(b.decode_step(&[5, 0], &[2, 0]).is_ok());
        assert!(b.decode_step(&[6, 0], &[3, 0]).is_ok());
        // the row's KV window is exhausted: decoding on demands a rollover
        assert!(b.decode_step(&[7, 0], &[4, 0]).is_err(), "past max_len without rollover");
        // the rollover re-encode resets the row's position model
        b.prefill_row(0, &[5, 6, 7], 3, 0).unwrap();
        assert!(b.decode_step(&[8, 0], &[3, 0]).is_ok());
        // vacated rows are exempt from the checks
        b.vacate_row(0);
        assert!(b.decode_step(&[9, 0], &[0, 0]).is_ok());
    }

    #[test]
    fn partial_prefix_splice_is_verified() {
        let mut b = MockBackend::new(1, 4, 8);
        b.prefill_row(0, &[1, 2, 3, 0], 3, 0).unwrap();
        assert!(b.prefill_row(0, &[1, 9, 4, 5], 4, 2).is_err(), "kept prefix must match");
        assert_eq!(b.prefill_row(0, &[1, 2, 4, 5], 4, 2).unwrap(), 6);
        // keeping KV of a vacated row is a scheduling bug
        b.vacate_row(0);
        assert!(b.prefill_row(0, &[1, 2, 4, 5], 4, 2).is_err());
        assert!(b.prefill_row(0, &[1, 2, 4, 5], 4, 0).is_ok(), "fresh encode recovers");
    }

    #[test]
    fn kv_rows_round_trip_deterministically() {
        let mut b = MockBackend::new(2, 3, 8);
        assert!(b.export_kv_row(0).is_err(), "no KV state before an encode");
        b.prefill_row(0, &[5, 6, 0], 2, 0).unwrap();
        b.prefill_row(1, &[1, 2, 300], 3, 0).unwrap();
        let r0 = b.export_kv_row(0).unwrap();
        let r1 = b.export_kv_row(1).unwrap();
        // columns 0/1 of each plane row carry the token's lo/hi bytes
        assert_eq!(r0.k[0], 5.0, "row 0 pos 0: lo = 5");
        assert_eq!(r0.k[1], 0.0, "row 0 pos 0: hi = 0");
        assert_eq!(r1.k[2 * MOCK_KV_COLS], 44.0, "300 & 0xff");
        assert_eq!(r1.k[2 * MOCK_KV_COLS + 1], 1.0, "300 >> 8");
        assert_eq!(r1.v[2 * MOCK_KV_COLS], 1.0, "v swaps hi into column 0");
        // the tail columns are non-constant: the plane is spectrum-rich,
        // not all-equal data a codec could compress for free
        let tail: Vec<f32> = (2..MOCK_KV_COLS).map(|c| r1.k[2 * MOCK_KV_COLS + c]).collect();
        assert!(tail.iter().any(|&x| x != tail[0]), "tail must vary: {tail:?}");
        // import row 1's snapshot into row 0: a pure function of the snapshot
        b.import_kv_row(0, &r1, 3).unwrap();
        assert_eq!(b.export_kv_row(0).unwrap(), r1, "snapshot survives the round trip");
        assert_eq!(b.export_kv_row(1).unwrap(), r1, "determinism");
        // vacating releases the row's state
        b.vacate_row(0);
        assert!(b.export_kv_row(0).is_err(), "vacated rows hold nothing to export");
    }

    #[test]
    fn import_validates_shape_and_encoding() {
        let mut b = MockBackend::new(2, 3, 8);
        assert_eq!(b.kv_row_elems(), 3 * MOCK_KV_COLS);
        b.prefill_row(0, &[5, 6, 0], 2, 0).unwrap();
        let good = b.export_kv_row(0).unwrap();
        let short = KvRowState { k: vec![1.0], v: vec![1.5] };
        assert!(b.import_kv_row(1, &short, 2).is_err(), "wrong row length");
        let mut lossy = good.clone();
        lossy.k[0] += 0.3; // beyond the 0.25 token-recovery tolerance
        assert!(b.import_kv_row(1, &lossy, 2).is_err(), "over-lossy k");
        let mut corrupt = good.clone();
        corrupt.v[0] += 7.0; // k says one token, v says another
        assert!(b.import_kv_row(1, &corrupt, 2).is_err(), "k/v cross-check");
        assert!(b.import_kv_row(2, &good, 2).is_err(), "row out of range");
        assert!(b.import_kv_row(1, &good, 0).is_err(), "zero-length import");
        assert!(b.import_kv_row(1, &good, 2).is_ok());
    }

    #[test]
    fn planes_survive_lossy_codecs_token_exactly() {
        use crate::serve::kvcodec::{encode_row, KvCodec};
        let mut b = MockBackend::new(1, 4, 8).vocab(50_021);
        b.prefill_row(0, &[1009, 2, 300, 49_999], 4, 0).unwrap();
        let row = b.export_kv_row(0).unwrap();
        let geom = b.kv_row_geom();
        for codec in [KvCodec::F16, KvCodec::RankR { rank: 3 }] {
            let enc = encode_row(&row, codec, geom).unwrap();
            let mut dec = KvRowState::default();
            enc.decode_into(&mut dec);
            b.import_kv_row(0, &dec, 4).unwrap();
            assert_eq!(b.export_kv_row(0).unwrap(), row, "{codec:?} must recover every token");
        }
    }
}
