//! A deterministic, artifact-free [`EngineBackend`]: the hermetic test
//! harness for the serving tier.
//!
//! `MockBackend` generates scripted token streams with a pure arithmetic
//! rule — the token after `t` is `(t + stride) % vocab` — evaluated on
//! whatever the scheduler feeds it. Because the engine's join prefill
//! right-aligns each row's window, the last window token is always the
//! row's most recent real token, so a row's stream is the arithmetic
//! progression `p + stride, p + 2·stride, …` (mod `vocab`) from its last
//! prompt token `p`, *regardless* of when neighbours join, vacate, or the
//! KV window rolls over. Tests can therefore predict exact outputs while
//! exercising the real continuous-batching machinery: router dispatch,
//! slot refills, streaming, cancellation, deadlines, and backpressure —
//! all under `cargo test -q` with no PJRT artifact on disk.
//!
//! Knobs:
//! - [`step_delay`](MockBackend::step_delay): per-decode-step latency, so
//!   mid-flight cancellation and deadline expiry have time to land;
//! - [`fail_after`](MockBackend::fail_after): one-shot decode failure, to
//!   exercise the engine's batch-failure path (`FinishReason::Error`) and
//!   its recovery on the next join prefill;
//! - [`stride`](MockBackend::stride) / [`vocab`](MockBackend::vocab): make
//!   streams distinguishable per model when several mock pools sit behind
//!   one `ModelRouter`.

use crate::serve::engine::EngineBackend;
use anyhow::Result;
use std::time::Duration;

/// Deterministic scripted backend (see module docs). `Clone` so one
/// configured instance can serve as the template for every worker in a
/// pool — see [`MockBackend::factory`].
#[derive(Clone, Debug)]
pub struct MockBackend {
    batch: usize,
    prompt_len: usize,
    max_len: usize,
    stride: i32,
    vocab: i32,
    step_delay: Duration,
    fail_after: Option<u64>,
    decode_calls: u64,
}

impl MockBackend {
    /// A backend with the given batch geometry; token rule `t → t + 1`
    /// (mod 1009), zero step latency, no failure injection.
    pub fn new(batch: usize, prompt_len: usize, max_len: usize) -> Self {
        assert!(batch > 0 && prompt_len > 0 && max_len >= prompt_len, "degenerate mock geometry");
        Self {
            batch,
            prompt_len,
            max_len,
            stride: 1,
            vocab: 1009,
            step_delay: Duration::ZERO,
            fail_after: None,
            decode_calls: 0,
        }
    }

    /// Token-rule increment: next token = `(t + stride) % vocab`.
    pub fn stride(mut self, stride: i32) -> Self {
        assert!(stride > 0, "stride must be positive");
        self.stride = stride;
        self
    }

    /// Token-rule modulus (tokens stay in `[0, vocab)`).
    pub fn vocab(mut self, vocab: i32) -> Self {
        assert!(vocab > 1, "vocab must exceed 1");
        self.vocab = vocab;
        self
    }

    /// Sleep this long inside every decode step — controllable latency for
    /// deadline/cancellation tests.
    pub fn step_delay(mut self, d: Duration) -> Self {
        self.step_delay = d;
        self
    }

    /// Make the Nth decode call (1-based, counted across the backend's
    /// lifetime) return an error — once. The trigger then clears, so the
    /// worker's next join prefill serves normally: tests cover both the
    /// `FinishReason::Error` path and recovery.
    pub fn fail_after(mut self, nth_call: u64) -> Self {
        assert!(nth_call > 0, "fail_after is 1-based");
        self.fail_after = Some(nth_call);
        self
    }

    /// A `ServicePool::start_with` factory that hands each worker its own
    /// clone of this backend.
    pub fn factory(
        self,
    ) -> impl Fn(usize) -> Result<Box<dyn EngineBackend>> + Send + Sync + 'static {
        move |_worker| Ok(Box::new(self.clone()) as Box<dyn EngineBackend>)
    }

    /// The scripted stream for a row whose last real token is `t`: its
    /// next `n` tokens under this backend's rule. Test helper.
    pub fn expected_stream(&self, t: i32, n: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(n);
        let mut cur = t;
        for _ in 0..n {
            cur = self.next_token(cur);
            out.push(cur);
        }
        out
    }

    fn next_token(&self, t: i32) -> i32 {
        (t + self.stride).rem_euclid(self.vocab)
    }
}

impl EngineBackend for MockBackend {
    fn batch_size(&self) -> usize {
        self.batch
    }

    fn prompt_len(&self) -> usize {
        self.prompt_len
    }

    fn max_len(&self) -> usize {
        self.max_len
    }

    fn describe(&self) -> String {
        format!(
            "mock bs={} prompt_len={} max_len={} stride={}",
            self.batch, self.prompt_len, self.max_len, self.stride
        )
    }

    fn prefill(&mut self, tokens: &[i32]) -> Result<Vec<i32>> {
        anyhow::ensure!(
            tokens.len() == self.batch * self.prompt_len,
            "prefill batch is [batch, prompt_len]"
        );
        // Right-aligned windows: the last column is each row's most recent
        // real token (or pad for an empty row — its output is junk the
        // scheduler ignores, same as the artifact path).
        Ok(tokens
            .chunks_exact(self.prompt_len)
            .map(|row| self.next_token(row[self.prompt_len - 1]))
            .collect())
    }

    fn decode_step(&mut self, feed: &[i32], _pos: usize) -> Result<Vec<i32>> {
        anyhow::ensure!(feed.len() == self.batch, "decode feed is one token per row");
        if !self.step_delay.is_zero() {
            std::thread::sleep(self.step_delay);
        }
        self.decode_calls += 1;
        if self.fail_after.is_some_and(|n| self.decode_calls >= n) {
            self.fail_after = None; // one-shot: recover on the next prefill
            anyhow::bail!("injected mock decode failure at call {}", self.decode_calls);
        }
        Ok(feed.iter().map(|&t| self.next_token(t)).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefill_reads_last_window_column() {
        let mut b = MockBackend::new(2, 3, 8);
        // rows right-aligned: [pad, 5, 6] and [1, 2, 3]
        let next = b.prefill(&[0, 5, 6, 1, 2, 3]).unwrap();
        assert_eq!(next, vec![7, 4]);
    }

    #[test]
    fn decode_applies_rule_per_row() {
        let mut b = MockBackend::new(3, 2, 4).stride(10).vocab(25);
        let next = b.decode_step(&[1, 20, 0], 2).unwrap();
        assert_eq!(next, vec![11, 5, 10], "wraps at vocab");
    }

    #[test]
    fn expected_stream_matches_rule() {
        let b = MockBackend::new(1, 2, 4).stride(7).vocab(100);
        assert_eq!(b.expected_stream(95, 3), vec![2, 9, 16]);
    }

    #[test]
    fn fail_after_is_one_shot() {
        let mut b = MockBackend::new(1, 2, 8).fail_after(2);
        assert!(b.decode_step(&[1], 2).is_ok());
        assert!(b.decode_step(&[2], 3).is_err());
        assert!(b.decode_step(&[3], 4).is_ok(), "trigger clears after firing");
    }

    #[test]
    fn shape_mismatches_are_errors_not_panics() {
        let mut b = MockBackend::new(2, 3, 8);
        assert!(b.prefill(&[1, 2, 3]).is_err());
        assert!(b.decode_step(&[1], 3).is_err());
    }
}
