//! Multi-artifact routing: one process serving several models side by side.
//!
//! [`ModelRouter`] owns a set of *named* [`ServicePool`]s — e.g.
//! `full_130m` / `sltrain_130m` / `cola_130m`, the paper's Table 11
//! comparison — and dispatches [`submit`](ModelRouter::submit) by model
//! name. Each pool keeps its own admission queue, workers, and counters, so
//! backpressure is per-model: one model's `QueueFull` never blocks another.
//! Misrouted requests fail with the typed [`RouteError::UnknownModel`]
//! instead of an artifact error deep in a worker. Stats are available
//! per model ([`stats`](ModelRouter::stats),
//! [`stats_by_model`](ModelRouter::stats_by_model)) and aggregated across
//! the fleet ([`aggregate_stats`](ModelRouter::aggregate_stats)); individual
//! models can be drained with [`shutdown_model`](ModelRouter::shutdown_model)
//! while the rest keep serving.

use crate::config::RouterConfig;
use crate::serve::service::{
    Completion, InferenceService, ServicePool, ServiceStats, SubmitError, SubmitOptions,
    TokenStream,
};
use anyhow::Result;

/// Why a routed submit failed.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RouteError {
    /// No pool is registered under this model name.
    UnknownModel(String),
    /// The named pool refused the submit (backpressure or shutdown).
    Submit(SubmitError),
    /// The named pool's circuit breaker is open (or a recovery probe is
    /// already in flight): recent worker faults say the pool is unhealthy,
    /// so the router refuses to queue into it. Retryable — the breaker
    /// admits a probe once its cooldown elapses.
    CircuitOpen(String),
}

impl std::fmt::Display for RouteError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RouteError::UnknownModel(m) => write!(f, "unknown model `{m}`"),
            RouteError::Submit(e) => write!(f, "{e}"),
            RouteError::CircuitOpen(m) => {
                write!(f, "circuit breaker open for model `{m}`")
            }
        }
    }
}

impl std::error::Error for RouteError {}

impl From<SubmitError> for RouteError {
    fn from(e: SubmitError) -> Self {
        RouteError::Submit(e)
    }
}

/// A set of named [`ServicePool`]s behind one dispatch surface.
pub struct ModelRouter {
    /// Insertion-ordered; model counts are small, so lookup is linear.
    pools: Vec<(String, ServicePool)>,
}

impl ModelRouter {
    /// Bring up one PJRT pool per configured model. Fails fast if any
    /// artifact is missing (pools already started are dropped, which drains
    /// them).
    pub fn start(cfg: &RouterConfig) -> Result<Self> {
        let mut pools = Vec::new();
        for (name, model_cfg) in cfg.resolved_models() {
            let pool = ServicePool::start(model_cfg)
                .map_err(|e| e.context(format!("starting pool for model `{name}`")))?;
            pools.push((name, pool));
        }
        Self::from_pools(pools)
    }

    /// Assemble a router from already-started pools (mock-backed pools in
    /// tests, heterogeneous `start_with` pools in embedders).
    pub fn from_pools(pools: Vec<(String, ServicePool)>) -> Result<Self> {
        anyhow::ensure!(!pools.is_empty(), "router needs at least one model");
        for (i, (name, _)) in pools.iter().enumerate() {
            anyhow::ensure!(
                !pools[..i].iter().any(|(n, _)| n == name),
                "duplicate model name `{name}`"
            );
        }
        Ok(Self { pools })
    }

    /// Registered model names, in registration order.
    pub fn models(&self) -> Vec<&str> {
        self.pools.iter().map(|(n, _)| n.as_str()).collect()
    }

    /// The pool behind a model name, if registered.
    pub fn pool(&self, model: &str) -> Option<&ServicePool> {
        self.pools.iter().find(|(n, _)| n == model).map(|(_, p)| p)
    }

    fn pool_or_err(&self, model: &str) -> Result<&ServicePool, RouteError> {
        self.pool(model).ok_or_else(|| RouteError::UnknownModel(model.to_string()))
    }

    /// Route a submit to the named model's pool. Non-blocking; per-model
    /// backpressure surfaces as `RouteError::Submit(QueueFull)`, and a pool
    /// whose circuit breaker is open refuses with `RouteError::CircuitOpen`
    /// before the request ever queues (direct `ServicePool::submit`
    /// deliberately bypasses the breaker — the router is the fleet-facing
    /// surface where refusing early is the right call).
    pub fn submit(
        &self,
        model: &str,
        prompt: Vec<i32>,
        opts: SubmitOptions,
    ) -> Result<TokenStream, RouteError> {
        let pool = self.pool_or_err(model)?;
        if !pool.breaker_admit() {
            return Err(RouteError::CircuitOpen(model.to_string()));
        }
        pool.submit(prompt, opts).map_err(RouteError::from)
    }

    /// Blocking convenience: submit to the named model and wait for the
    /// completion. Routes through [`submit`](Self::submit), so the pool's
    /// circuit breaker applies here too — an admitted request on an `Open`
    /// pool is the half-open probe.
    pub fn generate(
        &self,
        model: &str,
        prompt: Vec<i32>,
        opts: SubmitOptions,
    ) -> Result<Completion> {
        self.submit(model, prompt, opts).map_err(anyhow::Error::new)?.wait()
    }

    /// Blocking submit to the named model, riding out `QueueFull` (see
    /// `ServicePool::submit_wait`).
    pub fn submit_wait(
        &self,
        model: &str,
        prompt: Vec<i32>,
        opts: SubmitOptions,
    ) -> Result<TokenStream> {
        let pool = self.pool_or_err(model).map_err(anyhow::Error::new)?;
        pool.submit_wait(prompt, opts)
    }

    /// One model's queue/slot occupancy and lifetime counters.
    pub fn stats(&self, model: &str) -> Result<ServiceStats, RouteError> {
        Ok(self.pool_or_err(model)?.stats())
    }

    /// Per-model stats snapshot, in registration order.
    pub fn stats_by_model(&self) -> Vec<(&str, ServiceStats)> {
        self.pools.iter().map(|(n, p)| (n.as_str(), p.stats())).collect()
    }

    /// Fleet-wide stats: counters and gauges sum across models;
    /// `decode_tokens_per_sec` is recomputed from the summed token count and
    /// summed worker busy-time (not a mean of per-model rates).
    pub fn aggregate_stats(&self) -> ServiceStats {
        let mut agg = ServiceStats {
            workers: 0,
            queue_depth: 0,
            queue_capacity: 0,
            active: 0,
            submitted: 0,
            completed: 0,
            cancelled: 0,
            expired: 0,
            rejected: 0,
            failed: 0,
            decoded_tokens: 0,
            decode_tokens_per_sec: 0.0,
            prefill_calls: 0,
            prefills_elided: 0,
            prefill_nanos: 0,
            rows_joined_midflight: 0,
            partial_prefix_hits: 0,
            partial_prefix_tokens_saved: 0,
            join_wait_nanos: 0,
            kv_cache_hits: 0,
            kv_cache_misses: 0,
            kv_cache_evictions: 0,
            kv_bytes_resident: 0,
            kv_bytes_saved: 0,
            kv_decode_nanos: 0,
            worker_panics: 0,
            worker_restarts: 0,
            requests_redispatched: 0,
            retries: 0,
            shed_infeasible: 0,
            shed_expired: 0,
            breaker_state: Default::default(),
            breaker_opens: 0,
            breaker_recoveries: 0,
        };
        let mut busy_secs = 0.0;
        for (_, pool) in &self.pools {
            let s = pool.stats();
            agg.workers += s.workers;
            agg.queue_depth += s.queue_depth;
            agg.queue_capacity += s.queue_capacity;
            agg.active += s.active;
            agg.submitted += s.submitted;
            agg.completed += s.completed;
            agg.cancelled += s.cancelled;
            agg.expired += s.expired;
            agg.rejected += s.rejected;
            agg.failed += s.failed;
            agg.decoded_tokens += s.decoded_tokens;
            agg.prefill_calls += s.prefill_calls;
            agg.prefills_elided += s.prefills_elided;
            agg.prefill_nanos += s.prefill_nanos;
            agg.rows_joined_midflight += s.rows_joined_midflight;
            agg.partial_prefix_hits += s.partial_prefix_hits;
            agg.partial_prefix_tokens_saved += s.partial_prefix_tokens_saved;
            agg.join_wait_nanos += s.join_wait_nanos;
            agg.kv_cache_hits += s.kv_cache_hits;
            agg.kv_cache_misses += s.kv_cache_misses;
            agg.kv_cache_evictions += s.kv_cache_evictions;
            agg.kv_bytes_resident += s.kv_bytes_resident;
            agg.kv_bytes_saved += s.kv_bytes_saved;
            agg.kv_decode_nanos += s.kv_decode_nanos;
            agg.worker_panics += s.worker_panics;
            agg.worker_restarts += s.worker_restarts;
            agg.requests_redispatched += s.requests_redispatched;
            agg.retries += s.retries;
            agg.shed_infeasible += s.shed_infeasible;
            agg.shed_expired += s.shed_expired;
            // fleet breaker state is the *worst* pool's (severity order)
            agg.breaker_state = agg.breaker_state.max(s.breaker_state);
            agg.breaker_opens += s.breaker_opens;
            agg.breaker_recoveries += s.breaker_recoveries;
            if s.decode_tokens_per_sec > 0.0 {
                busy_secs += s.decoded_tokens as f64 / s.decode_tokens_per_sec;
            }
        }
        if busy_secs > 0.0 {
            agg.decode_tokens_per_sec = agg.decoded_tokens as f64 / busy_secs;
        }
        agg
    }

    /// Drain one model: stop its admissions, resolve its queued requests,
    /// finish its in-flight rows, and join its workers — the other models
    /// keep serving. The model stays registered; further submits to it fail
    /// with `RouteError::Submit(ShuttingDown)`.
    pub fn shutdown_model(&self, model: &str) -> Result<(), RouteError> {
        self.pool_or_err(model)?.shutdown();
        Ok(())
    }

    /// Drain every model (idempotent; also runs on drop via each pool).
    pub fn shutdown(&self) {
        for (_, pool) in &self.pools {
            pool.shutdown();
        }
    }
}
