//! Pure reference models of the serving primitives, plus an exhaustive
//! interleaving explorer that checks the real types against them.
//!
//! # Why this works
//!
//! Every checked primitive serialises each operation under one lock
//! ([`BoundedQueue`] holds its `Mutex` for the whole op; [`KvPrefixCache`]
//! is `&mut self` behind a worker; [`CircuitBreaker`] takes its state lock
//! per transition), so any concurrent execution is
//! equivalent to *some* total order of the individual ops. Linearizability
//! therefore reduces to: **for every schedulable total order of the ops,
//! the real type's observations match the reference model's.** The
//! explorer enumerates those orders exhaustively for small per-thread op
//! sequences — unlike `queue_stress.rs`, which merely samples them.
//!
//! # Blocking ops
//!
//! [`QueueOp::PopBlocking`] only *completes* (and thus only linearises)
//! when the queue is non-empty or closed, so the explorer schedules it
//! only in states where [`QueueModel::ready`] holds. Replaying such a
//! schedule on the real queue then never parks. A state where ops remain
//! but none is schedulable is reported as a [deadlock]
//! (`ExploreReport::deadlocks`) — e.g. a lone `PopBlocking` against an
//! empty queue that nothing will ever close.
//!
//! # Extending the models
//!
//! To put a new primitive under the checker: (1) define `Op`/`Obs` enums
//! and a `Clone`-able model with `ready`/`apply`; (2) impl the matching
//! `*Sut` trait for the real type (and for deliberately-broken wrappers —
//! regression tests pin the minimal counterexample the explorer finds);
//! (3) drive it from `tests/serve_interleave.rs`. See `docs/concurrency.md`.

use crate::serve::kvcache::{hash_tokens, KvPrefixCache, KvRowState};
use crate::serve::kvcodec;
use crate::serve::queue::{BoundedQueue, PushError};
use crate::serve::supervisor::{BreakerSnapshot, BreakerState, CircuitBreaker};
use std::collections::VecDeque;

// ---------------------------------------------------------------------------
// Queue: ops, observations, reference model
// ---------------------------------------------------------------------------

/// One queue operation, as issued by some thread.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QueueOp {
    /// `push(value, high_priority)`.
    Push(i32, bool),
    /// Non-blocking pop across both bands.
    TryPop,
    /// Non-blocking pop from the high band only.
    TryPopHigh,
    /// Blocking pop; schedulable only when it would complete (see module
    /// docs).
    PopBlocking,
    /// Close the queue and drain the leftovers.
    Close,
}

/// What a [`QueueOp`] observed. `Divergence` means the real queue and the
/// model disagreed on one of these.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum QueueObs {
    /// Push accepted.
    Pushed,
    /// Push refused: at capacity (item handed back).
    Full(i32),
    /// Push refused: queue closed (item handed back).
    Closed(i32),
    /// Non-blocking pop yielded an item.
    Item(i32),
    /// Non-blocking pop found nothing (band empty).
    Empty,
    /// Close drained these leftovers, high band first.
    Drained(Vec<i32>),
    /// Blocking pop woke with an item, or `None` after close+drain.
    Woke(Option<i32>),
}

/// Executable specification of [`BoundedQueue`] semantics: two FIFO bands,
/// high drains first, hard capacity across both, close is sticky.
#[derive(Clone, Debug)]
pub struct QueueModel {
    cap: usize,
    high: VecDeque<i32>,
    normal: VecDeque<i32>,
    closed: bool,
}

impl QueueModel {
    pub fn new(capacity: usize) -> Self {
        Self {
            cap: capacity.max(1),
            high: VecDeque::new(),
            normal: VecDeque::new(),
            closed: false,
        }
    }

    fn len(&self) -> usize {
        self.high.len() + self.normal.len()
    }

    /// Whether `op` can complete (linearise) in the current state. Only
    /// [`QueueOp::PopBlocking`] is ever not ready.
    pub fn ready(&self, op: QueueOp) -> bool {
        match op {
            QueueOp::PopBlocking => self.len() > 0 || self.closed,
            _ => true,
        }
    }

    /// Apply `op` (which must be [`ready`](Self::ready)) and return what it
    /// observes.
    pub fn apply(&mut self, op: QueueOp) -> QueueObs {
        match op {
            QueueOp::Push(v, high) => {
                if self.closed {
                    QueueObs::Closed(v)
                } else if self.len() >= self.cap {
                    QueueObs::Full(v)
                } else {
                    if high {
                        self.high.push_back(v);
                    } else {
                        self.normal.push_back(v);
                    }
                    QueueObs::Pushed
                }
            }
            QueueOp::TryPop => self.pop().map_or(QueueObs::Empty, QueueObs::Item),
            QueueOp::TryPopHigh => {
                self.high.pop_front().map_or(QueueObs::Empty, QueueObs::Item)
            }
            QueueOp::PopBlocking => QueueObs::Woke(self.pop()),
            QueueOp::Close => {
                self.closed = true;
                let mut left: Vec<i32> = self.high.drain(..).collect();
                left.extend(self.normal.drain(..));
                QueueObs::Drained(left)
            }
        }
    }

    fn pop(&mut self) -> Option<i32> {
        self.high.pop_front().or_else(|| self.normal.pop_front())
    }
}

/// System-under-test seam: anything that can execute [`QueueOp`]s. Implemented
/// by the real [`BoundedQueue`] and, in tests, by deliberately-broken
/// wrappers that pin the explorer's counterexamples as regressions.
pub trait QueueSut {
    fn apply(&self, op: QueueOp) -> QueueObs;
}

impl QueueSut for BoundedQueue<i32> {
    fn apply(&self, op: QueueOp) -> QueueObs {
        match op {
            QueueOp::Push(v, high) => match self.push(v, high) {
                Ok(()) => QueueObs::Pushed,
                Err(PushError::Full(v)) => QueueObs::Full(v),
                Err(PushError::Closed(v)) => QueueObs::Closed(v),
            },
            QueueOp::TryPop => self.try_pop().map_or(QueueObs::Empty, QueueObs::Item),
            QueueOp::TryPopHigh => {
                self.try_pop_high().map_or(QueueObs::Empty, QueueObs::Item)
            }
            // Scheduled only when the model says it completes, so this
            // never parks during replay (see module docs).
            QueueOp::PopBlocking => QueueObs::Woke(self.pop_blocking()),
            QueueOp::Close => QueueObs::Drained(self.close()),
        }
    }
}

// ---------------------------------------------------------------------------
// Interleaving explorer
// ---------------------------------------------------------------------------

/// First disagreement between the SUT and the model on some schedule.
#[derive(Clone, Debug)]
pub struct Divergence {
    /// The full `(thread, op)` schedule that exposed it.
    pub schedule: Vec<(usize, QueueOp)>,
    /// Index into `schedule` of the diverging op.
    pub step: usize,
    /// What the reference model observed.
    pub expected: QueueObs,
    /// What the real type observed.
    pub actual: QueueObs,
}

/// Result of exhaustively exploring every schedulable interleaving.
#[derive(Debug)]
pub struct ExploreReport {
    /// Complete schedules enumerated (every thread ran every op).
    pub schedules: usize,
    /// States where ops remained but none was schedulable.
    pub deadlocks: usize,
    /// First model/SUT disagreement found, if any.
    pub divergence: Option<Divergence>,
}

/// Exhaustively enumerate every interleaving of the per-thread op sequences
/// in `threads` that respects [`QueueModel::ready`], replay each complete
/// (and each deadlocked) schedule on a fresh SUT from `mk`, and compare
/// observations step by step against a fresh [`QueueModel`].
pub fn explore_queue<S: QueueSut>(
    capacity: usize,
    threads: &[Vec<QueueOp>],
    mk: &dyn Fn() -> S,
) -> ExploreReport {
    let mut report = ExploreReport { schedules: 0, deadlocks: 0, divergence: None };
    let mut pos = vec![0usize; threads.len()];
    let mut trace: Vec<(usize, QueueOp)> = Vec::new();
    dfs(&QueueModel::new(capacity), capacity, threads, &mut pos, &mut trace, mk, &mut report);
    report
}

fn dfs<S: QueueSut>(
    model: &QueueModel,
    capacity: usize,
    threads: &[Vec<QueueOp>],
    pos: &mut [usize],
    trace: &mut Vec<(usize, QueueOp)>,
    mk: &dyn Fn() -> S,
    report: &mut ExploreReport,
) {
    let mut any_remaining = false;
    let mut scheduled = false;
    for t in 0..threads.len() {
        if pos[t] >= threads[t].len() {
            continue;
        }
        any_remaining = true;
        let op = threads[t][pos[t]];
        if !model.ready(op) {
            continue;
        }
        scheduled = true;
        let mut next = model.clone();
        next.apply(op);
        pos[t] += 1;
        trace.push((t, op));
        dfs(&next, capacity, threads, pos, trace, mk, report);
        trace.pop();
        pos[t] -= 1;
    }
    if !any_remaining {
        report.schedules += 1;
        record_replay(capacity, trace, mk, report);
    } else if !scheduled {
        report.deadlocks += 1;
        // The prefix executed so far must still linearise.
        record_replay(capacity, trace, mk, report);
    }
}

fn record_replay<S: QueueSut>(
    capacity: usize,
    trace: &[(usize, QueueOp)],
    mk: &dyn Fn() -> S,
    report: &mut ExploreReport,
) {
    if report.divergence.is_some() {
        return;
    }
    let sut = mk();
    let mut model = QueueModel::new(capacity);
    for (step, &(_, op)) in trace.iter().enumerate() {
        let expected = model.apply(op);
        let actual = sut.apply(op);
        if expected != actual {
            report.divergence =
                Some(Divergence { schedule: trace.to_vec(), step, expected, actual });
            return;
        }
    }
}

// ---------------------------------------------------------------------------
// KV prefix cache: ops, observations, reference model
// ---------------------------------------------------------------------------

/// One cache operation. Windows come from a caller-supplied table (the
/// drivers verify the table's FNV hashes are collision-free, so the model
/// may key by index where the real cache keys by hash).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CacheOp {
    /// `insert(windows[w], next_token)`.
    Insert(usize, i32),
    /// `probe(windows[w])` + `peek` on a hit.
    Probe(usize),
    /// `evict_lru()` — drop the least-recently-used entry, if any.
    EvictLru,
}

/// What a [`CacheOp`] observed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CacheObs {
    /// Insert completed: how many entries it evicted, and how many encoded
    /// bytes it released (evicted payloads plus, on a refresh, the replaced
    /// payload — see `InsertOutcome::bytes_released`).
    Inserted { evicted: u64, released: u64 },
    /// Probe hit; the stored next token.
    Hit(i32),
    /// Probe missed.
    Miss,
    /// Explicit eviction freed this many bytes (`None`: cache was empty).
    Evicted(Option<u64>),
    /// Pseudo-observation used by the budgeted checker when the SUT's
    /// resident byte count disagrees with the model's after a step.
    Bytes(u64),
}

/// Canonical per-window KV payload shared by the cache SUT and the model's
/// cost function: window index `w` gets `w + 1`-element planes, so windows
/// have *distinct* encoded sizes and a byte budget evicts differently from
/// an entry cap.
pub fn model_row(w: usize) -> KvRowState {
    KvRowState { k: vec![w as f32; w + 1], v: vec![-(w as f32); w + 1] }
}

/// Exact encoded size of [`model_row`]`(w)` under the lossless `f32` codec —
/// the model prices windows with the same function the real cache meters.
pub fn model_row_bytes(w: usize) -> u64 {
    kvcodec::f32_row_bytes(&model_row(w))
}

/// Executable specification of [`KvPrefixCache`] semantics: a bounded
/// MRU-first list — probes and inserts both promote to the front, inserts
/// at capacity evict the back, and with a byte budget inserts keep evicting
/// the back until the encoded payloads fit (an oversized entry is still
/// admitted once the cache is empty, mirroring the `capacity >= 1` floor).
#[derive(Clone, Debug)]
pub struct CacheModel {
    cap: usize,
    /// Byte budget over encoded payloads; 0 = unlimited.
    max_bytes: u64,
    /// MRU-first `(window index, next token, encoded bytes)`.
    entries: Vec<(usize, i32, u64)>,
}

impl CacheModel {
    pub fn new(capacity: usize) -> Self {
        Self::with_bytes(capacity, 0)
    }

    /// A model with a byte budget, pricing window `w` at
    /// [`model_row_bytes`]`(w)` exactly like the SUT's canonical rows.
    pub fn with_bytes(capacity: usize, max_bytes: u64) -> Self {
        Self { cap: capacity.max(1), max_bytes, entries: Vec::new() }
    }

    /// Sum of encoded payload bytes over resident entries.
    pub fn bytes_resident(&self) -> u64 {
        self.entries.iter().map(|&(_, _, b)| b).sum()
    }

    fn over_budget(&self) -> bool {
        self.max_bytes > 0 && self.bytes_resident() > self.max_bytes
    }

    /// Pop the LRU entry into the `(evicted, released)` tally.
    fn pop_lru(&mut self, evicted: &mut u64, released: &mut u64) {
        if let Some((_, _, b)) = self.entries.pop() {
            *evicted += 1;
            *released += b;
        }
    }

    pub fn apply(&mut self, op: CacheOp) -> CacheObs {
        match op {
            CacheOp::Probe(w) => match self.entries.iter().position(|&(e, _, _)| e == w) {
                Some(i) => {
                    let e = self.entries.remove(i);
                    self.entries.insert(0, e);
                    CacheObs::Hit(e.1)
                }
                None => CacheObs::Miss,
            },
            CacheOp::Insert(w, tok) => {
                let cost = model_row_bytes(w);
                let (mut evicted, mut released) = (0u64, 0u64);
                if let Some(i) = self.entries.iter().position(|&(e, _, _)| e == w) {
                    released += self.entries[i].2;
                    self.entries.remove(i);
                    self.entries.insert(0, (w, tok, cost));
                    // a grown payload can overflow the budget; never evict
                    // the just-refreshed MRU entry
                    while self.over_budget() && self.entries.len() > 1 {
                        self.pop_lru(&mut evicted, &mut released);
                    }
                    return CacheObs::Inserted { evicted, released };
                }
                while self.entries.len() >= self.cap {
                    self.pop_lru(&mut evicted, &mut released);
                }
                while self.max_bytes > 0
                    && !self.entries.is_empty()
                    && self.bytes_resident() + cost > self.max_bytes
                {
                    self.pop_lru(&mut evicted, &mut released);
                }
                self.entries.insert(0, (w, tok, cost));
                CacheObs::Inserted { evicted, released }
            }
            CacheOp::EvictLru => match self.entries.pop() {
                Some((_, _, b)) => CacheObs::Evicted(Some(b)),
                None => CacheObs::Evicted(None),
            },
        }
    }
}

/// System-under-test seam for the cache model.
pub trait CacheSut {
    fn apply(&mut self, op: CacheOp, windows: &[Vec<i32>]) -> CacheObs;

    /// Resident encoded bytes — compared step-by-step against the model by
    /// [`check_cache_sequences_budgeted`].
    fn bytes_resident(&self) -> u64;
}

impl CacheSut for KvPrefixCache {
    fn apply(&mut self, op: CacheOp, windows: &[Vec<i32>]) -> CacheObs {
        match op {
            CacheOp::Probe(w) => {
                let win = &windows[w];
                match self.probe(hash_tokens(win), win) {
                    Some(idx) => CacheObs::Hit(self.peek(idx).1),
                    None => CacheObs::Miss,
                }
            }
            CacheOp::Insert(w, tok) => {
                let win = windows[w].clone();
                let len = win.len();
                let kv = model_row(w);
                // the f32 codec cannot fail; a codec error would surface as
                // an all-zero outcome and diverge from the model
                let out = self.insert(hash_tokens(&win), win, len, &kv, tok).unwrap_or_default();
                CacheObs::Inserted { evicted: out.evicted, released: out.bytes_released }
            }
            CacheOp::EvictLru => CacheObs::Evicted(self.evict_lru()),
        }
    }

    fn bytes_resident(&self) -> u64 {
        KvPrefixCache::bytes_resident(self)
    }
}

/// First disagreement between a cache SUT and [`CacheModel`].
#[derive(Clone, Debug)]
pub struct CacheDivergence {
    /// The op sequence that exposed it.
    pub sequence: Vec<CacheOp>,
    /// Index into `sequence` of the diverging op.
    pub step: usize,
    pub expected: CacheObs,
    pub actual: CacheObs,
}

/// Exhaustively run every length-`depth` sequence over `alphabet` against a
/// fresh SUT and a fresh [`CacheModel`], comparing observations step by
/// step. Returns `(sequences checked, first divergence)`.
///
/// The window table must be collision-free under [`hash_tokens`] for the
/// index-keyed model to match the hash-keyed cache — drivers assert this
/// before calling.
pub fn check_cache_sequences<S: CacheSut>(
    capacity: usize,
    windows: &[Vec<i32>],
    alphabet: &[CacheOp],
    depth: usize,
    mk: &dyn Fn() -> S,
) -> (usize, Option<CacheDivergence>) {
    check_sequences_impl(capacity, 0, false, windows, alphabet, depth, mk)
}

/// [`check_cache_sequences`] with a byte budget: the model runs with
/// `max_bytes`, and after every step the SUT's
/// [`bytes_resident`](CacheSut::bytes_resident) must equal the model's —
/// a byte-ledger divergence is reported as [`CacheObs::Bytes`].
pub fn check_cache_sequences_budgeted<S: CacheSut>(
    capacity: usize,
    max_bytes: u64,
    windows: &[Vec<i32>],
    alphabet: &[CacheOp],
    depth: usize,
    mk: &dyn Fn() -> S,
) -> (usize, Option<CacheDivergence>) {
    check_sequences_impl(capacity, max_bytes, true, windows, alphabet, depth, mk)
}

#[allow(clippy::too_many_arguments)]
fn check_sequences_impl<S: CacheSut>(
    capacity: usize,
    max_bytes: u64,
    compare_bytes: bool,
    windows: &[Vec<i32>],
    alphabet: &[CacheOp],
    depth: usize,
    mk: &dyn Fn() -> S,
) -> (usize, Option<CacheDivergence>) {
    let mut checked = 0usize;
    let mut seq = vec![0usize; depth]; // odometer over alphabet indices
    loop {
        checked += 1;
        let ops: Vec<CacheOp> = seq.iter().map(|&i| alphabet[i]).collect();
        let mut model = CacheModel::with_bytes(capacity, max_bytes);
        let mut sut = mk();
        for (step, &op) in ops.iter().enumerate() {
            let expected = model.apply(op);
            let actual = sut.apply(op, windows);
            if expected != actual {
                return (
                    checked,
                    Some(CacheDivergence { sequence: ops, step, expected, actual }),
                );
            }
            if compare_bytes && model.bytes_resident() != sut.bytes_resident() {
                return (
                    checked,
                    Some(CacheDivergence {
                        sequence: ops,
                        step,
                        expected: CacheObs::Bytes(model.bytes_resident()),
                        actual: CacheObs::Bytes(sut.bytes_resident()),
                    }),
                );
            }
        }
        // advance the odometer
        let mut d = 0;
        loop {
            if d == depth {
                return (checked, None);
            }
            seq[d] += 1;
            if seq[d] < alphabet.len() {
                break;
            }
            seq[d] = 0;
            d += 1;
        }
    }
}

// ---------------------------------------------------------------------------
// Circuit breaker: ops, observations, reference model
// ---------------------------------------------------------------------------

/// One breaker operation. All three are non-blocking, so every interleaving
/// is schedulable and the explorer enumerates raw permutations — no
/// `ready` predicate needed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BreakerOp {
    /// `record_success()` — a request completed normally.
    Success,
    /// `record_failure()` — a batch error, worker panic, or factory error.
    Failure,
    /// `admit_with(cooled)` — an admission decision with the cooldown
    /// predicate pinned, since a wall clock is not schedulable.
    Admit { cooled: bool },
}

/// What a [`BreakerOp`] observed: the admission verdict (for admits) plus
/// the state the breaker was left in.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BreakerObs {
    /// Success/failure recorded; the resulting state.
    Recorded(BreakerState),
    /// Admission decision: the verdict and the resulting state.
    Admit { admitted: bool, state: BreakerState },
    /// Pseudo-observation used when the end-of-schedule snapshots (state
    /// plus transition tallies) disagree.
    Snapshot(BreakerSnapshot),
}

/// Executable specification of [`CircuitBreaker`] transitions — the pure
/// function of `(state, op, cooldown_elapsed)` drawn in the state diagram
/// in `serve::supervisor`, including the transition tallies the snapshot
/// reports.
#[derive(Clone, Debug)]
pub struct BreakerModel {
    open_after: u32,
    recover_after: u32,
    state: BreakerState,
    consec_failures: u32,
    consec_successes: u32,
    degraded: u64,
    opens: u64,
    half_opens: u64,
    recoveries: u64,
}

impl BreakerModel {
    pub fn new(open_after: u32, recover_after: u32) -> Self {
        Self {
            open_after,
            // mirrors the real type's floor
            recover_after: recover_after.max(1),
            state: BreakerState::Healthy,
            consec_failures: 0,
            consec_successes: 0,
            degraded: 0,
            opens: 0,
            half_opens: 0,
            recoveries: 0,
        }
    }

    /// State + tallies, for end-of-schedule comparison against the SUT's.
    pub fn snapshot(&self) -> BreakerSnapshot {
        BreakerSnapshot {
            state: self.state,
            degraded: self.degraded,
            opens: self.opens,
            half_opens: self.half_opens,
            recoveries: self.recoveries,
        }
    }

    pub fn apply(&mut self, op: BreakerOp) -> BreakerObs {
        match op {
            BreakerOp::Success => {
                if self.open_after != 0 {
                    self.consec_failures = 0;
                    self.consec_successes = self.consec_successes.saturating_add(1);
                    match self.state {
                        BreakerState::Degraded
                            if self.consec_successes >= self.recover_after =>
                        {
                            self.state = BreakerState::Healthy;
                            self.recoveries += 1;
                        }
                        BreakerState::HalfOpen => {
                            self.state = BreakerState::Healthy;
                            self.recoveries += 1;
                        }
                        _ => {}
                    }
                }
                BreakerObs::Recorded(self.state)
            }
            BreakerOp::Failure => {
                if self.open_after != 0 {
                    self.consec_successes = 0;
                    self.consec_failures = self.consec_failures.saturating_add(1);
                    match self.state {
                        BreakerState::Healthy => {
                            self.state = BreakerState::Degraded;
                            self.degraded += 1;
                            if self.consec_failures >= self.open_after {
                                self.state = BreakerState::Open;
                                self.opens += 1;
                            }
                        }
                        BreakerState::Degraded
                            if self.consec_failures >= self.open_after =>
                        {
                            self.state = BreakerState::Open;
                            self.opens += 1;
                        }
                        BreakerState::HalfOpen => {
                            self.state = BreakerState::Open;
                            self.opens += 1;
                        }
                        _ => {}
                    }
                }
                BreakerObs::Recorded(self.state)
            }
            BreakerOp::Admit { cooled } => {
                let admitted = if self.open_after == 0 {
                    true
                } else {
                    match self.state {
                        BreakerState::Healthy | BreakerState::Degraded => true,
                        BreakerState::Open if cooled => {
                            self.state = BreakerState::HalfOpen;
                            self.half_opens += 1;
                            true
                        }
                        BreakerState::Open | BreakerState::HalfOpen => false,
                    }
                };
                BreakerObs::Admit { admitted, state: self.state }
            }
        }
    }
}

/// System-under-test seam for the breaker model. On the real type `apply`
/// is two lock acquisitions (the transition, then `state()`), which is
/// sound here: replays are single-threaded — the *schedule* carries the
/// concurrency, exactly like the queue explorer.
pub trait BreakerSut {
    fn apply(&self, op: BreakerOp) -> BreakerObs;
    fn snapshot(&self) -> BreakerSnapshot;
}

impl BreakerSut for CircuitBreaker {
    fn apply(&self, op: BreakerOp) -> BreakerObs {
        match op {
            BreakerOp::Success => {
                self.record_success();
                BreakerObs::Recorded(self.state())
            }
            BreakerOp::Failure => {
                self.record_failure();
                BreakerObs::Recorded(self.state())
            }
            BreakerOp::Admit { cooled } => {
                let admitted = self.admit_with(cooled);
                BreakerObs::Admit { admitted, state: self.state() }
            }
        }
    }

    fn snapshot(&self) -> BreakerSnapshot {
        CircuitBreaker::snapshot(self)
    }
}

/// First disagreement between a breaker SUT and [`BreakerModel`].
#[derive(Clone, Debug)]
pub struct BreakerDivergence {
    /// The full `(thread, op)` schedule that exposed it.
    pub schedule: Vec<(usize, BreakerOp)>,
    /// Index of the diverging op, or `schedule.len()` for an
    /// end-of-schedule snapshot mismatch.
    pub step: usize,
    pub expected: BreakerObs,
    pub actual: BreakerObs,
}

/// Result of exhaustively exploring every breaker interleaving.
#[derive(Debug)]
pub struct BreakerExploreReport {
    /// Complete schedules enumerated (every thread ran every op).
    pub schedules: usize,
    /// First model/SUT disagreement found, if any.
    pub divergence: Option<BreakerDivergence>,
}

/// Exhaustively enumerate every interleaving of the per-thread op
/// sequences (all breaker ops are non-blocking, so all interleavings are
/// schedulable), replay each on a fresh SUT from `mk`, and compare
/// observations step by step — plus the final snapshot — against a fresh
/// [`BreakerModel`].
pub fn explore_breaker<S: BreakerSut>(
    open_after: u32,
    recover_after: u32,
    threads: &[Vec<BreakerOp>],
    mk: &dyn Fn() -> S,
) -> BreakerExploreReport {
    let mut report = BreakerExploreReport { schedules: 0, divergence: None };
    let mut pos = vec![0usize; threads.len()];
    let mut trace: Vec<(usize, BreakerOp)> = Vec::new();
    breaker_dfs(open_after, recover_after, threads, &mut pos, &mut trace, mk, &mut report);
    report
}

fn breaker_dfs<S: BreakerSut>(
    open_after: u32,
    recover_after: u32,
    threads: &[Vec<BreakerOp>],
    pos: &mut [usize],
    trace: &mut Vec<(usize, BreakerOp)>,
    mk: &dyn Fn() -> S,
    report: &mut BreakerExploreReport,
) {
    let mut complete = true;
    for t in 0..threads.len() {
        if pos[t] >= threads[t].len() {
            continue;
        }
        complete = false;
        let op = threads[t][pos[t]];
        pos[t] += 1;
        trace.push((t, op));
        breaker_dfs(open_after, recover_after, threads, pos, trace, mk, report);
        trace.pop();
        pos[t] -= 1;
    }
    if complete {
        report.schedules += 1;
        breaker_replay(open_after, recover_after, trace, mk, report);
    }
}

fn breaker_replay<S: BreakerSut>(
    open_after: u32,
    recover_after: u32,
    trace: &[(usize, BreakerOp)],
    mk: &dyn Fn() -> S,
    report: &mut BreakerExploreReport,
) {
    if report.divergence.is_some() {
        return;
    }
    let sut = mk();
    let mut model = BreakerModel::new(open_after, recover_after);
    for (step, &(_, op)) in trace.iter().enumerate() {
        let expected = model.apply(op);
        let actual = sut.apply(op);
        if expected != actual {
            report.divergence =
                Some(BreakerDivergence { schedule: trace.to_vec(), step, expected, actual });
            return;
        }
    }
    let (want, got) = (model.snapshot(), sut.snapshot());
    if want != got {
        report.divergence = Some(BreakerDivergence {
            schedule: trace.to_vec(),
            step: trace.len(),
            expected: BreakerObs::Snapshot(want),
            actual: BreakerObs::Snapshot(got),
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn queue_model_matches_documented_semantics() {
        let mut m = QueueModel::new(2);
        assert_eq!(m.apply(QueueOp::Push(1, false)), QueueObs::Pushed);
        assert_eq!(m.apply(QueueOp::Push(2, true)), QueueObs::Pushed);
        assert_eq!(m.apply(QueueOp::Push(3, false)), QueueObs::Full(3));
        assert_eq!(m.apply(QueueOp::TryPop), QueueObs::Item(2), "high first");
        assert_eq!(m.apply(QueueOp::TryPopHigh), QueueObs::Empty);
        assert_eq!(m.apply(QueueOp::Close), QueueObs::Drained(vec![1]));
        assert_eq!(m.apply(QueueOp::Push(4, false)), QueueObs::Closed(4));
        assert!(m.ready(QueueOp::PopBlocking), "closed queue never blocks");
        assert_eq!(m.apply(QueueOp::PopBlocking), QueueObs::Woke(None));
    }

    #[test]
    fn pop_blocking_not_ready_on_empty_open_queue() {
        let m = QueueModel::new(1);
        assert!(!m.ready(QueueOp::PopBlocking));
        assert!(m.ready(QueueOp::TryPop), "non-blocking ops are always ready");
    }

    /// `Inserted` with no evictions and no refresh releases nothing.
    const CLEAN: CacheObs = CacheObs::Inserted { evicted: 0, released: 0 };

    #[test]
    fn cache_model_promotes_on_probe_and_evicts_lru() {
        let mut m = CacheModel::new(2);
        assert_eq!(m.apply(CacheOp::Insert(0, 10)), CLEAN);
        assert_eq!(m.apply(CacheOp::Insert(1, 11)), CLEAN);
        // probe 0 promotes it, so inserting 2 evicts 1 (LRU), not 0
        assert_eq!(m.apply(CacheOp::Probe(0)), CacheObs::Hit(10));
        assert_eq!(
            m.apply(CacheOp::Insert(2, 12)),
            CacheObs::Inserted { evicted: 1, released: model_row_bytes(1) }
        );
        assert_eq!(m.apply(CacheOp::Probe(1)), CacheObs::Miss);
        assert_eq!(m.apply(CacheOp::Probe(0)), CacheObs::Hit(10));
        assert_eq!(m.bytes_resident(), model_row_bytes(0) + model_row_bytes(2));
    }

    #[test]
    fn cache_model_byte_budget_evicts_differently_from_entry_cap() {
        // windows 0..3 cost 18, 26, 34, 42 bytes; a 64-byte budget holds
        // {0,1} (44) or {2} + {0} (52) but never {2,3} (76)
        assert_eq!(model_row_bytes(0), 18);
        assert_eq!(model_row_bytes(3), 42);
        let mut m = CacheModel::with_bytes(16, 64);
        assert_eq!(m.apply(CacheOp::Insert(0, 10)), CLEAN);
        assert_eq!(m.apply(CacheOp::Insert(1, 11)), CLEAN);
        assert_eq!(m.bytes_resident(), 44);
        // window 3 (42 B) forces both residents out: 44 + 42 > 64, 26 + 42 > 64
        assert_eq!(
            m.apply(CacheOp::Insert(3, 13)),
            CacheObs::Inserted { evicted: 2, released: 44 }
        );
        assert_eq!(m.bytes_resident(), 42);
        // a refresh releases the replaced payload without evicting
        assert_eq!(
            m.apply(CacheOp::Insert(3, 14)),
            CacheObs::Inserted { evicted: 0, released: 42 }
        );
        assert_eq!(m.apply(CacheOp::Probe(3)), CacheObs::Hit(14));
        // explicit eviction reports the freed bytes; empty reports None
        assert_eq!(m.apply(CacheOp::EvictLru), CacheObs::Evicted(Some(42)));
        assert_eq!(m.apply(CacheOp::EvictLru), CacheObs::Evicted(None));
        assert_eq!(m.bytes_resident(), 0);
    }

    #[test]
    fn cache_model_admits_oversized_entry_when_empty() {
        let mut m = CacheModel::with_bytes(4, 20);
        // 26 B > 20 B budget, but the cache is empty: admitted (soft floor)
        assert_eq!(m.apply(CacheOp::Insert(1, 11)), CLEAN);
        assert_eq!(m.bytes_resident(), 26);
        // the next insert clears the oversized resident first
        assert_eq!(
            m.apply(CacheOp::Insert(0, 10)),
            CacheObs::Inserted { evicted: 1, released: 26 }
        );
        assert_eq!(m.bytes_resident(), 18);
    }

    #[test]
    fn explorer_counts_interleavings_of_independent_pushes() {
        // 3 threads x 1 push, no blocking: 3! = 6 schedules, no deadlocks.
        let threads = vec![
            vec![QueueOp::Push(1, false)],
            vec![QueueOp::Push(2, false)],
            vec![QueueOp::Push(3, true)],
        ];
        let report = explore_queue(4, &threads, &|| BoundedQueue::new(4));
        assert_eq!(report.schedules, 6);
        assert_eq!(report.deadlocks, 0);
        assert!(report.divergence.is_none(), "{:?}", report.divergence);
    }

    #[test]
    fn explorer_reports_deadlock_for_unwakeable_pop() {
        let threads = vec![vec![QueueOp::PopBlocking]];
        let report = explore_queue(1, &threads, &|| BoundedQueue::new(1));
        assert_eq!(report.schedules, 0);
        assert_eq!(report.deadlocks, 1);
        assert!(report.divergence.is_none());
    }

    #[test]
    fn breaker_model_walks_the_state_machine() {
        let mut m = BreakerModel::new(2, 2);
        assert_eq!(m.apply(BreakerOp::Failure), BreakerObs::Recorded(BreakerState::Degraded));
        assert_eq!(m.apply(BreakerOp::Failure), BreakerObs::Recorded(BreakerState::Open));
        assert_eq!(
            m.apply(BreakerOp::Admit { cooled: false }),
            BreakerObs::Admit { admitted: false, state: BreakerState::Open }
        );
        assert_eq!(
            m.apply(BreakerOp::Admit { cooled: true }),
            BreakerObs::Admit { admitted: true, state: BreakerState::HalfOpen }
        );
        assert_eq!(m.apply(BreakerOp::Success), BreakerObs::Recorded(BreakerState::Healthy));
        let s = m.snapshot();
        assert_eq!((s.degraded, s.opens, s.half_opens, s.recoveries), (1, 1, 1, 1));
    }

    #[test]
    fn breaker_model_with_open_after_zero_never_transitions() {
        let mut m = BreakerModel::new(0, 1);
        for _ in 0..5 {
            assert_eq!(m.apply(BreakerOp::Failure), BreakerObs::Recorded(BreakerState::Healthy));
        }
        assert_eq!(
            m.apply(BreakerOp::Admit { cooled: false }),
            BreakerObs::Admit { admitted: true, state: BreakerState::Healthy }
        );
        assert_eq!(m.snapshot(), BreakerSnapshot::default());
    }

    #[test]
    fn breaker_explorer_matches_the_real_breaker() {
        use std::time::Duration;
        // 2 failures || 1 success || 1 probe admit: 4!/2! = 12 schedules.
        let threads = vec![
            vec![BreakerOp::Failure, BreakerOp::Failure],
            vec![BreakerOp::Success],
            vec![BreakerOp::Admit { cooled: true }],
        ];
        let report =
            explore_breaker(2, 1, &threads, &|| CircuitBreaker::new(2, 1, Duration::ZERO));
        assert_eq!(report.schedules, 12);
        assert!(report.divergence.is_none(), "{:?}", report.divergence);
    }
}
