//! The serving tier's single seam over `std::sync` / `std::thread` — a
//! loom-style shim plus the repo's concurrency policies in one place.
//!
//! Every runtime module under `serve/` routes its Mutex/Condvar/atomic/
//! thread usage through this module (`cola lint`'s `sync-shim` rule makes
//! that mechanical): swapping these definitions for a model checker's — or
//! instrumenting them — never touches a call site again.
//!
//! Three policies live here rather than at call sites:
//!
//! - **Poison policy** ([`Mutex::lock_or_poisoned`]): serving data guarded
//!   by these locks (queue bands, worker handles) is structurally valid at
//!   every unlock point — mutations are small and self-contained — so a
//!   panicked peer cannot leave it half-written in a way later operations
//!   would misread. We therefore take the poisoned guard and continue
//!   (`PoisonError::into_inner`) instead of propagating panics across
//!   threads; the alternative turns one worker's bug into a pool-wide
//!   abort while clients are still parked on stream channels.
//! - **Lock hierarchy** ([`LockRank`]): locks are ranked, and nested
//!   acquisition must follow strictly increasing rank. `cola lint` checks
//!   this statically per function; debug builds also enforce it at runtime
//!   with a thread-local stack of held ranks, so an inversion panics in
//!   tests long before it deadlocks in production.
//! - **Ordering policy**: counters and gauges that only feed stats
//!   snapshots use `Relaxed` (encapsulated in [`Counter`] / [`Gauge`]);
//!   anything that gates control flow — cancel flags, worker liveness —
//!   uses `SeqCst` ([`Flag::set`]/[`Flag::get`], [`Countdown`]). The one
//!   deliberate exception, [`Flag::poll`], is documented at its definition.

use std::sync::PoisonError;
use std::time::Duration;

pub use std::sync::mpsc::{channel, Receiver, Sender};
pub use std::sync::Arc;
pub use std::thread::JoinHandle;

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};

// ---------------------------------------------------------------------------
// Lock hierarchy
// ---------------------------------------------------------------------------

/// Rank of every lock in the serving tier. Nested acquisition must follow
/// strictly increasing rank; acquiring an equal or lower rank while holding
/// one is an inversion (`cola lint` rule `lock-hierarchy`, plus the
/// debug-build runtime check below). Keep this table in sync with
/// `analysis::rules::LOCK_CLASSES` and `docs/concurrency.md` (the lint
/// table also ranks locks outside the serve tier — e.g. the runtime's
/// compile cache — which take `std::sync::Mutex` directly and have no
/// variant here).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum LockRank {
    /// `ServicePool::workers` — join handles, touched only at shutdown.
    PoolWorkers = 0,
    /// `BoundedQueue::inner` — the admission queue's bands.
    QueueInner = 1,
    /// Reserved for the ROADMAP's sharded pool-level KV cache.
    KvShard = 2,
    /// `Supervisor::lifecycle` — worker restart budget accounting.
    SupervisorLifecycle = 3,
    /// `CircuitBreaker::breaker` — breaker state machine + transition tallies.
    BreakerState = 4,
}

#[cfg(debug_assertions)]
mod rank_check {
    use super::LockRank;
    use std::cell::RefCell;

    thread_local! {
        /// Ranks this thread currently holds, in acquisition order.
        static HELD: RefCell<Vec<LockRank>> = const { RefCell::new(Vec::new()) };
    }

    pub(super) fn acquire(rank: LockRank) {
        HELD.with(|h| {
            let mut h = h.borrow_mut();
            if let Some(&top) = h.last() {
                if top >= rank {
                    // lint: allow(no-panic): debug-only lock-order check — the
                    // whole point is to fail loudly in tests, not deadlock later
                    panic!(
                        "lock-order violation: acquiring {rank:?} while holding {top:?} \
                         (ranks must strictly increase; see docs/concurrency.md)"
                    );
                }
            }
            h.push(rank);
        });
    }

    pub(super) fn release(rank: LockRank) {
        HELD.with(|h| {
            let mut h = h.borrow_mut();
            if let Some(pos) = h.iter().rposition(|&r| r == rank) {
                h.remove(pos);
            }
        });
    }
}

#[cfg(not(debug_assertions))]
mod rank_check {
    use super::LockRank;
    #[inline(always)]
    pub(super) fn acquire(_rank: LockRank) {}
    #[inline(always)]
    pub(super) fn release(_rank: LockRank) {}
}

// ---------------------------------------------------------------------------
// Mutex + Condvar
// ---------------------------------------------------------------------------

/// A ranked mutex with the serve tier's poison policy baked in. See module
/// docs for both policies.
pub struct Mutex<T> {
    rank: LockRank,
    inner: std::sync::Mutex<T>,
}

impl<T> Mutex<T> {
    pub fn new(rank: LockRank, value: T) -> Self {
        Self { rank, inner: std::sync::Mutex::new(value) }
    }

    /// Acquire the lock, taking the data even if a previous holder panicked
    /// (poison policy: serve-tier critical sections leave the data valid at
    /// every unlock point, so continuing is safe; aborting the pool is not).
    /// Debug builds assert the lock hierarchy on entry.
    pub fn lock_or_poisoned(&self) -> MutexGuard<'_, T> {
        // Check order *before* blocking: an inversion is a bug even on the
        // runs where the timing happens not to deadlock.
        rank_check::acquire(self.rank);
        let g = self.inner.lock().unwrap_or_else(PoisonError::into_inner);
        MutexGuard { g: std::mem::ManuallyDrop::new(g), rank: self.rank }
    }
}

/// Guard returned by [`Mutex::lock_or_poisoned`]; releases the lock and pops
/// the debug rank stack on drop.
pub struct MutexGuard<'a, T> {
    /// `ManuallyDrop` so [`Condvar::wait`] can move the std guard out
    /// without running our `Drop` (the rank entry must survive the park:
    /// the lock is reacquired before `wait` returns).
    g: std::mem::ManuallyDrop<std::sync::MutexGuard<'a, T>>,
    rank: LockRank,
}

impl<T> std::ops::Deref for MutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        &**self.g
    }
}

impl<T> std::ops::DerefMut for MutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        &mut **self.g
    }
}

impl<T> Drop for MutexGuard<'_, T> {
    fn drop(&mut self) {
        // SAFETY: `g` is still live here — the only place it is taken out
        // (`Condvar::wait`) forgets the guard instead of dropping it.
        unsafe { std::mem::ManuallyDrop::drop(&mut self.g) };
        rank_check::release(self.rank);
    }
}

/// Condition variable paired with [`Mutex`]; waits tolerate poisoning under
/// the same policy as [`Mutex::lock_or_poisoned`].
pub struct Condvar(std::sync::Condvar);

impl Default for Condvar {
    fn default() -> Self {
        Self::new()
    }
}

impl Condvar {
    pub fn new() -> Self {
        Self(std::sync::Condvar::new())
    }

    /// Atomically release the lock and park; the guard is reacquired before
    /// this returns. The debug rank entry stays on the stack across the
    /// park — the thread still logically holds the lock's place in its
    /// acquisition order, and no code runs on this thread while parked.
    pub fn wait<'a, T>(&self, mut guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
        let rank = guard.rank;
        // SAFETY: `guard` is forgotten immediately below, so its `Drop`
        // (which would drop `g` a second time and pop the rank) never runs.
        let std_g = unsafe { std::mem::ManuallyDrop::take(&mut guard.g) };
        std::mem::forget(guard);
        let std_g = self.0.wait(std_g).unwrap_or_else(PoisonError::into_inner);
        MutexGuard { g: std::mem::ManuallyDrop::new(std_g), rank }
    }

    pub fn notify_one(&self) {
        self.0.notify_one();
    }

    pub fn notify_all(&self) {
        self.0.notify_all();
    }
}

// ---------------------------------------------------------------------------
// Atomics with their ordering policy attached
// ---------------------------------------------------------------------------

/// Monotonic event counter for stats snapshots.
///
/// relaxed: counters are independent tallies read by `stats()` snapshots;
/// no other memory is published through them, so cross-counter skew within
/// one snapshot is acceptable and no ordering stronger than `Relaxed` buys
/// anything.
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub const fn new() -> Self {
        Self(AtomicU64::new(0))
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Occupancy gauge (goes up and down) for stats snapshots.
///
/// relaxed: same policy as [`Counter`] — the gauge feeds snapshots only and
/// publishes no other memory.
#[derive(Default)]
pub struct Gauge(AtomicUsize);

impl Gauge {
    pub const fn new() -> Self {
        Self(AtomicUsize::new(0))
    }

    pub fn add(&self, n: usize) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn sub(&self, n: usize) {
        self.0.fetch_sub(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> usize {
        self.0.load(Ordering::Relaxed)
    }
}

/// One-way boolean used for cooperative cancellation: set once, read often.
#[derive(Default)]
pub struct Flag(AtomicBool);

impl Flag {
    pub const fn new() -> Self {
        Self(AtomicBool::new(false))
    }

    /// Raise the flag (SeqCst: the cancel must be visible to any worker
    /// that subsequently observes the request, on every path).
    pub fn set(&self) {
        self.0.store(true, Ordering::SeqCst);
    }

    /// Read with full ordering — the submit/shutdown paths use this.
    pub fn get(&self) -> bool {
        self.0.load(Ordering::SeqCst)
    }

    /// Hot-loop read for the decode sweep.
    ///
    /// relaxed: cooperative cancellation only needs *eventual* visibility —
    /// a sweep that misses a just-raised flag catches it one decode step
    /// later, which is within the cancel latency the API already promises
    /// ("the engine vacates the row at the next decode step").
    pub fn poll(&self) -> bool {
        self.0.load(Ordering::Relaxed)
    }
}

/// Exponentially-weighted moving average over `u64` samples (nanoseconds in
/// practice), stored as a plain fixed-point integer so hot-path readers pay
/// one atomic load. `observe` folds a sample in with weight 1/8; zero means
/// "no samples yet" (callers treat an empty estimator as *no estimate*, so
/// a genuine 0ns sample is rounded up to 1).
///
/// relaxed: the estimate feeds advisory admission decisions and stats only;
/// a racy read-modify-write between two workers loses at most one sample's
/// weight, which is within the noise an EWMA already smooths over, and no
/// other memory is published through it.
#[derive(Default)]
pub struct Ewma(AtomicU64);

impl Ewma {
    pub const fn new() -> Self {
        Self(AtomicU64::new(0))
    }

    /// Fold one sample into the average (weight 1/8; first sample seeds it).
    pub fn observe(&self, sample: u64) {
        let sample = sample.max(1);
        let cur = self.0.load(Ordering::Relaxed);
        let next = if cur == 0 { sample } else { cur - cur / 8 + sample / 8 };
        self.0.store(next.max(1), Ordering::Relaxed);
    }

    /// Current estimate; 0 = no samples yet. (Named `estimate`, not `get`,
    /// so the hot-path lint's name-based call graph cannot confuse readers
    /// on the decode path with unrelated `get` implementations.)
    pub fn estimate(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Countdown for last-one-out detection (worker liveness). Each participant
/// calls [`arrive`](Self::arrive) exactly once; the call that brings the
/// count to zero returns `true` and runs the epilogue (closing the queue,
/// failing stranded requests).
#[derive(Default)]
pub struct Countdown(AtomicUsize);

impl Countdown {
    pub const fn new() -> Self {
        Self(AtomicUsize::new(0))
    }

    /// Set the number of participants before any of them starts.
    pub fn set(&self, n: usize) {
        self.0.store(n, Ordering::SeqCst);
    }

    /// Record this participant's exit; `true` for the last one out. SeqCst
    /// so exactly one caller wins and it observes every peer's prior writes.
    pub fn arrive(&self) -> bool {
        self.0.fetch_sub(1, Ordering::SeqCst) == 1
    }
}

// ---------------------------------------------------------------------------
// Threads
// ---------------------------------------------------------------------------

/// Spawn a named thread (the seam for all serve-tier spawns).
pub fn spawn_named<F>(name: &str, f: F) -> std::io::Result<JoinHandle<()>>
where
    F: FnOnce() + Send + 'static,
{
    std::thread::Builder::new().name(name.to_string()).spawn(f)
}

/// Sleep the current thread (the seam for all serve-tier sleeps).
pub fn sleep(d: Duration) {
    std::thread::sleep(d);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mutex_roundtrips_and_guards_deref() {
        let m = Mutex::new(LockRank::QueueInner, 41);
        *m.lock_or_poisoned() += 1;
        assert_eq!(*m.lock_or_poisoned(), 42);
    }

    #[test]
    fn lock_or_poisoned_survives_a_panicked_holder() {
        let m = Arc::new(Mutex::new(LockRank::QueueInner, 7));
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let _g = m2.lock_or_poisoned();
            panic!("poison the lock");
        })
        .join();
        assert_eq!(*m.lock_or_poisoned(), 7, "data survives the poisoned holder");
    }

    #[test]
    fn condvar_wait_wakes_and_returns_the_guard() {
        let pair = Arc::new((Mutex::new(LockRank::QueueInner, false), Condvar::new()));
        let p2 = pair.clone();
        let waker = std::thread::spawn(move || {
            *p2.0.lock_or_poisoned() = true;
            p2.1.notify_one();
        });
        let mut g = pair.0.lock_or_poisoned();
        while !*g {
            g = pair.1.wait(g);
        }
        drop(g);
        waker.join().unwrap();
    }

    #[cfg(debug_assertions)]
    #[test]
    fn rank_inversion_panics_in_debug_builds() {
        let outer = Mutex::new(LockRank::QueueInner, ());
        let inner = Mutex::new(LockRank::PoolWorkers, ());
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _g = outer.lock_or_poisoned();
            let _h = inner.lock_or_poisoned(); // rank 0 under rank 1 → inversion
        }));
        assert!(caught.is_err(), "acquiring a lower rank under a higher one must panic");
        // and the correct order passes (the poisoned locks are reusable
        // thanks to the poison policy)
        let _g = inner.lock_or_poisoned();
        let _h = outer.lock_or_poisoned();
    }

    #[test]
    fn counters_gauges_flags_countdowns() {
        let c = Counter::new();
        c.add(3);
        c.add(4);
        assert_eq!(c.get(), 7);

        let g = Gauge::new();
        g.add(5);
        g.sub(2);
        assert_eq!(g.get(), 3);

        let f = Flag::new();
        assert!(!f.get() && !f.poll());
        f.set();
        assert!(f.get() && f.poll());

        let cd = Countdown::new();
        cd.set(2);
        assert!(!cd.arrive());
        assert!(cd.arrive(), "last participant out sees true");
    }

    #[test]
    fn ewma_seeds_then_smooths_and_never_returns_to_zero() {
        let e = Ewma::new();
        assert_eq!(e.estimate(), 0, "no samples yet");
        e.observe(800);
        assert_eq!(e.estimate(), 800, "first sample seeds the estimate");
        e.observe(1600);
        assert_eq!(e.estimate(), 800 - 100 + 200, "1/8 sample weight");
        for _ in 0..200 {
            e.observe(0); // rounded up to 1: the estimator stays non-zero
        }
        assert!(e.estimate() >= 1, "a seeded estimator never reads as empty");
    }
}
