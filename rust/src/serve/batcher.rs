//! Dynamic batcher: groups requests into fixed-size decode batches within a
//! latency window (max_wait), the standard continuous-serving tradeoff.

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// Collects up to `batch_size` items from `rx`, waiting at most `max_wait`
/// after the first item arrives. Returns an empty vec if the channel closed
/// with nothing pending.
pub struct DynamicBatcher {
    pub batch_size: usize,
    pub max_wait: Duration,
}

impl DynamicBatcher {
    pub fn new(batch_size: usize, max_wait: Duration) -> Self {
        Self { batch_size, max_wait }
    }

    /// Blocking collect. `None` = channel closed and drained.
    pub fn collect<T>(&self, rx: &Receiver<T>) -> Option<Vec<T>> {
        // block for the first item
        let first = rx.recv().ok()?;
        let mut batch = vec![first];
        let deadline = Instant::now() + self.max_wait;
        while batch.len() < self.batch_size {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(item) => batch.push(item),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        Some(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn collects_full_batch_immediately() {
        let (tx, rx) = channel();
        for i in 0..4 {
            tx.send(i).unwrap();
        }
        let b = DynamicBatcher::new(4, Duration::from_millis(100));
        let got = b.collect(&rx).unwrap();
        assert_eq!(got, vec![0, 1, 2, 3]);
    }

    #[test]
    fn flushes_partial_after_window() {
        let (tx, rx) = channel();
        tx.send(1).unwrap();
        let b = DynamicBatcher::new(8, Duration::from_millis(20));
        let t0 = Instant::now();
        let got = b.collect(&rx).unwrap();
        assert_eq!(got, vec![1]);
        assert!(t0.elapsed() >= Duration::from_millis(15));
    }

    #[test]
    fn none_when_closed_empty() {
        let (tx, rx) = channel::<i32>();
        drop(tx);
        let b = DynamicBatcher::new(4, Duration::from_millis(5));
        assert!(b.collect(&rx).is_none());
    }

    #[test]
    fn caps_at_batch_size() {
        let (tx, rx) = channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let b = DynamicBatcher::new(4, Duration::from_millis(50));
        assert_eq!(b.collect(&rx).unwrap().len(), 4);
        assert_eq!(b.collect(&rx).unwrap().len(), 4);
        assert_eq!(b.collect(&rx).unwrap().len(), 2);
    }
}
