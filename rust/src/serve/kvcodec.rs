//! Pluggable KV-row codecs for the host-side prefix cache (`kvcache`).
//!
//! CoLA's thesis is that transformer activations are low-rank, so the KV
//! snapshots the cache stores (and ships over the `EngineBackend` KV-row
//! seam) are massively redundant. This module turns that into cache
//! capacity: a [`KvRowState`] is encoded once on insert and decoded once on
//! import, and the cache budgets **bytes** of encoded payload rather than
//! entry counts.
//!
//! # Codec contract
//!
//! | Codec   | Error contract                                               |
//! |---------|--------------------------------------------------------------|
//! | `F32`   | lossless — decode is bit-identical to the input              |
//! | `F16`   | per-element round-to-nearest-even; values exactly            |
//! |         | representable in half precision (integers ≤ 2048, etc.)      |
//! |         | round-trip bit-exact, everything else within half an f16 ulp |
//! | `RankR` | per-layer rank-r truncation via `linalg::svd::`              |
//! |         | `truncated_factor`; max-abs reconstruction error is bounded  |
//! |         | by √(Σ_{i>r} σᵢ²), the truncated spectral tail               |
//!
//! Every [`EncodedPlane`] knows its exact serialized size
//! ([`EncodedPlane::encoded_bytes`] equals `serialize_into`'s output length
//! to the byte — a property test in `tests/kvcodec_props.rs` pins this), so
//! the cache's byte accounting is exact, not estimated.
//!
//! The codec runs only at row-encode boundaries (`encode_row` in the
//! engine), never inside the decode hot loop — the `cola lint` hot-path
//! pass keeps it that way.

use crate::linalg::{truncated_factor, Mat};
use crate::serve::kvcache::KvRowState;
use anyhow::Result;

/// A fully-specified codec, as handed to the cache and the engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvCodec {
    /// Identity: planes stored as raw f32 — lossless.
    F32,
    /// Half-precision planes: 2 bytes/element, round-to-nearest-even.
    F16,
    /// Per-layer truncated rank-`rank` factorization: a `rows × cols` plane
    /// becomes `rows × rank` + `rank × cols` factors.
    RankR { rank: usize },
}

/// The config-facing codec name: what `kv_codec=...` parses into. The rank
/// for `RankR` arrives through the separate `kv_rank` knob, so the two
/// overrides compose in either order; [`KvCodecKind::with_rank`] joins them
/// into a [`KvCodec`] at engine-start time.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum KvCodecKind {
    #[default]
    F32,
    F16,
    RankR,
}

impl KvCodecKind {
    /// Parse a config value; unknown names are rejected with a typed error
    /// listing the accepted set.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(KvCodecKind::F32),
            "f16" => Ok(KvCodecKind::F16),
            "rankr" => Ok(KvCodecKind::RankR),
            _ => anyhow::bail!("unknown kv codec `{s}` (expected f32|f16|rankr)"),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            KvCodecKind::F32 => "f32",
            KvCodecKind::F16 => "f16",
            KvCodecKind::RankR => "rankr",
        }
    }

    /// Combine with the configured rank. The rank is clamped to ≥ 1 — a
    /// rank-0 codec would decode every plane to zeros, which is never what
    /// a config meant.
    pub fn with_rank(self, rank: usize) -> KvCodec {
        match self {
            KvCodecKind::F32 => KvCodec::F32,
            KvCodecKind::F16 => KvCodec::F16,
            KvCodecKind::RankR => KvCodec::RankR { rank: rank.max(1) },
        }
    }
}

/// Logical shape of one KV plane as stacked per-layer matrices. Only the
/// `RankR` codec consults it (the factorization needs matrix structure);
/// `F32`/`F16` treat the plane as a flat vector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PlaneGeom {
    pub layers: usize,
    pub rows: usize,
    pub cols: usize,
}

impl PlaneGeom {
    pub fn flat(elems: usize) -> Self {
        Self { layers: 1, rows: 1, cols: elems }
    }

    pub fn elems(&self) -> usize {
        self.layers * self.rows * self.cols
    }
}

/// One encoded KV plane. The serialized layout (little-endian) is:
///
/// - `F32`:   tag `0u8` · `len: u32` · `len × f32`          → 5 + 4·len bytes
/// - `F16`:   tag `1u8` · `len: u32` · `len × u16`          → 5 + 2·len bytes
/// - `RankR`: tag `2u8` · `layers,rows,cols,rank: 4 × u32`
///   · per layer `rows·rank + rank·cols` f32 factors
///   → 17 + 4·layers·(rows·rank + rank·cols) bytes
#[derive(Clone, Debug, PartialEq)]
pub enum EncodedPlane {
    F32(Vec<f32>),
    F16(Vec<u16>),
    RankR { layers: usize, rows: usize, cols: usize, rank: usize, factors: Vec<f32> },
}

impl EncodedPlane {
    /// Exact serialized size in bytes — matches `serialize_into` output
    /// length for every variant (pinned by a property test).
    pub fn encoded_bytes(&self) -> u64 {
        match self {
            EncodedPlane::F32(d) => 5 + 4 * d.len() as u64,
            EncodedPlane::F16(d) => 5 + 2 * d.len() as u64,
            EncodedPlane::RankR { factors, .. } => 17 + 4 * factors.len() as u64,
        }
    }

    pub fn serialize_into(&self, out: &mut Vec<u8>) {
        match self {
            EncodedPlane::F32(d) => {
                out.push(0);
                out.extend_from_slice(&(d.len() as u32).to_le_bytes());
                for x in d {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
            EncodedPlane::F16(d) => {
                out.push(1);
                out.extend_from_slice(&(d.len() as u32).to_le_bytes());
                for x in d {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
            EncodedPlane::RankR { layers, rows, cols, rank, factors } => {
                out.push(2);
                for dim in [*layers, *rows, *cols, *rank] {
                    out.extend_from_slice(&(dim as u32).to_le_bytes());
                }
                for x in factors {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
        }
    }

    /// Parse one plane from the front of `buf`; returns the plane and the
    /// number of bytes consumed.
    pub fn deserialize_from(buf: &[u8]) -> Result<(EncodedPlane, usize)> {
        let Some(&tag) = buf.first() else {
            anyhow::bail!("encoded plane: empty buffer");
        };
        match tag {
            0 => {
                let len = read_u32(buf, 1)? as usize;
                let mut data = Vec::with_capacity(len);
                for i in 0..len {
                    data.push(f32::from_le_bytes(read4(buf, 5 + 4 * i)?));
                }
                Ok((EncodedPlane::F32(data), 5 + 4 * len))
            }
            1 => {
                let len = read_u32(buf, 1)? as usize;
                let mut data = Vec::with_capacity(len);
                for i in 0..len {
                    let off = 5 + 2 * i;
                    let Some(pair) = buf.get(off..off + 2) else {
                        anyhow::bail!("encoded plane: truncated at byte {off}");
                    };
                    data.push(u16::from_le_bytes([pair[0], pair[1]]));
                }
                Ok((EncodedPlane::F16(data), 5 + 2 * len))
            }
            2 => {
                let layers = read_u32(buf, 1)? as usize;
                let rows = read_u32(buf, 5)? as usize;
                let cols = read_u32(buf, 9)? as usize;
                let rank = read_u32(buf, 13)? as usize;
                let n = layers * (rows * rank + rank * cols);
                let mut factors = Vec::with_capacity(n);
                for i in 0..n {
                    factors.push(f32::from_le_bytes(read4(buf, 17 + 4 * i)?));
                }
                Ok((EncodedPlane::RankR { layers, rows, cols, rank, factors }, 17 + 4 * n))
            }
            other => anyhow::bail!("encoded plane: unknown tag {other}"),
        }
    }

    /// Decode into `out` (cleared first), so the engine can reuse one
    /// scratch buffer per slot across imports.
    pub fn decode_into(&self, out: &mut Vec<f32>) {
        out.clear();
        match self {
            EncodedPlane::F32(d) => out.extend_from_slice(d),
            EncodedPlane::F16(d) => out.extend(d.iter().map(|&h| f16_to_f32(h))),
            EncodedPlane::RankR { layers, rows, cols, rank, factors } => {
                let (layers, rows, cols, rank) = (*layers, *rows, *cols, *rank);
                out.reserve(layers * rows * cols);
                let per_layer = rows * rank + rank * cols;
                for layer in 0..layers {
                    let base = layer * per_layer;
                    let l = &factors[base..base + rows * rank];
                    let rt = &factors[base + rows * rank..base + per_layer];
                    for i in 0..rows {
                        for j in 0..cols {
                            let mut s = 0.0f64;
                            for k in 0..rank {
                                s += l[i * rank + k] as f64 * rt[k * cols + j] as f64;
                            }
                            out.push(s as f32);
                        }
                    }
                }
            }
        }
    }
}

/// An encoded KV-row snapshot: the cache's stored payload.
#[derive(Clone, Debug, PartialEq)]
pub struct EncodedKvRow {
    pub k: EncodedPlane,
    pub v: EncodedPlane,
}

impl EncodedKvRow {
    pub fn encoded_bytes(&self) -> u64 {
        self.k.encoded_bytes() + self.v.encoded_bytes()
    }

    pub fn serialize(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.encoded_bytes() as usize);
        self.k.serialize_into(&mut out);
        self.v.serialize_into(&mut out);
        out
    }

    pub fn deserialize(buf: &[u8]) -> Result<EncodedKvRow> {
        let (k, used) = EncodedPlane::deserialize_from(buf)?;
        let (v, used_v) = EncodedPlane::deserialize_from(&buf[used..])?;
        anyhow::ensure!(
            used + used_v == buf.len(),
            "encoded KV row: {} trailing bytes",
            buf.len() - used - used_v
        );
        Ok(EncodedKvRow { k, v })
    }

    pub fn decode_into(&self, out: &mut KvRowState) {
        self.k.decode_into(&mut out.k);
        self.v.decode_into(&mut out.v);
    }
}

/// Encode a row snapshot under `codec`. `geom` describes both planes (k and
/// v have identical shape at this seam); only `RankR` validates it.
pub fn encode_row(kv: &KvRowState, codec: KvCodec, geom: PlaneGeom) -> Result<EncodedKvRow> {
    Ok(EncodedKvRow { k: encode_plane(&kv.k, codec, geom)?, v: encode_plane(&kv.v, codec, geom)? })
}

/// Serialized size a row would take under the lossless `F32` codec — the
/// baseline `kv_bytes_saved` is measured against.
pub fn f32_row_bytes(kv: &KvRowState) -> u64 {
    10 + 4 * (kv.k.len() + kv.v.len()) as u64
}

fn encode_plane(data: &[f32], codec: KvCodec, geom: PlaneGeom) -> Result<EncodedPlane> {
    match codec {
        KvCodec::F32 => Ok(EncodedPlane::F32(data.to_vec())),
        KvCodec::F16 => Ok(EncodedPlane::F16(data.iter().map(|&x| f32_to_f16(x)).collect())),
        KvCodec::RankR { rank } => {
            anyhow::ensure!(
                geom.elems() == data.len() && geom.rows > 0 && geom.cols > 0,
                "rank-r codec needs a matching plane geometry: {}x{}x{} vs {} elems",
                geom.layers,
                geom.rows,
                geom.cols,
                data.len()
            );
            let r = rank.min(geom.rows).min(geom.cols);
            let per = geom.rows * geom.cols;
            let mut factors = Vec::with_capacity(geom.layers * (geom.rows * r + r * geom.cols));
            for layer in 0..geom.layers {
                let plane = &data[layer * per..(layer + 1) * per];
                let m = Mat::from_f32(geom.rows, geom.cols, plane);
                let (l, rt) = truncated_factor(&m, r);
                factors.extend(l.data.iter().map(|&x| x as f32));
                factors.extend(rt.data.iter().map(|&x| x as f32));
            }
            Ok(EncodedPlane::RankR {
                layers: geom.layers,
                rows: geom.rows,
                cols: geom.cols,
                rank: r,
                factors,
            })
        }
    }
}

/// f32 → f16 bit conversion, round-to-nearest-even (ties to even), with
/// inf/nan/subnormal handling. Hand-rolled: the crate is dependency-free.
pub fn f32_to_f16(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let man = bits & 0x007f_ffff;
    if exp == 255 {
        // inf stays inf; nan keeps a set mantissa bit so it stays nan
        return sign | 0x7c00 | u16::from(man != 0) << 9;
    }
    let unbiased = exp - 127;
    if unbiased > 15 {
        return sign | 0x7c00; // overflow → ±inf
    }
    if unbiased >= -14 {
        // normal half: keep 10 mantissa bits, round the dropped 13
        let mut m = man >> 13;
        let dropped = man & 0x1fff;
        let mut e = (unbiased + 15) as u32;
        if dropped > 0x1000 || (dropped == 0x1000 && (m & 1) != 0) {
            m += 1;
            if m == 0x400 {
                m = 0;
                e += 1;
                if e >= 31 {
                    return sign | 0x7c00;
                }
            }
        }
        return sign | ((e as u16) << 10) | (m as u16);
    }
    if unbiased < -25 {
        return sign; // underflow → ±0 (2⁻²⁵ itself ties to even = 0)
    }
    // subnormal half: make the implicit leading 1 explicit, then shift
    let full = man | 0x0080_0000;
    let shift = (-14 - unbiased) as u32 + 13; // in 14..=24
    let mut m = full >> shift;
    let rem = full & ((1u32 << shift) - 1);
    let half = 1u32 << (shift - 1);
    if rem > half || (rem == half && (m & 1) != 0) {
        m += 1; // a carry past 0x3ff lands on the smallest normal — valid
    }
    sign | (m as u16)
}

/// f16 → f32 bit conversion (exact — every f16 value is representable).
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let man = (h & 0x03ff) as u32;
    if exp == 0 {
        // ±0 or subnormal: value is man × 2⁻²⁴ (exact in f32)
        let mag = man as f32 / 16_777_216.0;
        return if sign != 0 { -mag } else { mag };
    }
    if exp == 31 {
        return f32::from_bits(sign | 0x7f80_0000 | (man << 13));
    }
    f32::from_bits(sign | ((exp + 112) << 23) | (man << 13))
}

fn read_u32(buf: &[u8], at: usize) -> Result<u32> {
    read4(buf, at).map(u32::from_le_bytes)
}

fn read4(buf: &[u8], at: usize) -> Result<[u8; 4]> {
    let Some(b) = buf.get(at..at + 4) else {
        anyhow::bail!("encoded plane: truncated at byte {at}");
    };
    Ok([b[0], b[1], b[2], b[3]])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codec_kind_parses_and_rejects() {
        assert_eq!(KvCodecKind::parse("f32").unwrap(), KvCodecKind::F32);
        assert_eq!(KvCodecKind::parse("f16").unwrap(), KvCodecKind::F16);
        assert_eq!(KvCodecKind::parse("rankr").unwrap(), KvCodecKind::RankR);
        for bad in ["f64", "rank-r", "F16", "", "int8"] {
            assert!(KvCodecKind::parse(bad).is_err(), "`{bad}` must be rejected");
        }
        assert_eq!(KvCodecKind::F16.with_rank(4), KvCodec::F16);
        assert_eq!(KvCodecKind::RankR.with_rank(4), KvCodec::RankR { rank: 4 });
        assert_eq!(KvCodecKind::RankR.with_rank(0), KvCodec::RankR { rank: 1 });
    }

    #[test]
    fn f16_known_values_round_trip() {
        for (x, bits) in [
            (0.0f32, 0x0000u16),
            (-0.0, 0x8000),
            (1.0, 0x3c00),
            (-2.0, 0xc000),
            (0.5, 0x3800),
            (65504.0, 0x7bff),       // f16::MAX
            (6.103_515_6e-5, 0x0400), // smallest normal 2⁻¹⁴
            (5.960_464_5e-8, 0x0001), // smallest subnormal 2⁻²⁴
        ] {
            assert_eq!(f32_to_f16(x), bits, "encode {x}");
            assert_eq!(f16_to_f32(bits), x, "decode {bits:#06x}");
        }
        assert_eq!(f32_to_f16(f32::INFINITY), 0x7c00);
        assert_eq!(f32_to_f16(f32::NEG_INFINITY), 0xfc00);
        assert!(f16_to_f32(f32_to_f16(f32::NAN)).is_nan());
        assert_eq!(f32_to_f16(1e9), 0x7c00, "overflow saturates to inf");
        assert_eq!(f32_to_f16(1e-10), 0x0000, "underflow flushes to zero");
    }

    #[test]
    fn f16_rounds_to_nearest_even() {
        // 1 + 2⁻¹¹ is exactly halfway between 1.0 and the next f16
        // (1 + 2⁻¹⁰); ties-to-even keeps the even mantissa 1.0.
        assert_eq!(f32_to_f16(1.0 + 2f32.powi(-11)), 0x3c00);
        // Just above the tie rounds up.
        assert_eq!(f32_to_f16(1.0 + 2f32.powi(-11) + 2f32.powi(-20)), 0x3c01);
        // (1 + 3·2⁻¹¹): halfway between 0x3c01 (odd) and 0x3c02 → even 0x3c02.
        assert_eq!(f32_to_f16(1.0 + 3.0 * 2f32.powi(-11)), 0x3c02);
        // Small integers are exact.
        for t in 0..=2048 {
            let x = t as f32;
            assert_eq!(f16_to_f32(f32_to_f16(x)), x, "integer {t} must be f16-exact");
        }
    }

    #[test]
    fn integers_above_2048_are_not_exact_but_close() {
        let x = 2049.0f32;
        let y = f16_to_f32(f32_to_f16(x));
        assert_ne!(x, y);
        assert!((x - y).abs() <= 1.0, "within one f16 ulp at this magnitude");
    }

    fn row(k: Vec<f32>, v: Vec<f32>) -> KvRowState {
        KvRowState { k, v }
    }

    #[test]
    fn f32_codec_is_lossless_and_sized_exactly() {
        let kv = row(vec![1.5, -2.25, 3.0], vec![0.0, 7.125, -1.0]);
        let enc = encode_row(&kv, KvCodec::F32, PlaneGeom::flat(3)).unwrap();
        assert_eq!(enc.encoded_bytes(), 2 * (5 + 4 * 3));
        assert_eq!(enc.encoded_bytes(), f32_row_bytes(&kv));
        let bytes = enc.serialize();
        assert_eq!(bytes.len() as u64, enc.encoded_bytes());
        let back = EncodedKvRow::deserialize(&bytes).unwrap();
        assert_eq!(back, enc);
        let mut out = row(vec![], vec![]);
        enc.decode_into(&mut out);
        assert_eq!(out, kv);
    }

    #[test]
    fn f16_codec_halves_payload() {
        let kv = row(vec![1.0; 8], vec![2.0; 8]);
        let enc = encode_row(&kv, KvCodec::F16, PlaneGeom::flat(8)).unwrap();
        assert_eq!(enc.encoded_bytes(), 2 * (5 + 2 * 8));
        let bytes = enc.serialize();
        assert_eq!(bytes.len() as u64, enc.encoded_bytes());
        assert_eq!(EncodedKvRow::deserialize(&bytes).unwrap(), enc);
        let mut out = row(vec![], vec![]);
        enc.decode_into(&mut out);
        assert_eq!(out, kv, "f16-exact values round-trip losslessly");
    }

    #[test]
    fn rankr_reconstructs_low_rank_planes_and_compresses() {
        // 4×6 rank-1 plane: outer product of two vectors, two layers.
        let u = [1.0f32, -2.0, 0.5, 3.0];
        let w = [2.0f32, 1.0, -1.0, 0.25, 4.0, -0.5];
        let mut plane = Vec::new();
        for layer in 0..2 {
            let scale = (layer + 1) as f32;
            for &ui in &u {
                for &wj in &w {
                    plane.push(scale * ui * wj);
                }
            }
        }
        let kv = row(plane.clone(), plane.iter().map(|x| -x).collect());
        let geom = PlaneGeom { layers: 2, rows: 4, cols: 6 };
        let enc = encode_row(&kv, KvCodec::RankR { rank: 1 }, geom).unwrap();
        // 17 + 4·2·(4·1 + 1·6) per plane = 97 < 5 + 4·48 = 197 raw
        assert_eq!(enc.encoded_bytes(), 2 * (17 + 4 * 2 * (4 + 6)));
        assert!(enc.encoded_bytes() < f32_row_bytes(&kv));
        let bytes = enc.serialize();
        assert_eq!(bytes.len() as u64, enc.encoded_bytes());
        assert_eq!(EncodedKvRow::deserialize(&bytes).unwrap(), enc);
        let mut out = row(vec![], vec![]);
        enc.decode_into(&mut out);
        for (a, b) in kv.k.iter().zip(&out.k) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
        for (a, b) in kv.v.iter().zip(&out.v) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn rankr_rejects_mismatched_geometry() {
        let kv = row(vec![0.0; 10], vec![0.0; 10]);
        let geom = PlaneGeom { layers: 1, rows: 3, cols: 3 }; // 9 ≠ 10
        assert!(encode_row(&kv, KvCodec::RankR { rank: 2 }, geom).is_err());
        assert!(encode_row(&kv, KvCodec::F32, geom).is_ok(), "f32 ignores geometry");
    }

    #[test]
    fn deserialize_rejects_garbage() {
        assert!(EncodedKvRow::deserialize(&[]).is_err());
        assert!(EncodedKvRow::deserialize(&[9, 0, 0, 0, 0]).is_err(), "unknown tag");
        let kv = row(vec![1.0, 2.0], vec![3.0, 4.0]);
        let enc = encode_row(&kv, KvCodec::F32, PlaneGeom::flat(2)).unwrap();
        let mut bytes = enc.serialize();
        bytes.pop();
        assert!(EncodedKvRow::deserialize(&bytes).is_err(), "truncation");
        bytes.push(0);
        bytes.push(0);
        assert!(EncodedKvRow::deserialize(&bytes).is_err(), "trailing bytes");
    }
}
