//! Scripted fault injection: a deterministic, seeded [`FaultPlan`] plus a
//! [`FaultInjectingBackend`] wrapper that turns any [`EngineBackend`] (mock
//! or PJRT) into a misbehaving one — on a script, not by accident.
//!
//! The plan is a list of `(fault kind, schedule)` pairs. Schedules count
//! calls **per injection site** (prefill / decode / export / import), so
//! "fail the 5th prefill" and "fail every 7th decode step" compose without
//! interfering. Probabilistic schedules draw from a splitmix64 stream
//! seeded by `(plan seed, worker index)`, so a chaos soak is byte-for-byte
//! reproducible across runs while different workers still see different
//! fault timings.
//!
//! Fault taxonomy (see `docs/robustness.md`):
//!
//! | kind              | site    | surfaces as                               |
//! |-------------------|---------|-------------------------------------------|
//! | `DecodeError`     | decode  | `Err` from `decode_step` → batch failure  |
//! | `PrefillError`    | prefill | `Err` from `prefill_row` → batch failure  |
//! | `ExportCorrupt`   | export  | sign-flipped KV snapshot → poisoned cache |
//! | `ImportError`     | import  | `Err` from `import_kv_row`                |
//! | `LatencySpike`    | decode  | bounded stall before the step runs        |
//! | `WorkerHang`      | decode  | longer bounded stall (SLO pressure)       |
//! | `WorkerPanic`     | decode  | thread panic → supervisor restart path    |
//!
//! `LatencySpike` and `WorkerHang` differ only in intent and typical
//! duration: both are *bounded* stalls (an unbounded hang would wedge the
//! chaos soak itself); the hang is long enough to blow deadlines and feed
//! the EWMA shedding path, the spike is jitter.
//!
//! This module replaces `MockBackend::fail_after` — a single hard-coded
//! one-shot decode error — with a composable plan any backend can carry.

use crate::metrics;
use crate::serve::engine::EngineBackend;
use crate::serve::kvcache::KvRowState;
use crate::serve::kvcodec::PlaneGeom;
use crate::serve::sync;
use anyhow::Result;
use std::time::Duration;

/// What goes wrong when a scheduled fault fires.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// `decode_step` returns an error (the whole batch fails over to the
    /// salvage path).
    DecodeError,
    /// `prefill_row` returns an error (a single-row encode fails, which the
    /// worker treats as a batch failure for that round).
    PrefillError,
    /// `export_kv_row` silently returns a sign-flipped snapshot — the
    /// corruption is only observable when the poisoned cache entry is later
    /// imported and the backend's cross-checks (or the model's outputs)
    /// disagree.
    ExportCorrupt,
    /// `import_kv_row` returns an error (a cache restore fails mid-join).
    ImportError,
    /// A bounded stall before the decode step runs.
    LatencySpike(Duration),
    /// A longer bounded stall — long enough to blow deadlines, not long
    /// enough to wedge a test harness.
    WorkerHang(Duration),
    /// The worker thread panics inside `decode_step` — the supervision /
    /// restart path's trigger.
    WorkerPanic,
}

/// Which backend entry point a fault kind intercepts.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Site {
    Prefill = 0,
    Decode = 1,
    Export = 2,
    Import = 3,
}

impl FaultKind {
    fn site(self) -> Site {
        match self {
            FaultKind::PrefillError => Site::Prefill,
            FaultKind::ExportCorrupt => Site::Export,
            FaultKind::ImportError => Site::Import,
            FaultKind::DecodeError
            | FaultKind::LatencySpike(_)
            | FaultKind::WorkerHang(_)
            | FaultKind::WorkerPanic => Site::Decode,
        }
    }
}

/// When a fault fires, counted in calls to its site (1-based).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultSchedule {
    /// Fire on exactly the `n`th call to the site (1-based; 0 ≡ 1), once.
    Once(u64),
    /// Fire on every `n`th call (n = 0 never fires).
    EveryNth(u64),
    /// Fire on each call with probability `num/den`, drawn from the plan's
    /// seeded splitmix64 stream (`den` = 0 never fires).
    Probabilistic { num: u32, den: u32 },
}

/// A deterministic fault script: seed + `(kind, schedule)` list. `Clone` so
/// one plan can arm every worker of a pool (each worker's stream is
/// re-seeded with its index).
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    seed: u64,
    faults: Vec<(FaultKind, FaultSchedule)>,
}

impl FaultPlan {
    /// An empty plan drawing from `seed`.
    pub fn seeded(seed: u64) -> Self {
        Self { seed, faults: Vec::new() }
    }

    /// Add one scheduled fault (builder-style).
    pub fn inject(mut self, kind: FaultKind, schedule: FaultSchedule) -> Self {
        self.faults.push((kind, schedule));
        self
    }

    /// No faults scheduled at all?
    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    /// Arm this plan around a backend for worker `worker`. The worker index
    /// perturbs the probabilistic stream only — call-count schedules stay
    /// identical across workers.
    pub fn wrap<B: EngineBackend>(&self, inner: B, worker: usize) -> FaultInjectingBackend<B> {
        FaultInjectingBackend {
            inner,
            faults: self.faults.iter().map(|&(kind, schedule)| Armed {
                kind,
                schedule,
                fired: false,
            }).collect(),
            calls: [0; 4],
            rng: splitmix64(self.seed ^ splitmix64(worker as u64 + 1)),
        }
    }
}

/// One scheduled fault plus its per-backend firing state.
struct Armed {
    kind: FaultKind,
    schedule: FaultSchedule,
    fired: bool,
}

/// An [`EngineBackend`] that forwards to `inner` but consults its armed
/// fault list at every entry point. Wrap a `Box<dyn EngineBackend>` to slot
/// into an existing backend factory unchanged.
pub struct FaultInjectingBackend<B: EngineBackend> {
    inner: B,
    faults: Vec<Armed>,
    /// Per-site call counters, indexed by [`Site`].
    calls: [u64; 4],
    /// splitmix64 state for probabilistic schedules.
    rng: u64,
}

/// The splitmix64 mixer (same house PRNG as the mock backend's noise):
/// full-period, seedable, and good enough for fault schedules.
fn splitmix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

impl<B: EngineBackend> FaultInjectingBackend<B> {
    /// Count one call to `site` and return the first armed fault that fires
    /// on it, if any. Evaluation order is the plan's insertion order, so
    /// firing is deterministic given (seed, worker, call history).
    fn trip(&mut self, site: Site) -> Option<FaultKind> {
        let n = {
            let c = &mut self.calls[site as usize];
            *c += 1;
            *c
        };
        for f in self.faults.iter_mut() {
            if f.kind.site() != site {
                continue;
            }
            let fire = match f.schedule {
                FaultSchedule::Once(at) => !f.fired && n == at.max(1),
                FaultSchedule::EveryNth(k) => k > 0 && n % k == 0,
                FaultSchedule::Probabilistic { num, den } => {
                    self.rng = splitmix64(self.rng);
                    den > 0 && self.rng % u64::from(den) < u64::from(num)
                }
            };
            if fire {
                f.fired = true;
                metrics::log_info(&format!(
                    "fault injected: {:?} at {site:?} call {n}",
                    f.kind
                ));
                return Some(f.kind);
            }
        }
        None
    }
}

impl<B: EngineBackend> EngineBackend for FaultInjectingBackend<B> {
    fn batch_size(&self) -> usize {
        self.inner.batch_size()
    }

    fn prompt_len(&self) -> usize {
        self.inner.prompt_len()
    }

    fn max_len(&self) -> usize {
        self.inner.max_len()
    }

    fn describe(&self) -> String {
        format!("faulty({})", self.inner.describe())
    }

    fn prefill_row(&mut self, row: usize, window: &[i32], len: usize, keep: usize) -> Result<i32> {
        if let Some(FaultKind::PrefillError) = self.trip(Site::Prefill) {
            anyhow::bail!("injected fault: prefill error (row {row})");
        }
        self.inner.prefill_row(row, window, len, keep)
    }

    // lint: hot-path-end — fault bookkeeping is chaos-harness overhead, not
    // scheduler cost; the wrapped backend's decode_step is its own boundary.
    fn decode_step(&mut self, feed: &[i32], pos: &[usize]) -> Result<Vec<i32>> {
        match self.trip(Site::Decode) {
            Some(FaultKind::DecodeError) => {
                anyhow::bail!("injected fault: decode error");
            }
            Some(FaultKind::LatencySpike(d)) | Some(FaultKind::WorkerHang(d)) => {
                sync::sleep(d);
            }
            Some(FaultKind::WorkerPanic) => {
                // lint: allow(no-panic): the entire point of this fault kind
                // is to exercise the supervisor's catch_unwind/restart path.
                panic!("injected fault: worker panic");
            }
            _ => {}
        }
        self.inner.decode_step(feed, pos)
    }

    fn kv_row_elems(&self) -> usize {
        self.inner.kv_row_elems()
    }

    fn kv_row_geom(&self) -> PlaneGeom {
        self.inner.kv_row_geom()
    }

    fn export_kv_row(&mut self, row: usize) -> Result<KvRowState> {
        let mut kv = self.inner.export_kv_row(row)?;
        if let Some(FaultKind::ExportCorrupt) = self.trip(Site::Export) {
            // Sign-flip both planes: numerically loud enough that any
            // backend cross-check (the mock verifies restored content) or
            // downstream output comparison catches the poisoned entry.
            for x in kv.k.iter_mut().chain(kv.v.iter_mut()) {
                *x = -*x;
            }
        }
        Ok(kv)
    }

    fn import_kv_row(&mut self, row: usize, kv: &KvRowState, len: usize) -> Result<()> {
        if let Some(FaultKind::ImportError) = self.trip(Site::Import) {
            anyhow::bail!("injected fault: KV import error (row {row})");
        }
        self.inner.import_kv_row(row, kv, len)
    }

    fn vacate_row(&mut self, row: usize) {
        self.inner.vacate_row(row);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Minimal backend for schedule tests: succeeds at everything.
    struct NullBackend;

    impl EngineBackend for NullBackend {
        fn batch_size(&self) -> usize {
            2
        }
        fn prompt_len(&self) -> usize {
            4
        }
        fn max_len(&self) -> usize {
            8
        }
        fn describe(&self) -> String {
            "null".into()
        }
        fn prefill_row(&mut self, _r: usize, _w: &[i32], _l: usize, _k: usize) -> Result<i32> {
            Ok(1)
        }
        fn decode_step(&mut self, feed: &[i32], _pos: &[usize]) -> Result<Vec<i32>> {
            Ok(vec![0; feed.len()])
        }
        fn kv_row_elems(&self) -> usize {
            4
        }
        fn export_kv_row(&mut self, _row: usize) -> Result<KvRowState> {
            Ok(KvRowState { k: vec![1.0; 4], v: vec![2.0; 4] })
        }
        fn import_kv_row(&mut self, _row: usize, _kv: &KvRowState, _len: usize) -> Result<()> {
            Ok(())
        }
    }

    fn step(b: &mut FaultInjectingBackend<NullBackend>) -> Result<Vec<i32>> {
        b.decode_step(&[0, 0], &[0, 0])
    }

    #[test]
    fn once_fires_exactly_once_at_the_scheduled_call() {
        let plan = FaultPlan::seeded(7)
            .inject(FaultKind::DecodeError, FaultSchedule::Once(3));
        let mut b = plan.wrap(NullBackend, 0);
        assert!(step(&mut b).is_ok());
        assert!(step(&mut b).is_ok());
        assert!(step(&mut b).is_err(), "third decode call fires");
        for _ in 0..10 {
            assert!(step(&mut b).is_ok(), "one-shot never re-fires");
        }
    }

    #[test]
    fn every_nth_fires_periodically_per_site() {
        let plan = FaultPlan::seeded(7)
            .inject(FaultKind::DecodeError, FaultSchedule::EveryNth(4));
        let mut b = plan.wrap(NullBackend, 0);
        let outcomes: Vec<bool> = (0..12).map(|_| step(&mut b).is_err()).collect();
        let expect: Vec<bool> = (1..=12u64).map(|n| n % 4 == 0).collect();
        assert_eq!(outcomes, expect);
        // the decode schedule never counts prefill calls
        assert!(b.prefill_row(0, &[0; 4], 1, 0).is_ok());
    }

    #[test]
    fn probabilistic_stream_is_deterministic_per_seed_and_worker() {
        let plan = FaultPlan::seeded(42)
            .inject(FaultKind::DecodeError, FaultSchedule::Probabilistic { num: 1, den: 3 });
        let run = |worker: usize| -> Vec<bool> {
            let mut b = plan.wrap(NullBackend, worker);
            (0..64).map(|_| step(&mut b).is_err()).collect()
        };
        assert_eq!(run(0), run(0), "same seed + worker → identical script");
        assert!(run(0).iter().any(|&f| f), "1/3 odds fire within 64 calls");
        assert!(run(0).iter().any(|&f| !f), "…but not on every call");
        assert_ne!(run(0), run(1), "workers draw from distinct streams");
    }

    #[test]
    fn export_corruption_flips_planes_and_import_fault_errors() {
        let plan = FaultPlan::seeded(1)
            .inject(FaultKind::ExportCorrupt, FaultSchedule::Once(1))
            .inject(FaultKind::ImportError, FaultSchedule::Once(2));
        let mut b = plan.wrap(NullBackend, 0);
        let kv = b.export_kv_row(0).unwrap();
        assert!(kv.k.iter().all(|&x| x == -1.0), "k plane sign-flipped");
        assert!(kv.v.iter().all(|&x| x == -2.0), "v plane sign-flipped");
        let clean = b.export_kv_row(0).unwrap();
        assert!(clean.k.iter().all(|&x| x == 1.0), "one-shot corruption");
        assert!(b.import_kv_row(0, &clean, 1).is_ok());
        assert!(b.import_kv_row(0, &clean, 1).is_err(), "second import fires");
    }

    #[test]
    fn prefill_fault_errors_and_spike_only_delays() {
        let plan = FaultPlan::seeded(1)
            .inject(FaultKind::PrefillError, FaultSchedule::Once(2))
            .inject(
                FaultKind::LatencySpike(Duration::from_millis(1)),
                FaultSchedule::Once(1),
            );
        let mut b = plan.wrap(NullBackend, 0);
        assert!(b.prefill_row(0, &[0; 4], 1, 0).is_ok());
        assert!(b.prefill_row(0, &[0; 4], 1, 0).is_err());
        assert!(step(&mut b).is_ok(), "a spike stalls but succeeds");
    }

    #[test]
    fn worker_panic_fault_panics_for_the_supervisor_to_catch() {
        let plan =
            FaultPlan::seeded(1).inject(FaultKind::WorkerPanic, FaultSchedule::Once(1));
        let mut b = plan.wrap(NullBackend, 0);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = step(&mut b);
        }));
        assert!(caught.is_err(), "WorkerPanic panics out of decode_step");
    }

    #[test]
    fn empty_plan_is_transparent() {
        let plan = FaultPlan::default();
        assert!(plan.is_empty());
        let mut b = plan.wrap(NullBackend, 0);
        assert_eq!(b.describe(), "faulty(null)");
        assert_eq!(b.batch_size(), 2);
        for _ in 0..32 {
            assert!(step(&mut b).is_ok());
        }
    }
}
