//! Inference engine (Table 11's serving path): a dynamic batcher in front of
//! the AOT prefill/decode artifacts with a device-resident KV cache.
//!
//! Threading model: PJRT objects are not `Send`, so a dedicated engine
//! thread owns the client, executables, params and KV caches; callers submit
//! `Request`s over an mpsc channel and receive completions over per-request
//! channels. This is the same leader/worker shape a vLLM-style router uses,
//! scaled to one CPU device.

pub mod batcher;
pub mod engine;

pub use batcher::DynamicBatcher;
pub use engine::{Engine, EngineHandle, Request, Response};
