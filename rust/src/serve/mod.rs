//! Serving (Table 11's inference path): a production-style, multi-model
//! service API over pluggable engine backends.
//!
//! # Architecture
//!
//! ```text
//!  submit(model, prompt, SubmitOptions)
//!        │
//!        ▼
//!   ModelRouter ── UnknownModel? ──► RouteError (typed, no pool touched)
//!        │ dispatch by name
//!        ├─────────────┬──────────────┐
//!        ▼             ▼              ▼
//!  ServicePool     ServicePool    ServicePool      one pool per artifact,
//!  "full_130m"    "sltrain_130m"  "cola_130m"      each with its own:
//!        │
//!        ├── BoundedQueue (priority bands, queue_depth cap
//!        │                 → SubmitError::QueueFull, per-model backpressure)
//!        │        │ pop between decode steps
//!        ▼        ▼
//!   TokenStream ◄── stream events ── engine workers (1..N threads)
//!   .recv()/.cancel()                     │
//!   .wait() → Completion             SlotTable[bs] — continuous batching:
//!                                    vacated rows refill from the queue at
//!                                    the next join-prefill boundary
//!                                         │ prefill / decode_step
//!                                         │ export_kv_rows / import_kv_rows
//!                                         ▼
//!                                    EngineBackend (trait)
//!                                    ├─ PjrtBackend: AOT artifacts on the
//!                                    │  PJRT CPU client (thread-local Rc)
//!                                    └─ MockBackend: deterministic scripted
//!                                       streams — hermetic tests, no
//!                                       artifact on disk
//!                                         ▲
//!                                         │ per-row KV snapshots
//!                                    KvPrefixCache (per worker, host-side
//!                                    bounded LRU keyed by window hash —
//!                                    join prefills whose windows are all
//!                                    cached are *elided* entirely)
//! ```
//!
//! - [`ModelRouter`] owns several named [`ServicePool`]s (the Table 11
//!   full/SLTrain/CoLA variants served from one process), dispatches by
//!   model name with a typed [`RouteError`], aggregates per-model and
//!   fleet-wide [`ServiceStats`], and drains models individually.
//! - [`InferenceService`] is the single-pool trait: `submit` / `stats` /
//!   `shutdown`. [`ServicePool`] implements it over N engine workers
//!   sharing one bounded admission queue.
//! - [`EngineBackend`](engine::EngineBackend) is the seam between
//!   scheduling and model execution: the worker loop (admission, join
//!   prefills, lockstep decode, vacate/refill) is backend-agnostic, so the
//!   whole serving tier — router, slots, queue, streaming, cancellation,
//!   deadlines — tests hermetically on [`MockBackend`] under
//!   `cargo test -q`.
//! - Requests carry typed [`SubmitOptions`] (token budget, stop tokens,
//!   deadline, priority) and resolve through a [`TokenStream`] that yields
//!   tokens as they decode, supports mid-flight [`TokenStream::cancel`], and
//!   ends in a typed [`Completion`] (`tokens`, [`FinishReason`], [`Timing`]).
//! - Admission is explicitly backpressured per model: a bounded queue
//!   refuses submits with [`SubmitError::QueueFull`] rather than hiding
//!   load in an unbounded channel.
//! - **Prefill avoidance** ([`kvcache`]): each worker keeps a bounded LRU
//!   of host-side per-row KV snapshots keyed by window-token hash, filled
//!   through the [`EngineBackend`](engine::EngineBackend) KV-row seam
//!   (`export_kv_rows` / `import_kv_rows`). A join prefill whose occupied
//!   windows are all cached — repeated prefixes like system prompts and
//!   retries, or deterministic re-generations after a rollover — is elided
//!   entirely; stats surface it as `prefill_calls` / `prefills_elided` /
//!   `kv_cache_{hits,misses,evictions}` plus `prefill_nanos` timing.
//!   (Mid-flight rows whose window shifted need a per-row-position decode
//!   artifact to reuse KV across the shift — the RoPE rotation is
//!   position-dependent — so those still re-encode; see ROADMAP.)
//! - **Compressed, byte-budgeted caching** ([`kvcodec`]): cache entries are
//!   stored *encoded* under a pluggable codec (`kv_codec=f32|f16|rankr`,
//!   with `kv_rank` for the low-rank mode) and the cache evicts by encoded
//!   **bytes** (`kv_cache_bytes`) as well as entry count. The codec
//!   contract is explicit: `f32` is lossless (cache on/off streams stay
//!   byte-identical); `f16` rounds to nearest-even, so f16-exact payloads
//!   (like the mock backend's small-integer planes) also stay
//!   byte-identical; `rankr` reconstructs each plane with max-abs error
//!   bounded by the truncated spectral tail √(Σ_{i>r} σᵢ²) — lossy in
//!   general, token-identical whenever the backend's argmax margins exceed
//!   that bound. Byte accounting is exact (`encoded_bytes()` ==
//!   serialized size; `bytes_inserted − bytes_released == bytes_resident`)
//!   and surfaced as `kv_bytes_resident` / `kv_bytes_saved`, with decode
//!   cost timed as `kv_decode_nanos`. Encode/decode runs only at
//!   prefill/import boundaries — never inside the decode hot loop, which
//!   the `cola lint` hot-path pass keeps allocation-free.
//! - **Chunked, priority-aware admission**: at most
//!   `ServeConfig::join_chunk` Normal-priority rows join per prefill
//!   boundary, while High-priority requests pop first and are never
//!   chunk-limited — one burst cannot stall every in-flight decode or
//!   saturate the slot table before urgent work lands.
//!
//! # Concurrency correctness tooling
//!
//! The serving tier is hand-rolled concurrency (Mutex/Condvar queue, atomic
//! cancel flags, shared counters, worker threads), so its invariants are
//! enforced mechanically rather than by review hope:
//!
//! - **[`sync`] seam**: every concurrency primitive used by the serve
//!   runtime is routed through [`serve::sync`](sync) — a thin shim over
//!   `std::sync`/`std::thread` that centralises the poison policy
//!   (`lock_or_poisoned`), a ranked lock hierarchy (checked at runtime in
//!   debug builds), and the memory-ordering policy (typed atomics:
//!   [`sync::Counter`], [`sync::Gauge`], [`sync::Flag`],
//!   [`sync::Countdown`]). Direct `std::sync`/`std::thread` use in
//!   `serve/` is a lint error outside `#[cfg(test)]`.
//! - **`cola lint`** ([`crate::analysis`]): a dependency-free whole-crate
//!   static analyzer run by `scripts/verify.sh`. Per-file rules enforce the
//!   no-panic rule on serve runtime paths, `// SAFETY:` on every `unsafe`,
//!   justification comments on `Ordering::Relaxed`, the declared lock
//!   hierarchy, and the sync-shim routing above; interprocedural passes
//!   propagate held locks across the call graph (acquired-before cycles,
//!   blocking ops under a lock) and walk the declared hot paths rejecting
//!   heap allocation. The hot roots are marked in source with
//!   `// lint: hot-path` — today that is [`engine`]'s steady-state
//!   `decode_loop`, whose transitive call set (sweeping, shedding, refills,
//!   queue draining, slot bookkeeping) must stay allocation-free, with the
//!   backend `decode_step` implementations marked `// lint: hot-path-end`
//!   as the model-execution boundary. Tier-1 tests pin both properties on
//!   this crate's real sources (`analysis` module tests). See
//!   `docs/concurrency.md` for rule codes, waiver syntax, and the baseline
//!   ratchet workflow.
//! - **Interleaving checks** ([`model`] + `tests/serve_interleave.rs`): the
//!   queue and KV-cache semantics are extracted into pure reference models
//!   and checked against the real types under *exhaustive* enumeration of
//!   small-thread interleavings — linearizability by construction, not by
//!   stress-test luck.

pub mod engine;
pub mod kvcache;
pub mod kvcodec;
pub mod mock;
pub mod model;
pub mod queue;
pub mod router;
pub mod service;
pub mod slots;
pub mod sync;

pub use engine::{EngineBackend, PjrtBackend};
pub use kvcache::{InsertOutcome, KvPrefixCache, KvRowState};
pub use kvcodec::{EncodedKvRow, EncodedPlane, KvCodec, KvCodecKind, PlaneGeom};
pub use mock::MockBackend;
pub use queue::BoundedQueue;
pub use router::{ModelRouter, RouteError};
pub use service::{
    CancelHandle, Completion, FinishReason, InferenceService, Priority, QueuedRequest,
    ServicePool, ServiceStats, StreamEvent, SubmitError, SubmitOptions, Timing, TokenStream,
};
pub use slots::SlotTable;
