//! Serving (Table 11's inference path): a production-style, multi-model
//! service API over pluggable engine backends.
//!
//! # Architecture
//!
//! ```text
//!  submit(model, prompt, SubmitOptions)
//!        │
//!        ▼
//!   ModelRouter ── UnknownModel? ──► RouteError (typed, no pool touched)
//!        │ dispatch by name
//!        ├─────────────┬──────────────┐
//!        ▼             ▼              ▼
//!  ServicePool     ServicePool    ServicePool      one pool per artifact,
//!  "full_130m"    "sltrain_130m"  "cola_130m"      each with its own:
//!        │
//!        ├── BoundedQueue (priority bands, queue_depth cap
//!        │                 → SubmitError::QueueFull, per-model backpressure)
//!        │        │ pop after every decode step
//!        ▼        ▼
//!   TokenStream ◄── stream events ── engine workers (1..N threads)
//!   .recv()/.cancel()                     │
//!   .wait() → Completion             SlotTable[bs] — continuous batching:
//!                                    every row carries its own KV write
//!                                    position; a vacated row refills from
//!                                    the queue and is prefilled *alone*,
//!                                    spliced into the live batch while its
//!                                    neighbours keep decoding
//!                                         │ prefill_row / decode_step(pos[])
//!                                         │ export_kv_row / import_kv_row
//!                                         ▼
//!                                    EngineBackend (trait)
//!                                    ├─ PjrtBackend: AOT artifacts on the
//!                                    │  PJRT CPU client (thread-local Rc)
//!                                    └─ MockBackend: deterministic scripted
//!                                       streams — hermetic tests, no
//!                                       artifact on disk
//!                                         ▲
//!                                         │ per-row KV snapshots
//!                                    KvPrefixCache (per worker, host-side
//!                                    bounded LRU keyed by window hash plus
//!                                    a chunked prefix hash chain — a row
//!                                    prefill is elided on a full-window
//!                                    hit, or shortened to its tail on a
//!                                    partial-prefix hit)
//! ```
//!
//! # Batching lifecycle (per-row state machine)
//!
//! There is no batch-wide prefill barrier. Each slot row moves through its
//! own state machine, independent of its neighbours:
//!
//! ```text
//!   vacant ──admit──► fresh ──encode_row──► live(pos = real_len)
//!                                               │ decode_step bumps pos
//!                                               ├─ pos == max_len ──► rollover:
//!                                               │    re-encode this row only
//!                                               ├─ stop/budget/cancel/deadline
//!                                               │        ──► finish → vacant
//!                                               ▼
//!                                           live(pos+1)
//! ```
//!
//! `encode_row` admits one row into a *live* batch: a full-window cache hit
//! imports the snapshot (prefill elided entirely); a partial-prefix hit
//! imports the longest cached prefix and `prefill_row` recomputes only from
//! there (`keep = prefix_len`); a miss runs `prefill_row` from scratch. In
//! every case the resulting KV row is row-scattered into the batch cache at
//! that row's slot while the other rows' entries are untouched — their
//! decode streams are byte-identical whether or not a neighbour joined
//! mid-flight. `decode_step` then takes a *vector* of positions
//! (`pos: &[usize]`, one per row), so rows at different depths advance in
//! one lockstep launch, and rollover (`pos == max_len`) is a per-row event:
//! only the row that hit the window edge re-encodes, at its own position,
//! while the rest keep decoding. Joining latency is therefore O(1) in batch
//! occupancy — exactly one `prefill_row` (or zero, on a cache hit) per
//! admission, never a re-prefill of occupied rows
//! (`tests/serve_prefix_cache.rs`, `cola serve --mock` occupancy sweep).
//!
//! - [`ModelRouter`] owns several named [`ServicePool`]s (the Table 11
//!   full/SLTrain/CoLA variants served from one process), dispatches by
//!   model name with a typed [`RouteError`], aggregates per-model and
//!   fleet-wide [`ServiceStats`], and drains models individually.
//! - [`InferenceService`] is the single-pool trait: `submit` / `stats` /
//!   `shutdown`. [`ServicePool`] implements it over N engine workers
//!   sharing one bounded admission queue.
//! - [`EngineBackend`](engine::EngineBackend) is the seam between
//!   scheduling and model execution: the worker loop (admission, single-row
//!   prefills, per-row-position decode, vacate/refill) is backend-agnostic,
//!   so the whole serving tier — router, slots, queue, streaming,
//!   cancellation, deadlines — tests hermetically on [`MockBackend`] under
//!   `cargo test -q`, including an oracle that asserts the scheduler feeds
//!   each live row its true position every step.
//! - Requests carry typed [`SubmitOptions`] (token budget, stop tokens,
//!   deadline, priority) and resolve through a [`TokenStream`] that yields
//!   tokens as they decode, supports mid-flight [`TokenStream::cancel`], and
//!   ends in a typed [`Completion`] (`tokens`, [`FinishReason`], [`Timing`]).
//! - Admission is explicitly backpressured per model: a bounded queue
//!   refuses submits with [`SubmitError::QueueFull`] rather than hiding
//!   load in an unbounded channel.
//! - **Prefill avoidance** ([`kvcache`]): each worker keeps a bounded LRU
//!   of host-side per-row KV snapshots keyed by window-token hash, filled
//!   through the [`EngineBackend`](engine::EngineBackend) KV-row seam
//!   (`export_kv_row` / `import_kv_row`). A row whose full window is cached
//!   — repeated prefixes like system prompts and retries, or deterministic
//!   re-generations after a rollover — skips `prefill_row` entirely. On a
//!   miss, a **chunked prefix hash chain** is probed: every insert also
//!   registers hashes of the window's prefixes at chunk-multiple lengths,
//!   so a lookup returns the *longest cached prefix* of the new window
//!   (think shared system prompts under different user tails); the prefix
//!   KV is imported and `prefill_row` keeps it (`keep = prefix_len`),
//!   recomputing only the tail. Windows are left-aligned (real tokens at
//!   offsets `0..len`, trailing PAD) precisely so shared prefixes land at
//!   identical offsets regardless of request length. Stats surface all of
//!   it: `prefill_calls` / `prefills_elided` / `kv_cache_{hits,misses,
//!   evictions}` / `partial_prefix_hits` / `partial_prefix_tokens_saved`
//!   plus `prefill_nanos` timing.
//! - **Compressed, byte-budgeted caching** ([`kvcodec`]): cache entries are
//!   stored *encoded* under a pluggable codec (`kv_codec=f32|f16|rankr`,
//!   with `kv_rank` for the low-rank mode) and the cache evicts by encoded
//!   **bytes** (`kv_cache_bytes`) as well as entry count. The codec
//!   contract is explicit: `f32` is lossless (cache on/off streams stay
//!   byte-identical); `f16` rounds to nearest-even, so f16-exact payloads
//!   (like the mock backend's small-integer planes) also stay
//!   byte-identical; `rankr` reconstructs each plane with max-abs error
//!   bounded by the truncated spectral tail √(Σ_{i>r} σᵢ²) — lossy in
//!   general, token-identical whenever the backend's argmax margins exceed
//!   that bound. Byte accounting is exact (`encoded_bytes()` ==
//!   serialized size; `bytes_inserted − bytes_released == bytes_resident`)
//!   and surfaced as `kv_bytes_resident` / `kv_bytes_saved`, with decode
//!   cost timed as `kv_decode_nanos`. Encode/decode runs only at
//!   prefill/import boundaries — never inside the decode hot loop, which
//!   the `cola lint` hot-path pass keeps allocation-free.
//! - **Paced, priority-aware admission**: refill runs after every decode
//!   step, admitting at most `ServeConfig::join_chunk` Normal-priority rows
//!   per step, while High-priority requests pop first and are never
//!   chunk-limited — one burst cannot monopolise vacated slots or starve
//!   urgent work, and because admission is a single-row splice there is no
//!   in-flight decode for it to stall. Per-request admission latency is
//!   surfaced as [`Timing`] `queued` and aggregated as `join_wait_nanos` /
//!   `rows_joined_midflight`.
//!
//! # Concurrency correctness tooling
//!
//! The serving tier is hand-rolled concurrency (Mutex/Condvar queue, atomic
//! cancel flags, shared counters, worker threads), so its invariants are
//! enforced mechanically rather than by review hope:
//!
//! - **[`sync`] seam**: every concurrency primitive used by the serve
//!   runtime is routed through [`serve::sync`](sync) — a thin shim over
//!   `std::sync`/`std::thread` that centralises the poison policy
//!   (`lock_or_poisoned`), a ranked lock hierarchy (checked at runtime in
//!   debug builds), and the memory-ordering policy (typed atomics:
//!   [`sync::Counter`], [`sync::Gauge`], [`sync::Flag`],
//!   [`sync::Countdown`]). Direct `std::sync`/`std::thread` use in
//!   `serve/` is a lint error outside `#[cfg(test)]`.
//! - **`cola lint`** ([`crate::analysis`]): a dependency-free whole-crate
//!   static analyzer run by `scripts/verify.sh`. Per-file rules enforce the
//!   no-panic rule on serve runtime paths, `// SAFETY:` on every `unsafe`,
//!   justification comments on `Ordering::Relaxed`, the declared lock
//!   hierarchy, and the sync-shim routing above; interprocedural passes
//!   propagate held locks across the call graph (acquired-before cycles,
//!   blocking ops under a lock) and walk the declared hot paths rejecting
//!   heap allocation. The hot roots are marked in source with
//!   `// lint: hot-path` — today that is [`engine`]'s steady-state
//!   `decode_loop`, whose transitive call set (sweeping, shedding, refills,
//!   queue draining, slot bookkeeping) must stay allocation-free, with the
//!   backend `decode_step` implementations marked `// lint: hot-path-end`
//!   as the model-execution boundary. Tier-1 tests pin both properties on
//!   this crate's real sources (`analysis` module tests). See
//!   `docs/concurrency.md` for rule codes, waiver syntax, and the baseline
//!   ratchet workflow.
//! - **Interleaving checks** ([`model`] + `tests/serve_interleave.rs`): the
//!   queue, KV-cache, and circuit-breaker semantics are extracted into pure
//!   reference models and checked against the real types under *exhaustive*
//!   enumeration of small-thread interleavings — linearizability by
//!   construction, not by stress-test luck.
//!
//! # Fault tolerance
//!
//! Workers fail; requests shouldn't (see `docs/robustness.md` for the full
//! treatment):
//!
//! - **Scripted fault injection** ([`fault`]): a seeded, deterministic
//!   [`FaultPlan`] arms a [`FaultInjectingBackend`] wrapper around *any*
//!   backend with decode/prefill errors, KV export corruption and import
//!   errors, latency spikes, hangs, and worker panics, on one-shot,
//!   every-Nth, or seeded-probabilistic schedules. The `cola serve --mock
//!   --chaos` harness drives a whole soak off one plan and asserts zero
//!   lost requests.
//! - **Worker supervision and salvage** ([`supervisor`] + the worker loop):
//!   `serve_batch` runs under `catch_unwind`; on a panic or a persistent
//!   batch error the dead worker's in-flight rows are *salvaged* — each
//!   request folds its already-streamed tokens back in and is requeued at
//!   the front of the queue (capacity-exempt), to resume on another worker
//!   exactly where its stream paused, byte-identical for the client — up to
//!   `retry_budget` times, after which it finishes with
//!   [`FinishReason::Error`]. The pool respawns dead workers from a
//!   pool-wide `restart_budget`.
//! - **Circuit breaker** ([`supervisor::CircuitBreaker`]): consecutive
//!   worker faults walk Healthy → Degraded → Open; `ModelRouter::submit`
//!   consults it and refuses with `RouteError::CircuitOpen` instead of
//!   queueing into a known-dead pool, and after a cooldown a single
//!   half-open probe decides reopen-vs-recover.
//! - **SLO-aware shedding**: at pop time a request is shed *before* burning
//!   a prefill if its deadline already expired (`shed_expired`) or if EWMA
//!   prefill/decode rates say it cannot finish in time
//!   ([`FinishReason::Shed`], `shed_infeasible`).

pub mod engine;
pub mod fault;
pub mod kvcache;
pub mod kvcodec;
pub mod mock;
pub mod model;
pub mod queue;
pub mod router;
pub mod service;
pub mod slots;
pub mod supervisor;
pub mod sync;

pub use engine::{EngineBackend, PjrtBackend};
pub use fault::{FaultInjectingBackend, FaultKind, FaultPlan, FaultSchedule};
pub use kvcache::{InsertOutcome, KvPrefixCache, KvRowState};
pub use kvcodec::{EncodedKvRow, EncodedPlane, KvCodec, KvCodecKind, PlaneGeom};
pub use mock::MockBackend;
pub use queue::BoundedQueue;
pub use router::{ModelRouter, RouteError};
pub use service::{
    CancelHandle, Completion, FinishReason, InferenceService, Priority, QueuedRequest,
    ServicePool, ServiceStats, StreamEvent, SubmitError, SubmitOptions, Timing, TokenStream,
};
pub use slots::SlotTable;
pub use supervisor::{BreakerSnapshot, BreakerState, CircuitBreaker, Supervisor};
