//! Serving (Table 11's inference path): a production-style service API over
//! the AOT prefill/decode artifacts with device-resident KV caches.
//!
//! # Architecture
//!
//! ```text
//!  submit(prompt, SubmitOptions) ──► BoundedQueue (priority bands,
//!        │                           queue_depth cap → SubmitError::QueueFull)
//!        ▼                                │ pop between decode steps
//!   TokenStream ◄── stream events ── ServicePool workers (1..N threads)
//!   .recv()/.cancel()                     │ each: own PJRT client + params
//!   .wait() → Completion                  ▼
//!                                    SlotTable[serve_bs] — continuous
//!                                    batching: finished/cancelled/expired
//!                                    rows refill from the queue at the next
//!                                    join-prefill boundary
//! ```
//!
//! - [`InferenceService`] is the public trait: `submit` / `stats` /
//!   `shutdown`. [`ServicePool`] implements it over N single-artifact engine
//!   workers; PJRT objects are `Rc`-based and stay thread-local per worker
//!   (see `runtime::client()`).
//! - Requests carry typed [`SubmitOptions`] (token budget, stop tokens,
//!   deadline, priority) and resolve through a [`TokenStream`] that yields
//!   tokens as they decode, supports mid-flight [`TokenStream::cancel`], and
//!   ends in a typed [`Completion`] (`tokens`, [`FinishReason`], [`Timing`]).
//! - Admission is explicitly backpressured: the bounded queue refuses
//!   submits with [`SubmitError::QueueFull`] rather than hiding load in an
//!   unbounded channel.
//! - Inside a worker, a fixed `serve_bs` slot table decodes in lockstep and
//!   refills vacated rows from the queue between decode steps (see
//!   `engine` for why joins happen at prefill boundaries under the shared
//!   `pos` scalar of the decode artifact).
//!
//! The flush-and-wait `DynamicBatcher` + `Engine::spawn`/`EngineHandle`
//! design this replaces batched one static group at a time: a batch ran to
//! its longest member while finished rows decoded into the void and newly
//! arrived requests waited for the next flush.

pub mod engine;
pub mod queue;
pub mod service;
pub mod slots;

pub use queue::BoundedQueue;
pub use service::{
    CancelHandle, Completion, FinishReason, InferenceService, Priority, ServicePool,
    ServiceStats, StreamEvent, SubmitError, SubmitOptions, Timing, TokenStream,
};
pub use slots::SlotTable;
