//! Serving (Table 11's inference path): a production-style, multi-model
//! service API over pluggable engine backends.
//!
//! # Architecture
//!
//! ```text
//!  submit(model, prompt, SubmitOptions)
//!        │
//!        ▼
//!   ModelRouter ── UnknownModel? ──► RouteError (typed, no pool touched)
//!        │ dispatch by name
//!        ├─────────────┬──────────────┐
//!        ▼             ▼              ▼
//!  ServicePool     ServicePool    ServicePool      one pool per artifact,
//!  "full_130m"    "sltrain_130m"  "cola_130m"      each with its own:
//!        │
//!        ├── BoundedQueue (priority bands, queue_depth cap
//!        │                 → SubmitError::QueueFull, per-model backpressure)
//!        │        │ pop between decode steps
//!        ▼        ▼
//!   TokenStream ◄── stream events ── engine workers (1..N threads)
//!   .recv()/.cancel()                     │
//!   .wait() → Completion             SlotTable[bs] — continuous batching:
//!                                    vacated rows refill from the queue at
//!                                    the next join-prefill boundary
//!                                         │ prefill / decode_step
//!                                         ▼
//!                                    EngineBackend (trait)
//!                                    ├─ PjrtBackend: AOT artifacts on the
//!                                    │  PJRT CPU client (thread-local Rc)
//!                                    └─ MockBackend: deterministic scripted
//!                                       streams — hermetic tests, no
//!                                       artifact on disk
//! ```
//!
//! - [`ModelRouter`] owns several named [`ServicePool`]s (the Table 11
//!   full/SLTrain/CoLA variants served from one process), dispatches by
//!   model name with a typed [`RouteError`], aggregates per-model and
//!   fleet-wide [`ServiceStats`], and drains models individually.
//! - [`InferenceService`] is the single-pool trait: `submit` / `stats` /
//!   `shutdown`. [`ServicePool`] implements it over N engine workers
//!   sharing one bounded admission queue.
//! - [`EngineBackend`](engine::EngineBackend) is the seam between
//!   scheduling and model execution: the worker loop (admission, join
//!   prefills, lockstep decode, vacate/refill) is backend-agnostic, so the
//!   whole serving tier — router, slots, queue, streaming, cancellation,
//!   deadlines — tests hermetically on [`MockBackend`] under
//!   `cargo test -q`.
//! - Requests carry typed [`SubmitOptions`] (token budget, stop tokens,
//!   deadline, priority) and resolve through a [`TokenStream`] that yields
//!   tokens as they decode, supports mid-flight [`TokenStream::cancel`], and
//!   ends in a typed [`Completion`] (`tokens`, [`FinishReason`], [`Timing`]).
//! - Admission is explicitly backpressured per model: a bounded queue
//!   refuses submits with [`SubmitError::QueueFull`] rather than hiding
//!   load in an unbounded channel.

pub mod engine;
pub mod mock;
pub mod queue;
pub mod router;
pub mod service;
pub mod slots;

pub use engine::{EngineBackend, PjrtBackend};
pub use mock::MockBackend;
pub use queue::BoundedQueue;
pub use router::{ModelRouter, RouteError};
pub use service::{
    CancelHandle, Completion, FinishReason, InferenceService, Priority, QueuedRequest,
    ServicePool, ServiceStats, StreamEvent, SubmitError, SubmitOptions, Timing, TokenStream,
};
pub use slots::SlotTable;
