//! The engine worker: slot-based continuous batching behind the
//! [`EngineBackend`] trait.
//!
//! The worker loop is pure scheduling — admission, join prefills, lockstep
//! decode, vacate/refill — and talks to the model through [`EngineBackend`],
//! which owns everything stateful about *how* a batch is encoded and
//! decoded. Two implementations exist:
//!
//! - [`PjrtBackend`]: the AOT prefill/decode artifacts on the PJRT CPU
//!   client. Each worker owns its client, compiled executables,
//!   device-resident params and KV caches (PJRT wrappers are `Rc`-based, so
//!   nothing XLA leaves this thread).
//! - [`MockBackend`](crate::serve::mock::MockBackend): a deterministic,
//!   artifact-free backend so the entire scheduling surface (router, slot
//!   table, queue, streaming, cancellation, deadlines) runs hermetically
//!   under `cargo test -q`.
//!
//! The loop:
//!
//! 1. park on the admission queue while the slot table is idle;
//! 2. top up free slots from the queue (expired/cancelled/zero-budget
//!    requests resolve immediately without burning a slot);
//! 3. **join prefill**: re-encode the merged batch — every occupied row's
//!    right-aligned context window — in one `[batch, prompt_len]` call,
//!    producing fresh KV state and one next-token per row. The decode step
//!    shares a single `pos` scalar across the batch, so rows can only join
//!    at a prefill boundary; re-encoding restarts positions at 0, which
//!    RoPE's shift-equivariance makes attention-equivalent for the tokens
//!    inside the window. Context older than the most recent `prompt_len`
//!    tokens is dropped at a join — sliding-window semantics, so a row's
//!    continuation can depend on whether neighbours joined mid-flight
//!    (ROADMAP lists prefix caching / per-row positions as the fix);
//! 4. decode in lockstep, streaming each row's token as it lands, vacating
//!    rows that finish/cancel/expire — and break back to (3) when an
//!    admission into a vacated slot actually lands, or when the KV window
//!    is exhausted (`pos == max_len`, a sliding-window rollover that lets
//!    generations run past the backend's static window).
//!
//! Rows that sit empty while the queue is dry still decode junk (the shapes
//! are static), but unlike the retired flush-and-wait batcher they are
//! refilled the instant work arrives instead of after the whole batch
//! drains.

use crate::data::tokenizer;
use crate::metrics;
use crate::runtime::executor::{buf_i32_vec, lit_i32, to_device};
use crate::runtime::{ArtifactDir, Executor};
use crate::serve::service::{FinishReason, QueuedRequest, Shared};
use crate::serve::slots::{self, SlotTable};
use anyhow::{Context, Result};
use std::rc::Rc;
use std::sync::atomic::Ordering;
use std::time::Instant;

// ---------------------------------------------------------------------------
// Backend trait
// ---------------------------------------------------------------------------

/// What the scheduling loop needs from a model: static batch geometry plus
/// the two batched ops (join prefill, lockstep decode step).
///
/// Implementations are constructed *inside* the worker thread (see
/// `ServicePool::start_with`), so they may hold thread-local, non-`Send`
/// state — the PJRT backend does exactly that.
pub trait EngineBackend {
    /// Rows decoded in lockstep (the artifact's `serve_bs`).
    fn batch_size(&self) -> usize;

    /// Join-prefill window length: how many trailing context tokens each row
    /// re-encodes when the merged batch is rebuilt.
    fn prompt_len(&self) -> usize;

    /// Static KV window: decode positions available after one prefill. When
    /// `pos` reaches this, the worker re-prefills (sliding-window rollover).
    fn max_len(&self) -> usize;

    /// Human-readable identity for worker-up log lines.
    fn describe(&self) -> String;

    /// Re-encode the merged batch: `tokens` is `[batch_size * prompt_len]`
    /// row-major (each row right-aligned, pad-filled). Rebuilds the KV state
    /// and returns one next-token per row.
    fn prefill(&mut self, tokens: &[i32]) -> Result<Vec<i32>>;

    /// One lockstep decode step at position `pos`: `feed` is one token per
    /// row (pad for free rows, whose output is ignored). Returns one
    /// next-token per row and advances the KV state.
    fn decode_step(&mut self, feed: &[i32], pos: usize) -> Result<Vec<i32>>;
}

// ---------------------------------------------------------------------------
// PJRT artifact backend
// ---------------------------------------------------------------------------

/// [`EngineBackend`] over the AOT prefill/decode artifacts. Owns the
/// compiled executables, device-resident params, and the KV cache buffers
/// that thread from one call to the next. All PJRT objects are `Rc`-based
/// and stay on the constructing thread.
pub struct PjrtBackend {
    prefill: Rc<Executor>,
    decode: Rc<Executor>,
    /// Model params only (the first `n_params` of state0); optimizer state
    /// is not needed to serve.
    params: Vec<xla::PjRtBuffer>,
    /// `(kc, vc)` produced by the last prefill/decode call.
    kv: Option<(xla::PjRtBuffer, xla::PjRtBuffer)>,
    batch: usize,
    prompt_len: usize,
    max_len: usize,
    name: String,
}

impl PjrtBackend {
    /// Open an artifact built with `--serve` and compile its step functions.
    pub fn open(artifact: &str) -> Result<Self> {
        let art = ArtifactDir::open_named(artifact)?;
        let man = art.manifest.clone();
        let batch = man.serve_batch.context("artifact not built with --serve")?;
        let prompt_len = man.prompt_len.unwrap_or(8);
        let max_len = man.max_len.unwrap_or(man.preset.seq_len);
        let prefill = art.step("prefill")?;
        let decode = art.step("decode_step")?;
        // params stay on device for the backend's lifetime
        let mut params = art.load_state0_buffers()?;
        params.truncate(man.n_params);
        Ok(Self {
            prefill,
            decode,
            params,
            kv: None,
            batch,
            prompt_len,
            max_len,
            name: man.name,
        })
    }
}

impl EngineBackend for PjrtBackend {
    fn batch_size(&self) -> usize {
        self.batch
    }

    fn prompt_len(&self) -> usize {
        self.prompt_len
    }

    fn max_len(&self) -> usize {
        self.max_len
    }

    fn describe(&self) -> String {
        format!(
            "pjrt:{} bs={} prompt_len={} max_len={}",
            self.name, self.batch, self.prompt_len, self.max_len
        )
    }

    fn prefill(&mut self, tokens: &[i32]) -> Result<Vec<i32>> {
        let tok_buf =
            to_device(&lit_i32(tokens, &[self.batch as i64, self.prompt_len as i64])?)?;
        let mut refs: Vec<&xla::PjRtBuffer> = self.params.iter().collect();
        refs.push(&tok_buf);
        let mut out = self.prefill.run_b(&refs)?;
        anyhow::ensure!(out.len() == 3, "prefill returns (next, kc, vc)");
        let vcb = out.pop().unwrap();
        let kcb = out.pop().unwrap();
        self.kv = Some((kcb, vcb));
        buf_i32_vec(&out[0])
    }

    fn decode_step(&mut self, feed: &[i32], pos: usize) -> Result<Vec<i32>> {
        // Take the KV pair; a failed step leaves `kv` empty, and the worker
        // always re-prefills after a batch failure, which restores it.
        let (kcb, vcb) = self.kv.take().context("decode_step before prefill")?;
        let tok_b = to_device(&lit_i32(feed, &[self.batch as i64])?)?;
        let pos_b = to_device(&xla::Literal::scalar(pos as i32))?;
        let mut refs: Vec<&xla::PjRtBuffer> = self.params.iter().collect();
        refs.push(&kcb);
        refs.push(&vcb);
        refs.push(&tok_b);
        refs.push(&pos_b);
        let mut out = self.decode.run_b(&refs)?;
        anyhow::ensure!(out.len() == 3, "decode returns (next, kc, vc)");
        let vcb2 = out.pop().unwrap();
        let kcb2 = out.pop().unwrap();
        self.kv = Some((kcb2, vcb2));
        buf_i32_vec(&out[0])
    }
}

// ---------------------------------------------------------------------------
// Scheduling loop (backend-agnostic)
// ---------------------------------------------------------------------------

/// Body of one `cola-serve-N` thread (spawned by `ServicePool::start_with`).
pub(crate) fn run_worker(backend: &mut dyn EngineBackend, shared: &Shared) -> Result<()> {
    let mut table = SlotTable::new(backend.batch_size());
    let mut gauge = 0usize; // this worker's contribution to stats.active
    metrics::log_info(&format!("serve worker up: {}", backend.describe()));

    loop {
        // Park while idle; `None` = queue closed and drained → exit.
        if table.active() == 0 {
            sync_gauge(shared, &mut gauge, 0);
            match shared.queue.pop_blocking() {
                Some(req) => {
                    admit_one(&mut table, shared, req);
                }
                None => break,
            }
        }
        // Top up the remaining free slots without blocking.
        while table.free() > 0 {
            match shared.queue.try_pop() {
                Some(req) => {
                    admit_one(&mut table, shared, req);
                }
                None => break,
            }
        }
        if table.active() == 0 {
            continue; // everything popped had already expired/cancelled
        }
        sync_gauge(shared, &mut gauge, table.active());

        if let Err(e) = decode_rounds(shared, backend, &mut table, &mut gauge) {
            let n = table.fail_all(Instant::now());
            shared.counters.failed.fetch_add(n as u64, Ordering::Relaxed);
            sync_gauge(shared, &mut gauge, 0);
            metrics::log_info(&format!("serve batch failed ({n} requests): {e:#}"));
        }
    }
    sync_gauge(shared, &mut gauge, 0);
    Ok(())
}

/// Pop-side resolution: requests that should never occupy a slot complete
/// immediately; the rest are admitted (the caller guarantees a free slot).
/// Returns whether a slot was actually occupied.
fn admit_one(table: &mut SlotTable, shared: &Shared, req: QueuedRequest) -> bool {
    let now = Instant::now();
    if req.cancel.load(Ordering::Relaxed) {
        slots::complete_unstarted(req, FinishReason::Cancelled, now);
        shared.counters.cancelled.fetch_add(1, Ordering::Relaxed);
    } else if req.deadline.is_some_and(|d| now >= d) {
        slots::complete_unstarted(req, FinishReason::DeadlineExpired, now);
        shared.counters.expired.fetch_add(1, Ordering::Relaxed);
    } else if req.max_new_tokens == 0 {
        // zero generation budget: complete empty instead of emitting the
        // prefill token
        slots::complete_unstarted(req, FinishReason::Length, now);
        shared.counters.completed.fetch_add(1, Ordering::Relaxed);
    } else if table.admit(req, now).is_none() {
        debug_assert!(false, "admit_one called with a full slot table");
    } else {
        return true;
    }
    false
}

/// Resolve cancelled/expired requests still sitting in the admission queue,
/// freeing their capacity instead of letting dead entries block submits (and
/// hang their clients) until a slot frees up to pop them.
fn shed_dead_queued(shared: &Shared, now: Instant) {
    let dead = shared
        .queue
        .drain_where(|r| r.cancel.load(Ordering::Relaxed) || r.deadline.is_some_and(|d| now >= d));
    for req in dead {
        if req.cancel.load(Ordering::Relaxed) {
            slots::complete_unstarted(req, FinishReason::Cancelled, now);
            shared.counters.cancelled.fetch_add(1, Ordering::Relaxed);
        } else {
            slots::complete_unstarted(req, FinishReason::DeadlineExpired, now);
            shared.counters.expired.fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// One join-prefill plus the lockstep decode rounds that follow it. Returns
/// when the table drained, a refill opportunity appeared, or the KV window
/// rolled over — the caller re-enters after topping up slots.
fn decode_rounds(
    shared: &Shared,
    backend: &mut dyn EngineBackend,
    table: &mut SlotTable,
    gauge: &mut usize,
) -> Result<()> {
    let (serve_bs, prompt_len, max_len) =
        (backend.batch_size(), backend.prompt_len(), backend.max_len());

    // --- join prefill over the merged batch ---------------------------------
    let mut toks = Vec::with_capacity(serve_bs * prompt_len);
    for i in 0..serve_bs {
        toks.extend(table.window(i, prompt_len, tokenizer::PAD));
    }
    let mut next = backend.prefill(&toks)?;
    let rows = next.len();
    anyhow::ensure!(rows == serve_bs, "prefill returned {rows} rows, want {serve_bs}");

    let mut now = Instant::now();
    for i in table.occupied() {
        if let Some(reason) = table.push_token(i, next[i], now) {
            tally_finish(shared, reason);
        }
    }
    sync_gauge(shared, gauge, table.active());

    // --- lockstep decode ----------------------------------------------------
    let mut pos = prompt_len;
    let mut step = 0usize;
    loop {
        now = Instant::now();
        let (cancelled, expired) = table.sweep(now);
        shared.counters.cancelled.fetch_add(cancelled as u64, Ordering::Relaxed);
        shared.counters.expired.fetch_add(expired as u64, Ordering::Relaxed);
        // Periodically shed cancelled/expired entries still queued, so dead
        // work frees admission capacity without waiting for a pop. Throttled:
        // an O(queue) scan under the shared lock is not for every step.
        if step % 16 == 0 {
            shed_dead_queued(shared, now);
        }
        step += 1;
        if table.active() == 0 {
            sync_gauge(shared, gauge, 0);
            return Ok(()); // drained → caller parks or admits
        }
        // Refill vacated slots eagerly — but only pay the join prefill when
        // an admission actually lands (a dead queued request, or another
        // worker winning the race for it, must not cost us a prefill).
        if table.free() > 0 {
            let mut admitted = false;
            while table.free() > 0 {
                match shared.queue.try_pop() {
                    Some(req) => admitted |= admit_one(table, shared, req),
                    None => break,
                }
            }
            if admitted {
                sync_gauge(shared, gauge, table.active());
                return Ok(()); // caller re-enters via join prefill
            }
        }
        sync_gauge(shared, gauge, table.active());
        if pos >= max_len {
            return Ok(()); // KV window exhausted → sliding-window rollover
        }

        let feed = table.feed_tokens(tokenizer::PAD);
        let t_step = Instant::now();
        next = backend.decode_step(&feed, pos)?;
        let rows = next.len();
        anyhow::ensure!(rows == serve_bs, "decode returned {rows} rows, want {serve_bs}");
        pos += 1;

        let occupied = table.occupied();
        shared
            .counters
            .decoded_tokens
            .fetch_add(occupied.len() as u64, Ordering::Relaxed);
        shared
            .counters
            .decode_nanos
            .fetch_add(t_step.elapsed().as_nanos() as u64, Ordering::Relaxed);
        now = Instant::now();
        for i in occupied {
            if let Some(reason) = table.push_token(i, next[i], now) {
                tally_finish(shared, reason);
            }
        }
    }
}

fn tally_finish(shared: &Shared, reason: FinishReason) {
    match reason {
        FinishReason::Length | FinishReason::Stop => {
            shared.counters.completed.fetch_add(1, Ordering::Relaxed);
        }
        // cancellations/expiries are tallied where they are detected
        _ => {}
    }
}

/// Publish this worker's slot occupancy into the pool-wide `active` gauge.
fn sync_gauge(shared: &Shared, prev: &mut usize, cur: usize) {
    use std::cmp::Ordering::*;
    match cur.cmp(prev) {
        Greater => shared.counters.active.fetch_add(cur - *prev, Ordering::Relaxed),
        Less => shared.counters.active.fetch_sub(*prev - cur, Ordering::Relaxed),
        Equal => cur,
    };
    *prev = cur;
}
