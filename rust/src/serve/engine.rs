//! The serving engine thread: prefill + greedy decode over batched requests.
//!
//! Geometry comes from the artifact's manifest (`serve_batch`, `prompt_len`,
//! `max_len`); prompts are right-padded/truncated to `prompt_len` and
//! batches are padded with dummy rows so every PJRT call sees the static
//! shapes the artifact was lowered for (dummy rows decode into the void).

use crate::config::ServeConfig;
use crate::data::tokenizer;
use crate::metrics;
use crate::runtime::executor::{buf_i32_vec, lit_i32, to_device};
use crate::runtime::ArtifactDir;
use crate::serve::DynamicBatcher;
use anyhow::{Context, Result};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::time::{Duration, Instant};

/// One generation request.
pub struct Request {
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    pub resp: Sender<Response>,
}

/// Completion for one request.
#[derive(Clone, Debug)]
pub struct Response {
    pub tokens: Vec<i32>,
    /// end-to-end latency including queueing
    pub latency: Duration,
    /// decode throughput of the batch that served this request
    pub batch_tokens_per_sec: f64,
}

/// Cloneable submit-side handle.
#[derive(Clone)]
pub struct EngineHandle {
    tx: Sender<Request>,
}

impl EngineHandle {
    /// Submit a prompt; returns a receiver for the completion.
    pub fn submit(&self, prompt: Vec<i32>, max_new: usize) -> Receiver<Response> {
        let (tx, rx) = channel();
        let _ = self.tx.send(Request { prompt, max_new_tokens: max_new, resp: tx });
        rx
    }

    /// Blocking convenience call.
    pub fn generate(&self, prompt: Vec<i32>, max_new: usize) -> Result<Response> {
        self.submit(prompt, max_new)
            .recv()
            .context("engine thread dropped the request")
    }
}

/// Engine configuration + spawn.
pub struct Engine;

impl Engine {
    /// Spawn the engine thread. Returns (handle, join guard).
    pub fn spawn(cfg: ServeConfig) -> Result<(EngineHandle, std::thread::JoinHandle<()>)> {
        let (tx, rx) = channel::<Request>();
        let artifact = cfg.artifact.clone();
        // Fail fast on a missing artifact before spawning.
        ArtifactDir::open_named(&artifact)?;
        let join = std::thread::Builder::new()
            .name("cola-serve-engine".into())
            .spawn(move || {
                if let Err(e) = Self::engine_main(&cfg, rx) {
                    metrics::log_info(&format!("engine exited with error: {e:#}"));
                }
            })?;
        Ok((EngineHandle { tx }, join))
    }

    fn engine_main(cfg: &ServeConfig, rx: Receiver<Request>) -> Result<()> {
        let art = ArtifactDir::open_named(&cfg.artifact)?;
        let man = art.manifest.clone();
        let (serve_bs, prompt_len, max_len) = (
            man.serve_batch.context("artifact not built with --serve")?,
            man.prompt_len.unwrap_or(8),
            man.max_len.unwrap_or(man.preset.seq_len),
        );
        let prefill = art.step("prefill")?;
        let decode = art.step("decode_step")?;
        // params stay on device for the engine's lifetime
        let params = art.load_state0_buffers()?;
        let params = &params[..man.n_params];

        let batcher = DynamicBatcher::new(serve_bs, Duration::from_millis(cfg.max_wait_ms));
        metrics::log_info(&format!(
            "serve engine up: {} bs={} prompt_len={} max_len={}",
            man.name, serve_bs, prompt_len, max_len
        ));

        while let Some(batch) = batcher.collect(&rx) {
            let t0 = Instant::now();
            let starts: Vec<Instant> = batch.iter().map(|_| t0).collect();
            if let Err(e) = Self::serve_batch(
                &man, prefill.as_ref(), decode.as_ref(), params, &batch, serve_bs,
                prompt_len, max_len, &starts,
            ) {
                metrics::log_info(&format!("batch failed: {e:#}"));
            }
        }
        Ok(())
    }

    #[allow(clippy::too_many_arguments)]
    fn serve_batch(
        man: &crate::runtime::Manifest,
        prefill: &crate::runtime::Executor,
        decode: &crate::runtime::Executor,
        params: &[xla::PjRtBuffer],
        batch: &[Request],
        serve_bs: usize,
        prompt_len: usize,
        max_len: usize,
        starts: &[Instant],
    ) -> Result<()> {
        // assemble fixed-shape prompt tensor [serve_bs, prompt_len]
        let mut toks = vec![tokenizer::PAD; serve_bs * prompt_len];
        for (i, req) in batch.iter().enumerate() {
            let p = &req.prompt;
            let take = p.len().min(prompt_len);
            // right-align so the last prompt token is at prompt_len-1
            let dst = &mut toks[i * prompt_len..(i + 1) * prompt_len];
            dst[prompt_len - take..].copy_from_slice(&p[p.len() - take..]);
        }
        let tok_buf = to_device(&lit_i32(&toks, &[serve_bs as i64, prompt_len as i64])?)?;

        let mut refs: Vec<&xla::PjRtBuffer> = params.iter().collect();
        refs.push(&tok_buf);
        let mut out = prefill.run_b(&refs)?;
        anyhow::ensure!(out.len() == 3, "prefill returns (next, kc, vc)");
        let mut vcb = out.pop().unwrap();
        let mut kcb = out.pop().unwrap();
        let mut next = buf_i32_vec(&out[0])?;

        let max_new = batch
            .iter()
            .map(|r| r.max_new_tokens)
            .max()
            .unwrap_or(1)
            .min(max_len - prompt_len);

        let mut generated: Vec<Vec<i32>> = vec![Vec::new(); batch.len()];
        for (i, g) in generated.iter_mut().enumerate() {
            g.push(next[i]);
        }

        let t_decode = Instant::now();
        let mut decoded_tokens = 0usize;
        for s in 0..max_new.saturating_sub(1) {
            let pos = (prompt_len + s) as i32;
            let tok_b = to_device(&lit_i32(&next, &[serve_bs as i64])?)?;
            let pos_b = to_device(&xla::Literal::scalar(pos))?;
            let mut refs: Vec<&xla::PjRtBuffer> = params.iter().collect();
            refs.push(&kcb);
            refs.push(&vcb);
            refs.push(&tok_b);
            refs.push(&pos_b);
            let mut out = decode.run_b(&refs)?;
            anyhow::ensure!(out.len() == 3, "decode returns (next, kc, vc)");
            vcb = out.pop().unwrap();
            kcb = out.pop().unwrap();
            next = buf_i32_vec(&out[0])?;
            for (i, g) in generated.iter_mut().enumerate() {
                if g.len() < batch[i].max_new_tokens {
                    g.push(next[i]);
                }
            }
            decoded_tokens += serve_bs;
        }
        let tps = (decoded_tokens + serve_bs) as f64 / t_decode.elapsed().as_secs_f64().max(1e-9);

        for (i, req) in batch.iter().enumerate() {
            let _ = req.resp.send(Response {
                tokens: generated[i].clone(),
                latency: starts[i].elapsed(),
                batch_tokens_per_sec: tps,
            });
        }
        let _ = man;
        Ok(())
    }
}
