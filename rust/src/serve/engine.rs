//! The engine worker: slot-based continuous batching behind the
//! [`EngineBackend`] trait.
//!
//! The worker loop is pure scheduling — admission, join prefills, lockstep
//! decode, vacate/refill — and talks to the model through [`EngineBackend`],
//! which owns everything stateful about *how* a batch is encoded and
//! decoded. Two implementations exist:
//!
//! - [`PjrtBackend`]: the AOT prefill/decode artifacts on the PJRT CPU
//!   client. Each worker owns its client, compiled executables,
//!   device-resident params and KV caches (PJRT wrappers are `Rc`-based, so
//!   nothing XLA leaves this thread).
//! - [`MockBackend`](crate::serve::mock::MockBackend): a deterministic,
//!   artifact-free backend so the entire scheduling surface (router, slot
//!   table, queue, streaming, cancellation, deadlines) runs hermetically
//!   under `cargo test -q`.
//!
//! The loop:
//!
//! 1. park on the admission queue while the slot table is idle;
//! 2. top up free slots from the queue — **chunked admission**: at most
//!    `join_chunk` Normal-priority rows join per prefill boundary, while
//!    High-priority rows are popped first and are never chunk-limited, so
//!    one burst of new requests can neither stall every in-flight decode
//!    nor saturate the table before urgent work lands (expired/cancelled/
//!    zero-budget requests resolve immediately without burning a slot);
//! 3. **join prefill**: re-encode the merged batch — every occupied row's
//!    right-aligned context window — in one `[batch, prompt_len]` call,
//!    producing fresh KV state and one next-token per row. The decode step
//!    shares a single `pos` scalar across the batch, so rows can only join
//!    at a prefill boundary; re-encoding restarts positions at 0, which
//!    RoPE's shift-equivariance makes attention-equivalent for the tokens
//!    inside the window. **Prefill avoidance**: a row's post-prefill KV
//!    slice is a pure function of its window (rows never attend across the
//!    batch), so each worker keeps a host-side
//!    [`KvPrefixCache`](crate::serve::kvcache::KvPrefixCache) of per-row KV
//!    snapshots keyed by window hash. When *every* occupied row hits —
//!    repeated prefixes (system prompts, retries), or rows whose window is
//!    unchanged since the prefill that inserted it — the join prefill is
//!    elided entirely: rows are restored through
//!    [`EngineBackend::import_kv_rows`] instead of re-encoded. Real
//!    prefills are timed (`prefill_nanos`) and export their missing rows
//!    into the cache via [`EngineBackend::export_kv_rows`];
//! 4. decode in lockstep, streaming each row's token as it lands, vacating
//!    rows that finish/cancel/expire — and break back to (3) when an
//!    admission into a vacated slot actually lands, or when the KV window
//!    is exhausted (`pos == max_len`, a sliding-window rollover that lets
//!    generations run past the backend's static window). Deterministic
//!    decoding makes even rollover windows repeat across retries of the
//!    same prompt, so rollover prefills of repeated traffic hit the cache
//!    too.
//!
//! Rows that sit empty while the queue is dry still decode junk (the shapes
//! are static), but unlike the retired flush-and-wait batcher they are
//! refilled the instant work arrives instead of after the whole batch
//! drains.

use crate::data::tokenizer;
use crate::metrics;
use crate::runtime::executor::{buf_f32_vec, buf_i32_vec, lit_f32_vec, lit_i32, to_device};
use crate::runtime::{ArtifactDir, Executor};
use crate::serve::kvcache::{KvPrefixCache, KvRowState};
use crate::serve::kvcodec::{KvCodec, PlaneGeom};
use crate::serve::service::{FinishReason, QueuedRequest, Shared};
use crate::serve::slots::{self, SlotTable};
use anyhow::{Context, Result};
use std::rc::Rc;
use std::time::Instant;

// ---------------------------------------------------------------------------
// Backend trait
// ---------------------------------------------------------------------------

/// What the scheduling loop needs from a model: static batch geometry plus
/// the two batched ops (join prefill, lockstep decode step), and — for
/// prefill avoidance — per-row KV state transfer between device and host.
///
/// Implementations are constructed *inside* the worker thread (see
/// `ServicePool::start_with`), so they may hold thread-local, non-`Send`
/// state — the PJRT backend does exactly that.
pub trait EngineBackend {
    /// Rows decoded in lockstep (the artifact's `serve_bs`).
    fn batch_size(&self) -> usize;

    /// Join-prefill window length: how many trailing context tokens each row
    /// re-encodes when the merged batch is rebuilt.
    fn prompt_len(&self) -> usize;

    /// Static KV window: decode positions available after one prefill. When
    /// `pos` reaches this, the worker re-prefills (sliding-window rollover).
    fn max_len(&self) -> usize;

    /// Human-readable identity for worker-up log lines.
    fn describe(&self) -> String;

    /// Re-encode the merged batch: `tokens` is `[batch_size * prompt_len]`
    /// row-major (each row right-aligned, pad-filled). Rebuilds the KV state
    /// and returns one next-token per row.
    fn prefill(&mut self, tokens: &[i32]) -> Result<Vec<i32>>;

    /// One lockstep decode step at position `pos`: `feed` is one token per
    /// row (pad for free rows, whose output is ignored). Returns one
    /// next-token per row and advances the KV state.
    fn decode_step(&mut self, feed: &[i32], pos: usize) -> Result<Vec<i32>>;

    /// f32 elements per plane (`k` or `v`) of one row's KV snapshot, or 0
    /// when the backend cannot export/import KV rows — the engine then
    /// disables the prefix cache instead of failing at the first boundary.
    fn kv_row_elems(&self) -> usize {
        0
    }

    /// Matrix structure of one KV plane, as stacked per-layer `rows × cols`
    /// matrices, for codecs that factorize (the rank-r codec clamps its
    /// rank to `min(rows, cols)`, so an honest geometry is what makes
    /// low-rank compression effective). The default flat shape is safe but
    /// degenerate — backends that support KV export should override it.
    fn kv_row_geom(&self) -> PlaneGeom {
        PlaneGeom::flat(self.kv_row_elems())
    }

    /// Snapshot the post-prefill KV state of the given rows to the host
    /// (one [`KvRowState`] per requested row, in order). Only called after
    /// a successful [`prefill`](Self::prefill) and only when
    /// [`kv_row_elems`](Self::kv_row_elems) is non-zero.
    fn export_kv_rows(&mut self, _rows: &[usize]) -> Result<Vec<KvRowState>> {
        anyhow::bail!("backend `{}` does not support KV row export", self.describe())
    }

    /// Replace the batch KV state from per-row host snapshots (`None` =
    /// free row, which gets a zero slice — its decode output is junk the
    /// scheduler ignores). `rows.len() == batch_size()`. After this call
    /// the backend must behave exactly as if a prefill of the snapshotted
    /// windows had just run.
    fn import_kv_rows(&mut self, _rows: &[Option<&KvRowState>]) -> Result<()> {
        anyhow::bail!("backend `{}` does not support KV row import", self.describe())
    }
}

// ---------------------------------------------------------------------------
// PJRT artifact backend
// ---------------------------------------------------------------------------

/// [`EngineBackend`] over the AOT prefill/decode artifacts. Owns the
/// compiled executables, device-resident params, and the KV cache buffers
/// that thread from one call to the next. All PJRT objects are `Rc`-based
/// and stay on the constructing thread.
pub struct PjrtBackend {
    prefill: Rc<Executor>,
    decode: Rc<Executor>,
    /// Model params only (the first `n_params` of state0); optimizer state
    /// is not needed to serve.
    params: Vec<xla::PjRtBuffer>,
    /// `(kc, vc)` produced by the last prefill/decode call.
    kv: Option<(xla::PjRtBuffer, xla::PjRtBuffer)>,
    /// Reusable argument scratch: params + per-call inputs as raw pointers,
    /// so the hot loop stops re-collecting a `Vec` of borrows every step
    /// (see `Executor::run_b_ptr`).
    scratch: Vec<*const xla::PjRtBuffer>,
    batch: usize,
    prompt_len: usize,
    max_len: usize,
    /// KV cache geometry `[n_layers, batch, max_len, n_heads, head_dim]` —
    /// the per-row export/import slicing below depends on this layout
    /// (aot.py lowers the cache exactly so).
    n_layers: usize,
    n_heads: usize,
    head_dim: usize,
    name: String,
}

impl PjrtBackend {
    /// Open an artifact built with `--serve` and compile its step functions.
    pub fn open(artifact: &str) -> Result<Self> {
        let art = ArtifactDir::open_named(artifact)?;
        let man = art.manifest.clone();
        let batch = man.serve_batch.context("artifact not built with --serve")?;
        let prompt_len = man.prompt_len.unwrap_or(8);
        let max_len = man.max_len.unwrap_or(man.preset.seq_len);
        let prefill = art.step("prefill")?;
        let decode = art.step("decode_step")?;
        // params stay on device for the backend's lifetime
        let mut params = art.load_state0_buffers()?;
        params.truncate(man.n_params);
        let scratch = Vec::with_capacity(params.len() + 4);
        anyhow::ensure!(
            man.preset.n_heads > 0 && man.preset.d % man.preset.n_heads == 0,
            "preset head geometry (d={}, n_heads={})",
            man.preset.d,
            man.preset.n_heads
        );
        Ok(Self {
            prefill,
            decode,
            params,
            kv: None,
            scratch,
            batch,
            prompt_len,
            max_len,
            n_layers: man.preset.n_layers,
            n_heads: man.preset.n_heads,
            head_dim: man.preset.d / man.preset.n_heads,
            name: man.name,
        })
    }

    /// f32 elements of one row within one layer (`max_len * n_heads *
    /// head_dim`), the contiguous unit the `[L, B, T, H, hd]` layout stores
    /// per `(layer, row)`.
    fn layer_row_elems(&self) -> usize {
        self.max_len * self.n_heads * self.head_dim
    }

    fn kv_dims(&self) -> [i64; 5] {
        [
            self.n_layers as i64,
            self.batch as i64,
            self.max_len as i64,
            self.n_heads as i64,
            self.head_dim as i64,
        ]
    }

    /// Rebuild `self.scratch` as params ++ `extra` and run `exe` over it.
    fn run_step(
        &mut self,
        exe: &Rc<Executor>,
        extra: &[&xla::PjRtBuffer],
    ) -> Result<Vec<xla::PjRtBuffer>> {
        self.scratch.clear();
        self.scratch.extend(self.params.iter().map(|p| p as *const xla::PjRtBuffer));
        for b in extra {
            self.scratch.push(*b);
        }
        // SAFETY: every pointer in `scratch` was just derived from a live
        // reference (`self.params` and `extra`) that outlives this call.
        unsafe { exe.run_b_ptr(&self.scratch) }
    }
}

impl EngineBackend for PjrtBackend {
    fn batch_size(&self) -> usize {
        self.batch
    }

    fn prompt_len(&self) -> usize {
        self.prompt_len
    }

    fn max_len(&self) -> usize {
        self.max_len
    }

    fn describe(&self) -> String {
        format!(
            "pjrt:{} bs={} prompt_len={} max_len={}",
            self.name, self.batch, self.prompt_len, self.max_len
        )
    }

    fn prefill(&mut self, tokens: &[i32]) -> Result<Vec<i32>> {
        let tok_buf =
            to_device(&lit_i32(tokens, &[self.batch as i64, self.prompt_len as i64])?)?;
        let exe = self.prefill.clone();
        let mut out = self.run_step(&exe, &[&tok_buf])?;
        anyhow::ensure!(out.len() == 3, "prefill returns (next, kc, vc)");
        let vcb = out.pop().context("prefill output vc")?;
        let kcb = out.pop().context("prefill output kc")?;
        self.kv = Some((kcb, vcb));
        buf_i32_vec(&out[0])
    }

    // lint: hot-path-end — the backend step is the model-execution cost the
    // benchmark measures; its device transfers are not scheduler overhead.
    fn decode_step(&mut self, feed: &[i32], pos: usize) -> Result<Vec<i32>> {
        // Take the KV pair; a failed step leaves `kv` empty, and the worker
        // always re-prefills after a batch failure, which restores it.
        let (kcb, vcb) = self.kv.take().context("decode_step before prefill")?;
        let tok_b = to_device(&lit_i32(feed, &[self.batch as i64])?)?;
        let pos_b = to_device(&xla::Literal::scalar(pos as i32))?;
        let exe = self.decode.clone();
        let mut out = self.run_step(&exe, &[&kcb, &vcb, &tok_b, &pos_b])?;
        anyhow::ensure!(out.len() == 3, "decode returns (next, kc, vc)");
        let vcb2 = out.pop().context("decode output vc")?;
        let kcb2 = out.pop().context("decode output kc")?;
        self.kv = Some((kcb2, vcb2));
        buf_i32_vec(&out[0])
    }

    fn kv_row_elems(&self) -> usize {
        self.n_layers * self.layer_row_elems()
    }

    fn kv_row_geom(&self) -> PlaneGeom {
        // per layer, a row's plane is [max_len, n_heads * head_dim] — the
        // contiguous slice export_kv_rows gathers per (layer, row)
        PlaneGeom {
            layers: self.n_layers,
            rows: self.max_len,
            cols: self.n_heads * self.head_dim,
        }
    }

    fn export_kv_rows(&mut self, rows: &[usize]) -> Result<Vec<KvRowState>> {
        let (kcb, vcb) = self.kv.as_ref().context("export_kv_rows before prefill")?;
        // one host transfer for the whole batch, then per-row gather — the
        // [L, B, T, H, hd] layout scatters a row across layers
        let k_host = buf_f32_vec(kcb)?;
        let v_host = buf_f32_vec(vcb)?;
        let lr = self.layer_row_elems();
        let row_elems = self.kv_row_elems();
        let mut out = Vec::with_capacity(rows.len());
        for &r in rows {
            anyhow::ensure!(r < self.batch, "export row {r} out of range (batch {})", self.batch);
            let mut k = Vec::with_capacity(row_elems);
            let mut v = Vec::with_capacity(row_elems);
            for l in 0..self.n_layers {
                let off = (l * self.batch + r) * lr;
                k.extend_from_slice(&k_host[off..off + lr]);
                v.extend_from_slice(&v_host[off..off + lr]);
            }
            out.push(KvRowState { k, v });
        }
        Ok(out)
    }

    fn import_kv_rows(&mut self, rows: &[Option<&KvRowState>]) -> Result<()> {
        anyhow::ensure!(
            rows.len() == self.batch,
            "import_kv_rows wants one entry per row ({} != {})",
            rows.len(),
            self.batch
        );
        let lr = self.layer_row_elems();
        let row_elems = self.kv_row_elems();
        let full = self.n_layers * self.batch * lr;
        // free rows stay zero — the same state a fresh prefill gives padding
        let mut k_host = vec![0f32; full];
        let mut v_host = vec![0f32; full];
        for (r, state) in rows.iter().enumerate() {
            let Some(s) = state else { continue };
            anyhow::ensure!(
                s.k.len() == row_elems && s.v.len() == row_elems,
                "KV row snapshot has {} elems, backend wants {row_elems}",
                s.k.len()
            );
            for l in 0..self.n_layers {
                let dst = (l * self.batch + r) * lr;
                let src = l * lr;
                k_host[dst..dst + lr].copy_from_slice(&s.k[src..src + lr]);
                v_host[dst..dst + lr].copy_from_slice(&s.v[src..src + lr]);
            }
        }
        let dims = self.kv_dims();
        self.kv = Some((
            to_device(&lit_f32_vec(&k_host, &dims)?)?,
            to_device(&lit_f32_vec(&v_host, &dims)?)?,
        ));
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Scheduling loop (backend-agnostic)
// ---------------------------------------------------------------------------

/// Worker-loop knobs carried from `ServeConfig` into each engine thread.
pub(crate) struct EngineOptions {
    /// KV prefix-cache capacity in rows; 0 disables prefill avoidance.
    pub(crate) kv_cache_entries: usize,
    /// KV prefix-cache byte budget over encoded payloads; 0 = unlimited.
    pub(crate) kv_cache_bytes: usize,
    /// Codec the cache stores entries under (`ServeConfig::kv_codec` joined
    /// with `kv_rank`).
    pub(crate) kv_codec: KvCodec,
    /// Normal-priority admissions per join boundary; 0 = unlimited.
    pub(crate) join_chunk: usize,
}

/// Per-worker scratch and cache state that persists across decode rounds.
struct WorkerState {
    /// Host-side KV prefix cache (`None` when disabled by config or an
    /// export-incapable backend).
    cache: Option<KvPrefixCache>,
    join_chunk: usize,
    /// Merged `[batch * prompt_len]` prefill input, rebuilt in place.
    toks: Vec<i32>,
    /// Occupied-row snapshot reused every decode step.
    occ: Vec<usize>,
    /// Per-row decode feed reused every decode step.
    feed: Vec<i32>,
    /// `(row, probe result)` per occupied row at the current boundary.
    probes: Vec<(usize, Option<usize>)>,
    /// Per-slot decode scratch for elided prefills: cache entries are
    /// stored encoded, so each hit is decoded here before import. Reused
    /// across boundaries — decode is codec work, not per-call allocation.
    decoded: Vec<KvRowState>,
    /// Last published value of the `kv_bytes_resident` gauge, so cache
    /// byte-occupancy changes sync as deltas (same pattern as the `active`
    /// gauge in `sync_gauge`).
    kv_bytes: usize,
    /// Scratch for dead-queued sheds, reused so the decode loop's periodic
    /// sweep stays allocation-free when nothing matches.
    dead: Vec<QueuedRequest>,
}

/// Body of one `cola-serve-N` thread (spawned by `ServicePool::start_with`).
pub(crate) fn run_worker(
    backend: &mut dyn EngineBackend,
    shared: &Shared,
    opts: &EngineOptions,
) -> Result<()> {
    let mut table = SlotTable::new(backend.batch_size());
    let mut gauge = 0usize; // this worker's contribution to stats.active
    let cache_rows = if backend.kv_row_elems() > 0 { opts.kv_cache_entries } else { 0 };
    let mut st = WorkerState {
        cache: (cache_rows > 0).then(|| {
            KvPrefixCache::with_codec(
                cache_rows,
                opts.kv_cache_bytes as u64,
                opts.kv_codec,
                backend.kv_row_geom(),
            )
        }),
        join_chunk: opts.join_chunk,
        toks: vec![tokenizer::PAD; backend.batch_size() * backend.prompt_len()],
        occ: Vec::with_capacity(backend.batch_size()),
        feed: Vec::with_capacity(backend.batch_size()),
        probes: Vec::with_capacity(backend.batch_size()),
        decoded: vec![KvRowState::default(); backend.batch_size()],
        kv_bytes: 0,
        dead: Vec::with_capacity(8),
    };
    metrics::log_info(&format!(
        "serve worker up: {} kv_cache={} kv_bytes={} kv_codec={:?} join_chunk={}",
        backend.describe(),
        cache_rows,
        opts.kv_cache_bytes,
        opts.kv_codec,
        if st.join_chunk == 0 { "off".into() } else { st.join_chunk.to_string() }
    ));

    loop {
        // Park while idle; `None` = queue closed and drained → exit.
        if table.active() == 0 {
            sync_gauge(shared, &mut gauge, 0);
            match shared.queue.pop_blocking() {
                Some(req) => {
                    admit_one(&mut table, shared, req);
                }
                None => break,
            }
        }
        // Top up free slots without blocking (chunk-capped for Normal; the
        // waking request above is admitted regardless).
        refill_slots(&mut table, shared, st.join_chunk);
        if table.active() == 0 {
            continue; // everything popped had already expired/cancelled
        }
        sync_gauge(shared, &mut gauge, table.active());

        if let Err(e) = decode_rounds(shared, backend, &mut table, &mut gauge, &mut st) {
            let n = table.fail_all(Instant::now());
            shared.counters.failed.add(n as u64);
            sync_gauge(shared, &mut gauge, 0);
            metrics::log_info(&format!("serve batch failed ({n} requests): {e:#}"));
        }
    }
    sync_gauge(shared, &mut gauge, 0);
    // this worker's cache dies with it — retire its resident-bytes share
    if st.kv_bytes > 0 {
        shared.counters.kv_bytes_resident.sub(st.kv_bytes);
    }
    Ok(())
}

/// Pop-side resolution: requests that should never occupy a slot complete
/// immediately; the rest are admitted (the caller guarantees a free slot).
/// Returns whether a slot was actually occupied.
fn admit_one(table: &mut SlotTable, shared: &Shared, req: QueuedRequest) -> bool {
    let now = Instant::now();
    if req.cancel.poll() {
        slots::complete_unstarted(req, FinishReason::Cancelled, now);
        shared.counters.cancelled.add(1);
    } else if req.deadline.is_some_and(|d| now >= d) {
        slots::complete_unstarted(req, FinishReason::DeadlineExpired, now);
        shared.counters.expired.add(1);
    } else if req.max_new_tokens == 0 {
        // zero generation budget: complete empty instead of emitting the
        // prefill token
        slots::complete_unstarted(req, FinishReason::Length, now);
        shared.counters.completed.add(1);
    } else if table.admit(req, now).is_none() {
        debug_assert!(false, "admit_one called with a full slot table");
    } else {
        return true;
    }
    false
}

/// Chunked, priority-aware top-up of free slots: High-priority requests are
/// popped first and never chunk-limited; at most `join_chunk` Normal rows
/// are admitted per call (0 = unlimited). Returns whether any admission
/// actually landed (dead queued requests resolve without costing a slot or
/// a prefill).
fn refill_slots(table: &mut SlotTable, shared: &Shared, join_chunk: usize) -> bool {
    let mut admitted = false;
    let mut normal_left = if join_chunk == 0 { usize::MAX } else { join_chunk };
    while table.free() > 0 {
        if let Some(req) = shared.queue.try_pop_high() {
            admitted |= admit_one(table, shared, req);
            continue;
        }
        if normal_left == 0 {
            break;
        }
        match shared.queue.try_pop() {
            Some(req) => {
                if admit_one(table, shared, req) {
                    normal_left -= 1;
                    admitted = true;
                }
            }
            None => break,
        }
    }
    admitted
}

/// Resolve cancelled/expired requests still sitting in the admission queue,
/// freeing their capacity instead of letting dead entries block submits (and
/// hang their clients) until a slot frees up to pop them. `scratch` is a
/// caller-owned buffer (the worker keeps one) so the common nothing-matched
/// sweep runs without touching the heap.
fn shed_dead_queued(shared: &Shared, now: Instant, scratch: &mut Vec<QueuedRequest>) {
    scratch.clear();
    shared
        .queue
        .drain_where_into(|r| r.cancel.poll() || r.deadline.is_some_and(|d| now >= d), scratch);
    for req in scratch.drain(..) {
        if req.cancel.poll() {
            slots::complete_unstarted(req, FinishReason::Cancelled, now);
            shared.counters.cancelled.add(1);
        } else {
            slots::complete_unstarted(req, FinishReason::DeadlineExpired, now);
            shared.counters.expired.add(1);
        }
    }
}

/// The join boundary: restore every occupied row from the KV prefix cache
/// when possible (an **elided** prefill), otherwise run the real prefill —
/// timed — and export the rows the cache was missing. Expects `st.occ` and
/// `st.toks` to be current. Returns one next-token per row.
fn join_prefill(
    shared: &Shared,
    backend: &mut dyn EngineBackend,
    table: &mut SlotTable,
    st: &mut WorkerState,
    serve_bs: usize,
    prompt_len: usize,
) -> Result<Vec<i32>> {
    let c = &shared.counters;
    let WorkerState { cache, toks, occ, probes, decoded, kv_bytes, .. } = st;

    if let Some(cache) = cache.as_mut() {
        probes.clear();
        let mut misses = 0u64;
        for &i in occ.iter() {
            let h = table.window_hash(i, prompt_len, tokenizer::PAD);
            let p = cache.probe(h, &toks[i * prompt_len..(i + 1) * prompt_len]);
            misses += u64::from(p.is_none());
            probes.push((i, p));
        }
        c.kv_cache_hits.add(occ.len() as u64 - misses);
        c.kv_cache_misses.add(misses);
        if misses == 0 && !occ.is_empty() {
            // Every window is known: skip the forward pass, decode the
            // encoded snapshots into per-slot scratch (timed — this is the
            // codec's cost on the elision path), rebuild the batch KV from
            // them, and replay the cached next tokens (free rows get zero
            // KV; their output is junk anyway).
            let t0 = Instant::now();
            let mut next = vec![tokenizer::PAD; serve_bs];
            for &(i, p) in probes.iter() {
                // `misses == 0` makes every probe `Some`; a `None` here
                // would mean serving a zero KV row, so bail to the real
                // prefill path below instead of trusting it.
                let Some(idx) = p else { anyhow::bail!("probe/miss accounting diverged") };
                cache.decode_into(idx, &mut decoded[i]);
                next[i] = cache.peek(idx).1;
            }
            c.kv_decode_nanos.add(t0.elapsed().as_nanos() as u64);
            let mut rows: Vec<Option<&KvRowState>> = vec![None; serve_bs];
            for &(i, p) in probes.iter() {
                if p.is_some() {
                    rows[i] = Some(&decoded[i]);
                }
            }
            backend.import_kv_rows(&rows)?;
            c.prefills_elided.add(1);
            return Ok(next);
        }
    }

    let t0 = Instant::now();
    let next = backend.prefill(toks)?;
    c.prefill_calls.add(1);
    c.prefill_nanos.add(t0.elapsed().as_nanos() as u64);
    anyhow::ensure!(
        next.len() == serve_bs,
        "prefill returned {} rows, want {serve_bs}",
        next.len()
    );

    if let Some(cache) = cache.as_mut() {
        // export only the rows the probe missed — hit rows are already
        // resident (and were LRU-touched by the probe)
        let miss_rows: Vec<usize> =
            probes.iter().filter(|(_, p)| p.is_none()).map(|&(i, _)| i).collect();
        if !miss_rows.is_empty() {
            let states = backend.export_kv_rows(&miss_rows)?;
            anyhow::ensure!(
                states.len() == miss_rows.len(),
                "export returned {} rows, want {}",
                states.len(),
                miss_rows.len()
            );
            let mut evicted = 0u64;
            let mut bytes_saved = 0u64;
            for (&i, kv) in miss_rows.iter().zip(states) {
                let h = table.window_hash(i, prompt_len, tokenizer::PAD);
                let window = toks[i * prompt_len..(i + 1) * prompt_len].to_vec();
                let out = cache.insert(h, window, &kv, next[i])?;
                evicted += out.evicted;
                bytes_saved += out.bytes_saved;
            }
            c.kv_cache_evictions.add(evicted);
            c.kv_bytes_saved.add(bytes_saved);
            // Gauge tracks the *resident* encoded bytes across all workers;
            // sync it by delta against this worker's last observation so
            // evictions (including budget-driven ones) are reflected too.
            let cur = cache.bytes_resident() as usize;
            if cur > *kv_bytes {
                c.kv_bytes_resident.add(cur - *kv_bytes);
            } else {
                c.kv_bytes_resident.sub(*kv_bytes - cur);
            }
            *kv_bytes = cur;
        }
    }
    Ok(next)
}

/// One join-prefill plus the lockstep decode rounds that follow it. Returns
/// when the table drained, a refill opportunity appeared, or the KV window
/// rolled over — the caller re-enters after topping up slots.
fn decode_rounds(
    shared: &Shared,
    backend: &mut dyn EngineBackend,
    table: &mut SlotTable,
    gauge: &mut usize,
    st: &mut WorkerState,
) -> Result<()> {
    let (serve_bs, prompt_len, max_len) =
        (backend.batch_size(), backend.prompt_len(), backend.max_len());

    // --- join prefill over the merged batch (elided when fully cached) ------
    table.occupied_into(&mut st.occ);
    for i in 0..serve_bs {
        let row = &mut st.toks[i * prompt_len..(i + 1) * prompt_len];
        table.write_window(i, tokenizer::PAD, row);
    }
    let next = join_prefill(shared, backend, table, st, serve_bs, prompt_len)?;

    let now = Instant::now();
    for &i in &st.occ {
        if let Some(reason) = table.push_token(i, next[i], now) {
            tally_finish(shared, reason);
        }
    }
    sync_gauge(shared, gauge, table.active());

    decode_loop(shared, backend, table, gauge, st, serve_bs, max_len, prompt_len)
}

/// The steady-state lockstep decode loop — the tightest loop in serving.
/// Declared as the allocation lint's hot root: everything reachable from
/// here (sweeping, queue shedding, refills, slot bookkeeping) must stay off
/// the heap, reusing the scratch buffers in [`WorkerState`]. The backend
/// `decode_step` implementations are the boundary (`lint: hot-path-end`) —
/// their internals are model-execution cost, not scheduler overhead.
/// Returns when the table drains, a refill lands, or the KV window rolls
/// over; the caller re-enters through the join prefill.
#[allow(clippy::too_many_arguments)]
// lint: hot-path
fn decode_loop(
    shared: &Shared,
    backend: &mut dyn EngineBackend,
    table: &mut SlotTable,
    gauge: &mut usize,
    st: &mut WorkerState,
    serve_bs: usize,
    max_len: usize,
    mut pos: usize,
) -> Result<()> {
    let mut step = 0usize;
    loop {
        let mut now = Instant::now();
        let (cancelled, expired) = table.sweep(now);
        shared.counters.cancelled.add(cancelled as u64);
        shared.counters.expired.add(expired as u64);
        // Periodically shed cancelled/expired entries still queued, so dead
        // work frees admission capacity without waiting for a pop. Throttled:
        // an O(queue) scan under the shared lock is not for every step.
        if step % 16 == 0 {
            shed_dead_queued(shared, now, &mut st.dead);
        }
        step += 1;
        if table.active() == 0 {
            sync_gauge(shared, gauge, 0);
            return Ok(()); // drained → caller parks or admits
        }
        // Refill vacated slots eagerly — but only pay the join prefill when
        // an admission actually lands (a dead queued request, or another
        // worker winning the race for it, must not cost us a prefill).
        if table.free() > 0 && refill_slots(table, shared, st.join_chunk) {
            sync_gauge(shared, gauge, table.active());
            return Ok(()); // caller re-enters via join prefill
        }
        sync_gauge(shared, gauge, table.active());
        if pos >= max_len {
            return Ok(()); // KV window exhausted → sliding-window rollover
        }

        table.feed_tokens_into(tokenizer::PAD, &mut st.feed);
        let t_step = Instant::now();
        let next = backend.decode_step(&st.feed, pos)?;
        let rows = next.len();
        anyhow::ensure!(rows == serve_bs, "decode returned {rows} rows, want {serve_bs}");
        pos += 1;

        table.occupied_into(&mut st.occ);
        shared
            .counters
            .decoded_tokens
            .add(st.occ.len() as u64);
        shared
            .counters
            .decode_nanos
            .add(t_step.elapsed().as_nanos() as u64);
        now = Instant::now();
        for &i in &st.occ {
            if let Some(reason) = table.push_token(i, next[i], now) {
                tally_finish(shared, reason);
            }
        }
    }
}

fn tally_finish(shared: &Shared, reason: FinishReason) {
    match reason {
        FinishReason::Length | FinishReason::Stop => {
            shared.counters.completed.add(1);
        }
        // cancellations/expiries are tallied where they are detected
        _ => {}
    }
}

/// Publish this worker's slot occupancy into the pool-wide `active` gauge.
fn sync_gauge(shared: &Shared, prev: &mut usize, cur: usize) {
    if cur > *prev {
        shared.counters.active.add(cur - *prev);
    } else if cur < *prev {
        shared.counters.active.sub(*prev - cur);
    }
    *prev = cur;
}
