//! The engine worker: slot-based continuous batching over the AOT
//! prefill/decode artifacts.
//!
//! Each worker owns its PJRT client, compiled executables, device-resident
//! params and KV caches (PJRT wrappers are `Rc`-based, so nothing XLA leaves
//! this thread). The loop:
//!
//! 1. park on the admission queue while the slot table is idle;
//! 2. top up free slots from the queue (expired/cancelled/zero-budget
//!    requests resolve immediately without burning a slot);
//! 3. **join prefill**: re-encode the merged batch — every occupied row's
//!    right-aligned context window — in one `[serve_bs, prompt_len]` call,
//!    producing fresh KV caches and one next-token per row. The decode
//!    artifact shares a single `pos` scalar across the batch, so rows can
//!    only join at a prefill boundary; re-encoding restarts positions at 0,
//!    which RoPE's shift-equivariance makes attention-equivalent for the
//!    tokens inside the window. Context older than the most recent
//!    `prompt_len` tokens is dropped at a join — sliding-window semantics,
//!    so a row's continuation can depend on whether neighbours joined
//!    mid-flight (ROADMAP lists prefix caching / per-row positions as the
//!    fix);
//! 4. decode in lockstep, streaming each row's token as it lands, vacating
//!    rows that finish/cancel/expire — and break back to (3) when an
//!    admission into a vacated slot actually lands, or when the KV window
//!    is exhausted (`pos == max_len`, a sliding-window rollover that lets
//!    generations run past the artifact's static window).
//!
//! Rows that sit empty while the queue is dry still decode junk (the shapes
//! are static), but unlike the retired flush-and-wait batcher they are
//! refilled the instant work arrives instead of after the whole batch
//! drains.

use crate::config::ServeConfig;
use crate::data::tokenizer;
use crate::metrics;
use crate::runtime::executor::{buf_i32_vec, lit_i32, to_device};
use crate::runtime::{ArtifactDir, Executor};
use crate::serve::service::{FinishReason, QueuedRequest, Shared};
use crate::serve::slots::{self, SlotTable};
use anyhow::{Context, Result};
use std::sync::atomic::Ordering;
use std::time::Instant;

/// Body of one `cola-serve-N` thread (spawned by `ServicePool::start`).
pub(crate) fn worker_main(cfg: &ServeConfig, shared: &Shared) -> Result<()> {
    let art = ArtifactDir::open_named(&cfg.artifact)?;
    let man = art.manifest.clone();
    let serve_bs = man.serve_batch.context("artifact not built with --serve")?;
    let prompt_len = man.prompt_len.unwrap_or(8);
    let max_len = man.max_len.unwrap_or(man.preset.seq_len);
    let prefill = art.step("prefill")?;
    let decode = art.step("decode_step")?;
    // params stay on device for the worker's lifetime
    let params_all = art.load_state0_buffers()?;
    let params = &params_all[..man.n_params];

    let mut table = SlotTable::new(serve_bs);
    let mut gauge = 0usize; // this worker's contribution to stats.active
    metrics::log_info(&format!(
        "serve worker up: {} bs={serve_bs} prompt_len={prompt_len} max_len={max_len}",
        man.name
    ));

    loop {
        // Park while idle; `None` = queue closed and drained → exit.
        if table.active() == 0 {
            sync_gauge(shared, &mut gauge, 0);
            match shared.queue.pop_blocking() {
                Some(req) => {
                    admit_one(&mut table, shared, req);
                }
                None => break,
            }
        }
        // Top up the remaining free slots without blocking.
        while table.free() > 0 {
            match shared.queue.try_pop() {
                Some(req) => {
                    admit_one(&mut table, shared, req);
                }
                None => break,
            }
        }
        if table.active() == 0 {
            continue; // everything popped had already expired/cancelled
        }
        sync_gauge(shared, &mut gauge, table.active());

        if let Err(e) = decode_rounds(
            shared, prefill.as_ref(), decode.as_ref(), params, &mut table, &mut gauge,
            serve_bs, prompt_len, max_len,
        ) {
            let n = table.fail_all(Instant::now());
            shared.counters.failed.fetch_add(n as u64, Ordering::Relaxed);
            sync_gauge(shared, &mut gauge, 0);
            metrics::log_info(&format!("serve batch failed ({n} requests): {e:#}"));
        }
    }
    sync_gauge(shared, &mut gauge, 0);
    Ok(())
}

/// Pop-side resolution: requests that should never occupy a slot complete
/// immediately; the rest are admitted (the caller guarantees a free slot).
/// Returns whether a slot was actually occupied.
fn admit_one(table: &mut SlotTable, shared: &Shared, req: QueuedRequest) -> bool {
    let now = Instant::now();
    if req.cancel.load(Ordering::Relaxed) {
        slots::complete_unstarted(req, FinishReason::Cancelled, now);
        shared.counters.cancelled.fetch_add(1, Ordering::Relaxed);
    } else if req.deadline.is_some_and(|d| now >= d) {
        slots::complete_unstarted(req, FinishReason::DeadlineExpired, now);
        shared.counters.expired.fetch_add(1, Ordering::Relaxed);
    } else if req.max_new_tokens == 0 {
        // zero generation budget: complete empty instead of emitting the
        // prefill token
        slots::complete_unstarted(req, FinishReason::Length, now);
        shared.counters.completed.fetch_add(1, Ordering::Relaxed);
    } else if table.admit(req, now).is_none() {
        debug_assert!(false, "admit_one called with a full slot table");
    } else {
        return true;
    }
    false
}

/// Resolve cancelled/expired requests still sitting in the admission queue,
/// freeing their capacity instead of letting dead entries block submits (and
/// hang their clients) until a slot frees up to pop them.
fn shed_dead_queued(shared: &Shared, now: Instant) {
    let dead = shared
        .queue
        .drain_where(|r| r.cancel.load(Ordering::Relaxed) || r.deadline.is_some_and(|d| now >= d));
    for req in dead {
        if req.cancel.load(Ordering::Relaxed) {
            slots::complete_unstarted(req, FinishReason::Cancelled, now);
            shared.counters.cancelled.fetch_add(1, Ordering::Relaxed);
        } else {
            slots::complete_unstarted(req, FinishReason::DeadlineExpired, now);
            shared.counters.expired.fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// One join-prefill plus the lockstep decode rounds that follow it. Returns
/// when the table drained, a refill opportunity appeared, or the KV window
/// rolled over — the caller re-enters after topping up slots.
#[allow(clippy::too_many_arguments)]
fn decode_rounds(
    shared: &Shared,
    prefill: &Executor,
    decode: &Executor,
    params: &[xla::PjRtBuffer],
    table: &mut SlotTable,
    gauge: &mut usize,
    serve_bs: usize,
    prompt_len: usize,
    max_len: usize,
) -> Result<()> {
    // --- join prefill over the merged batch ---------------------------------
    let mut toks = Vec::with_capacity(serve_bs * prompt_len);
    for i in 0..serve_bs {
        toks.extend(table.window(i, prompt_len, tokenizer::PAD));
    }
    let tok_buf = to_device(&lit_i32(&toks, &[serve_bs as i64, prompt_len as i64])?)?;
    let mut refs: Vec<&xla::PjRtBuffer> = params.iter().collect();
    refs.push(&tok_buf);
    let mut out = prefill.run_b(&refs)?;
    anyhow::ensure!(out.len() == 3, "prefill returns (next, kc, vc)");
    let mut vcb = out.pop().unwrap();
    let mut kcb = out.pop().unwrap();
    let mut next = buf_i32_vec(&out[0])?;

    let mut now = Instant::now();
    for i in table.occupied() {
        if let Some(reason) = table.push_token(i, next[i], now) {
            tally_finish(shared, reason);
        }
    }
    sync_gauge(shared, gauge, table.active());

    // --- lockstep decode ----------------------------------------------------
    let mut pos = prompt_len;
    let mut step = 0usize;
    loop {
        now = Instant::now();
        let (cancelled, expired) = table.sweep(now);
        shared.counters.cancelled.fetch_add(cancelled as u64, Ordering::Relaxed);
        shared.counters.expired.fetch_add(expired as u64, Ordering::Relaxed);
        // Periodically shed cancelled/expired entries still queued, so dead
        // work frees admission capacity without waiting for a pop. Throttled:
        // an O(queue) scan under the shared lock is not for every step.
        if step % 16 == 0 {
            shed_dead_queued(shared, now);
        }
        step += 1;
        if table.active() == 0 {
            sync_gauge(shared, gauge, 0);
            return Ok(()); // drained → caller parks or admits
        }
        // Refill vacated slots eagerly — but only pay the join prefill when
        // an admission actually lands (a dead queued request, or another
        // worker winning the race for it, must not cost us a prefill).
        if table.free() > 0 {
            let mut admitted = false;
            while table.free() > 0 {
                match shared.queue.try_pop() {
                    Some(req) => admitted |= admit_one(table, shared, req),
                    None => break,
                }
            }
            if admitted {
                sync_gauge(shared, gauge, table.active());
                return Ok(()); // caller re-enters via join prefill
            }
        }
        sync_gauge(shared, gauge, table.active());
        if pos >= max_len {
            return Ok(()); // KV window exhausted → sliding-window rollover
        }

        let feed = table.feed_tokens(tokenizer::PAD);
        let tok_b = to_device(&lit_i32(&feed, &[serve_bs as i64])?)?;
        let pos_b = to_device(&xla::Literal::scalar(pos as i32))?;
        let mut refs: Vec<&xla::PjRtBuffer> = params.iter().collect();
        refs.push(&kcb);
        refs.push(&vcb);
        refs.push(&tok_b);
        refs.push(&pos_b);
        let t_step = Instant::now();
        let mut out = decode.run_b(&refs)?;
        anyhow::ensure!(out.len() == 3, "decode returns (next, kc, vc)");
        vcb = out.pop().unwrap();
        kcb = out.pop().unwrap();
        next = buf_i32_vec(&out[0])?;
        pos += 1;

        let occupied = table.occupied();
        shared
            .counters
            .decoded_tokens
            .fetch_add(occupied.len() as u64, Ordering::Relaxed);
        shared
            .counters
            .decode_nanos
            .fetch_add(t_step.elapsed().as_nanos() as u64, Ordering::Relaxed);
        now = Instant::now();
        for i in occupied {
            if let Some(reason) = table.push_token(i, next[i], now) {
                tally_finish(shared, reason);
            }
        }
    }
}

fn tally_finish(shared: &Shared, reason: FinishReason) {
    match reason {
        FinishReason::Length | FinishReason::Stop => {
            shared.counters.completed.fetch_add(1, Ordering::Relaxed);
        }
        // cancellations/expiries are tallied where they are detected
        _ => {}
    }
}

/// Publish this worker's slot occupancy into the pool-wide `active` gauge.
fn sync_gauge(shared: &Shared, prev: &mut usize, cur: usize) {
    use std::cmp::Ordering::*;
    match cur.cmp(prev) {
        Greater => shared.counters.active.fetch_add(cur - *prev, Ordering::Relaxed),
        Less => shared.counters.active.fetch_sub(*prev - cur, Ordering::Relaxed),
        Equal => cur,
    };
    *prev = cur;
}
