//! The engine worker: barrier-free continuous batching behind the
//! [`EngineBackend`] trait.
//!
//! The worker loop is pure scheduling — admission, per-row encodes,
//! lockstep decode, vacate/refill — and talks to the model through
//! [`EngineBackend`], which owns everything stateful about *how* a row is
//! encoded and a batch is decoded. Two implementations exist:
//!
//! - [`PjrtBackend`]: the AOT prefill/decode artifacts on the PJRT CPU
//!   client. Each worker owns its client, compiled executables,
//!   device-resident params and KV caches (PJRT wrappers are `Rc`-based, so
//!   nothing XLA leaves this thread).
//! - [`MockBackend`](crate::serve::mock::MockBackend): a deterministic,
//!   artifact-free backend so the entire scheduling surface (router, slot
//!   table, queue, streaming, cancellation, deadlines) runs hermetically
//!   under `cargo test -q`.
//!
//! Every row carries its **own decode position** (`pos: i32[serve_bs]` in
//! the decode artifact), so there is no join-prefill barrier: a vacated
//! slot is refilled *mid-flight* by a **single-row prefill**
//! ([`EngineBackend::prefill_row`], a row-scatter into the live batch KV)
//! — or by a cache restore ([`EngineBackend::import_kv_row`]) — while
//! every other row keeps decoding from its own position. Joining-row
//! admission latency is therefore O(1) in batch occupancy: one row encode,
//! zero re-prefills of occupied rows. KV-window rollover is a *per-row*
//! event too — the row whose `pos` hits `max_len` re-encodes its own
//! sliding window; its neighbours never notice.
//!
//! The loop:
//!
//! 1. park on the admission queue while the slot table is idle;
//! 2. top up free slots from the queue (expired/cancelled/zero-budget
//!    requests resolve immediately without burning a slot). Admissions are
//!    paced: at most `join_chunk` Normal-priority rows join per decode
//!    step, while High-priority rows are popped first and are never
//!    chunk-limited, so a burst of new requests cannot stall in-flight
//!    decodes behind a wall of back-to-back row encodes;
//! 3. **encode** each fresh or rolled-over row individually: probe the
//!    worker's host-side
//!    [`KvPrefixCache`](crate::serve::kvcache::KvPrefixCache) first — a
//!    whole-window hit restores the row without any forward pass (an
//!    **elided** prefill); a chunked **partial-prefix** hit imports the
//!    longest cached prefix and prefills only the tail (`keep` positions
//!    retained — shared system prompts across requests of different
//!    lengths); a miss runs the timed single-row prefill and exports the
//!    fresh row back into the cache;
//! 4. decode in lockstep at per-row positions, streaming each row's token
//!    as it lands, vacating rows that finish/cancel/expire (releasing
//!    their backend rows via [`EngineBackend::vacate_row`]) and breaking
//!    back to (3) whenever an admission lands or a row needs its rollover.
//!
//! Rows that sit empty while the queue is dry still decode junk (the
//! shapes are static), but they cost no encodes and are refilled the
//! instant work arrives.

use crate::data::tokenizer;
use crate::metrics;
use crate::runtime::executor::{buf_f32_vec, buf_i32_vec, lit_f32_vec, lit_i32, to_device};
use crate::runtime::{ArtifactDir, Executor};
use crate::serve::kvcache::{KvPrefixCache, KvRowState};
use crate::serve::kvcodec::{KvCodec, PlaneGeom};
use crate::serve::queue::PushError;
use crate::serve::service::{FinishReason, QueuedRequest, Shared};
use crate::serve::slots::{self, SlotTable};
use anyhow::{Context, Result};
use std::rc::Rc;
use std::time::Instant;

// ---------------------------------------------------------------------------
// Backend trait
// ---------------------------------------------------------------------------

/// What the scheduling loop needs from a model: static batch geometry, a
/// **single-row** encode that splices one row into the live batch KV, a
/// lockstep decode step at **per-row positions**, and — for prefill
/// avoidance — per-row KV state transfer between device and host.
///
/// Implementations are constructed *inside* the worker thread (see
/// `ServicePool::start_with`), so they may hold thread-local, non-`Send`
/// state — the PJRT backend does exactly that.
pub trait EngineBackend {
    /// Rows decoded in lockstep (the artifact's `serve_bs`).
    fn batch_size(&self) -> usize;

    /// Encode-window length: how many context tokens a single-row prefill
    /// encodes (the static width of `prefill_row`'s window input).
    fn prompt_len(&self) -> usize;

    /// Static KV window: decode positions available to a row after one
    /// encode. When a row's `pos` reaches this, the worker re-encodes that
    /// row (a per-row sliding-window rollover).
    fn max_len(&self) -> usize;

    /// Human-readable identity for worker-up log lines.
    fn describe(&self) -> String;

    /// Encode one row into the live batch: `window` is `[prompt_len]`
    /// left-aligned (real tokens at `0..len`, trailing pad). Rebuilds the
    /// row's KV at positions `0..len` — except positions `0..keep`, which
    /// retain the row's existing (imported) KV state, so a partial-prefix
    /// restore only pays for the tail — and returns the row's next token
    /// (decoded from position `len - 1`). Other rows' KV state must be
    /// left untouched.
    fn prefill_row(&mut self, row: usize, window: &[i32], len: usize, keep: usize) -> Result<i32>;

    /// One lockstep decode step: `feed` is one token per row (pad for free
    /// rows, whose output is ignored) and `pos` is each row's own KV write
    /// position. Returns one next-token per row and advances the KV state.
    fn decode_step(&mut self, feed: &[i32], pos: &[usize]) -> Result<Vec<i32>>;

    /// f32 elements per plane (`k` or `v`) of one row's KV snapshot, or 0
    /// when the backend cannot export/import KV rows — the engine then
    /// disables the prefix cache instead of failing at the first encode.
    fn kv_row_elems(&self) -> usize {
        0
    }

    /// Matrix structure of one KV plane, as stacked per-layer `rows × cols`
    /// matrices, for codecs that factorize (the rank-r codec clamps its
    /// rank to `min(rows, cols)`, so an honest geometry is what makes
    /// low-rank compression effective). The default flat shape is safe but
    /// degenerate — backends that support KV export should override it.
    fn kv_row_geom(&self) -> PlaneGeom {
        PlaneGeom::flat(self.kv_row_elems())
    }

    /// Snapshot one row's post-encode KV state to the host. Only called
    /// after a successful [`prefill_row`](Self::prefill_row) on that row
    /// and only when [`kv_row_elems`](Self::kv_row_elems) is non-zero.
    fn export_kv_row(&mut self, _row: usize) -> Result<KvRowState> {
        anyhow::bail!("backend `{}` does not support KV row export", self.describe())
    }

    /// Restore one row's KV state from a host snapshot whose first `len`
    /// positions are real. After this call the backend must behave exactly
    /// as if an encode of the snapshotted window had just run on that row;
    /// other rows must be left untouched.
    fn import_kv_row(&mut self, _row: usize, _kv: &KvRowState, _len: usize) -> Result<()> {
        anyhow::bail!("backend `{}` does not support KV row import", self.describe())
    }

    /// The scheduler no longer tracks this row (finished, cancelled,
    /// expired, or batch failure). Backends with per-row liveness models
    /// (the mock's position oracle) release the row here; stateless
    /// backends ignore it.
    fn vacate_row(&mut self, _row: usize) {}
}

/// Forwarding impl so wrappers generic over `B: EngineBackend` — the fault
/// injector in `serve::fault` — compose with factories that hand out boxed
/// backends without re-monomorphizing per concrete type.
impl EngineBackend for Box<dyn EngineBackend> {
    fn batch_size(&self) -> usize {
        (**self).batch_size()
    }

    fn prompt_len(&self) -> usize {
        (**self).prompt_len()
    }

    fn max_len(&self) -> usize {
        (**self).max_len()
    }

    fn describe(&self) -> String {
        (**self).describe()
    }

    fn prefill_row(&mut self, row: usize, window: &[i32], len: usize, keep: usize) -> Result<i32> {
        (**self).prefill_row(row, window, len, keep)
    }

    // lint: hot-path-end — pure dynamic dispatch into the wrapped backend,
    // which carries its own boundary marker.
    fn decode_step(&mut self, feed: &[i32], pos: &[usize]) -> Result<Vec<i32>> {
        (**self).decode_step(feed, pos)
    }

    fn kv_row_elems(&self) -> usize {
        (**self).kv_row_elems()
    }

    fn kv_row_geom(&self) -> PlaneGeom {
        (**self).kv_row_geom()
    }

    fn export_kv_row(&mut self, row: usize) -> Result<KvRowState> {
        (**self).export_kv_row(row)
    }

    fn import_kv_row(&mut self, row: usize, kv: &KvRowState, len: usize) -> Result<()> {
        (**self).import_kv_row(row, kv, len)
    }

    fn vacate_row(&mut self, row: usize) {
        (**self).vacate_row(row);
    }
}

// ---------------------------------------------------------------------------
// PJRT artifact backend
// ---------------------------------------------------------------------------

/// [`EngineBackend`] over the AOT single-row-prefill/decode artifacts.
/// Owns the compiled executables, device-resident params, and the KV cache
/// buffers that thread from one call to the next. All PJRT objects are
/// `Rc`-based and stay on the constructing thread.
pub struct PjrtBackend {
    prefill_row: Rc<Executor>,
    decode: Rc<Executor>,
    /// Model params only (the first `n_params` of state0); optimizer state
    /// is not needed to serve.
    params: Vec<xla::PjRtBuffer>,
    /// `(kc, vc)` produced by the last prefill_row/decode call.
    kv: Option<(xla::PjRtBuffer, xla::PjRtBuffer)>,
    /// Reusable argument scratch: params + per-call inputs as raw pointers,
    /// so the hot loop stops re-collecting a `Vec` of borrows every step
    /// (see `Executor::run_b_ptr`).
    scratch: Vec<*const xla::PjRtBuffer>,
    /// Reusable i32 staging for the per-row position vector.
    pos_i32: Vec<i32>,
    batch: usize,
    prompt_len: usize,
    max_len: usize,
    /// KV cache geometry `[n_layers, batch, max_len, n_heads, head_dim]` —
    /// the per-row export/import slicing below depends on this layout
    /// (aot.py lowers the cache exactly so).
    n_layers: usize,
    n_heads: usize,
    head_dim: usize,
    name: String,
}

impl PjrtBackend {
    /// Open an artifact built with `--serve` and compile its step functions.
    pub fn open(artifact: &str) -> Result<Self> {
        let art = ArtifactDir::open_named(artifact)?;
        let man = art.manifest.clone();
        let batch = man.serve_batch.context("artifact not built with --serve")?;
        let prompt_len = man.prompt_len.unwrap_or(8);
        let max_len = man.max_len.unwrap_or(man.preset.seq_len);
        let prefill_row = art.step("prefill_row").context(
            "artifact lacks the prefill_row step (pre-per-row-position build?) — \
             regenerate it with python/compile/aot.py --serve",
        )?;
        let decode = art.step("decode_step")?;
        // params stay on device for the backend's lifetime
        let mut params = art.load_state0_buffers()?;
        params.truncate(man.n_params);
        let scratch = Vec::with_capacity(params.len() + 8);
        anyhow::ensure!(
            man.preset.n_heads > 0 && man.preset.d % man.preset.n_heads == 0,
            "preset head geometry (d={}, n_heads={})",
            man.preset.d,
            man.preset.n_heads
        );
        Ok(Self {
            prefill_row,
            decode,
            params,
            kv: None,
            scratch,
            pos_i32: Vec::with_capacity(batch),
            batch,
            prompt_len,
            max_len,
            n_layers: man.preset.n_layers,
            n_heads: man.preset.n_heads,
            head_dim: man.preset.d / man.preset.n_heads,
            name: man.name,
        })
    }

    /// f32 elements of one row within one layer (`max_len * n_heads *
    /// head_dim`), the contiguous unit the `[L, B, T, H, hd]` layout stores
    /// per `(layer, row)`.
    fn layer_row_elems(&self) -> usize {
        self.max_len * self.n_heads * self.head_dim
    }

    fn kv_dims(&self) -> [i64; 5] {
        [
            self.n_layers as i64,
            self.batch as i64,
            self.max_len as i64,
            self.n_heads as i64,
            self.head_dim as i64,
        ]
    }

    /// Make sure `self.kv` holds a live buffer pair — a worker that has
    /// never encoded a row (or whose last step failed) starts from zeroed
    /// KV, the same state a fresh batch prefill used to produce.
    fn ensure_kv(&mut self) -> Result<()> {
        if self.kv.is_some() {
            return Ok(());
        }
        let full = self.n_layers * self.batch * self.layer_row_elems();
        let zeros = vec![0f32; full];
        let dims = self.kv_dims();
        self.kv = Some((
            to_device(&lit_f32_vec(&zeros, &dims)?)?,
            to_device(&lit_f32_vec(&zeros, &dims)?)?,
        ));
        Ok(())
    }

    /// Rebuild `self.scratch` as params ++ `extra` and run `exe` over it.
    fn run_step(
        &mut self,
        exe: &Rc<Executor>,
        extra: &[&xla::PjRtBuffer],
    ) -> Result<Vec<xla::PjRtBuffer>> {
        self.scratch.clear();
        self.scratch.extend(self.params.iter().map(|p| p as *const xla::PjRtBuffer));
        for b in extra {
            self.scratch.push(*b);
        }
        // SAFETY: every pointer in `scratch` was just derived from a live
        // reference (`self.params` and `extra`) that outlives this call.
        unsafe { exe.run_b_ptr(&self.scratch) }
    }
}

impl EngineBackend for PjrtBackend {
    fn batch_size(&self) -> usize {
        self.batch
    }

    fn prompt_len(&self) -> usize {
        self.prompt_len
    }

    fn max_len(&self) -> usize {
        self.max_len
    }

    fn describe(&self) -> String {
        format!(
            "pjrt:{} bs={} prompt_len={} max_len={}",
            self.name, self.batch, self.prompt_len, self.max_len
        )
    }

    fn prefill_row(&mut self, row: usize, window: &[i32], len: usize, keep: usize) -> Result<i32> {
        anyhow::ensure!(row < self.batch, "prefill_row row {row} out of range");
        anyhow::ensure!(
            window.len() == self.prompt_len,
            "prefill_row window has {} tokens, artifact wants {}",
            window.len(),
            self.prompt_len
        );
        anyhow::ensure!(
            0 < len && len <= self.prompt_len && keep <= len,
            "prefill_row wants 0 < len <= prompt_len and keep <= len (len {len}, keep {keep})"
        );
        self.ensure_kv()?;
        // Take the KV pair; a failed step leaves `kv` empty, and ensure_kv
        // rebuilds zeroed state on the next encode after a batch failure.
        let (kcb, vcb) = self.kv.take().context("prefill_row KV state")?;
        let win_b = to_device(&lit_i32(window, &[self.prompt_len as i64])?)?;
        let row_b = to_device(&xla::Literal::scalar(row as i32))?;
        let len_b = to_device(&xla::Literal::scalar(len as i32))?;
        let keep_b = to_device(&xla::Literal::scalar(keep as i32))?;
        let exe = self.prefill_row.clone();
        let mut out = self.run_step(&exe, &[&kcb, &vcb, &win_b, &row_b, &len_b, &keep_b])?;
        anyhow::ensure!(out.len() == 3, "prefill_row returns (next, kc, vc)");
        let vcb2 = out.pop().context("prefill_row output vc")?;
        let kcb2 = out.pop().context("prefill_row output kc")?;
        self.kv = Some((kcb2, vcb2));
        let next = buf_i32_vec(&out[0])?;
        next.first().copied().context("prefill_row returned an empty next token")
    }

    // lint: hot-path-end — the backend step is the model-execution cost the
    // benchmark measures; its device transfers are not scheduler overhead.
    fn decode_step(&mut self, feed: &[i32], pos: &[usize]) -> Result<Vec<i32>> {
        anyhow::ensure!(pos.len() == self.batch, "decode pos is one position per row");
        // Take the KV pair; a failed step leaves `kv` empty, and the worker
        // always re-encodes after a batch failure, which restores it.
        let (kcb, vcb) = self.kv.take().context("decode_step before any encode")?;
        let tok_b = to_device(&lit_i32(feed, &[self.batch as i64])?)?;
        self.pos_i32.clear();
        self.pos_i32.extend(pos.iter().map(|&p| p as i32));
        let pos_b = to_device(&lit_i32(&self.pos_i32, &[self.batch as i64])?)?;
        let exe = self.decode.clone();
        let mut out = self.run_step(&exe, &[&kcb, &vcb, &tok_b, &pos_b])?;
        anyhow::ensure!(out.len() == 3, "decode returns (next, kc, vc)");
        let vcb2 = out.pop().context("decode output vc")?;
        let kcb2 = out.pop().context("decode output kc")?;
        self.kv = Some((kcb2, vcb2));
        buf_i32_vec(&out[0])
    }

    fn kv_row_elems(&self) -> usize {
        self.n_layers * self.layer_row_elems()
    }

    fn kv_row_geom(&self) -> PlaneGeom {
        // per layer, a row's plane is [max_len, n_heads * head_dim] — the
        // contiguous slice export_kv_row gathers per (layer, row)
        PlaneGeom {
            layers: self.n_layers,
            rows: self.max_len,
            cols: self.n_heads * self.head_dim,
        }
    }

    fn export_kv_row(&mut self, row: usize) -> Result<KvRowState> {
        anyhow::ensure!(row < self.batch, "export row {row} out of range (batch {})", self.batch);
        let (kcb, vcb) = self.kv.as_ref().context("export_kv_row before any encode")?;
        // one host transfer, then a per-layer gather — the [L, B, T, H, hd]
        // layout scatters a row across layers
        let k_host = buf_f32_vec(kcb)?;
        let v_host = buf_f32_vec(vcb)?;
        let lr = self.layer_row_elems();
        let row_elems = self.kv_row_elems();
        let mut k = Vec::with_capacity(row_elems);
        let mut v = Vec::with_capacity(row_elems);
        for l in 0..self.n_layers {
            let off = (l * self.batch + row) * lr;
            k.extend_from_slice(&k_host[off..off + lr]);
            v.extend_from_slice(&v_host[off..off + lr]);
        }
        Ok(KvRowState { k, v })
    }

    fn import_kv_row(&mut self, row: usize, kv: &KvRowState, _len: usize) -> Result<()> {
        anyhow::ensure!(row < self.batch, "import row {row} out of range (batch {})", self.batch);
        let lr = self.layer_row_elems();
        let row_elems = self.kv_row_elems();
        anyhow::ensure!(
            kv.k.len() == row_elems && kv.v.len() == row_elems,
            "KV row snapshot has {} elems, backend wants {row_elems}",
            kv.k.len()
        );
        self.ensure_kv()?;
        // read-modify-write: splice the row into the live planes without
        // touching any other row's state (the whole point of a mid-flight
        // join), then re-upload
        let (kcb, vcb) = self.kv.take().context("import_kv_row KV state")?;
        let mut k_host = buf_f32_vec(&kcb)?;
        let mut v_host = buf_f32_vec(&vcb)?;
        for l in 0..self.n_layers {
            let dst = (l * self.batch + row) * lr;
            let src = l * lr;
            k_host[dst..dst + lr].copy_from_slice(&kv.k[src..src + lr]);
            v_host[dst..dst + lr].copy_from_slice(&kv.v[src..src + lr]);
        }
        let dims = self.kv_dims();
        self.kv = Some((
            to_device(&lit_f32_vec(&k_host, &dims)?)?,
            to_device(&lit_f32_vec(&v_host, &dims)?)?,
        ));
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Scheduling loop (backend-agnostic)
// ---------------------------------------------------------------------------

/// Worker-loop knobs carried from `ServeConfig` into each engine thread.
pub(crate) struct EngineOptions {
    /// KV prefix-cache capacity in rows; 0 disables prefill avoidance.
    pub(crate) kv_cache_entries: usize,
    /// KV prefix-cache byte budget over encoded payloads; 0 = unlimited.
    pub(crate) kv_cache_bytes: usize,
    /// Codec the cache stores entries under (`ServeConfig::kv_codec` joined
    /// with `kv_rank`).
    pub(crate) kv_codec: KvCodec,
    /// Normal-priority admissions per decode step; 0 = unlimited.
    pub(crate) join_chunk: usize,
    /// How many times an in-flight request may be salvaged and redispatched
    /// after worker faults before it resolves as `Error { retries }`.
    pub(crate) retry_budget: u32,
}

/// Consecutive `serve_batch` failures after which a worker stops trusting
/// its backend and dies (the supervision loop in `ServicePool::start_with`
/// then respawns it with a *fresh* backend, restart budget permitting).
/// Transient single-step faults never hit this; a wedged backend does.
const FATAL_CONSEC_FAILURES: u32 = 3;

/// Why the hot decode loop handed control back to [`serve_batch`].
enum LoopEvent {
    /// No occupied rows remain — the caller parks on the queue.
    Drained,
    /// A fresh admission or a per-row rollover needs an encode (heap work
    /// the hot loop refuses to do itself).
    Encode,
}

/// Per-worker scratch and cache state that persists across decode rounds.
struct WorkerState {
    /// Host-side KV prefix cache (`None` when disabled by config or an
    /// export-incapable backend).
    cache: Option<KvPrefixCache>,
    join_chunk: usize,
    /// Single-row `[prompt_len]` window scratch, rebuilt per encode.
    window: Vec<i32>,
    /// Occupied-row snapshot reused every decode step.
    occ: Vec<usize>,
    /// Per-row decode feed reused every decode step.
    feed: Vec<i32>,
    /// Per-row decode positions reused every decode step.
    pos: Vec<usize>,
    /// Rows vacated by the last sweep, whose backend state must be
    /// released. Reused across steps.
    vacated: Vec<usize>,
    /// Decode scratch for cache-restored rows: entries are stored encoded,
    /// so each hit is decoded here before import. Reused across encodes —
    /// decode is codec work, not per-call allocation.
    decoded: KvRowState,
    /// Last published value of the `kv_bytes_resident` gauge, so cache
    /// byte-occupancy changes sync as deltas (same pattern as the `active`
    /// gauge in `sync_gauge`).
    kv_bytes: usize,
    /// Scratch for dead-queued sheds, reused so the decode loop's periodic
    /// sweep stays allocation-free when nothing matches.
    dead: Vec<QueuedRequest>,
}

/// Body of one `cola-serve-N` thread (spawned by `ServicePool::start_with`).
pub(crate) fn run_worker(
    backend: &mut dyn EngineBackend,
    shared: &Shared,
    opts: &EngineOptions,
) -> Result<()> {
    let mut table = SlotTable::new(backend.batch_size());
    let mut gauge = 0usize; // this worker's contribution to stats.active
    let cache_rows = if backend.kv_row_elems() > 0 { opts.kv_cache_entries } else { 0 };
    // Prefix-chain granularity: half the window is coarse enough to keep
    // per-entry key counts tiny yet catches the dominant real-traffic case
    // (a shared system prompt filling the front of the window).
    let chunk = (backend.prompt_len() / 2).max(1);
    let mut st = WorkerState {
        cache: (cache_rows > 0).then(|| {
            KvPrefixCache::with_codec(
                cache_rows,
                opts.kv_cache_bytes as u64,
                opts.kv_codec,
                backend.kv_row_geom(),
            )
            .with_chunk(chunk)
        }),
        join_chunk: opts.join_chunk,
        window: vec![tokenizer::PAD; backend.prompt_len()],
        occ: Vec::with_capacity(backend.batch_size()),
        feed: Vec::with_capacity(backend.batch_size()),
        pos: Vec::with_capacity(backend.batch_size()),
        vacated: Vec::with_capacity(backend.batch_size()),
        decoded: KvRowState::default(),
        kv_bytes: 0,
        dead: Vec::with_capacity(8),
    };
    metrics::log_info(&format!(
        "serve worker up: {} kv_cache={} kv_bytes={} kv_codec={:?} prefix_chunk={} join_chunk={}",
        backend.describe(),
        cache_rows,
        opts.kv_cache_bytes,
        opts.kv_codec,
        chunk,
        if st.join_chunk == 0 { "off".into() } else { st.join_chunk.to_string() }
    ));

    let mut consec_failures = 0u32;
    let mut exit_err: Option<anyhow::Error> = None;
    loop {
        // Park while idle; `None` = queue closed and drained → exit.
        if table.active() == 0 {
            sync_gauge(shared, &mut gauge, 0);
            match shared.queue.pop_blocking() {
                Some(req) => {
                    admit_one(&mut table, shared, req);
                }
                None => break,
            }
        }
        // Top up free slots without blocking (chunk-capped for Normal; the
        // waking request above is admitted regardless).
        refill_slots(&mut table, shared, st.join_chunk);
        if table.active() == 0 {
            continue; // everything popped had already expired/cancelled
        }
        sync_gauge(shared, &mut gauge, table.active());

        // `catch_unwind` turns a panicking backend (or a scheduler bug)
        // into a supervised worker death instead of a silently shrunken
        // fleet; on every failure path the in-flight batch is *salvaged*
        // back into the queue rather than failed wholesale.
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            serve_batch(shared, backend, &mut table, &mut gauge, &mut st)
        }));
        match outcome {
            Ok(Ok(())) => {
                consec_failures = 0; // the batch drained cleanly
            }
            Ok(Err(e)) => {
                consec_failures += 1;
                let n = salvage_batch(backend, &mut table, shared, &mut st, opts.retry_budget);
                sync_gauge(shared, &mut gauge, 0);
                shared.supervisor.breaker.record_failure();
                metrics::log_info(&format!(
                    "serve batch failed ({n} requests salvaged, \
                     consecutive failure {consec_failures}): {e:#}"
                ));
                if consec_failures >= FATAL_CONSEC_FAILURES {
                    exit_err = Some(e.context(format!(
                        "{consec_failures} consecutive batch failures; \
                         worker gives up its backend"
                    )));
                    break;
                }
            }
            Err(payload) => {
                shared.counters.worker_panics.add(1);
                let n = salvage_batch(backend, &mut table, shared, &mut st, opts.retry_budget);
                sync_gauge(shared, &mut gauge, 0);
                let msg = payload
                    .downcast_ref::<&str>()
                    .map(|s| (*s).to_string())
                    .or_else(|| payload.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "non-string panic payload".to_string());
                exit_err =
                    Some(anyhow::anyhow!("worker panicked mid-batch ({n} salvaged): {msg}"));
                break;
            }
        }
    }
    sync_gauge(shared, &mut gauge, 0);
    // this worker's cache dies with it — retire its resident-bytes share
    if st.kv_bytes > 0 {
        shared.counters.kv_bytes_resident.sub(st.kv_bytes);
    }
    match exit_err {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

/// Pull every in-flight request out of a faulted batch and put it back in
/// the queue. Requests within their retry budget are requeued at the front
/// of the high band (`BoundedQueue::requeue` — capacity-exempt, so a fault
/// cannot turn into load shedding); the rest resolve with
/// `Error { retries }` carrying their partial tokens. Returns how many rows
/// were salvaged off the table.
fn salvage_batch(
    backend: &mut dyn EngineBackend,
    table: &mut SlotTable,
    shared: &Shared,
    st: &mut WorkerState,
    retry_budget: u32,
) -> usize {
    // release every backend row first, so the backend's liveness model
    // matches the now-empty table (harmless on a dead backend — the
    // supervisor hands the respawned worker a fresh one)
    table.occupied_into(&mut st.occ);
    for &i in &st.occ {
        backend.vacate_row(i);
    }
    st.dead.clear();
    let n = table.salvage_all(&mut st.dead);
    let now = Instant::now();
    for mut req in st.dead.drain(..) {
        if req.retries < retry_budget {
            req.retries += 1;
            shared.counters.retries.add(1);
            match shared.queue.requeue(req) {
                Ok(()) => {
                    shared.counters.requests_redispatched.add(1);
                }
                // Closed (or, defensively, Full): the pool is draining —
                // resolve the request here instead of losing it.
                Err(PushError::Closed(req) | PushError::Full(req)) => {
                    let retries = req.retries;
                    slots::complete_unstarted(req, FinishReason::Error { retries }, now);
                    shared.counters.failed.add(1);
                }
            }
        } else {
            let retries = req.retries;
            slots::complete_unstarted(req, FinishReason::Error { retries }, now);
            shared.counters.failed.add(1);
        }
    }
    n
}

/// Pop-side resolution: requests that should never occupy a slot complete
/// immediately; the rest are admitted (the caller guarantees a free slot).
/// Returns whether a slot was actually occupied.
///
/// Shedding happens here, *before* any prefill is burned: a deadline that
/// already passed while queued resolves as `DeadlineExpired` (also counted
/// under `shed_expired`), and a deadline the pool's measured rates say is
/// unreachable resolves as `Shed` (counted under `shed_infeasible`).
fn admit_one(table: &mut SlotTable, shared: &Shared, req: QueuedRequest) -> bool {
    let now = Instant::now();
    if req.cancel.poll() {
        slots::complete_unstarted(req, FinishReason::Cancelled, now);
        shared.counters.cancelled.add(1);
    } else if req.deadline.is_some_and(|d| now >= d) {
        // expired while queued: shed at pop time — the request never cost
        // a slot or a prefill
        slots::complete_unstarted(req, FinishReason::DeadlineExpired, now);
        shared.counters.expired.add(1);
        shared.counters.shed_expired.add(1);
    } else if req.max_new_tokens == 0 {
        // zero generation budget: complete empty instead of emitting the
        // encode token
        slots::complete_unstarted(req, FinishReason::Length, now);
        shared.counters.completed.add(1);
    } else if deadline_infeasible(shared, &req, now) {
        slots::complete_unstarted(req, FinishReason::Shed, now);
        shared.counters.shed_infeasible.add(1);
    } else if table.admit(req, now).is_none() {
        debug_assert!(false, "admit_one called with a full slot table");
    } else {
        return true;
    }
    false
}

/// SLO feasibility check against the pool's EWMA-measured rates: one
/// prefill plus `max_new_tokens` decode steps must fit in the deadline's
/// remaining budget. Both estimators must be seeded (a fresh pool has no
/// evidence and sheds nothing), and requests without deadlines are always
/// feasible. Pure saturating integer arithmetic — this runs on the decode
/// hot path via `refill_slots`.
fn deadline_infeasible(shared: &Shared, req: &QueuedRequest, now: Instant) -> bool {
    let Some(deadline) = req.deadline else { return false };
    let prefill = shared.counters.prefill_ewma.estimate();
    let decode = shared.counters.decode_ewma.estimate();
    if prefill == 0 || decode == 0 {
        return false;
    }
    let remaining = deadline.saturating_duration_since(now).as_nanos() as u64;
    // a salvaged request already spent part of its token budget
    let tokens_left = req.max_new_tokens.saturating_sub(req.emitted.len()) as u64;
    let need = prefill.saturating_add(decode.saturating_mul(tokens_left));
    need > remaining
}

/// Chunked, priority-aware top-up of free slots: High-priority requests are
/// popped first and never chunk-limited; at most `join_chunk` Normal rows
/// are admitted per call (0 = unlimited). Returns whether any admission
/// actually landed (dead queued requests resolve without costing a slot or
/// an encode). Admitted rows are `fresh` — the caller owes them a
/// single-row encode before the next decode step.
fn refill_slots(table: &mut SlotTable, shared: &Shared, join_chunk: usize) -> bool {
    let mut admitted = false;
    let mut normal_left = if join_chunk == 0 { usize::MAX } else { join_chunk };
    while table.free() > 0 {
        if let Some(req) = shared.queue.try_pop_high() {
            admitted |= admit_one(table, shared, req);
            continue;
        }
        if normal_left == 0 {
            break;
        }
        match shared.queue.try_pop() {
            Some(req) => {
                if admit_one(table, shared, req) {
                    normal_left -= 1;
                    admitted = true;
                }
            }
            None => break,
        }
    }
    admitted
}

/// Resolve cancelled/expired requests still sitting in the admission queue,
/// freeing their capacity instead of letting dead entries block submits (and
/// hang their clients) until a slot frees up to pop them. `scratch` is a
/// caller-owned buffer (the worker keeps one) so the common nothing-matched
/// sweep runs without touching the heap.
fn shed_dead_queued(shared: &Shared, now: Instant, scratch: &mut Vec<QueuedRequest>) {
    scratch.clear();
    shared
        .queue
        .drain_where_into(|r| r.cancel.poll() || r.deadline.is_some_and(|d| now >= d), scratch);
    for req in scratch.drain(..) {
        if req.cancel.poll() {
            slots::complete_unstarted(req, FinishReason::Cancelled, now);
            shared.counters.cancelled.add(1);
        } else {
            slots::complete_unstarted(req, FinishReason::DeadlineExpired, now);
            shared.counters.expired.add(1);
        }
    }
}

/// Encode one row into the live batch — admission (`fresh`) or per-row
/// rollover. Cache order: whole-window hit → restore, no forward pass
/// (elided); chunked partial-prefix hit → import the longest cached prefix
/// and prefill only the tail; miss → full single-row prefill. Real encodes
/// are timed, exported, and inserted back into the cache. The encode's
/// produced token is pushed to the row (finishing it when it was the last
/// of its budget).
fn encode_row(
    shared: &Shared,
    backend: &mut dyn EngineBackend,
    table: &mut SlotTable,
    st: &mut WorkerState,
    i: usize,
    prompt_len: usize,
    fresh: bool,
) -> Result<()> {
    let c = &shared.counters;
    let WorkerState { cache, window, decoded, kv_bytes, .. } = st;
    // an empty prompt encodes its all-pad window as one real pad token, so
    // the row still gets a position to decode from
    let len = table.real_len(i, prompt_len).max(1).min(prompt_len);
    table.write_window(i, tokenizer::PAD, window);
    let h = table.window_hash(i, prompt_len, tokenizer::PAD);

    let mut restored = false;
    let mut produced = tokenizer::PAD;
    if let Some(cache) = cache.as_mut() {
        if let Some(idx) = cache.probe(h, window) {
            // whole-window hit: no forward pass at all — decode the
            // encoded snapshot (timed: the codec's cost on the elision
            // path), splice it in, replay the cached next token
            let t0 = Instant::now();
            cache.decode_into(idx, decoded);
            produced = cache.peek(idx).1;
            c.kv_decode_nanos.add(t0.elapsed().as_nanos() as u64);
            backend.import_kv_row(i, decoded, len)?;
            c.kv_cache_hits.add(1);
            c.prefills_elided.add(1);
            restored = true;
        } else {
            c.kv_cache_misses.add(1);
            // partial-prefix: splice in the longest cached prefix so the
            // prefill only has to rebuild the tail
            let mut keep = 0usize;
            if let Some((idx, plen)) = cache.probe_prefix(window, len) {
                let t0 = Instant::now();
                cache.decode_into(idx, decoded);
                c.kv_decode_nanos.add(t0.elapsed().as_nanos() as u64);
                backend.import_kv_row(i, decoded, plen)?;
                keep = plen;
                c.partial_prefix_hits.add(1);
                c.partial_prefix_tokens_saved.add(plen as u64);
            }
            let t0 = Instant::now();
            produced = backend.prefill_row(i, window, len, keep)?;
            let dt = t0.elapsed().as_nanos() as u64;
            c.prefill_calls.add(1);
            c.prefill_nanos.add(dt);
            c.prefill_ewma.observe(dt);
            let kv = backend.export_kv_row(i)?;
            let out = cache.insert(h, window.clone(), len, &kv, produced)?;
            c.kv_cache_evictions.add(out.evicted);
            c.kv_bytes_saved.add(out.bytes_saved);
            // Gauge tracks the *resident* encoded bytes across all workers;
            // sync it by delta against this worker's last observation so
            // evictions (including budget-driven ones) are reflected too.
            let cur = cache.bytes_resident() as usize;
            if cur > *kv_bytes {
                c.kv_bytes_resident.add(cur - *kv_bytes);
            } else {
                c.kv_bytes_resident.sub(*kv_bytes - cur);
            }
            *kv_bytes = cur;
            restored = true;
        }
    }
    if !restored {
        let t0 = Instant::now();
        produced = backend.prefill_row(i, window, len, 0)?;
        let dt = t0.elapsed().as_nanos() as u64;
        c.prefill_calls.add(1);
        c.prefill_nanos.add(dt);
        c.prefill_ewma.observe(dt);
    }

    let now = Instant::now();
    if fresh {
        // stats for the tentpole claim: how long an admitted request held
        // a slot before its row went live, and whether other rows kept
        // decoding state while it joined (the barrier the per-row design
        // removed would have re-encoded all of them)
        if table.live_rows() > 0 {
            c.rows_joined_midflight.add(1);
        }
        c.join_wait_nanos.add(table.admission_wait(i, now).as_nanos() as u64);
    }
    table.set_row_live(i, len);
    if let Some(reason) = table.push_token(i, produced, now) {
        tally_finish(shared, reason);
        backend.vacate_row(i);
    }
    Ok(())
}

/// Drive the batch until it drains: encode whatever rows need encoding
/// (fresh admissions first, then per-row rollovers), then hand control to
/// the hot decode loop until it reports more encode work or the table
/// empties. All heap work (window assembly, cache codec traffic, KV
/// import/export) lives here, outside the lint-pinned hot set.
fn serve_batch(
    shared: &Shared,
    backend: &mut dyn EngineBackend,
    table: &mut SlotTable,
    gauge: &mut usize,
    st: &mut WorkerState,
) -> Result<()> {
    let (serve_bs, prompt_len, max_len) =
        (backend.batch_size(), backend.prompt_len(), backend.max_len());
    loop {
        while let Some(i) = table.first_fresh() {
            encode_row(shared, backend, table, st, i, prompt_len, true)?;
        }
        while let Some(i) = table.first_rollover(max_len) {
            encode_row(shared, backend, table, st, i, prompt_len, false)?;
        }
        sync_gauge(shared, gauge, table.active());
        match decode_loop(shared, backend, table, gauge, st, serve_bs, max_len)? {
            LoopEvent::Drained => return Ok(()),
            LoopEvent::Encode => {}
        }
    }
}

/// The steady-state lockstep decode loop — the tightest loop in serving.
/// Declared as the allocation lint's hot root: everything reachable from
/// here (sweeping, queue shedding, refills, slot bookkeeping) must stay off
/// the heap, reusing the scratch buffers in [`WorkerState`]. The backend
/// `decode_step` implementations are the boundary (`lint: hot-path-end`) —
/// their internals are model-execution cost, not scheduler overhead.
/// Returns [`LoopEvent::Drained`] when the table empties, or
/// [`LoopEvent::Encode`] when a fresh admission or a per-row rollover
/// needs heap-side encode work — admissions are checked *after* each
/// decode step, so `join_chunk` paces row encodes against decode progress
/// instead of letting a burst encode back-to-back.
// lint: hot-path
fn decode_loop(
    shared: &Shared,
    backend: &mut dyn EngineBackend,
    table: &mut SlotTable,
    gauge: &mut usize,
    st: &mut WorkerState,
    serve_bs: usize,
    max_len: usize,
) -> Result<LoopEvent> {
    let mut step = 0usize;
    loop {
        let mut now = Instant::now();
        let (cancelled, expired) = table.sweep(now, &mut st.vacated);
        shared.counters.cancelled.add(cancelled as u64);
        shared.counters.expired.add(expired as u64);
        for &r in &st.vacated {
            backend.vacate_row(r);
        }
        // Periodically shed cancelled/expired entries still queued, so dead
        // work frees admission capacity without waiting for a pop. Throttled:
        // an O(queue) scan under the shared lock is not for every step.
        if step % 16 == 0 {
            shed_dead_queued(shared, now, &mut st.dead);
        }
        step += 1;
        if table.active() == 0 {
            sync_gauge(shared, gauge, 0);
            return Ok(LoopEvent::Drained); // caller parks or admits
        }
        // Fresh rows (admitted below, or by run_worker) and rolled-over
        // rows must not decode — their KV rows are not live. Hand them
        // back for their single-row encode.
        if table.has_fresh() || table.first_rollover(max_len).is_some() {
            sync_gauge(shared, gauge, table.active());
            return Ok(LoopEvent::Encode);
        }
        sync_gauge(shared, gauge, table.active());

        table.feed_tokens_into(tokenizer::PAD, &mut st.feed);
        table.positions_into(&mut st.pos);
        let t_step = Instant::now();
        let next = backend.decode_step(&st.feed, &st.pos)?;
        let rows = next.len();
        anyhow::ensure!(rows == serve_bs, "decode returned {rows} rows, want {serve_bs}");

        table.occupied_into(&mut st.occ);
        let step_nanos = t_step.elapsed().as_nanos() as u64;
        shared.counters.decoded_tokens.add(st.occ.len() as u64);
        shared.counters.decode_nanos.add(step_nanos);
        if !st.occ.is_empty() {
            // per-useful-token cost feeds the admission feasibility check
            shared.counters.decode_ewma.observe(step_nanos / st.occ.len() as u64);
        }
        now = Instant::now();
        for &i in &st.occ {
            table.bump_pos(i);
            if let Some(reason) = table.push_token(i, next[i], now) {
                tally_finish(shared, reason);
                backend.vacate_row(i);
            }
        }
        // Refill vacated slots *after* the step, so chunked admission paces
        // joins against decode progress — and only report encode work when
        // an admission actually lands (a dead queued request, or another
        // worker winning the race for it, must not interrupt decoding).
        if table.free() > 0 && refill_slots(table, shared, st.join_chunk) {
            sync_gauge(shared, gauge, table.active());
            return Ok(LoopEvent::Encode);
        }
    }
}

fn tally_finish(shared: &Shared, reason: FinishReason) {
    match reason {
        FinishReason::Length | FinishReason::Stop => {
            shared.counters.completed.add(1);
            // completions are the circuit breaker's success signal (one
            // short lock-free-of-allocation transition; hot-path safe)
            shared.supervisor.breaker.record_success();
        }
        // cancellations/expiries are tallied where they are detected
        _ => {}
    }
}

/// Publish this worker's slot occupancy into the pool-wide `active` gauge.
fn sync_gauge(shared: &Shared, prev: &mut usize, cur: usize) {
    if cur > *prev {
        shared.counters.active.add(cur - *prev);
    } else if cur < *prev {
        shared.counters.active.sub(*prev - cur);
    }
    *prev = cur;
}
