//! Slot table for continuous batching: a fixed `serve_bs` grid of rows the
//! worker decodes in lockstep. Finished / cancelled / expired rows are
//! vacated and refilled from the admission queue between decode steps, so
//! slots spend their time on real requests instead of dummy rows decoding
//! into the void.
//!
//! The table is pure bookkeeping (no PJRT): it tracks, per row, the request,
//! its generated tokens, and its **decode position** — the next KV write
//! index for that row, independent of every other row. A freshly admitted
//! row is `fresh` until the engine encodes it into a backend row
//! ([`SlotTable::set_row_live`]); from then on its position advances one per
//! decode step ([`SlotTable::bump_pos`]) and rolls over *individually* when
//! it exhausts the backend's static KV window — no batch-wide barrier. The
//! engine asks the table for each row's left-aligned context window (real
//! tokens first, trailing pad) when it single-row-prefills an admission or
//! a rollover, and for the per-row feed tokens / positions of the next
//! decode step; decoded tokens are reported back via
//! [`SlotTable::push_token`]. Stream events go out on each request's
//! channel as they happen.

use crate::serve::kvcache;
use crate::serve::service::{Completion, FinishReason, QueuedRequest, StreamEvent, Timing};
use std::time::Instant;

/// A request occupying one slot.
struct ActiveRequest {
    req: QueuedRequest,
    generated: Vec<i32>,
    admitted_at: Instant,
    first_token_at: Option<Instant>,
    /// The window changed since `window_hash` last ran (admission or a new
    /// generated token) — the cached hash below is stale.
    window_dirty: bool,
    /// `(prompt_len, pad, hash)` of the last hashed window — both inputs
    /// fold into the hash, so both key the cache — letting clean rows skip
    /// rehashing at every encode boundary.
    window_hash: (usize, i32, u64),
    /// Next KV write position for this row's decode step. Starts at the
    /// row's real window length after an encode; bumped once per decode
    /// step; meaningless while `fresh`.
    pos: usize,
    /// Admitted but not yet encoded into a backend row — the engine must
    /// single-row-prefill (or cache-restore) it before the row may decode.
    fresh: bool,
}

/// Fixed-capacity row table; one per engine worker.
pub struct SlotTable {
    slots: Vec<Option<ActiveRequest>>,
}

impl SlotTable {
    pub fn new(n_slots: usize) -> Self {
        Self { slots: (0..n_slots).map(|_| None).collect() }
    }

    pub fn size(&self) -> usize {
        self.slots.len()
    }

    pub fn active(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    pub fn free(&self) -> usize {
        self.size() - self.active()
    }

    /// Indices of occupied rows, without allocating. Borrows the table
    /// immutably — callers that vacate rows while walking the indices use
    /// [`occupied_into`](Self::occupied_into) with a reusable scratch vec.
    pub fn occupied_iter(&self) -> impl Iterator<Item = usize> + '_ {
        self.slots.iter().enumerate().filter(|(_, s)| s.is_some()).map(|(i, _)| i)
    }

    /// Snapshot the occupied indices into a caller-owned scratch vec (the
    /// engine reuses one across every decode step instead of allocating).
    pub fn occupied_into(&self, out: &mut Vec<usize>) {
        out.clear();
        out.extend(self.occupied_iter());
    }

    /// Indices of occupied rows (allocating snapshot; hot paths use
    /// [`occupied_iter`](Self::occupied_iter) / [`occupied_into`](Self::occupied_into)).
    pub fn occupied(&self) -> Vec<usize> {
        self.occupied_iter().collect()
    }

    /// Place a request into the lowest free slot. `None` when the table is
    /// full (callers check `free()` first).
    ///
    /// A redispatched request re-enters with its previously streamed tokens
    /// folded into `req.emitted` (see [`SlotTable::salvage`]); they seed
    /// `generated` here — `mem::take` is a pointer swap, no allocation — so
    /// the context window, stop scan and `max_new_tokens` budget all compose
    /// across worker faults. Already-streamed tokens are *not* re-sent:
    /// [`push_token`](Self::push_token) only streams newly decoded tokens.
    pub fn admit(&mut self, mut req: QueuedRequest, now: Instant) -> Option<usize> {
        let i = self.slots.iter().position(|s| s.is_none())?;
        let generated = std::mem::take(&mut req.emitted);
        self.slots[i] = Some(ActiveRequest {
            req,
            generated,
            admitted_at: now,
            first_token_at: None,
            window_dirty: true,
            window_hash: (0, 0, 0),
            pos: 0,
            fresh: true,
        });
        Some(i)
    }

    /// The three segments of row `i`'s **left-aligned** window: the prompt
    /// tail, the generated tail, and the trailing pad count. Single source
    /// of truth for [`window`](Self::window),
    /// [`write_window`](Self::write_window) and
    /// [`window_hash`](Self::window_hash). Left alignment puts a shared
    /// prefix at the *same* window offsets regardless of each request's
    /// total length — the property the KV cache's chunked prefix keying
    /// relies on (right-aligned windows would shift a shared system prompt
    /// by each request's pad count).
    fn window_segments(&self, i: usize, prompt_len: usize) -> (&[i32], &[i32], usize) {
        let Some(ent) = self.slots[i].as_ref() else { return (&[], &[], prompt_len) };
        let take = (ent.req.prompt.len() + ent.generated.len()).min(prompt_len);
        let from_gen = take.min(ent.generated.len());
        let from_prompt = take - from_gen;
        (
            &ent.req.prompt[ent.req.prompt.len() - from_prompt..],
            &ent.generated[ent.generated.len() - from_gen..],
            prompt_len - take,
        )
    }

    /// Number of real (non-pad) tokens in row `i`'s window: `min(prompt +
    /// generated, prompt_len)`. This is the position a row decodes from
    /// right after an encode. 0 for vacant rows.
    pub fn real_len(&self, i: usize, prompt_len: usize) -> usize {
        let (prompt, gen, _) = self.window_segments(i, prompt_len);
        prompt.len() + gen.len()
    }

    /// Write row `i`'s window into `out` (`out.len() == prompt_len`)
    /// without allocating — the engine assembles single-row prefill inputs
    /// into one reused buffer.
    pub fn write_window(&self, i: usize, pad: i32, out: &mut [i32]) {
        let (prompt, gen, n_pad) = self.window_segments(i, out.len());
        out[..prompt.len()].copy_from_slice(prompt);
        out[prompt.len()..prompt.len() + gen.len()].copy_from_slice(gen);
        out[out.len() - n_pad..].fill(pad);
    }

    /// Left-aligned context window for row `i`: the most recent
    /// `prompt_len` tokens of `prompt ++ generated` at offsets `0..len`,
    /// right-padded with `pad`. This is what a single-row prefill encodes
    /// on admission or rollover; RoPE is shift-equivariant, so restarting
    /// positions at 0 preserves attention geometry *within* the window —
    /// anything older is dropped (sliding-window truncation).
    pub fn window(&self, i: usize, prompt_len: usize, pad: i32) -> Vec<i32> {
        let mut w = vec![pad; prompt_len];
        self.write_window(i, pad, &mut w);
        w
    }

    /// Hash of row `i`'s window under [`kvcache::hash_tokens`] — the KV
    /// prefix-cache key. Cached per row and recomputed only when the window
    /// changed (dirty tracking), so clean rows cost one comparison per
    /// lookup. Free rows hash their all-pad window.
    pub fn window_hash(&mut self, i: usize, prompt_len: usize, pad: i32) -> u64 {
        if let Some(ent) = self.slots[i].as_ref() {
            if !ent.window_dirty && ent.window_hash.0 == prompt_len && ent.window_hash.1 == pad {
                return ent.window_hash.2;
            }
        }
        let (prompt, gen, n_pad) = self.window_segments(i, prompt_len);
        let mut h = kvcache::hash_tokens(&[]);
        for &t in prompt.iter().chain(gen) {
            h = kvcache::fold_token(h, t);
        }
        for _ in 0..n_pad {
            h = kvcache::fold_token(h, pad);
        }
        if let Some(ent) = self.slots[i].as_mut() {
            ent.window_dirty = false;
            ent.window_hash = (prompt_len, pad, h);
        }
        h
    }

    /// Whether row `i`'s window changed since its last
    /// [`window_hash`](Self::window_hash) (always `false` for free rows,
    /// whose pad window never changes).
    pub fn window_dirty(&self, i: usize) -> bool {
        self.slots[i].as_ref().is_some_and(|e| e.window_dirty)
    }

    /// Row `i`'s next KV write position (0 for vacant or fresh rows).
    pub fn pos(&self, i: usize) -> usize {
        self.slots[i].as_ref().map_or(0, |e| e.pos)
    }

    /// Snapshot every row's decode position into a caller-owned scratch vec
    /// (vacant rows report 0; their decode output is junk the scheduler
    /// ignores). One entry per slot, in row order — the `pos` vector the
    /// backend's per-row decode step consumes.
    pub fn positions_into(&self, out: &mut Vec<usize>) {
        out.clear();
        out.extend(self.slots.iter().map(|s| s.as_ref().map_or(0, |e| e.pos)));
    }

    /// Mark row `i` live after the engine encoded it into a backend row:
    /// clears `fresh` and starts the row's decode position at `len` (its
    /// real window length — the first KV index the encode did not fill).
    pub fn set_row_live(&mut self, i: usize, len: usize) {
        if let Some(ent) = self.slots[i].as_mut() {
            ent.fresh = false;
            ent.pos = len;
        } else {
            debug_assert!(false, "set_row_live({i}) on a vacant slot");
        }
    }

    /// Advance row `i`'s decode position by one (after a decode step wrote
    /// KV at the old position). No-op for vacant rows.
    pub fn bump_pos(&mut self, i: usize) {
        if let Some(ent) = self.slots[i].as_mut() {
            ent.pos += 1;
        }
    }

    /// Whether any occupied row is still awaiting its first encode.
    pub fn has_fresh(&self) -> bool {
        self.slots.iter().any(|s| s.as_ref().is_some_and(|e| e.fresh))
    }

    /// Lowest fresh row, if any — the next single-row prefill target.
    pub fn first_fresh(&self) -> Option<usize> {
        self.slots.iter().position(|s| s.as_ref().is_some_and(|e| e.fresh))
    }

    /// Lowest live row whose position exhausted the backend's static KV
    /// window (`pos >= max_len`) — it must be re-encoded (a *per-row*
    /// sliding-window rollover) before the batch can step again.
    pub fn first_rollover(&self, max_len: usize) -> Option<usize> {
        self.slots
            .iter()
            .position(|s| s.as_ref().is_some_and(|e| !e.fresh && e.pos >= max_len))
    }

    /// Occupied rows that are already encoded (`!fresh`) — the rows that
    /// keep decoding while a fresh admission joins. The engine counts a
    /// join as "mid-flight" when this is non-zero at encode time.
    pub fn live_rows(&self) -> usize {
        self.slots.iter().filter(|s| s.as_ref().is_some_and(|e| !e.fresh)).count()
    }

    /// How long row `i` has held its slot since admission (zero for vacant
    /// rows). Sampled by the engine when a fresh row goes live — the
    /// admission→live gap behind the `join_wait_nanos` stat.
    pub fn admission_wait(&self, i: usize, now: Instant) -> std::time::Duration {
        self.slots[i]
            .as_ref()
            .map_or(std::time::Duration::ZERO, |e| now.saturating_duration_since(e.admitted_at))
    }

    /// Per-row input tokens for the next decode step: each active row feeds
    /// its last generated token; free rows feed `pad` (their output is
    /// ignored).
    pub fn feed_tokens(&self, pad: i32) -> Vec<i32> {
        let mut v = Vec::with_capacity(self.slots.len());
        self.feed_tokens_into(pad, &mut v);
        v
    }

    /// [`feed_tokens`](Self::feed_tokens) into a caller-owned scratch vec —
    /// the engine's decode loop reuses one instead of allocating per step.
    pub fn feed_tokens_into(&self, pad: i32, out: &mut Vec<i32>) {
        out.clear();
        out.extend(
            self.slots
                .iter()
                .map(|s| s.as_ref().and_then(|e| e.generated.last().copied()).unwrap_or(pad)),
        );
    }

    /// Record one decoded token for row `i`: stream it, then finish the row
    /// if it hit a stop token or its `max_new_tokens` budget. Returns the
    /// finish reason when the row was vacated.
    pub fn push_token(&mut self, i: usize, tok: i32, now: Instant) -> Option<FinishReason> {
        let ent = self.slots[i].as_mut()?;
        ent.generated.push(tok);
        ent.window_dirty = true;
        if ent.first_token_at.is_none() {
            ent.first_token_at = Some(now);
        }
        let _ = ent.req.tx.send(StreamEvent::Token(tok));
        let reason = if ent.req.stop_tokens.contains(&tok) {
            Some(FinishReason::Stop)
        } else if ent.generated.len() >= ent.req.max_new_tokens {
            Some(FinishReason::Length)
        } else {
            None
        };
        if let Some(r) = reason {
            self.finish(i, r, now);
        }
        reason
    }

    /// Vacate rows whose cancel flag is set or whose deadline has passed.
    /// Returns `(cancelled, expired)` counts; the vacated row indices are
    /// appended to `vacated` (cleared first — a caller-owned scratch vec,
    /// so the engine can release the matching backend rows without
    /// allocating in its decode loop).
    pub fn sweep(&mut self, now: Instant, vacated: &mut Vec<usize>) -> (usize, usize) {
        vacated.clear();
        let (mut cancelled, mut expired) = (0, 0);
        for i in 0..self.slots.len() {
            let Some(ent) = self.slots[i].as_ref() else { continue };
            if ent.req.cancel.poll() {
                self.finish(i, FinishReason::Cancelled, now);
                cancelled += 1;
                vacated.push(i);
            } else if ent.req.deadline.is_some_and(|d| now >= d) {
                self.finish(i, FinishReason::DeadlineExpired, now);
                expired += 1;
                vacated.push(i);
            }
        }
        (cancelled, expired)
    }

    /// Vacate every row with `FinishReason::Error` (engine batch failure);
    /// partial tokens are delivered. Returns how many rows were failed.
    /// The supervised worker loop prefers [`salvage_all`](Self::salvage_all)
    /// — this is the terminal path for requests whose retry budget is spent
    /// or whose queue has closed.
    pub fn fail_all(&mut self, now: Instant) -> usize {
        let mut n = 0;
        for i in 0..self.slots.len() {
            if let Some(ent) = self.slots[i].as_ref() {
                let retries = ent.req.retries;
                self.finish(i, FinishReason::Error { retries }, now);
                n += 1;
            }
        }
        n
    }

    /// Extract row `i`'s request for redispatch after a worker fault: the
    /// slot is vacated and everything generated so far is folded back into
    /// `req.emitted` — **no** terminal event is sent, so from the client's
    /// side the stream is simply pausing. The supervisor either requeues the
    /// request (transparent retry; [`admit`](Self::admit) re-seeds the
    /// context from `emitted`) or resolves it with [`complete_unstarted`]
    /// once its retry budget is spent.
    pub fn salvage(&mut self, i: usize) -> Option<QueuedRequest> {
        let mut ent = self.slots[i].take()?;
        ent.req.emitted = ent.generated;
        Some(ent.req)
    }

    /// [`salvage`](Self::salvage) every occupied row (a worker fault takes
    /// the whole batch out at once), appending the live requests to `out`.
    /// Returns how many rows were salvaged.
    pub fn salvage_all(&mut self, out: &mut Vec<QueuedRequest>) -> usize {
        let mut n = 0;
        for i in 0..self.slots.len() {
            if let Some(req) = self.salvage(i) {
                out.push(req);
                n += 1;
            }
        }
        n
    }

    fn finish(&mut self, i: usize, reason: FinishReason, now: Instant) {
        let Some(ent) = self.slots[i].take() else {
            // Internal invariant: every caller checked occupancy first. A
            // vacant row here is a bookkeeping bug, but panicking would take
            // the whole worker (and its other slots) down with it.
            debug_assert!(false, "finish() on a vacant slot {i}");
            return;
        };
        let timing = Timing {
            queued: ent.admitted_at.saturating_duration_since(ent.req.submitted_at),
            first_token: ent
                .first_token_at
                .map(|t| t.saturating_duration_since(ent.req.submitted_at)),
            total: now.saturating_duration_since(ent.req.submitted_at),
        };
        let _ = ent.req.tx.send(StreamEvent::Done(Completion {
            tokens: ent.generated,
            finish_reason: reason,
            timing,
        }));
    }
}

/// Resolve a request outside a slot: never admitted (expired/cancelled while
/// queued, shed, or `max_new_tokens == 0` — which completes with zero tokens
/// rather than smuggling out the prefill token), or salvaged from a faulted
/// worker with its retry budget spent. The completion delivers whatever the
/// request already streamed (`req.emitted` — empty for requests that never
/// ran; moving the vec out is allocation-free).
pub fn complete_unstarted(req: QueuedRequest, reason: FinishReason, now: Instant) {
    let timing = Timing {
        queued: now.saturating_duration_since(req.submitted_at),
        first_token: None,
        total: now.saturating_duration_since(req.submitted_at),
    };
    let _ = req.tx.send(StreamEvent::Done(Completion {
        tokens: req.emitted,
        finish_reason: reason,
        timing,
    }));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::sync::Flag;
    use std::sync::mpsc::{channel, Receiver};
    use std::sync::Arc;
    use std::time::Duration;

    fn mk_req(
        prompt: Vec<i32>,
        max_new: usize,
        stop: Vec<i32>,
        deadline: Option<Instant>,
    ) -> (QueuedRequest, Receiver<StreamEvent>, Arc<Flag>) {
        let (tx, rx) = channel();
        let cancel = Arc::new(Flag::new());
        let req = QueuedRequest {
            prompt,
            max_new_tokens: max_new,
            stop_tokens: stop,
            deadline,
            submitted_at: Instant::now(),
            tx,
            cancel: cancel.clone(),
            emitted: Vec::new(),
            retries: 0,
        };
        (req, rx, cancel)
    }

    fn drain(rx: &Receiver<StreamEvent>) -> (Vec<i32>, Option<Completion>) {
        let mut toks = Vec::new();
        let mut done = None;
        while let Ok(ev) = rx.try_recv() {
            match ev {
                StreamEvent::Token(t) => toks.push(t),
                StreamEvent::Done(c) => done = Some(c),
            }
        }
        (toks, done)
    }

    #[test]
    fn refill_takes_lowest_free_slot() {
        let now = Instant::now();
        let mut tbl = SlotTable::new(3);
        let mut rxs = Vec::new();
        for p in 0..3 {
            let (req, rx, _) = mk_req(vec![p], 1, vec![], None);
            assert_eq!(tbl.admit(req, now), Some(p as usize));
            rxs.push(rx);
        }
        assert_eq!(tbl.free(), 0);
        let (req, _rx, _) = mk_req(vec![9], 4, vec![], None);
        assert_eq!(tbl.admit(req, now), None, "full table rejects admission");
        // finish the middle row (max_new = 1 → one token ends it)
        assert_eq!(tbl.push_token(1, 42, now), Some(FinishReason::Length));
        assert_eq!(tbl.free(), 1);
        let (req, _rx2, _) = mk_req(vec![9], 4, vec![], None);
        assert_eq!(tbl.admit(req, now), Some(1), "refill reuses the freed slot");
        assert_eq!(tbl.occupied(), vec![0, 1, 2]);
    }

    #[test]
    fn stop_token_finishes_with_stop() {
        let now = Instant::now();
        let mut tbl = SlotTable::new(1);
        let (req, rx, _) = mk_req(vec![1, 2], 10, vec![99], None);
        tbl.admit(req, now).unwrap();
        assert_eq!(tbl.push_token(0, 5, now), None);
        assert_eq!(tbl.push_token(0, 99, now), Some(FinishReason::Stop));
        let (toks, done) = drain(&rx);
        assert_eq!(toks, vec![5, 99], "stop token is streamed and included");
        let c = done.unwrap();
        assert_eq!(c.tokens, vec![5, 99]);
        assert_eq!(c.finish_reason, FinishReason::Stop);
    }

    #[test]
    fn length_cap_streams_then_completes() {
        let now = Instant::now();
        let mut tbl = SlotTable::new(1);
        let (req, rx, _) = mk_req(vec![1], 2, vec![], None);
        tbl.admit(req, now).unwrap();
        assert_eq!(tbl.push_token(0, 7, now), None);
        assert_eq!(tbl.push_token(0, 8, now), Some(FinishReason::Length));
        let (toks, done) = drain(&rx);
        assert_eq!(toks, vec![7, 8]);
        let c = done.unwrap();
        assert_eq!(c.finish_reason, FinishReason::Length);
        assert!(c.timing.first_token.is_some());
    }

    #[test]
    fn cancellation_mid_decode_vacates_with_partial_tokens() {
        let now = Instant::now();
        let mut tbl = SlotTable::new(2);
        let (req, rx, cancel) = mk_req(vec![1], 100, vec![], None);
        tbl.admit(req, now).unwrap();
        tbl.push_token(0, 3, now);
        let mut vac = Vec::new();
        assert_eq!(tbl.sweep(now, &mut vac), (0, 0), "no flags set yet");
        assert!(vac.is_empty());
        cancel.set();
        assert_eq!(tbl.sweep(now, &mut vac), (1, 0));
        assert_eq!(vac, vec![0], "sweep reports the vacated row");
        assert_eq!(tbl.active(), 0);
        let (_, done) = drain(&rx);
        let c = done.unwrap();
        assert_eq!(c.finish_reason, FinishReason::Cancelled);
        assert_eq!(c.tokens, vec![3], "partial output is delivered");
    }

    #[test]
    fn deadline_expiry_vacates_row() {
        let now = Instant::now();
        let mut tbl = SlotTable::new(1);
        let (req, rx, _) = mk_req(vec![1], 100, vec![], Some(now + Duration::from_millis(5)));
        tbl.admit(req, now).unwrap();
        let mut vac = Vec::new();
        assert_eq!(tbl.sweep(now, &mut vac), (0, 0), "deadline still in the future");
        assert_eq!(tbl.sweep(now + Duration::from_millis(6), &mut vac), (0, 1));
        assert_eq!(vac, vec![0]);
        let (_, done) = drain(&rx);
        assert_eq!(done.unwrap().finish_reason, FinishReason::DeadlineExpired);
    }

    #[test]
    fn window_is_left_aligned_and_slides_over_generated() {
        let now = Instant::now();
        let mut tbl = SlotTable::new(1);
        let (req, _rx, _) = mk_req(vec![1, 2, 3], 100, vec![], None);
        tbl.admit(req, now).unwrap();
        assert_eq!(tbl.window(0, 5, 0), vec![1, 2, 3, 0, 0], "right-padded");
        assert_eq!(tbl.real_len(0, 5), 3);
        for t in [4, 5, 6] {
            tbl.push_token(0, t, now);
        }
        // context 1,2,3,4,5,6 → keep the most recent 5
        assert_eq!(tbl.window(0, 5, 0), vec![2, 3, 4, 5, 6]);
        assert_eq!(tbl.real_len(0, 5), 5);
        assert_eq!(tbl.feed_tokens(0), vec![6]);
        // free rows window/feed as pure padding
        let tbl2 = SlotTable::new(2);
        assert_eq!(tbl2.window(1, 3, 0), vec![0, 0, 0]);
        assert_eq!(tbl2.real_len(1, 3), 0);
        assert_eq!(tbl2.feed_tokens(0), vec![0, 0]);
    }

    #[test]
    fn write_window_matches_window_and_reuses_buffer() {
        let now = Instant::now();
        let mut tbl = SlotTable::new(2);
        let (req, _rx, _) = mk_req(vec![1, 2, 3], 100, vec![], None);
        tbl.admit(req, now).unwrap();
        tbl.push_token(0, 4, now);
        let mut buf = vec![-1; 5];
        tbl.write_window(0, 0, &mut buf);
        assert_eq!(buf, tbl.window(0, 5, 0));
        assert_eq!(buf, vec![1, 2, 3, 4, 0]);
        // free row: pure padding, buffer fully overwritten
        tbl.write_window(1, 9, &mut buf);
        assert_eq!(buf, vec![9; 5]);
    }

    #[test]
    fn per_row_positions_track_encode_and_decode_independently() {
        let now = Instant::now();
        let mut tbl = SlotTable::new(3);
        let (r0, _a, _) = mk_req(vec![1, 2], 100, vec![], None);
        let (r1, _b, _) = mk_req(vec![1, 2, 3, 4], 100, vec![], None);
        tbl.admit(r0, now).unwrap();
        tbl.admit(r1, now).unwrap();
        assert!(tbl.has_fresh());
        assert_eq!(tbl.first_fresh(), Some(0));
        assert_eq!(tbl.pos(0), 0, "fresh rows report position 0");
        assert_eq!(tbl.live_rows(), 0, "fresh rows are not live");
        // encode row 0 at its real length; row 1 stays fresh
        tbl.set_row_live(0, tbl.real_len(0, 5));
        assert_eq!(tbl.pos(0), 2);
        assert_eq!(tbl.live_rows(), 1, "row 0 decodes while row 1 joins");
        assert!(tbl.admission_wait(0, now + Duration::from_millis(3)) >= Duration::from_millis(3));
        assert_eq!(tbl.admission_wait(2, now), Duration::ZERO, "vacant rows report zero wait");
        assert_eq!(tbl.first_fresh(), Some(1));
        tbl.set_row_live(1, tbl.real_len(1, 5));
        assert_eq!(tbl.pos(1), 4);
        assert!(!tbl.has_fresh());
        // positions advance per row, vacant rows report 0
        tbl.bump_pos(0);
        let mut pos = Vec::new();
        tbl.positions_into(&mut pos);
        assert_eq!(pos, vec![3, 4, 0]);
        // rollover is a per-row predicate: only row 1 exhausts max_len 4
        assert_eq!(tbl.first_rollover(4), Some(1));
        assert_eq!(tbl.first_rollover(5), None);
        // fresh rows never report as rollovers even at pos 0 < max_len
        let (r2, _c, _) = mk_req(vec![9], 100, vec![], None);
        tbl.admit(r2, now).unwrap();
        assert_eq!(tbl.first_rollover(4), Some(1), "fresh row 2 is not a rollover");
    }

    #[test]
    fn window_hash_matches_kvcache_and_tracks_dirtiness() {
        use crate::serve::kvcache::hash_tokens;
        let now = Instant::now();
        let mut tbl = SlotTable::new(2);
        let (req, _rx, _) = mk_req(vec![1, 2, 3], 100, vec![], None);
        tbl.admit(req, now).unwrap();
        assert!(tbl.window_dirty(0), "fresh admission is dirty");
        let h = tbl.window_hash(0, 5, 0);
        assert_eq!(h, hash_tokens(&tbl.window(0, 5, 0)));
        assert!(!tbl.window_dirty(0), "hashing cleans the row");
        assert_eq!(tbl.window_hash(0, 5, 0), h, "cached hash is stable");
        // pad folds into the hash, so it must key the cache too (the row is
        // clean here — a stale pad-0 hash must not be served for pad 9)
        assert_eq!(tbl.window_hash(0, 5, 9), hash_tokens(&[1, 2, 3, 9, 9]));
        assert_eq!(tbl.window_hash(0, 5, 0), h, "switching back re-keys correctly");
        tbl.push_token(0, 4, now);
        assert!(tbl.window_dirty(0), "a generated token dirties the window");
        let h2 = tbl.window_hash(0, 5, 0);
        assert_ne!(h2, h);
        assert_eq!(h2, hash_tokens(&tbl.window(0, 5, 0)));
        // a different prompt_len invalidates the cached hash too
        assert_eq!(tbl.window_hash(0, 3, 0), hash_tokens(&tbl.window(0, 3, 0)));
        // free rows hash their all-pad window and are never dirty
        assert!(!tbl.window_dirty(1));
        assert_eq!(tbl.window_hash(1, 3, 7), hash_tokens(&[7, 7, 7]));
    }

    #[test]
    fn occupied_iter_agrees_with_snapshot() {
        let now = Instant::now();
        let mut tbl = SlotTable::new(3);
        let (r0, _a, _) = mk_req(vec![1], 5, vec![], None);
        let (r2, _b, _) = mk_req(vec![2], 5, vec![], None);
        tbl.admit(r0, now).unwrap();
        tbl.admit(r2, now).unwrap();
        tbl.push_token(0, 9, now);
        tbl.push_token(0, 9, now);
        let mut scratch = vec![99; 8];
        tbl.occupied_into(&mut scratch);
        assert_eq!(scratch, tbl.occupied());
        assert_eq!(tbl.occupied_iter().collect::<Vec<_>>(), scratch);
        assert_eq!(scratch, vec![0, 1]);
    }

    #[test]
    fn complete_unstarted_delivers_empty_completion() {
        let (req, rx, _) = mk_req(vec![1, 2], 0, vec![], None);
        complete_unstarted(req, FinishReason::Length, Instant::now());
        let (toks, done) = drain(&rx);
        assert!(toks.is_empty());
        let c = done.unwrap();
        assert!(c.tokens.is_empty(), "max_new_tokens == 0 yields no prefill token");
        assert_eq!(c.finish_reason, FinishReason::Length);
    }

    #[test]
    fn salvage_folds_generated_back_without_a_terminal_event() {
        let now = Instant::now();
        let mut tbl = SlotTable::new(2);
        let (req, rx, _) = mk_req(vec![1, 2], 100, vec![], None);
        tbl.admit(req, now).unwrap();
        tbl.push_token(0, 7, now);
        tbl.push_token(0, 8, now);
        let req = tbl.salvage(0).expect("occupied row salvages");
        assert_eq!(req.emitted, vec![7, 8], "generated folds into emitted");
        assert_eq!(tbl.active(), 0, "the slot is vacated");
        assert!(tbl.salvage(0).is_none(), "vacant rows have nothing to salvage");
        let (toks, done) = drain(&rx);
        assert_eq!(toks, vec![7, 8], "tokens streamed before the fault stay streamed");
        assert!(done.is_none(), "no Done: the request is still live");
        // spent retry budget → terminal completion carries the partial tokens
        complete_unstarted(req, FinishReason::Error { retries: 2 }, now);
        let (_, done) = drain(&rx);
        let c = done.unwrap();
        assert_eq!(c.tokens, vec![7, 8]);
        assert_eq!(c.finish_reason, FinishReason::Error { retries: 2 });
    }

    #[test]
    fn emitted_tokens_seed_readmission_window_feed_and_budget() {
        let now = Instant::now();
        let mut tbl = SlotTable::new(1);
        let (mut req, rx, _) = mk_req(vec![1, 2], 4, vec![], None);
        req.emitted = vec![3, 4]; // salvaged mid-stream with 2 of 4 tokens out
        tbl.admit(req, now).unwrap();
        // the context window composes prompt ++ emitted, and the next decode
        // feeds the last emitted token — exactly where the stream paused
        assert_eq!(tbl.window(0, 6, 0), vec![1, 2, 3, 4, 0, 0]);
        assert_eq!(tbl.real_len(0, 6), 4);
        assert_eq!(tbl.feed_tokens(0), vec![4]);
        // the length budget counts the already-emitted tokens
        assert_eq!(tbl.push_token(0, 5, now), None);
        assert_eq!(tbl.push_token(0, 6, now), Some(FinishReason::Length));
        let (toks, done) = drain(&rx);
        assert_eq!(toks, vec![5, 6], "seeded tokens are not re-streamed");
        let c = done.unwrap();
        assert_eq!(c.tokens, vec![3, 4, 5, 6], "the completion carries the full output");
    }

    #[test]
    fn salvage_all_sweeps_every_occupied_row() {
        let now = Instant::now();
        let mut tbl = SlotTable::new(3);
        let (r0, _a, _) = mk_req(vec![1], 10, vec![], None);
        let (r2, _b, _) = mk_req(vec![2], 10, vec![], None);
        tbl.admit(r0, now).unwrap();
        tbl.admit(r2, now).unwrap();
        tbl.push_token(0, 5, now);
        let mut out = Vec::new();
        assert_eq!(tbl.salvage_all(&mut out), 2);
        assert_eq!(tbl.active(), 0);
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].emitted, vec![5]);
        assert!(out[1].emitted.is_empty());
    }
}
