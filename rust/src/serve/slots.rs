//! Slot table for continuous batching: a fixed `serve_bs` grid of rows the
//! worker decodes in lockstep. Finished / cancelled / expired rows are
//! vacated and refilled from the admission queue between decode steps, so
//! slots spend their time on real requests instead of dummy rows decoding
//! into the void.
//!
//! The table is pure bookkeeping (no PJRT): the engine asks it for the
//! right-aligned context window of each row (to rebuild a merged batch via a
//! "join prefill") and for the per-row feed tokens of the next decode step,
//! and reports decoded tokens back via [`SlotTable::push_token`]. Stream
//! events go out on each request's channel as they happen.

use crate::serve::service::{Completion, FinishReason, QueuedRequest, StreamEvent, Timing};
use std::sync::atomic::Ordering;
use std::time::Instant;

/// A request occupying one slot.
struct ActiveRequest {
    req: QueuedRequest,
    generated: Vec<i32>,
    admitted_at: Instant,
    first_token_at: Option<Instant>,
}

/// Fixed-capacity row table; one per engine worker.
pub struct SlotTable {
    slots: Vec<Option<ActiveRequest>>,
}

impl SlotTable {
    pub fn new(n_slots: usize) -> Self {
        Self { slots: (0..n_slots).map(|_| None).collect() }
    }

    pub fn size(&self) -> usize {
        self.slots.len()
    }

    pub fn active(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    pub fn free(&self) -> usize {
        self.size() - self.active()
    }

    /// Indices of occupied rows (snapshot, so callers can mutate while
    /// iterating).
    pub fn occupied(&self) -> Vec<usize> {
        (0..self.slots.len()).filter(|&i| self.slots[i].is_some()).collect()
    }

    /// Place a request into the lowest free slot. `None` when the table is
    /// full (callers check `free()` first).
    pub fn admit(&mut self, req: QueuedRequest, now: Instant) -> Option<usize> {
        let i = self.slots.iter().position(|s| s.is_none())?;
        self.slots[i] = Some(ActiveRequest {
            req,
            generated: Vec::new(),
            admitted_at: now,
            first_token_at: None,
        });
        Some(i)
    }

    /// Right-aligned context window for row `i`: the most recent
    /// `prompt_len` tokens of `prompt ++ generated`, left-padded with `pad`.
    /// This is what a join prefill re-encodes when the merged batch is
    /// rebuilt; RoPE is shift-equivariant, so restarting positions at 0
    /// preserves attention geometry *within* the window — anything older is
    /// dropped (sliding-window truncation, same as the engine's rollover).
    pub fn window(&self, i: usize, prompt_len: usize, pad: i32) -> Vec<i32> {
        let mut w = vec![pad; prompt_len];
        if let Some(ent) = self.slots[i].as_ref() {
            let take = (ent.req.prompt.len() + ent.generated.len()).min(prompt_len);
            let from_gen = take.min(ent.generated.len());
            let from_prompt = take - from_gen;
            let dst = &mut w[prompt_len - take..];
            dst[..from_prompt]
                .copy_from_slice(&ent.req.prompt[ent.req.prompt.len() - from_prompt..]);
            dst[from_prompt..]
                .copy_from_slice(&ent.generated[ent.generated.len() - from_gen..]);
        }
        w
    }

    /// Per-row input tokens for the next decode step: each active row feeds
    /// its last generated token; free rows feed `pad` (their output is
    /// ignored).
    pub fn feed_tokens(&self, pad: i32) -> Vec<i32> {
        self.slots
            .iter()
            .map(|s| s.as_ref().and_then(|e| e.generated.last().copied()).unwrap_or(pad))
            .collect()
    }

    /// Record one decoded token for row `i`: stream it, then finish the row
    /// if it hit a stop token or its `max_new_tokens` budget. Returns the
    /// finish reason when the row was vacated.
    pub fn push_token(&mut self, i: usize, tok: i32, now: Instant) -> Option<FinishReason> {
        let ent = self.slots[i].as_mut()?;
        ent.generated.push(tok);
        if ent.first_token_at.is_none() {
            ent.first_token_at = Some(now);
        }
        let _ = ent.req.tx.send(StreamEvent::Token(tok));
        let reason = if ent.req.stop_tokens.contains(&tok) {
            Some(FinishReason::Stop)
        } else if ent.generated.len() >= ent.req.max_new_tokens {
            Some(FinishReason::Length)
        } else {
            None
        };
        if let Some(r) = reason {
            self.finish(i, r, now);
        }
        reason
    }

    /// Vacate rows whose cancel flag is set or whose deadline has passed.
    /// Returns `(cancelled, expired)` counts.
    pub fn sweep(&mut self, now: Instant) -> (usize, usize) {
        let (mut cancelled, mut expired) = (0, 0);
        for i in 0..self.slots.len() {
            let Some(ent) = self.slots[i].as_ref() else { continue };
            if ent.req.cancel.load(Ordering::Relaxed) {
                self.finish(i, FinishReason::Cancelled, now);
                cancelled += 1;
            } else if ent.req.deadline.is_some_and(|d| now >= d) {
                self.finish(i, FinishReason::DeadlineExpired, now);
                expired += 1;
            }
        }
        (cancelled, expired)
    }

    /// Vacate every row with `FinishReason::Error` (engine batch failure);
    /// partial tokens are delivered. Returns how many rows were failed.
    pub fn fail_all(&mut self, now: Instant) -> usize {
        let mut n = 0;
        for i in 0..self.slots.len() {
            if self.slots[i].is_some() {
                self.finish(i, FinishReason::Error, now);
                n += 1;
            }
        }
        n
    }

    fn finish(&mut self, i: usize, reason: FinishReason, now: Instant) {
        let ent = self.slots[i].take().expect("finish() on an occupied slot");
        let timing = Timing {
            queued: ent.admitted_at.saturating_duration_since(ent.req.submitted_at),
            first_token: ent
                .first_token_at
                .map(|t| t.saturating_duration_since(ent.req.submitted_at)),
            total: now.saturating_duration_since(ent.req.submitted_at),
        };
        let _ = ent.req.tx.send(StreamEvent::Done(Completion {
            tokens: ent.generated,
            finish_reason: reason,
            timing,
        }));
    }
}

/// Resolve a request that never reached a slot (expired/cancelled while
/// queued, shed at shutdown, or admitted with `max_new_tokens == 0` — which
/// completes with zero tokens rather than smuggling out the prefill token).
pub fn complete_unstarted(req: QueuedRequest, reason: FinishReason, now: Instant) {
    let timing = Timing {
        queued: now.saturating_duration_since(req.submitted_at),
        first_token: None,
        total: now.saturating_duration_since(req.submitted_at),
    };
    let _ = req.tx.send(StreamEvent::Done(Completion {
        tokens: Vec::new(),
        finish_reason: reason,
        timing,
    }));
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicBool;
    use std::sync::mpsc::{channel, Receiver};
    use std::sync::Arc;
    use std::time::Duration;

    fn mk_req(
        prompt: Vec<i32>,
        max_new: usize,
        stop: Vec<i32>,
        deadline: Option<Instant>,
    ) -> (QueuedRequest, Receiver<StreamEvent>, Arc<AtomicBool>) {
        let (tx, rx) = channel();
        let cancel = Arc::new(AtomicBool::new(false));
        let req = QueuedRequest {
            prompt,
            max_new_tokens: max_new,
            stop_tokens: stop,
            deadline,
            submitted_at: Instant::now(),
            tx,
            cancel: cancel.clone(),
        };
        (req, rx, cancel)
    }

    fn drain(rx: &Receiver<StreamEvent>) -> (Vec<i32>, Option<Completion>) {
        let mut toks = Vec::new();
        let mut done = None;
        while let Ok(ev) = rx.try_recv() {
            match ev {
                StreamEvent::Token(t) => toks.push(t),
                StreamEvent::Done(c) => done = Some(c),
            }
        }
        (toks, done)
    }

    #[test]
    fn refill_takes_lowest_free_slot() {
        let now = Instant::now();
        let mut tbl = SlotTable::new(3);
        let mut rxs = Vec::new();
        for p in 0..3 {
            let (req, rx, _) = mk_req(vec![p], 1, vec![], None);
            assert_eq!(tbl.admit(req, now), Some(p as usize));
            rxs.push(rx);
        }
        assert_eq!(tbl.free(), 0);
        let (req, _rx, _) = mk_req(vec![9], 4, vec![], None);
        assert_eq!(tbl.admit(req, now), None, "full table rejects admission");
        // finish the middle row (max_new = 1 → one token ends it)
        assert_eq!(tbl.push_token(1, 42, now), Some(FinishReason::Length));
        assert_eq!(tbl.free(), 1);
        let (req, _rx2, _) = mk_req(vec![9], 4, vec![], None);
        assert_eq!(tbl.admit(req, now), Some(1), "refill reuses the freed slot");
        assert_eq!(tbl.occupied(), vec![0, 1, 2]);
    }

    #[test]
    fn stop_token_finishes_with_stop() {
        let now = Instant::now();
        let mut tbl = SlotTable::new(1);
        let (req, rx, _) = mk_req(vec![1, 2], 10, vec![99], None);
        tbl.admit(req, now).unwrap();
        assert_eq!(tbl.push_token(0, 5, now), None);
        assert_eq!(tbl.push_token(0, 99, now), Some(FinishReason::Stop));
        let (toks, done) = drain(&rx);
        assert_eq!(toks, vec![5, 99], "stop token is streamed and included");
        let c = done.unwrap();
        assert_eq!(c.tokens, vec![5, 99]);
        assert_eq!(c.finish_reason, FinishReason::Stop);
    }

    #[test]
    fn length_cap_streams_then_completes() {
        let now = Instant::now();
        let mut tbl = SlotTable::new(1);
        let (req, rx, _) = mk_req(vec![1], 2, vec![], None);
        tbl.admit(req, now).unwrap();
        assert_eq!(tbl.push_token(0, 7, now), None);
        assert_eq!(tbl.push_token(0, 8, now), Some(FinishReason::Length));
        let (toks, done) = drain(&rx);
        assert_eq!(toks, vec![7, 8]);
        let c = done.unwrap();
        assert_eq!(c.finish_reason, FinishReason::Length);
        assert!(c.timing.first_token.is_some());
    }

    #[test]
    fn cancellation_mid_decode_vacates_with_partial_tokens() {
        let now = Instant::now();
        let mut tbl = SlotTable::new(2);
        let (req, rx, cancel) = mk_req(vec![1], 100, vec![], None);
        tbl.admit(req, now).unwrap();
        tbl.push_token(0, 3, now);
        assert_eq!(tbl.sweep(now), (0, 0), "no flags set yet");
        cancel.store(true, Ordering::Relaxed);
        assert_eq!(tbl.sweep(now), (1, 0));
        assert_eq!(tbl.active(), 0);
        let (_, done) = drain(&rx);
        let c = done.unwrap();
        assert_eq!(c.finish_reason, FinishReason::Cancelled);
        assert_eq!(c.tokens, vec![3], "partial output is delivered");
    }

    #[test]
    fn deadline_expiry_vacates_row() {
        let now = Instant::now();
        let mut tbl = SlotTable::new(1);
        let (req, rx, _) = mk_req(vec![1], 100, vec![], Some(now + Duration::from_millis(5)));
        tbl.admit(req, now).unwrap();
        assert_eq!(tbl.sweep(now), (0, 0), "deadline still in the future");
        assert_eq!(tbl.sweep(now + Duration::from_millis(6)), (0, 1));
        let (_, done) = drain(&rx);
        assert_eq!(done.unwrap().finish_reason, FinishReason::DeadlineExpired);
    }

    #[test]
    fn window_is_right_aligned_and_slides_over_generated() {
        let now = Instant::now();
        let mut tbl = SlotTable::new(1);
        let (req, _rx, _) = mk_req(vec![1, 2, 3], 100, vec![], None);
        tbl.admit(req, now).unwrap();
        assert_eq!(tbl.window(0, 5, 0), vec![0, 0, 1, 2, 3], "left-padded");
        for t in [4, 5, 6] {
            tbl.push_token(0, t, now);
        }
        // context 1,2,3,4,5,6 → keep the most recent 5
        assert_eq!(tbl.window(0, 5, 0), vec![2, 3, 4, 5, 6]);
        assert_eq!(tbl.feed_tokens(0), vec![6]);
        // free rows window/feed as pure padding
        let tbl2 = SlotTable::new(2);
        assert_eq!(tbl2.window(1, 3, 0), vec![0, 0, 0]);
        assert_eq!(tbl2.feed_tokens(0), vec![0, 0]);
    }

    #[test]
    fn complete_unstarted_delivers_empty_completion() {
        let (req, rx, _) = mk_req(vec![1, 2], 0, vec![], None);
        complete_unstarted(req, FinishReason::Length, Instant::now());
        let (toks, done) = drain(&rx);
        assert!(toks.is_empty());
        let c = done.unwrap();
        assert!(c.tokens.is_empty(), "max_new_tokens == 0 yields no prefill token");
        assert_eq!(c.finish_reason, FinishReason::Length);
    }
}
