//! Bounded admission queue for the serving pool: two priority bands (high
//! drains before normal, FIFO within a band), a hard capacity that surfaces
//! backpressure to callers instead of buffering unboundedly, and condvar
//! parking so idle workers block instead of spinning.

use crate::serve::sync::{Condvar, LockRank, Mutex};
use std::collections::VecDeque;

/// Why a non-blocking `push` did not enqueue. The item is handed back so the
/// caller can resolve it (e.g. complete the request with an error).
#[derive(Debug)]
pub enum PushError<T> {
    /// Capacity reached — the caller should shed load or retry later.
    Full(T),
    /// `close()` was called; the queue accepts nothing more.
    Closed(T),
}

struct Inner<T> {
    high: VecDeque<T>,
    normal: VecDeque<T>,
    closed: bool,
}

impl<T> Inner<T> {
    fn len(&self) -> usize {
        self.high.len() + self.normal.len()
    }

    fn pop(&mut self) -> Option<T> {
        self.high.pop_front().or_else(|| self.normal.pop_front())
    }
}

/// MPMC bounded queue shared by the submit side and all engine workers.
pub struct BoundedQueue<T> {
    inner: Mutex<Inner<T>>,
    cv: Condvar,
    capacity: usize,
}

impl<T> BoundedQueue<T> {
    pub fn new(capacity: usize) -> Self {
        Self {
            inner: Mutex::new(
                LockRank::QueueInner,
                Inner { high: VecDeque::new(), normal: VecDeque::new(), closed: false },
            ),
            cv: Condvar::new(),
            capacity: capacity.max(1),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.inner.lock_or_poisoned().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Non-blocking enqueue; never waits for space (bounded = explicit
    /// backpressure, not hidden latency).
    pub fn push(&self, item: T, high_priority: bool) -> Result<(), PushError<T>> {
        let mut inner = self.inner.lock_or_poisoned();
        if inner.closed {
            return Err(PushError::Closed(item));
        }
        if inner.len() >= self.capacity {
            return Err(PushError::Full(item));
        }
        if high_priority {
            inner.high.push_back(item);
        } else {
            inner.normal.push_back(item);
        }
        drop(inner);
        self.cv.notify_one();
        Ok(())
    }

    /// Pop without blocking (used by workers topping up free slots between
    /// decode steps).
    pub fn try_pop(&self) -> Option<T> {
        self.inner.lock_or_poisoned().pop()
    }

    /// Pop from the high band only, without blocking. Chunked admission
    /// uses this to let High-priority work bypass the per-boundary
    /// `join_chunk` cap that paces Normal admissions.
    pub fn try_pop_high(&self) -> Option<T> {
        self.inner.lock_or_poisoned().high.pop_front()
    }

    /// Block until an item is available. `None` means the queue was closed
    /// and fully drained — the worker should exit.
    pub fn pop_blocking(&self) -> Option<T> {
        let mut inner = self.inner.lock_or_poisoned();
        loop {
            if let Some(item) = inner.pop() {
                return Some(item);
            }
            if inner.closed {
                return None;
            }
            inner = self.cv.wait(inner);
        }
    }

    /// Re-enqueue a salvaged in-flight item at the *front* of the high band,
    /// exempt from the capacity check: the item already held queue capacity
    /// when it was first admitted, so bouncing it on `Full` would turn a
    /// worker fault into load shedding. Front-of-band keeps redispatch
    /// latency minimal (high pops first and is never chunk-limited). Fails
    /// only when the queue is closed — the caller then resolves the request
    /// itself (typed error completion) instead of losing it.
    pub fn requeue(&self, item: T) -> Result<(), PushError<T>> {
        let mut inner = self.inner.lock_or_poisoned();
        if inner.closed {
            return Err(PushError::Closed(item));
        }
        inner.high.push_front(item);
        drop(inner);
        self.cv.notify_one();
        Ok(())
    }

    /// Remove and return every queued item matching `pred`, freeing its
    /// capacity immediately (cancelled/expired requests must not block
    /// admission while they wait for a pop). Order within bands is kept.
    pub fn drain_where(&self, pred: impl FnMut(&T) -> bool) -> Vec<T> {
        let mut out = Vec::new();
        self.drain_where_into(pred, &mut out);
        out
    }

    /// Allocation-free `drain_where`: matches are appended to `out` (which
    /// the caller reuses across calls), survivors stay in band order. The
    /// engine's decode loop calls this every shed sweep, so it must not
    /// touch the heap when nothing matches — each band is rotated in place
    /// through its existing ring buffer instead of rebuilt.
    pub fn drain_where_into(&self, mut pred: impl FnMut(&T) -> bool, out: &mut Vec<T>) {
        let mut guard = self.inner.lock_or_poisoned();
        let inner = &mut *guard;
        for band in [&mut inner.high, &mut inner.normal] {
            // One full rotation: pop each item once; survivors go to the
            // back, so after `len` steps the band holds exactly the
            // survivors in their original relative order.
            for _ in 0..band.len() {
                let Some(item) = band.pop_front() else { break };
                if pred(&item) {
                    out.push(item);
                } else {
                    band.push_back(item);
                }
            }
        }
    }

    /// Close the queue, waking every parked worker, and hand back whatever
    /// was still enqueued so the caller can resolve those requests.
    pub fn close(&self) -> Vec<T> {
        let mut inner = self.inner.lock_or_poisoned();
        inner.closed = true;
        let mut left: Vec<T> = inner.high.drain(..).collect();
        left.extend(inner.normal.drain(..));
        drop(inner);
        self.cv.notify_all();
        left
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn full_is_surfaced_at_capacity() {
        let q = BoundedQueue::new(2);
        q.push(1, false).unwrap();
        q.push(2, false).unwrap();
        match q.push(3, false) {
            Err(PushError::Full(3)) => {}
            other => panic!("expected Full(3), got {other:?}"),
        }
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn fifo_within_priority_band() {
        let q = BoundedQueue::new(8);
        for i in 0..4 {
            q.push(i, false).unwrap();
        }
        assert_eq!(
            (0..4).map(|_| q.try_pop().unwrap()).collect::<Vec<_>>(),
            vec![0, 1, 2, 3]
        );
    }

    #[test]
    fn high_band_drains_before_normal() {
        let q = BoundedQueue::new(8);
        q.push("n1", false).unwrap();
        q.push("h1", true).unwrap();
        q.push("n2", false).unwrap();
        q.push("h2", true).unwrap();
        let order: Vec<_> = (0..4).map(|_| q.try_pop().unwrap()).collect();
        assert_eq!(order, vec!["h1", "h2", "n1", "n2"]);
    }

    #[test]
    fn try_pop_high_skips_the_normal_band() {
        let q = BoundedQueue::new(8);
        q.push("n1", false).unwrap();
        q.push("h1", true).unwrap();
        q.push("h2", true).unwrap();
        assert_eq!(q.try_pop_high(), Some("h1"), "FIFO within the high band");
        assert_eq!(q.try_pop_high(), Some("h2"));
        assert_eq!(q.try_pop_high(), None, "normal entries are not visible");
        assert_eq!(q.len(), 1);
        assert_eq!(q.try_pop(), Some("n1"));
    }

    #[test]
    fn try_pop_high_after_close_is_none() {
        let q = BoundedQueue::new(4);
        q.push("h", true).unwrap();
        q.push("n", false).unwrap();
        assert_eq!(q.close(), vec!["h", "n"], "close hands everything back");
        assert_eq!(q.try_pop_high(), None, "the high band was drained by close");
        assert_eq!(q.try_pop(), None);
        assert!(q.is_empty());
        // a second close stays empty and is harmless
        assert!(q.close().is_empty());
        assert_eq!(q.try_pop_high(), None);
    }

    #[test]
    fn close_drains_and_unblocks() {
        let q = Arc::new(BoundedQueue::new(4));
        q.push(7, false).unwrap();
        q.push(8, true).unwrap();
        let (ack_tx, ack_rx) = std::sync::mpsc::channel();
        let waiter = {
            let q = q.clone();
            std::thread::spawn(move || {
                // drain the two queued items, then park until close
                let mut got = vec![q.pop_blocking().unwrap(), q.pop_blocking().unwrap()];
                got.sort();
                assert_eq!(got, vec![7, 8]);
                ack_tx.send(()).unwrap();
                q.pop_blocking()
            })
        };
        ack_rx.recv().unwrap(); // queue is drained; waiter is parking
        let left = q.close();
        assert!(left.is_empty(), "waiter already drained the queue");
        assert_eq!(waiter.join().unwrap(), None, "parked pop wakes as None on close");
        match q.push(9, false) {
            Err(PushError::Closed(9)) => {}
            other => panic!("expected Closed(9), got {other:?}"),
        }
    }

    #[test]
    fn close_returns_leftovers_high_first() {
        let q = BoundedQueue::new(4);
        q.push("n", false).unwrap();
        q.push("h", true).unwrap();
        assert_eq!(q.close(), vec!["h", "n"]);
        assert!(q.is_empty());
    }

    #[test]
    fn drain_where_frees_capacity_and_keeps_order() {
        let q = BoundedQueue::new(4);
        q.push(1, false).unwrap();
        q.push(2, false).unwrap();
        q.push(3, true).unwrap();
        q.push(4, false).unwrap();
        match q.push(5, false) {
            Err(PushError::Full(5)) => {}
            other => panic!("expected Full(5), got {other:?}"),
        }
        let dead = q.drain_where(|&x| x % 2 == 0);
        assert_eq!(dead, vec![2, 4]);
        assert_eq!(q.len(), 2);
        q.push(5, false).unwrap(); // capacity freed immediately
        assert_eq!(q.try_pop(), Some(3), "high band survivor first");
        assert_eq!(q.try_pop(), Some(1));
        assert_eq!(q.try_pop(), Some(5));
    }

    #[test]
    fn drain_where_into_reuses_the_caller_buffer() {
        let q = BoundedQueue::new(8);
        for i in 0..6 {
            q.push(i, i == 3).unwrap(); // 3 rides the high band
        }
        let mut scratch: Vec<i32> = Vec::with_capacity(8);
        q.drain_where_into(|&x| x % 2 == 1, &mut scratch);
        assert_eq!(scratch, vec![3, 1, 5], "high-band match first, then normal in order");
        assert!(scratch.capacity() >= 8, "matches landed in the caller's buffer");
        scratch.clear();
        q.drain_where_into(|_| false, &mut scratch);
        assert!(scratch.is_empty());
        assert_eq!(
            (0..3).map(|_| q.try_pop().unwrap()).collect::<Vec<_>>(),
            vec![0, 2, 4],
            "survivors keep band order across both sweeps"
        );
    }

    #[test]
    fn requeue_jumps_the_line_and_ignores_capacity() {
        let q = BoundedQueue::new(2);
        q.push("n1", false).unwrap();
        q.push("h1", true).unwrap();
        assert!(matches!(q.push("n2", false), Err(PushError::Full("n2"))));
        q.requeue("salvaged").unwrap(); // full queue still accepts it
        assert_eq!(q.len(), 3);
        assert_eq!(q.try_pop(), Some("salvaged"), "front of the high band");
        assert_eq!(q.try_pop(), Some("h1"));
        assert_eq!(q.try_pop(), Some("n1"));
        q.close();
        assert!(matches!(q.requeue("late"), Err(PushError::Closed("late"))));
    }

    #[test]
    fn pop_blocking_wakes_on_push() {
        let q = Arc::new(BoundedQueue::new(4));
        let waiter = {
            let q = q.clone();
            std::thread::spawn(move || q.pop_blocking())
        };
        std::thread::sleep(Duration::from_millis(10));
        q.push(42, false).unwrap();
        assert_eq!(waiter.join().unwrap(), Some(42));
    }
}
