//! `cola` — the launcher CLI for the CoLA training/serving runtime.
//!
//! Subcommands:
//!   train     train an artifact (e.g. --artifact p60m_cola steps=400)
//!   eval      evaluate validation perplexity of a checkpoint
//!   serve     run a load generator against the serving tier
//!             (`ModelRouter` → named `ServicePool`s: continuous batching,
//!             streaming, bounded admission queues, KV prefix caching).
//!             Flags: --requests N, --config file.json, --model NAME
//!             (restrict load to one model), --mock (hermetic MockBackend
//!             smoke with a repeated-prefix workload — no artifact needed;
//!             add --distinct D for prompt variety, --chaos for a seeded
//!             fault-injection soak proving transparent redispatch, worker
//!             restart, and circuit-breaker recovery, and --bench-json PATH
//!             to record a BENCH_serve.json line); key=value overrides:
//!             artifact, max_new_tokens, workers, queue_depth,
//!             default_deadline_ms, kv_cache_entries, kv_cache_bytes,
//!             kv_codec (f32|f16|rankr), kv_rank, join_chunk, retry_budget,
//!             restart_budget, breaker_open_after, breaker_recover_after,
//!             breaker_cooldown_ms, models=name:artifact,... and
//!             name.key=value per model.
//!             Prints per-model p50/p95/p99 latency, time-to-first-token,
//!             and labeled queue/counter/prefill-cache stats plus a fleet
//!             aggregate.
//!   rank      activation-spectrum analysis (Fig. 2) on an artifact
//!   cost      print the analytic paper tables (2/3/4, Fig 5/6/7 data)
//!   data-gen  pre-build the corpus + BPE tokenizer caches
//!   lint      whole-crate static analysis: per-file convention rules plus
//!             interprocedural lock-graph and hot-path allocation passes
//!             (`--format json`, `--baseline`, `--dump-lock-graph`)
//!
//! Config values are `key=value` pairs after flags; `train` and `serve`
//! both accept `--config file.json` plus overrides (see config::TrainConfig
//! / config::ServeConfig).

use anyhow::{Context, Result};
use cola::config::{apply_train_overrides, load_router_config, TrainConfig};
use cola::coordinator::Trainer;
use cola::costmodel::{tables, PaperPreset, PAPER_PRESETS};
use cola::data::{corpus::CorpusCfg, CorpusGen};
use cola::metrics;
use cola::metrics::{fmt_ms, percentile};
use cola::serve::{ModelRouter, RouteError, SubmitError, SubmitOptions};

fn usage() -> ! {
    eprintln!(
        "usage: cola <train|eval|serve|rank|cost|data-gen|lint> [--artifact NAME] [key=value ...]\n\
         serve: cola serve [--artifact NAME] [--requests N] [--config f.json] [--model NAME]\n\
                [--mock] [--distinct D] [--chaos] [--bench-json PATH]\n\
                [max_new_tokens=K] [workers=N] [queue_depth=D] [default_deadline_ms=MS]\n\
                [kv_cache_entries=E] [kv_cache_bytes=B] [kv_codec=f32|f16|rankr]\n\
                [kv_rank=R] [join_chunk=J] [retry_budget=R] [restart_budget=R]\n\
                [breaker_open_after=N] [breaker_recover_after=N] [breaker_cooldown_ms=MS]\n\
                [models=name:artifact,...] [name.key=value ...]\n\
                --chaos (with --mock): seeded fault soak — injected decode/prefill\n\
                errors, latency spikes, and a worker panic must lose zero requests,\n\
                keep streams byte-identical, and recover the circuit breaker\n\
         lint:  cola lint [--root DIR] [--format text|json] [--baseline FILE]\n\
                [--write-baseline FILE] [--dump-lock-graph]\n\
                whole-crate static concurrency/safety checks over rust/src (strict)\n\
                and rust/tests (relaxed profile); interprocedural lock-graph and\n\
                hot-path passes included (rule codes, waiver syntax, baseline\n\
                workflow: docs/concurrency.md); exits 1 on non-baselined findings\n\
         run `cola cost` for the analytic paper tables; `cola serve --mock` needs no\n\
         artifacts; `make artifacts` first for the rest."
    );
    std::process::exit(2);
}

/// Split argv into (flags map, key=value overrides).
fn parse_args(
    args: &[String],
) -> (std::collections::HashMap<String, String>, Vec<(String, String)>) {
    let mut flags = std::collections::HashMap::new();
    let mut kvs = Vec::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(name) = a.strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") && !args[i + 1].contains('=') {
                flags.insert(name.to_string(), args[i + 1].clone());
                i += 2;
                continue;
            }
            flags.insert(name.to_string(), "true".to_string());
        } else if let Some((k, v)) = a.split_once('=') {
            kvs.push((k.to_string(), v.to_string()));
        } else {
            eprintln!("unrecognized argument `{a}`");
            usage();
        }
        i += 1;
    }
    (flags, kvs)
}

fn train_cfg(
    flags: &std::collections::HashMap<String, String>,
    kvs: &[(String, String)],
) -> Result<TrainConfig> {
    let mut cfg = TrainConfig::default();
    if let Some(a) = flags.get("artifact") {
        cfg.artifact = a.clone();
    }
    apply_train_overrides(&mut cfg, kvs)?;
    Ok(cfg)
}

fn cmd_train(
    flags: std::collections::HashMap<String, String>,
    kvs: Vec<(String, String)>,
) -> Result<()> {
    let cfg = train_cfg(&flags, &kvs)?;
    let mut tr = Trainer::new(cfg)?;
    let report = tr.run()?;
    println!(
        "done: {} steps={} loss={:.4} val_ppl={:.3} {:.0} tok/s peak_rss={:.2} GB",
        report.artifact,
        report.steps,
        report.final_loss,
        report.val_ppl,
        report.tokens_per_sec,
        report.peak_rss_bytes as f64 / 1e9
    );
    Ok(())
}

fn cmd_eval(
    flags: std::collections::HashMap<String, String>,
    kvs: Vec<(String, String)>,
) -> Result<()> {
    let cfg = train_cfg(&flags, &kvs)?;
    let mut tr = Trainer::new(cfg)?;
    if let Some(ckpt) = flags.get("checkpoint") {
        tr.load_checkpoint(std::path::Path::new(ckpt))?;
    }
    let ppl = tr.evaluate(16)?;
    println!("val_ppl={ppl:.3}");
    Ok(())
}

/// Load generator against the serving tier: brings up a `ModelRouter` (one
/// pool per configured model), round-robins `--requests` prompts across the
/// targeted models with queue backpressure (retrying on `QueueFull`), then
/// reports per-model latency percentiles, time-to-first-token, and labeled
/// counter stats plus a fleet aggregate. `--model NAME` restricts the load
/// to one model.
fn cmd_serve(
    flags: std::collections::HashMap<String, String>,
    kvs: Vec<(String, String)>,
) -> Result<()> {
    // precedence for pool defaults (last wins): built-ins < --config file
    // plain keys < --artifact < key=value; each model then layers its own
    // file stanza and `name.key=value` overrides on top of those defaults
    // (see config::load_router_config)
    let mut all_kvs = Vec::new();
    if let Some(a) = flags.get("artifact") {
        all_kvs.push(("artifact".to_string(), a.clone()));
    }
    all_kvs.extend(kvs);
    let rcfg = load_router_config(flags.get("config").map(std::path::Path::new), &all_kvs)?;
    let models = rcfg.resolved_models();
    let n_requests: usize = flags.get("requests").map(|s| s.parse()).transpose()?.unwrap_or(16);

    if flags.contains_key("mock") {
        // --model restricts the smoke exactly like the artifact path (and a
        // typoed name must fail loudly, not silently drive every model)
        let targeted: Vec<(String, cola::config::ServeConfig)> = match flags.get("model") {
            Some(m) => match models.iter().find(|(n, _)| n == m) {
                Some(found) => vec![found.clone()],
                None => anyhow::bail!(
                    "--model `{m}` is not configured (models: {})",
                    models.iter().map(|(n, _)| n.as_str()).collect::<Vec<_>>().join(", ")
                ),
            },
            None => models,
        };
        return cmd_serve_mock(&flags, &targeted, n_requests);
    }

    // which models the load generator drives (the router serves them all)
    let targets: Vec<String> = match flags.get("model") {
        Some(m) => {
            anyhow::ensure!(
                models.iter().any(|(n, _)| n == m),
                "--model `{m}` is not configured (models: {})",
                models.iter().map(|(n, _)| n.as_str()).collect::<Vec<_>>().join(", ")
            );
            vec![m.clone()]
        }
        None => models.iter().map(|(n, _)| n.clone()).collect(),
    };
    for (name, cfg) in &models {
        anyhow::ensure!(
            cfg.workers > 0 || !targets.contains(name),
            "model `{name}` needs workers >= 1 (workers=0 is admission-only)"
        );
    }

    let router = ModelRouter::start(&rcfg)?;
    // per-model tokenizer (vocab comes from each artifact's manifest)
    let mut encoders = Vec::new();
    for name in &targets {
        let cfg = &models.iter().find(|(n, _)| n == name).unwrap().1;
        let vocab =
            cola::runtime::ArtifactDir::open_named(&cfg.artifact)?.manifest.preset.vocab;
        encoders.push(cola::coordinator::trainer::shared_bpe(vocab)?);
    }
    let mut gen = CorpusGen::new(CorpusCfg::default());

    if n_requests > 0 {
        // warmup: compiles each target's prefill+decode before timing starts
        for (name, bpe) in targets.iter().zip(&encoders) {
            let opts = SubmitOptions { max_new_tokens: Some(2), ..Default::default() };
            router.generate(name, bpe.encode(&gen.text(40)), opts)?;
        }
    }

    let t0 = std::time::Instant::now();
    let mut streams: Vec<(usize, cola::serve::TokenStream)> = Vec::new();
    let (mut retries, mut max_queue) = (0u64, 0usize);
    for r in 0..n_requests {
        let which = r % targets.len();
        let prompt = encoders[which].encode(&gen.text(60));
        loop {
            match router.submit(&targets[which], prompt.clone(), SubmitOptions::default()) {
                Ok(s) => break streams.push((which, s)),
                Err(RouteError::Submit(SubmitError::QueueFull)) => {
                    // bounded queue pushed back: wait for capacity
                    retries += 1;
                    std::thread::sleep(std::time::Duration::from_millis(1));
                }
                Err(e) => anyhow::bail!("submit failed: {e}"),
            }
        }
        max_queue = max_queue.max(router.aggregate_stats().queue_depth);
    }
    // per-target sample sets
    let mut tokens = vec![0usize; targets.len()];
    let mut lat = vec![Vec::new(); targets.len()];
    let mut ttft = vec![Vec::new(); targets.len()];
    for (which, s) in streams {
        let c = s.wait()?;
        tokens[which] += c.tokens.len();
        lat[which].push(c.timing.total.as_secs_f64() * 1000.0);
        if let Some(t) = c.timing.first_token {
            ttft[which].push(t.as_secs_f64() * 1000.0);
        }
    }
    let secs = t0.elapsed().as_secs_f64();
    let total_tokens: usize = tokens.iter().sum();
    let agg = router.aggregate_stats();
    println!(
        "served {n_requests} requests across {} model(s), {total_tokens} tokens in {secs:.2}s \
         ({:.0} tok/s wall, {:.0} tok/s decode)",
        targets.len(),
        total_tokens as f64 / secs.max(1e-9),
        agg.decode_tokens_per_sec
    );
    for (i, name) in targets.iter().enumerate() {
        let label = [("model", name.as_str())];
        println!(
            "{}: requests={} tokens={} | latency p50={} p95={} p99={} | ttft p50={} p99={}",
            metrics::fmt_labels(&label),
            lat[i].len(),
            tokens[i],
            fmt_ms(percentile(&lat[i], 50.0)),
            fmt_ms(percentile(&lat[i], 95.0)),
            fmt_ms(percentile(&lat[i], 99.0)),
            fmt_ms(percentile(&ttft[i], 50.0)),
            fmt_ms(percentile(&ttft[i], 99.0)),
        );
    }
    for (name, s) in router.stats_by_model() {
        let label = [("model", name)];
        println!(
            "{} {} {} {} {}",
            metrics::stat_line("serve_submitted", &label, s.submitted),
            metrics::stat_line("serve_completed", &label, s.completed),
            metrics::stat_line("serve_cancelled", &label, s.cancelled),
            metrics::stat_line("serve_expired", &label, s.expired),
            metrics::stat_line("serve_rejected", &label, s.rejected),
        );
        println!(
            "{} {} {} {} {}",
            metrics::stat_line("serve_prefill_calls", &label, s.prefill_calls),
            metrics::stat_line("serve_prefills_elided", &label, s.prefills_elided),
            metrics::stat_line("serve_kv_cache_hits", &label, s.kv_cache_hits),
            metrics::stat_line("serve_kv_cache_misses", &label, s.kv_cache_misses),
            metrics::stat_line("serve_kv_cache_evictions", &label, s.kv_cache_evictions),
        );
        println!(
            "{} {} {}",
            metrics::stat_line("serve_kv_bytes_resident", &label, s.kv_bytes_resident),
            metrics::stat_line("serve_kv_bytes_saved", &label, s.kv_bytes_saved),
            metrics::stat_line("serve_kv_decode_nanos", &label, s.kv_decode_nanos),
        );
        println!(
            "{} {} {} {}",
            metrics::stat_line("serve_rows_joined_midflight", &label, s.rows_joined_midflight),
            metrics::stat_line("serve_partial_prefix_hits", &label, s.partial_prefix_hits),
            metrics::stat_line(
                "serve_partial_prefix_tokens_saved",
                &label,
                s.partial_prefix_tokens_saved
            ),
            metrics::stat_line("serve_join_wait_nanos", &label, s.join_wait_nanos),
        );
        println!(
            "{} {} {} {} {}",
            metrics::stat_line("serve_worker_restarts", &label, s.worker_restarts),
            metrics::stat_line("serve_worker_panics", &label, s.worker_panics),
            metrics::stat_line("serve_requests_redispatched", &label, s.requests_redispatched),
            metrics::stat_line("serve_retries", &label, s.retries),
            metrics::stat_line("serve_failed", &label, s.failed),
        );
        println!(
            "{} {} {} {} {}",
            metrics::stat_line("serve_shed_infeasible", &label, s.shed_infeasible),
            metrics::stat_line("serve_shed_expired", &label, s.shed_expired),
            metrics::stat_line("serve_breaker_state", &label, s.breaker_state.as_str()),
            metrics::stat_line("serve_breaker_opens", &label, s.breaker_opens),
            metrics::stat_line("serve_breaker_recoveries", &label, s.breaker_recoveries),
        );
    }
    println!(
        "queue: peak depth {max_queue}/{} full-retries {retries} | \
         submitted={} completed={} cancelled={} expired={} rejected={}",
        agg.queue_capacity, agg.submitted, agg.completed, agg.cancelled, agg.expired, agg.rejected
    );
    println!(
        "prefill: {} real ({:.1}ms avg) + {} elided ({} of boundaries) | \
         kv cache: hit rate {} evictions {}",
        agg.prefill_calls,
        if agg.prefill_calls > 0 {
            agg.prefill_nanos as f64 / agg.prefill_calls as f64 * 1e-6
        } else {
            0.0
        },
        agg.prefills_elided,
        metrics::fmt_pct(agg.prefills_elided, agg.prefill_calls + agg.prefills_elided),
        metrics::fmt_pct(agg.kv_cache_hits, agg.kv_cache_hits + agg.kv_cache_misses),
        agg.kv_cache_evictions,
    );
    println!(
        "kv bytes: resident {} saved {} (codec vs f32) | cached-row decode {:.2}ms total",
        agg.kv_bytes_resident,
        agg.kv_bytes_saved,
        agg.kv_decode_nanos as f64 * 1e-6,
    );
    println!(
        "joins: {} mid-flight | partial-prefix hits {} ({} window tokens re-used) | \
         admission->live wait {:.2}ms total",
        agg.rows_joined_midflight,
        agg.partial_prefix_hits,
        agg.partial_prefix_tokens_saved,
        agg.join_wait_nanos as f64 * 1e-6,
    );
    println!(
        "robustness: restarts {} (panics {}) redispatched {} retries {} failed {} | \
         shed infeasible {} expired {} | breaker {} (opens {} recoveries {})",
        agg.worker_restarts,
        agg.worker_panics,
        agg.requests_redispatched,
        agg.retries,
        agg.failed,
        agg.shed_infeasible,
        agg.shed_expired,
        agg.breaker_state.as_str(),
        agg.breaker_opens,
        agg.breaker_recoveries,
    );
    router.shutdown();
    Ok(())
}

/// Hermetic serving smoke (`cola serve --mock`): the same `ModelRouter` →
/// `ServicePool` surface over deterministic `MockBackend` pools — no
/// artifact, no tokenizer — driven with a repeated-prefix workload that
/// exercises prefill avoidance. Runs the workload twice, prefix cache on
/// then off, proves the streamed outputs are byte-identical, reports the
/// prefill/elision counters, then adds the per-row-engine proofs: a
/// mixed-length shared-system-prompt workload must produce partial-prefix
/// hits (again byte-identical cache on/off), and an occupancy sweep pins
/// joining-row TTFT as O(1) in batch occupancy (one prefill per join, ratio
/// gate ≤ 1.5×). With `--bench-json PATH` it records a one-line JSON
/// benchmark so CI can track the serving perf trajectory.
fn cmd_serve_mock(
    flags: &std::collections::HashMap<String, String>,
    models: &[(String, cola::config::ServeConfig)],
    n_requests: usize,
) -> Result<()> {
    use cola::serve::{FinishReason, MockBackend, ServicePool, ServiceStats};
    let distinct: usize =
        flags.get("distinct").map(|s| s.parse()).transpose()?.unwrap_or(4).max(1);
    for (name, cfg) in models {
        anyhow::ensure!(cfg.workers > 0, "model `{name}` needs workers >= 1 for --mock");
    }
    // 2ms real-prefill latency makes elision visible in wall-clock numbers;
    // decode itself is free, so tokens/s contrasts the prefill paths.
    let mock = MockBackend::new(4, 8, 24)
        .vocab(50_021)
        .prefill_delay(std::time::Duration::from_millis(2));
    // deterministic synthetic prompts, recycled every `distinct` requests —
    // the repeated prefixes (system prompts / retries) the KV cache targets
    let prompts: Vec<Vec<i32>> =
        (0..distinct).map(|d| (0..6).map(|j| 100 + 17 * d as i32 + j).collect()).collect();

    let run = |mutate: &dyn Fn(&mut cola::config::ServeConfig),
               workload: &[Vec<i32>]|
     -> Result<(Vec<Vec<i32>>, ServiceStats, f64)> {
        let mut pools = Vec::new();
        for (name, cfg) in models {
            let mut cfg = cfg.clone();
            mutate(&mut cfg);
            pools.push((name.clone(), ServicePool::start_with(cfg, mock.clone().factory())?));
        }
        let router = ModelRouter::from_pools(pools)?;
        let t0 = std::time::Instant::now();
        let mut outs = Vec::with_capacity(n_requests);
        for r in 0..n_requests {
            let name = &models[r % models.len()].0;
            let prompt = workload[r % workload.len()].clone();
            let c = router.generate(name, prompt, SubmitOptions::default())?;
            anyhow::ensure!(
                matches!(c.finish_reason, FinishReason::Length | FinishReason::Stop),
                "mock request {r} ended with {:?}",
                c.finish_reason
            );
            outs.push(c.tokens);
        }
        // nanos → f64 seconds with a floor: sub-resolution runs must never
        // divide by zero and record a spurious 0 tok/s in BENCH_serve.json
        let secs = (t0.elapsed().as_nanos() as f64 / 1e9).max(1e-9);
        let agg = router.aggregate_stats();
        router.shutdown();
        Ok((outs, agg, secs))
    };

    let (outs_on, on, secs_on) = run(&|_| {}, &prompts)?;
    let (outs_off, off, secs_off) = run(&|c| c.kv_cache_entries = 0, &prompts)?;
    anyhow::ensure!(
        outs_on == outs_off,
        "prefix cache changed streamed outputs — elision is broken"
    );

    let tokens: usize = outs_on.iter().map(Vec::len).sum();
    let boundaries = on.prefill_calls + on.prefills_elided;
    let lookups = on.kv_cache_hits + on.kv_cache_misses;
    println!(
        "mock smoke: {n_requests} requests x {} model(s), {distinct} distinct prompt(s), \
         {tokens} tokens",
        models.len()
    );
    println!(
        "  cache on : {:.0} tok/s wall | prefills {} real + {} elided ({} of {} boundaries)",
        tokens as f64 / secs_on,
        on.prefill_calls,
        on.prefills_elided,
        metrics::fmt_pct(on.prefills_elided, boundaries),
        boundaries,
    );
    println!(
        "  cache off: {:.0} tok/s wall | prefills {} real (baseline, outputs identical)",
        tokens as f64 / secs_off,
        off.prefill_calls,
    );
    println!(
        "  kv cache: {} hits / {} lookups ({}) | misses {} evictions {}",
        on.kv_cache_hits,
        lookups,
        metrics::fmt_pct(on.kv_cache_hits, lookups),
        on.kv_cache_misses,
        on.kv_cache_evictions,
    );

    // The perf gate CI relies on: with repeated prefixes and the cache
    // enabled, at least half of all join boundaries must avoid the real
    // prefill (ISSUE 5 acceptance). Only meaningful when the run is big
    // enough that warm-up misses cannot dominate.
    let cache_enabled = models.iter().all(|(_, c)| c.kv_cache_entries > 0);
    if cache_enabled && n_requests >= 2 * distinct * models.len() {
        anyhow::ensure!(
            2 * on.prefills_elided >= boundaries,
            "prefill avoidance regressed: only {} of {} boundaries elided",
            on.prefills_elided,
            boundaries
        );
    }

    // Fixed-memory codec comparison: rerun the same workload three times
    // under one shared byte budget sized so the lossless f32 codec can hold
    // only ~2.5 entries — the compressed codecs fit more windows into the
    // same bytes, which shows up directly as hit rate. Encoded entry sizes
    // are data-independent, so a zero row prices each codec exactly.
    use cola::serve::engine::EngineBackend;
    use cola::serve::{kvcodec, KvCodec, KvCodecKind, KvRowState};
    let geom = mock.kv_row_geom();
    let zero = KvRowState { k: vec![0.0; geom.elems()], v: vec![0.0; geom.elems()] };
    let codecs: [(KvCodecKind, usize, KvCodec); 3] = [
        (KvCodecKind::F32, 0, KvCodec::F32),
        (KvCodecKind::F16, 0, KvCodec::F16),
        (KvCodecKind::RankR, 3, KvCodec::RankR { rank: 3 }),
    ];
    let mut entry_bytes = [0u64; 3];
    for (i, (_, _, codec)) in codecs.iter().enumerate() {
        entry_bytes[i] = kvcodec::encode_row(&zero, *codec, geom)?.encoded_bytes();
    }
    let budget = entry_bytes[0] * 5 / 2;
    let mut fixed_mem = [(0.0f64, 0u64, 0u64); 3]; // (hit rate, bytes resident, bytes saved)
    if cache_enabled {
        for (i, (kind, rank, _)) in codecs.iter().enumerate() {
            let (outs, s, _) = run(
                &|c| {
                    c.kv_cache_bytes = budget as usize;
                    c.kv_codec = *kind;
                    c.kv_rank = *rank;
                },
                &prompts,
            )?;
            anyhow::ensure!(
                outs == outs_on,
                "kv_codec={} changed streamed outputs under a byte budget",
                kind.as_str()
            );
            let looks = s.kv_cache_hits + s.kv_cache_misses;
            fixed_mem[i] = (
                if looks > 0 { s.kv_cache_hits as f64 / looks as f64 } else { 0.0 },
                s.kv_bytes_resident,
                s.kv_bytes_saved,
            );
            println!(
                "  fixed mem ({budget} B): codec {:<5} {:>5} B/entry | hit rate {:.0}% | \
                 resident {} B saved {} B",
                kind.as_str(),
                entry_bytes[i],
                fixed_mem[i].0 * 100.0,
                fixed_mem[i].1,
                fixed_mem[i].2,
            );
        }
        // Compressed codecs must never do worse than f32 at equal memory —
        // and with enough distinct prompts to thrash the f32 budget they
        // must do strictly better (that is the point of the codecs).
        anyhow::ensure!(
            fixed_mem[1].0 >= fixed_mem[0].0 && fixed_mem[2].0 >= fixed_mem[0].0,
            "compressed codecs lost hit rate at fixed memory: f32 {:.2} f16 {:.2} rankr {:.2}",
            fixed_mem[0].0,
            fixed_mem[1].0,
            fixed_mem[2].0
        );
        if distinct >= 3 && n_requests >= 2 * distinct * models.len() {
            anyhow::ensure!(
                fixed_mem[1].0 > fixed_mem[0].0 && fixed_mem[2].0 > fixed_mem[0].0,
                "compression bought no hit rate at fixed memory: f32 {:.2} f16 {:.2} rankr {:.2}",
                fixed_mem[0].0,
                fixed_mem[1].0,
                fixed_mem[2].0
            );
        }
    }

    // Partial-prefix workload: every prompt opens with the same 4-token
    // system prefix (= the engine's prefix-chunk size at prompt_len 8) but
    // continues with tails of *different lengths*, so whole-window lookups
    // miss while the shared chunk hits — the mixed-length
    // shared-system-prompt case the chunked prefix chain exists for. Run it
    // cache on and off: streams must stay byte-identical, and with the
    // cache on the misses must recover the shared prefix.
    let sys = [900, 901, 902, 903];
    let pp_prompts: Vec<Vec<i32>> = (0..distinct)
        .map(|d| {
            let mut p = sys.to_vec();
            p.extend((0..1 + d % 3).map(|j| 950 + 10 * d as i32 + j as i32));
            p
        })
        .collect();
    let (pp_outs_on, pp, _) = run(&|_| {}, &pp_prompts)?;
    let (pp_outs_off, _, _) = run(&|c| c.kv_cache_entries = 0, &pp_prompts)?;
    anyhow::ensure!(
        pp_outs_on == pp_outs_off,
        "partial-prefix reuse changed streamed outputs — tail prefill is broken"
    );
    let pp_hit_rate = if pp.kv_cache_misses > 0 {
        pp.partial_prefix_hits as f64 / pp.kv_cache_misses as f64
    } else {
        0.0
    };
    println!(
        "  partial prefix: {} hits on {} whole-window misses ({:.0}%) | {} window tokens re-used",
        pp.partial_prefix_hits,
        pp.kv_cache_misses,
        pp_hit_rate * 100.0,
        pp.partial_prefix_tokens_saved,
    );
    if cache_enabled && distinct >= 2 {
        anyhow::ensure!(
            pp.partial_prefix_hits > 0,
            "mixed-length shared-system-prompt workload produced no partial-prefix hits"
        );
    }

    // Occupancy sweep: the tentpole's O(1)-admission proof. Fill a slow
    // 1-worker pool with `occ` long-running background rows, then time a
    // probe request's TTFT. Under the per-row engine the join is one
    // single-row encode regardless of occupancy (the stats delta below
    // pins that); under the old barrier engine the probe would wait for a
    // whole-batch re-prefill, scaling TTFT with occupancy.
    use cola::serve::InferenceService;
    let slow = MockBackend::new(4, 8, 24)
        .vocab(50_021)
        .prefill_delay(std::time::Duration::from_millis(10))
        .step_delay(std::time::Duration::from_millis(2));
    let probe_ttft = |occ: usize| -> Result<f64> {
        // min of 3 independent sessions — robust to scheduler hiccups
        let mut best = f64::INFINITY;
        for round in 0..3 {
            let mut cfg = models[0].1.clone();
            cfg.workers = 1;
            cfg.kv_cache_entries = 0; // every join pays its real encode
            let pool = ServicePool::start_with(cfg, slow.clone().factory())?;
            let mut bg = Vec::new();
            for b in 0..occ {
                // 18 tokens: encode + 17 decode steps, dying at pos 23 —
                // outlives the probe without ever rolling over (which would
                // add prefill calls and break the O(1) assertion below)
                bg.push(pool.submit(
                    vec![500 + 31 * (b as i32 + 1); 6],
                    SubmitOptions { max_new_tokens: Some(18), ..Default::default() },
                )?);
            }
            // background rows are live once each has streamed a token
            for s in &mut bg {
                anyhow::ensure!(
                    matches!(s.recv(), Some(cola::serve::StreamEvent::Token(_))),
                    "background row died before going live"
                );
            }
            let s0 = pool.stats();
            let c = pool.generate(
                vec![700 + round, 701, 702, 703, 704, 705],
                SubmitOptions { max_new_tokens: Some(2), ..Default::default() },
            )?;
            let s1 = pool.stats();
            anyhow::ensure!(
                s1.prefill_calls - s0.prefill_calls == 1,
                "joining at occupancy {occ} cost {} prefills — occupied rows were re-encoded",
                s1.prefill_calls - s0.prefill_calls
            );
            anyhow::ensure!(
                occ == 0 || s1.rows_joined_midflight > s0.rows_joined_midflight,
                "probe at occupancy {occ} was not counted as a mid-flight join"
            );
            let ttft =
                c.timing.first_token.context("probe produced no token")?.as_secs_f64() * 1e3;
            best = best.min(ttft);
            for s in bg {
                let _ = s.wait();
            }
        }
        Ok(best)
    };
    let serve_bs = 4usize; // MockBackend::new(4, ...) above
    let (ttft_low, ttft_high) = (probe_ttft(1)?, probe_ttft(serve_bs - 1)?);
    let ttft_ratio = ttft_high / ttft_low.max(1e-9);
    println!(
        "  join ttft: occupancy 1 = {ttft_low:.2}ms, occupancy {} = {ttft_high:.2}ms \
         (ratio {ttft_ratio:.2}x, gate <= 1.5x)",
        serve_bs - 1,
    );
    anyhow::ensure!(
        ttft_ratio <= 1.5,
        "joining-row TTFT scales with occupancy ({ttft_ratio:.2}x > 1.5x) — \
         the barrier is back"
    );

    // Chaos soak (--chaos): scripted faults against the same serving surface
    // must lose zero requests, keep streams byte-identical, restart panicked
    // workers, and walk the circuit breaker through open → probe → healthy.
    let chaos = if flags.contains_key("chaos") {
        Some(cmd_serve_chaos(models, &prompts, n_requests)?)
    } else {
        None
    };

    if let Some(path) = flags.get("bench-json") {
        use cola::util::json::Json;
        let mut fields = vec![
            ("bench", Json::s("serve_mock")),
            // distinguishes a real run from the statically-derived baseline
            // committed as BENCH_serve.json (provenance "derived-static")
            ("provenance", Json::s("measured")),
            ("requests", Json::num(n_requests as f64)),
            ("distinct_prompts", Json::num(distinct as f64)),
            ("tokens", Json::num(tokens as f64)),
            ("tokens_per_sec", Json::num(tokens as f64 / secs_on)),
            ("tokens_per_sec_nocache", Json::num(tokens as f64 / secs_off)),
            ("prefill_calls", Json::num(on.prefill_calls as f64)),
            ("prefills_elided", Json::num(on.prefills_elided as f64)),
            ("kv_cache_hits", Json::num(on.kv_cache_hits as f64)),
            ("kv_cache_misses", Json::num(on.kv_cache_misses as f64)),
            (
                "cache_hit_rate",
                Json::num(if lookups > 0 {
                    on.kv_cache_hits as f64 / lookups as f64
                } else {
                    0.0
                }),
            ),
            ("kv_decode_nanos", Json::num(on.kv_decode_nanos as f64)),
            // partial-prefix workload: shared system prefix, mixed lengths
            ("partial_prefix_hits", Json::num(pp.partial_prefix_hits as f64)),
            (
                "partial_prefix_tokens_saved",
                Json::num(pp.partial_prefix_tokens_saved as f64),
            ),
            ("partial_prefix_hit_rate", Json::num(pp_hit_rate)),
            // occupancy sweep: joining-row TTFT must not scale with batch fill
            (
                "join_ttft_by_occupancy",
                Json::obj(vec![
                    ("occ1", Json::num(ttft_low)),
                    ("occ3", Json::num(ttft_high)),
                ]),
            ),
            ("join_ttft_occupancy_ratio", Json::num(ttft_ratio)),
            ("kv_budget_bytes", Json::num(budget as f64)),
            (
                "bytes_per_entry",
                Json::obj(vec![
                    ("f32", Json::num(entry_bytes[0] as f64)),
                    ("f16", Json::num(entry_bytes[1] as f64)),
                    ("rankr", Json::num(entry_bytes[2] as f64)),
                ]),
            ),
            (
                "hit_rate_fixed_mem",
                Json::obj(vec![
                    ("f32", Json::num(fixed_mem[0].0)),
                    ("f16", Json::num(fixed_mem[1].0)),
                    ("rankr", Json::num(fixed_mem[2].0)),
                ]),
            ),
            (
                "kv_bytes_saved_fixed_mem",
                Json::obj(vec![
                    ("f32", Json::num(fixed_mem[0].2 as f64)),
                    ("f16", Json::num(fixed_mem[1].2 as f64)),
                    ("rankr", Json::num(fixed_mem[2].2 as f64)),
                ]),
            ),
        ];
        if let Some(ch) = &chaos {
            fields.extend([
                ("chaos_requests", Json::num(ch.requests as f64)),
                ("chaos_lost", Json::num(ch.lost as f64)),
                ("chaos_redispatched", Json::num(ch.redispatched as f64)),
                ("chaos_retries", Json::num(ch.retries as f64)),
                ("chaos_worker_restarts", Json::num(ch.worker_restarts as f64)),
                ("chaos_worker_panics", Json::num(ch.worker_panics as f64)),
                ("chaos_breaker_opens", Json::num(ch.breaker_opens as f64)),
                ("chaos_breaker_recoveries", Json::num(ch.breaker_recoveries as f64)),
            ]);
        }
        let j = Json::obj(fields);
        std::fs::write(path, format!("{j}\n"))
            .with_context(|| format!("writing {path}"))?;
        println!("  wrote {path}");
    }
    Ok(())
}

/// What the `--chaos` soak observed, for the printed summary and the
/// `chaos_*` fields of `--bench-json`.
struct ChaosReport {
    /// Requests submitted across all three scenarios.
    requests: usize,
    /// Requests that never resolved — any non-zero value fails the soak
    /// before this report is built, so a written report always says 0.
    lost: usize,
    redispatched: u64,
    retries: u64,
    worker_restarts: u64,
    worker_panics: u64,
    breaker_opens: u64,
    breaker_recoveries: u64,
}

/// `cola serve --mock --chaos`: a deterministic fault soak over the same
/// router/pool surface the smoke uses, in three scenarios (docs/robustness.md):
///
/// 1. **Transient-fault soak** — injected prefill/decode errors and latency
///    spikes while `n` requests stream. Every request must resolve
///    (`Length`/`Stop`), streams must be byte-identical to a fault-free
///    baseline (redispatch is transparent), and at least one request must
///    have been salvaged and redispatched.
/// 2. **Worker panic** — a scripted `decode_step` panic kills the worker
///    mid-stream; the supervisor must salvage the request, respawn the
///    worker (twice — the one-shot schedule re-arms per respawned backend),
///    and the stream must complete byte-identical to the fault-free run.
/// 3. **Breaker walk** — with `retry_budget=0` and `breaker_open_after=1`,
///    one injected fault fails a request and opens the breaker; a routed
///    submit must be refused with `CircuitOpen`; after the cooldown, a
///    probe request must be admitted half-open, complete, and restore
///    `Healthy`.
fn cmd_serve_chaos(
    models: &[(String, cola::config::ServeConfig)],
    prompts: &[Vec<i32>],
    n_requests: usize,
) -> Result<ChaosReport> {
    use cola::serve::engine::EngineBackend;
    use cola::serve::{
        BreakerState, FaultKind, FaultPlan, FaultSchedule, FinishReason, InferenceService,
        MockBackend, ServicePool, ServiceStats,
    };
    use std::time::{Duration, Instant};

    let name = models[0].0.clone();
    let base_cfg = models[0].1.clone();
    let fault_pool =
        |cfg: cola::config::ServeConfig, mock: MockBackend, plan: FaultPlan| -> Result<ServicePool> {
            ServicePool::start_with(cfg, move |w| {
                Ok(Box::new(plan.wrap(mock.clone(), w)) as Box<dyn EngineBackend>)
            })
        };
    let await_state = |pool: &ServicePool, want: BreakerState| -> Result<()> {
        let t0 = Instant::now();
        while pool.breaker_state() != want {
            anyhow::ensure!(
                t0.elapsed() < Duration::from_secs(5),
                "chaos: breaker stuck at {:?} waiting for {want:?}",
                pool.breaker_state()
            );
            std::thread::sleep(Duration::from_millis(1));
        }
        Ok(())
    };

    // -- scenario 1: transient-fault soak, zero lost, byte-identical --------
    let n = n_requests.max(8);
    let mut cfg = base_cfg.clone();
    // transient faults must never exhaust a retry budget: each worker's
    // one-shot error faults fire at most twice per backend instance, so a
    // budget past 2 faults/worker makes exhaustion impossible by schedule
    cfg.retry_budget = 2 * cfg.workers.max(1) as u32 + 4;
    cfg.breaker_open_after = 0; // breaker behaviour is scenario 3's subject
    cfg.default_deadline_ms = 0; // latency spikes must not expire anything
    let mock = MockBackend::new(4, 8, 24).vocab(50_021);
    let soak = |plan: FaultPlan| -> Result<(Vec<Vec<i32>>, ServiceStats)> {
        let pool = fault_pool(cfg.clone(), mock.clone(), plan)?;
        let router = ModelRouter::from_pools(vec![(name.clone(), pool)])?;
        let mut streams = Vec::with_capacity(n);
        for r in 0..n {
            let prompt = prompts[r % prompts.len()].clone();
            loop {
                let opts = SubmitOptions { max_new_tokens: Some(12), ..Default::default() };
                match router.submit(&name, prompt.clone(), opts) {
                    Ok(s) => break streams.push(s),
                    Err(RouteError::Submit(SubmitError::QueueFull)) => {
                        std::thread::sleep(Duration::from_millis(1));
                    }
                    Err(e) => anyhow::bail!("chaos soak submit failed: {e}"),
                }
            }
        }
        let mut outs = Vec::with_capacity(streams.len());
        for (r, s) in streams.into_iter().enumerate() {
            let c = s.wait()?;
            anyhow::ensure!(
                matches!(c.finish_reason, FinishReason::Length | FinishReason::Stop),
                "chaos soak lost request {r} to {:?}",
                c.finish_reason
            );
            outs.push(c.tokens);
        }
        let stats = router.aggregate_stats();
        router.shutdown();
        Ok((outs, stats))
    };
    let (baseline, _) = soak(FaultPlan::default())?;
    let plan = FaultPlan::seeded(42)
        .inject(FaultKind::PrefillError, FaultSchedule::Once(3))
        .inject(FaultKind::DecodeError, FaultSchedule::Once(5))
        .inject(
            FaultKind::LatencySpike(Duration::from_millis(2)),
            FaultSchedule::EveryNth(9),
        );
    let (outs, soak_stats) = soak(plan)?;
    anyhow::ensure!(
        outs == baseline,
        "chaos soak changed streamed outputs — redispatch is not transparent"
    );
    anyhow::ensure!(
        soak_stats.failed == 0 && soak_stats.completed == n as u64,
        "chaos soak dropped requests: completed {} of {n}, failed {}",
        soak_stats.completed,
        soak_stats.failed
    );
    anyhow::ensure!(
        soak_stats.requests_redispatched >= 1,
        "chaos soak injected faults but salvaged nothing — the faults never landed"
    );
    println!(
        "  chaos soak: {n} requests, 0 lost | {} redispatched ({} retries) | \
         streams byte-identical to fault-free baseline",
        soak_stats.requests_redispatched, soak_stats.retries,
    );

    // -- scenario 2: worker panic → supervised restart, stream survives -----
    let mut pcfg = base_cfg.clone();
    pcfg.workers = 1;
    pcfg.retry_budget = 2;
    pcfg.restart_budget = 3;
    pcfg.breaker_open_after = 0;
    pcfg.default_deadline_ms = 0;
    let pmock = MockBackend::new(1, 8, 64).vocab(50_021);
    let popts = || SubmitOptions { max_new_tokens: Some(10), ..Default::default() };
    let clean = fault_pool(pcfg.clone(), pmock.clone(), FaultPlan::default())?;
    let want = clean.generate(prompts[0].clone(), popts())?;
    clean.shutdown();
    // Once(4) re-arms on every respawned backend: panic at the 4th decode
    // call of each incarnation → 4 + 4 + 2 tokens across exactly 2 restarts,
    // inside retry_budget=2 and restart_budget=3
    let pplan = FaultPlan::seeded(7).inject(FaultKind::WorkerPanic, FaultSchedule::Once(4));
    let ppool = fault_pool(pcfg, pmock, pplan)?;
    let got = ppool.generate(prompts[0].clone(), popts())?;
    let ps = ppool.stats();
    ppool.shutdown();
    anyhow::ensure!(
        matches!(got.finish_reason, FinishReason::Length) && got.tokens == want.tokens,
        "chaos: stream did not survive the worker panics byte-identically \
         ({:?}, {} tokens vs {})",
        got.finish_reason,
        got.tokens.len(),
        want.tokens.len()
    );
    anyhow::ensure!(
        ps.worker_restarts == 2 && ps.worker_panics == 2 && ps.failed == 0,
        "chaos: panic supervision off-script: restarts {} panics {} failed {}",
        ps.worker_restarts,
        ps.worker_panics,
        ps.failed
    );
    println!(
        "  chaos panic: worker panicked x{} -> {} supervised restarts, \
         stream survived byte-identical ({} redispatches)",
        ps.worker_panics, ps.worker_restarts, ps.requests_redispatched,
    );

    // -- scenario 3: breaker opens, denies, probes half-open, recovers ------
    let mut bcfg = base_cfg.clone();
    bcfg.workers = 1;
    bcfg.retry_budget = 0; // the injected fault must fail its request
    bcfg.restart_budget = 3;
    bcfg.breaker_open_after = 1;
    bcfg.breaker_recover_after = 1;
    // wide enough that the deny-while-open assertion cannot race the
    // cooldown on a stalled CI machine
    bcfg.breaker_cooldown_ms = 250;
    bcfg.default_deadline_ms = 0;
    let bmock = MockBackend::new(1, 8, 64).vocab(50_021);
    let bplan = FaultPlan::seeded(3).inject(FaultKind::DecodeError, FaultSchedule::Once(2));
    let bpool = fault_pool(bcfg, bmock, bplan)?;
    let router = ModelRouter::from_pools(vec![(name.clone(), bpool)])?;
    let bopts = || SubmitOptions { max_new_tokens: Some(4), ..Default::default() };
    let c = router.generate(&name, prompts[0].clone(), bopts())?;
    anyhow::ensure!(
        matches!(c.finish_reason, FinishReason::Error { .. }),
        "chaos: injected fault with retry_budget=0 should fail typed, got {:?}",
        c.finish_reason
    );
    let pool = router.pool(&name).context("chaos pool vanished")?;
    await_state(pool, BreakerState::Open)?;
    match router.submit(&name, prompts[0].clone(), bopts()) {
        Err(RouteError::CircuitOpen(m)) => anyhow::ensure!(m == name, "wrong model in CircuitOpen"),
        Err(e) => anyhow::bail!("chaos: open breaker refused with the wrong error: {e}"),
        Ok(_) => anyhow::bail!("chaos: open breaker admitted a request before its cooldown"),
    }
    std::thread::sleep(Duration::from_millis(300));
    let probe = router.generate(&name, prompts[1 % prompts.len()].clone(), bopts())?;
    anyhow::ensure!(
        matches!(probe.finish_reason, FinishReason::Length | FinishReason::Stop),
        "chaos: half-open probe failed with {:?}",
        probe.finish_reason
    );
    await_state(pool, BreakerState::Healthy)?;
    let bs = router.aggregate_stats();
    router.shutdown();
    anyhow::ensure!(
        bs.breaker_opens >= 1 && bs.breaker_recoveries >= 1,
        "chaos: breaker walk left no transition evidence (opens {}, recoveries {})",
        bs.breaker_opens,
        bs.breaker_recoveries
    );
    println!(
        "  chaos breaker: opened on fault, denied while open, probe recovered -> healthy \
         (opens {}, recoveries {})",
        bs.breaker_opens, bs.breaker_recoveries,
    );

    // scenario 3 submits 2 resolvable requests (the denied CircuitOpen
    // submit never queues); every wait() above returned, so nothing is lost
    let requests = n + 1 + 2;
    let resolved = outs.len() + 1 + 2;
    Ok(ChaosReport {
        requests,
        lost: requests - resolved,
        redispatched: soak_stats.requests_redispatched + ps.requests_redispatched,
        retries: soak_stats.retries + ps.retries,
        worker_restarts: ps.worker_restarts,
        worker_panics: ps.worker_panics,
        breaker_opens: bs.breaker_opens,
        breaker_recoveries: bs.breaker_recoveries,
    })
}

fn cmd_rank(
    flags: std::collections::HashMap<String, String>,
    kvs: Vec<(String, String)>,
) -> Result<()> {
    let cfg = train_cfg(&flags, &kvs)?;
    let alpha: f64 = flags.get("alpha").map(|s| s.parse()).transpose()?.unwrap_or(0.95);
    let mut tr = Trainer::new(cfg)?;
    if let Some(ckpt) = flags.get("checkpoint") {
        tr.load_checkpoint(std::path::Path::new(ckpt))?;
    }
    let ranks = tr.rank_probe(alpha)?;
    println!("effective rank r({alpha}) per tap:");
    for (name, r, d) in ranks {
        println!("  {name:>12}: {r:>4} / {d}");
    }
    Ok(())
}

fn cmd_cost(flags: std::collections::HashMap<String, String>) -> Result<()> {
    let scale = flags.get("scale").map(String::as_str).unwrap_or("llama1b");
    let batch: usize = flags.get("batch").map(|s| s.parse()).transpose()?.unwrap_or(16);
    let p = PaperPreset::by_name(scale)
        .with_context(|| format!("unknown scale `{scale}` (try llama60m..llama7b)"))?;
    println!("== Table 2: full-rank per-layer FLOPs ({scale}, batch {batch}) ==");
    println!("{}", tables::render_table2(p, batch));
    println!("== Table 3: per-method training compute ==");
    println!("{}", tables::render_table3(p, batch));
    println!("== Table 4: checkpointing memory/recompute ==");
    println!("{}", tables::render_table4(p, batch));
    println!("== Fig 5/6: memory breakdown ==");
    println!("{}", tables::render_membreakdown(p, 32));
    println!("== all paper scales (Table 3 ratios at batch {batch}) ==");
    for p in &PAPER_PRESETS {
        let g = cola::costmodel::Geometry::from_paper(p, p.tokens_per_batch(batch));
        let full = cola::costmodel::compute_total(cola::costmodel::Method::FullRank, &g);
        let cola_c = cola::costmodel::compute_total(cola::costmodel::Method::Cola, &g);
        println!("  {:>10}: C_CoLA/C_full = {:.2}", p.name, cola_c / full);
    }
    Ok(())
}

fn cmd_data_gen(flags: std::collections::HashMap<String, String>) -> Result<()> {
    let out = flags.get("out").map(String::as_str).unwrap_or("data_cache");
    // SAFETY: single-threaded at this point in main.
    unsafe { std::env::set_var("COLA_DATA_CACHE", out) };
    for vocab in [512usize, 1024, 2048, 4096] {
        let bpe = cola::coordinator::trainer::shared_bpe(vocab)?;
        println!("bpe vocab={} ready ({} merges applied)", vocab, bpe.vocab_size() - 260);
    }
    Ok(())
}

/// `cola lint` — run the in-house whole-crate static analyzer (see
/// `cola::analysis`): per-file convention rules plus the interprocedural
/// lock-graph and hot-path allocation passes. Exits non-zero on any
/// finding not covered by the optional `--baseline` ratchet file.
fn cmd_lint(flags: std::collections::HashMap<String, String>) -> Result<()> {
    use cola::analysis::{self, Baseline};
    let an = match flags.get("root") {
        // explicit root: strict profile over that one tree, no tests dir
        Some(r) => {
            let root = std::path::PathBuf::from(r);
            analysis::analyze_repo(&root, None)
                .with_context(|| format!("walking {}", root.display()))?
        }
        None => {
            // work from either the repo root or rust/
            let base = if std::path::Path::new("src/serve").exists() {
                std::path::PathBuf::from(".")
            } else {
                std::path::PathBuf::from("rust")
            };
            analysis::analyze_repo(&base.join("src"), Some(&base.join("tests")))
                .with_context(|| format!("walking {}", base.display()))?
        }
    };
    if flags.contains_key("dump-lock-graph") {
        print!("{}", an.lock_graph.dot());
        return Ok(());
    }
    if let Some(path) = flags.get("write-baseline") {
        let baseline = Baseline::from_diags(&an.diagnostics);
        std::fs::write(path, baseline.render())
            .with_context(|| format!("writing baseline {path}"))?;
        eprintln!(
            "cola lint: baseline covering {} finding(s) written to {path}",
            an.diagnostics.len()
        );
        return Ok(());
    }
    let (kept, suppressed) = match flags.get("baseline") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading baseline {path}"))?;
            Baseline::parse(&text)
                .with_context(|| format!("parsing baseline {path}"))?
                .apply(an.diagnostics)
        }
        None => (an.diagnostics, 0),
    };
    match flags.get("format").map(String::as_str).unwrap_or("text") {
        "json" => print!("{}", analysis::render_json(&kept, suppressed)),
        "text" => {
            for d in &kept {
                eprintln!("{d}");
            }
            if kept.is_empty() && suppressed == 0 {
                println!("cola lint: clean");
            } else if kept.is_empty() {
                println!("cola lint: clean ({suppressed} baselined finding(s) suppressed)");
            }
        }
        other => anyhow::bail!("cola lint: unknown --format `{other}` (expected text|json)"),
    }
    if kept.is_empty() {
        Ok(())
    } else {
        anyhow::bail!("cola lint: {} finding(s)", kept.len());
    }
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        usage();
    }
    metrics::set_verbose(std::env::var("COLA_VERBOSE").is_ok());
    let (flags, kvs) = parse_args(&args[1..]);
    match args[0].as_str() {
        "train" => cmd_train(flags, kvs),
        // internal: benches spawn this to get per-variant peak-RSS in a
        // fresh process; results land in the shared run cache.
        "train-cached" => {
            let artifact = flags.get("artifact").context("--artifact required")?;
            let steps: usize = flags.get("steps").context("--steps")?.parse()?;
            let seed: u64 = flags.get("seed").map(|s| s.parse()).transpose()?.unwrap_or(0);
            let r = cola::coordinator::cached_or_train(artifact, steps, seed)?;
            println!(
                "cached: {} val_ppl={:.3} tok/s={:.0} rss={:.2}GB",
                r.artifact,
                r.val_ppl,
                r.tokens_per_sec,
                r.peak_rss_bytes as f64 / 1e9
            );
            Ok(())
        }
        "eval" => cmd_eval(flags, kvs),
        "serve" => cmd_serve(flags, kvs),
        "rank" => cmd_rank(flags, kvs),
        "cost" => cmd_cost(flags),
        "data-gen" => cmd_data_gen(flags),
        "lint" => cmd_lint(flags),
        _ => usage(),
    }
}
