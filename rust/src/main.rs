//! `cola` — the launcher CLI for the CoLA training/serving runtime.
//!
//! Subcommands:
//!   train     train an artifact (e.g. --artifact p60m_cola steps=400)
//!   eval      evaluate validation perplexity of a checkpoint
//!   serve     bring up the inference engine and run a demo workload
//!   rank      activation-spectrum analysis (Fig. 2) on an artifact
//!   cost      print the analytic paper tables (2/3/4, Fig 5/6/7 data)
//!   data-gen  pre-build the corpus + BPE tokenizer caches
//!
//! Config values are `key=value` pairs after flags (see config::TrainConfig).

use anyhow::{Context, Result};
use cola::config::{apply_train_overrides, ServeConfig, TrainConfig};
use cola::coordinator::Trainer;
use cola::costmodel::{tables, PaperPreset, PAPER_PRESETS};
use cola::data::{corpus::CorpusCfg, CorpusGen};
use cola::metrics;
use cola::serve::Engine;

fn usage() -> ! {
    eprintln!(
        "usage: cola <train|eval|serve|rank|cost|data-gen> [--artifact NAME] [key=value ...]\n\
         run `cola cost` for the analytic paper tables; `make artifacts` first for the rest."
    );
    std::process::exit(2);
}

/// Split argv into (flags map, key=value overrides).
fn parse_args(
    args: &[String],
) -> (std::collections::HashMap<String, String>, Vec<(String, String)>) {
    let mut flags = std::collections::HashMap::new();
    let mut kvs = Vec::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(name) = a.strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") && !args[i + 1].contains('=') {
                flags.insert(name.to_string(), args[i + 1].clone());
                i += 2;
                continue;
            }
            flags.insert(name.to_string(), "true".to_string());
        } else if let Some((k, v)) = a.split_once('=') {
            kvs.push((k.to_string(), v.to_string()));
        } else {
            eprintln!("unrecognized argument `{a}`");
            usage();
        }
        i += 1;
    }
    (flags, kvs)
}

fn train_cfg(
    flags: &std::collections::HashMap<String, String>,
    kvs: &[(String, String)],
) -> Result<TrainConfig> {
    let mut cfg = TrainConfig::default();
    if let Some(a) = flags.get("artifact") {
        cfg.artifact = a.clone();
    }
    apply_train_overrides(&mut cfg, kvs)?;
    Ok(cfg)
}

fn cmd_train(
    flags: std::collections::HashMap<String, String>,
    kvs: Vec<(String, String)>,
) -> Result<()> {
    let cfg = train_cfg(&flags, &kvs)?;
    let mut tr = Trainer::new(cfg)?;
    let report = tr.run()?;
    println!(
        "done: {} steps={} loss={:.4} val_ppl={:.3} {:.0} tok/s peak_rss={:.2} GB",
        report.artifact,
        report.steps,
        report.final_loss,
        report.val_ppl,
        report.tokens_per_sec,
        report.peak_rss_bytes as f64 / 1e9
    );
    Ok(())
}

fn cmd_eval(
    flags: std::collections::HashMap<String, String>,
    kvs: Vec<(String, String)>,
) -> Result<()> {
    let cfg = train_cfg(&flags, &kvs)?;
    let mut tr = Trainer::new(cfg)?;
    if let Some(ckpt) = flags.get("checkpoint") {
        tr.load_checkpoint(std::path::Path::new(ckpt))?;
    }
    let ppl = tr.evaluate(16)?;
    println!("val_ppl={ppl:.3}");
    Ok(())
}

fn cmd_serve(flags: std::collections::HashMap<String, String>) -> Result<()> {
    let mut cfg = ServeConfig::default();
    if let Some(a) = flags.get("artifact") {
        cfg.artifact = a.clone();
    }
    if let Some(n) = flags.get("max-new") {
        cfg.max_new_tokens = n.parse().context("max-new")?;
    }
    let n_requests: usize = flags.get("requests").map(|s| s.parse()).transpose()?.unwrap_or(16);

    let (handle, join) = Engine::spawn(cfg.clone())?;
    let bpe = cola::coordinator::trainer::shared_bpe(
        cola::runtime::ArtifactDir::open_named(&cfg.artifact)?.manifest.preset.vocab,
    )?;
    let mut gen = CorpusGen::new(CorpusCfg::default());
    let mut latencies = Vec::new();
    let t0 = std::time::Instant::now();
    let mut pending = Vec::new();
    for _ in 0..n_requests {
        let prompt = bpe.encode(&gen.text(60));
        pending.push(handle.submit(prompt, cfg.max_new_tokens));
    }
    let mut total_tokens = 0;
    for rx in pending {
        let resp = rx.recv()?;
        total_tokens += resp.tokens.len();
        latencies.push(resp.latency.as_secs_f64() * 1000.0);
    }
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p50 = latencies[latencies.len() / 2];
    let p95 = latencies[(latencies.len() * 95 / 100).min(latencies.len() - 1)];
    println!(
        "served {n_requests} requests, {total_tokens} tokens in {:.2}s ({:.0} tok/s) p50={p50:.0}ms p95={p95:.0}ms",
        t0.elapsed().as_secs_f64(),
        total_tokens as f64 / t0.elapsed().as_secs_f64()
    );
    drop(handle);
    let _ = join.join();
    Ok(())
}

fn cmd_rank(
    flags: std::collections::HashMap<String, String>,
    kvs: Vec<(String, String)>,
) -> Result<()> {
    let cfg = train_cfg(&flags, &kvs)?;
    let alpha: f64 = flags.get("alpha").map(|s| s.parse()).transpose()?.unwrap_or(0.95);
    let mut tr = Trainer::new(cfg)?;
    if let Some(ckpt) = flags.get("checkpoint") {
        tr.load_checkpoint(std::path::Path::new(ckpt))?;
    }
    let ranks = tr.rank_probe(alpha)?;
    println!("effective rank r({alpha}) per tap:");
    for (name, r, d) in ranks {
        println!("  {name:>12}: {r:>4} / {d}");
    }
    Ok(())
}

fn cmd_cost(flags: std::collections::HashMap<String, String>) -> Result<()> {
    let scale = flags.get("scale").map(String::as_str).unwrap_or("llama1b");
    let batch: usize = flags.get("batch").map(|s| s.parse()).transpose()?.unwrap_or(16);
    let p = PaperPreset::by_name(scale)
        .with_context(|| format!("unknown scale `{scale}` (try llama60m..llama7b)"))?;
    println!("== Table 2: full-rank per-layer FLOPs ({scale}, batch {batch}) ==");
    println!("{}", tables::render_table2(p, batch));
    println!("== Table 3: per-method training compute ==");
    println!("{}", tables::render_table3(p, batch));
    println!("== Table 4: checkpointing memory/recompute ==");
    println!("{}", tables::render_table4(p, batch));
    println!("== Fig 5/6: memory breakdown ==");
    println!("{}", tables::render_membreakdown(p, 32));
    println!("== all paper scales (Table 3 ratios at batch {batch}) ==");
    for p in &PAPER_PRESETS {
        let g = cola::costmodel::Geometry::from_paper(p, p.tokens_per_batch(batch));
        let full = cola::costmodel::compute_total(cola::costmodel::Method::FullRank, &g);
        let cola_c = cola::costmodel::compute_total(cola::costmodel::Method::Cola, &g);
        println!("  {:>10}: C_CoLA/C_full = {:.2}", p.name, cola_c / full);
    }
    Ok(())
}

fn cmd_data_gen(flags: std::collections::HashMap<String, String>) -> Result<()> {
    let out = flags.get("out").map(String::as_str).unwrap_or("data_cache");
    // SAFETY: single-threaded at this point in main.
    unsafe { std::env::set_var("COLA_DATA_CACHE", out) };
    for vocab in [512usize, 1024, 2048, 4096] {
        let bpe = cola::coordinator::trainer::shared_bpe(vocab)?;
        println!("bpe vocab={} ready ({} merges applied)", vocab, bpe.vocab_size() - 260);
    }
    Ok(())
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        usage();
    }
    metrics::set_verbose(std::env::var("COLA_VERBOSE").is_ok());
    let (flags, kvs) = parse_args(&args[1..]);
    match args[0].as_str() {
        "train" => cmd_train(flags, kvs),
        // internal: benches spawn this to get per-variant peak-RSS in a
        // fresh process; results land in the shared run cache.
        "train-cached" => {
            let artifact = flags.get("artifact").context("--artifact required")?;
            let steps: usize = flags.get("steps").context("--steps")?.parse()?;
            let seed: u64 = flags.get("seed").map(|s| s.parse()).transpose()?.unwrap_or(0);
            let r = cola::coordinator::cached_or_train(artifact, steps, seed)?;
            println!(
                "cached: {} val_ppl={:.3} tok/s={:.0} rss={:.2}GB",
                r.artifact,
                r.val_ppl,
                r.tokens_per_sec,
                r.peak_rss_bytes as f64 / 1e9
            );
            Ok(())
        }
        "eval" => cmd_eval(flags, kvs),
        "serve" => cmd_serve(flags),
        "rank" => cmd_rank(flags, kvs),
        "cost" => cmd_cost(flags),
        "data-gen" => cmd_data_gen(flags),
        _ => usage(),
    }
}
