//! `cola` — the launcher CLI for the CoLA training/serving runtime.
//!
//! Subcommands:
//!   train     train an artifact (e.g. --artifact p60m_cola steps=400)
//!   eval      evaluate validation perplexity of a checkpoint
//!   serve     run a load generator against the serving tier
//!             (`ModelRouter` → named `ServicePool`s: continuous batching,
//!             streaming, bounded admission queues). Flags: --requests N,
//!             --config file.json, --model NAME (restrict load to one
//!             model); key=value overrides: artifact, max_new_tokens,
//!             workers, queue_depth, default_deadline_ms,
//!             models=name:artifact,... and name.key=value per model.
//!             Prints per-model p50/p95/p99 latency, time-to-first-token,
//!             and labeled queue/counter stats plus a fleet aggregate.
//!   rank      activation-spectrum analysis (Fig. 2) on an artifact
//!   cost      print the analytic paper tables (2/3/4, Fig 5/6/7 data)
//!   data-gen  pre-build the corpus + BPE tokenizer caches
//!
//! Config values are `key=value` pairs after flags; `train` and `serve`
//! both accept `--config file.json` plus overrides (see config::TrainConfig
//! / config::ServeConfig).

use anyhow::{Context, Result};
use cola::config::{apply_train_overrides, load_router_config, TrainConfig};
use cola::coordinator::Trainer;
use cola::costmodel::{tables, PaperPreset, PAPER_PRESETS};
use cola::data::{corpus::CorpusCfg, CorpusGen};
use cola::metrics;
use cola::metrics::{fmt_ms, percentile};
use cola::serve::{ModelRouter, RouteError, SubmitError, SubmitOptions};

fn usage() -> ! {
    eprintln!(
        "usage: cola <train|eval|serve|rank|cost|data-gen> [--artifact NAME] [key=value ...]\n\
         serve: cola serve [--artifact NAME] [--requests N] [--config f.json] [--model NAME]\n\
                [max_new_tokens=K] [workers=N] [queue_depth=D] [default_deadline_ms=MS]\n\
                [models=name:artifact,...] [name.key=value ...]\n\
         run `cola cost` for the analytic paper tables; `make artifacts` first for the rest."
    );
    std::process::exit(2);
}

/// Split argv into (flags map, key=value overrides).
fn parse_args(
    args: &[String],
) -> (std::collections::HashMap<String, String>, Vec<(String, String)>) {
    let mut flags = std::collections::HashMap::new();
    let mut kvs = Vec::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(name) = a.strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") && !args[i + 1].contains('=') {
                flags.insert(name.to_string(), args[i + 1].clone());
                i += 2;
                continue;
            }
            flags.insert(name.to_string(), "true".to_string());
        } else if let Some((k, v)) = a.split_once('=') {
            kvs.push((k.to_string(), v.to_string()));
        } else {
            eprintln!("unrecognized argument `{a}`");
            usage();
        }
        i += 1;
    }
    (flags, kvs)
}

fn train_cfg(
    flags: &std::collections::HashMap<String, String>,
    kvs: &[(String, String)],
) -> Result<TrainConfig> {
    let mut cfg = TrainConfig::default();
    if let Some(a) = flags.get("artifact") {
        cfg.artifact = a.clone();
    }
    apply_train_overrides(&mut cfg, kvs)?;
    Ok(cfg)
}

fn cmd_train(
    flags: std::collections::HashMap<String, String>,
    kvs: Vec<(String, String)>,
) -> Result<()> {
    let cfg = train_cfg(&flags, &kvs)?;
    let mut tr = Trainer::new(cfg)?;
    let report = tr.run()?;
    println!(
        "done: {} steps={} loss={:.4} val_ppl={:.3} {:.0} tok/s peak_rss={:.2} GB",
        report.artifact,
        report.steps,
        report.final_loss,
        report.val_ppl,
        report.tokens_per_sec,
        report.peak_rss_bytes as f64 / 1e9
    );
    Ok(())
}

fn cmd_eval(
    flags: std::collections::HashMap<String, String>,
    kvs: Vec<(String, String)>,
) -> Result<()> {
    let cfg = train_cfg(&flags, &kvs)?;
    let mut tr = Trainer::new(cfg)?;
    if let Some(ckpt) = flags.get("checkpoint") {
        tr.load_checkpoint(std::path::Path::new(ckpt))?;
    }
    let ppl = tr.evaluate(16)?;
    println!("val_ppl={ppl:.3}");
    Ok(())
}

/// Load generator against the serving tier: brings up a `ModelRouter` (one
/// pool per configured model), round-robins `--requests` prompts across the
/// targeted models with queue backpressure (retrying on `QueueFull`), then
/// reports per-model latency percentiles, time-to-first-token, and labeled
/// counter stats plus a fleet aggregate. `--model NAME` restricts the load
/// to one model.
fn cmd_serve(
    flags: std::collections::HashMap<String, String>,
    kvs: Vec<(String, String)>,
) -> Result<()> {
    // precedence for pool defaults (last wins): built-ins < --config file
    // plain keys < --artifact < key=value; each model then layers its own
    // file stanza and `name.key=value` overrides on top of those defaults
    // (see config::load_router_config)
    let mut all_kvs = Vec::new();
    if let Some(a) = flags.get("artifact") {
        all_kvs.push(("artifact".to_string(), a.clone()));
    }
    all_kvs.extend(kvs);
    let rcfg = load_router_config(flags.get("config").map(std::path::Path::new), &all_kvs)?;
    let models = rcfg.resolved_models();
    let n_requests: usize = flags.get("requests").map(|s| s.parse()).transpose()?.unwrap_or(16);

    // which models the load generator drives (the router serves them all)
    let targets: Vec<String> = match flags.get("model") {
        Some(m) => {
            anyhow::ensure!(
                models.iter().any(|(n, _)| n == m),
                "--model `{m}` is not configured (models: {})",
                models.iter().map(|(n, _)| n.as_str()).collect::<Vec<_>>().join(", ")
            );
            vec![m.clone()]
        }
        None => models.iter().map(|(n, _)| n.clone()).collect(),
    };
    for (name, cfg) in &models {
        anyhow::ensure!(
            cfg.workers > 0 || !targets.contains(name),
            "model `{name}` needs workers >= 1 (workers=0 is admission-only)"
        );
    }

    let router = ModelRouter::start(&rcfg)?;
    // per-model tokenizer (vocab comes from each artifact's manifest)
    let mut encoders = Vec::new();
    for name in &targets {
        let cfg = &models.iter().find(|(n, _)| n == name).unwrap().1;
        let vocab =
            cola::runtime::ArtifactDir::open_named(&cfg.artifact)?.manifest.preset.vocab;
        encoders.push(cola::coordinator::trainer::shared_bpe(vocab)?);
    }
    let mut gen = CorpusGen::new(CorpusCfg::default());

    if n_requests > 0 {
        // warmup: compiles each target's prefill+decode before timing starts
        for (name, bpe) in targets.iter().zip(&encoders) {
            let opts = SubmitOptions { max_new_tokens: Some(2), ..Default::default() };
            router.generate(name, bpe.encode(&gen.text(40)), opts)?;
        }
    }

    let t0 = std::time::Instant::now();
    let mut streams: Vec<(usize, cola::serve::TokenStream)> = Vec::new();
    let (mut retries, mut max_queue) = (0u64, 0usize);
    for r in 0..n_requests {
        let which = r % targets.len();
        let prompt = encoders[which].encode(&gen.text(60));
        loop {
            match router.submit(&targets[which], prompt.clone(), SubmitOptions::default()) {
                Ok(s) => break streams.push((which, s)),
                Err(RouteError::Submit(SubmitError::QueueFull)) => {
                    // bounded queue pushed back: wait for capacity
                    retries += 1;
                    std::thread::sleep(std::time::Duration::from_millis(1));
                }
                Err(e) => anyhow::bail!("submit failed: {e}"),
            }
        }
        max_queue = max_queue.max(router.aggregate_stats().queue_depth);
    }
    // per-target sample sets
    let mut tokens = vec![0usize; targets.len()];
    let mut lat = vec![Vec::new(); targets.len()];
    let mut ttft = vec![Vec::new(); targets.len()];
    for (which, s) in streams {
        let c = s.wait()?;
        tokens[which] += c.tokens.len();
        lat[which].push(c.timing.total.as_secs_f64() * 1000.0);
        if let Some(t) = c.timing.first_token {
            ttft[which].push(t.as_secs_f64() * 1000.0);
        }
    }
    let secs = t0.elapsed().as_secs_f64();
    let total_tokens: usize = tokens.iter().sum();
    let agg = router.aggregate_stats();
    println!(
        "served {n_requests} requests across {} model(s), {total_tokens} tokens in {secs:.2}s \
         ({:.0} tok/s wall, {:.0} tok/s decode)",
        targets.len(),
        total_tokens as f64 / secs.max(1e-9),
        agg.decode_tokens_per_sec
    );
    for (i, name) in targets.iter().enumerate() {
        let label = [("model", name.as_str())];
        println!(
            "{}: requests={} tokens={} | latency p50={} p95={} p99={} | ttft p50={} p99={}",
            metrics::fmt_labels(&label),
            lat[i].len(),
            tokens[i],
            fmt_ms(percentile(&lat[i], 50.0)),
            fmt_ms(percentile(&lat[i], 95.0)),
            fmt_ms(percentile(&lat[i], 99.0)),
            fmt_ms(percentile(&ttft[i], 50.0)),
            fmt_ms(percentile(&ttft[i], 99.0)),
        );
    }
    for (name, s) in router.stats_by_model() {
        let label = [("model", name)];
        println!(
            "{} {} {} {} {}",
            metrics::stat_line("serve_submitted", &label, s.submitted),
            metrics::stat_line("serve_completed", &label, s.completed),
            metrics::stat_line("serve_cancelled", &label, s.cancelled),
            metrics::stat_line("serve_expired", &label, s.expired),
            metrics::stat_line("serve_rejected", &label, s.rejected),
        );
    }
    println!(
        "queue: peak depth {max_queue}/{} full-retries {retries} | \
         submitted={} completed={} cancelled={} expired={} rejected={}",
        agg.queue_capacity, agg.submitted, agg.completed, agg.cancelled, agg.expired, agg.rejected
    );
    router.shutdown();
    Ok(())
}

fn cmd_rank(
    flags: std::collections::HashMap<String, String>,
    kvs: Vec<(String, String)>,
) -> Result<()> {
    let cfg = train_cfg(&flags, &kvs)?;
    let alpha: f64 = flags.get("alpha").map(|s| s.parse()).transpose()?.unwrap_or(0.95);
    let mut tr = Trainer::new(cfg)?;
    if let Some(ckpt) = flags.get("checkpoint") {
        tr.load_checkpoint(std::path::Path::new(ckpt))?;
    }
    let ranks = tr.rank_probe(alpha)?;
    println!("effective rank r({alpha}) per tap:");
    for (name, r, d) in ranks {
        println!("  {name:>12}: {r:>4} / {d}");
    }
    Ok(())
}

fn cmd_cost(flags: std::collections::HashMap<String, String>) -> Result<()> {
    let scale = flags.get("scale").map(String::as_str).unwrap_or("llama1b");
    let batch: usize = flags.get("batch").map(|s| s.parse()).transpose()?.unwrap_or(16);
    let p = PaperPreset::by_name(scale)
        .with_context(|| format!("unknown scale `{scale}` (try llama60m..llama7b)"))?;
    println!("== Table 2: full-rank per-layer FLOPs ({scale}, batch {batch}) ==");
    println!("{}", tables::render_table2(p, batch));
    println!("== Table 3: per-method training compute ==");
    println!("{}", tables::render_table3(p, batch));
    println!("== Table 4: checkpointing memory/recompute ==");
    println!("{}", tables::render_table4(p, batch));
    println!("== Fig 5/6: memory breakdown ==");
    println!("{}", tables::render_membreakdown(p, 32));
    println!("== all paper scales (Table 3 ratios at batch {batch}) ==");
    for p in &PAPER_PRESETS {
        let g = cola::costmodel::Geometry::from_paper(p, p.tokens_per_batch(batch));
        let full = cola::costmodel::compute_total(cola::costmodel::Method::FullRank, &g);
        let cola_c = cola::costmodel::compute_total(cola::costmodel::Method::Cola, &g);
        println!("  {:>10}: C_CoLA/C_full = {:.2}", p.name, cola_c / full);
    }
    Ok(())
}

fn cmd_data_gen(flags: std::collections::HashMap<String, String>) -> Result<()> {
    let out = flags.get("out").map(String::as_str).unwrap_or("data_cache");
    // SAFETY: single-threaded at this point in main.
    unsafe { std::env::set_var("COLA_DATA_CACHE", out) };
    for vocab in [512usize, 1024, 2048, 4096] {
        let bpe = cola::coordinator::trainer::shared_bpe(vocab)?;
        println!("bpe vocab={} ready ({} merges applied)", vocab, bpe.vocab_size() - 260);
    }
    Ok(())
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        usage();
    }
    metrics::set_verbose(std::env::var("COLA_VERBOSE").is_ok());
    let (flags, kvs) = parse_args(&args[1..]);
    match args[0].as_str() {
        "train" => cmd_train(flags, kvs),
        // internal: benches spawn this to get per-variant peak-RSS in a
        // fresh process; results land in the shared run cache.
        "train-cached" => {
            let artifact = flags.get("artifact").context("--artifact required")?;
            let steps: usize = flags.get("steps").context("--steps")?.parse()?;
            let seed: u64 = flags.get("seed").map(|s| s.parse()).transpose()?.unwrap_or(0);
            let r = cola::coordinator::cached_or_train(artifact, steps, seed)?;
            println!(
                "cached: {} val_ppl={:.3} tok/s={:.0} rss={:.2}GB",
                r.artifact,
                r.val_ppl,
                r.tokens_per_sec,
                r.peak_rss_bytes as f64 / 1e9
            );
            Ok(())
        }
        "eval" => cmd_eval(flags, kvs),
        "serve" => cmd_serve(flags, kvs),
        "rank" => cmd_rank(flags, kvs),
        "cost" => cmd_cost(flags),
        "data-gen" => cmd_data_gen(flags),
        _ => usage(),
    }
}
