//! `cola` — the launcher CLI for the CoLA training/serving runtime.
//!
//! Subcommands:
//!   train     train an artifact (e.g. --artifact p60m_cola steps=400)
//!   eval      evaluate validation perplexity of a checkpoint
//!   serve     run a load generator against the serving pool
//!             (`ServicePool`: continuous batching, streaming, bounded
//!             admission queue). Flags: --requests N, --config file.json;
//!             key=value overrides: artifact, max_new_tokens, workers,
//!             queue_depth, default_deadline_ms. Prints p50/p95/p99
//!             latency, time-to-first-token, and queue-depth stats.
//!   rank      activation-spectrum analysis (Fig. 2) on an artifact
//!   cost      print the analytic paper tables (2/3/4, Fig 5/6/7 data)
//!   data-gen  pre-build the corpus + BPE tokenizer caches
//!
//! Config values are `key=value` pairs after flags; `train` and `serve`
//! both accept `--config file.json` plus overrides (see config::TrainConfig
//! / config::ServeConfig).

use anyhow::{Context, Result};
use cola::config::{apply_serve_overrides, apply_train_overrides, load_serve_config, TrainConfig};
use cola::coordinator::Trainer;
use cola::costmodel::{tables, PaperPreset, PAPER_PRESETS};
use cola::data::{corpus::CorpusCfg, CorpusGen};
use cola::metrics;
use cola::metrics::{fmt_ms, percentile};
use cola::serve::{InferenceService, ServicePool, SubmitError, SubmitOptions};

fn usage() -> ! {
    eprintln!(
        "usage: cola <train|eval|serve|rank|cost|data-gen> [--artifact NAME] [key=value ...]\n\
         serve: cola serve [--artifact NAME] [--requests N] [--config f.json]\n\
                [max_new_tokens=K] [workers=N] [queue_depth=D] [default_deadline_ms=MS]\n\
         run `cola cost` for the analytic paper tables; `make artifacts` first for the rest."
    );
    std::process::exit(2);
}

/// Split argv into (flags map, key=value overrides).
fn parse_args(
    args: &[String],
) -> (std::collections::HashMap<String, String>, Vec<(String, String)>) {
    let mut flags = std::collections::HashMap::new();
    let mut kvs = Vec::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(name) = a.strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") && !args[i + 1].contains('=') {
                flags.insert(name.to_string(), args[i + 1].clone());
                i += 2;
                continue;
            }
            flags.insert(name.to_string(), "true".to_string());
        } else if let Some((k, v)) = a.split_once('=') {
            kvs.push((k.to_string(), v.to_string()));
        } else {
            eprintln!("unrecognized argument `{a}`");
            usage();
        }
        i += 1;
    }
    (flags, kvs)
}

fn train_cfg(
    flags: &std::collections::HashMap<String, String>,
    kvs: &[(String, String)],
) -> Result<TrainConfig> {
    let mut cfg = TrainConfig::default();
    if let Some(a) = flags.get("artifact") {
        cfg.artifact = a.clone();
    }
    apply_train_overrides(&mut cfg, kvs)?;
    Ok(cfg)
}

fn cmd_train(
    flags: std::collections::HashMap<String, String>,
    kvs: Vec<(String, String)>,
) -> Result<()> {
    let cfg = train_cfg(&flags, &kvs)?;
    let mut tr = Trainer::new(cfg)?;
    let report = tr.run()?;
    println!(
        "done: {} steps={} loss={:.4} val_ppl={:.3} {:.0} tok/s peak_rss={:.2} GB",
        report.artifact,
        report.steps,
        report.final_loss,
        report.val_ppl,
        report.tokens_per_sec,
        report.peak_rss_bytes as f64 / 1e9
    );
    Ok(())
}

fn cmd_eval(
    flags: std::collections::HashMap<String, String>,
    kvs: Vec<(String, String)>,
) -> Result<()> {
    let cfg = train_cfg(&flags, &kvs)?;
    let mut tr = Trainer::new(cfg)?;
    if let Some(ckpt) = flags.get("checkpoint") {
        tr.load_checkpoint(std::path::Path::new(ckpt))?;
    }
    let ppl = tr.evaluate(16)?;
    println!("val_ppl={ppl:.3}");
    Ok(())
}

/// Load generator against the serving pool: submits `--requests` prompts
/// with queue backpressure (retrying on `QueueFull`), then reports latency
/// percentiles, time-to-first-token, throughput, and queue/slot stats.
fn cmd_serve(
    flags: std::collections::HashMap<String, String>,
    kvs: Vec<(String, String)>,
) -> Result<()> {
    // precedence (last wins): defaults < --config file < --artifact < key=value
    let mut cfg = load_serve_config(flags.get("config").map(std::path::Path::new), &[])?;
    if let Some(a) = flags.get("artifact") {
        cfg.artifact = a.clone();
    }
    apply_serve_overrides(&mut cfg, &kvs)?;
    let n_requests: usize = flags.get("requests").map(|s| s.parse()).transpose()?.unwrap_or(16);
    anyhow::ensure!(cfg.workers > 0, "serve needs workers >= 1 (workers=0 is admission-only)");

    let pool = ServicePool::start(cfg.clone())?;
    let bpe = cola::coordinator::trainer::shared_bpe(
        cola::runtime::ArtifactDir::open_named(&cfg.artifact)?.manifest.preset.vocab,
    )?;
    let mut gen = CorpusGen::new(CorpusCfg::default());

    if n_requests > 0 {
        // warmup: compiles prefill+decode on the worker before timing starts
        let opts = SubmitOptions { max_new_tokens: Some(2), ..Default::default() };
        pool.generate(bpe.encode(&gen.text(40)), opts)?;
    }

    let t0 = std::time::Instant::now();
    let mut streams = Vec::new();
    let (mut retries, mut max_queue) = (0u64, 0usize);
    for _ in 0..n_requests {
        let prompt = bpe.encode(&gen.text(60));
        loop {
            match pool.submit(prompt.clone(), SubmitOptions::default()) {
                Ok(s) => break streams.push(s),
                Err(SubmitError::QueueFull) => {
                    // bounded queue pushed back: wait for capacity
                    retries += 1;
                    std::thread::sleep(std::time::Duration::from_millis(1));
                }
                Err(e) => anyhow::bail!("submit failed: {e}"),
            }
        }
        max_queue = max_queue.max(pool.stats().queue_depth);
    }
    let (mut total_tokens, mut lat, mut ttft) = (0usize, Vec::new(), Vec::new());
    for s in streams {
        let c = s.wait()?;
        total_tokens += c.tokens.len();
        lat.push(c.timing.total.as_secs_f64() * 1000.0);
        if let Some(t) = c.timing.first_token {
            ttft.push(t.as_secs_f64() * 1000.0);
        }
    }
    let secs = t0.elapsed().as_secs_f64();
    let stats = pool.stats();
    println!(
        "served {n_requests} requests, {total_tokens} tokens in {secs:.2}s \
         ({:.0} tok/s wall, {:.0} tok/s decode)",
        total_tokens as f64 / secs.max(1e-9),
        stats.decode_tokens_per_sec
    );
    println!(
        "latency p50={} p95={} p99={} | ttft p50={} p99={}",
        fmt_ms(percentile(&lat, 50.0)),
        fmt_ms(percentile(&lat, 95.0)),
        fmt_ms(percentile(&lat, 99.0)),
        fmt_ms(percentile(&ttft, 50.0)),
        fmt_ms(percentile(&ttft, 99.0)),
    );
    println!(
        "queue: peak depth {max_queue}/{} full-retries {retries} | \
         submitted={} completed={} cancelled={} expired={} rejected={}",
        stats.queue_capacity,
        stats.submitted,
        stats.completed,
        stats.cancelled,
        stats.expired,
        stats.rejected
    );
    pool.shutdown();
    Ok(())
}

fn cmd_rank(
    flags: std::collections::HashMap<String, String>,
    kvs: Vec<(String, String)>,
) -> Result<()> {
    let cfg = train_cfg(&flags, &kvs)?;
    let alpha: f64 = flags.get("alpha").map(|s| s.parse()).transpose()?.unwrap_or(0.95);
    let mut tr = Trainer::new(cfg)?;
    if let Some(ckpt) = flags.get("checkpoint") {
        tr.load_checkpoint(std::path::Path::new(ckpt))?;
    }
    let ranks = tr.rank_probe(alpha)?;
    println!("effective rank r({alpha}) per tap:");
    for (name, r, d) in ranks {
        println!("  {name:>12}: {r:>4} / {d}");
    }
    Ok(())
}

fn cmd_cost(flags: std::collections::HashMap<String, String>) -> Result<()> {
    let scale = flags.get("scale").map(String::as_str).unwrap_or("llama1b");
    let batch: usize = flags.get("batch").map(|s| s.parse()).transpose()?.unwrap_or(16);
    let p = PaperPreset::by_name(scale)
        .with_context(|| format!("unknown scale `{scale}` (try llama60m..llama7b)"))?;
    println!("== Table 2: full-rank per-layer FLOPs ({scale}, batch {batch}) ==");
    println!("{}", tables::render_table2(p, batch));
    println!("== Table 3: per-method training compute ==");
    println!("{}", tables::render_table3(p, batch));
    println!("== Table 4: checkpointing memory/recompute ==");
    println!("{}", tables::render_table4(p, batch));
    println!("== Fig 5/6: memory breakdown ==");
    println!("{}", tables::render_membreakdown(p, 32));
    println!("== all paper scales (Table 3 ratios at batch {batch}) ==");
    for p in &PAPER_PRESETS {
        let g = cola::costmodel::Geometry::from_paper(p, p.tokens_per_batch(batch));
        let full = cola::costmodel::compute_total(cola::costmodel::Method::FullRank, &g);
        let cola_c = cola::costmodel::compute_total(cola::costmodel::Method::Cola, &g);
        println!("  {:>10}: C_CoLA/C_full = {:.2}", p.name, cola_c / full);
    }
    Ok(())
}

fn cmd_data_gen(flags: std::collections::HashMap<String, String>) -> Result<()> {
    let out = flags.get("out").map(String::as_str).unwrap_or("data_cache");
    // SAFETY: single-threaded at this point in main.
    unsafe { std::env::set_var("COLA_DATA_CACHE", out) };
    for vocab in [512usize, 1024, 2048, 4096] {
        let bpe = cola::coordinator::trainer::shared_bpe(vocab)?;
        println!("bpe vocab={} ready ({} merges applied)", vocab, bpe.vocab_size() - 260);
    }
    Ok(())
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        usage();
    }
    metrics::set_verbose(std::env::var("COLA_VERBOSE").is_ok());
    let (flags, kvs) = parse_args(&args[1..]);
    match args[0].as_str() {
        "train" => cmd_train(flags, kvs),
        // internal: benches spawn this to get per-variant peak-RSS in a
        // fresh process; results land in the shared run cache.
        "train-cached" => {
            let artifact = flags.get("artifact").context("--artifact required")?;
            let steps: usize = flags.get("steps").context("--steps")?.parse()?;
            let seed: u64 = flags.get("seed").map(|s| s.parse()).transpose()?.unwrap_or(0);
            let r = cola::coordinator::cached_or_train(artifact, steps, seed)?;
            println!(
                "cached: {} val_ppl={:.3} tok/s={:.0} rss={:.2}GB",
                r.artifact,
                r.val_ppl,
                r.tokens_per_sec,
                r.peak_rss_bytes as f64 / 1e9
            );
            Ok(())
        }
        "eval" => cmd_eval(flags, kvs),
        "serve" => cmd_serve(flags, kvs),
        "rank" => cmd_rank(flags, kvs),
        "cost" => cmd_cost(flags),
        "data-gen" => cmd_data_gen(flags),
        _ => usage(),
    }
}
