//! Streaming batch iterators: corpus text → BPE ids → fixed-shape i32
//! token tensors matching the artifact's `tokens_shape`.
//!
//! The iterator is epoch-free (fresh corpus text forever — the paper's
//! "C4 without data repetition" regime) and deterministic given a seed.
//! A held-out validation stream uses a disjoint seed.

use super::corpus::{CorpusCfg, CorpusGen};
use super::tokenizer::Bpe;
use crate::util::rng::Rng;

/// Produces LM train batches shaped [n_micro, mb, seq+1] (flattened row-major).
pub struct BatchIter {
    gen: CorpusGen,
    bpe: Bpe,
    buf: Vec<i32>,
    /// clamp ids into the model vocab (tokenizer may be bigger in tests)
    vocab_clamp: i32,
}

impl BatchIter {
    pub fn new(bpe: Bpe, seed: u64, vocab_clamp: usize) -> Self {
        let gen = CorpusGen::new(CorpusCfg { seed, ..CorpusCfg::default() });
        Self { gen, bpe, buf: Vec::new(), vocab_clamp: vocab_clamp as i32 }
    }

    fn refill(&mut self, need: usize) {
        while self.buf.len() < need {
            let text = self.gen.text(need.max(4096) * 4);
            let ids = self.bpe.encode(&text);
            self.buf.extend(ids.into_iter().map(|t| t.min(self.vocab_clamp - 1)));
        }
    }

    /// Next batch of `shape` = [n_micro, mb, seq(+1)]; returns flat i32 vec.
    pub fn next_batch(&mut self, shape: &[usize]) -> Vec<i32> {
        let total: usize = shape.iter().product();
        self.refill(total);
        self.buf.drain(..total).collect()
    }

    /// Next eval batch of [bs, seq+1].
    pub fn next_eval(&mut self, bs: usize, seq_plus1: usize) -> Vec<i32> {
        self.next_batch(&[bs, seq_plus1])
    }
}

/// MLM batches for the BERT-proxy: (tokens, labels-in-mask channel).
///
/// 15% of positions are selected; selected tokens are replaced by `<mask>`
/// (id 3) in the token tensor; the mask channel carries `orig_id + 1` at
/// selected positions and 0 elsewhere (the +1 lets 0 mean "not a target" —
/// see model.mlm_loss).
pub struct MlmBatchIter {
    inner: BatchIter,
    rng: Rng,
    mask_prob: f64,
}

impl MlmBatchIter {
    pub fn new(bpe: Bpe, seed: u64, vocab_clamp: usize) -> Self {
        Self {
            inner: BatchIter::new(bpe, seed, vocab_clamp),
            rng: Rng::new(seed ^ 0xBE27),
            mask_prob: 0.15,
        }
    }

    /// Returns (tokens, mask) both shaped `shape` = [n_micro, mb, seq].
    pub fn next_batch(&mut self, shape: &[usize]) -> (Vec<i32>, Vec<i32>) {
        let toks = self.inner.next_batch(shape);
        let mut masked = toks.clone();
        let mut mask = vec![0i32; toks.len()];
        for i in 0..toks.len() {
            if self.rng.f64() < self.mask_prob {
                mask[i] = toks[i] + 1;
                masked[i] = super::tokenizer::MASK;
            }
        }
        (masked, mask)
    }
}

/// Synthetic classification tasks for the GLUE proxy (Table 8). Each task t
/// labels a sequence by a simple latent rule over its tokens, with varying
/// difficulty — the fine-tuning analogue of GLUE's task diversity.
pub struct ClsTaskGen {
    bpe: Bpe,
    gen: CorpusGen,
    rng: Rng,
    pub n_classes: usize,
    task: usize,
    vocab_clamp: i32,
}

impl ClsTaskGen {
    pub fn new(bpe: Bpe, task: usize, seed: u64, n_classes: usize, vocab_clamp: usize) -> Self {
        let gen = CorpusGen::new(CorpusCfg {
            seed: seed ^ (task as u64 * 977),
            ..CorpusCfg::default()
        });
        Self {
            bpe,
            gen,
            rng: Rng::new(seed ^ 0x61ea ^ task as u64),
            n_classes,
            task,
            vocab_clamp: vocab_clamp as i32,
        }
    }

    /// Latent labeling rule per task family. All rules are functions of the
    /// token sequence that a transformer encoder can learn but that require
    /// different features (counts, positions, co-occurrence) — mimicking the
    /// spread of GLUE tasks.
    fn label(&self, toks: &[i32]) -> i32 {
        let k = self.n_classes as i64;
        let t = self.task % 4;
        match t {
            // token-sum parity-class (bag-of-words feature)
            0 => (toks.iter().map(|&x| x as i64).sum::<i64>() % k).unsigned_abs() as i32,
            // leading-token bucket (positional feature)
            1 => ((toks[0] as i64 + toks[1] as i64) % k) as i32,
            // max-token bucket (content feature)
            2 => ((toks.iter().copied().max().unwrap_or(0) as i64) % k) as i32,
            // windowed co-occurrence hash (interaction feature)
            _ => {
                let mut h: i64 = 0;
                for w in toks.windows(2).step_by(7) {
                    h = (h * 31 + w[0] as i64 * 7 + w[1] as i64) % 1_000_003;
                }
                (h % k) as i32
            }
        }
    }

    /// Generate a balanced-ish batch: (tokens [bs, seq], labels [bs]).
    pub fn next_batch(&mut self, bs: usize, seq: usize) -> (Vec<i32>, Vec<i32>) {
        let mut toks = Vec::with_capacity(bs * seq);
        let mut labels = Vec::with_capacity(bs);
        for _ in 0..bs {
            let text = self.gen.text(seq * 6);
            let mut ids: Vec<i32> = self
                .bpe
                .encode(&text)
                .into_iter()
                .map(|t| t.min(self.vocab_clamp - 1))
                .collect();
            ids.resize(seq, super::tokenizer::PAD);
            let lbl = self.label(&ids);
            toks.extend_from_slice(&ids);
            labels.push(lbl);
            let _ = &self.rng;
        }
        (toks, labels)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::{CorpusCfg, CorpusGen};

    fn bpe() -> Bpe {
        let text = CorpusGen::new(CorpusCfg::default()).text(40_000);
        Bpe::train(&text, 512)
    }

    #[test]
    fn batch_shape_and_range() {
        let mut it = BatchIter::new(bpe(), 0, 512);
        let b = it.next_batch(&[2, 4, 65]);
        assert_eq!(b.len(), 2 * 4 * 65);
        assert!(b.iter().all(|&t| (0..512).contains(&t)));
    }

    #[test]
    fn deterministic_stream() {
        let mut a = BatchIter::new(bpe(), 7, 512);
        let mut b = BatchIter::new(bpe(), 7, 512);
        assert_eq!(a.next_batch(&[1, 2, 10]), b.next_batch(&[1, 2, 10]));
        // and streams do not repeat themselves
        let x = a.next_batch(&[1, 2, 10]);
        let y = a.next_batch(&[1, 2, 10]);
        assert_ne!(x, y);
    }

    #[test]
    fn disjoint_seeds_disjoint_batches() {
        let mut a = BatchIter::new(bpe(), 1, 512);
        let mut b = BatchIter::new(bpe(), 2, 512);
        assert_ne!(a.next_batch(&[1, 2, 32]), b.next_batch(&[1, 2, 32]));
    }

    #[test]
    fn vocab_clamp_applies() {
        let mut it = BatchIter::new(bpe(), 0, 300);
        let b = it.next_batch(&[1, 2, 50]);
        assert!(b.iter().all(|&t| t < 300));
    }

    #[test]
    fn mlm_masks_about_15pct() {
        let mut it = MlmBatchIter::new(bpe(), 0, 512);
        let (toks, mask) = it.next_batch(&[1, 8, 128]);
        let n = toks.len() as f64;
        let n_masked = mask.iter().filter(|&&m| m > 0).count() as f64;
        assert!((n_masked / n - 0.15).abs() < 0.05);
        for i in 0..toks.len() {
            if mask[i] > 0 {
                assert_eq!(toks[i], crate::data::tokenizer::MASK);
                assert!(mask[i] - 1 < 512);
            }
        }
    }

    #[test]
    fn cls_labels_in_range_and_learnable() {
        let mut g = ClsTaskGen::new(bpe(), 0, 0, 4, 512);
        let (toks, labels) = g.next_batch(16, 32);
        assert_eq!(toks.len(), 16 * 32);
        assert!(labels.iter().all(|&l| (0..4).contains(&l)));
        // the rule is a function of tokens: same tokens => same label
        let g2 = ClsTaskGen::new(bpe(), 0, 0, 4, 512);
        let row: Vec<i32> = toks[..32].to_vec();
        assert_eq!(g2.label(&row), labels[0]);
    }
}
