//! Synthetic corpus generator: a two-level Markov "grammar" over a Zipfian
//! word inventory.
//!
//! Design goals (what pre-training dynamics actually need from data):
//! * heavy-tailed unigram distribution (Zipf s≈1.1, like natural text);
//! * local syntactic structure (word-level Markov chains per "topic", so
//!   models with more capacity keep improving);
//! * long-range mixing (topic switches with sticky transitions) so context
//!   beyond a few tokens carries signal;
//! * unbounded, deterministic streaming (seeded) — C4's no-repetition regime.

use crate::util::rng::Rng;

/// Corpus hyper-parameters.
#[derive(Clone, Debug)]
pub struct CorpusCfg {
    pub n_words: usize,
    pub n_topics: usize,
    /// successors per word within a topic (grammar branching factor)
    pub branching: usize,
    /// probability of staying in the current topic per word
    pub topic_stickiness: f64,
    /// Zipf exponent for word frequencies
    pub zipf_s: f64,
    /// mean sentence length in words
    pub mean_sentence: usize,
    pub seed: u64,
}

impl Default for CorpusCfg {
    fn default() -> Self {
        Self {
            n_words: 4096,
            n_topics: 16,
            branching: 12,
            topic_stickiness: 0.98,
            zipf_s: 1.1,
            mean_sentence: 14,
            seed: 0,
        }
    }
}

/// Streaming text generator.
pub struct CorpusGen {
    cfg: CorpusCfg,
    rng: Rng,
    /// word id → surface form
    words: Vec<String>,
    /// zipfian sampling weights
    weights: Vec<f64>,
    /// topic → word → successor word ids
    grammar: Vec<Vec<Vec<u32>>>,
    topic: usize,
    cur_word: usize,
}

/// Letters used to synthesize pronounceable word surfaces.
const CONS: &[u8] = b"bcdfghjklmnprstvwz";
const VOWL: &[u8] = b"aeiou";

fn surface(id: usize, rng: &mut Rng) -> String {
    // deterministic-ish pronounceable word: alternating consonant/vowel
    let syllables = 1 + (id % 3) + if rng.f64() < 0.3 { 1 } else { 0 };
    let mut s = String::new();
    for _ in 0..syllables {
        s.push(CONS[rng.below(CONS.len())] as char);
        s.push(VOWL[rng.below(VOWL.len())] as char);
        if rng.f64() < 0.25 {
            s.push(CONS[rng.below(CONS.len())] as char);
        }
    }
    s
}

impl CorpusGen {
    pub fn new(cfg: CorpusCfg) -> Self {
        let mut rng = Rng::new(cfg.seed ^ 0xC01A);
        // unique surfaces
        let mut words = Vec::with_capacity(cfg.n_words);
        let mut seen = std::collections::HashSet::new();
        while words.len() < cfg.n_words {
            let w = surface(words.len(), &mut rng);
            if seen.insert(w.clone()) {
                words.push(w);
            }
        }
        // zipf weights over a random permutation (rank != id)
        let mut ranks: Vec<usize> = (0..cfg.n_words).collect();
        rng.shuffle(&mut ranks);
        let mut weights = vec![0.0; cfg.n_words];
        for (id, rank) in ranks.iter().enumerate() {
            weights[id] = 1.0 / ((rank + 1) as f64).powf(cfg.zipf_s);
        }
        // per-topic grammar: each word gets `branching` candidate successors
        let grammar = (0..cfg.n_topics)
            .map(|_| {
                (0..cfg.n_words)
                    .map(|_| {
                        (0..cfg.branching)
                            .map(|_| rng.categorical(&weights) as u32)
                            .collect()
                    })
                    .collect()
            })
            .collect();
        let topic = rng.below(cfg.n_topics);
        let cur_word = rng.categorical(&weights);
        Self { cfg, rng, words, weights, grammar, topic, cur_word }
    }

    /// Next word id under the grammar walk.
    fn next_word(&mut self) -> usize {
        if self.rng.f64() > self.cfg.topic_stickiness {
            self.topic = self.rng.below(self.cfg.n_topics);
        }
        let succ = &self.grammar[self.topic][self.cur_word];
        // mostly grammar-driven, occasionally a fresh zipf draw (noise floor)
        let next = if self.rng.f64() < 0.9 {
            succ[self.rng.below(succ.len())] as usize
        } else {
            self.rng.categorical(&self.weights)
        };
        self.cur_word = next;
        next
    }

    /// Generate roughly `n_bytes` of text (sentences with punctuation).
    pub fn text(&mut self, n_bytes: usize) -> String {
        let mut out = String::with_capacity(n_bytes + 64);
        while out.len() < n_bytes {
            let len = 3 + self.rng.below(2 * self.cfg.mean_sentence);
            for i in 0..len {
                let w = self.next_word();
                if i > 0 {
                    out.push(' ');
                }
                out.push_str(&self.words[w]);
            }
            out.push_str(". ");
        }
        out
    }

    pub fn vocab_surfaces(&self) -> &[String] {
        &self.words
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = CorpusGen::new(CorpusCfg::default());
        let mut b = CorpusGen::new(CorpusCfg::default());
        assert_eq!(a.text(1000), b.text(1000));
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = CorpusGen::new(CorpusCfg::default());
        let mut b = CorpusGen::new(CorpusCfg { seed: 1, ..CorpusCfg::default() });
        assert_ne!(a.text(1000), b.text(1000));
    }

    #[test]
    fn zipf_head_dominates() {
        // the most frequent word should be far more common than the median
        let mut g = CorpusGen::new(CorpusCfg::default());
        let text = g.text(200_000);
        let mut counts = std::collections::HashMap::new();
        for w in text.split([' ', '.']) {
            if !w.is_empty() {
                *counts.entry(w).or_insert(0usize) += 1;
            }
        }
        let mut freqs: Vec<usize> = counts.values().copied().collect();
        freqs.sort_unstable_by(|a, b| b.cmp(a));
        assert!(freqs[0] > 20 * freqs[freqs.len() / 2]);
    }

    #[test]
    fn text_is_sentences() {
        let mut g = CorpusGen::new(CorpusCfg::default());
        let t = g.text(5000);
        assert!(t.contains(". "));
        assert!(t.split(". ").count() > 10);
    }

    #[test]
    fn bigram_structure_exists() {
        // grammar ⇒ conditional entropy < unigram entropy by a clear margin
        let mut g = CorpusGen::new(CorpusCfg::default());
        let text = g.text(400_000);
        let words: Vec<&str> = text.split([' ', '.']).filter(|w| !w.is_empty()).collect();
        let mut uni = std::collections::HashMap::new();
        let mut bi = std::collections::HashMap::new();
        for w in words.windows(2) {
            *uni.entry(w[0]).or_insert(0f64) += 1.0;
            *bi.entry((w[0], w[1])).or_insert(0f64) += 1.0;
        }
        let n = (words.len() - 1) as f64;
        let h_uni: f64 = uni.values().map(|c| -(c / n) * (c / n).log2()).sum();
        let h_joint: f64 = bi.values().map(|c| -(c / n) * (c / n).log2()).sum();
        let h_cond = h_joint - h_uni;
        assert!(
            h_cond < h_uni - 1.0,
            "no structure: H(X2|X1)={h_cond:.2} vs H(X)={h_uni:.2}"
        );
    }
}
