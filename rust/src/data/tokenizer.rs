//! Byte-pair-encoding tokenizer: train / encode / decode / save / load.
//!
//! Classic BPE over bytes with a word-boundary marker, trained on the
//! synthetic corpus. Special tokens: 0=<pad> 1=<bos> 2=<eos> 3=<mask>.

use crate::util::json::Json;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::Path;

pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;
pub const MASK: i32 = 3;
pub const N_SPECIAL: usize = 4;

/// A trained BPE vocabulary.
#[derive(Clone, Debug)]
pub struct Bpe {
    /// token id → byte string (ids < N_SPECIAL are specials)
    pub vocab: Vec<Vec<u8>>,
    /// merge ranks: (left id, right id) → merged id
    merges: HashMap<(u32, u32), u32>,
}

impl Bpe {
    /// Train a BPE of `vocab_size` total tokens on `text`.
    pub fn train(text: &str, vocab_size: usize) -> Self {
        assert!(vocab_size >= N_SPECIAL + 256 + 1, "vocab too small for bytes");
        // base vocabulary: specials + 256 bytes
        let mut vocab: Vec<Vec<u8>> = Vec::with_capacity(vocab_size);
        for name in ["<pad>", "<bos>", "<eos>", "<mask>"] {
            vocab.push(name.as_bytes().to_vec());
        }
        for b in 0..=255u8 {
            vocab.push(vec![b]);
        }
        let byte_id = |b: u8| (N_SPECIAL + b as usize) as u32;

        // word frequency table ("word" = whitespace chunk + trailing space)
        let mut word_freq: HashMap<Vec<u32>, usize> = HashMap::new();
        for w in text.split_whitespace() {
            let mut ids: Vec<u32> = w.bytes().map(byte_id).collect();
            ids.push(byte_id(b' ')); // boundary marker byte
            *word_freq.entry(ids).or_insert(0) += 1;
        }
        let mut words: Vec<(Vec<u32>, usize)> = word_freq.into_iter().collect();
        words.sort(); // determinism

        let mut merges = HashMap::new();
        while vocab.len() < vocab_size {
            // count adjacent pairs
            let mut pair_counts: HashMap<(u32, u32), usize> = HashMap::new();
            for (w, f) in &words {
                for p in w.windows(2) {
                    *pair_counts.entry((p[0], p[1])).or_insert(0) += f;
                }
            }
            // best pair (ties broken by id order for determinism)
            let Some((&best, &cnt)) = pair_counts
                .iter()
                .max_by_key(|(pair, c)| (**c, std::cmp::Reverse(**pair)))
            else {
                break;
            };
            if cnt < 2 {
                break;
            }
            let new_id = vocab.len() as u32;
            let mut bytes = vocab[best.0 as usize].clone();
            bytes.extend_from_slice(&vocab[best.1 as usize]);
            vocab.push(bytes);
            merges.insert(best, new_id);
            // apply merge to all words
            for (w, _) in words.iter_mut() {
                let mut out = Vec::with_capacity(w.len());
                let mut i = 0;
                while i < w.len() {
                    if i + 1 < w.len() && (w[i], w[i + 1]) == best {
                        out.push(new_id);
                        i += 2;
                    } else {
                        out.push(w[i]);
                        i += 1;
                    }
                }
                *w = out;
            }
        }
        Self { vocab, merges }
    }

    pub fn vocab_size(&self) -> usize {
        self.vocab.len()
    }

    /// Encode text to token ids (no BOS/EOS added).
    pub fn encode(&self, text: &str) -> Vec<i32> {
        let mut out = Vec::with_capacity(text.len() / 3);
        for w in text.split_whitespace() {
            let mut ids: Vec<u32> = w
                .bytes()
                .map(|b| (N_SPECIAL + b as usize) as u32)
                .collect();
            ids.push((N_SPECIAL + b' ' as usize) as u32);
            // iteratively apply the lowest-id merge available (id order ==
            // training order == rank order)
            loop {
                let mut best: Option<(usize, u32)> = None;
                for (i, p) in ids.windows(2).enumerate() {
                    if let Some(&m) = self.merges.get(&(p[0], p[1])) {
                        if best.map_or(true, |(_, bm)| m < bm) {
                            best = Some((i, m));
                        }
                    }
                }
                match best {
                    Some((i, m)) => {
                        ids[i] = m;
                        ids.remove(i + 1);
                    }
                    None => break,
                }
            }
            out.extend(ids.iter().map(|&x| x as i32));
        }
        out
    }

    /// Decode ids back to text (boundary bytes become spaces).
    pub fn decode(&self, ids: &[i32]) -> String {
        let mut bytes = Vec::new();
        for &id in ids {
            let id = id as usize;
            if id < N_SPECIAL || id >= self.vocab.len() {
                continue;
            }
            bytes.extend_from_slice(&self.vocab[id]);
        }
        String::from_utf8_lossy(&bytes).trim_end().to_string()
    }

    // -- persistence ---------------------------------------------------------

    pub fn save(&self, path: &Path) -> Result<()> {
        let vocab = Json::Arr(
            self.vocab
                .iter()
                .map(|v| Json::Arr(v.iter().map(|&b| Json::num(b as f64)).collect()))
                .collect(),
        );
        let merges = Json::Arr(
            self.merges
                .iter()
                .map(|(&(a, b), &m)| {
                    Json::Arr(vec![Json::num(a as f64), Json::num(b as f64), Json::num(m as f64)])
                })
                .collect(),
        );
        let j = Json::obj(vec![("vocab", vocab), ("merges", merges)]);
        if let Some(p) = path.parent() {
            std::fs::create_dir_all(p)?;
        }
        std::fs::write(path, j.to_string())?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text)?;
        let vocab = j
            .req("vocab")?
            .as_arr()
            .context("vocab")?
            .iter()
            .map(|v| v.usize_vec().into_iter().map(|b| b as u8).collect())
            .collect();
        let mut merges = HashMap::new();
        for m in j.req("merges")?.as_arr().context("merges")? {
            let v = m.usize_vec();
            anyhow::ensure!(v.len() == 3, "bad merge row");
            merges.insert((v[0] as u32, v[1] as u32), v[2] as u32);
        }
        Ok(Self { vocab, merges })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::{CorpusCfg, CorpusGen};

    fn sample() -> String {
        CorpusGen::new(CorpusCfg::default()).text(60_000)
    }

    #[test]
    fn roundtrip_identity() {
        let text = sample();
        let bpe = Bpe::train(&text, 512);
        let probe = "the quick zipa fox rela bani";
        let ids = bpe.encode(probe);
        assert_eq!(bpe.decode(&ids), probe);
    }

    #[test]
    fn merges_compress() {
        let text = sample();
        let bpe = Bpe::train(&text, 1024);
        let probe: String = text.chars().take(4000).collect();
        let n_ids = bpe.encode(&probe).len();
        // BPE on in-distribution text must beat raw bytes clearly
        assert!(
            (n_ids as f64) < 0.6 * probe.len() as f64,
            "{n_ids} ids for {} bytes",
            probe.len()
        );
    }

    #[test]
    fn vocab_size_respected() {
        let bpe = Bpe::train(&sample(), 700);
        assert_eq!(bpe.vocab_size(), 700);
    }

    #[test]
    fn ids_in_range_and_not_special() {
        let bpe = Bpe::train(&sample(), 512);
        for id in bpe.encode("zalu bani koto") {
            assert!((N_SPECIAL as i32..512).contains(&id));
        }
    }

    #[test]
    fn save_load_identical_encoding() {
        let text = sample();
        let bpe = Bpe::train(&text, 512);
        let tmp = std::env::temp_dir().join("cola_bpe_test.json");
        bpe.save(&tmp).unwrap();
        let loaded = Bpe::load(&tmp).unwrap();
        let probe: String = text.chars().take(1000).collect();
        assert_eq!(bpe.encode(&probe), loaded.encode(&probe));
        std::fs::remove_file(&tmp).ok();
    }

    #[test]
    fn training_deterministic() {
        let text = sample();
        let a = Bpe::train(&text, 400);
        let b = Bpe::train(&text, 400);
        assert_eq!(a.encode("zalu bani"), b.encode("zalu bani"));
    }
}
