//! Data pipeline: the C4 stand-in.
//!
//! The paper pre-trains on C4 streamed without repetition. This image has no
//! network and no C4, so we build the closest synthetic equivalent that
//! exercises the identical code path (DESIGN.md §6): a hierarchical-Markov
//! "grammar" corpus with Zipfian vocabulary (so there is real, learnable
//! structure and a heavy-tailed token distribution), a byte-pair-encoding
//! tokenizer trained on that corpus, sharded token storage, and an
//! epoch-free streaming batch iterator.

pub mod batcher;
pub mod corpus;
pub mod tokenizer;

pub use batcher::{BatchIter, ClsTaskGen, MlmBatchIter};
pub use corpus::CorpusGen;
pub use tokenizer::Bpe;
