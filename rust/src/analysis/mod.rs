//! `cola lint`: a dependency-free static-analysis pass over `rust/src/`
//! that turns the repo's concurrency conventions into build failures.
//!
//! Rules (details and rationale in `docs/concurrency.md`):
//!
//! | rule              | scope                  | requirement |
//! |-------------------|------------------------|-------------|
//! | `no-panic`        | serve runtime files    | no `.unwrap()`/`.expect(`/panicking macros |
//! | `safety-comment`  | all of `src/`          | `unsafe` carries a nearby `// SAFETY:` / `# Safety` |
//! | `relaxed-ordering`| all of `src/`          | `Ordering::Relaxed` carries a `relaxed:` justification |
//! | `lock-hierarchy`  | all of `src/`          | locks acquired in strictly increasing declared rank |
//! | `unknown-lock`    | all of `src/`          | every lock receiver is in the declared table |
//! | `sync-shim`       | `serve/` (not `sync.rs`)| no direct `std::sync`/`std::thread` |
//!
//! `#[cfg(test)]` regions are exempt from every rule except
//! `safety-comment`, and any rule can be waived in place with
//! `// lint: allow(<rule>): <reason>`.
//!
//! The pass is a token scanner ([`scan`]), not a compiler plugin: zero
//! dependencies, runs in milliseconds, and is self-tested both by fixture
//! strings ([`rules`]) and by linting this very crate
//! (`lint_runs_clean_on_this_repo` below) — so "the repo lints clean" is
//! itself a tier-1 test, not a CI hope.

pub mod rules;
pub mod scan;

use std::fmt;
use std::path::{Path, PathBuf};

/// One lint finding, rendered as `file:line: [rule] message`.
#[derive(Debug)]
pub struct Diagnostic {
    /// Path relative to the lint root, `/`-separated.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// Rule identifier (also the waiver key).
    pub rule: &'static str,
    pub msg: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

/// Lint every `.rs` file under `root` (recursively, deterministic order).
/// Returns the findings; an empty vec means the tree is clean.
pub fn lint_dir(root: &Path) -> std::io::Result<Vec<Diagnostic>> {
    let mut files = Vec::new();
    collect_rs(root, &mut files)?;
    files.sort();
    let mut diags = Vec::new();
    for f in &files {
        let rel: String = f
            .strip_prefix(root)
            .unwrap_or(f)
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        let src = std::fs::read_to_string(f)?;
        diags.extend(rules::lint_source(&rel, &src));
    }
    Ok(diags)
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The acceptance criterion "cola lint runs clean on the repo" as an
    /// enforced test rather than a claim: lint this crate's own `src/`.
    #[test]
    fn lint_runs_clean_on_this_repo() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
        let diags = lint_dir(&root).expect("walk src/");
        assert!(
            diags.is_empty(),
            "cola lint found {} issue(s):\n{}",
            diags.len(),
            diags.iter().map(|d| d.to_string()).collect::<Vec<_>>().join("\n")
        );
    }
}
