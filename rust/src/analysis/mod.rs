//! `cola lint`: a dependency-free, multi-pass static analyzer over the
//! crate sources that turns the repo's concurrency conventions into build
//! failures. v2 is whole-crate: a lightweight item parser ([`parse`])
//! recovers `fn` spans and a conservative name-based call graph, and two
//! interprocedural passes run on top of the per-file rules —
//!
//! | code | rule                  | pass      | requirement |
//! |------|-----------------------|-----------|-------------|
//! | L001 | `no-panic`            | per-file  | no `.unwrap()`/`.expect(`/panicking macros in serve runtime files |
//! | L002 | `safety-comment`      | per-file  | `unsafe` carries a nearby `// SAFETY:` / `# Safety` |
//! | L003 | `relaxed-ordering`    | per-file  | `Ordering::Relaxed` carries a `relaxed:` justification |
//! | L004 | `lock-hierarchy`      | per-file  | lexically nested locks in strictly increasing declared rank |
//! | L005 | `unknown-lock`        | per-file  | every lock receiver is in the declared table |
//! | L006 | `sync-shim`           | per-file  | no direct `std::sync`/`std::thread` in `serve/` |
//! | L007 | `lock-cycle`          | [`graph`] | the global acquired-before graph is acyclic |
//! | L008 | `lock-order`          | [`graph`] | no acquisition under a caller-held lock of rank ≥ its own |
//! | L009 | `blocking-under-lock` | [`graph`] | no Condvar wait / sleep / join / recv while any lock is held |
//! | L010 | `hot-path-alloc`      | [`hotpath`] | no heap allocation in the declared decode hot path |
//! | L011 | `stale-waiver`        | here      | every `lint: allow` waiver still suppresses something |
//!
//! `rust/src/` is linted under the strict [`Profile::Runtime`];
//! `rust/tests/` under [`Profile::Test`] (no-panic / sync-shim /
//! relaxed-ordering off, safety and lock rules on). Any rule can be waived
//! in place with `// lint: allow(<rule>): <reason>`; a waiver that stops
//! suppressing anything becomes an L011 finding, keeping the inventory
//! honest. Diagnostics are sorted by (file, line, rule) and CRLF input is
//! normalized in [`scan`], so output is byte-stable across platforms.
//!
//! The analyzer is self-proving at tier 1: fixture counterexamples pin
//! that every rule fires with a correct witness, and the repo's own lock
//! graph (acyclic, ascending-rank edges only) and decode hot path
//! (allocation-free, non-trivially populated) are asserted by tests below.

pub mod graph;
pub mod hotpath;
pub mod parse;
pub mod rules;
pub mod scan;

use crate::util::json::Json;
use scan::Line;
use std::collections::BTreeMap;
use std::fmt;
use std::path::{Path, PathBuf};

/// Stable diagnostic codes, one per rule. Codes are append-only: a rule
/// may be retired but its code is never reused, so baselines and tooling
/// parsing `--format json` stay valid across versions.
const RULE_CODES: &[(&str, &str)] = &[
    ("no-panic", "L001"),
    ("safety-comment", "L002"),
    ("relaxed-ordering", "L003"),
    ("lock-hierarchy", "L004"),
    ("unknown-lock", "L005"),
    ("sync-shim", "L006"),
    ("lock-cycle", "L007"),
    ("lock-order", "L008"),
    ("blocking-under-lock", "L009"),
    ("hot-path-alloc", "L010"),
    ("stale-waiver", "L011"),
];

/// The stable code for a rule name (`"L000"` for unknown rules, which
/// only fixture tests can produce).
pub fn rule_code(rule: &str) -> &'static str {
    RULE_CODES.iter().find(|&&(r, _)| r == rule).map_or("L000", |&(_, c)| c)
}

/// Which rule profile a file is linted under.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Profile {
    /// `rust/src/`: every rule.
    Runtime,
    /// `rust/tests/`: `safety-comment` + lock rules + whole-crate passes;
    /// `no-panic`, `sync-shim`, and `relaxed-ordering` off.
    Test,
}

/// One lint finding, rendered as `file:line: [code rule] message`.
#[derive(Debug)]
pub struct Diagnostic {
    /// Path relative to the lint root, `/`-separated (`tests/…` for the
    /// test tree).
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// Rule identifier (also the waiver key).
    pub rule: &'static str,
    /// Stable diagnostic code (`L001`…), see [`rule_code`].
    pub code: &'static str,
    pub msg: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{} {}] {}", self.file, self.line, self.code, self.rule, self.msg)
    }
}

/// Push a diagnostic (0-based line in, 1-based out), filling the code.
pub(crate) fn diag(out: &mut Vec<Diagnostic>, rel: &str, i: usize, rule: &'static str, msg: String) {
    out.push(Diagnostic { file: rel.to_string(), line: i + 1, rule, code: rule_code(rule), msg });
}

/// One scanned + parsed source file, shared by every pass.
pub(crate) struct FileData {
    pub(crate) rel: String,
    pub(crate) profile: Profile,
    pub(crate) lines: Vec<Line>,
    pub(crate) fns: Vec<parse::FnItem>,
    /// Innermost owning fn per line (`usize::MAX` = module level).
    pub(crate) owners: Vec<usize>,
}

/// One waiver comment: `// lint: allow(<rule>): <reason>`.
pub(crate) struct Waiver {
    pub(crate) line: usize,
    pub(crate) rule: String,
    pub(crate) used: bool,
}

/// The waivers of one file, with usage tracking for `stale-waiver`.
pub(crate) struct Waivers {
    pub(crate) list: Vec<Waiver>,
}

impl Waivers {
    /// Collect waivers from the comment channel. Only comments that *start*
    /// with `lint: allow(` count — doc-comment prose quoting the syntax
    /// (as this module's own docs do) never creates a phantom waiver.
    pub(crate) fn collect(lines: &[Line]) -> Waivers {
        let mut list = Vec::new();
        for (i, l) in lines.iter().enumerate() {
            let t = l.comment.trim_start();
            if let Some(rest) = t.strip_prefix("lint: allow(") {
                if let Some(end) = rest.find(')') {
                    list.push(Waiver { line: i, rule: rest[..end].to_string(), used: false });
                }
            }
        }
        Waivers { list }
    }

    /// Is `rule` waived at (0-based) `line` — same line as the waiver or
    /// the two below it? Marks every matching waiver as used.
    pub(crate) fn check(&mut self, line: usize, rule: &str) -> bool {
        let mut hit = false;
        for w in &mut self.list {
            if w.rule == rule && w.line <= line && line <= w.line + 2 {
                w.used = true;
                hit = true;
            }
        }
        hit
    }
}

/// Everything one analysis run produces: the findings plus the whole-crate
/// structures the tier-1 non-vacuity tests (and `--dump-lock-graph`)
/// inspect.
pub struct Analysis {
    /// All findings, sorted by (file, line, rule).
    pub diagnostics: Vec<Diagnostic>,
    pub lock_graph: graph::LockGraphInfo,
    pub hot: hotpath::HotPathInfo,
}

/// Analyze a set of in-memory sources: `(rel path, source, profile)`.
/// This is the single pipeline behind [`analyze_repo`] and the fixture
/// tests — per-file rules, then the interprocedural lock and hot-path
/// passes, then stale-waiver accounting over the combined usage.
pub fn analyze_sources(files: &[(String, String, Profile)]) -> Analysis {
    let mut fds: Vec<FileData> = Vec::new();
    let mut ws: Vec<Waivers> = Vec::new();
    for (rel, src, profile) in files {
        let lines = scan::scan(src);
        let fns = parse::parse_fns(&lines);
        let owners = parse::line_owners(lines.len(), &fns);
        ws.push(Waivers::collect(&lines));
        fds.push(FileData { rel: rel.clone(), profile: *profile, lines, fns, owners });
    }
    let mut diags = Vec::new();
    for (fd, w) in fds.iter().zip(ws.iter_mut()) {
        rules::run_rules(&fd.rel, &fd.lines, fd.profile, w, &mut diags);
    }
    let lock_graph = graph::run(&fds, &mut ws, &mut diags);
    let hot = hotpath::run(&fds, &mut ws, &mut diags);
    stale_waivers(&fds, &mut ws, &mut diags);
    diags.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    Analysis { diagnostics: diags, lock_graph, hot }
}

/// Emit `stale-waiver` (L011) for every waiver no pass consulted. Runs
/// last so usage from all passes is visible. Waivers inside `#[cfg(test)]`
/// regions of runtime files are out of every rule's scope and skipped.
fn stale_waivers(fds: &[FileData], ws: &mut [Waivers], out: &mut Vec<Diagnostic>) {
    for fi in 0..fds.len() {
        for idx in 0..ws[fi].list.len() {
            let (line, rule, used) = {
                let w = &ws[fi].list[idx];
                (w.line, w.rule.clone(), w.used)
            };
            if used || rule == "stale-waiver" {
                continue;
            }
            if fds[fi].profile == Profile::Runtime && fds[fi].lines[line].in_test {
                continue;
            }
            if ws[fi].check(line, "stale-waiver") {
                continue;
            }
            let msg = if RULE_CODES.iter().any(|&(r, _)| r == rule) {
                format!(
                    "waiver `lint: allow({rule})` no longer suppresses anything — the code \
                     it excused is gone or clean; delete the waiver"
                )
            } else {
                format!(
                    "waiver names unknown rule `{rule}` — it can never suppress anything \
                     (see the rule table in docs/concurrency.md)"
                )
            };
            diag(out, &fds[fi].rel, line, "stale-waiver", msg);
        }
    }
}

/// Analyze a source tree on disk: `src_root` under [`Profile::Runtime`]
/// and, when given and present, `tests_root` under [`Profile::Test`] with
/// rel paths prefixed `tests/`.
pub fn analyze_repo(src_root: &Path, tests_root: Option<&Path>) -> std::io::Result<Analysis> {
    let mut inputs = Vec::new();
    push_tree(src_root, "", Profile::Runtime, &mut inputs)?;
    if let Some(tr) = tests_root {
        if tr.is_dir() {
            push_tree(tr, "tests/", Profile::Test, &mut inputs)?;
        }
    }
    Ok(analyze_sources(&inputs))
}

fn push_tree(
    root: &Path,
    prefix: &str,
    profile: Profile,
    out: &mut Vec<(String, String, Profile)>,
) -> std::io::Result<()> {
    let mut files = Vec::new();
    collect_rs(root, &mut files)?;
    files.sort();
    for f in &files {
        let rel: String = f
            .strip_prefix(root)
            .unwrap_or(f)
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        out.push((format!("{prefix}{rel}"), std::fs::read_to_string(f)?, profile));
    }
    Ok(())
}

/// Lint every `.rs` file under `root` (strict profile), returning the
/// findings. Kept as the simple entry point for `--root DIR` runs.
pub fn lint_dir(root: &Path) -> std::io::Result<Vec<Diagnostic>> {
    Ok(analyze_repo(root, None)?.diagnostics)
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Baseline ratchet + JSON report
// ---------------------------------------------------------------------------

/// A findings baseline: per-`(file, code)` counts of accepted debt. The
/// ratchet suppresses up to the recorded count per key, so a new rule can
/// land against tracked debt while any *new* finding (or any file going
/// from N to N+1) still fails the build. Line numbers are deliberately not
/// part of the key — unrelated edits move lines without changing debt.
#[derive(Debug, Default)]
pub struct Baseline {
    counts: BTreeMap<String, usize>,
}

impl Baseline {
    pub fn from_diags(diags: &[Diagnostic]) -> Baseline {
        let mut counts = BTreeMap::new();
        for d in diags {
            *counts.entry(format!("{}|{}", d.file, d.code)).or_insert(0) += 1;
        }
        Baseline { counts }
    }

    pub fn parse(text: &str) -> anyhow::Result<Baseline> {
        let j = Json::parse(text)?;
        let mut counts = BTreeMap::new();
        if let Some(Json::Obj(m)) = j.get("counts") {
            for (k, v) in m {
                counts.insert(
                    k.clone(),
                    v.as_usize()
                        .ok_or_else(|| anyhow::anyhow!("baseline count for `{k}` not a number"))?,
                );
            }
        } else {
            anyhow::bail!("baseline missing `counts` object");
        }
        Ok(Baseline { counts })
    }

    pub fn render(&self) -> String {
        let counts =
            Json::Obj(self.counts.iter().map(|(k, v)| (k.clone(), Json::num(*v as f64))).collect());
        format!(
            "{}\n",
            Json::obj(vec![("tool", Json::s("cola-lint")), ("version", Json::num(1.0)), (
                "counts", counts
            )])
        )
    }

    /// Split `diags` into (kept, suppressed-count), consuming up to the
    /// baselined count per `(file, code)` in diagnostic order.
    pub fn apply(&self, diags: Vec<Diagnostic>) -> (Vec<Diagnostic>, usize) {
        let mut budget = self.counts.clone();
        let mut kept = Vec::new();
        let mut suppressed = 0;
        for d in diags {
            let key = format!("{}|{}", d.file, d.code);
            match budget.get_mut(&key) {
                Some(n) if *n > 0 => {
                    *n -= 1;
                    suppressed += 1;
                }
                _ => kept.push(d),
            }
        }
        (kept, suppressed)
    }
}

/// Render findings as the machine-readable report `scripts/verify.sh`
/// archives next to `BENCH_serve.json`.
pub fn render_json(diags: &[Diagnostic], suppressed: usize) -> String {
    let findings = Json::Arr(
        diags
            .iter()
            .map(|d| {
                Json::obj(vec![
                    ("file", Json::s(&d.file)),
                    ("line", Json::num(d.line as f64)),
                    ("code", Json::s(d.code)),
                    ("rule", Json::s(d.rule)),
                    ("msg", Json::s(&d.msg)),
                ])
            })
            .collect(),
    );
    format!(
        "{}\n",
        Json::obj(vec![
            ("tool", Json::s("cola-lint")),
            ("version", Json::num(2.0)),
            ("total", Json::num(diags.len() as f64)),
            ("suppressed_by_baseline", Json::num(suppressed as f64)),
            ("findings", findings),
        ])
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn repo_analysis() -> Analysis {
        let root = Path::new(env!("CARGO_MANIFEST_DIR"));
        analyze_repo(&root.join("src"), Some(&root.join("tests"))).expect("walk repo")
    }

    /// The acceptance criterion "cola lint runs clean on the repo" as an
    /// enforced test rather than a claim — now whole-crate (src strict +
    /// tests relaxed, interprocedural passes included).
    #[test]
    fn lint_runs_clean_on_this_repo() {
        let an = repo_analysis();
        assert!(
            an.diagnostics.is_empty(),
            "cola lint found {} issue(s):\n{}",
            an.diagnostics.len(),
            an.diagnostics.iter().map(|d| d.to_string()).collect::<Vec<_>>().join("\n")
        );
    }

    /// The repo's global acquired-before graph is acyclic — every edge
    /// ascends in rank, which makes cycles impossible — and the assertion
    /// is not vacuous: the pass really saw the declared locks' sites.
    #[test]
    fn repo_lock_graph_is_acyclic_and_nonvacuous() {
        let an = repo_analysis();
        let rank = |class: &str| {
            rules::LOCK_CLASSES.iter().find(|&&(_, _, c)| c == class).map(|&(_, r, _)| r)
        };
        for e in &an.lock_graph.edges {
            assert!(
                rank(e.from) < rank(e.to),
                "acquired-before edge does not ascend in rank: {e:?}"
            );
        }
        let count = |class: &str| {
            an.lock_graph
                .acquisitions
                .iter()
                .find(|&&(c, _)| c == class)
                .map_or(0, |&(_, n)| n)
        };
        assert!(count("queue-inner") >= 4, "queue lock sites seen: {:?}", an.lock_graph);
        assert!(count("pool-workers") >= 1, "pool lock sites seen: {:?}", an.lock_graph);
        assert!(count("runtime-compile-cache") >= 1, "compile cache seen: {:?}", an.lock_graph);
        // PR 10's supervision locks: the breaker state machine takes its
        // lock in record_success/record_failure/admit_with/try_admit/state/
        // snapshot; the supervisor lifecycle in try_restart/restarts_used
        assert!(count("breaker-state") >= 4, "breaker lock sites seen: {:?}", an.lock_graph);
        assert!(
            count("supervisor-lifecycle") >= 1,
            "lifecycle lock sites seen: {:?}",
            an.lock_graph
        );
        // the compile cache is held across Executor::compile_file
        assert!(
            an.lock_graph.called_under_lock.iter().any(|f| f == "compile_file"),
            "context propagation reached compile_file: {:?}",
            an.lock_graph.called_under_lock
        );
    }

    /// PR 5's "steady-state decode loop is allocation-free" claim, pinned:
    /// the engine's `decode_loop` is the declared hot root, the walk
    /// genuinely reaches the admission/sweep/drain helpers, and (via
    /// `lint_runs_clean_on_this_repo`) none of them allocates.
    #[test]
    fn repo_decode_hot_path_is_allocation_free_and_nonvacuous() {
        let an = repo_analysis();
        assert_eq!(an.hot.roots, vec!["decode_loop"], "declared hot roots");
        let expected = [
            "decode_loop",
            "refill_slots",
            "shed_dead_queued",
            "sweep",
            "push_token",
            "feed_tokens_into",
            "drain_where_into",
            "admit",
            "complete_unstarted",
        ];
        for name in expected {
            assert!(
                an.hot.reached.iter().any(|f| f == name),
                "hot set misses `{name}`: {:?}",
                an.hot.reached
            );
        }
        assert!(
            an.hot.boundaries.iter().any(|f| f == "decode_step"),
            "backend decode_step is the declared boundary: {:?}",
            an.hot.boundaries
        );
    }

    /// Fixture D: a waiver that suppresses nothing is itself a finding;
    /// a used waiver and a waived stale-waiver are not.
    #[test]
    fn stale_waivers_fire_and_used_waivers_do_not() {
        let stale = "// lint: allow(no-panic): excused code is long gone\nfn f() { g(); }\n";
        let an = analyze_sources(&[("serve/queue.rs".into(), stale.into(), Profile::Runtime)]);
        assert_eq!(an.diagnostics.len(), 1, "got: {:?}", an.diagnostics);
        let d = &an.diagnostics[0];
        assert_eq!((d.rule, d.code, d.line), ("stale-waiver", "L011", 1));

        let used = "// lint: allow(no-panic): fixture\nfn f() { x.unwrap(); }\n";
        let an = analyze_sources(&[("serve/queue.rs".into(), used.into(), Profile::Runtime)]);
        assert!(an.diagnostics.is_empty(), "used waiver is not stale: {:?}", an.diagnostics);

        let unknown = "// lint: allow(no-such-rule): typo\nfn f() { g(); }\n";
        let an = analyze_sources(&[("serve/queue.rs".into(), unknown.into(), Profile::Runtime)]);
        assert_eq!(an.diagnostics.len(), 1);
        assert!(an.diagnostics[0].msg.contains("unknown rule"), "{}", an.diagnostics[0].msg);

        let waived_stale = "// lint: allow(stale-waiver): kept for the next PR\n\
                            // lint: allow(no-panic): will return\nfn f() { g(); }\n";
        let an =
            analyze_sources(&[("serve/queue.rs".into(), waived_stale.into(), Profile::Runtime)]);
        assert!(an.diagnostics.is_empty(), "waived stale-waiver: {:?}", an.diagnostics);
    }

    /// Output is independent of input file order: sorted by
    /// (file, line, rule).
    #[test]
    fn diagnostics_are_sorted_and_order_independent() {
        let a = ("serve/queue.rs".to_string(), "fn f() { x.unwrap(); }\n".to_string(),
                 Profile::Runtime);
        let b = ("serve/engine.rs".to_string(),
                 "fn g() { y.unwrap(); }\nfn h() { panic!(\"x\"); }\n".to_string(),
                 Profile::Runtime);
        let fwd = analyze_sources(&[a.clone(), b.clone()]);
        let rev = analyze_sources(&[b, a]);
        let key = |an: &Analysis| -> Vec<String> {
            an.diagnostics.iter().map(|d| d.to_string()).collect()
        };
        assert_eq!(key(&fwd), key(&rev));
        let files: Vec<&str> = fwd.diagnostics.iter().map(|d| d.file.as_str()).collect();
        assert_eq!(files, vec!["serve/engine.rs", "serve/engine.rs", "serve/queue.rs"]);
        assert!(fwd.diagnostics[0].line <= fwd.diagnostics[1].line);
    }

    #[test]
    fn json_report_carries_codes_and_roundtrips() {
        let an = analyze_sources(&[(
            "serve/queue.rs".into(),
            "fn f() { x.unwrap(); }\n".into(),
            Profile::Runtime,
        )]);
        let report = render_json(&an.diagnostics, 3);
        let j = Json::parse(&report).expect("valid json");
        assert_eq!(j.get("tool").unwrap().as_str().unwrap(), "cola-lint");
        assert_eq!(j.get("total").unwrap().as_usize().unwrap(), 1);
        assert_eq!(j.get("suppressed_by_baseline").unwrap().as_usize().unwrap(), 3);
        let f = &j.get("findings").unwrap().as_arr().unwrap()[0];
        assert_eq!(f.get("code").unwrap().as_str().unwrap(), "L001");
        assert_eq!(f.get("rule").unwrap().as_str().unwrap(), "no-panic");
        assert_eq!(f.get("line").unwrap().as_usize().unwrap(), 1);
    }

    #[test]
    fn baseline_ratchets_but_admits_no_new_findings() {
        let src = "fn f() { x.unwrap(); }\nfn g() { y.unwrap(); }\n";
        let an = analyze_sources(&[("serve/queue.rs".into(), src.into(), Profile::Runtime)]);
        assert_eq!(an.diagnostics.len(), 2);
        let base = Baseline::from_diags(&an.diagnostics);
        // same debt: everything suppressed
        let (kept, n) = base.apply(analyze_sources(&[(
            "serve/queue.rs".into(),
            src.into(),
            Profile::Runtime,
        )]).diagnostics);
        assert!(kept.is_empty());
        assert_eq!(n, 2);
        // one more finding in the same file: exactly the overflow survives
        let worse = "fn f() { x.unwrap(); }\nfn g() { y.unwrap(); }\nfn h() { z.unwrap(); }\n";
        let (kept, n) = base.apply(analyze_sources(&[(
            "serve/queue.rs".into(),
            worse.into(),
            Profile::Runtime,
        )]).diagnostics);
        assert_eq!((kept.len(), n), (1, 2));
        // a different file is never covered by this file's debt
        let (kept, _) = base.apply(analyze_sources(&[(
            "serve/engine.rs".into(),
            "fn f() { x.unwrap(); }\n".into(),
            Profile::Runtime,
        )]).diagnostics);
        assert_eq!(kept.len(), 1);
        // render -> parse roundtrip preserves the ratchet
        let re = Baseline::parse(&base.render()).expect("roundtrip");
        assert_eq!(re.counts, base.counts);
    }

    #[test]
    fn rule_codes_are_unique_and_stable() {
        let mut codes: Vec<&str> = RULE_CODES.iter().map(|&(_, c)| c).collect();
        codes.sort_unstable();
        codes.dedup();
        assert_eq!(codes.len(), RULE_CODES.len(), "duplicate code in RULE_CODES");
        assert_eq!(rule_code("no-panic"), "L001");
        assert_eq!(rule_code("stale-waiver"), "L011");
        assert_eq!(rule_code("not-a-rule"), "L000");
    }
}
