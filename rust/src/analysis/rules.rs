//! The per-file `cola lint` rule set. Each rule matches on the scanned
//! code/comment channels of [`super::scan`] — see `docs/concurrency.md` for
//! the rule catalogue, the waiver syntax, and the declared lock hierarchy.
//! The whole-crate passes ([`super::graph`], [`super::hotpath`]) build on
//! the same lock table and low-level matchers exported from here.
//!
//! # Waivers
//!
//! `// lint: allow(<rule>): <reason>` suppresses `<rule>` on its own line
//! and on the two lines below it. The reason is mandatory by convention
//! (the lint does not parse it, reviewers do). A waiver that suppresses
//! nothing is itself a finding (`stale-waiver`, emitted by [`super`]).
//!
//! # Honest limitations
//!
//! This is a token-level lint, not a type checker. The `lock-hierarchy`
//! rule tracks guards *lexically* (a guard-preserving `let` binding is
//! considered held until its block's brace depth unwinds, or an explicit
//! `drop(<name>)`); acquisitions hidden behind a call boundary are the
//! interprocedural pass's job ([`super::graph`]), and the runtime rank
//! check in `serve::sync` (debug builds) backstops both.

use super::scan::{find_word, is_word, scan, Line};
use super::{diag, Diagnostic, Profile, Waivers};

/// Files (relative to the lint root) whose **runtime** code must be
/// panic-free: they run on serve worker threads, where a panic strands the
/// requests parked on that worker. Deliberately excludes `serve/mock.rs`
/// (a test backend whose builders assert on misuse) and `serve/model.rs`
/// (reference models driven only by tests).
const NO_PANIC_FILES: &[&str] = &[
    "serve/engine.rs",
    "serve/fault.rs",
    "serve/kvcache.rs",
    "serve/kvcodec.rs",
    "serve/mod.rs",
    "serve/queue.rs",
    "serve/router.rs",
    "serve/service.rs",
    "serve/slots.rs",
    "serve/supervisor.rs",
    "serve/sync.rs",
];

/// Method-call panic patterns (matched as substrings of blanked code).
const PANIC_METHODS: &[&str] = &[".unwrap()", ".expect("];

/// Panicking macros (matched word-boundary + `!`).
const PANIC_MACROS: &[&str] = &["panic", "unreachable", "todo", "unimplemented"];

/// The declared lock hierarchy: `(receiver ident, rank, class name)`.
/// Locks may only be acquired in strictly increasing rank order within a
/// thread. Receivers are classified by the field/binding name the guard is
/// taken from — add new locks here (and to `serve::sync::LockRank` when
/// they live in the serve tier).
pub(crate) const LOCK_CLASSES: &[(&str, u8, &str)] = &[
    ("workers", 0, "pool-workers"),
    ("inner", 1, "queue-inner"),
    ("shard", 2, "kv-shard"),
    ("lifecycle", 3, "supervisor-lifecycle"),
    ("breaker", 4, "breaker-state"),
    ("compiled", 5, "runtime-compile-cache"),
];

/// How far above a `Ordering::Relaxed` use its `relaxed:` justification may
/// sit. Wide enough that a type-level doc comment (justifying the policy
/// once for all methods of a wrapper like `serve::sync::Counter`) counts.
const RELAXED_WINDOW: usize = 24;

/// How far above an `unsafe` its `SAFETY:` / `# Safety` comment may sit.
const SAFETY_WINDOW: usize = 12;

/// Run the per-file rules for one file under the given profile.
///
/// The `Test` profile (integration tests under `rust/tests/`) keeps
/// `safety-comment` and the lock rules — test-only `unsafe` and lock
/// misuse are real bugs — but drops `no-panic` (asserting is what tests
/// do), `sync-shim` (tests may drive raw primitives to probe them), and
/// `relaxed-ordering` (test counters carry no doc obligations).
pub(crate) fn run_rules(
    rel: &str,
    lines: &[Line],
    profile: Profile,
    w: &mut Waivers,
    out: &mut Vec<Diagnostic>,
) {
    if profile == Profile::Runtime {
        no_panic(rel, lines, w, out);
        relaxed_ordering(rel, lines, w, out);
        sync_shim(rel, lines, w, out);
    }
    safety_comment(rel, lines, w, out);
    lock_hierarchy(rel, lines, w, out);
}

/// Lint one file standalone under the strict profile (fixture-test entry
/// point; the whole-crate passes and stale-waiver detection only run via
/// [`super::analyze_sources`]).
pub fn lint_source(rel: &str, source: &str) -> Vec<Diagnostic> {
    let lines = scan(source);
    let mut w = Waivers::collect(&lines);
    let mut diags = Vec::new();
    run_rules(rel, &lines, Profile::Runtime, &mut w, &mut diags);
    diags
}

/// Does `code` invoke macro `name` (word-boundary match followed by `!`)?
pub(crate) fn macro_called(code: &str, name: &str) -> bool {
    let chars: Vec<char> = code.chars().collect();
    let Some(p) = find_word(code, name) else { return false };
    chars.get(p + name.chars().count()) == Some(&'!')
}

// ---------------------------------------------------------------------------
// Rule: no-panic (L001)
// ---------------------------------------------------------------------------

fn no_panic(rel: &str, lines: &[Line], w: &mut Waivers, out: &mut Vec<Diagnostic>) {
    if !NO_PANIC_FILES.contains(&rel) {
        return;
    }
    for (i, line) in lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        for &m in PANIC_METHODS {
            if line.code.contains(m) && !w.check(i, "no-panic") {
                diag(
                    out,
                    rel,
                    i,
                    "no-panic",
                    format!(
                        "`{m}` in a serve runtime path — propagate with `?`/`.context(..)` \
                         or waive with `// lint: allow(no-panic): <reason>`"
                    ),
                );
            }
        }
        for &m in PANIC_MACROS {
            if macro_called(&line.code, m) && !w.check(i, "no-panic") {
                diag(
                    out,
                    rel,
                    i,
                    "no-panic",
                    format!(
                        "`{m}!` in a serve runtime path — a panicking worker strands its \
                         requests; return an error instead"
                    ),
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Rule: safety-comment (L002)
// ---------------------------------------------------------------------------

fn safety_comment(rel: &str, lines: &[Line], w: &mut Waivers, out: &mut Vec<Diagnostic>) {
    for (i, line) in lines.iter().enumerate() {
        if find_word(&line.code, "unsafe").is_none() {
            continue;
        }
        let justified = (i.saturating_sub(SAFETY_WINDOW)..=i).any(|j| {
            lines[j].comment.contains("SAFETY:") || lines[j].comment.contains("# Safety")
        });
        if !justified && !w.check(i, "safety-comment") {
            diag(
                out,
                rel,
                i,
                "safety-comment",
                format!(
                    "`unsafe` without a `// SAFETY:` comment (or `# Safety` doc section) \
                     within the preceding {SAFETY_WINDOW} lines"
                ),
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Rule: relaxed-ordering (L003)
// ---------------------------------------------------------------------------

fn relaxed_ordering(rel: &str, lines: &[Line], w: &mut Waivers, out: &mut Vec<Diagnostic>) {
    for (i, line) in lines.iter().enumerate() {
        if line.in_test || !line.code.contains("Ordering::Relaxed") {
            continue;
        }
        let justified = (i.saturating_sub(RELAXED_WINDOW)..=i)
            .any(|j| lines[j].comment.contains("relaxed:"));
        if !justified && !w.check(i, "relaxed-ordering") {
            diag(
                out,
                rel,
                i,
                "relaxed-ordering",
                format!(
                    "`Ordering::Relaxed` without a `relaxed:` justification comment within \
                     the preceding {RELAXED_WINDOW} lines — say why weak ordering is sound \
                     here, or use a `serve::sync` typed atomic"
                ),
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Rule: lock-hierarchy (L004) / unknown-lock (L005)
// ---------------------------------------------------------------------------

/// Positions (char index of the `.`) of lock acquisitions in `code`.
pub(crate) fn lock_calls(code: &str) -> Vec<usize> {
    let mut sites = Vec::new();
    for pat in [".lock_or_poisoned(", ".lock("] {
        let mut from = 0;
        while let Some(p) = code[from..].find(pat) {
            sites.push(from + p);
            from += p + pat.len();
        }
    }
    sites.sort_unstable();
    sites
}

/// The receiver ident a lock call is made on: the last `.`-separated path
/// segment before the call (`self.inner.lock_or_poisoned()` → `inner`).
pub(crate) fn receiver_ident(code: &str, dot: usize) -> String {
    let chars: Vec<char> = code.chars().collect();
    let mut start = dot;
    while start > 0 && (is_word(chars[start - 1]) || chars[start - 1] == '.') {
        start -= 1;
    }
    let path: String = chars[start..dot].iter().collect();
    path.rsplit('.').find(|s| !s.is_empty()).unwrap_or("").to_string()
}

/// `let [mut] <name> = …` binding name of a line, if any.
pub(crate) fn let_binding(code: &str) -> Option<String> {
    let t = code.trim_start();
    let rest = t.strip_prefix("let ")?.trim_start();
    let rest = rest.strip_prefix("mut ").unwrap_or(rest).trim_start();
    let name: String = rest.chars().take_while(|&c| is_word(c)).collect();
    (!name.is_empty()).then_some(name)
}

/// Idents passed to `drop(..)` on this line (releases a named guard early).
pub(crate) fn dropped_idents(code: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(p) = code[from..].find("drop(") {
        let abs = from + p;
        let name: String = code[abs + "drop(".len()..]
            .chars()
            .take_while(|&c| is_word(c))
            .collect();
        if !name.is_empty() {
            out.push(name);
        }
        from = abs + "drop(".len();
    }
    out
}

/// Classify the guard produced by the lock call at `dot`: `Some(binding)`
/// if the guard outlives the line under a `let` binding, `None` if it is a
/// temporary dropped at end of statement.
///
/// Follows the method chain after the call's closing paren: `.unwrap()` /
/// `.expect(..)` are guard-preserving (the chain still yields the guard);
/// any other chained method *consumes* the temporary — so
/// `let h = q.lock_or_poisoned().drain(..).collect();` binds a `Vec`, not
/// a guard, while `let g = m.lock().unwrap();` binds the guard.
pub(crate) fn guard_binding(code: &str, dot: usize) -> Option<String> {
    let binding = let_binding(code)?;
    let chars: Vec<char> = code.chars().collect();
    let mut i = dot;
    while i < chars.len() && chars[i] != '(' {
        i += 1;
    }
    loop {
        // `i` sits on an opening paren: find its match on this line
        let mut depth = 0i32;
        while i < chars.len() {
            match chars[i] {
                '(' => depth += 1,
                ')' => {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                _ => {}
            }
            i += 1;
        }
        if i >= chars.len() {
            // call spans lines; conservatively treat the guard as bound
            return Some(binding);
        }
        i += 1;
        while chars.get(i) == Some(&' ') {
            i += 1;
        }
        if chars.get(i) != Some(&'.') {
            return Some(binding);
        }
        i += 1;
        let start = i;
        while i < chars.len() && is_word(chars[i]) {
            i += 1;
        }
        let method: String = chars[start..i].iter().collect();
        if method != "unwrap" && method != "expect" {
            return None;
        }
        while i < chars.len() && chars[i] != '(' {
            i += 1;
        }
        if i >= chars.len() {
            return Some(binding);
        }
    }
}

/// A lexically-held lock guard.
struct Held {
    rank: u8,
    class: &'static str,
    /// Brace depth of the line that took the guard; released when a later
    /// line starts below it.
    depth: usize,
    binding: Option<String>,
}

fn lock_hierarchy(rel: &str, lines: &[Line], w: &mut Waivers, out: &mut Vec<Diagnostic>) {
    if rel == "serve/sync.rs" {
        // The shim *implements* ranked locking (and checks it at runtime in
        // debug builds); its internal std lock is below the hierarchy.
        return;
    }
    let mut held: Vec<Held> = Vec::new();
    for (i, line) in lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        held.retain(|g| line.depth >= g.depth);
        for name in dropped_idents(&line.code) {
            held.retain(|g| g.binding.as_deref() != Some(name.as_str()));
        }
        for dot in lock_calls(&line.code) {
            let recv = receiver_ident(&line.code, dot);
            let Some(&(_, rank, class)) =
                LOCK_CLASSES.iter().find(|&&(r, _, _)| r == recv)
            else {
                if !w.check(i, "unknown-lock") {
                    diag(
                        out,
                        rel,
                        i,
                        "unknown-lock",
                        format!(
                            "lock acquired through receiver `{recv}` which is not in the \
                             declared lock table — add it to `analysis::rules::LOCK_CLASSES` \
                             with a rank (see docs/concurrency.md)"
                        ),
                    );
                }
                continue;
            };
            if let Some(g) = held.iter().find(|g| g.rank >= rank) {
                if !w.check(i, "lock-hierarchy") {
                    diag(
                        out,
                        rel,
                        i,
                        "lock-hierarchy",
                        format!(
                            "acquiring `{class}` (rank {rank}) while holding `{held}` (rank \
                             {hrank}) — locks must be taken in strictly increasing rank order",
                            held = g.class,
                            hrank = g.rank,
                        ),
                    );
                }
            }
            if let Some(binding) = guard_binding(&line.code, dot) {
                held.push(Held { rank, class, depth: line.depth, binding: Some(binding) });
            }
            // chained/unbound acquisitions are temporaries: gone at end of
            // line (the interprocedural pass models the same-line window)
        }
    }
}

// ---------------------------------------------------------------------------
// Rule: sync-shim (L006)
// ---------------------------------------------------------------------------

fn sync_shim(rel: &str, lines: &[Line], w: &mut Waivers, out: &mut Vec<Diagnostic>) {
    if !rel.starts_with("serve/") || rel == "serve/sync.rs" {
        return;
    }
    for (i, line) in lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        for pat in ["std::sync", "std::thread"] {
            if line.code.contains(pat) && !w.check(i, "sync-shim") {
                diag(
                    out,
                    rel,
                    i,
                    "sync-shim",
                    format!(
                        "`{pat}` used directly in serve runtime code — route concurrency \
                         primitives through `crate::serve::sync` so they stay under one \
                         poison/ordering/rank policy"
                    ),
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_fired(rel: &str, src: &str) -> Vec<String> {
        lint_source(rel, src).into_iter().map(|d| d.rule.to_string()).collect()
    }

    #[test]
    fn no_panic_fires_in_scope_and_respects_tests_and_waivers() {
        let src = "fn f() { x.unwrap(); }\n";
        let d = lint_source("serve/queue.rs", src);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].rule, "no-panic");
        assert_eq!(d[0].code, "L001");
        assert_eq!(d[0].line, 1);
        assert_eq!(d[0].file, "serve/queue.rs");
        // out of scope file: clean
        assert!(lint_source("runtime/executor.rs", src).is_empty());
        // test code: clean
        let test_src = "#[cfg(test)]\nmod tests {\n    fn f() { x.unwrap(); }\n}\n";
        assert!(lint_source("serve/queue.rs", test_src).is_empty());
        // waived: clean
        let waived_src = "// lint: allow(no-panic): fixture\nfn f() { x.unwrap(); }\n";
        assert!(lint_source("serve/queue.rs", waived_src).is_empty());
        // string/comment occurrences never fire
        let masked = "fn f() { let s = \".unwrap()\"; } // .unwrap()\n";
        assert!(lint_source("serve/queue.rs", masked).is_empty());
    }

    #[test]
    fn no_panic_catches_macros_but_not_lookalikes() {
        let src = "fn f() { panic!(\"boom\"); }\n";
        assert_eq!(rules_fired("serve/engine.rs", src), vec!["no-panic"]);
        let ok = "fn f() { debug_assert!(x); my_panic_helper(); }\n";
        assert!(lint_source("serve/engine.rs", ok).is_empty());
        let expect = "fn f() { x.expect(\"reason\"); }\n";
        assert_eq!(rules_fired("serve/engine.rs", expect), vec!["no-panic"]);
    }

    #[test]
    fn safety_comment_required_for_unsafe() {
        let bad = "fn f() { unsafe { g() } }\n";
        assert_eq!(rules_fired("runtime/executor.rs", bad), vec!["safety-comment"]);
        let good = "fn f() {\n    // SAFETY: g has no preconditions here.\n    unsafe { g() }\n}\n";
        assert!(lint_source("runtime/executor.rs", good).is_empty());
        let doc = "/// # Safety\n///\n/// Caller must uphold X.\npub unsafe fn f() {}\n";
        assert!(lint_source("runtime/executor.rs", doc).is_empty());
        let lookalike = "#[allow(unused_unsafe)]\nfn f() {}\n";
        assert!(lint_source("runtime/executor.rs", lookalike).is_empty());
    }

    #[test]
    fn relaxed_ordering_requires_justification() {
        let bad = "fn f() { X.load(Ordering::Relaxed); }\n";
        assert_eq!(rules_fired("metrics/mod.rs", bad), vec!["relaxed-ordering"]);
        let good = "// relaxed: stats-only tally.\nfn f() { X.load(Ordering::Relaxed); }\n";
        assert!(lint_source("metrics/mod.rs", good).is_empty());
    }

    #[test]
    fn lock_hierarchy_flags_inversions_and_unknown_receivers() {
        // rank 1 (queue-inner) held, then rank 0 (pool-workers): inversion
        let bad = "fn f(&self) {\n    let g = self.inner.lock_or_poisoned();\n    \
                   let w = self.workers.lock_or_poisoned();\n}\n";
        assert_eq!(rules_fired("serve/service.rs", bad), vec!["lock-hierarchy"]);
        // waiver silences it
        let waived = "fn f(&self) {\n    let g = self.inner.lock_or_poisoned();\n    \
                      // lint: allow(lock-hierarchy): fixture\n    \
                      let w = self.workers.lock_or_poisoned();\n}\n";
        assert!(lint_source("serve/service.rs", waived).is_empty());
    }

    #[test]
    fn lock_hierarchy_ascending_and_scoping() {
        let asc = "fn f(&self) {\n    let w = self.workers.lock_or_poisoned();\n    \
                   let g = self.inner.lock_or_poisoned();\n}\n";
        assert!(lint_source("serve/service.rs", asc).is_empty(), "ascending ranks are legal");
        // same-rank reacquisition (self-deadlock) is flagged
        let re = "fn f(&self) {\n    let a = self.inner.lock_or_poisoned();\n    \
                  let b = self.inner.lock_or_poisoned();\n}\n";
        assert_eq!(rules_fired("serve/queue.rs", re), vec!["lock-hierarchy"]);
        // a dropped guard no longer blocks reacquisition
        let seq = "fn f(&self) {\n    let a = self.inner.lock_or_poisoned();\n    \
                   drop(a);\n    let b = self.inner.lock_or_poisoned();\n}\n";
        assert!(lint_source("serve/queue.rs", seq).is_empty());
        // scope exit releases: sibling functions don't leak guards
        let sib = "fn f(&self) {\n    let a = self.inner.lock_or_poisoned();\n}\n\
                   fn g(&self) {\n    let b = self.inner.lock_or_poisoned();\n}\n";
        assert!(lint_source("serve/queue.rs", sib).is_empty());
        // unknown receiver
        let unk = "fn f(&self) { let a = self.mystery.lock(); }\n";
        assert_eq!(rules_fired("serve/service.rs", unk), vec!["unknown-lock"]);
    }

    #[test]
    fn chained_temporary_guards_do_not_count_as_held() {
        // `.drain(..).collect()` consumes the guard at end of statement —
        // the next line's acquisition is NOT nested (ServicePool::shutdown)
        let seq = "fn f(&self) {\n    let hs: Vec<_> = \
                   self.workers.lock_or_poisoned().drain(..).collect();\n    \
                   let w = self.workers.lock_or_poisoned();\n}\n";
        assert!(lint_source("serve/service.rs", seq).is_empty(), "temporary died on its line");
        // `.lock().unwrap()` is guard-preserving: still held on later lines
        let held = "fn f(&self) {\n    let c = self.compiled.lock().unwrap();\n    \
                    let d = self.compiled.lock().unwrap();\n}\n";
        assert_eq!(
            lint_source("runtime/artifact.rs", held)
                .iter()
                .map(|d| d.rule)
                .collect::<Vec<_>>(),
            vec!["lock-hierarchy"]
        );
        let probe = |code: &str| guard_binding(code, lock_calls(code)[0]);
        assert_eq!(probe("    let g = m.lock().unwrap();"), Some("g".into()));
        assert_eq!(probe("    let n = m.lock().unwrap().len();"), None);
        assert_eq!(probe("    m.lock();"), None);
        assert_eq!(probe("    let w = self.workers.lock_or_poisoned();"), Some("w".into()));
    }

    #[test]
    fn test_profile_drops_panic_and_shim_but_keeps_safety_and_locks() {
        let src = "fn t() {\n    use std::thread;\n    x.unwrap();\n    \
                   let g = self.inner.lock_or_poisoned();\n    \
                   let w = self.workers.lock_or_poisoned();\n    unsafe { poke() }\n}\n";
        let lines = scan(src);
        let mut w = Waivers::collect(&lines);
        let mut diags = Vec::new();
        run_rules("tests/serve_interleave.rs", &lines, Profile::Test, &mut w, &mut diags);
        let mut rules: Vec<&str> = diags.iter().map(|d| d.rule).collect();
        rules.sort_unstable();
        assert_eq!(rules, vec!["lock-hierarchy", "safety-comment"], "got: {diags:?}");
    }

    #[test]
    fn diagnostics_render_as_file_line_code_rule() {
        let d = lint_source("serve/queue.rs", "fn f() { x.unwrap(); }\n");
        let rendered = d[0].to_string();
        assert!(
            rendered.starts_with("serve/queue.rs:1: [L001 no-panic]"),
            "got: {rendered}"
        );
    }
}
