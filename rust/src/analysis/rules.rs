//! The `cola lint` rule set. Each rule matches on the scanned code/comment
//! channels of [`super::scan`] — see `docs/concurrency.md` for the rule
//! catalogue, the waiver syntax, and the declared lock hierarchy.
//!
//! # Waivers
//!
//! `// lint: allow(<rule>): <reason>` suppresses `<rule>` on its own line
//! and on the two lines below it. The reason is mandatory by convention
//! (the lint does not parse it, reviewers do).
//!
//! # Honest limitations
//!
//! This is a token-level lint, not a type checker. The lock-hierarchy rule
//! tracks guards *lexically* (a `let`-bound guard is considered held until
//! its block's brace depth unwinds, or an explicit `drop(<name>)`); it
//! cannot see acquisitions hidden behind a function call boundary. The
//! runtime rank check in `serve::sync` (debug builds) covers exactly that
//! blind spot, so the two enforce the hierarchy together.

use super::Diagnostic;
use super::scan::{find_word, is_word, Line, scan};

/// Files (relative to the lint root) whose **runtime** code must be
/// panic-free: they run on serve worker threads, where a panic strands the
/// requests parked on that worker. Deliberately excludes `serve/mock.rs`
/// (a test backend whose builders assert on misuse) and `serve/model.rs`
/// (reference models driven only by tests).
const NO_PANIC_FILES: &[&str] = &[
    "serve/engine.rs",
    "serve/kvcache.rs",
    "serve/mod.rs",
    "serve/queue.rs",
    "serve/router.rs",
    "serve/service.rs",
    "serve/slots.rs",
    "serve/sync.rs",
];

/// Method-call panic patterns (matched as substrings of blanked code).
const PANIC_METHODS: &[&str] = &[".unwrap()", ".expect("];

/// Panicking macros (matched word-boundary + `!`).
const PANIC_MACROS: &[&str] = &["panic", "unreachable", "todo", "unimplemented"];

/// The declared lock hierarchy: `(receiver ident, rank, class name)`.
/// Locks may only be acquired in strictly increasing rank order within a
/// thread. Receivers are classified by the field/binding name the guard is
/// taken from — add new locks here (and to `serve::sync::LockRank` when
/// they live in the serve tier).
const LOCK_CLASSES: &[(&str, u8, &str)] = &[
    ("workers", 0, "pool-workers"),
    ("inner", 1, "queue-inner"),
    ("shard", 2, "kv-shard"),
    ("compiled", 3, "runtime-compile-cache"),
];

/// How far above a `Ordering::Relaxed` use its `relaxed:` justification may
/// sit. Wide enough that a type-level doc comment (justifying the policy
/// once for all methods of a wrapper like `serve::sync::Counter`) counts.
const RELAXED_WINDOW: usize = 24;

/// How far above an `unsafe` its `SAFETY:` / `# Safety` comment may sit.
const SAFETY_WINDOW: usize = 12;

/// Lint one file. `rel` is the path relative to the lint root, with `/`
/// separators (it selects which per-file rules apply).
pub fn lint_source(rel: &str, source: &str) -> Vec<Diagnostic> {
    let lines = scan(source);
    let mut diags = Vec::new();
    no_panic(rel, &lines, &mut diags);
    safety_comment(rel, &lines, &mut diags);
    relaxed_ordering(rel, &lines, &mut diags);
    lock_hierarchy(rel, &lines, &mut diags);
    sync_shim(rel, &lines, &mut diags);
    diags
}

/// Is rule `rule` waived at line `i` (same line or the two above)?
fn waived(lines: &[Line], i: usize, rule: &str) -> bool {
    let pat = format!("lint: allow({rule})");
    (i.saturating_sub(2)..=i).any(|j| lines[j].comment.contains(&pat))
}

fn diag(out: &mut Vec<Diagnostic>, rel: &str, i: usize, rule: &'static str, msg: String) {
    out.push(Diagnostic { file: rel.to_string(), line: i + 1, rule, msg });
}

/// Does `code` invoke macro `name` (word-boundary match followed by `!`)?
fn macro_called(code: &str, name: &str) -> bool {
    let chars: Vec<char> = code.chars().collect();
    let Some(p) = find_word(code, name) else { return false };
    chars.get(p + name.chars().count()) == Some(&'!')
}

// ---------------------------------------------------------------------------
// Rule: no-panic
// ---------------------------------------------------------------------------

fn no_panic(rel: &str, lines: &[Line], out: &mut Vec<Diagnostic>) {
    if !NO_PANIC_FILES.contains(&rel) {
        return;
    }
    for (i, line) in lines.iter().enumerate() {
        if line.in_test || waived(lines, i, "no-panic") {
            continue;
        }
        for &m in PANIC_METHODS {
            if line.code.contains(m) {
                diag(
                    out,
                    rel,
                    i,
                    "no-panic",
                    format!(
                        "`{m}` in a serve runtime path — propagate with `?`/`.context(..)` \
                         or waive with `// lint: allow(no-panic): <reason>`"
                    ),
                );
            }
        }
        for &m in PANIC_MACROS {
            if macro_called(&line.code, m) {
                diag(
                    out,
                    rel,
                    i,
                    "no-panic",
                    format!(
                        "`{m}!` in a serve runtime path — a panicking worker strands its \
                         requests; return an error instead"
                    ),
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Rule: safety-comment
// ---------------------------------------------------------------------------

fn safety_comment(rel: &str, lines: &[Line], out: &mut Vec<Diagnostic>) {
    for (i, line) in lines.iter().enumerate() {
        if find_word(&line.code, "unsafe").is_none() || waived(lines, i, "safety-comment") {
            continue;
        }
        let justified = (i.saturating_sub(SAFETY_WINDOW)..=i).any(|j| {
            lines[j].comment.contains("SAFETY:") || lines[j].comment.contains("# Safety")
        });
        if !justified {
            diag(
                out,
                rel,
                i,
                "safety-comment",
                format!(
                    "`unsafe` without a `// SAFETY:` comment (or `# Safety` doc section) \
                     within the preceding {SAFETY_WINDOW} lines"
                ),
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Rule: relaxed-ordering
// ---------------------------------------------------------------------------

fn relaxed_ordering(rel: &str, lines: &[Line], out: &mut Vec<Diagnostic>) {
    for (i, line) in lines.iter().enumerate() {
        if line.in_test
            || !line.code.contains("Ordering::Relaxed")
            || waived(lines, i, "relaxed-ordering")
        {
            continue;
        }
        let justified = (i.saturating_sub(RELAXED_WINDOW)..=i)
            .any(|j| lines[j].comment.contains("relaxed:"));
        if !justified {
            diag(
                out,
                rel,
                i,
                "relaxed-ordering",
                format!(
                    "`Ordering::Relaxed` without a `relaxed:` justification comment within \
                     the preceding {RELAXED_WINDOW} lines — say why weak ordering is sound \
                     here, or use a `serve::sync` typed atomic"
                ),
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Rule: lock-hierarchy / unknown-lock
// ---------------------------------------------------------------------------

/// Positions (char index of the `.`) of lock acquisitions in `code`.
fn lock_calls(code: &str) -> Vec<usize> {
    let mut sites = Vec::new();
    for pat in [".lock_or_poisoned(", ".lock("] {
        let mut from = 0;
        while let Some(p) = code[from..].find(pat) {
            sites.push(from + p);
            from += p + pat.len();
        }
    }
    sites.sort_unstable();
    sites
}

/// The receiver ident a lock call is made on: the last `.`-separated path
/// segment before the call (`self.inner.lock_or_poisoned()` → `inner`).
fn receiver_ident(code: &str, dot: usize) -> String {
    let chars: Vec<char> = code.chars().collect();
    let mut start = dot;
    while start > 0 && (is_word(chars[start - 1]) || chars[start - 1] == '.') {
        start -= 1;
    }
    let path: String = chars[start..dot].iter().collect();
    path.rsplit('.').find(|s| !s.is_empty()).unwrap_or("").to_string()
}

/// `let [mut] <name> = …` binding name of a line, if any.
fn let_binding(code: &str) -> Option<String> {
    let t = code.trim_start();
    let rest = t.strip_prefix("let ")?.trim_start();
    let rest = rest.strip_prefix("mut ").unwrap_or(rest).trim_start();
    let name: String = rest.chars().take_while(|&c| is_word(c)).collect();
    (!name.is_empty()).then_some(name)
}

/// Idents passed to `drop(..)` on this line (releases a named guard early).
fn dropped_idents(code: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(p) = code[from..].find("drop(") {
        let abs = from + p;
        // word boundary: don't match `mem::drop(` as-is? it is still a drop.
        let name: String = code[abs + "drop(".len()..]
            .chars()
            .take_while(|&c| is_word(c))
            .collect();
        if !name.is_empty() {
            out.push(name);
        }
        from = abs + "drop(".len();
    }
    out
}

/// A lexically-held lock guard.
struct Held {
    rank: u8,
    class: &'static str,
    /// Brace depth of the line that took the guard; released when a later
    /// line starts below it.
    depth: usize,
    binding: Option<String>,
}

fn lock_hierarchy(rel: &str, lines: &[Line], out: &mut Vec<Diagnostic>) {
    if rel == "serve/sync.rs" {
        // The shim *implements* ranked locking (and checks it at runtime in
        // debug builds); its internal std lock is below the hierarchy.
        return;
    }
    let mut held: Vec<Held> = Vec::new();
    for (i, line) in lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        held.retain(|g| line.depth >= g.depth);
        for name in dropped_idents(&line.code) {
            held.retain(|g| g.binding.as_deref() != Some(name.as_str()));
        }
        for dot in lock_calls(&line.code) {
            let recv = receiver_ident(&line.code, dot);
            let Some(&(_, rank, class)) =
                LOCK_CLASSES.iter().find(|&&(r, _, _)| r == recv)
            else {
                if !waived(lines, i, "unknown-lock") {
                    diag(
                        out,
                        rel,
                        i,
                        "unknown-lock",
                        format!(
                            "lock acquired through receiver `{recv}` which is not in the \
                             declared lock table — add it to `analysis::rules::LOCK_CLASSES` \
                             with a rank (see docs/concurrency.md)"
                        ),
                    );
                }
                continue;
            };
            if !waived(lines, i, "lock-hierarchy") {
                if let Some(g) = held.iter().find(|g| g.rank >= rank) {
                    diag(
                        out,
                        rel,
                        i,
                        "lock-hierarchy",
                        format!(
                            "acquiring `{class}` (rank {rank}) while holding `{held}` (rank \
                             {hrank}) — locks must be taken in strictly increasing rank order",
                            held = g.class,
                            hrank = g.rank,
                        ),
                    );
                }
            }
            if let_binding(&line.code).is_some() {
                held.push(Held {
                    rank,
                    class,
                    depth: line.depth,
                    binding: let_binding(&line.code),
                });
            }
            // non-`let` acquisitions are temporaries: gone at end of line
        }
    }
}

// ---------------------------------------------------------------------------
// Rule: sync-shim
// ---------------------------------------------------------------------------

fn sync_shim(rel: &str, lines: &[Line], out: &mut Vec<Diagnostic>) {
    if !rel.starts_with("serve/") || rel == "serve/sync.rs" {
        return;
    }
    for (i, line) in lines.iter().enumerate() {
        if line.in_test || waived(lines, i, "sync-shim") {
            continue;
        }
        for pat in ["std::sync", "std::thread"] {
            if line.code.contains(pat) {
                diag(
                    out,
                    rel,
                    i,
                    "sync-shim",
                    format!(
                        "`{pat}` used directly in serve runtime code — route concurrency \
                         primitives through `crate::serve::sync` so they stay under one \
                         poison/ordering/rank policy"
                    ),
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_fired(rel: &str, src: &str) -> Vec<String> {
        lint_source(rel, src).into_iter().map(|d| d.rule.to_string()).collect()
    }

    #[test]
    fn no_panic_fires_in_scope_and_respects_tests_and_waivers() {
        let src = "fn f() { x.unwrap(); }\n";
        let d = lint_source("serve/queue.rs", src);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].rule, "no-panic");
        assert_eq!(d[0].line, 1);
        assert_eq!(d[0].file, "serve/queue.rs");
        // out of scope file: clean
        assert!(lint_source("runtime/executor.rs", src).is_empty());
        // test code: clean
        let test_src = "#[cfg(test)]\nmod tests {\n    fn f() { x.unwrap(); }\n}\n";
        assert!(lint_source("serve/queue.rs", test_src).is_empty());
        // waived: clean
        let waived_src = "// lint: allow(no-panic): fixture\nfn f() { x.unwrap(); }\n";
        assert!(lint_source("serve/queue.rs", waived_src).is_empty());
        // string/comment occurrences never fire
        let masked = "fn f() { let s = \".unwrap()\"; } // .unwrap()\n";
        assert!(lint_source("serve/queue.rs", masked).is_empty());
    }

    #[test]
    fn no_panic_catches_macros_but_not_lookalikes() {
        let src = "fn f() { panic!(\"boom\"); }\n";
        assert_eq!(rules_fired("serve/engine.rs", src), vec!["no-panic"]);
        let ok = "fn f() { debug_assert!(x); my_panic_helper(); }\n";
        assert!(lint_source("serve/engine.rs", ok).is_empty());
        let expect = "fn f() { x.expect(\"reason\"); }\n";
        assert_eq!(rules_fired("serve/engine.rs", expect), vec!["no-panic"]);
    }

    #[test]
    fn safety_comment_required_for_unsafe() {
        let bad = "fn f() { unsafe { g() } }\n";
        assert_eq!(rules_fired("runtime/executor.rs", bad), vec!["safety-comment"]);
        let good = "fn f() {\n    // SAFETY: g has no preconditions here.\n    unsafe { g() }\n}\n";
        assert!(lint_source("runtime/executor.rs", good).is_empty());
        let doc = "/// # Safety\n///\n/// Caller must uphold X.\npub unsafe fn f() {}\n";
        assert!(lint_source("runtime/executor.rs", doc).is_empty());
        let lookalike = "#[allow(unused_unsafe)]\nfn f() {}\n";
        assert!(lint_source("runtime/executor.rs", lookalike).is_empty());
    }

    #[test]
    fn relaxed_ordering_requires_justification() {
        let bad = "fn f() { X.load(Ordering::Relaxed); }\n";
        assert_eq!(rules_fired("metrics/mod.rs", bad), vec!["relaxed-ordering"]);
        let good = "// relaxed: stats-only tally.\nfn f() { X.load(Ordering::Relaxed); }\n";
        assert!(lint_source("metrics/mod.rs", good).is_empty());
    }

    #[test]
    fn lock_hierarchy_flags_inversions_and_unknown_receivers() {
        // rank 1 (queue-inner) held, then rank 0 (pool-workers): inversion
        let bad = "fn f(&self) {\n    let g = self.inner.lock_or_poisoned();\n    \
                   let w = self.workers.lock_or_poisoned();\n}\n";
        assert_eq!(rules_fired("serve/service.rs", bad), vec!["lock-hierarchy"]);
        // waiver silences it
        let waived = "fn f(&self) {\n    let g = self.inner.lock_or_poisoned();\n    \
                      // lint: allow(lock-hierarchy): fixture\n    \
                      let w = self.workers.lock_or_poisoned();\n}\n";
        assert!(lint_source("serve/service.rs", waived).is_empty());
    }

    #[test]
    fn lock_hierarchy_ascending_and_scoping() {
        let asc = "fn f(&self) {\n    let w = self.workers.lock_or_poisoned();\n    \
                   let g = self.inner.lock_or_poisoned();\n}\n";
        assert!(lint_source("serve/service.rs", asc).is_empty(), "ascending ranks are legal");
        // same-rank reacquisition (self-deadlock) is flagged
        let re = "fn f(&self) {\n    let a = self.inner.lock_or_poisoned();\n    \
                  let b = self.inner.lock_or_poisoned();\n}\n";
        assert_eq!(rules_fired("serve/queue.rs", re), vec!["lock-hierarchy"]);
        // a dropped guard no longer blocks reacquisition
        let seq = "fn f(&self) {\n    let a = self.inner.lock_or_poisoned();\n    \
                   drop(a);\n    let b = self.inner.lock_or_poisoned();\n}\n";
        assert!(lint_source("serve/queue.rs", seq).is_empty());
        // scope exit releases: sibling functions don't leak guards
        let sib = "fn f(&self) {\n    let a = self.inner.lock_or_poisoned();\n}\n\
                   fn g(&self) {\n    let b = self.inner.lock_or_poisoned();\n}\n";
        assert!(lint_source("serve/queue.rs", sib).is_empty());
        // unknown receiver
        let unk = "fn f(&self) { let a = self.mystery.lock(); }\n";
        assert_eq!(rules_fired("serve/service.rs", unk), vec!["unknown-lock"]);
    }

    #[test]
    fn sync_shim_rule_confines_std_sync_to_the_shim() {
        let bad = "use std::sync::Mutex;\nfn f() {}\n";
        assert_eq!(rules_fired("serve/queue.rs", bad), vec!["sync-shim"]);
        assert!(lint_source("serve/sync.rs", bad).is_empty(), "the shim itself is exempt");
        assert!(lint_source("runtime/executor.rs", bad).is_empty(), "only serve/ is scoped");
        let test_ok = "#[cfg(test)]\nmod tests {\n    use std::thread;\n}\n";
        assert!(lint_source("serve/queue.rs", test_ok).is_empty());
    }

    #[test]
    fn diagnostics_render_as_file_line_rule() {
        let d = lint_source("serve/queue.rs", "fn f() { x.unwrap(); }\n");
        let rendered = d[0].to_string();
        assert!(
            rendered.starts_with("serve/queue.rs:1: [no-panic]"),
            "got: {rendered}"
        );
    }
}
