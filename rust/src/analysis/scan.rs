//! Line-oriented token scanner behind `cola lint`: a small character state
//! machine (no syn, no proc-macro machinery) that splits a Rust source file
//! into per-line *code* and *comment* channels and tracks just enough
//! structure — brace depth and `#[cfg(test)]` regions — for the rules in
//! [`super::rules`] to match on.
//!
//! The code channel preserves column positions: every character inside a
//! string/char literal or a comment is replaced by a space, so substring
//! matches in rules can never fire on literal or comment text, and tokens
//! can never fuse across a blanked region (`foo/*x*/bar` stays two words).
//! Handled literal forms: `"..."` with escapes, `b"..."`, raw strings
//! `r"…"`/`r#"…"#` (any hash count), char literals `'x'`/`'\n'`, and
//! lifetimes (`'a`, `'static`), which stay in the code channel. Block
//! comments nest, as in Rust.

/// One scanned source line.
#[derive(Debug)]
pub struct Line {
    /// Code text with strings/chars/comments blanked to spaces
    /// (column-preserving).
    pub code: String,
    /// Concatenated comment text of the line (line + block comments,
    /// including doc comments).
    pub comment: String,
    /// Whether any part of the line lies in a `#[cfg(test)]` region.
    pub in_test: bool,
    /// Brace depth at the start of the line.
    pub depth: usize,
}

/// The literal/comment state carried across characters.
enum State {
    Normal,
    LineComment,
    /// Nesting level of `/* ... */`.
    BlockComment(usize),
    Str,
    /// Hash count of `r#..#"..."#..#`.
    RawStr(usize),
    CharLit,
}

/// Does `src[i..]` start with `pat`?
fn starts_with_at(src: &[char], i: usize, pat: &str) -> bool {
    pat.chars().enumerate().all(|(k, p)| src.get(i + k) == Some(&p))
}

pub(crate) fn is_word(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Scan `source` into per-line code/comment channels. Never fails: input
/// that is not valid Rust (unterminated literals, stray braces) degrades to
/// best-effort channels rather than an error — the compiler owns syntax,
/// the lint only owns conventions.
pub fn scan(source: &str) -> Vec<Line> {
    let src: Vec<char> = source.chars().collect();
    let mut lines = Vec::new();
    let mut code = String::new();
    let mut comment = String::new();
    let mut state = State::Normal;
    let mut depth = 0usize;
    let mut line_depth = 0usize;
    // `#[cfg(test)]` seen; waiting for the item's `{` (a `;` first — e.g.
    // a cfg'd `use` — cancels it).
    let mut pending_test = false;
    // Depth just *outside* the open test region's brace, when inside one.
    let mut test_depth: Option<usize> = None;
    // A test region touched this line (covers regions closing mid-line).
    let mut line_touched_test = false;

    let mut i = 0;
    while i < src.len() {
        let c = src[i];
        if c == '\r' {
            // CRLF normalization: carriage returns never reach either
            // channel, so findings (and their columns) are byte-stable
            // across checkouts with different line-ending conventions.
            i += 1;
            continue;
        }
        if c == '\n' {
            if matches!(state, State::LineComment) {
                state = State::Normal;
            }
            lines.push(Line {
                code: std::mem::take(&mut code),
                comment: std::mem::take(&mut comment),
                in_test: test_depth.is_some() || line_touched_test,
                depth: line_depth,
            });
            line_depth = depth;
            line_touched_test = false;
            i += 1;
            continue;
        }
        match state {
            State::Normal => {
                if starts_with_at(&src, i, "//") {
                    state = State::LineComment;
                    code.push_str("  ");
                    i += 2;
                } else if starts_with_at(&src, i, "/*") {
                    state = State::BlockComment(1);
                    code.push_str("  ");
                    i += 2;
                } else if c == '"' {
                    state = State::Str;
                    code.push(' ');
                    i += 1;
                } else if c == 'b' && src.get(i + 1) == Some(&'"') && !prev_is_word(&src, i) {
                    // byte string: skip the prefix, the quote opens Str next
                    code.push(' ');
                    i += 1;
                } else if c == 'r'
                    && !prev_is_word(&src, i)
                    && raw_str_hashes(&src, i + 1).is_some()
                {
                    let h = raw_str_hashes(&src, i + 1).unwrap_or(0);
                    state = State::RawStr(h);
                    for _ in 0..(2 + h) {
                        code.push(' '); // r, hashes, opening quote
                    }
                    i += 2 + h;
                } else if c == '\'' {
                    // lifetime ('a, 'static) vs char literal ('x', '\n')
                    let lifetime = src.get(i + 1).is_some_and(|&n| is_word(n))
                        && src.get(i + 2) != Some(&'\'');
                    if lifetime {
                        code.push(c);
                        i += 1;
                    } else {
                        state = State::CharLit;
                        code.push(' ');
                        i += 1;
                    }
                } else {
                    if c == '#'
                        && (starts_with_at(&src, i, "#[cfg(test)]")
                            || starts_with_at(&src, i, "#[cfg(all(test"))
                    {
                        pending_test = true;
                    }
                    if c == ';' && pending_test {
                        pending_test = false;
                    }
                    if c == '{' {
                        if pending_test && test_depth.is_none() {
                            test_depth = Some(depth);
                            pending_test = false;
                        }
                        depth += 1;
                        if test_depth.is_some() {
                            line_touched_test = true;
                        }
                    }
                    if c == '}' {
                        depth = depth.saturating_sub(1);
                        if test_depth.is_some_and(|td| depth <= td) {
                            test_depth = None;
                            line_touched_test = true;
                        }
                    }
                    code.push(c);
                    i += 1;
                }
            }
            State::LineComment => {
                comment.push(c);
                code.push(' ');
                i += 1;
            }
            State::BlockComment(n) => {
                if starts_with_at(&src, i, "/*") {
                    state = State::BlockComment(n + 1);
                    code.push_str("  ");
                    i += 2;
                } else if starts_with_at(&src, i, "*/") {
                    state = if n > 1 { State::BlockComment(n - 1) } else { State::Normal };
                    code.push_str("  ");
                    i += 2;
                } else {
                    comment.push(c);
                    code.push(' ');
                    i += 1;
                }
            }
            State::Str => {
                if c == '\\' {
                    code.push_str("  ");
                    i += 2;
                } else {
                    if c == '"' {
                        state = State::Normal;
                    }
                    code.push(' ');
                    i += 1;
                }
            }
            State::RawStr(h) => {
                if c == '"' && (0..h).all(|k| src.get(i + 1 + k) == Some(&'#')) {
                    state = State::Normal;
                    for _ in 0..(1 + h) {
                        code.push(' ');
                    }
                    i += 1 + h;
                } else {
                    code.push(' ');
                    i += 1;
                }
            }
            State::CharLit => {
                if c == '\\' {
                    code.push_str("  ");
                    i += 2;
                } else {
                    if c == '\'' {
                        state = State::Normal;
                    }
                    code.push(' ');
                    i += 1;
                }
            }
        }
    }
    if !code.is_empty() || !comment.is_empty() {
        lines.push(Line {
            code,
            comment,
            in_test: test_depth.is_some() || line_touched_test,
            depth: line_depth,
        });
    }
    lines
}

/// Is the char before `i` part of an identifier (so `i` cannot start a
/// literal prefix like `r"` / `b"`)?
fn prev_is_word(src: &[char], i: usize) -> bool {
    i > 0 && is_word(src[i - 1])
}

/// `Some(hash_count)` when `src[i..]` is the `#*"` opener of a raw string.
fn raw_str_hashes(src: &[char], i: usize) -> Option<usize> {
    let mut h = 0;
    while src.get(i + h) == Some(&'#') {
        h += 1;
    }
    (src.get(i + h) == Some(&'"')).then_some(h)
}

/// `code.find(word)` restricted to whole-word matches (`_` counts as a word
/// character, so `unused_unsafe` does not contain the word `unsafe`).
pub fn find_word(code: &str, word: &str) -> Option<usize> {
    let chars: Vec<char> = code.chars().collect();
    let pat: Vec<char> = word.chars().collect();
    if pat.is_empty() || chars.len() < pat.len() {
        return None;
    }
    for start in 0..=(chars.len() - pat.len()) {
        if chars[start..start + pat.len()] == pat[..]
            && (start == 0 || !is_word(chars[start - 1]))
            && (start + pat.len() == chars.len() || !is_word(chars[start + pat.len()]))
        {
            return Some(start);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strings_and_comments_are_blanked_column_preserving() {
        let src = "let x = \".unwrap()\"; // .unwrap()";
        let lines = scan(&format!("{src}\n"));
        assert_eq!(lines.len(), 1);
        assert!(!lines[0].code.contains(".unwrap()"), "code: {:?}", lines[0].code);
        assert!(lines[0].comment.contains(".unwrap()"));
        assert_eq!(lines[0].code.chars().count(), src.chars().count());
    }

    #[test]
    fn raw_strings_and_char_literals_are_blanked_lifetimes_kept() {
        let lines = scan("fn f<'a>(s: &'a str) { let c = '{'; let r = r#\"panic!\"#; }\n");
        let code = &lines[0].code;
        assert!(code.contains("<'a>"), "lifetime survives: {code:?}");
        assert!(!code.contains("panic!"));
        // the '{' char literal must not count toward depth
        let lines = scan("let c = '{';\nlet d = 1;\n");
        assert_eq!(lines[1].depth, 0);
    }

    #[test]
    fn block_comments_nest_and_tokens_do_not_fuse() {
        let lines = scan("a/* x /* y */ z */b\n");
        let code = &lines[0].code;
        assert!(!code.contains("ab"), "blanking preserves separation: {code:?}");
        assert!(code.contains('a') && code.contains('b'));
        assert!(lines[0].comment.contains('y'));
    }

    #[test]
    fn cfg_test_regions_are_tracked_by_depth() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn t() {}\n}\nfn live2() {}\n";
        let lines = scan(src);
        assert!(!lines[0].in_test);
        assert!(lines[2].in_test, "mod header opens the region");
        assert!(lines[3].in_test);
        assert!(lines[4].in_test, "closing brace still counts");
        assert!(!lines[5].in_test, "region ends with its brace");
    }

    #[test]
    fn cfg_test_use_item_does_not_open_a_region() {
        let src = "#[cfg(test)]\nuse foo::bar;\nfn live() { let _ = 1; }\n";
        let lines = scan(src);
        assert!(!lines[2].in_test, "the `;` cancelled the pending attr");
    }

    #[test]
    fn crlf_sources_scan_identically_to_lf() {
        let lf = "fn f() { x.unwrap(); }\n// lint: allow(no-panic): why\nlet y = 1;\n";
        let crlf = lf.replace('\n', "\r\n");
        let (a, b) = (scan(lf), scan(&crlf));
        assert_eq!(a.len(), b.len());
        for (la, lb) in a.iter().zip(&b) {
            assert_eq!(la.code, lb.code, "code channel is CR-free and identical");
            assert_eq!(la.comment, lb.comment);
        }
        assert!(!b[1].comment.contains('\r'));
    }

    #[test]
    fn find_word_respects_underscore_boundaries() {
        assert!(find_word("#[allow(unused_unsafe)]", "unsafe").is_none());
        assert_eq!(find_word("  unsafe {", "unsafe"), Some(2));
        assert!(find_word("my_unsafe_fn()", "unsafe").is_none());
    }
}
