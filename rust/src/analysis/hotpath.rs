//! Hot-path allocation pass (`hot-path-alloc`, L010): starting from
//! functions declared hot with a `// lint: hot-path` marker, walk the
//! transitive call set (the name-based graph of [`super::parse`]) and
//! reject heap-allocation tokens anywhere inside it — `Vec::new`,
//! `Box::new`, `with_capacity`, `to_vec`, `to_string`, `to_owned`,
//! `collect`, `clone`, `vec!`, `format!`, and friends.
//!
//! This is what turns PR 5's "the steady-state decode loop does not
//! allocate" claim from a review hope into a build failure: the engine's
//! `decode_loop` is the declared root, and every helper it reaches —
//! slot admission, sweeping, queue draining — is checked, however many
//! calls deep the allocation hides.
//!
//! `// lint: hot-path-end` marks a *boundary*: the function is reachable
//! but exempt and not traversed further. The backend `decode_step`
//! implementations carry it — their internals are the model-execution
//! cost the benchmark measures, not scheduler overhead the lint polices.
//!
//! `Vec::new()` is flagged even though a capacity-0 vec does not touch the
//! allocator, because it is almost always followed by growth; the rare
//! deliberate empty-vec handoff carries a waiver
//! (`// lint: allow(hot-path-alloc): <reason>`) so the exception is
//! visible in review.

use super::parse::call_tokens;
use super::rules::macro_called;
use super::{diag, Diagnostic, FileData, Profile, Waivers};
use std::collections::BTreeMap;

/// Substring allocation patterns over blanked code.
const ALLOC_PATTERNS: &[&str] = &[
    "Vec::new(",
    "VecDeque::new(",
    "String::new(",
    "Box::new(",
    "Rc::new(",
    "Arc::new(",
    "HashMap::new(",
    "HashSet::new(",
    "BTreeMap::new(",
    "BTreeSet::new(",
    "with_capacity(",
    ".to_vec(",
    ".to_string(",
    ".to_owned(",
    ".collect(",
    ".collect::<",
    ".clone(",
];

/// Allocating macros (matched word-boundary + `!`).
const ALLOC_MACROS: &[&str] = &["vec", "format"];

/// The declared hot set, exposed for the tier-1 non-vacuity assertions.
#[derive(Debug, Default)]
pub struct HotPathInfo {
    /// Functions carrying a `// lint: hot-path` marker.
    pub roots: Vec<String>,
    /// Every function in the transitive hot set (roots included,
    /// boundaries excluded), sorted.
    pub reached: Vec<String>,
    /// Reachable functions exempted by `// lint: hot-path-end`.
    pub boundaries: Vec<String>,
}

/// Key identifying one function occurrence.
type FnId = (usize, usize); // (file index, fn index within file)

/// Run the hot-path pass. Emits `hot-path-alloc` diagnostics into `out`
/// and returns the hot-set summary.
pub(crate) fn run(
    files: &[FileData],
    waivers: &mut [Waivers],
    out: &mut Vec<Diagnostic>,
) -> HotPathInfo {
    // name -> candidate fns; test fns only resolvable from test callers,
    // mirroring the graph pass.
    let mut by_name: BTreeMap<&str, Vec<FnId>> = BTreeMap::new();
    let mut roots: Vec<FnId> = Vec::new();
    for (fi, fd) in files.iter().enumerate() {
        for (ii, item) in fd.fns.iter().enumerate() {
            by_name.entry(&item.name).or_default().push((fi, ii));
            if item.hot_root {
                roots.push((fi, ii));
            }
        }
    }

    let mut info = HotPathInfo::default();
    // parent call edge for witness paths: child -> (parent, call site)
    let mut parent: BTreeMap<FnId, (FnId, String)> = BTreeMap::new();
    let mut queue: Vec<FnId> = roots.clone();
    let mut seen: Vec<FnId> = roots.clone();
    for &(fi, ii) in &roots {
        info.roots.push(files[fi].fns[ii].name.clone());
    }

    while let Some(id @ (fi, ii)) = queue.pop() {
        let fd = &files[fi];
        let item = &fd.fns[ii];
        if item.hot_end {
            info.boundaries.push(item.name.clone());
            continue;
        }
        info.reached.push(item.name.clone());
        let caller_is_test = fd.profile == Profile::Test || item.in_test;
        for li in item.decl_line..=item.body_end.min(fd.lines.len().saturating_sub(1)) {
            if fd.owners[li] != ii || (fd.profile == Profile::Runtime && fd.lines[li].in_test) {
                continue;
            }
            let code = &fd.lines[li].code;
            for tok in alloc_tokens(code) {
                if waivers[fi].check(li, "hot-path-alloc") {
                    continue;
                }
                diag(
                    out,
                    &fd.rel,
                    li,
                    "hot-path-alloc",
                    format!(
                        "heap allocation `{tok}` in the hot path: {} — the decode loop \
                         must stay allocation-free; reuse a scratch buffer owned by the \
                         caller, or waive with `// lint: allow(hot-path-alloc): <reason>`",
                        witness(files, &parent, id),
                    ),
                );
            }
            for call in call_tokens(code) {
                let Some(cands) = by_name.get(call.name.as_str()) else { continue };
                for &target @ (tfi, tii) in cands {
                    let t = &files[tfi].fns[tii];
                    let target_is_test = files[tfi].profile == Profile::Test || t.in_test;
                    if (target_is_test && !caller_is_test) || seen.contains(&target) {
                        continue;
                    }
                    seen.push(target);
                    parent.insert(
                        target,
                        (id, format!("{}:{}", fd.rel, li + 1)),
                    );
                    queue.push(target);
                }
            }
        }
    }
    info.roots.sort();
    info.reached.sort();
    info.reached.dedup();
    info.boundaries.sort();
    info.boundaries.dedup();
    info
}

/// Allocation tokens present on one blanked code line.
fn alloc_tokens(code: &str) -> Vec<&'static str> {
    let mut out = Vec::new();
    for &pat in ALLOC_PATTERNS {
        // `.collect(` and `.collect::<` describe the same call; report once
        if pat == ".collect::<" && code.contains(".collect(") {
            continue;
        }
        if code.contains(pat) {
            out.push(pat);
        }
    }
    for &m in ALLOC_MACROS {
        if macro_called(code, m) {
            out.push(if m == "vec" { "vec![..]" } else { "format!(..)" });
        }
    }
    out
}

/// Render the root -> … -> here call chain for a finding.
fn witness(
    files: &[FileData],
    parent: &BTreeMap<FnId, (FnId, String)>,
    mut id: FnId,
) -> String {
    let mut parts = vec![files[id.0].fns[id.1].name.clone()];
    while let Some((p, site)) = parent.get(&id) {
        parts.push(format!("{} ({site})", files[p.0].fns[p.1].name));
        id = *p;
    }
    parts.reverse();
    parts.join(" -> ")
}

#[cfg(test)]
mod tests {
    use super::super::{analyze_sources, Profile};

    /// Fixture C: an allocation smuggled two calls deep. Only the root is
    /// marked hot; the `.to_vec()` lives in `helper_two`, reached through
    /// `helper_one` — a per-file lint can never see this.
    #[test]
    fn allocation_two_calls_deep_fires_with_call_chain() {
        let a = "// lint: hot-path\nfn hot_root(&self) {\n    helper_one(1);\n}\n";
        let b = "fn helper_one(&self, n: usize) {\n    helper_two(n);\n}\n\
                 fn helper_two(&self, n: usize) {\n    let v = self.buf.to_vec();\n}\n";
        let an = analyze_sources(&[
            ("serve/hr.rs".into(), a.into(), Profile::Runtime),
            ("serve/hh.rs".into(), b.into(), Profile::Runtime),
        ]);
        let hits: Vec<_> =
            an.diagnostics.iter().filter(|d| d.rule == "hot-path-alloc").collect();
        assert_eq!(hits.len(), 1, "got: {:?}", an.diagnostics);
        assert_eq!((hits[0].file.as_str(), hits[0].line), ("serve/hh.rs", 5));
        let msg = &hits[0].msg;
        assert!(msg.contains("`.to_vec(`"), "{msg}");
        assert!(
            msg.contains("hot_root (serve/hr.rs:3) -> helper_one (serve/hh.rs:2) -> helper_two"),
            "witness chain names every hop: {msg}"
        );
        assert_eq!(an.hot.roots, vec!["hot_root"]);
        assert!(an.hot.reached.contains(&"helper_two".to_string()));
    }

    /// `hot-path-end` stops traversal: the boundary fn's own allocations
    /// are exempt, and nothing past it is visited.
    #[test]
    fn hot_path_end_is_a_traversal_boundary() {
        let src = "// lint: hot-path\nfn hot_root(&self) {\n    boundary(1);\n}\n\n\
                   // lint: hot-path-end\nfn boundary(&self, n: usize) {\n    \
                   let v = vec![0u8; n];\n    deeper(v);\n}\n\n\
                   fn deeper(&self, v: Vec<u8>) {\n    let s = v.to_vec();\n}\n";
        let an = analyze_sources(&[("serve/hb.rs".into(), src.into(), Profile::Runtime)]);
        assert!(
            an.diagnostics.iter().all(|d| d.rule != "hot-path-alloc"),
            "got: {:?}",
            an.diagnostics
        );
        assert_eq!(an.hot.boundaries, vec!["boundary"]);
        assert!(!an.hot.reached.contains(&"deeper".to_string()));
    }

    #[test]
    fn waiver_suppresses_and_counts_as_used() {
        let src = "// lint: hot-path\nfn hot_root(&self) {\n    \
                   // lint: allow(hot-path-alloc): capacity-0, never grows here\n    \
                   let v: Vec<u8> = Vec::new();\n}\n";
        let an = analyze_sources(&[("serve/hw.rs".into(), src.into(), Profile::Runtime)]);
        assert!(
            an.diagnostics.is_empty(),
            "waived alloc and no stale-waiver: {:?}",
            an.diagnostics
        );
    }

    #[test]
    fn macros_and_direct_constructors_fire_in_a_root() {
        let src = "// lint: hot-path\nfn hot_root(&self) {\n    let s = format!(\"x\");\n    \
                   let b = Box::new(1);\n}\n";
        let an = analyze_sources(&[("serve/hm.rs".into(), src.into(), Profile::Runtime)]);
        let rules: Vec<_> = an.diagnostics.iter().map(|d| (d.rule, d.line)).collect();
        assert_eq!(
            rules,
            vec![("hot-path-alloc", 3), ("hot-path-alloc", 4)],
            "got: {:?}",
            an.diagnostics
        );
    }

    /// Functions not reachable from a root are never checked.
    #[test]
    fn cold_functions_may_allocate_freely() {
        let src = "fn cold(&self) {\n    let v = vec![1, 2, 3];\n    let s = v.clone();\n}\n";
        let an = analyze_sources(&[("serve/hc.rs".into(), src.into(), Profile::Runtime)]);
        assert!(an.diagnostics.is_empty(), "got: {:?}", an.diagnostics);
        assert!(an.hot.roots.is_empty() && an.hot.reached.is_empty());
    }
}
